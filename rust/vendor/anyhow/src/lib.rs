//! Offline, API-compatible subset of the `anyhow` crate (the build
//! environment has no crates.io access — see docs/ARCHITECTURE.md,
//! "Crate-availability constraint").
//!
//! Implements exactly the surface this repository uses:
//!
//! * [`Error`]: an opaque error carrying a context chain. `Display`
//!   prints the outermost message, `{:#}` prints the full chain
//!   joined by `": "`, `Debug` prints the chain as a `Caused by:`
//!   list — matching upstream `anyhow` semantics for all three.
//! * [`Result<T>`] with the `E = Error` default.
//! * [`anyhow!`], [`bail!`], [`ensure!`] macros (format-string and
//!   single-expression forms).
//! * [`Context`]: `.context(..)` / `.with_context(|| ..)` on any
//!   `Result<T, E: Into<Error>>` and on `Option<T>`.
//!
//! Unsupported upstream features (unused here): `downcast`,
//! `backtrace`, `chain`, `#[source]` preservation as live objects —
//! sources are flattened to strings at conversion time.

use std::convert::Infallible;
use std::error::Error as StdError;
use std::fmt;

/// An error with a chain of context messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

/// `anyhow::Result<T>`: `std::result::Result` with a defaulted error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    fn wrap<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// Like upstream anyhow, `Error` deliberately does not implement
// `std::error::Error`, which is what makes this blanket `From` (the
// `?` conversion) coherent.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        let mut chain = vec![error.to_string()];
        let mut source = error.source();
        while let Some(cause) = source {
            chain.push(cause.to_string());
            source = cause.source();
        }
        Error { chain }
    }
}

/// Attach context to errors, upstream-`anyhow` style.
pub trait Context<T, E>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T, Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        Err(std::io::Error::other("disk on fire"))?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert_eq!(e.to_string(), "disk on fire");
    }

    #[test]
    fn context_wraps_outermost_first() {
        let e = io_fail().context("loading manifest").unwrap_err();
        assert_eq!(e.to_string(), "loading manifest");
        assert_eq!(format!("{e:#}"), "loading manifest: disk on fire");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
        assert!(dbg.contains("disk on fire"), "{dbg}");
    }

    #[test]
    fn with_context_is_lazy_on_ok() {
        let r: Result<u8, std::num::ParseIntError> = "7".parse();
        let v = r
            .with_context(|| unreachable_context())
            .unwrap();
        assert_eq!(v, 7);

        fn unreachable_context() -> String {
            panic!("context closure must not run on Ok")
        }
    }

    #[test]
    fn option_context() {
        let none: Option<u8> = None;
        let e = none.context("missing field").unwrap_err();
        assert_eq!(e.to_string(), "missing field");
        assert_eq!(Some(3u8).context("x").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky {}", x);
            }
            Err(anyhow!("fell through"))
        }
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(f(7).unwrap_err().to_string(), "unlucky 7");
        assert_eq!(f(1).unwrap_err().to_string(), "fell through");
        let owned = String::from("owned message");
        assert_eq!(anyhow!(owned).to_string(), "owned message");
    }

    #[test]
    fn error_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
