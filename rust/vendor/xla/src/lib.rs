//! Offline, API-compatible subset of the `xla` crate (the PJRT
//! bindings) — the build environment has no crates.io access and no
//! libxla, so this shim supplies exactly the surface
//! `rust/src/runtime/container.rs` uses:
//!
//! * [`PjRtClient::cpu`] — client construction (`!Send`, like the real
//!   `Rc`-based client, so the one-thread-per-container discipline is
//!   enforced by the compiler here too).
//! * [`HloModuleProto::from_text_file`] / [`XlaComputation::from_proto`]
//!   / [`PjRtClient::compile`] — artifact loading and compilation.
//! * [`Literal`] (`vec1`, `reshape`, `to_tuple1`, `to_vec`) and
//!   [`PjRtLoadedExecutable::execute`] returning [`PjRtBuffer`]s with
//!   `to_literal_sync`.
//!
//! Execution semantics: the real crate runs AOT-lowered HLO. Offline
//! we cannot, so `from_text_file` accepts the **`muse-sim-hlo v1`**
//! dialect — a tiny feed-forward program format the compile path can
//! emit alongside (or instead of) true HLO text when targeting this
//! shim — and `compile` produces an interpreter for it. Real HLO text
//! is detected and rejected with a clear error at load time, so a
//! mismatch between artifacts and runtime fails loudly at container
//! startup (the same place the real bindings would fail), never at
//! scoring time.
//!
//! `muse-sim-hlo v1` grammar (whitespace-separated tokens, `#`
//! comments to end of line):
//!
//! ```text
//! muse-sim-hlo v1
//! input <batch> <dim>
//! dense <in> <out>          # then out*in weights (row-major, one
//!                           # output unit after another), then <out>
//!                           # biases
//! relu | tanh | sigmoid     # element-wise activations, any order
//! output 1                  # final width must be 1 score per row
//! ```
//!
//! The interpreter evaluates rows independently, in f32 like the PJRT
//! CPU backend, and returns a 1-tuple of a `[batch]` literal — the
//! same shape contract `aot.py` lowers with (`return_tuple=True`).

use std::fmt;
use std::marker::PhantomData;

/// Error type for the shim; `Debug` matches how the runtime formats
/// real `xla` errors (`{e:?}`).
pub struct Error {
    msg: String,
}

impl Error {
    fn new(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

// ---------------------------------------------------------------
// Literals
// ---------------------------------------------------------------

/// A host literal: an f32 buffer with a shape, or a tuple of literals.
#[derive(Debug, Clone)]
pub struct Literal {
    data: Vec<f32>,
    shape: Vec<i64>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal {
            shape: vec![data.len() as i64],
            data: data.to_vec(),
            tuple: None,
        }
    }

    fn tuple(parts: Vec<Literal>) -> Literal {
        Literal {
            data: vec![],
            shape: vec![],
            tuple: Some(parts),
        }
    }

    /// Reinterpret the buffer under a new shape (element count must
    /// match).
    pub fn reshape(self, dims: &[i64]) -> Result<Literal> {
        if self.tuple.is_some() {
            return Err(Error::new("cannot reshape a tuple literal"));
        }
        let count: i64 = dims.iter().product();
        if count < 0 || count as usize != self.data.len() {
            return Err(Error::new(format!(
                "reshape {:?} -> {:?}: element count mismatch ({} elements)",
                self.shape,
                dims,
                self.data.len()
            )));
        }
        Ok(Literal {
            data: self.data,
            shape: dims.to_vec(),
            tuple: None,
        })
    }

    /// Unwrap a 1-tuple literal (the `return_tuple=True` contract).
    pub fn to_tuple1(self) -> Result<Literal> {
        match self.tuple {
            Some(mut parts) if parts.len() == 1 => Ok(parts.remove(0)),
            Some(parts) => Err(Error::new(format!(
                "expected a 1-tuple, got a {}-tuple",
                parts.len()
            ))),
            None => Err(Error::new("expected a tuple literal")),
        }
    }

    /// Copy out the host buffer.
    pub fn to_vec<T: FromLiteral>(&self) -> Result<Vec<T>> {
        if self.tuple.is_some() {
            return Err(Error::new("cannot to_vec a tuple literal"));
        }
        T::from_f32(&self.data)
    }

    fn rows_cols(&self) -> Result<(usize, usize)> {
        match self.shape.as_slice() {
            [r, c] if *r >= 0 && *c >= 0 => Ok((*r as usize, *c as usize)),
            other => Err(Error::new(format!("expected rank-2 input, got {other:?}"))),
        }
    }
}

/// Element types extractable from a [`Literal`] (f32 only offline).
pub trait FromLiteral: Sized {
    fn from_f32(data: &[f32]) -> Result<Vec<Self>>;
}

impl FromLiteral for f32 {
    fn from_f32(data: &[f32]) -> Result<Vec<f32>> {
        Ok(data.to_vec())
    }
}

// ---------------------------------------------------------------
// Program loading
// ---------------------------------------------------------------

#[derive(Debug, Clone)]
enum Layer {
    /// `weights` is row-major `[out][in]`; `bias` is `[out]`.
    Dense {
        input: usize,
        output: usize,
        weights: Vec<f32>,
        bias: Vec<f32>,
    },
    Relu,
    Tanh,
    Sigmoid,
}

/// Token cursor over the artifact text (comments stripped).
struct Cursor<'a> {
    tokens: Vec<&'a str>,
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(text: &'a str) -> Cursor<'a> {
        Cursor {
            tokens: text
                .lines()
                .map(|l| l.split('#').next().unwrap_or(""))
                .flat_map(str::split_whitespace)
                .collect(),
            pos: 0,
        }
    }

    fn next(&mut self) -> Option<&'a str> {
        let t = self.tokens.get(self.pos).copied();
        if t.is_some() {
            self.pos += 1;
        }
        t
    }

    fn usize(&mut self, what: &str) -> Result<usize> {
        let t = self
            .next()
            .ok_or_else(|| Error::new(format!("unexpected end of program: expected {what}")))?;
        t.parse::<usize>()
            .map_err(|e| Error::new(format!("bad {what} '{t}': {e}")))
    }

    fn f32(&mut self, what: &str) -> Result<f32> {
        let t = self
            .next()
            .ok_or_else(|| Error::new(format!("unexpected end of program: expected {what}")))?;
        t.parse::<f32>()
            .map_err(|e| Error::new(format!("bad {what} '{t}': {e}")))
    }
}

/// A parsed `muse-sim-hlo v1` program (stands in for the HLO proto).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    batch: usize,
    dim: usize,
    layers: Vec<Layer>,
}

impl HloModuleProto {
    /// Load and parse an artifact text file. Real HLO text is rejected
    /// with a descriptive error (this shim interprets only the
    /// `muse-sim-hlo v1` dialect; see the module docs).
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::new(format!("read {path}: {e}")))?;
        Self::parse(&text).map_err(|e| Error::new(format!("{path}: {}", e.msg)))
    }

    /// Parse program text (exposed for tests).
    pub fn parse(text: &str) -> Result<HloModuleProto> {
        let mut c = Cursor::new(text);
        if c.next() != Some("muse-sim-hlo") {
            return Err(Error::new(
                "not a muse-sim-hlo artifact (the offline xla shim cannot execute \
                 true HLO text; re-emit artifacts in the muse-sim-hlo v1 dialect)",
            ));
        }
        let version = c.next();
        if version != Some("v1") {
            return Err(Error::new(format!(
                "unsupported muse-sim-hlo version {version:?}"
            )));
        }
        let mut batch = None;
        let mut dim = None;
        let mut width: Option<usize> = None; // per-row width so far
        let mut layers = Vec::new();
        while let Some(op) = c.next() {
            match op {
                "input" => {
                    let b = c.usize("input batch")?;
                    let d = c.usize("input dim")?;
                    if b == 0 || d == 0 {
                        return Err(Error::new("input batch/dim must be positive"));
                    }
                    batch = Some(b);
                    dim = Some(d);
                    width = Some(d);
                }
                "dense" => {
                    let input = c.usize("dense in-width")?;
                    let output = c.usize("dense out-width")?;
                    let w = width.ok_or_else(|| Error::new("dense before input declaration"))?;
                    if input != w {
                        return Err(Error::new(format!(
                            "dense expects in-width {input} but current width is {w}"
                        )));
                    }
                    if output == 0 {
                        return Err(Error::new("dense out-width must be positive"));
                    }
                    let mut weights = Vec::with_capacity(input * output);
                    for _ in 0..input * output {
                        weights.push(c.f32("dense weight")?);
                    }
                    let mut bias = Vec::with_capacity(output);
                    for _ in 0..output {
                        bias.push(c.f32("dense bias")?);
                    }
                    width = Some(output);
                    layers.push(Layer::Dense {
                        input,
                        output,
                        weights,
                        bias,
                    });
                }
                "relu" => layers.push(Layer::Relu),
                "tanh" => layers.push(Layer::Tanh),
                "sigmoid" => layers.push(Layer::Sigmoid),
                "output" => {
                    let n = c.usize("output width")?;
                    if Some(n) != width {
                        return Err(Error::new(format!(
                            "declared output width {n} but program width is {width:?}"
                        )));
                    }
                }
                other => return Err(Error::new(format!("unknown op '{other}'"))),
            }
        }
        let (Some(batch), Some(dim)) = (batch, dim) else {
            return Err(Error::new("missing input declaration"));
        };
        if width != Some(1) {
            return Err(Error::new(format!(
                "program must end at width 1 (one score per row), got {width:?}"
            )));
        }
        Ok(HloModuleProto { batch, dim, layers })
    }
}

/// The computation wrapper (a pass-through offline).
#[derive(Debug, Clone)]
pub struct XlaComputation {
    program: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {
            program: proto.clone(),
        }
    }
}

// ---------------------------------------------------------------
// Client / executable / buffers
// ---------------------------------------------------------------

/// The PJRT CPU client. `!Send` on purpose (mirrors the `Rc`-based
/// real client): all use stays on the spawning container thread.
pub struct PjRtClient {
    _not_send: PhantomData<*const ()>,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient {
            _not_send: PhantomData,
        })
    }

    /// "Compile": validate once more and wrap an interpreter.
    pub fn compile(&self, computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable {
            program: computation.program.clone(),
            _not_send: PhantomData,
        })
    }
}

/// A device buffer holding an execution result.
#[derive(Debug)]
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

/// A compiled (interpretable) program bound to the client.
pub struct PjRtLoadedExecutable {
    program: HloModuleProto,
    _not_send: PhantomData<*const ()>,
}

impl PjRtLoadedExecutable {
    /// Execute on one input literal of shape `[batch, dim]`; returns
    /// the per-device, per-output buffer grid (1x1 here), each buffer
    /// a 1-tuple of the `[batch]` score vector.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        if args.len() != 1 {
            return Err(Error::new(format!(
                "expected exactly 1 argument, got {}",
                args.len()
            )));
        }
        let input = args[0].borrow();
        let (rows, cols) = input.rows_cols()?;
        if rows != self.program.batch || cols != self.program.dim {
            return Err(Error::new(format!(
                "input shape [{rows}, {cols}] does not match program input [{}, {}]",
                self.program.batch, self.program.dim
            )));
        }
        // One pass over the whole batch per layer, over SoA activation
        // buffers (unit `u`'s lane is `[u*rows, (u+1)*rows)`), instead
        // of re-walking the layer stack row-at-a-time. Dense layers
        // run 8 rows in lockstep so each weight load is amortized
        // across all lanes and the inner loop is contiguous in the
        // activation buffer. Per row the arithmetic is the exact
        // in-order sequence of the row-at-a-time interpreter
        // (`acc = bias[o]; acc += w[i]*x[i]` for `i` ascending; rows
        // never mix), so per-row scores are bitwise identical.
        const LANES: usize = 8;
        let max_width = self
            .program
            .layers
            .iter()
            .map(|l| match l {
                Layer::Dense { output, .. } => *output,
                _ => 0,
            })
            .max()
            .unwrap_or(0)
            .max(cols);
        let mut cur = vec![0.0f32; rows * max_width];
        let mut nxt = vec![0.0f32; rows * max_width];
        let mut width = cols;
        // Transpose the row-major input into SoA lanes once.
        for r in 0..rows {
            for c in 0..cols {
                cur[c * rows + r] = input.data[r * cols + c];
            }
        }
        for layer in &self.program.layers {
            match layer {
                Layer::Dense {
                    input: in_w,
                    output,
                    weights,
                    bias,
                } => {
                    let mut r = 0;
                    while r + LANES <= rows {
                        for o in 0..*output {
                            let wrow = &weights[o * in_w..(o + 1) * in_w];
                            let mut acc = [bias[o]; LANES];
                            for (i, w) in wrow.iter().enumerate() {
                                let lane = &cur[i * rows + r..i * rows + r + LANES];
                                for l in 0..LANES {
                                    acc[l] += w * lane[l];
                                }
                            }
                            nxt[o * rows + r..o * rows + r + LANES]
                                .copy_from_slice(&acc);
                        }
                        r += LANES;
                    }
                    // Remainder rows (rows % 8): scalar per-row loop.
                    for r in r..rows {
                        for o in 0..*output {
                            let wrow = &weights[o * in_w..(o + 1) * in_w];
                            let mut acc = bias[o];
                            for (i, w) in wrow.iter().enumerate() {
                                acc += w * cur[i * rows + r];
                            }
                            nxt[o * rows + r] = acc;
                        }
                    }
                    std::mem::swap(&mut cur, &mut nxt);
                    width = *output;
                }
                Layer::Relu => {
                    for v in cur[..rows * width].iter_mut() {
                        *v = v.max(0.0);
                    }
                }
                Layer::Tanh => {
                    for v in cur[..rows * width].iter_mut() {
                        *v = v.tanh();
                    }
                }
                Layer::Sigmoid => {
                    for v in cur[..rows * width].iter_mut() {
                        *v = 1.0 / (1.0 + (-*v).exp());
                    }
                }
            }
        }
        // The parser guarantees the program ends at width 1, so lane 0
        // of the final buffer is the per-row score vector.
        cur.truncate(rows);
        let out = Literal {
            shape: vec![rows as i64],
            data: cur,
            tuple: None,
        };
        Ok(vec![vec![PjRtBuffer {
            literal: Literal::tuple(vec![out]),
        }]])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const LOGISTIC: &str = "\
muse-sim-hlo v1
# 2-feature logistic model
input 4 2
dense 2 1
  1.0 -1.0
  0.5
sigmoid
output 1
";

    fn run(program: &str, data: &[f32], rows: usize, cols: usize) -> Vec<f32> {
        let proto = HloModuleProto::parse(program).unwrap();
        let client = PjRtClient::cpu().unwrap();
        let exe = client.compile(&XlaComputation::from_proto(&proto)).unwrap();
        let lit = Literal::vec1(data)
            .reshape(&[rows as i64, cols as i64])
            .unwrap();
        let out = exe.execute::<Literal>(&[lit]).unwrap();
        out[0][0]
            .to_literal_sync()
            .unwrap()
            .to_tuple1()
            .unwrap()
            .to_vec::<f32>()
            .unwrap()
    }

    #[test]
    fn logistic_program_matches_closed_form() {
        let data = [0.0, 0.0, 1.0, 0.0, 0.0, 1.0, 2.0, -1.0];
        let got = run(LOGISTIC, &data, 4, 2);
        let sigmoid = |z: f32| 1.0 / (1.0 + (-z).exp());
        let want = [
            sigmoid(0.5),
            sigmoid(1.5),
            sigmoid(-0.5),
            sigmoid(3.5),
        ];
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-6, "{g} vs {w}");
        }
    }

    #[test]
    fn mlp_layers_compose() {
        let program = "\
muse-sim-hlo v1
input 2 2
dense 2 2
  1.0 0.0
  0.0 1.0
  0.0 0.0
relu
dense 2 1
  1.0 1.0
  0.0
sigmoid
output 1
";
        let got = run(program, &[1.0, -2.0, -1.0, -1.0], 2, 2);
        let sigmoid = |z: f32| 1.0 / (1.0 + (-z).exp());
        assert!((got[0] - sigmoid(1.0)).abs() < 1e-6);
        assert!((got[1] - sigmoid(0.0)).abs() < 1e-6);
    }

    /// The lane-parallel SoA interpreter is bitwise-equal to a
    /// row-at-a-time reference (the pre-batched interpreter loop,
    /// kept here as the oracle) for every remainder row count
    /// `rows % 8 ∈ 0..=7` on a deep MLP with mixed activations.
    #[test]
    fn batched_interpreter_matches_row_oracle_bitwise() {
        // Deterministic pseudo-random weights (xorshift; the vendored
        // shim has no dependency on the main crate's rng util).
        let mut state = 0x9E3779B97F4A7C15u64;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            ((state >> 11) as f64 / (1u64 << 53) as f64 * 2.0 - 1.0) as f32
        };
        let dim = 5;
        let hidden = 7;
        for rows in [1usize, 2, 7, 8, 9, 15, 16, 19] {
            let mut program = format!("muse-sim-hlo v1\ninput {rows} {dim}\n");
            program.push_str(&format!("dense {dim} {hidden}\n"));
            let mut w1 = Vec::new();
            for _ in 0..dim * hidden + hidden {
                let v = next();
                w1.push(v);
                program.push_str(&format!("{v} "));
            }
            program.push_str("\nrelu\ntanh\n");
            program.push_str(&format!("dense {hidden} 1\n"));
            let mut w2 = Vec::new();
            for _ in 0..hidden + 1 {
                let v = next();
                w2.push(v);
                program.push_str(&format!("{v} "));
            }
            program.push_str("\nsigmoid\noutput 1\n");
            let data: Vec<f32> = (0..rows * dim).map(|_| next() * 3.0).collect();
            let got = run(&program, &data, rows, dim);
            // Row-at-a-time oracle: the exact per-row op sequence.
            for r in 0..rows {
                let x = &data[r * dim..(r + 1) * dim];
                let mut h = Vec::new();
                for o in 0..hidden {
                    let mut acc = w1[dim * hidden + o];
                    for i in 0..dim {
                        acc += w1[o * dim + i] * x[i];
                    }
                    h.push(acc.max(0.0).tanh());
                }
                let mut acc = w2[hidden];
                for i in 0..hidden {
                    acc += w2[i] * h[i];
                }
                let want = 1.0 / (1.0 + (-acc).exp());
                assert_eq!(
                    got[r].to_bits(),
                    want.to_bits(),
                    "rows={rows} r={r}: batched {} vs oracle {want}",
                    got[r]
                );
            }
        }
    }

    #[test]
    fn rejects_real_hlo_text() {
        let err = HloModuleProto::parse("HloModule jit_forward ...").unwrap_err();
        assert!(format!("{err:?}").contains("muse-sim-hlo"));
    }

    #[test]
    fn rejects_shape_mismatch() {
        let proto = HloModuleProto::parse(LOGISTIC).unwrap();
        let client = PjRtClient::cpu().unwrap();
        let exe = client.compile(&XlaComputation::from_proto(&proto)).unwrap();
        let lit = Literal::vec1(&[0.0; 6]).reshape(&[3, 2]).unwrap();
        assert!(exe.execute::<Literal>(&[lit]).is_err());
    }

    #[test]
    fn rejects_width_and_arity_errors() {
        assert!(HloModuleProto::parse("muse-sim-hlo v1\ninput 1 2\n").is_err()); // width 2 != 1
        assert!(HloModuleProto::parse("muse-sim-hlo v2\n").is_err());
        assert!(
            HloModuleProto::parse("muse-sim-hlo v1\ninput 1 2\ndense 3 1\n0 0 0 0\n").is_err()
        );
    }

    #[test]
    fn reshape_validates_element_count() {
        assert!(Literal::vec1(&[0.0; 4]).reshape(&[2, 2]).is_ok());
        assert!(Literal::vec1(&[0.0; 4]).reshape(&[3, 2]).is_err());
    }
}
