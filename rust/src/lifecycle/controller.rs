//! The lifecycle autopilot: a background state machine per managed
//! (predictor, tenant) pair that closes the paper's Fig. 3 loop
//! without a human in it —
//!
//! ```text
//!            ┌────────────────────────────────────────────────┐
//!            ▼                (validation fails: cooldown)    │
//!  Observing ──drift──▶ FitReady ──Eq.5──▶ ShadowDeployed ────┤
//!      ▲                 (refit T^Q                │pass      │
//!      │                  from sketch)             ▼          │
//!      └──── baseline ◀── Promoted ◀──────── Validated        │
//!            rotated        ▲ (routing swap, COW snapshot)    │
//!                           └─────────────────────────────────┘
//! ```
//!
//! * **Observing** — live raw scores stream from the data plane into
//!   per-worker [`ScoreFeed`] rings, drained each tick into sketches.
//!   With no baseline yet, the pair waits for the Eq. 5 sample gate
//!   and installs the *initial* custom `T^Q` directly (the paper's
//!   Section 3.1 first-fit promotion). With a baseline, tumbling
//!   detection windows are PSI/KS-scored against the distribution
//!   frozen at the last fit.
//! * **FitReady** — drift confirmed; the pair collects a fresh
//!   post-drift sketch until Eq. 5 is satisfied, then refits the
//!   tenant's `T^Q` from the sketch (O(sketch), not O(events)) and
//!   shadow-deploys a candidate predictor carrying it.
//! * **ShadowDeployed** — the existing mirroring machinery feeds the
//!   candidate; once enough mirrored responses accumulate,
//!   `validate_shadow` checks distribution stability. Failure tears
//!   the candidate down and returns to Observing under cooldown.
//! * **Validated → Promoted** — `promote` rewrites the tenant's
//!   scoring rule server-side (one COW snapshot publication, traffic
//!   never pauses), the baseline rotates to the fit distribution, and
//!   the loop re-arms. The replaced predictor is decommissioned when
//!   no routing rule references it anymore (configurable).
//!
//! The hub side ([`LifecycleHub`]) is the data-plane contract: one
//! wait-free feed-table load plus one atomic ring append per scored
//! event, no locks (`EXPERIMENTS.md` "Lifecycle autopilot" measures
//! the overhead). Everything else — draining, sketch merging, drift
//! scoring, control-plane calls — happens at tick rate on a
//! background thread ([`spawn_controller`]) or via
//! `POST /v1/lifecycle/check`.

use super::drift::DriftDetector;
use super::sketch::{DrainStats, QuantileSketch, ScoreFeed, SketchSummary};
use crate::config::{CalibrationStrategy, LifecycleConfig, RoutingConfig};
use crate::coordinator::{ControlPlane, Engine, TenantHandle, TenantInterner};
use crate::transforms::quantile::QuantileMap;
use crate::transforms::{full_range, quantile_fit, FullRangeConfig};
use crate::util::slab::HandleSlab;
use anyhow::{anyhow, Context, Result};
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Marker splitting an autopilot candidate name from its root
/// predictor (`root--lc<seq>-<tenant>`).
const CANDIDATE_MARKER: &str = "--lc";

/// The per-pair control state (see the module diagram).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LifecycleState {
    Observing,
    FitReady,
    ShadowDeployed,
    Validated,
    Promoted,
}

impl LifecycleState {
    pub fn as_str(&self) -> &'static str {
        match self {
            LifecycleState::Observing => "observing",
            LifecycleState::FitReady => "fit_ready",
            LifecycleState::ShadowDeployed => "shadow_deployed",
            LifecycleState::Validated => "validated",
            LifecycleState::Promoted => "promoted",
        }
    }
}

/// Memory-budget tier of a pair's feed ring. At 100k mostly-idle
/// tenants the rings — not the KLL sketches — dominate the lifecycle
/// plane's RSS (`feedStripes × feedCapacity × 8B` each), so the
/// controller sizes each pair's ring to its observed activity:
///
/// * **Hot** — a full `feedStripes × feedCapacity` ring; earned by a
///   tick whose ring pressure (samples drained + samples overwritten)
///   reaches `hotFeedSamples`. Sticky: a hot pair keeps its ring
///   until it goes cold (no resize flapping at the promotion
///   threshold).
/// * **Warm** — a single `warmFeedCapacity` stripe; where every pair
///   starts, and where cold pairs return on renewed traffic.
/// * **Cold** — no ring at all; reached after `coldAfterIdleTicks`
///   consecutive zero-sample drains. The ring is drained into the
///   pair's sketch before eviction (no buffered sample is lost), and
///   renewed traffic is detected from the pair's data-lake record
///   count — samples scored while cold reach the lake but not the
///   sketch, accounted in `lifecycle_cold_missed_samples`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FeedTier {
    Hot,
    Warm,
    Cold,
}

impl FeedTier {
    pub fn as_str(&self) -> &'static str {
        match self {
            FeedTier::Hot => "hot",
            FeedTier::Warm => "warm",
            FeedTier::Cold => "cold",
        }
    }

    /// The ring this tier wants installed (`None`: evicted).
    fn ring_tier(self) -> Option<FeedTier> {
        match self {
            FeedTier::Cold => None,
            t => Some(t),
        }
    }
}

/// One managed (tenant → live predictor) pair. Keyed by
/// [`TenantHandle`] in the hub's pair map; the name fields are
/// interned `Arc<str>`s shared with the router/interner, so an
/// established pair's tick allocates no strings.
struct PairState {
    tenant: Arc<str>,
    /// The pair's interned handle — indexes the feed slabs.
    handle: TenantHandle,
    /// The predictor currently serving the tenant's live traffic.
    predictor: Arc<str>,
    state: LifecycleState,
    /// Fit accumulator: initial calibration (no baseline yet) and the
    /// post-drift refit sample (FitReady).
    fit_acc: QuantileSketch,
    /// Tumbling drift-detection window (Observing with a baseline).
    window: QuantileSketch,
    /// Raw-score distribution frozen at the last installed fit.
    frozen: Option<SketchSummary>,
    /// The summary the current candidate was fitted from; becomes the
    /// new baseline on promotion.
    fit_summary: Option<SketchSummary>,
    shadow: Option<String>,
    /// Ticks spent waiting in ShadowDeployed (starvation guard).
    shadow_ticks: u32,
    cooldown: u32,
    candidate_seq: u64,
    last_psi: f64,
    last_ks: f64,
    fits: u64,
    promotions: u64,
    validation_failures: u64,
    dropped_samples: u64,
    last_error: Option<String>,
    /// Memory-budget tier (see [`FeedTier`]).
    tier: FeedTier,
    /// Tier of the ring currently installed in the feed slab (`None`:
    /// no ring). Reconcile touches the slab only when this disagrees
    /// with `tier` or the pair moved predictor — live rings are
    /// otherwise preserved across ticks.
    ring: Option<FeedTier>,
    /// Predictor whose feed slab holds this pair's ring (lags
    /// `predictor` for one reconcile after a promotion).
    feed_predictor: Arc<str>,
    /// Consecutive ticks whose drain collected zero samples.
    idle_ticks: u32,
    /// The pair's data-lake record count captured at eviction; growth
    /// beyond it re-promotes the pair to Warm.
    lake_count_at_cold: usize,
    /// A provisional cold-start Beta-mixture T^Q is installed for this
    /// pair (`lifecycle.coldstartMinSamples`); cleared when the first
    /// real Eq. 5 fit replaces it.
    coldstart_installed: bool,
}

impl PairState {
    fn new(
        tenant: &str,
        handle: TenantHandle,
        predictor: &Arc<str>,
        cfg: &LifecycleConfig,
    ) -> PairState {
        // Deterministic per-tenant sketch seeds keep runs reproducible.
        let seed = tenant.bytes().fold(0xD81F_5EEDu64, |h, b| {
            h.wrapping_mul(0x100000001B3).wrapping_add(b as u64)
        });
        PairState {
            tenant: Arc::from(tenant),
            handle,
            predictor: Arc::clone(predictor),
            feed_predictor: Arc::clone(predictor),
            tier: FeedTier::Warm,
            ring: None,
            idle_ticks: 0,
            lake_count_at_cold: 0,
            coldstart_installed: false,
            state: LifecycleState::Observing,
            fit_acc: QuantileSketch::with_seed(cfg.sketch_k, seed),
            window: QuantileSketch::with_seed(cfg.sketch_k, seed ^ 0xFF),
            frozen: None,
            fit_summary: None,
            shadow: None,
            shadow_ticks: 0,
            cooldown: 0,
            candidate_seq: 0,
            last_psi: 0.0,
            last_ks: 0.0,
            fits: 0,
            promotions: 0,
            validation_failures: 0,
            dropped_samples: 0,
            last_error: None,
        }
    }

    /// Which sketch is currently fed by the drain.
    fn draining_into_fit(&self) -> bool {
        matches!(self.state, LifecycleState::FitReady)
            || (self.state == LifecycleState::Observing && self.frozen.is_none())
    }
}

/// Public snapshot of one pair, for `/v1/lifecycle` and tests.
#[derive(Debug, Clone)]
pub struct PairStatus {
    pub tenant: String,
    pub predictor: String,
    pub state: LifecycleState,
    pub tier: FeedTier,
    pub fit_samples: u64,
    pub window_samples: u64,
    pub baseline_frozen: bool,
    /// Serving through a provisional cold-start T^Q (no Eq. 5 fit yet).
    pub coldstart: bool,
    pub shadow: Option<String>,
    pub psi: f64,
    pub ks: f64,
    pub fits: u64,
    pub promotions: u64,
    pub validation_failures: u64,
    pub dropped_samples: u64,
    pub last_error: Option<String>,
}

/// Outcome of one controller tick.
#[derive(Debug, Clone)]
pub struct TickReport {
    pub pairs: Vec<PairStatus>,
}

/// Feed lookup: predictor name → handle-indexed feed slab. The outer
/// map is published copy-on-write at predictor-**set**-change rate
/// (rare: a predictor appearing or leaving the managed set); a
/// per-tenant ring install publishes one constant-size segment of the
/// handle's owning slab shard. The old two-level string map recloned
/// every registered tenant's entry per registration — an O(tenants)
/// republish that made onboarding storms quadratic.
type FeedTable = HashMap<Arc<str>, Arc<HandleSlab<Arc<ScoreFeed>>>>;

/// The lifecycle hub: hot-path feed surface + background pair state.
pub struct LifecycleHub {
    cfg: LifecycleConfig,
    /// The engine's tenant interner (shared): pair discovery resolves
    /// handles through it, name-keyed admin probes look up through it,
    /// and the feed slabs mirror its shard count.
    interner: Arc<TenantInterner>,
    feeds: crate::util::swap::SnapCell<FeedTable>,
    /// Bumped after every feed-table change (outer republish or slab
    /// slot install/evict). The engine's per-predictor tenant routes
    /// cache `(epoch, feed)` pairs keyed by [`TenantHandle`]; an epoch
    /// mismatch invalidates the cached feed in one integer compare,
    /// so the hot path never probes the table at all when warm.
    feeds_epoch: AtomicU64,
    /// Keyed by tenant handle; background/tick side only. A pair's
    /// full identity is `(handle, predictor)` — both interned, so a
    /// tick over established pairs allocates no strings.
    pairs: Mutex<BTreeMap<TenantHandle, PairState>>,
    /// The routing config the last discovery pass ran against. The
    /// managed-tenant set is a pure function of `cfg.tenants` plus the
    /// routing rules, so discovery (the only per-tick string work) is
    /// skipped entirely while routing is unchanged. Holding the `Arc`
    /// keeps the pointer identity check sound (no address reuse).
    last_routing: Mutex<Option<Arc<RoutingConfig>>>,
}

impl LifecycleHub {
    pub fn new(cfg: LifecycleConfig, interner: Arc<TenantInterner>) -> LifecycleHub {
        LifecycleHub {
            cfg,
            interner,
            feeds: crate::util::swap::SnapCell::new(Arc::new(FeedTable::new())),
            feeds_epoch: AtomicU64::new(0),
            pairs: Mutex::new(BTreeMap::new()),
            last_routing: Mutex::new(None),
        }
    }

    /// The current feed-table epoch (see the field docs). Monotone;
    /// a cached `(epoch, feed)` pair is valid iff epochs match.
    #[inline]
    pub fn feeds_epoch(&self) -> u64 {
        self.feeds_epoch.load(Ordering::SeqCst)
    }

    /// Resolve a pair's feed ring directly (route-cache rebuild path):
    /// one table load, one name probe, one wait-free slab probe.
    /// `None` for unmanaged or cold pairs.
    pub fn feed_for(&self, predictor: &str, tenant: TenantHandle) -> Option<Arc<ScoreFeed>> {
        let table = self.feeds.load();
        table.get(predictor)?.get(tenant.index())
    }

    pub fn config(&self) -> &LifecycleConfig {
        &self.cfg
    }

    /// Hot-path record: one wait-free feed-table load, one name probe,
    /// one wait-free slab probe, one atomic ring append — no string is
    /// hashed for the tenant. Unregistered (or cold) pairs are ignored
    /// (the controller registers them on its next tick).
    #[inline]
    pub fn record(&self, predictor: &str, tenant: TenantHandle, raw: f64) {
        let table = self.feeds.load();
        if let Some(feed) = table.get(predictor).and_then(|s| s.get(tenant.index())) {
            feed.push(raw);
        }
    }

    /// Batch-path record: the feed is resolved once per (batch,
    /// tenant) group, appends are one atomic each.
    pub fn record_batch(&self, predictor: &str, tenant: TenantHandle, raws: &[f64]) {
        let table = self.feeds.load();
        if let Some(feed) = table.get(predictor).and_then(|s| s.get(tenant.index())) {
            for &r in raws {
                feed.push(r);
            }
        }
    }

    /// Merged live sketch for a pair (everything observed since the
    /// last fit) — the control plane's `fit_custom_quantile` consumes
    /// this instead of replaying the data lake when the autopilot is
    /// tracking the pair. Name-keyed (admin surface): resolves the
    /// handle through the interner.
    pub fn sketch_summary(&self, predictor: &str, tenant: &str) -> Option<SketchSummary> {
        let handle = self.interner.lookup(tenant)?;
        let pairs = self.pairs.lock().unwrap();
        let pair = pairs.get(&handle)?;
        if &*pair.predictor != predictor {
            return None;
        }
        let mut merged = pair.fit_acc.clone();
        merged.merge(&pair.window);
        if merged.is_empty() {
            None
        } else {
            Some(merged.summary())
        }
    }

    /// Current pair statuses without advancing anything.
    pub fn status(&self) -> Vec<PairStatus> {
        self.pairs.lock().unwrap().values().map(pair_status).collect()
    }

    /// Live feed-ring bytes across every installed ring — the
    /// lifecycle plane's dominant RSS term and the tenant-tsunami
    /// scenario's bounded-memory gauge.
    pub fn feed_memory_bytes(&self) -> usize {
        let table = self.feeds.load();
        let mut total = 0;
        for slab in table.values() {
            slab.for_each(|_, feed| total += feed.memory_bytes());
        }
        total
    }

    /// `(hot, warm, cold)` pair counts.
    pub fn tier_counts(&self) -> (usize, usize, usize) {
        let pairs = self.pairs.lock().unwrap();
        let mut counts = (0usize, 0usize, 0usize);
        for p in pairs.values() {
            match p.tier {
                FeedTier::Hot => counts.0 += 1,
                FeedTier::Warm => counts.1 += 1,
                FeedTier::Cold => counts.2 += 1,
            }
        }
        counts
    }

    /// Run one controller pass: discover managed pairs, drain feeds
    /// into sketches, advance every pair's state machine, reconcile
    /// the feed table. Errors on one pair are recorded on that pair
    /// and do not abort the others.
    pub fn tick(&self, engine: &Engine) -> Result<TickReport> {
        let required = quantile_fit::required_samples(
            self.cfg.alert_rate,
            self.cfg.delta,
            self.cfg.z,
        )?;
        let detector = DriftDetector {
            psi_threshold: self.cfg.psi_threshold,
            ks_threshold: self.cfg.ks_threshold,
            bins: self.cfg.drift_bins,
        };
        let snap = engine.load_snapshot();
        let mut pairs = self.pairs.lock().unwrap();

        // 1. Discover managed tenants and their live predictors. The
        //    only string-allocating pass of the tick, and it runs only
        //    when the routing config changed since the last tick — the
        //    managed set is a pure function of `cfg.tenants` plus the
        //    routing rules, so an unchanged config (pointer identity;
        //    the Arc below pins the address) cannot change it.
        let discover = {
            let mut last = self.last_routing.lock().unwrap();
            let changed = last
                .as_ref()
                .map_or(true, |r| !Arc::ptr_eq(r, &snap.routing));
            if changed {
                *last = Some(Arc::clone(&snap.routing));
            }
            changed
        };
        if discover {
            let mut tenants: Vec<&str> = self.cfg.tenants.iter().map(String::as_str).collect();
            if self.cfg.auto_discover {
                for rule in &snap.routing.scoring_rules {
                    for t in &rule.condition.tenants {
                        if !tenants.contains(&t.as_str()) {
                            tenants.push(t);
                        }
                    }
                }
            }
            for tenant in tenants {
                let intent = crate::config::Intent {
                    tenant: tenant.to_string(),
                    ..Default::default()
                };
                let Ok(res) = crate::coordinator::Router::resolve_in(&snap.routing, &intent)
                else {
                    continue; // unroutable tenant: nothing to manage
                };
                let handle = self.interner.resolve(tenant);
                let pair = pairs
                    .entry(handle)
                    .or_insert_with(|| PairState::new(tenant, handle, &res.live, &self.cfg));
                // External reroute/promotion: follow the routing truth.
                // Mid-transition the autopilot owns the routing change,
                // so only re-sync while Observing.
                if pair.state == LifecycleState::Observing && *pair.predictor != *res.live {
                    pair.predictor = Arc::clone(&res.live);
                }
            }
        }

        // 2. Drain feeds into the state-appropriate sketch, and let
        //    the drain result drive the pair's memory tier.
        let table = self.feeds.load();
        for pair in pairs.values_mut() {
            let feed = table
                .get(&*pair.feed_predictor)
                .and_then(|s| s.get(pair.handle.index()));
            let Some(feed) = feed else {
                if pair.tier == FeedTier::Cold {
                    // No ring: watch the pair's lake record count for
                    // renewed traffic. Growth re-promotes to Warm; the
                    // grown-by samples reached the lake but no sketch,
                    // so they are accounted as missed. Shrinkage is
                    // lake-retention decay, not traffic — track it so
                    // decay plus new traffic still nets a detection.
                    let now = engine.lake.count_for(&pair.tenant, &pair.predictor);
                    if now > pair.lake_count_at_cold {
                        let missed = (now - pair.lake_count_at_cold) as u64;
                        engine
                            .counters
                            .add("lifecycle_cold_missed_samples", missed);
                        engine.counters.inc("lifecycle_feed_repromotions");
                        pair.tier = FeedTier::Warm;
                        pair.idle_ticks = 0;
                        // A detection window partially filled *before*
                        // the pair went cold describes the pre-idle
                        // distribution, and the cold gap's samples were
                        // never sketched — evaluating drift across that
                        // splice would compare the frozen baseline
                        // against a stale composite. Discard it
                        // un-evaluated (the fit accumulator is kept:
                        // Eq. 5 counts samples, not windows).
                        if pair.window.count() > 0 {
                            engine
                                .counters
                                .inc("lifecycle_drift_skipped_thin_window");
                            pair.window.reset();
                        }
                    } else {
                        pair.lake_count_at_cold = now;
                    }
                }
                continue; // Warm/Hot without a ring: registered below
            };
            let stats = drain_into(pair, &feed);
            pair.dropped_samples += stats.dropped;
            if stats.dropped > 0 {
                engine.counters.add("lifecycle_samples_dropped", stats.dropped);
            }
            if stats.collected > 0 {
                pair.idle_ticks = 0;
                // Ring pressure — drained plus overwritten — is the
                // hot signal, not drained alone: a warm ring smaller
                // than `hotFeedSamples` saturates (drops) long before
                // its drain count could ever reach the threshold.
                if stats.collected + stats.dropped >= self.cfg.hot_feed_samples {
                    pair.tier = FeedTier::Hot;
                }
            } else {
                pair.idle_ticks = pair.idle_ticks.saturating_add(1);
                if pair.idle_ticks >= self.cfg.cold_after_idle_ticks {
                    pair.tier = FeedTier::Cold;
                    pair.lake_count_at_cold =
                        engine.lake.count_for(&pair.tenant, &pair.predictor);
                }
            }
        }

        // 3. Advance the state machines.
        for pair in pairs.values_mut() {
            if let Err(e) = advance_pair(engine, &self.cfg, &detector, required, pair) {
                pair.last_error = Some(format!("{e:#}"));
                engine.counters.inc("lifecycle_errors");
            }
        }

        // 4. Reconcile the feed table with the pairs' (possibly
        //    promoted) predictors and (possibly changed) tiers. Runs
        //    under the pairs lock so an outgoing ring can be drained
        //    into its pair's sketch before eviction or resize.
        self.reconcile_feeds(engine, &mut pairs);
        drop(pairs);

        engine.counters.inc("lifecycle_ticks");
        Ok(TickReport { pairs: self.status() })
    }

    fn reconcile_feeds(&self, engine: &Engine, pairs: &mut BTreeMap<TenantHandle, PairState>) {
        let mut changed = false;
        let current = self.feeds.load();

        // A. Retire rings whose pair moved predictor, changed tier or
        //    went cold. The outgoing ring drains into the pair's
        //    sketch first — an eviction or resize never loses a
        //    buffered sample (samples racing in behind the drain are
        //    bounded by the route-cache epoch window).
        for pair in pairs.values_mut() {
            let desired = pair.tier.ring_tier();
            let moved = *pair.feed_predictor != *pair.predictor;
            if pair.ring.is_some() && (moved || pair.ring != desired) {
                if let Some(slab) = current.get(&*pair.feed_predictor) {
                    if let Some(feed) = slab.get(pair.handle.index()) {
                        let stats = drain_into(pair, &feed);
                        pair.dropped_samples += stats.dropped;
                        if stats.dropped > 0 {
                            engine
                                .counters
                                .add("lifecycle_samples_dropped", stats.dropped);
                        }
                        slab.clear(pair.handle.index());
                        changed = true;
                    }
                }
                pair.ring = None;
                if desired.is_none() {
                    engine.counters.inc("lifecycle_feed_evictions");
                }
            }
        }

        // B. Republish the outer predictor map only when the managed
        //    predictor *set* changed (slabs are reused by name).
        let mut needed: Vec<Arc<str>> = Vec::new();
        for p in pairs.values() {
            if p.tier != FeedTier::Cold && !needed.iter().any(|n| **n == *p.predictor) {
                needed.push(Arc::clone(&p.predictor));
            }
        }
        let outer_changed = needed.iter().any(|n| !current.contains_key(&**n))
            || current.keys().any(|k| !needed.iter().any(|n| **n == **k));
        let table = if outer_changed {
            changed = true;
            let shards = self.interner.shard_count();
            self.feeds.rcu(|old| {
                let mut next = FeedTable::with_capacity(needed.len());
                for n in &needed {
                    let slab = old
                        .get(&**n)
                        .cloned()
                        .unwrap_or_else(|| Arc::new(HandleSlab::with_shards(shards)));
                    next.insert(Arc::clone(n), slab);
                }
                let next = Arc::new(next);
                (Arc::clone(&next), next)
            })
        } else {
            current
        };

        // C. Install rings the pairs' tiers call for.
        for pair in pairs.values_mut() {
            let Some(tier) = pair.tier.ring_tier() else {
                continue;
            };
            if pair.ring == Some(tier) {
                continue;
            }
            let Some(slab) = table.get(&*pair.predictor) else {
                continue;
            };
            let feed = if tier == FeedTier::Hot {
                ScoreFeed::new(self.cfg.feed_stripes, self.cfg.feed_capacity)
            } else {
                ScoreFeed::new(1, self.cfg.warm_feed_capacity)
            };
            slab.set(pair.handle.index(), Arc::new(feed));
            pair.ring = Some(tier);
            pair.feed_predictor = Arc::clone(&pair.predictor);
            changed = true;
        }

        if changed {
            // After the publish, so a reader pairing the new epoch
            // with the old table is impossible; the benign inverse
            // race (old epoch + new table) self-heals on next use.
            self.feeds_epoch.fetch_add(1, Ordering::SeqCst);
        }
    }
}

/// Drain `feed` into the sketch the pair's state is filling.
fn drain_into(pair: &mut PairState, feed: &ScoreFeed) -> DrainStats {
    if pair.draining_into_fit() {
        let sink = &mut pair.fit_acc;
        feed.drain(|v| sink.insert(v))
    } else {
        let sink = &mut pair.window;
        feed.drain(|v| sink.insert(v))
    }
}

fn pair_status(p: &PairState) -> PairStatus {
    PairStatus {
        tenant: p.tenant.to_string(),
        predictor: p.predictor.to_string(),
        state: p.state,
        tier: p.tier,
        fit_samples: p.fit_acc.count(),
        window_samples: p.window.count(),
        baseline_frozen: p.frozen.is_some(),
        coldstart: p.coldstart_installed,
        shadow: p.shadow.clone(),
        psi: p.last_psi,
        ks: p.last_ks,
        fits: p.fits,
        promotions: p.promotions,
        validation_failures: p.validation_failures,
        dropped_samples: p.dropped_samples,
        last_error: p.last_error.clone(),
    }
}

/// The reference distribution a pair validates and fits against: the
/// live predictor's configured reference.
fn pair_reference(engine: &Engine, predictor: &str) -> crate::transforms::ReferenceDistribution {
    match engine.registry.config(predictor) {
        Some(cfg) => Engine::reference(&cfg.reference),
        None => Engine::reference("fraud-default"),
    }
}

/// Number of equal-mass grid points handed to the full-range /
/// cold-start mixture fitter as a pseudo-sample of the live
/// distribution (`fit_mixture` needs >= 100; more buys moment
/// accuracy at O(grid) cost).
const FULL_RANGE_GRID_POINTS: usize = 257;

/// Fit a tenant T^Q from a sketch summary through the configured
/// calibration strategy — the `lifecycle.calibrationStrategy` seam.
/// Both arms consume the same summary and reference grid and produce
/// the same artifact, so every caller (initial fit, post-drift refit)
/// drives the identical shadow→validate→promote path regardless of
/// strategy.
fn fit_strategy(
    cfg: &LifecycleConfig,
    summary: &SketchSummary,
    refq: &[f64],
) -> Result<QuantileMap> {
    match cfg.calibration_strategy {
        CalibrationStrategy::QuantileMap => summary.fit_quantile_map(refq),
        CalibrationStrategy::FullRange => {
            let fr = FullRangeConfig {
                w: cfg.coldstart_w,
                ..FullRangeConfig::default()
            };
            let grid = summary.quantile_grid(FULL_RANGE_GRID_POINTS);
            full_range::fit_from_grid(&grid, summary.total_weight(), refq, &fr)
        }
    }
}

fn candidate_name(pair: &PairState) -> String {
    let root = pair
        .predictor
        .split(CANDIDATE_MARKER)
        .next()
        .unwrap_or(&pair.predictor);
    format!(
        "{root}{CANDIDATE_MARKER}{}-{}",
        pair.candidate_seq, pair.tenant
    )
}

fn advance_pair(
    engine: &Engine,
    cfg: &LifecycleConfig,
    detector: &DriftDetector,
    required: u64,
    pair: &mut PairState,
) -> Result<()> {
    let cp = ControlPlane::new(engine);
    match pair.state {
        LifecycleState::Observing => {
            if pair.cooldown > 0 {
                pair.cooldown -= 1;
                return Ok(());
            }
            match &pair.frozen {
                None => {
                    // Initial calibration: first custom T^Q, installed
                    // directly once Eq. 5 is satisfied (Section 3.1).
                    if pair.fit_acc.count() >= required {
                        let summary = pair.fit_acc.summary();
                        let refq = pair_reference(engine, &pair.predictor)
                            .quantile_grid(engine.quantile_points);
                        let map = fit_strategy(cfg, &summary, &refq)
                            .context("initial sketch fit")?;
                        engine
                            .predictor(&pair.predictor)?
                            .install_tenant_quantile(&pair.tenant, map.shared());
                        pair.frozen = Some(summary);
                        pair.fit_acc.reset();
                        pair.window.reset();
                        pair.coldstart_installed = false;
                        pair.fits += 1;
                        pair.last_error = None;
                        engine.counters.inc("lifecycle_fits");
                    } else if !pair.coldstart_installed
                        && cfg.coldstart_min_samples > 0
                        && pair.fit_acc.count()
                            >= cfg.coldstart_min_samples.max(engine.quantile_points as u64)
                    {
                        // Cold-start prior (Section 2.4, Eqs. 6-8):
                        // the Eq. 5 gate can take a low-traffic tenant
                        // a long time to fill, and until now fresh
                        // tenants scored through the *identity* map —
                        // raw, uncalibrated scores. Fit the bimodal
                        // Beta mixture to the early sample and install
                        // it as a provisional T^Q. No baseline is
                        // frozen and `fit_acc` keeps accumulating: the
                        // real fit still happens at the gate and
                        // replaces this.
                        let summary = pair.fit_acc.summary();
                        let refq = pair_reference(engine, &pair.predictor)
                            .quantile_grid(engine.quantile_points);
                        let fr = FullRangeConfig {
                            w: cfg.coldstart_w,
                            ..FullRangeConfig::default()
                        };
                        let grid = summary.quantile_grid(FULL_RANGE_GRID_POINTS);
                        let map =
                            full_range::fit_from_grid(&grid, summary.total_weight(), &refq, &fr)
                                .context("cold-start mixture fit")?;
                        engine
                            .predictor(&pair.predictor)?
                            .install_tenant_quantile(&pair.tenant, map.shared());
                        pair.coldstart_installed = true;
                        pair.last_error = None;
                        engine.counters.inc("lifecycle_coldstart_fits");
                    }
                }
                Some(frozen) => {
                    if pair.window.count() >= cfg.min_drift_samples {
                        let report = detector.evaluate(frozen, &pair.window.summary());
                        if !report.evaluated {
                            // Either side was too thin to score — an
                            // explicit non-verdict (satellite-1 fix:
                            // this used to read as PSI=KS=0, i.e. "no
                            // drift"). Keep collecting; don't touch
                            // the last PSI/KS readings.
                            engine
                                .counters
                                .inc("lifecycle_drift_skipped_thin_window");
                            return Ok(());
                        }
                        pair.last_psi = report.psi;
                        pair.last_ks = report.ks;
                        pair.window.reset();
                        if report.drifted {
                            engine.counters.inc("lifecycle_drift_detected");
                            // Collect a *pure* post-drift sample for
                            // the refit.
                            pair.fit_acc.reset();
                            pair.state = LifecycleState::FitReady;
                        }
                    }
                }
            }
        }
        LifecycleState::FitReady => {
            if pair.fit_acc.count() >= required {
                let summary = pair.fit_acc.summary();
                let refq =
                    pair_reference(engine, &pair.predictor).quantile_grid(engine.quantile_points);
                let map = fit_strategy(cfg, &summary, &refq)
                    .context("post-drift sketch refit")?
                    .shared();
                let mut candidate = engine
                    .registry
                    .config(&pair.predictor)
                    .ok_or_else(|| anyhow!("no deploy config for '{}'", pair.predictor))?;
                pair.candidate_seq += 1;
                candidate.name = candidate_name(pair);
                cp.shadow_deploy(&candidate, &pair.tenant, map)
                    .with_context(|| format!("shadow deploy '{}'", candidate.name))?;
                pair.shadow = Some(candidate.name);
                pair.fit_summary = Some(summary);
                pair.shadow_ticks = 0;
                pair.fits += 1;
                pair.last_error = None;
                engine.counters.inc("lifecycle_fits");
                pair.state = LifecycleState::ShadowDeployed;
            }
        }
        LifecycleState::ShadowDeployed => {
            let shadow = pair.shadow.clone().ok_or_else(|| anyhow!("state lost shadow"))?;
            let mirrored = engine.lake.count_for(&pair.tenant, &shadow);
            if mirrored >= cfg.min_validation_samples {
                pair.shadow_ticks = 0;
                let reference = pair_reference(engine, &pair.predictor);
                let v = cp.validate_shadow(
                    &shadow,
                    &pair.tenant,
                    &reference,
                    cfg.min_validation_samples,
                    cfg.validation_tolerance,
                )?;
                if v.pass {
                    pair.state = LifecycleState::Validated;
                } else {
                    // No promote: tear the candidate down, re-arm
                    // under cooldown (baseline unchanged — the drift
                    // is still real, the fit just didn't validate).
                    cp.decommission(&shadow)
                        .with_context(|| format!("tear down failed candidate '{shadow}'"))?;
                    pair.shadow = None;
                    pair.fit_summary = None;
                    pair.validation_failures += 1;
                    pair.cooldown = cfg.cooldown_ticks;
                    pair.window.reset();
                    pair.state = LifecycleState::Observing;
                    engine.counters.inc("lifecycle_validation_failures");
                }
            } else {
                // Starvation guard: the shared lake ring may never
                // retain enough of this tenant's mirrors (retention
                // evicts them as fast as they land). Don't hold a
                // candidate — and its containers and mirror traffic —
                // hostage forever.
                pair.shadow_ticks += 1;
                if pair.shadow_ticks >= cfg.shadow_timeout_ticks {
                    cp.decommission(&shadow)
                        .with_context(|| format!("tear down starved candidate '{shadow}'"))?;
                    pair.shadow = None;
                    pair.fit_summary = None;
                    pair.shadow_ticks = 0;
                    pair.cooldown = cfg.cooldown_ticks;
                    pair.window.reset();
                    pair.state = LifecycleState::Observing;
                    pair.last_error = Some(format!(
                        "shadow '{shadow}' starved: {mirrored}/{} mirrored samples after {} ticks",
                        cfg.min_validation_samples, cfg.shadow_timeout_ticks
                    ));
                    engine.counters.inc("lifecycle_shadow_timeouts");
                }
            }
        }
        LifecycleState::Validated => {
            let shadow = pair.shadow.clone().ok_or_else(|| anyhow!("state lost shadow"))?;
            cp.promote(&pair.tenant, &shadow)
                .with_context(|| format!("promote '{shadow}' for '{}'", pair.tenant))?;
            pair.promotions += 1;
            engine.counters.inc("lifecycle_promotions");
            pair.state = LifecycleState::Promoted;
        }
        LifecycleState::Promoted => {
            let shadow = pair.shadow.take().ok_or_else(|| anyhow!("state lost shadow"))?;
            let old = std::mem::replace(&mut pair.predictor, Arc::from(shadow));
            // The candidate was fitted on the post-drift distribution:
            // that summary *is* the new baseline.
            pair.frozen = pair.fit_summary.take().or(pair.frozen.take());
            pair.fit_acc.reset();
            pair.window.reset();
            // Re-arm FIRST: the rotation above already consumed the
            // shadow, so any error from here on must not leave the
            // pair wedged in Promoted (where every tick would fail on
            // the missing shadow forever).
            pair.state = LifecycleState::Observing;
            if cfg.decommission_old && old != pair.predictor {
                let routing = engine.router.snapshot();
                let referenced = routing
                    .scoring_rules
                    .iter()
                    .any(|r| *r.target_predictor == *old)
                    || routing
                        .shadow_rules
                        .iter()
                        .any(|r| r.target_predictors.iter().any(|t| **t == *old));
                if !referenced {
                    // Best-effort: a lost race with an operator's own
                    // decommission is bookkeeping, not a loop failure
                    // — count it, never fail the pair over it.
                    match cp.decommission(&old) {
                        Ok(()) => engine.counters.inc("lifecycle_decommissions"),
                        Err(_) => engine.counters.inc("lifecycle_decommission_races"),
                    }
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Intent, MuseConfig};
    use crate::coordinator::ScoreRequest;
    use crate::runtime::{ModelPool, SimArtifacts};

    fn sim_engine(yaml: &str) -> (SimArtifacts, Engine) {
        let fix = SimArtifacts::in_temp().unwrap();
        let pool = Arc::new(ModelPool::new(fix.manifest().unwrap()));
        let engine = Engine::build(&MuseConfig::from_yaml(yaml).unwrap(), pool).unwrap();
        (fix, engine)
    }

    const AUTO_CFG: &str = r#"
routing:
  scoringRules:
  - description: "bank1 dedicated"
    condition:
      tenants: ["bank1"]
    targetPredictorName: "p"
  - description: "catch-all"
    condition: {}
    targetPredictorName: "p"
predictors:
- name: p
  experts: [s1]
  quantile: identity
lifecycle:
  enabled: true
"#;

    #[test]
    fn candidate_names_strip_prior_suffixes() {
        let cfg = crate::config::LifecycleConfig::default();
        let base: Arc<str> = Arc::from("base");
        let mut pair = PairState::new("acme", TenantHandle::from_index(0), &base, &cfg);
        pair.candidate_seq = 1;
        assert_eq!(candidate_name(&pair), "base--lc1-acme");
        pair.predictor = "base--lc1-acme".into();
        pair.candidate_seq = 2;
        assert_eq!(candidate_name(&pair), "base--lc2-acme");
    }

    #[test]
    fn record_without_registration_is_a_safe_noop() {
        let interner = Arc::new(TenantInterner::new());
        let hub = LifecycleHub::new(
            crate::config::LifecycleConfig::default(),
            Arc::clone(&interner),
        );
        let nobody = interner.resolve("nobody");
        hub.record("ghost", nobody, 0.5);
        hub.record_batch("ghost", nobody, &[0.1, 0.2]);
        // A handle the interner never issued is equally inert.
        hub.record("ghost", TenantHandle::from_index(7), 0.5);
        assert!(hub.status().is_empty());
        assert!(hub.sketch_summary("ghost", "nobody").is_none());
        assert_eq!(hub.feed_memory_bytes(), 0);
    }

    #[test]
    fn tick_autodiscovers_rule_tenants_and_wires_feeds() {
        let (_fix, engine) = sim_engine(AUTO_CFG);
        let hub = engine.lifecycle.as_ref().unwrap();
        // First tick: pair discovered from the scoring rule's tenant
        // condition, feed registered at the end of the pass.
        hub.tick(&engine).unwrap();
        let status = hub.status();
        assert_eq!(status.len(), 1);
        assert_eq!(status[0].tenant, "bank1");
        assert_eq!(status[0].predictor, "p");
        assert_eq!(status[0].state, LifecycleState::Observing);
        assert_eq!(status[0].fit_samples, 0);

        // Scored traffic now lands in the ring; the next tick drains
        // it into the pair's fit accumulator (no baseline yet).
        let d = engine.predictor("p").unwrap().feature_dim();
        for i in 0..5 {
            engine
                .score(&ScoreRequest {
                    intent: Intent {
                        tenant: "bank1".into(),
                        ..Intent::default()
                    },
                    entity: format!("e{i}"),
                    features: vec![0.05 * i as f32; d],
                })
                .unwrap();
        }
        hub.tick(&engine).unwrap();
        let status = hub.status();
        assert_eq!(status[0].fit_samples, 5, "{status:?}");
        assert_eq!(status[0].dropped_samples, 0);
        assert_eq!(engine.counters.get("lifecycle_ticks"), 2);
        // Catch-all traffic from unmanaged tenants is not tracked.
        engine
            .score(&ScoreRequest {
                intent: Intent {
                    tenant: "stranger".into(),
                    ..Intent::default()
                },
                entity: "x".into(),
                features: vec![0.0; d],
            })
            .unwrap();
        hub.tick(&engine).unwrap();
        assert_eq!(hub.status().len(), 1, "stranger must not be managed");
        engine.drain_shadows();
    }

    #[test]
    fn reconcile_preserves_live_feeds_across_ticks() {
        let (_fix, engine) = sim_engine(AUTO_CFG);
        let hub = engine.lifecycle.as_ref().unwrap();
        let bank1 = engine.tenants.resolve("bank1");
        hub.tick(&engine).unwrap();
        let f1 = hub.feed_for("p", bank1).unwrap();
        hub.tick(&engine).unwrap();
        let f2 = hub.feed_for("p", bank1).unwrap();
        assert!(
            Arc::ptr_eq(&f1, &f2),
            "reconcile must not replace a live feed (in-flight samples would be lost)"
        );
    }

    #[test]
    fn feed_epoch_bumps_only_on_republish() {
        let (_fix, engine) = sim_engine(AUTO_CFG);
        let hub = engine.lifecycle.as_ref().unwrap();
        let bank1 = engine.tenants.resolve("bank1");
        assert_eq!(hub.feeds_epoch(), 0);
        assert!(hub.feed_for("p", bank1).is_none());
        hub.tick(&engine).unwrap(); // registers the bank1 feed
        assert_eq!(hub.feeds_epoch(), 1);
        let feed = hub.feed_for("p", bank1).unwrap();
        hub.tick(&engine).unwrap(); // unchanged world: no republish
        assert_eq!(
            hub.feeds_epoch(),
            1,
            "an unchanged feed table must not invalidate cached routes"
        );
        assert!(Arc::ptr_eq(&feed, &hub.feed_for("p", bank1).unwrap()));
    }

    const TIER_CFG: &str = r#"
routing:
  scoringRules:
  - description: "bank1 dedicated"
    condition:
      tenants: ["bank1"]
    targetPredictorName: "p"
  - description: "catch-all"
    condition: {}
    targetPredictorName: "p"
predictors:
- name: p
  experts: [s1]
  quantile: identity
lifecycle:
  enabled: true
  hotFeedSamples: 4
  coldAfterIdleTicks: 2
  warmFeedCapacity: 64
"#;

    fn score_n(engine: &Engine, tenant: &str, n: usize) {
        let d = engine.predictor("p").unwrap().feature_dim();
        for i in 0..n {
            engine
                .score(&ScoreRequest {
                    intent: Intent {
                        tenant: tenant.into(),
                        ..Intent::default()
                    },
                    entity: format!("e{i}"),
                    features: vec![0.05 * (i % 16) as f32; d],
                })
                .unwrap();
        }
    }

    #[test]
    fn tiers_promote_on_volume_and_evict_on_idle() {
        let (_fix, engine) = sim_engine(TIER_CFG);
        let hub = engine.lifecycle.as_ref().unwrap();
        let bank1 = engine.tenants.resolve("bank1");

        hub.tick(&engine).unwrap(); // pair discovered, warm ring wired
        assert_eq!(hub.tier_counts(), (0, 1, 0));
        let warm_bytes = hub.feed_memory_bytes();
        assert!(warm_bytes > 0);

        // A drain at/above hotFeedSamples earns the full-size ring.
        score_n(&engine, "bank1", 5);
        hub.tick(&engine).unwrap();
        assert_eq!(hub.tier_counts(), (1, 0, 0));
        assert_eq!(hub.status()[0].fit_samples, 5, "resize must not drop samples");
        assert!(
            hub.feed_memory_bytes() > warm_bytes,
            "hot ring must be larger than warm"
        );

        // coldAfterIdleTicks zero-sample drains evict the ring.
        hub.tick(&engine).unwrap();
        hub.tick(&engine).unwrap();
        assert_eq!(hub.tier_counts(), (0, 0, 1));
        assert!(hub.feed_for("p", bank1).is_none(), "cold pair keeps no ring");
        assert_eq!(hub.feed_memory_bytes(), 0);
        assert_eq!(engine.counters.get("lifecycle_feed_evictions"), 1);
        assert_eq!(hub.status()[0].fit_samples, 5, "eviction must not drop samples");

        // Traffic while cold reaches the lake but no ring; the next
        // tick notices the lake growth, accounts the missed samples
        // and re-promotes the pair to Warm with a fresh ring.
        score_n(&engine, "bank1", 3);
        hub.tick(&engine).unwrap();
        assert_eq!(hub.tier_counts(), (0, 1, 0));
        assert!(hub.feed_for("p", bank1).is_some());
        assert_eq!(engine.counters.get("lifecycle_feed_repromotions"), 1);
        assert_eq!(engine.counters.get("lifecycle_cold_missed_samples"), 3);
        engine.drain_shadows();
    }

    /// Scores `n` events whose features (and hence raw scores) are all
    /// distinct — continuous enough that a quantile fit never trips
    /// the satellite-2 knot-collapse gate.
    fn score_spread(engine: &Engine, tenant: &str, n: usize) {
        let d = engine.predictor("p").unwrap().feature_dim();
        for i in 0..n {
            engine
                .score(&ScoreRequest {
                    intent: Intent {
                        tenant: tenant.into(),
                        ..Intent::default()
                    },
                    entity: format!("e{i}"),
                    features: vec![0.9 * (i as f32 + 0.5) / n as f32; d],
                })
                .unwrap();
        }
    }

    /// Lax Eq. 5 (`required` = 1) so the initial fit freezes a
    /// baseline as soon as the sketch can carry a grid; minDrift stays
    /// high enough that a partial window never evaluates.
    const SEAM_CFG: &str = r#"
routing:
  scoringRules:
  - description: "bank1 dedicated"
    condition:
      tenants: ["bank1"]
    targetPredictorName: "p"
  - description: "catch-all"
    condition: {}
    targetPredictorName: "p"
predictors:
- name: p
  experts: [s1]
  quantile: identity
lifecycle:
  enabled: true
  alertRate: 0.5
  delta: 1.0
  z: 0.1
  minDriftSamples: 64
  coldAfterIdleTicks: 2
  warmFeedCapacity: 256
"#;

    #[test]
    fn repromoted_pair_discards_stale_window_unevaluated() {
        // Regression (ISSUE 10 satellite 1): a detection window
        // partially filled before a pair went Cold used to survive
        // eviction and repromotion, so the next drift evaluation
        // compared the frozen baseline against a stale pre-idle
        // composite spliced with post-gap traffic. The exact sequence:
        // fit baseline → partial window → idle to Cold → traffic
        // while cold → repromote. The stale window must be discarded
        // un-evaluated, and the skip accounted.
        let (_fix, engine) = sim_engine(SEAM_CFG);
        let hub = engine.lifecycle.as_ref().unwrap();

        hub.tick(&engine).unwrap(); // discover + wire warm ring
        score_spread(&engine, "bank1", 150);
        hub.tick(&engine).unwrap(); // drains 150 >= required -> initial fit
        let st = &hub.status()[0];
        assert!(st.baseline_frozen, "initial fit must have frozen: {st:?}");
        assert_eq!(st.fits, 1);

        // Partial window: below minDriftSamples, so never evaluated.
        score_spread(&engine, "bank1", 30);
        hub.tick(&engine).unwrap();
        assert_eq!(hub.status()[0].window_samples, 30);

        // Idle to Cold (ring drained into the window, then evicted).
        hub.tick(&engine).unwrap();
        hub.tick(&engine).unwrap();
        assert_eq!(hub.tier_counts(), (0, 0, 1));
        assert_eq!(hub.status()[0].window_samples, 30, "eviction keeps the window");

        // Traffic while cold reaches the lake only; repromotion must
        // throw the stale window away rather than splice over the gap.
        score_spread(&engine, "bank1", 5);
        hub.tick(&engine).unwrap();
        assert_eq!(hub.tier_counts(), (0, 1, 0));
        assert_eq!(
            hub.status()[0].window_samples,
            0,
            "stale pre-cold window must not survive repromotion"
        );
        assert_eq!(
            engine.counters.get("lifecycle_drift_skipped_thin_window"),
            1,
            "the discarded window must be accounted as a skipped evaluation"
        );
        engine.drain_shadows();
    }

    const COLDSTART_CFG: &str = r#"
routing:
  scoringRules:
  - description: "bank1 dedicated"
    condition:
      tenants: ["bank1"]
    targetPredictorName: "p"
  - description: "catch-all"
    condition: {}
    targetPredictorName: "p"
predictors:
- name: p
  experts: [s1]
  quantile: identity
lifecycle:
  enabled: true
  alertRate: 0.01
  coldstartMinSamples: 100
  coldstartW: 0.02
  warmFeedCapacity: 256
"#;

    #[test]
    fn coldstart_installs_mixture_map_before_eq5_gate() {
        // Tentpole part 3: a fresh tenant far from the Eq. 5 gate
        // (a=0.01 needs ~9.5k samples) gets a provisional Beta-mixture
        // T^Q from its first ~150 samples instead of serving raw
        // identity scores until the gate fills.
        let (_fix, engine) = sim_engine(COLDSTART_CFG);
        let hub = engine.lifecycle.as_ref().unwrap();

        hub.tick(&engine).unwrap();
        assert!(!engine.predictor("p").unwrap().has_tenant_quantile("bank1"));
        score_spread(&engine, "bank1", 150);
        hub.tick(&engine).unwrap();

        let st = &hub.status()[0];
        assert!(st.coldstart, "cold-start map must be flagged: {st:?}");
        assert!(!st.baseline_frozen, "cold-start must not freeze a baseline");
        assert_eq!(st.fits, 0, "cold-start is not an Eq. 5 fit");
        assert_eq!(st.last_error, None);
        assert_eq!(engine.counters.get("lifecycle_coldstart_fits"), 1);
        assert!(
            engine.predictor("p").unwrap().has_tenant_quantile("bank1"),
            "the tenant must now score through the mixture T^Q"
        );

        // More traffic below the gate: the provisional map is fitted
        // once, not churned every tick.
        score_spread(&engine, "bank1", 50);
        hub.tick(&engine).unwrap();
        assert_eq!(engine.counters.get("lifecycle_coldstart_fits"), 1);
        engine.drain_shadows();
    }

    #[test]
    fn full_range_strategy_drives_the_same_initial_fit_path() {
        // The calibrationStrategy seam end-to-end: with fullRange
        // configured, the Eq. 5 initial fit installs a mixture-backed
        // T^Q through the exact same Observing arm.
        let yaml = SEAM_CFG.replace("minDriftSamples: 64", "minDriftSamples: 64\n  calibrationStrategy: fullRange");
        let (_fix, engine) = sim_engine(&yaml);
        let hub = engine.lifecycle.as_ref().unwrap();
        assert_eq!(
            hub.config().calibration_strategy,
            crate::config::CalibrationStrategy::FullRange
        );
        hub.tick(&engine).unwrap();
        score_spread(&engine, "bank1", 150);
        hub.tick(&engine).unwrap();
        let st = &hub.status()[0];
        assert_eq!(st.fits, 1, "{st:?}");
        assert!(st.baseline_frozen);
        assert_eq!(st.last_error, None);
        assert!(engine.predictor("p").unwrap().has_tenant_quantile("bank1"));
        engine.drain_shadows();
    }
}

/// Handle to the background controller thread.
pub struct LifecycleController {
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl LifecycleController {
    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }
}

impl Drop for LifecycleController {
    fn drop(&mut self) {
        self.stop();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Spawn the autopilot loop: one [`LifecycleHub::tick`] every
/// `lifecycle.checkIntervalMs`. Errors are recorded on the pair (and
/// in `lifecycle_errors`) — the loop never dies on a failed tick.
pub fn spawn_controller(engine: Arc<Engine>) -> Result<LifecycleController> {
    let hub = engine
        .lifecycle
        .clone()
        .ok_or_else(|| anyhow!("lifecycle is not enabled in the config"))?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop_t = Arc::clone(&stop);
    let interval = Duration::from_millis(hub.config().check_interval_ms.max(1));
    let thread = std::thread::Builder::new()
        .name("lifecycle-controller".into())
        .spawn(move || {
            while !stop_t.load(Ordering::SeqCst) {
                let _ = hub.tick(&engine);
                // Sleep in small slices so stop() is prompt.
                let mut left = interval;
                while !stop_t.load(Ordering::SeqCst) && left > Duration::ZERO {
                    let step = left.min(Duration::from_millis(50));
                    std::thread::sleep(step);
                    left = left.saturating_sub(step);
                }
            }
        })
        .context("spawn lifecycle controller")?;
    Ok(LifecycleController {
        stop,
        thread: Some(thread),
    })
}
