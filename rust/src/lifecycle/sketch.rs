//! Mergeable streaming quantile sketch (KLL-style) + the lock-free
//! per-worker score buffer that feeds it from the scoring hot path.
//!
//! The lifecycle autopilot needs the live score distribution of every
//! (predictor, tenant) pair, continuously, without touching the data
//! plane. Replaying the `DataLake` is O(events) per refit and grows
//! without bound; the sketch gives the same quantile surface in
//! O(k·log(n/k)) memory with O(1) amortized insert, and two sketches
//! merge losslessly (error bounds add sub-linearly), so per-worker
//! buffers can be drained into one authoritative sketch by a
//! background thread.
//!
//! # Structure
//!
//! A [`QuantileSketch`] is a stack of levels; items at level `i` carry
//! weight `2^i`. Inserts push weight-1 items into level 0. A full
//! level (≥ `k` items) is sorted and *compacted*: a random-offset
//! half of its items is promoted to the next level at double weight.
//! Each compaction of a level with item weight `w` perturbs any rank
//! query by at most `w`, and a level sees at most `n/(k·2^i)`
//! compactions, so the total normalized rank error is bounded by
//! `(L-1)/k` for `L` levels — `L ≈ log2(n/k) + 1`. [`epsilon`] reports
//! `(2(L-1) + 2)/k`, a deliberately conservative version of that bound
//! (the factor 2 absorbs the ±1 total-weight drift a compaction of an
//! odd-length level can introduce); the property tests in this module
//! hold the sketch to it across adversarial streams.
//!
//! Exact stream min/max are tracked separately so the fitted `T^Q`
//! support endpoints never collapse inward under compaction.
//!
//! [`epsilon`]: QuantileSketch::epsilon
//!
//! # Hot-path feed
//!
//! [`ScoreFeed`] is the data-plane side: a set of striped rings of
//! `AtomicU64` cells. A worker thread appends with one `fetch_add`
//! (its stripe's head cursor) and one `swap` (the cell) — no mutex,
//! no CAS loop, no allocation. Stripes are assigned per thread from a
//! thread-local, so concurrent workers do not contend on one cursor.
//! If producers lap the drainer the oldest samples are overwritten;
//! the drainer accounts the loss in [`DrainStats::dropped`] (a sketch
//! is a sample of the distribution anyway — bounded loss under burst
//! is the designed degradation, in contrast to an unbounded queue).

use crate::util::rng::Rng;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Minimum compaction capacity (below this the error bound is
/// meaningless and compaction overhead dominates).
pub const MIN_K: usize = 8;

/// A mergeable KLL-style quantile sketch over `f64` scores.
#[derive(Debug, Clone)]
pub struct QuantileSketch {
    k: usize,
    /// True number of inserted samples (merges included).
    count: u64,
    /// `levels[i]` holds items of weight `2^i`; only level 0 receives
    /// raw inserts. Levels are unsorted between compactions.
    levels: Vec<Vec<f64>>,
    /// Exact stream extremes (compaction may drop the retained ones).
    min: f64,
    max: f64,
    rng: Rng,
}

impl QuantileSketch {
    /// `k` is the per-level compaction capacity: higher `k`, lower
    /// error, more memory. Seeded deterministically from `k` so runs
    /// are reproducible; use [`QuantileSketch::with_seed`] to vary.
    pub fn new(k: usize) -> QuantileSketch {
        QuantileSketch::with_seed(k, 0x4B4C_4C00 ^ k as u64)
    }

    pub fn with_seed(k: usize, seed: u64) -> QuantileSketch {
        let k = k.max(MIN_K);
        QuantileSketch {
            k,
            count: 0,
            levels: vec![Vec::new()],
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            rng: Rng::new(seed),
        }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// Number of samples observed (not retained).
    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Retained items across all levels — the actual memory footprint,
    /// bounded by `k · levels()` ≤ `k · (log2(n/k) + 2)`.
    pub fn memory_items(&self) -> usize {
        self.levels.iter().map(Vec::len).sum()
    }

    pub fn levels(&self) -> usize {
        self.levels.len()
    }

    /// Conservative normalized rank-error bound for the current state
    /// (see the module docs for the derivation). Quantile queries are
    /// accurate to ±`epsilon()` in rank across the whole range.
    pub fn epsilon(&self) -> f64 {
        (2.0 * (self.levels.len() - 1) as f64 + 2.0) / self.k as f64
    }

    /// Forget everything (start a fresh observation window).
    pub fn reset(&mut self) {
        self.count = 0;
        self.levels.clear();
        self.levels.push(Vec::new());
        self.min = f64::INFINITY;
        self.max = f64::NEG_INFINITY;
    }

    /// O(1) amortized: a push, plus a compaction cascade whose total
    /// work over n inserts is O(n) (each item is touched once per
    /// level it passes through, and half die at every promotion).
    pub fn insert(&mut self, x: f64) {
        if !x.is_finite() {
            return; // scores are finite by contract; never poison the sketch
        }
        self.count += 1;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        self.levels[0].push(x);
        if self.levels[0].len() >= self.k {
            self.compact_cascade(0);
        }
    }

    /// Merge another sketch into this one (level-wise concatenation +
    /// re-compaction). Error bounds are preserved: compaction counts
    /// stay bounded by the combined weight passing through each level.
    pub fn merge(&mut self, other: &QuantileSketch) {
        if other.count == 0 {
            return;
        }
        self.count += other.count;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        while self.levels.len() < other.levels.len() {
            self.levels.push(Vec::new());
        }
        for (i, lvl) in other.levels.iter().enumerate() {
            self.levels[i].extend_from_slice(lvl);
        }
        for i in 0..self.levels.len() {
            if self.levels[i].len() >= self.k {
                self.compact_cascade(i);
            }
        }
    }

    fn compact_cascade(&mut self, mut i: usize) {
        while i < self.levels.len() && self.levels[i].len() >= self.k {
            if i + 1 == self.levels.len() {
                self.levels.push(Vec::new());
            }
            let offset = usize::from(self.rng.bernoulli(0.5));
            let mut lvl = std::mem::take(&mut self.levels[i]);
            lvl.sort_unstable_by(|a, b| a.partial_cmp(b).expect("finite by insert contract"));
            let promoted = lvl.iter().skip(offset).step_by(2);
            self.levels[i + 1].extend(promoted);
            i += 1;
        }
    }

    /// Immutable weighted summary for quantile/CDF queries — O(m log m)
    /// in retained items, built once and queried many times (drift
    /// scoring, `T^Q` grid extraction).
    pub fn summary(&self) -> SketchSummary {
        let mut items: Vec<(f64, u64)> = Vec::with_capacity(self.memory_items());
        for (i, lvl) in self.levels.iter().enumerate() {
            let w = 1u64 << i;
            items.extend(lvl.iter().map(|&v| (v, w)));
        }
        items.sort_unstable_by(|a, b| a.0.partial_cmp(&b.0).expect("finite by insert contract"));
        let total: u64 = items.iter().map(|&(_, w)| w).sum();
        SketchSummary {
            items,
            total,
            min: self.min,
            max: self.max,
        }
    }
}

/// Sorted weighted view of a [`QuantileSketch`] at one instant.
#[derive(Debug, Clone)]
pub struct SketchSummary {
    /// (value, weight), sorted by value.
    items: Vec<(f64, u64)>,
    total: u64,
    min: f64,
    max: f64,
}

impl SketchSummary {
    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Total retained weight (≈ the observed sample count).
    pub fn total_weight(&self) -> u64 {
        self.total
    }

    /// Estimated quantile at probability `p` — the smallest retained
    /// value whose cumulative weight reaches `p · total`. Endpoints
    /// return the exact stream min/max.
    pub fn quantile(&self, p: f64) -> f64 {
        assert!(!self.is_empty(), "quantile of empty sketch");
        let p = p.clamp(0.0, 1.0);
        if p == 0.0 {
            return self.min;
        }
        if p == 1.0 {
            return self.max;
        }
        let target = p * self.total as f64;
        let mut cum = 0u64;
        for &(v, w) in &self.items {
            cum += w;
            if cum as f64 >= target {
                return v;
            }
        }
        self.max
    }

    /// Estimated CDF: fraction of observed mass ≤ `x`.
    pub fn cdf(&self, x: f64) -> f64 {
        if self.is_empty() {
            return 0.0;
        }
        // items is sorted by value: binary search the upper bound.
        let idx = self.items.partition_point(|&(v, _)| v <= x);
        let below: u64 = self.items[..idx].iter().map(|&(_, w)| w).sum();
        below as f64 / self.total as f64
    }

    /// Quantiles at the uniform `n_points` probability grid — the
    /// source grid of a `T^Q` refit (`q^S_i` of Eq. 4), computed in
    /// one cumulative pass. Non-decreasing by construction; callers
    /// fitting a `QuantileMap` dedup ties with
    /// `quantile_fit::dedup_monotone`.
    pub fn quantile_grid(&self, n_points: usize) -> Vec<f64> {
        assert!(n_points >= 2);
        assert!(!self.is_empty(), "quantile grid of empty sketch");
        let mut out = Vec::with_capacity(n_points);
        out.push(self.min);
        let mut cum = 0u64;
        let mut iter = self.items.iter();
        let mut cur = iter.next();
        for i in 1..n_points - 1 {
            let target = i as f64 / (n_points - 1) as f64 * self.total as f64;
            while let Some(&(v, w)) = cur {
                if (cum + w) as f64 >= target {
                    out.push(v);
                    break;
                }
                cum += w;
                cur = iter.next();
            }
            if out.len() < i + 1 {
                out.push(self.max);
            }
        }
        out.push(self.max);
        out
    }

    /// Fit a tenant `T^Q` from this sketch: the merged quantile grid
    /// is paired with the reference grid through the generic
    /// `quantile_fit::fit_from_grid` primitive — O(sketch items),
    /// never O(events). This adapter lives on the sketch side so the
    /// `transforms` layer stays independent of the lifecycle
    /// subsystem.
    pub fn fit_quantile_map(
        &self,
        ref_quantiles: &[f64],
    ) -> anyhow::Result<crate::transforms::QuantileMap> {
        anyhow::ensure!(
            self.total >= ref_quantiles.len() as u64,
            "sketch holds {} samples for {} quantile points",
            self.total,
            ref_quantiles.len()
        );
        crate::transforms::quantile_fit::fit_from_grid(
            self.quantile_grid(ref_quantiles.len()),
            self.total,
            ref_quantiles,
        )
    }

    /// As [`SketchSummary::fit_quantile_map`], gated by the Eq. 5
    /// sample bound on the sketch's observed weight.
    pub fn fit_quantile_map_gated(
        &self,
        ref_quantiles: &[f64],
        alert_rate: f64,
        delta: f64,
        z: f64,
    ) -> anyhow::Result<crate::transforms::QuantileMap> {
        let need = crate::transforms::quantile_fit::required_samples(alert_rate, delta, z)?;
        anyhow::ensure!(
            self.total >= need.max(ref_quantiles.len() as u64),
            "insufficient samples for quantile fit: sketch has {}, Eq.5 requires {need} \
             (a={alert_rate}, delta={delta}, z={z})",
            self.total
        );
        crate::transforms::quantile_fit::fit_grid_gated(
            self.quantile_grid(ref_quantiles.len()),
            self.total,
            ref_quantiles,
            alert_rate,
            delta,
            z,
        )
    }
}

// ---------------------------------------------------------------
// Hot-path feed
// ---------------------------------------------------------------

/// Sentinel for an empty ring cell. Scores are packed as widened f32
/// bit patterns (≤ `u32::MAX`), so `u64::MAX` is unreachable.
const EMPTY: u64 = u64::MAX;

#[inline]
fn pack(score: f64) -> u64 {
    // f32 resolution is far below the sketch's rank error; one cell
    // per event keeps the append a single atomic store.
    (score as f32).to_bits() as u64
}

#[inline]
fn unpack(bits: u64) -> f64 {
    f32::from_bits(bits as u32) as f64
}

static NEXT_THREAD_SLOT: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Stable per-thread stripe index, assigned on first use.
    static THREAD_SLOT: usize = NEXT_THREAD_SLOT.fetch_add(1, Ordering::Relaxed);
}

struct Stripe {
    /// Total pushes ever made to this stripe (not wrapped).
    head: AtomicU64,
    /// `head` as of the last drain (drainer-only bookkeeping).
    drained_head: AtomicU64,
    slots: Box<[AtomicU64]>,
}

/// Outcome of one [`ScoreFeed::drain`] pass.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DrainStats {
    /// Samples handed to the sink.
    pub collected: u64,
    /// Estimated samples lost to ring overwrite since the last drain
    /// (producers lapping the drainer).
    pub dropped: u64,
}

/// Lock-free multi-producer score buffer between the scoring hot path
/// and the lifecycle drainer. See the module docs for the contract.
pub struct ScoreFeed {
    stripes: Vec<Stripe>,
}

impl ScoreFeed {
    /// `stripes` rings of `capacity` cells each. Capacity is rounded
    /// up to a power of two so the ring index is a mask, not a `%`.
    pub fn new(stripes: usize, capacity: usize) -> ScoreFeed {
        let stripes = stripes.max(1);
        let capacity = capacity.max(64).next_power_of_two();
        ScoreFeed {
            stripes: (0..stripes)
                .map(|_| Stripe {
                    head: AtomicU64::new(0),
                    drained_head: AtomicU64::new(0),
                    slots: (0..capacity).map(|_| AtomicU64::new(EMPTY)).collect(),
                })
                .collect(),
        }
    }

    /// Hot-path append: one `fetch_add` + one `swap`, both on the
    /// caller's stripe. Never blocks, never allocates, never loops.
    #[inline]
    pub fn push(&self, score: f64) {
        let slot = THREAD_SLOT.with(|s| *s);
        let stripe = &self.stripes[slot % self.stripes.len()];
        let mask = stripe.slots.len() - 1;
        let i = stripe.head.fetch_add(1, Ordering::Relaxed) as usize & mask;
        stripe.slots[i].store(pack(score), Ordering::Release);
    }

    /// Harvest every occupied cell into `sink`, leaving the ring
    /// empty. Background-thread rate; concurrent pushes may land
    /// before or after the sweep — either way they are collected by
    /// this pass or the next.
    pub fn drain(&self, mut sink: impl FnMut(f64)) -> DrainStats {
        let mut stats = DrainStats::default();
        for stripe in &self.stripes {
            let head = stripe.head.load(Ordering::Acquire);
            let mut collected = 0u64;
            for cell in stripe.slots.iter() {
                let bits = cell.swap(EMPTY, Ordering::Acquire);
                if bits != EMPTY {
                    sink(unpack(bits));
                    collected += 1;
                }
            }
            let prev = stripe.drained_head.swap(head, Ordering::Relaxed);
            let produced = head - prev;
            stats.collected += collected;
            stats.dropped += produced.saturating_sub(collected);
        }
        stats
    }

    /// Total pushes across stripes (tests / monitoring).
    pub fn pushed(&self) -> u64 {
        self.stripes
            .iter()
            .map(|s| s.head.load(Ordering::Relaxed))
            .sum()
    }

    /// Ring-buffer bytes this feed holds live (slots only — the
    /// dominant term). Drives the lifecycle memory budget: at 100k
    /// mostly-idle tenants the rings, not the KLL sketches, are the
    /// RSS story, so tier transitions resize exactly this.
    pub fn memory_bytes(&self) -> usize {
        self.stripes
            .iter()
            .map(|s| s.slots.len() * std::mem::size_of::<u64>())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop;

    /// Exact normalized rank of `q` in `data` (fraction ≤ q).
    fn exact_rank(data: &[f64], q: f64) -> f64 {
        data.iter().filter(|&&x| x <= q).count() as f64 / data.len() as f64
    }

    fn assert_within_epsilon(data: &[f64], sketch: &QuantileSketch, tag: &str) {
        let eps = sketch.epsilon();
        let s = sketch.summary();
        for i in 0..=20 {
            let p = i as f64 / 20.0;
            let q = s.quantile(p);
            let r = exact_rank(data, q);
            // The sketch's value at rank p must sit within eps of p.
            // `exact_rank` counts ties as ≤, so allow the tie mass on
            // the low side by also accepting rank-of-strictly-less.
            let r_lo = data.iter().filter(|&&x| x < q).count() as f64 / data.len() as f64;
            assert!(
                r + 1e-12 >= p - eps && r_lo <= p + eps,
                "{tag}: p={p} q={q} rank={r} rank_lo={r_lo} eps={eps} n={}",
                data.len()
            );
        }
    }

    fn streams(n: usize, seed: u64) -> Vec<(&'static str, Vec<f64>)> {
        let mut rng = Rng::new(seed);
        let uniform: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
        let mut sorted = uniform.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut reversed = sorted.clone();
        reversed.reverse();
        let heavy: Vec<f64> = (0..n).map(|_| rng.f64().powi(8)).collect();
        let dup: Vec<f64> = (0..n).map(|i| if i % 3 == 0 { 0.25 } else { rng.f64() }).collect();
        vec![
            ("uniform", uniform),
            ("sorted", sorted),
            ("reversed", reversed),
            ("heavy-tail", heavy),
            ("duplicates", dup),
        ]
    }

    #[test]
    fn prop_quantiles_within_epsilon_on_adversarial_streams() {
        prop::check(12, |g| {
            let n = g.usize(500..6000);
            let k = *g.pick(&[64usize, 128, 256]);
            let seed = g.u64();
            for (tag, data) in streams(n, seed) {
                let mut s = QuantileSketch::with_seed(k, seed ^ 0xA5);
                for &x in &data {
                    s.insert(x);
                }
                prop_assert!(s.count() == n as u64, "count mismatch");
                // Can't use assert_within_epsilon (panics) inside a
                // prop; inline the check with prop_assert.
                let eps = s.epsilon();
                let sum = s.summary();
                for i in 0..=20 {
                    let p = i as f64 / 20.0;
                    let q = sum.quantile(p);
                    let r = exact_rank(&data, q);
                    let r_lo =
                        data.iter().filter(|&&x| x < q).count() as f64 / data.len() as f64;
                    prop_assert!(
                        r + 1e-12 >= p - eps && r_lo <= p + eps,
                        "{tag}: p={p} q={q} rank={r} rank_lo={r_lo} eps={eps} n={n} k={k}"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn memory_is_bounded_and_logarithmic() {
        let k = 128;
        let mut s = QuantileSketch::new(k);
        let mut rng = Rng::new(3);
        let n = 200_000u64;
        for _ in 0..n {
            s.insert(rng.f64());
        }
        let max_levels = ((n as f64 / k as f64).log2().ceil() as usize) + 2;
        assert!(
            s.levels() <= max_levels,
            "levels {} > log bound {max_levels}",
            s.levels()
        );
        assert!(
            s.memory_items() <= k * s.levels(),
            "memory {} items exceeds k*levels = {}",
            s.memory_items(),
            k * s.levels()
        );
        // The documented epsilon stays useful at this scale.
        assert!(s.epsilon() < 0.4, "epsilon degenerate: {}", s.epsilon());
    }

    #[test]
    fn exact_below_k() {
        // Fewer than k items: no compaction ever ran, quantiles exact.
        let mut s = QuantileSketch::new(256);
        let data: Vec<f64> = (0..100).map(|i| i as f64 / 99.0).collect();
        for &x in &data {
            s.insert(x);
        }
        let sum = s.summary();
        assert_eq!(sum.quantile(0.0), 0.0);
        assert_eq!(sum.quantile(1.0), 1.0);
        assert!((sum.quantile(0.5) - 0.494949).abs() < 0.02);
        assert_eq!(s.memory_items(), 100);
    }

    #[test]
    fn merge_matches_concatenated_stream() {
        let mut rng = Rng::new(11);
        let a_data: Vec<f64> = (0..8_000).map(|_| rng.f64().powi(2)).collect();
        let b_data: Vec<f64> = (0..12_000).map(|_| 1.0 - rng.f64().powi(3)).collect();
        let mut a = QuantileSketch::with_seed(256, 1);
        let mut b = QuantileSketch::with_seed(256, 2);
        for &x in &a_data {
            a.insert(x);
        }
        for &x in &b_data {
            b.insert(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), 20_000);
        let mut all = a_data;
        all.extend_from_slice(&b_data);
        assert_within_epsilon(&all, &a, "merged");
    }

    #[test]
    fn merge_empty_and_into_empty() {
        let mut a = QuantileSketch::new(64);
        let b = QuantileSketch::new(64);
        a.merge(&b);
        assert!(a.is_empty());
        let mut c = QuantileSketch::new(64);
        c.insert(0.5);
        a.merge(&c);
        assert_eq!(a.count(), 1);
        assert_eq!(a.summary().quantile(0.5), 0.5);
    }

    #[test]
    fn reset_clears_state() {
        let mut s = QuantileSketch::new(64);
        for i in 0..1000 {
            s.insert(i as f64);
        }
        s.reset();
        assert!(s.is_empty());
        assert_eq!(s.memory_items(), 0);
        s.insert(0.7);
        assert_eq!(s.summary().quantile(0.5), 0.7);
    }

    #[test]
    fn non_finite_inserts_are_ignored() {
        let mut s = QuantileSketch::new(64);
        s.insert(f64::NAN);
        s.insert(f64::INFINITY);
        s.insert(0.3);
        assert_eq!(s.count(), 1);
    }

    #[test]
    fn cdf_and_quantile_agree() {
        let mut s = QuantileSketch::new(256);
        let mut rng = Rng::new(5);
        for _ in 0..10_000 {
            s.insert(rng.f64());
        }
        let sum = s.summary();
        for i in 1..10 {
            let p = i as f64 / 10.0;
            let q = sum.quantile(p);
            assert!(
                (sum.cdf(q) - p).abs() < 2.0 * s.epsilon() + 0.01,
                "p={p} q={q} cdf={}",
                sum.cdf(q)
            );
        }
    }

    #[test]
    fn quantile_grid_is_monotone_and_spans_extremes() {
        let mut s = QuantileSketch::new(128);
        let mut rng = Rng::new(6);
        for _ in 0..5_000 {
            s.insert(rng.f64() * 0.5 + 0.25);
        }
        let grid = s.summary().quantile_grid(65);
        assert_eq!(grid.len(), 65);
        for w in grid.windows(2) {
            assert!(w[1] >= w[0], "grid not monotone");
        }
        assert_eq!(grid[0], s.summary().quantile(0.0));
        assert_eq!(grid[64], s.summary().quantile(1.0));
    }

    #[test]
    fn feed_roundtrip_single_thread() {
        let feed = ScoreFeed::new(2, 64);
        for i in 0..50 {
            feed.push(i as f64 / 50.0);
        }
        let mut got = Vec::new();
        let stats = feed.drain(|v| got.push(v));
        assert_eq!(stats.collected, 50);
        assert_eq!(stats.dropped, 0);
        assert_eq!(got.len(), 50);
        // Values survive the f32 packing within f32 resolution.
        got.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for (i, v) in got.iter().enumerate() {
            assert!((v - i as f64 / 50.0).abs() < 1e-6);
        }
        // Second drain finds nothing.
        let stats = feed.drain(|_| panic!("ring should be empty"));
        assert_eq!(stats, DrainStats::default());
    }

    #[test]
    fn feed_overflow_drops_oldest_and_accounts_it() {
        let feed = ScoreFeed::new(1, 64);
        for i in 0..200 {
            feed.push(i as f64);
        }
        let mut got = Vec::new();
        let stats = feed.drain(|v| got.push(v));
        assert_eq!(stats.collected, 64);
        assert_eq!(stats.dropped, 136);
        // Survivors are the newest writes.
        for v in got {
            assert!(v >= 136.0, "stale value {v} survived overwrite");
        }
    }

    #[test]
    fn feed_concurrent_producers_lose_nothing_within_capacity() {
        use std::sync::Arc;
        let feed = Arc::new(ScoreFeed::new(8, 1024));
        let per_thread = 500usize; // 8 * 500 << 8 * 1024
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let feed = Arc::clone(&feed);
                std::thread::spawn(move || {
                    for i in 0..per_thread {
                        feed.push((t * per_thread + i) as f64);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let mut got = Vec::new();
        let stats = feed.drain(|v| got.push(v));
        // Threads map to stripes by a process-global thread counter,
        // so several may share a stripe; capacity 1024 per stripe
        // against ≤ 8·500 writes still cannot overflow a stripe unless
        // all 8 land on one (impossible: 8 distinct slots cover ≥ 1
        // stripe each ... but two threads on one stripe is fine:
        // 2·500 < 1024). Worst legal case: 2 threads/stripe.
        assert!(stats.collected >= 2 * per_thread as u64, "{stats:?}");
        assert_eq!(stats.collected + stats.dropped, 8 * per_thread as u64);
        // No torn values: everything collected is one of the pushes.
        for v in got {
            assert!(v.fract() == 0.0 && (0.0..4000.0).contains(&v), "torn value {v}");
        }
    }

    #[test]
    fn feed_drain_into_sketch() {
        let feed = ScoreFeed::new(4, 256);
        let mut rng = Rng::new(8);
        let mut pushed = Vec::new();
        for _ in 0..600 {
            let v = rng.f64();
            pushed.push(v);
            feed.push(v);
        }
        let mut sketch = QuantileSketch::new(128);
        let stats = feed.drain(|v| sketch.insert(v));
        assert_eq!(stats.collected, 600);
        assert_eq!(sketch.count(), 600);
        assert_within_epsilon(&pushed, &sketch, "drained");
    }
}
