//! The lifecycle autopilot (closing the paper's Fig. 3 loop): online
//! score-distribution tracking with mergeable streaming quantile
//! sketches fed lock-free from the data plane (`sketch`), PSI/KS
//! drift detection against the distribution frozen at the last fit
//! (`drift`), and a background shadow→validate→promote state machine
//! per managed (predictor, tenant) pair (`controller`) that refits
//! `T^Q` from sketches — O(sketch), never O(events) — and drives the
//! existing control-plane machinery with zero client interaction.

pub mod controller;
pub mod drift;
pub mod sketch;

pub use controller::{
    spawn_controller, FeedTier, LifecycleController, LifecycleHub, LifecycleState, PairStatus,
    TickReport,
};
pub use drift::{fit_ready, ks, psi, DriftDetector, DriftReport};
pub use sketch::{DrainStats, QuantileSketch, ScoreFeed, SketchSummary};
