//! Drift detection over sketched score distributions: PSI and KS
//! statistics between the live window and the distribution frozen at
//! the last `T^Q` fit, plus the Eq. 5 fit-readiness gate.
//!
//! The comparison is *sketch vs sketch*: both sides are
//! [`SketchSummary`] views, so a drift check costs O(retained items),
//! never O(events). PSI bins are the frozen distribution's own
//! quantile edges (equal-mass bins), which makes the expected share
//! exactly `1/bins` and concentrates sensitivity where the baseline
//! actually has mass — the standard population-stability construction.
//!
//! Interpretation conventions (industry-standard PSI bands): < 0.1 no
//! shift, 0.1–0.25 moderate, > 0.25 significant. The default
//! controller threshold sits at 0.25; KS (max CDF gap) defaults to
//! 0.15. Both must be cheap enough to run every controller tick.

use super::sketch::SketchSummary;
use crate::transforms::quantile_fit::required_samples;
use anyhow::Result;

/// Floor for observed/expected shares so empty bins contribute a
/// large-but-finite PSI term instead of ±∞.
const SHARE_FLOOR: f64 = 1e-4;

/// Population Stability Index of `live` against `baseline` over
/// `bins` equal-mass baseline bins (`(o - e) ln(o/e)` per bin, all
/// terms ≥ 0, summed). Bin edges come from the baseline's quantiles;
/// the expected share is the baseline's *actual* CDF mass between the
/// edges (≈ `1/bins` for continuous baselines, but exact under heavy
/// ties, where equal-mass edges collapse — score distributions pile
/// up near 0 in fraud workloads, and identical tie-heavy
/// distributions must yield PSI ≈ 0, not a false alarm).
///
/// `None` when either sketch is empty: an empty side means the
/// comparison never happened, which is *not* the same thing as
/// "no drift" (0.0). Callers deciding whether to alarm must treat
/// `None` as not-evaluated, never as stability evidence.
pub fn psi(baseline: &SketchSummary, live: &SketchSummary, bins: usize) -> Option<f64> {
    assert!(bins >= 2);
    if baseline.is_empty() || live.is_empty() {
        return None;
    }
    let mut total = 0.0;
    let mut prev_edge = f64::NEG_INFINITY;
    let mut prev_base_cdf = 0.0;
    let mut prev_live_cdf = 0.0;
    for b in 1..=bins {
        let (edge, base_cdf, live_cdf) = if b == bins {
            (f64::INFINITY, 1.0, 1.0)
        } else {
            let e = baseline.quantile(b as f64 / bins as f64);
            (e, baseline.cdf(e), live.cdf(e))
        };
        // Collapsed (zero-width) bin: fold into the next one.
        if b < bins && edge <= prev_edge {
            continue;
        }
        let expected = (base_cdf - prev_base_cdf).max(SHARE_FLOOR);
        let observed = (live_cdf - prev_live_cdf).max(SHARE_FLOOR);
        total += (observed - expected) * (observed / expected).ln();
        prev_edge = edge;
        prev_base_cdf = base_cdf;
        prev_live_cdf = live_cdf;
    }
    Some(total)
}

/// Two-sample Kolmogorov–Smirnov statistic between two sketches:
/// max CDF gap evaluated over both sketches' quantile grids.
///
/// `None` when either sketch is empty — same contract as [`psi`]:
/// not-evaluated is a distinct outcome from "no drift".
pub fn ks(a: &SketchSummary, b: &SketchSummary, grid_points: usize) -> Option<f64> {
    assert!(grid_points >= 2);
    if a.is_empty() || b.is_empty() {
        return None;
    }
    let mut d: f64 = 0.0;
    for src in [a, b] {
        for i in 0..grid_points {
            let x = src.quantile(i as f64 / (grid_points - 1) as f64);
            d = d.max((a.cdf(x) - b.cdf(x)).abs());
        }
    }
    Some(d)
}

/// Detector thresholds (from `lifecycle` config).
#[derive(Debug, Clone, Copy)]
pub struct DriftDetector {
    pub psi_threshold: f64,
    pub ks_threshold: f64,
    pub bins: usize,
}

/// One drift evaluation. `evaluated: false` means at least one side
/// of the comparison was empty, so `psi`/`ks` carry no information
/// (they are reported as 0.0 but MUST NOT be read as "no drift") and
/// `drifted` is `false` because nothing was established either way.
/// The controller counts such outcomes in
/// `lifecycle_drift_skipped_thin_window` instead of rotating state.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DriftReport {
    pub psi: f64,
    pub ks: f64,
    pub drifted: bool,
    pub evaluated: bool,
}

impl DriftReport {
    /// The not-evaluated outcome (empty baseline or live window).
    pub fn skipped() -> DriftReport {
        DriftReport {
            psi: 0.0,
            ks: 0.0,
            drifted: false,
            evaluated: false,
        }
    }
}

impl DriftDetector {
    pub fn evaluate(&self, baseline: &SketchSummary, live: &SketchSummary) -> DriftReport {
        let (Some(psi_v), Some(ks_v)) = (
            psi(baseline, live, self.bins),
            ks(baseline, live, 4 * self.bins + 1),
        ) else {
            return DriftReport::skipped();
        };
        DriftReport {
            psi: psi_v,
            ks: ks_v,
            drifted: psi_v > self.psi_threshold || ks_v > self.ks_threshold,
            evaluated: true,
        }
    }
}

/// Eq. 5 fit-readiness: does the sketch hold enough samples to refit
/// `T^Q` at target alert rate `a` within relative error `delta` at
/// confidence `z`? (Same bound the manual control-plane fit enforces;
/// the autopilot just evaluates it against the sketch count.)
pub fn fit_ready(samples: u64, alert_rate: f64, delta: f64, z: f64) -> Result<bool> {
    Ok(samples >= required_samples(alert_rate, delta, z)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lifecycle::sketch::QuantileSketch;
    use crate::util::rng::Rng;

    fn sketch_of(f: impl Fn(&mut Rng) -> f64, n: usize, seed: u64) -> QuantileSketch {
        let mut rng = Rng::new(seed);
        let mut s = QuantileSketch::with_seed(1024, seed ^ 0x11);
        for _ in 0..n {
            s.insert(f(&mut rng));
        }
        s
    }

    fn detector() -> DriftDetector {
        DriftDetector {
            psi_threshold: 0.25,
            ks_threshold: 0.15,
            bins: 10,
        }
    }

    #[test]
    fn identical_distributions_do_not_drift() {
        let a = sketch_of(|r| r.beta(2.0, 8.0), 20_000, 1).summary();
        let b = sketch_of(|r| r.beta(2.0, 8.0), 20_000, 2).summary();
        let rep = detector().evaluate(&a, &b);
        assert!(rep.psi < 0.05, "psi {} on identical dists", rep.psi);
        assert!(rep.ks < 0.05, "ks {} on identical dists", rep.ks);
        assert!(!rep.drifted);
    }

    #[test]
    fn mean_shift_is_detected_by_both() {
        let a = sketch_of(|r| 0.3 + 0.1 * r.normal(), 20_000, 3).summary();
        let b = sketch_of(|r| 0.5 + 0.1 * r.normal(), 20_000, 4).summary();
        let rep = detector().evaluate(&a, &b);
        assert!(rep.psi > 0.25, "psi {} too small for a 2σ shift", rep.psi);
        assert!(rep.ks > 0.15, "ks {} too small for a 2σ shift", rep.ks);
        assert!(rep.drifted);
    }

    #[test]
    fn variance_change_is_detected() {
        let a = sketch_of(|r| 0.5 + 0.05 * r.normal(), 20_000, 5).summary();
        let b = sketch_of(|r| 0.5 + 0.20 * r.normal(), 20_000, 6).summary();
        let rep = detector().evaluate(&a, &b);
        // A pure variance change moves little of the median mass, so
        // KS can be modest — PSI on equal-mass bins must catch it.
        assert!(rep.drifted, "variance x4 not detected: {rep:?}");
        assert!(rep.psi > 0.25, "psi {}", rep.psi);
    }

    #[test]
    fn tail_only_shift_registers_in_psi() {
        // 85% identical, 15% of mass teleports to the upper tail: the
        // fraud-wave shape the drift-storm scenario creates. Analytic
        // PSI: top bin observed ≈ 0.235 vs expected 0.1 contributes
        // 0.135·ln(2.35) ≈ 0.115, the other bins ≈ 0.02 — ≈ 0.14
        // total, comfortably above the 0.1 assertion floor even though
        // most of the distribution is unchanged.
        let a = sketch_of(|r| r.beta(2.0, 8.0), 30_000, 7).summary();
        let b = sketch_of(
            |r| {
                if r.bernoulli(0.15) {
                    0.9 + 0.05 * r.f64()
                } else {
                    r.beta(2.0, 8.0)
                }
            },
            30_000,
            8,
        )
        .summary();
        let rep = detector().evaluate(&a, &b);
        assert!(rep.psi > 0.1, "tail shift psi {}", rep.psi);
    }

    #[test]
    fn psi_is_near_zero_for_small_noise_and_large_for_disjoint() {
        let a = sketch_of(|r| r.f64(), 10_000, 9).summary();
        let b = sketch_of(|r| r.f64(), 10_000, 10).summary();
        assert!(psi(&a, &b, 10).unwrap() < 0.05);
        let c = sketch_of(|r| 2.0 + r.f64(), 10_000, 11).summary();
        let v = psi(&a, &c, 10).unwrap();
        assert!(v > 2.0, "disjoint psi {v}");
    }

    #[test]
    fn ks_matches_known_uniform_gap() {
        // U(0,1) vs U(0.25, 1.25): analytic KS = 0.25.
        let a = sketch_of(|r| r.f64(), 40_000, 12).summary();
        let b = sketch_of(|r| 0.25 + r.f64(), 40_000, 13).summary();
        let d = ks(&a, &b, 101).unwrap();
        assert!((d - 0.25).abs() < 0.03, "ks {d} vs analytic 0.25");
    }

    #[test]
    fn degenerate_baselines_do_not_panic() {
        // All-ties baseline collapses every equal-mass bin edge.
        let a = sketch_of(|_| 0.5, 5_000, 14).summary();
        let b = sketch_of(|r| r.f64(), 5_000, 15).summary();
        let v = psi(&a, &b, 10).unwrap();
        assert!(v.is_finite() && v > 0.25, "point mass vs uniform: psi {v}");
        // Identical tie-heavy distributions must NOT false-alarm.
        let c = sketch_of(|_| 0.5, 5_000, 16).summary();
        assert!(psi(&a, &c, 10).unwrap() < 0.05, "identical point masses drifted");
    }

    #[test]
    fn empty_sketches_are_not_evaluated_not_no_drift() {
        // Regression (ISSUE 10 satellite 1): psi()/ks() used to return
        // 0.0 — "no drift" — when either sketch was empty. A caller
        // comparing a repromoted pair's empty window against its
        // baseline would read perfect stability out of zero data.
        let b = sketch_of(|r| r.f64(), 5_000, 15).summary();
        let empty = QuantileSketch::new(64).summary();
        assert_eq!(psi(&empty, &b, 10), None);
        assert_eq!(ks(&empty, &b, 11), None);
        assert_eq!(psi(&b, &empty, 10), None);
        assert_eq!(ks(&b, &empty, 11), None);
        assert_eq!(psi(&empty, &empty, 10), None);
        // The detector surfaces the same outcome as a typed report.
        let rep = detector().evaluate(&empty, &b);
        assert!(!rep.evaluated && !rep.drifted, "{rep:?}");
        assert_eq!(rep, DriftReport::skipped());
        let rep = detector().evaluate(&b, &b);
        assert!(rep.evaluated, "{rep:?}");
    }

    #[test]
    fn fit_ready_tracks_eq5() {
        // a=0.1, delta=0.2, z=1.96 => n ≈ 865.
        assert!(!fit_ready(800, 0.1, 0.2, 1.96).unwrap());
        assert!(fit_ready(900, 0.1, 0.2, 1.96).unwrap());
        assert!(fit_ready(0, 0.5, 1.0, 0.1).is_ok());
        assert!(fit_ready(10, 0.0, 0.2, 1.96).is_err());
    }
}
