//! Ensemble aggregation `A` (paper Section 2.3.2).
//!
//! Combines the calibrated expert scores into one prediction. The
//! default is a weighted average; weights can be tuned per client or
//! shared across predictors, enabling "rapid, low-cost optimization of
//! ensemble behavior" without retraining experts.

use anyhow::{ensure, Result};

/// Aggregation strategy over calibrated expert scores.
#[derive(Debug, Clone, PartialEq)]
pub enum Aggregation {
    /// Weighted arithmetic mean with per-expert weights.
    WeightedMean(Vec<f64>),
    /// Plain arithmetic mean.
    Mean,
    /// Maximum (useful for "any expert alarms" policies; kept for
    /// configuration completeness, not used by the paper exhibits).
    Max,
    /// Identity for single-model predictors (paper: "the aggregation
    /// function A is the identity").
    Identity,
}

impl Aggregation {
    pub fn weighted(weights: Vec<f64>) -> Result<Self> {
        ensure!(!weights.is_empty(), "weights must be non-empty");
        ensure!(
            weights.iter().all(|w| w.is_finite() && *w >= 0.0),
            "weights must be finite and non-negative"
        );
        ensure!(
            weights.iter().sum::<f64>() > 0.0,
            "at least one weight must be positive"
        );
        Ok(Aggregation::WeightedMean(weights))
    }

    /// Number of expert inputs this aggregation expects (None = any).
    pub fn arity(&self) -> Option<usize> {
        match self {
            Aggregation::WeightedMean(w) => Some(w.len()),
            Aggregation::Identity => Some(1),
            _ => None,
        }
    }

    /// Combine calibrated scores into a single score.
    pub fn apply(&self, scores: &[f64]) -> Result<f64> {
        ensure!(!scores.is_empty(), "no scores to aggregate");
        match self {
            Aggregation::Identity => {
                ensure!(scores.len() == 1, "identity aggregation expects 1 score");
                Ok(scores[0])
            }
            Aggregation::Mean => Ok(scores.iter().sum::<f64>() / scores.len() as f64),
            Aggregation::Max => Ok(scores.iter().cloned().fold(f64::MIN, f64::max)),
            Aggregation::WeightedMean(w) => {
                ensure!(
                    w.len() == scores.len(),
                    "weight arity {} != score arity {}",
                    w.len(),
                    scores.len()
                );
                let num: f64 = scores.iter().zip(w).map(|(s, w)| s * w).sum();
                Ok(num / w.iter().sum::<f64>())
            }
        }
    }

    /// Hot-path variant: no allocation, panics are impossible once the
    /// predictor is validated at build time.
    #[inline]
    pub fn apply_unchecked(&self, scores: &[f64]) -> f64 {
        match self {
            Aggregation::Identity => scores[0],
            Aggregation::Mean => scores.iter().sum::<f64>() / scores.len() as f64,
            Aggregation::Max => scores.iter().cloned().fold(f64::MIN, f64::max),
            Aggregation::WeightedMean(w) => {
                let mut num = 0.0;
                let mut den = 0.0;
                for (s, w) in scores.iter().zip(w) {
                    num += s * w;
                    den += w;
                }
                num / den
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop;

    #[test]
    fn weighted_mean_basic() {
        let a = Aggregation::weighted(vec![1.0, 1.0, 2.0]).unwrap();
        let got = a.apply(&[0.2, 0.4, 0.9]).unwrap();
        assert!((got - (0.2 + 0.4 + 1.8) / 4.0).abs() < 1e-12);
    }

    #[test]
    fn mean_and_max() {
        assert_eq!(Aggregation::Mean.apply(&[0.1, 0.3]).unwrap(), 0.2);
        assert_eq!(Aggregation::Max.apply(&[0.1, 0.9, 0.3]).unwrap(), 0.9);
    }

    #[test]
    fn identity_arity() {
        assert_eq!(Aggregation::Identity.apply(&[0.7]).unwrap(), 0.7);
        assert!(Aggregation::Identity.apply(&[0.7, 0.8]).is_err());
    }

    #[test]
    fn rejects_bad_weights() {
        assert!(Aggregation::weighted(vec![]).is_err());
        assert!(Aggregation::weighted(vec![-1.0, 1.0]).is_err());
        assert!(Aggregation::weighted(vec![0.0, 0.0]).is_err());
        assert!(Aggregation::weighted(vec![f64::NAN]).is_err());
    }

    #[test]
    fn arity_mismatch_is_error() {
        let a = Aggregation::weighted(vec![1.0, 1.0]).unwrap();
        assert!(a.apply(&[0.5]).is_err());
        assert!(a.apply(&[0.5, 0.5, 0.5]).is_err());
    }

    #[test]
    fn empty_scores_is_error() {
        assert!(Aggregation::Mean.apply(&[]).is_err());
    }

    #[test]
    fn prop_weighted_mean_within_hull() {
        prop::check(256, |g| {
            let k = g.usize(1..9);
            let w: Vec<f64> = (0..k).map(|_| g.f64(0.01..2.0)).collect();
            let s: Vec<f64> = (0..k).map(|_| g.f64(0.0..1.0)).collect();
            let a = Aggregation::weighted(w).map_err(|e| e.to_string())?;
            let out = a.apply(&s).map_err(|e| e.to_string())?;
            let lo = s.iter().cloned().fold(f64::MAX, f64::min);
            let hi = s.iter().cloned().fold(f64::MIN, f64::max);
            prop_assert!(out >= lo - 1e-12 && out <= hi + 1e-12, "out of hull: {out}");
            Ok(())
        });
    }

    #[test]
    fn prop_unchecked_matches_checked() {
        prop::check(256, |g| {
            let k = g.usize(1..9);
            let w: Vec<f64> = (0..k).map(|_| g.f64(0.01..2.0)).collect();
            let s: Vec<f64> = (0..k).map(|_| g.f64(0.0..1.0)).collect();
            let a = Aggregation::weighted(w).unwrap();
            let c = a.apply(&s).unwrap();
            let u = a.apply_unchecked(&s);
            prop_assert!((c - u).abs() < 1e-15, "checked {c} != unchecked {u}");
            Ok(())
        });
    }

    #[test]
    fn zero_weight_expert_is_ignored() {
        let a = Aggregation::weighted(vec![0.0, 1.0]).unwrap();
        assert_eq!(a.apply(&[0.99, 0.5]).unwrap(), 0.5);
    }
}
