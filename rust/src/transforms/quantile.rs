//! Quantile Mapping `T^Q` (paper Eq. 4 / Section 2.3.3).
//!
//! Aligns the CDF of the predictor's output distribution `S` with a
//! fixed reference distribution `R` via a piecewise-linear map over
//! `N` precomputed quantiles. Lookup is `O(log N)` binary search —
//! this is THE hot-path transformation applied to every scored event,
//! so the table is immutable, contiguous and shared (`Arc`) across
//! worker threads.
//!
//! The transformation is monotone, so event ranking (and therefore
//! predictive performance) is preserved; only the distribution of the
//! reported score changes.

use anyhow::{ensure, Result};
use std::fmt;
use std::sync::Arc;

/// Events per lane group in [`QuantileMap::apply_batch`].
const LANES: usize = 8;

/// Grids at or below this many knots use the counting-scan segment
/// search (O(N) per lane group but perfectly vectorizable); larger
/// grids switch to the interleaved branchless binary search.
const SCAN_KNOTS: usize = 32;

/// Typed error for quantile-map application. `QuantileMap::apply`
/// historically panicked on a NaN input (the `partition_point` index
/// arithmetic underflowed); it is now total (NaN in, NaN out) and
/// callers that must *reject* non-finite scores instead of propagating
/// them use [`QuantileMap::try_apply`], which returns this.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum QuantileError {
    /// The score was NaN or ±∞ (the offending value is carried for
    /// error messages; NaN compares unequal to itself, so match on the
    /// variant, not the payload).
    NonFiniteScore(f64),
}

impl fmt::Display for QuantileError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            QuantileError::NonFiniteScore(s) => {
                write!(f, "cannot quantile-map non-finite score {s}")
            }
        }
    }
}

impl std::error::Error for QuantileError {}

/// An immutable piecewise-linear quantile transformation.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantileMap {
    /// Source quantiles `q^S_0..q^S_N` (strictly increasing).
    src: Vec<f64>,
    /// Reference quantiles `q^R_0..q^R_N` (non-decreasing).
    refq: Vec<f64>,
    /// Precomputed segment slopes (len N):
    /// `(refq[i+1]-refq[i])/(src[i+1]-src[i])`.
    slopes: Vec<f64>,
}

impl QuantileMap {
    /// Build from matching quantile grids (same length >= 2).
    ///
    /// `src` must be strictly increasing (source quantiles of a
    /// continuous score distribution); `refq` must be non-decreasing.
    pub fn new(src: Vec<f64>, refq: Vec<f64>) -> Result<Self> {
        ensure!(src.len() == refq.len(), "quantile grids differ in length");
        ensure!(src.len() >= 2, "need at least 2 quantile points");
        ensure!(
            src.iter().all(|v| v.is_finite()) && refq.iter().all(|v| v.is_finite()),
            "quantiles must be finite"
        );
        for w in src.windows(2) {
            ensure!(w[1] > w[0], "source quantiles must be strictly increasing");
        }
        for w in refq.windows(2) {
            ensure!(w[1] >= w[0], "reference quantiles must be non-decreasing");
        }
        let slopes = src
            .windows(2)
            .zip(refq.windows(2))
            .map(|(s, r)| (r[1] - r[0]) / (s[1] - s[0]))
            .collect();
        Ok(QuantileMap { src, refq, slopes })
    }

    /// Identity map on [0, 1] with `n_points` knots (useful default).
    pub fn identity(n_points: usize) -> Result<Self> {
        let grid: Vec<f64> = (0..n_points)
            .map(|i| i as f64 / (n_points - 1) as f64)
            .collect();
        QuantileMap::new(grid.clone(), grid)
    }

    /// Number of segments N.
    pub fn segments(&self) -> usize {
        self.slopes.len()
    }

    pub fn source_quantiles(&self) -> &[f64] {
        &self.src
    }

    pub fn reference_quantiles(&self) -> &[f64] {
        &self.refq
    }

    /// Eq. 4: map one score. Scores outside the source support clamp
    /// to the reference bounds (±∞ included); NaN propagates (NaN in,
    /// NaN out — the map is total and never panics; use
    /// [`QuantileMap::try_apply`] to reject non-finite inputs with a
    /// typed error instead). O(log N).
    #[inline]
    pub fn apply(&self, score: f64) -> f64 {
        if score.is_nan() {
            // Without this guard every comparison below is false and
            // `partition_point` returns 0, underflowing the segment
            // index — a panic on the hot path for one poisoned event.
            return f64::NAN;
        }
        let n = self.src.len();
        if score <= self.src[0] {
            return self.refq[0];
        }
        if score >= self.src[n - 1] {
            return self.refq[n - 1];
        }
        // partition_point returns the first index with src[i] > score;
        // the segment index is that minus one.
        let i = self.src.partition_point(|&q| q <= score) - 1;
        self.refq[i] + (score - self.src[i]) * self.slopes[i]
    }

    /// As [`QuantileMap::apply`], but rejects non-finite scores (NaN
    /// and ±∞) with a typed [`QuantileError`] instead of propagating
    /// or clamping them — the strict front door for scores that cross
    /// a trust boundary rather than coming out of the engine's own
    /// pipeline. (Replayed lakes are guarded on the fitting side too:
    /// `quantile_fit::fit_from_scores` rejects non-finite samples
    /// with a typed error instead of panicking in the quantile sort.)
    #[inline]
    pub fn try_apply(&self, score: f64) -> std::result::Result<f64, QuantileError> {
        if !score.is_finite() {
            return Err(QuantileError::NonFiniteScore(score));
        }
        Ok(self.apply(score))
    }

    /// Map a batch in place. Lane-parallel: events are processed in
    /// 8-wide groups whose segment search is branch-free (a counting
    /// scan for small grids, an interleaved CMOV binary search for
    /// large ones), so the compiler can keep all eight lanes in
    /// flight. Each event's arithmetic is the exact operation
    /// sequence of [`QuantileMap::apply`] — the early returns become
    /// selects over the same loads — so results are bitwise equal to
    /// the scalar path for every input, NaN and ±∞ included.
    pub fn apply_batch(&self, scores: &mut [f64]) {
        let mut chunks = scores.chunks_exact_mut(LANES);
        if self.src.len() <= SCAN_KNOTS {
            for chunk in &mut chunks {
                self.apply_lanes_scan(chunk);
            }
        } else {
            for chunk in &mut chunks {
                self.apply_lanes_search(chunk);
            }
        }
        // Remainder events (n % 8) take the scalar path — identical
        // by definition.
        for s in chunks.into_remainder() {
            *s = self.apply(*s);
        }
    }

    /// 8-wide kernel for small grids: the segment index is a counting
    /// scan (`idx = Σ_k [src[k] <= s]`) — one broadcast compare-and-
    /// accumulate per knot across all lanes, no data-dependent
    /// control flow.
    #[inline]
    fn apply_lanes_scan(&self, s: &mut [f64]) {
        debug_assert_eq!(s.len(), LANES);
        let mut count = [0usize; LANES];
        for &knot in &self.src {
            for l in 0..LANES {
                count[l] += (knot <= s[l]) as usize;
            }
        }
        self.finish_lanes(s, &count);
    }

    /// 8-wide kernel for large grids: a branchless binary search
    /// (conditional-move steps, no mispredictable branches)
    /// interleaved across all lanes — every step issues eight
    /// independent loads, hiding memory latency the scalar
    /// `partition_point` serializes.
    #[inline]
    fn apply_lanes_search(&self, s: &mut [f64]) {
        debug_assert_eq!(s.len(), LANES);
        let n = self.src.len();
        let mut base = [0usize; LANES];
        let mut size = n;
        while size > 1 {
            let half = size / 2;
            for l in 0..LANES {
                let mid = base[l] + half;
                // Both arms are plain loads: compiles to CMOV.
                base[l] = if self.src[mid] <= s[l] { mid } else { base[l] };
            }
            size -= half;
        }
        let mut count = [0usize; LANES];
        for l in 0..LANES {
            count[l] = base[l] + (self.src[base[l]] <= s[l]) as usize;
        }
        self.finish_lanes(s, &count);
    }

    /// Shared tail: `count[l]` is the number of knots `<= s[l]`
    /// (exactly what `partition_point` returns on the interior).
    /// The interpolation is computed unconditionally — for clamped
    /// or NaN lanes it may produce garbage (never a panic: the index
    /// is clamped into the slope table) — and the scalar path's
    /// early returns are replayed as selects in the same priority
    /// order: NaN, low clamp, high clamp, interpolate.
    #[inline]
    fn finish_lanes(&self, s: &mut [f64], count: &[usize; LANES]) {
        let n = self.src.len();
        for l in 0..LANES {
            let x = s[l];
            let i = count[l].saturating_sub(1).min(n - 2);
            let interp = self.refq[i] + (x - self.src[i]) * self.slopes[i];
            s[l] = if x.is_nan() {
                f64::NAN
            } else if x <= self.src[0] {
                self.refq[0]
            } else if x >= self.src[n - 1] {
                self.refq[n - 1]
            } else {
                interp
            };
        }
    }

    /// The inverse transformation (swap source and reference). Only
    /// valid when the reference grid is strictly increasing.
    pub fn inverse(&self) -> Result<QuantileMap> {
        QuantileMap::new(self.refq.clone(), self.src.clone())
    }

    /// Wrap in `Arc` for sharing across serving threads.
    pub fn shared(self) -> Arc<QuantileMap> {
        Arc::new(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop;

    fn simple() -> QuantileMap {
        QuantileMap::new(vec![0.0, 0.2, 1.0], vec![0.0, 0.8, 1.0]).unwrap()
    }

    #[test]
    fn validates_grids() {
        assert!(QuantileMap::new(vec![0.0], vec![0.0]).is_err());
        assert!(QuantileMap::new(vec![0.0, 0.0], vec![0.0, 1.0]).is_err());
        assert!(QuantileMap::new(vec![0.0, 1.0], vec![1.0, 0.0]).is_err());
        assert!(QuantileMap::new(vec![0.0, 1.0], vec![0.0, f64::NAN]).is_err());
        assert!(QuantileMap::new(vec![0.0, 1.0], vec![0.0, 1.0, 2.0]).is_err());
        // Flat reference segments are allowed (non-decreasing).
        assert!(QuantileMap::new(vec![0.0, 0.5, 1.0], vec![0.0, 0.0, 1.0]).is_ok());
    }

    #[test]
    fn maps_knots_exactly() {
        let m = simple();
        assert_eq!(m.apply(0.0), 0.0);
        assert_eq!(m.apply(0.2), 0.8);
        assert_eq!(m.apply(1.0), 1.0);
    }

    #[test]
    fn interpolates_linearly() {
        let m = simple();
        assert!((m.apply(0.1) - 0.4).abs() < 1e-12);
        assert!((m.apply(0.6) - 0.9).abs() < 1e-12);
    }

    #[test]
    fn clamps_out_of_support() {
        let m = QuantileMap::new(vec![0.2, 0.8], vec![0.1, 0.9]).unwrap();
        assert_eq!(m.apply(0.0), 0.1);
        assert_eq!(m.apply(1.0), 0.9);
        assert_eq!(m.apply(-5.0), 0.1);
    }

    #[test]
    fn identity_map() {
        let m = QuantileMap::identity(101).unwrap();
        for i in 0..=100 {
            let x = i as f64 / 100.0;
            assert!((m.apply(x) - x).abs() < 1e-12);
        }
    }

    #[test]
    fn prop_monotone_preserves_ranking() {
        prop::check(200, |g| {
            let n = g.usize(2..80);
            let src = g.monotone_grid(n, 0.0, 1.0);
            let refq = g.monotone_grid(n, 0.0, 1.0);
            let m = QuantileMap::new(src, refq).map_err(|e| e.to_string())?;
            let mut xs = g.vec_f64(-0.2..1.2, 2..200);
            xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let ys: Vec<f64> = xs.iter().map(|&x| m.apply(x)).collect();
            for w in ys.windows(2) {
                prop_assert!(w[1] >= w[0] - 1e-12, "ranking broken: {} -> {}", w[0], w[1]);
            }
            Ok(())
        });
    }

    #[test]
    fn prop_knots_map_to_knots() {
        prop::check(200, |g| {
            let n = g.usize(2..60);
            let src = g.monotone_grid(n, 0.0, 1.0);
            let refq = g.monotone_grid(n, 0.0, 1.0);
            let m = QuantileMap::new(src.clone(), refq.clone()).unwrap();
            for (s, r) in src.iter().zip(&refq) {
                let got = m.apply(*s);
                prop_assert!((got - r).abs() < 1e-9, "knot {s} -> {got}, want {r}");
            }
            Ok(())
        });
    }

    #[test]
    fn prop_inverse_roundtrips() {
        prop::check(100, |g| {
            let n = g.usize(2..40);
            let src = g.monotone_grid(n, 0.0, 1.0);
            let refq = g.monotone_grid(n, 0.0, 1.0);
            let m = QuantileMap::new(src.clone(), refq).unwrap();
            let inv = m.inverse().map_err(|e| e.to_string())?;
            let x = g.f64(0.0..1.0);
            let x = src[0] + (src[n - 1] - src[0]) * x; // inside support
            let round = inv.apply(m.apply(x));
            prop_assert!((round - x).abs() < 1e-9, "roundtrip {x} -> {round}");
            Ok(())
        });
    }

    #[test]
    fn prop_output_within_reference_bounds() {
        prop::check(200, |g| {
            let n = g.usize(2..60);
            let src = g.monotone_grid(n, 0.0, 1.0);
            let refq = g.monotone_grid(n, 0.2, 0.7);
            let m = QuantileMap::new(src, refq).unwrap();
            let x = g.f64(-1.0..2.0);
            let y = m.apply(x);
            prop_assert!((0.2..=0.7).contains(&y), "out of ref bounds: {y}");
            Ok(())
        });
    }

    #[test]
    fn nan_propagates_instead_of_panicking() {
        // The discovered panic: NaN fails every comparison, so the
        // pre-hardening segment search underflowed. The map is total
        // now — NaN in, NaN out — and try_apply surfaces the typed
        // error.
        let m = simple();
        assert!(m.apply(f64::NAN).is_nan());
        assert!(matches!(
            m.try_apply(f64::NAN),
            Err(QuantileError::NonFiniteScore(_))
        ));
        assert!(matches!(
            m.try_apply(f64::INFINITY),
            Err(QuantileError::NonFiniteScore(_))
        ));
        assert!(matches!(
            m.try_apply(f64::NEG_INFINITY),
            Err(QuantileError::NonFiniteScore(_))
        ));
        assert_eq!(m.try_apply(0.1), Ok(m.apply(0.1)));
        // ±∞ clamp under apply (the lenient path), like any
        // out-of-support score.
        assert_eq!(m.apply(f64::INFINITY), 1.0);
        assert_eq!(m.apply(f64::NEG_INFINITY), 0.0);
        // The error renders and matches on its variant.
        let e = m.try_apply(f64::NAN).unwrap_err();
        assert!(e.to_string().contains("non-finite"), "{e}");
    }

    #[test]
    fn degenerate_identity_grids_error_not_panic() {
        // identity(1) divides by zero into a NaN grid; identity(0)
        // produces an empty grid. Both must be rejected by the
        // constructor, never panic downstream.
        assert!(QuantileMap::identity(0).is_err());
        assert!(QuantileMap::identity(1).is_err());
        assert!(QuantileMap::identity(2).is_ok());
    }

    #[test]
    fn prop_grid_boundaries_clamp_exactly() {
        // Below q^S_0 and above q^S_N the map must return the exact
        // reference endpoints (bitwise), for grids of every size
        // including the minimal 2-point grid.
        prop::check(256, |g| {
            let n = g.usize(2..40);
            let src = g.monotone_grid(n, 0.1, 0.9);
            let refq = g.monotone_grid(n, 0.2, 0.8);
            let m = QuantileMap::new(src.clone(), refq.clone()).unwrap();
            let below = src[0] - g.f64(0.0..1.0) - 1e-9;
            let above = src[n - 1] + g.f64(0.0..1.0) + 1e-9;
            prop_assert!(
                m.apply(below).to_bits() == refq[0].to_bits(),
                "below-support {below} -> {} != refq[0] {}",
                m.apply(below),
                refq[0]
            );
            prop_assert!(
                m.apply(above).to_bits() == refq[n - 1].to_bits(),
                "above-support {above} -> {} != refq[N] {}",
                m.apply(above),
                refq[n - 1]
            );
            // The knots themselves map exactly to their endpoints.
            prop_assert!(m.apply(src[0]).to_bits() == refq[0].to_bits(), "q0 knot");
            prop_assert!(
                m.apply(src[n - 1]).to_bits() == refq[n - 1].to_bits(),
                "qN knot"
            );
            Ok(())
        });
    }

    #[test]
    fn prop_two_point_grids_interpolate_and_clamp() {
        // The smallest legal grid (one segment) across random spans:
        // interior points interpolate linearly, the outside clamps,
        // and non-finite inputs never panic.
        prop::check(256, |g| {
            let a = g.f64(-5.0..5.0);
            let b = a + g.f64(1e-6..3.0);
            let c = g.f64(-2.0..2.0);
            let d = c + g.f64(0.0..2.0);
            let m = QuantileMap::new(vec![a, b], vec![c, d]).map_err(|e| e.to_string())?;
            let t = g.f64(0.0..1.0);
            let x = a + (b - a) * t;
            let want = c + (x - a) * ((d - c) / (b - a));
            let got = m.apply(x);
            prop_assert!((got - want).abs() <= 1e-9, "interp {x} -> {got}, want {want}");
            prop_assert!(m.apply(a - 1.0) == c && m.apply(b + 1.0) == d, "clamp");
            prop_assert!(m.apply(f64::NAN).is_nan(), "NaN must propagate");
            prop_assert!(m.try_apply(f64::NAN).is_err(), "NaN must be rejected");
            prop_assert!(
                m.try_apply(x).map_err(|e| e.to_string())? == got,
                "try_apply disagrees with apply"
            );
            Ok(())
        });
    }

    #[test]
    fn prop_rejects_non_finite_grids() {
        // NaN/±∞ anywhere in either grid is a constructor error — the
        // map can then assume finite knots everywhere else.
        prop::check(128, |g| {
            let n = g.usize(2..20);
            let poison = *g.pick(&[f64::NAN, f64::INFINITY, f64::NEG_INFINITY]);
            let at = g.usize(0..n);
            let mut src = g.monotone_grid(n, 0.0, 1.0);
            let refq = g.monotone_grid(n, 0.0, 1.0);
            src[at] = poison;
            prop_assert!(
                QuantileMap::new(src, refq.clone()).is_err(),
                "poisoned src accepted (poison {poison} at {at})"
            );
            let src = g.monotone_grid(n, 0.0, 1.0);
            let mut refq = refq;
            refq[at] = poison;
            prop_assert!(
                QuantileMap::new(src, refq).is_err(),
                "poisoned refq accepted (poison {poison} at {at})"
            );
            Ok(())
        });
    }

    #[test]
    fn batch_matches_scalar() {
        let m = simple();
        let mut batch: Vec<f64> = (0..=20).map(|i| i as f64 / 20.0).collect();
        let want: Vec<f64> = batch.iter().map(|&x| m.apply(x)).collect();
        m.apply_batch(&mut batch);
        assert_eq!(batch, want);
    }

    /// The vectorized batch kernel is bitwise-equal to the scalar
    /// `apply` for every input class — NaN, ±∞, knots, out-of-support
    /// — on grids both sides of the scan/search threshold, at every
    /// remainder length `len % 8 ∈ 0..=7`.
    #[test]
    fn prop_apply_batch_bitwise_matches_scalar() {
        prop::check(256, |g| {
            // Straddle SCAN_KNOTS: small grids take the counting
            // scan, large ones the branchless search.
            let n = if g.bool(0.5) {
                g.usize(2..SCAN_KNOTS + 1)
            } else {
                g.usize(SCAN_KNOTS + 1..4 * SCAN_KNOTS)
            };
            let src = g.monotone_grid(n, -0.5, 1.5);
            let refq = g.monotone_grid(n, 0.0, 1.0);
            let m = QuantileMap::new(src.clone(), refq).unwrap();
            for rem in 0..8usize {
                let len = 8 * g.usize(0..3) + rem;
                let mut batch: Vec<f64> = (0..len)
                    .map(|_| match g.usize(0..12) {
                        0 => f64::NAN,
                        1 => f64::INFINITY,
                        2 => f64::NEG_INFINITY,
                        3 => src[0],
                        4 => src[n - 1],
                        5 => *g.pick(&src),
                        6 => src[0] - g.f64(0.0..2.0),
                        7 => src[n - 1] + g.f64(0.0..2.0),
                        _ => g.f64(-1.0..2.0),
                    })
                    .collect();
                let want: Vec<u64> =
                    batch.iter().map(|&x| m.apply(x).to_bits()).collect();
                m.apply_batch(&mut batch);
                for (i, (got, want)) in
                    batch.iter().map(|v| v.to_bits()).zip(&want).enumerate()
                {
                    prop_assert!(
                        got == *want,
                        "lane {i}/{len} (grid {n}): batch {:x} != scalar {want:x}",
                        got
                    );
                }
            }
            Ok(())
        });
    }

    /// Both lane kernels individually reproduce the scalar path on a
    /// deliberately adversarial 8-lane group (the exact group shape
    /// `apply_batch` dispatches).
    #[test]
    fn lane_kernels_match_scalar_on_edge_lanes() {
        for n in [2, SCAN_KNOTS, SCAN_KNOTS + 1, 257] {
            let src: Vec<f64> = (0..n).map(|i| i as f64 / (n - 1) as f64).collect();
            let refq: Vec<f64> =
                (0..n).map(|i| (i as f64 / (n - 1) as f64).sqrt()).collect();
            let m = QuantileMap::new(src, refq).unwrap();
            let lanes = [
                f64::NAN,
                f64::NEG_INFINITY,
                f64::INFINITY,
                -0.0,
                0.0,
                1.0,
                0.5,
                1.0 + 1e-12,
            ];
            let want: Vec<u64> = lanes.iter().map(|&x| m.apply(x).to_bits()).collect();
            let mut got = lanes;
            m.apply_batch(&mut got);
            for l in 0..8 {
                assert_eq!(
                    got[l].to_bits(),
                    want[l],
                    "grid {n} lane {l} input {}",
                    lanes[l]
                );
            }
        }
    }

    #[test]
    fn large_grid_lookup() {
        // Paper-scale grid: N = 1024 segments.
        let n = 1025;
        let src: Vec<f64> = (0..n).map(|i| (i as f64 / (n - 1) as f64).powi(2)).collect();
        let refq: Vec<f64> = (0..n).map(|i| i as f64 / (n - 1) as f64).collect();
        let m = QuantileMap::new(src, refq).unwrap();
        // sqrt is the analytic inverse of the squared grid.
        for i in 0..100 {
            let x = i as f64 / 100.0;
            assert!((m.apply(x) - x.sqrt()).abs() < 1e-3, "x={x}");
        }
    }
}
