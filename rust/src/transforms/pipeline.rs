//! Compiled per-tenant transform pipelines.
//!
//! The paper's two-level transformation `T^Q ∘ A ∘ T^C` (Sections
//! 2.2-2.3) was executed by the seed data plane as three *interpreted*
//! stages per event: an `Option<PosteriorCorrection>` branch per
//! expert, a heap-allocated `calibrated` vector per event, and a
//! tenant `HashMap` probe per event for `T^Q`. This module compiles
//! the chain **offline** — at deploy / promote / quantile-install time
//! — into a branch-free kernel the hot path replays:
//!
//! * [`PipelineSpec`] — the declarative per-tenant pipeline: one
//!   `T^C_k` per expert, the aggregation `A`, the tenant's `T^Q`. Its
//!   [`PipelineSpec::score_staged_one`] is the reference oracle (the
//!   exact arithmetic of the seed's staged path), kept forever as the
//!   equivalence baseline for property tests.
//! * [`CompiledStages`] — stages 1+2 (`T^C` + `A`) compiled per
//!   *predictor*: every correction becomes a [`CorrectionSlot`]
//!   whose neutral case is a slot-constant flag test (perfectly
//!   predicted; no `Option` discriminant load per event, and bitwise
//!   equal to the staged `None => s` branch for every input,
//!   non-finite included), and the aggregation becomes a dot product
//!   with a precomputed weight sum (same accumulation order as the
//!   staged `apply_unchecked`, so results are bitwise equal, not
//!   just close).
//! * [`CompiledPipeline`] — stages shared per predictor + the tenant's
//!   resolved `T^Q` table. Where legal (single expert, no correction)
//!   the whole chain **fuses to a single piecewise-linear lookup**;
//!   fusing a non-identity `T^C` into the table is *not* legal because
//!   `T^Q ∘ T^C` is piecewise-rational, not piecewise-linear, and the
//!   equivalence bar (<= 1e-12 vs the oracle) forbids approximating it.
//! * [`PipelineScratch`] — reusable flat SoA staging for expert score
//!   lanes, killing the per-batch `Vec<Vec<f32>>` allocation of the
//!   seed's `score_raw`.
//!
//! Who compiles what: `coordinator::Predictor` builds one
//! [`CompiledStages`] at deploy time and one [`CompiledPipeline`] per
//! tenant inside its copy-on-write quantile table, so the batcher and
//! the batch scoring path resolve a tenant's pipeline with **one probe
//! per (batch, tenant) group** and zero per-event lookups — see
//! docs/ARCHITECTURE.md "Pipeline compilation".

use super::{Aggregation, PosteriorCorrection, QuantileMap};
use anyhow::{ensure, Result};
use std::sync::Arc;

/// Events per lane group in the batched stage-1+2 kernel. Matches the
/// quantile kernel's group width so a whole chunk flows through
/// `T^C`, `A`, and `T^Q` with the same stride.
const LANES: usize = 8;

/// One expert's compiled `T^C`: the Eq. 3 rational map, or the
/// **neutral slot** for an absent correction. The neutral case is a
/// test of a slot-local constant flag — always perfectly predicted,
/// unlike the seed's per-event `Option` discriminant match — rather
/// than an arithmetic identity, because `1 - 0*s` is NaN (not 1) for
/// `s = ±∞` and the slot must reproduce the staged `None => s` branch
/// bitwise for *every* input, non-finite included.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CorrectionSlot {
    beta: f64,
    one_minus_beta: f64,
    neutral: bool,
}

impl CorrectionSlot {
    fn from_correction(c: &Option<PosteriorCorrection>) -> CorrectionSlot {
        match c {
            Some(c) => CorrectionSlot {
                beta: c.beta(),
                one_minus_beta: 1.0 - c.beta(),
                neutral: false,
            },
            None => CorrectionSlot {
                beta: 1.0,
                one_minus_beta: 0.0,
                neutral: true,
            },
        }
    }

    /// Apply the slot. Non-neutral slots run the exact operation
    /// sequence of [`PosteriorCorrection::apply`] (clamp,
    /// `1 - (1-beta)*s`, multiply, divide, clamp), so results are
    /// bitwise equal to the staged oracle; neutral slots return the
    /// input verbatim (including ±∞/NaN, matching `None => s`).
    #[inline]
    pub fn apply(&self, score: f64) -> f64 {
        if self.neutral {
            return score;
        }
        let s = score.clamp(0.0, 1.0);
        let denom = 1.0 - self.one_minus_beta * s;
        (self.beta * s / denom).clamp(0.0, 1.0)
    }

    pub fn is_neutral(&self) -> bool {
        self.neutral
    }
}

/// Compiled aggregation: the branch at the `Aggregation` enum is paid
/// once per batch, never per event.
#[derive(Debug, Clone, PartialEq)]
enum CompiledAgg {
    /// WeightedMean / Mean / Identity, normalised to one dot product.
    /// `weight_sum` is accumulated in the same order as the staged
    /// path recomputes it, so the division is bitwise identical.
    Dot { weights: Vec<f64>, weight_sum: f64 },
    Max,
}

/// Stages 1+2 of the chain (`T^C` per expert, then `A`), compiled once
/// per predictor and shared (`Arc`) by every tenant's pipeline.
#[derive(Debug, Clone, PartialEq)]
pub struct CompiledStages {
    slots: Vec<CorrectionSlot>,
    agg: CompiledAgg,
    /// `true` when the whole stage pair is the identity on expert
    /// lane 0 (single expert, no correction, identity/unit-weight
    /// aggregation): the kernel then skips straight to `T^Q`.
    passthrough: bool,
}

impl CompiledStages {
    pub fn compile(
        corrections: &[Option<PosteriorCorrection>],
        aggregation: &Aggregation,
    ) -> Result<CompiledStages> {
        ensure!(!corrections.is_empty(), "pipeline needs >= 1 expert");
        if let Some(arity) = aggregation.arity() {
            ensure!(
                arity == corrections.len(),
                "aggregation arity {arity} != {} experts",
                corrections.len()
            );
        }
        let slots: Vec<CorrectionSlot> = corrections
            .iter()
            .map(CorrectionSlot::from_correction)
            .collect();
        let agg = match aggregation {
            Aggregation::Max => CompiledAgg::Max,
            Aggregation::Identity => CompiledAgg::Dot {
                weights: vec![1.0],
                weight_sum: 1.0,
            },
            Aggregation::Mean => {
                let weights = vec![1.0; corrections.len()];
                CompiledAgg::Dot {
                    weight_sum: weights.iter().sum(),
                    weights,
                }
            }
            Aggregation::WeightedMean(w) => {
                // Same accumulation order as `apply_unchecked`'s
                // per-event `den += w` loop.
                let mut weight_sum = 0.0;
                for wi in w {
                    weight_sum += wi;
                }
                CompiledAgg::Dot {
                    weights: w.clone(),
                    weight_sum,
                }
            }
        };
        let passthrough = slots.len() == 1
            && slots[0].neutral
            && matches!(&agg, CompiledAgg::Dot { weights, .. } if weights == &[1.0]);
        Ok(CompiledStages {
            slots,
            agg,
            passthrough,
        })
    }

    pub fn n_experts(&self) -> usize {
        self.slots.len()
    }

    pub fn is_passthrough(&self) -> bool {
        self.passthrough
    }

    /// Stage-1+2 kernel over SoA expert lanes: `raw[i] = A([T^C_k(s_ki)])`
    /// for every event, appended to `out`. Branch-free per event — no
    /// `Option` match, no per-event `calibrated` buffer, no per-event
    /// allocation.
    ///
    /// Lane-parallel across events: 8 events move through the
    /// expert loop together, with each slot's neutral flag hoisted
    /// out of the lane loop (it is a slot constant, not per-event
    /// state), so the inner loops are straight-line arithmetic the
    /// compiler can vectorize. Per event the accumulation still
    /// visits experts in order `0..k` with the exact operation
    /// sequence of the scalar path, so results are bitwise equal to
    /// [`CompiledStages::raw_one`] and the staged oracle.
    pub fn raw_into(&self, scratch: &PipelineScratch, out: &mut Vec<f64>) {
        let (lanes, k, n) = scratch.lanes();
        debug_assert_eq!(k, self.slots.len(), "scratch lane count mismatch");
        out.reserve(n);
        if self.passthrough {
            // Identity chain: raw is expert lane 0 verbatim.
            out.extend(lanes[..n].iter().map(|&s| s as f64));
            return;
        }
        let mut i = 0;
        match &self.agg {
            CompiledAgg::Dot {
                weights,
                weight_sum,
            } => {
                while i + LANES <= n {
                    let mut num = [0.0f64; LANES];
                    for (j, (slot, w)) in self.slots.iter().zip(weights).enumerate() {
                        let lane = &lanes[j * n + i..j * n + i + LANES];
                        if slot.neutral {
                            for l in 0..LANES {
                                num[l] += lane[l] as f64 * w;
                            }
                        } else {
                            for l in 0..LANES {
                                let s = (lane[l] as f64).clamp(0.0, 1.0);
                                let denom = 1.0 - slot.one_minus_beta * s;
                                num[l] += (slot.beta * s / denom).clamp(0.0, 1.0) * w;
                            }
                        }
                    }
                    for &v in &num {
                        out.push(v / weight_sum);
                    }
                    i += LANES;
                }
                // Remainder events (n % 8): the scalar event loop.
                for i in i..n {
                    let mut num = 0.0;
                    for (j, (slot, w)) in self.slots.iter().zip(weights).enumerate() {
                        num += slot.apply(lanes[j * n + i] as f64) * w;
                    }
                    out.push(num / weight_sum);
                }
            }
            CompiledAgg::Max => {
                while i + LANES <= n {
                    let mut m = [f64::MIN; LANES];
                    for (j, slot) in self.slots.iter().enumerate() {
                        let lane = &lanes[j * n + i..j * n + i + LANES];
                        if slot.neutral {
                            for l in 0..LANES {
                                m[l] = m[l].max(lane[l] as f64);
                            }
                        } else {
                            for l in 0..LANES {
                                let s = (lane[l] as f64).clamp(0.0, 1.0);
                                let denom = 1.0 - slot.one_minus_beta * s;
                                m[l] = m[l].max((slot.beta * s / denom).clamp(0.0, 1.0));
                            }
                        }
                    }
                    out.extend_from_slice(&m);
                    i += LANES;
                }
                for i in i..n {
                    let mut m = f64::MIN;
                    for (j, slot) in self.slots.iter().enumerate() {
                        m = m.max(slot.apply(lanes[j * n + i] as f64));
                    }
                    out.push(m);
                }
            }
        }
    }

    /// Scalar stage-1+2 (one event, expert scores in order).
    pub fn raw_one(&self, expert_scores: &[f32]) -> f64 {
        debug_assert_eq!(expert_scores.len(), self.slots.len());
        if self.passthrough {
            return expert_scores[0] as f64;
        }
        match &self.agg {
            CompiledAgg::Dot {
                weights,
                weight_sum,
            } => {
                let mut num = 0.0;
                for ((slot, w), &s) in self.slots.iter().zip(weights).zip(expert_scores) {
                    num += slot.apply(s as f64) * w;
                }
                num / weight_sum
            }
            CompiledAgg::Max => {
                let mut m = f64::MIN;
                for (slot, &s) in self.slots.iter().zip(expert_scores) {
                    m = m.max(slot.apply(s as f64));
                }
                m
            }
        }
    }
}

/// Reusable SoA staging for expert score lanes: one flat `k * n`
/// buffer, lane `j` contiguous at `[j*n, (j+1)*n)`. Owned by each
/// batch-scoring call site (batcher worker, engine batch path) and
/// reused across batches — the seed's per-batch `Vec<Vec<f32>>`
/// allocation is gone.
#[derive(Default)]
pub struct PipelineScratch {
    lanes: Vec<f32>,
    k: usize,
    n: usize,
}

impl PipelineScratch {
    /// Size the buffer for `k` experts × `n` events. Keeps capacity
    /// across calls; only grows.
    pub fn begin(&mut self, k: usize, n: usize) {
        self.k = k;
        self.n = n;
        self.lanes.clear();
        self.lanes.resize(k * n, 0.0);
    }

    /// Expert `j`'s lane, to be filled with its `n` scores.
    pub fn lane_mut(&mut self, j: usize) -> &mut [f32] {
        let n = self.n;
        &mut self.lanes[j * n..(j + 1) * n]
    }

    /// (flat lanes, k, n).
    pub fn lanes(&self) -> (&[f32], usize, usize) {
        (&self.lanes, self.k, self.n)
    }
}

/// A fully compiled per-tenant pipeline: the predictor's shared
/// stage-1+2 kernel plus this tenant's resolved `T^Q` table. Published
/// copy-on-write inside the predictor's quantile table, so the data
/// plane never probes a tenant map per event.
#[derive(Debug, Clone)]
pub struct CompiledPipeline {
    stages: Arc<CompiledStages>,
    table: Arc<QuantileMap>,
    /// The whole chain is a single piecewise-linear lookup
    /// (`stages.is_passthrough()`): legal fusion per the module docs.
    fused: bool,
}

impl CompiledPipeline {
    pub fn new(stages: Arc<CompiledStages>, table: Arc<QuantileMap>) -> CompiledPipeline {
        let fused = stages.is_passthrough();
        CompiledPipeline {
            stages,
            table,
            fused,
        }
    }

    pub fn stages(&self) -> &Arc<CompiledStages> {
        &self.stages
    }

    pub fn table(&self) -> &Arc<QuantileMap> {
        &self.table
    }

    /// Whether the chain collapsed to one PWL lookup.
    pub fn is_fused(&self) -> bool {
        self.fused
    }

    /// Stage 3: the tenant's `T^Q` on an aggregated raw score.
    #[inline]
    pub fn finalize_one(&self, raw: f64) -> f64 {
        self.table.apply(raw)
    }

    /// Stage 3 over a raw slice, appended to `out` — the lane-parallel
    /// `T^Q` kernel ([`QuantileMap::apply_batch`]), bitwise equal to
    /// mapping `apply` per event.
    pub fn finalize_into(&self, raw: &[f64], out: &mut Vec<f64>) {
        let start = out.len();
        out.extend_from_slice(raw);
        self.table.apply_batch(&mut out[start..]);
    }

    /// Whole chain for one event: `(raw, final)`.
    #[inline]
    pub fn score_one(&self, expert_scores: &[f32]) -> (f64, f64) {
        if self.fused {
            let raw = expert_scores[0] as f64;
            return (raw, self.table.apply(raw));
        }
        let raw = self.stages.raw_one(expert_scores);
        (raw, self.table.apply(raw))
    }

    /// Whole chain over a staged batch: raw scores into `raw_out`,
    /// final scores into `out` (both appended).
    pub fn score_into(
        &self,
        scratch: &PipelineScratch,
        raw_out: &mut Vec<f64>,
        out: &mut Vec<f64>,
    ) {
        let start = raw_out.len();
        self.stages.raw_into(scratch, raw_out);
        self.finalize_into(&raw_out[start..], out);
    }
}

/// The declarative pipeline: what the control plane knows about one
/// `(predictor, tenant)` pair before compilation.
#[derive(Debug, Clone)]
pub struct PipelineSpec {
    pub corrections: Vec<Option<PosteriorCorrection>>,
    pub aggregation: Aggregation,
    pub tenant_map: Arc<QuantileMap>,
}

impl PipelineSpec {
    pub fn new(
        corrections: Vec<Option<PosteriorCorrection>>,
        aggregation: Aggregation,
        tenant_map: Arc<QuantileMap>,
    ) -> Result<PipelineSpec> {
        ensure!(!corrections.is_empty(), "pipeline needs >= 1 expert");
        if let Some(arity) = aggregation.arity() {
            ensure!(
                arity == corrections.len(),
                "aggregation arity {arity} != {} experts",
                corrections.len()
            );
        }
        Ok(PipelineSpec {
            corrections,
            aggregation,
            tenant_map,
        })
    }

    /// Compile to the branch-free kernel.
    pub fn compile(&self) -> Result<CompiledPipeline> {
        let stages = Arc::new(CompiledStages::compile(
            &self.corrections,
            &self.aggregation,
        )?);
        Ok(CompiledPipeline::new(stages, Arc::clone(&self.tenant_map)))
    }

    /// The staged reference oracle: byte-for-byte the arithmetic of the
    /// seed's interpreted path (`Predictor::score_raw`'s per-event loop
    /// followed by the tenant's `T^Q`). Property tests assert the
    /// compiled kernel against this; it must never be "optimised".
    pub fn score_staged_one(&self, expert_scores: &[f32]) -> (f64, f64) {
        let mut calibrated = vec![0.0f64; self.corrections.len()];
        for (j, c) in self.corrections.iter().enumerate() {
            let s = expert_scores[j] as f64;
            calibrated[j] = match c {
                Some(c) => c.apply(s),
                None => s,
            };
        }
        let raw = self.aggregation.apply_unchecked(&calibrated);
        (raw, self.tenant_map.apply(raw))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop;

    fn spec(
        betas: &[Option<f64>],
        aggregation: Aggregation,
        map: QuantileMap,
    ) -> PipelineSpec {
        let corrections = betas
            .iter()
            .map(|b| b.map(|b| PosteriorCorrection::new(b).unwrap()))
            .collect();
        PipelineSpec::new(corrections, aggregation, map.shared()).unwrap()
    }

    fn random_map(g: &mut prop::Gen) -> QuantileMap {
        let n = g.usize(2..40);
        let src = g.monotone_grid(n, 0.0, 1.0);
        let refq = g.monotone_grid(n, 0.0, 1.0);
        QuantileMap::new(src, refq).unwrap()
    }

    /// The acceptance-criteria property: compiled ≡ staged within
    /// 1e-12 across tenants (random maps), aggregations, correction
    /// mixes, and edge scores 0.0 / 1.0 / out-of-grid.
    #[test]
    fn prop_compiled_matches_staged_oracle() {
        prop::check(512, |g| {
            let k = g.usize(1..6);
            let betas: Vec<Option<f64>> = (0..k)
                .map(|_| {
                    if g.bool(0.3) {
                        None
                    } else {
                        Some(g.f64(0.001..1.0))
                    }
                })
                .collect();
            let aggregation = match g.usize(0..4) {
                0 => Aggregation::Mean,
                1 => Aggregation::Max,
                2 => Aggregation::weighted((0..k).map(|_| g.f64(0.01..3.0)).collect())
                    .unwrap(),
                _ if k == 1 => Aggregation::Identity,
                _ => Aggregation::Mean,
            };
            let s = spec(&betas, aggregation, random_map(g));
            let compiled = s.compile().map_err(|e| e.to_string())?;
            for _ in 0..16 {
                // Mostly in-range scores, with deliberate edge,
                // out-of-grid, and non-finite cases mixed in. ±inf
                // exercise the neutral slot's non-finite passthrough;
                // opposite infinities aggregate to NaN, which
                // `QuantileMap::apply` now propagates (NaN in, NaN
                // out) identically on both paths — the `agree` closure
                // below accepts matching NaNs.
                let scores: Vec<f32> = (0..k)
                    .map(|_| match g.usize(0..11) {
                        0 => 0.0,
                        1 => 1.0,
                        2 => g.f64(-0.5..0.0) as f32,
                        3 => g.f64(1.0..1.5) as f32,
                        4 => f32::INFINITY,
                        5 => f32::NEG_INFINITY,
                        _ => g.f64(0.0..1.0) as f32,
                    })
                    .collect();
                let (raw_s, fin_s) = s.score_staged_one(&scores);
                let (raw_c, fin_c) = compiled.score_one(&scores);
                // `a == b` catches the ±inf (and exact) cases where
                // `a - b` would be NaN; NaN results must agree in kind.
                let agree = |a: f64, b: f64| {
                    a == b || (a - b).abs() <= 1e-12 || (a.is_nan() && b.is_nan())
                };
                prop_assert!(
                    agree(raw_s, raw_c),
                    "raw diverged: staged {raw_s} vs compiled {raw_c} (scores {scores:?})"
                );
                prop_assert!(
                    agree(fin_s, fin_c),
                    "final diverged: staged {fin_s} vs compiled {fin_c} (scores {scores:?})"
                );
            }
            Ok(())
        });
    }

    /// Batch kernel == scalar kernel == staged oracle.
    #[test]
    fn prop_batch_kernel_matches_scalar() {
        prop::check(128, |g| {
            let k = g.usize(1..5);
            let betas: Vec<Option<f64>> =
                (0..k).map(|_| Some(g.f64(0.01..1.0))).collect();
            let s = spec(
                &betas,
                Aggregation::weighted(vec![1.0; k]).unwrap(),
                random_map(g),
            );
            let compiled = s.compile().unwrap();
            let n = g.usize(1..64);
            // Event-major random scores, transposed into lanes.
            let events: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..k).map(|_| g.f64(0.0..1.0) as f32).collect())
                .collect();
            let mut scratch = PipelineScratch::default();
            scratch.begin(k, n);
            for j in 0..k {
                let lane = scratch.lane_mut(j);
                for (i, e) in events.iter().enumerate() {
                    lane[i] = e[j];
                }
            }
            let mut raw = Vec::new();
            let mut fin = Vec::new();
            compiled.score_into(&scratch, &mut raw, &mut fin);
            prop_assert!(raw.len() == n && fin.len() == n, "length mismatch");
            for (i, e) in events.iter().enumerate() {
                let (r1, f1) = compiled.score_one(e);
                let (r2, f2) = s.score_staged_one(e);
                prop_assert!(
                    raw[i] == r1 && (raw[i] - r2).abs() <= 1e-12,
                    "raw[{i}] {} vs scalar {r1} vs staged {r2}",
                    raw[i]
                );
                prop_assert!(
                    fin[i] == f1 && (fin[i] - f2).abs() <= 1e-12,
                    "fin[{i}] {} vs scalar {f1} vs staged {f2}",
                    fin[i]
                );
            }
            Ok(())
        });
    }

    /// The lane-parallel batch kernel is bitwise-equal to the scalar
    /// event loop at every remainder length `n % 8 ∈ 0..=7`, across
    /// aggregations (Dot and Max), neutral/corrected slot mixes, and
    /// NaN/±∞ expert scores.
    #[test]
    fn prop_unrolled_batch_bitwise_matches_scalar() {
        prop::check(256, |g| {
            let k = g.usize(1..6);
            let betas: Vec<Option<f64>> = (0..k)
                .map(|_| {
                    if g.bool(0.4) {
                        None
                    } else {
                        Some(g.f64(0.001..1.0))
                    }
                })
                .collect();
            let aggregation = if g.bool(0.3) {
                Aggregation::Max
            } else {
                Aggregation::weighted((0..k).map(|_| g.f64(0.01..3.0)).collect()).unwrap()
            };
            let s = spec(&betas, aggregation, random_map(g));
            let compiled = s.compile().map_err(|e| e.to_string())?;
            for rem in 0..8usize {
                let n = 8 * g.usize(0..3) + rem;
                let events: Vec<Vec<f32>> = (0..n)
                    .map(|_| {
                        (0..k)
                            .map(|_| match g.usize(0..10) {
                                0 => f32::NAN,
                                1 => f32::INFINITY,
                                2 => f32::NEG_INFINITY,
                                3 => g.f64(-0.5..0.0) as f32,
                                4 => g.f64(1.0..1.5) as f32,
                                _ => g.f64(0.0..1.0) as f32,
                            })
                            .collect()
                    })
                    .collect();
                let mut scratch = PipelineScratch::default();
                scratch.begin(k, n);
                for j in 0..k {
                    let lane = scratch.lane_mut(j);
                    for (i, e) in events.iter().enumerate() {
                        lane[i] = e[j];
                    }
                }
                let mut raw = Vec::new();
                let mut fin = Vec::new();
                compiled.score_into(&scratch, &mut raw, &mut fin);
                prop_assert!(raw.len() == n && fin.len() == n, "length mismatch");
                for (i, e) in events.iter().enumerate() {
                    let (r1, f1) = compiled.score_one(e);
                    let bits = |a: f64, b: f64| a.to_bits() == b.to_bits();
                    prop_assert!(
                        bits(raw[i], r1),
                        "raw[{i}]/{n} {:x} != scalar {:x} (scores {e:?})",
                        raw[i].to_bits(),
                        r1.to_bits()
                    );
                    prop_assert!(
                        bits(fin[i], f1),
                        "fin[{i}]/{n} {:x} != scalar {:x} (scores {e:?})",
                        fin[i].to_bits(),
                        f1.to_bits()
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn neutral_slot_is_bitwise_identity() {
        let slot = CorrectionSlot::from_correction(&None);
        for s in [
            -1.5,
            -0.0,
            0.0,
            1e-300,
            0.5,
            1.0,
            7.25,
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::NAN,
        ] {
            assert_eq!(slot.apply(s).to_bits(), s.to_bits(), "s = {s}");
        }
        assert!(slot.is_neutral());
    }

    #[test]
    fn non_neutral_slot_matches_posterior_correction() {
        let c = PosteriorCorrection::new(0.18).unwrap();
        let slot = CorrectionSlot::from_correction(&Some(c));
        for i in -5..=25 {
            let s = i as f64 / 20.0; // includes out-of-range
            assert_eq!(slot.apply(s).to_bits(), c.apply(s).to_bits(), "s = {s}");
        }
    }

    #[test]
    fn single_expert_uncorrected_chain_fuses_to_pwl() {
        let s = spec(
            &[None],
            Aggregation::Identity,
            QuantileMap::new(vec![0.0, 0.2, 1.0], vec![0.0, 0.8, 1.0]).unwrap(),
        );
        let compiled = s.compile().unwrap();
        assert!(compiled.is_fused());
        assert!(compiled.stages().is_passthrough());
        // Fused result is exactly the table lookup.
        let (raw, fin) = compiled.score_one(&[0.1]);
        assert_eq!(raw, 0.1f32 as f64);
        assert!((fin - 0.4).abs() < 1e-9);
    }

    #[test]
    fn corrected_single_expert_does_not_fuse() {
        let s = spec(
            &[Some(0.5)],
            Aggregation::Identity,
            QuantileMap::identity(11).unwrap(),
        );
        assert!(!s.compile().unwrap().is_fused());
        // beta = 1 still carries the staged clamp, so it must not
        // collapse either (the oracle clamps, the identity would not).
        let s = spec(
            &[Some(1.0)],
            Aggregation::Identity,
            QuantileMap::identity(11).unwrap(),
        );
        let compiled = s.compile().unwrap();
        assert!(!compiled.is_fused());
        let (raw, _) = compiled.score_one(&[1.5]);
        assert_eq!(raw, 1.0, "beta=1 slot must keep the [0,1] clamp");
    }

    #[test]
    fn arity_mismatch_rejected() {
        assert!(PipelineSpec::new(
            vec![None],
            Aggregation::weighted(vec![1.0, 1.0]).unwrap(),
            QuantileMap::identity(3).unwrap().shared(),
        )
        .is_err());
        assert!(CompiledStages::compile(&[], &Aggregation::Mean).is_err());
    }

    #[test]
    fn scratch_reuse_across_batches() {
        let mut scratch = PipelineScratch::default();
        scratch.begin(2, 3);
        scratch.lane_mut(0).copy_from_slice(&[0.1, 0.2, 0.3]);
        scratch.lane_mut(1).copy_from_slice(&[0.4, 0.5, 0.6]);
        let (lanes, k, n) = scratch.lanes();
        assert_eq!((k, n), (2, 3));
        assert_eq!(lanes, &[0.1, 0.2, 0.3, 0.4, 0.5, 0.6]);
        // Shrink, then grow: contents are re-zeroed each begin().
        scratch.begin(1, 2);
        assert_eq!(scratch.lanes().0, &[0.0, 0.0]);
        scratch.begin(2, 4);
        assert_eq!(scratch.lanes().0.len(), 8);
        assert!(scratch.lanes().0.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn max_aggregation_compiles() {
        let s = spec(
            &[Some(0.2), None, Some(0.9)],
            Aggregation::Max,
            QuantileMap::identity(5).unwrap(),
        );
        let compiled = s.compile().unwrap();
        for scores in [[0.1f32, 0.9, 0.3], [0.0, 0.0, 0.0], [1.0, 0.5, 0.2]] {
            let (r1, f1) = compiled.score_one(&scores);
            let (r2, f2) = s.score_staged_one(&scores);
            assert_eq!(r1.to_bits(), r2.to_bits());
            assert_eq!(f1.to_bits(), f2.to_bits());
        }
    }
}
