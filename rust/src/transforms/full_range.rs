//! Full-range calibration: a second T^Q strategy (the
//! `lifecycle.calibrationStrategy: fullRange` seam).
//!
//! "Full-range Binary Classifier Calibration for Stable Model Updates
//! in Production" (arXiv:2607.05481) studies the regime where the
//! malicious score mass drifts fast while benign traffic stays
//! stable. A raw empirical quantile map (Eq. 4) re-fitted from live
//! traffic *chases the attacker*: the adversarial mass moves the
//! upper knots every refit, and tie-heavy attack templates collapse
//! knots outright (see `quantile_fit::FitError`). Full-range
//! calibration instead fits a *smooth, low-degree-of-freedom*
//! parametric model — here the repo's Beta mixture (Eq. 6), searched
//! with the same DE moment-matcher the cold-start module already
//! implements — to the live distribution, and maps through its
//! analytic quantiles. The map stays defined over the whole score
//! range (hence "full-range"), is immune to knot collapse under ties,
//! and moves only as fast as four moments can move.
//!
//! Both strategies consume the same inputs (a raw score sample or a
//! `SketchSummary` quantile grid plus a reference grid) and produce
//! the same artifact (a monotone [`QuantileMap`]), so the lifecycle
//! controller drives either through the identical
//! shadow→validate→promote path.

use super::quantile::QuantileMap;
use crate::coldstart::{fit_mixture, FitConfig, MixtureFit};
use anyhow::{ensure, Context, Result};

/// Knobs for the full-range fit. Deliberately cheaper than the
/// offline cold-start defaults: this runs inside the lifecycle tick.
#[derive(Debug, Clone, Copy)]
pub struct FullRangeConfig {
    /// Positive-class prior `w` of the mixture (configured, not
    /// estimated — the feed is unlabeled).
    pub w: f64,
    /// DE search hyper-parameters (validated by `FitConfig::validate`
    /// inside `fit_mixture`).
    pub fit: FitConfig,
}

impl Default for FullRangeConfig {
    fn default() -> Self {
        FullRangeConfig {
            w: 0.02,
            fit: FitConfig {
                n_trials: 3,
                population: 24,
                generations: 80,
                hist_bins: 40,
                seed: 0x4652_4E47, // "FRNG"; refits stay deterministic
                ..FitConfig::default()
            },
        }
    }
}

/// Fit the smooth source model from raw scores and pair its analytic
/// quantiles with the reference grid.
pub fn fit_from_scores(
    scores: &[f64],
    ref_quantiles: &[f64],
    cfg: &FullRangeConfig,
) -> Result<QuantileMap> {
    ensure!(ref_quantiles.len() >= 2, "reference grid needs >= 2 points");
    let fit = fit_mixture(scores, cfg.w, &cfg.fit)
        .context("full-range calibration: mixture fit failed")?;
    map_from_fit(&fit, ref_quantiles)
}

/// Fit from a **pre-estimated equal-mass quantile grid** (the
/// `SketchSummary::quantile_grid` output) — the autopilot's streaming
/// path. The grid's points are treated as an equal-mass pseudo-sample
/// of the live distribution: by construction the i-th point is the
/// i/(n-1) quantile, so the set carries the same first-four-moments
/// information the DE matcher needs, at O(grid) cost independent of
/// how many events produced the estimate. `n_samples` is the Eq. 5
/// currency behind the grid, gated exactly like
/// `quantile_fit::fit_from_grid`.
pub fn fit_from_grid(
    src_grid: &[f64],
    n_samples: u64,
    ref_quantiles: &[f64],
    cfg: &FullRangeConfig,
) -> Result<QuantileMap> {
    ensure!(ref_quantiles.len() >= 2, "reference grid needs >= 2 points");
    ensure!(
        src_grid.len() >= 100,
        "full-range fit needs a grid of >= 100 points, got {}",
        src_grid.len()
    );
    ensure!(
        n_samples >= ref_quantiles.len() as u64,
        "grid estimated from {n_samples} samples for {} quantile points",
        ref_quantiles.len()
    );
    // Scores live on [0,1]; sketch endpoints can sit exactly on the
    // boundary, and f32→f64 round-trips can graze it. Clamp rather
    // than reject — the mixture support is exactly [0,1].
    let pseudo: Vec<f64> = src_grid.iter().map(|&x| x.clamp(0.0, 1.0)).collect();
    let fit = fit_mixture(&pseudo, cfg.w, &cfg.fit)
        .context("full-range calibration: mixture fit from sketch grid failed")?;
    map_from_fit(&fit, ref_quantiles)
}

/// Pair the fitted mixture's analytic quantile grid with the
/// reference grid. The mixture grid is strictly increasing wherever
/// the pdf is positive (and `quantile_grid` ULP-dedups pathological
/// flats), so this cannot hit the empirical path's knot-collapse
/// refusal.
fn map_from_fit(fit: &MixtureFit, ref_quantiles: &[f64]) -> Result<QuantileMap> {
    let src = fit.mixture.quantile_grid(ref_quantiles.len());
    QuantileMap::new(src, ref_quantiles.to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::transforms::quantile_fit;
    use crate::util::{prop, rng::Rng, stats};

    fn beta_sample(alpha: f64, beta: f64, n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.beta(alpha, beta)).collect()
    }

    #[test]
    fn full_range_aligns_distribution() {
        // Map Beta(2,8) samples to a uniform reference through the
        // full-range map: mapped fresh samples must be ~U(0,1).
        let sample = beta_sample(2.0, 8.0, 40_000, 11);
        let refq = stats::prob_grid(257);
        let m = fit_from_scores(&sample, &refq, &FullRangeConfig::default()).unwrap();
        let fresh = beta_sample(2.0, 8.0, 20_000, 12);
        let mapped: Vec<f64> = fresh.iter().map(|&s| m.apply(s)).collect();
        let ks = stats::ks_distance(&mapped, |x| x.clamp(0.0, 1.0));
        assert!(ks < 0.05, "KS = {ks}");
    }

    #[test]
    fn survives_tie_heavy_grids_that_break_the_empirical_fit() {
        // The fast-attack regime: 80% of traffic is one replayed
        // template event with a single score. The empirical quantile
        // fit refuses (knot collapse, satellite-2 gate); the smooth
        // full-range fit still produces a usable monotone map.
        let mut scores = vec![0.31; 8000];
        scores.extend(beta_sample(2.0, 8.0, 2000, 13));
        let refq = stats::prob_grid(129);
        let emp = quantile_fit::fit_from_scores(&scores, &refq);
        assert!(
            emp.unwrap_err().to_string().contains("degenerate quantile grid"),
            "empirical fit should refuse the tied mass"
        );
        let m = fit_from_scores(&scores, &refq, &FullRangeConfig::default()).unwrap();
        for w in [0.0, 0.2, 0.31, 0.5, 0.9, 1.0].windows(2) {
            assert!(m.apply(w[1]) >= m.apply(w[0]), "map must stay monotone");
        }
    }

    #[test]
    fn grid_path_matches_score_path() {
        // Fitting from the equal-mass quantile grid of a sample must
        // land close to fitting from the sample itself.
        let sample = beta_sample(1.5, 12.0, 50_000, 17);
        let refq = stats::prob_grid(129);
        let from_scores = fit_from_scores(&sample, &refq, &FullRangeConfig::default()).unwrap();
        let probs = stats::prob_grid(257);
        let grid = stats::quantiles(&sample, &probs);
        let from_grid =
            fit_from_grid(&grid, sample.len() as u64, &refq, &FullRangeConfig::default()).unwrap();
        for i in 0..=20 {
            let x = i as f64 / 20.0;
            let d = (from_scores.apply(x) - from_grid.apply(x)).abs();
            assert!(d < 0.05, "x={x}: score-path {} vs grid-path {}", from_scores.apply(x), from_grid.apply(x));
        }
    }

    #[test]
    fn grid_path_enforces_arity_and_sample_gates() {
        let refq = stats::prob_grid(129);
        let cfg = FullRangeConfig::default();
        // Too few grid points for a mixture fit.
        let short: Vec<f64> = (0..50).map(|i| i as f64 / 50.0).collect();
        assert!(fit_from_grid(&short, 10_000, &refq, &cfg).is_err());
        // A grid "estimated" from fewer samples than reference points.
        let grid: Vec<f64> = (0..257).map(|i| i as f64 / 256.0).collect();
        assert!(fit_from_grid(&grid, 5, &refq, &cfg).is_err());
        assert!(fit_from_grid(&grid, 10_000, &refq, &cfg).is_ok());
        // Invalid DE config propagates as the satellite-3 typed error.
        let bad = FullRangeConfig {
            fit: FitConfig { population: 3, ..cfg.fit },
            ..cfg
        };
        let err = fit_from_grid(&grid, 10_000, &refq, &bad).unwrap_err();
        assert!(format!("{err:#}").contains("population"), "{err:#}");
    }

    #[test]
    fn prop_strategies_agree_on_stable_distributions() {
        // Strategy-equivalence (ISSUE 10 satellite 4): on a stable,
        // continuous (non-drifting, non-adversarial) distribution the
        // two calibration strategies must produce alert rates within
        // the Eq. 5 delta band of each other — otherwise A/B'ing them
        // through the same promote path would itself look like drift.
        let a = 0.1; // target alert rate
        let delta = 0.3; // Eq. 5 relative-error band
        prop::check(6, |g| {
            let alpha = g.f64(1.5..3.0);
            let beta = g.f64(5.0..12.0);
            let seed = g.usize(1..1_000_000) as u64;
            let sample = beta_sample(alpha, beta, 20_000, seed);
            let refq = stats::prob_grid(129); // uniform reference
            let emp = quantile_fit::fit_from_scores(&sample, &refq)
                .map_err(|e| e.to_string())?;
            let full = fit_from_scores(&sample, &refq, &FullRangeConfig::default())
                .map_err(|e| e.to_string())?;
            // Uniform reference: the (1-a) quantile is 1-a.
            let tau = 1.0 - a;
            let fresh = beta_sample(alpha, beta, 20_000, seed + 1);
            let rate = |m: &QuantileMap| {
                fresh.iter().filter(|&&s| m.apply(s) >= tau).count() as f64
                    / fresh.len() as f64
            };
            let (ra, rb) = (rate(&emp), rate(&full));
            prop_assert!(
                (ra - a).abs() <= delta * a,
                "quantile-map alert rate {ra:.4} outside Eq.5 band of {a}"
            );
            prop_assert!(
                (rb - a).abs() <= delta * a,
                "full-range alert rate {rb:.4} outside Eq.5 band of {a}"
            );
            prop_assert!(
                (ra - rb).abs() <= 2.0 * delta * a,
                "strategies disagree: {ra:.4} vs {rb:.4}"
            );
            Ok(())
        });
    }

    #[test]
    fn deterministic_given_config() {
        let sample = beta_sample(2.0, 9.0, 5_000, 23);
        let refq = stats::prob_grid(65);
        let cfg = FullRangeConfig::default();
        let m1 = fit_from_scores(&sample, &refq, &cfg).unwrap();
        let m2 = fit_from_scores(&sample, &refq, &cfg).unwrap();
        for i in 0..=50 {
            let x = i as f64 / 50.0;
            assert_eq!(m1.apply(x), m2.apply(x));
        }
    }
}
