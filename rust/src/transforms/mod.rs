//! The composable score transformations of paper Section 2.3:
//! Posterior Correction `T^C` (Eq. 3), ensemble aggregation `A`,
//! Quantile Mapping `T^Q` (Eq. 4) with its tenant-specific fitting
//! (Eq. 5), and the configurable reference distribution `R` — plus
//! the compiled per-tenant pipeline (`pipeline`) that fuses the
//! `T^Q ∘ A ∘ T^C` chain into a branch-free kernel for the data plane.

pub mod aggregation;
pub mod full_range;
pub mod pipeline;
pub mod posterior;
pub mod quantile;
pub mod quantile_fit;
pub mod reference;

pub use aggregation::Aggregation;
pub use full_range::FullRangeConfig;
pub use pipeline::{CompiledPipeline, CompiledStages, PipelineScratch, PipelineSpec};
pub use posterior::PosteriorCorrection;
pub use quantile::{QuantileError, QuantileMap};
pub use reference::ReferenceDistribution;
