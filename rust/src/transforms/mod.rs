//! The composable score transformations of paper Section 2.3:
//! Posterior Correction `T^C` (Eq. 3), ensemble aggregation `A`,
//! Quantile Mapping `T^Q` (Eq. 4) with its tenant-specific fitting
//! (Eq. 5), and the configurable reference distribution `R`.

pub mod aggregation;
pub mod posterior;
pub mod quantile;
pub mod quantile_fit;
pub mod reference;

pub use aggregation::Aggregation;
pub use posterior::PosteriorCorrection;
pub use quantile::QuantileMap;
pub use reference::ReferenceDistribution;
