//! Estimating source quantiles from live scores + the Eq. 5 sample-
//! size bound (paper Section 2.3.3 and Appendix A).
//!
//! The source quantiles `q^S_i` are tenant-specific: the same
//! predictor produces different score distributions across tenants,
//! so each client/predictor pair gets its own fit once enough
//! unlabeled traffic has accumulated. "Enough" is Eq. 5:
//!
//! `n ~= z^2 (1 - a) / (delta^2 a)`
//!
//! for target alert rate `a`, relative error `delta` and confidence
//! z-score `z`.

use super::quantile::QuantileMap;
use crate::util::stats;
use anyhow::{ensure, Result};

/// Default ceiling on the fraction of source-grid knots that may be
/// ULP-collapsed ties before a fit is refused (see [`FitError`]).
/// Sketch-derived grids of continuous score distributions sit far
/// below this (KLL item weights stay under the grid spacing whenever
/// `grid points <= sketch k`); crossing it means the live
/// distribution is genuinely tie-dominated and an empirical quantile
/// map would be mostly degenerate.
pub const DEFAULT_MAX_COLLAPSED_FRACTION: f64 = 0.5;

/// Typed fit failure: too many source knots collapsed onto ties.
///
/// `dedup_monotone` makes a tied grid strictly increasing by nudging
/// each tied knot one ULP above its neighbor — numerically sound for
/// the occasional tie, but under an adversarially tie-heavy live
/// distribution (a fast-attack wave replaying one template event, a
/// saturated model pinning scores) most of the grid becomes ULP-wide
/// steps: the fitted `T^Q` then maps a *wide* raw-score interval onto
/// a single reference point and the tenant's alert rate is whatever
/// that one point decides. Refusing the fit (and keeping the previous
/// `T^Q`) is strictly safer, so `fit_from_scores` / `fit_from_grid`
/// return this error when more than `max_fraction` of the knots had
/// to be nudged.
#[derive(Debug, Clone, PartialEq)]
pub struct FitError {
    pub collapsed: usize,
    pub total: usize,
    pub max_fraction: f64,
}

impl std::fmt::Display for FitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "degenerate quantile grid: {} of {} knots collapsed onto ties \
             (> {:.0}% allowed) — refusing a mostly-degenerate T^Q fit",
            self.collapsed,
            self.total,
            100.0 * self.max_fraction
        )
    }
}

impl std::error::Error for FitError {}

/// Eq. 5: minimum number of samples to fit the quantile transformation
/// such that the observed alert rate at target rate `a` stays within
/// relative error `delta` with confidence `z`.
pub fn required_samples(alert_rate: f64, delta: f64, z: f64) -> Result<u64> {
    ensure!(
        alert_rate > 0.0 && alert_rate < 1.0,
        "alert rate must be in (0,1), got {alert_rate}"
    );
    ensure!(delta > 0.0, "relative error must be positive");
    ensure!(z > 0.0, "z-score must be positive");
    Ok((z * z * (1.0 - alert_rate) / (delta * delta * alert_rate)).ceil() as u64)
}

/// Fit source quantiles from observed scores and pair them with the
/// reference grid to produce a tenant-specific `T^Q`.
///
/// `ref_quantiles` are the `q^R_i` of the target distribution at the
/// uniform probability grid; `scores` are the (unlabeled!) aggregated
/// predictor outputs observed for this tenant.
pub fn fit_from_scores(scores: &[f64], ref_quantiles: &[f64]) -> Result<QuantileMap> {
    fit_from_scores_tol(scores, ref_quantiles, DEFAULT_MAX_COLLAPSED_FRACTION)
}

/// [`fit_from_scores`] with an explicit collapsed-knot tolerance
/// (`max_collapsed_fraction` in [0, 1]; 1.0 restores the old
/// always-fit behavior for callers that knowingly handle degenerate
/// grids).
pub fn fit_from_scores_tol(
    scores: &[f64],
    ref_quantiles: &[f64],
    max_collapsed_fraction: f64,
) -> Result<QuantileMap> {
    ensure!(
        scores.len() >= ref_quantiles.len(),
        "need at least one sample per quantile point ({} < {})",
        scores.len(),
        ref_quantiles.len()
    );
    // A NaN among the scores would panic deep inside the quantile
    // sort (`util::stats::quantiles`). `QuantileMap::apply` is total
    // now (NaN propagates instead of panicking on the hot path), so a
    // poisoned event *can* reach a lake replay — reject it here as a
    // typed error on the control-plane path rather than a panic.
    ensure!(
        scores.iter().all(|s| s.is_finite()),
        "cannot fit quantiles from non-finite scores ({} of {} samples non-finite)",
        scores.iter().filter(|s| !s.is_finite()).count(),
        scores.len()
    );
    let probs = stats::prob_grid(ref_quantiles.len());
    let mut src = stats::quantiles(scores, &probs);
    let collapsed = dedup_monotone(&mut src);
    check_collapsed(collapsed, src.len(), max_collapsed_fraction)?;
    QuantileMap::new(src, ref_quantiles.to_vec())
}

/// The degeneracy gate shared by the score and grid fit paths.
fn check_collapsed(collapsed: usize, total: usize, max_fraction: f64) -> Result<()> {
    if collapsed as f64 > max_fraction * total as f64 {
        return Err(FitError {
            collapsed,
            total,
            max_fraction,
        }
        .into());
    }
    Ok(())
}

/// Gate + fit: checks the Eq. 5 bound before fitting, returning the
/// sample requirement in the error message when unmet. This is the
/// check the control plane runs before promoting a custom
/// transformation (Section 3.1).
pub fn fit_gated(
    scores: &[f64],
    ref_quantiles: &[f64],
    alert_rate: f64,
    delta: f64,
    z: f64,
) -> Result<QuantileMap> {
    let need = required_samples(alert_rate, delta, z)?;
    ensure!(
        scores.len() as u64 >= need,
        "insufficient samples for quantile fit: have {}, Eq.5 requires {} \
         (a={alert_rate}, delta={delta}, z={z})",
        scores.len(),
        need
    );
    fit_from_scores(scores, ref_quantiles)
}

/// Fit from a **pre-estimated source quantile grid** instead of a raw
/// score replay — O(grid), independent of how many events produced
/// the estimate. `n_samples` is the number of observations behind the
/// grid (the Eq. 5 currency). This is the primitive the lifecycle
/// autopilot's streaming-sketch refits consume
/// (`lifecycle::SketchSummary::fit_quantile_map`): the sketch hands
/// over its merged quantile grid, and recalibration never replays the
/// data lake. [`fit_from_scores`] remains for offline fits over
/// explicit sample vectors.
pub fn fit_from_grid(
    src_grid: Vec<f64>,
    n_samples: u64,
    ref_quantiles: &[f64],
) -> Result<QuantileMap> {
    fit_from_grid_tol(src_grid, n_samples, ref_quantiles, DEFAULT_MAX_COLLAPSED_FRACTION)
}

/// [`fit_from_grid`] with an explicit collapsed-knot tolerance (see
/// [`fit_from_scores_tol`]).
pub fn fit_from_grid_tol(
    mut src_grid: Vec<f64>,
    n_samples: u64,
    ref_quantiles: &[f64],
    max_collapsed_fraction: f64,
) -> Result<QuantileMap> {
    ensure!(
        src_grid.len() == ref_quantiles.len(),
        "source grid has {} points for {} reference points",
        src_grid.len(),
        ref_quantiles.len()
    );
    ensure!(
        n_samples >= ref_quantiles.len() as u64,
        "grid estimated from {n_samples} samples for {} quantile points",
        ref_quantiles.len()
    );
    let collapsed = dedup_monotone(&mut src_grid);
    check_collapsed(collapsed, src_grid.len(), max_collapsed_fraction)?;
    QuantileMap::new(src_grid, ref_quantiles.to_vec())
}

/// Gate + fit from a grid: the Eq. 5 bound applies to `n_samples`,
/// exactly as the data-lake path applies it to the replayed count.
pub fn fit_grid_gated(
    src_grid: Vec<f64>,
    n_samples: u64,
    ref_quantiles: &[f64],
    alert_rate: f64,
    delta: f64,
    z: f64,
) -> Result<QuantileMap> {
    let need = required_samples(alert_rate, delta, z)?;
    ensure!(
        n_samples >= need,
        "insufficient samples for quantile fit: grid built from {n_samples}, Eq.5 \
         requires {need} (a={alert_rate}, delta={delta}, z={z})"
    );
    fit_from_grid(src_grid, n_samples, ref_quantiles)
}

/// Make a non-decreasing grid strictly increasing by nudging ties up
/// by one ULP. Empirical quantiles of heavily-concentrated score
/// distributions (most fraud scores pile near 0) produce ties which
/// the `QuantileMap` constructor rejects.
///
/// Returns the number of knots that had to be nudged — the fit paths
/// turn an excessive count into a typed [`FitError`] instead of
/// silently producing a mostly-degenerate map.
pub fn dedup_monotone(grid: &mut [f64]) -> usize {
    let mut collapsed = 0;
    for i in 1..grid.len() {
        if grid[i] <= grid[i - 1] {
            grid[i] = next_up(grid[i - 1]);
            collapsed += 1;
        }
    }
    collapsed
}

#[inline]
fn next_up(x: f64) -> f64 {
    // f64::next_up is unstable on 1.95's MSRV contexts; do it manually.
    if x.is_nan() || x == f64::INFINITY {
        return x;
    }
    if x == 0.0 {
        return f64::from_bits(1);
    }
    let bits = x.to_bits();
    if x > 0.0 {
        f64::from_bits(bits + 1)
    } else {
        f64::from_bits(bits - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::{prop, rng::Rng, stats};

    #[test]
    fn eq5_matches_paper_example() {
        // Paper Appendix A: z=1.96, delta<=0.2 => n*a ~= z^2/delta^2 ~= 100.
        let n = required_samples(0.01, 0.2, 1.96).unwrap();
        let na = n as f64 * 0.01;
        assert!((na - 96.04 * 0.99).abs() < 2.0, "n*a = {na}");
    }

    #[test]
    fn eq5_scales_inversely_with_alert_rate() {
        let n1 = required_samples(0.001, 0.1, 1.96).unwrap();
        let n2 = required_samples(0.01, 0.1, 1.96).unwrap();
        assert!(n1 > 9 * n2 && n1 < 11 * n2, "{n1} vs {n2}");
    }

    #[test]
    fn eq5_rejects_degenerate() {
        assert!(required_samples(0.0, 0.1, 1.96).is_err());
        assert!(required_samples(1.0, 0.1, 1.96).is_err());
        assert!(required_samples(0.01, 0.0, 1.96).is_err());
        assert!(required_samples(0.01, 0.1, -1.0).is_err());
    }

    #[test]
    fn fit_aligns_distribution() {
        // Fit on Beta(2,8)-ish samples, map to uniform; mapped sample
        // must be ~U(0,1) by KS distance.
        let mut rng = Rng::new(42);
        let sample: Vec<f64> = (0..100_000).map(|_| rng.beta(2.0, 8.0)).collect();
        let refq = stats::prob_grid(513); // uniform reference
        let m = fit_from_scores(&sample, &refq).unwrap();
        let fresh: Vec<f64> = (0..20_000).map(|_| rng.beta(2.0, 8.0)).collect();
        let mapped: Vec<f64> = fresh.iter().map(|&s| m.apply(s)).collect();
        let ks = stats::ks_distance(&mapped, |x| x.clamp(0.0, 1.0));
        assert!(ks < 0.02, "KS = {ks}");
    }

    #[test]
    fn fit_refuses_degenerate_tie_heavy_grids() {
        // Regression (ISSUE 10 satellite 2): 99% of scores identical
        // means ~99% of the knots are ULP-collapsed ties — pre-PR the
        // fit silently succeeded and mapped the entire tied mass's
        // score interval onto one reference point. Now it is a typed
        // refusal at the default tolerance.
        let mut scores = vec![1e-6; 5000];
        scores.extend((0..50).map(|i| 0.1 + i as f64 / 100.0));
        let refq = stats::prob_grid(101);
        let err = fit_from_scores(&scores, &refq).unwrap_err();
        assert!(
            err.to_string().contains("degenerate quantile grid"),
            "wrong error: {err}"
        );
        // A caller that knowingly tolerates degeneracy can opt out —
        // and still gets a monotone (ULP-stepped) map.
        let m = fit_from_scores_tol(&scores, &refq, 1.0).unwrap();
        assert!(m.apply(1e-6) <= m.apply(0.5));
        // The grid path enforces the same gate.
        let mut grid = vec![0.25; 101];
        grid[100] = 0.9;
        assert!(
            fit_from_grid(grid.clone(), 5000, &refq)
                .unwrap_err()
                .to_string()
                .contains("degenerate quantile grid")
        );
        assert!(fit_from_grid_tol(grid, 5000, &refq, 1.0).is_ok());
    }

    #[test]
    fn prop_tie_fraction_decides_fit_refusal() {
        // Quantifies the degeneracy: with tie mass `t` of the sample
        // pinned to one value, ~t of the quantile knots collapse. Well
        // above the default tolerance the fit must refuse; with no
        // ties it must succeed; and the opt-out map concentrates the
        // whole tied interval onto (numerically) one reference point —
        // the failure mode the refusal exists to stop.
        prop::check(40, |g| {
            let t = g.f64(0.70..0.95);
            let tie_at = g.f64(0.2..0.8);
            let n = g.usize(1000..4000);
            let n_tied = (t * n as f64) as usize;
            let mut scores = vec![tie_at; n_tied];
            for _ in 0..(n - n_tied) {
                scores.push(g.f64(0.0..1.0));
            }
            let refq = stats::prob_grid(101);
            let err = fit_from_scores(&scores, &refq)
                .err()
                .ok_or_else(|| format!("tie fraction {t:.2} fitted without refusal"))?;
            prop_assert!(
                err.to_string().contains("degenerate quantile grid"),
                "wrong error: {err}"
            );
            // Opt-out: the degenerate map drops the entire tied mass
            // (~t of all traffic) onto ONE reference value, with an
            // ULP-wide cliff spanning ~t of the reference range just
            // above it — the failure mode the refusal exists to stop.
            let m = fit_from_scores_tol(&scores, &refq, 1.0).map_err(|e| e.to_string())?;
            let cliff = m.apply(tie_at + 1e-9) - m.apply(tie_at);
            prop_assert!(
                cliff > 0.4 * t,
                "expected an ULP cliff spanning ~{t:.2} of the reference, got {cliff:.3}"
            );
            // Continuous samples stay fittable at the default gate.
            let clean: Vec<f64> = (0..n).map(|_| g.f64(0.0..1.0).powi(2)).collect();
            prop_assert!(
                fit_from_scores(&clean, &refq).is_ok(),
                "continuous sample refused"
            );
            Ok(())
        });
    }

    #[test]
    fn fit_requires_enough_samples() {
        let refq = stats::prob_grid(101);
        assert!(fit_from_scores(&[0.1; 50], &refq).is_err());
    }

    #[test]
    fn fit_rejects_non_finite_scores_with_typed_error() {
        // One poisoned sample in a lake replay must be an error, not a
        // panic inside the quantile sort.
        let refq = stats::prob_grid(11);
        let mut scores: Vec<f64> = (0..100).map(|i| i as f64 / 100.0).collect();
        scores[50] = f64::NAN;
        let err = fit_from_scores(&scores, &refq).unwrap_err();
        assert!(err.to_string().contains("non-finite"), "{err}");
        scores[50] = f64::INFINITY;
        assert!(fit_from_scores(&scores, &refq).is_err());
        scores[50] = 0.5;
        assert!(fit_from_scores(&scores, &refq).is_ok());
    }

    #[test]
    fn gated_fit_enforces_eq5() {
        let refq = stats::prob_grid(11);
        let scores: Vec<f64> = (0..100).map(|i| i as f64 / 100.0).collect();
        // a=0.01, delta=0.2, z=1.96 needs ~9509 samples; 100 is too few.
        let err = fit_gated(&scores, &refq, 0.01, 0.2, 1.96).unwrap_err();
        assert!(err.to_string().contains("Eq.5"), "{err}");
        // With a lax requirement it passes.
        assert!(fit_gated(&scores, &refq, 0.5, 0.5, 1.0).is_ok());
    }

    #[test]
    fn sketch_fit_matches_exact_fit() {
        // Fit T^Q from a sketch's quantile grid (the autopilot refit
        // path, via the generic fit_from_grid primitive) and from the
        // full sample vector: both must align the mapped distribution
        // with the reference to comparable KS distance.
        use crate::lifecycle::sketch::QuantileSketch;
        let mut rng = Rng::new(21);
        let sample: Vec<f64> = (0..60_000).map(|_| rng.beta(2.0, 8.0)).collect();
        let mut sk = QuantileSketch::with_seed(1024, 9);
        for &x in &sample {
            sk.insert(x);
        }
        let refq = stats::prob_grid(257); // uniform reference
        let exact = fit_from_scores(&sample, &refq).unwrap();
        let sketched = sk.summary().fit_quantile_map(&refq).unwrap();
        let fresh: Vec<f64> = (0..20_000).map(|_| rng.beta(2.0, 8.0)).collect();
        let ks_exact =
            stats::ks_distance(&fresh.iter().map(|&s| exact.apply(s)).collect::<Vec<_>>(), |x| {
                x.clamp(0.0, 1.0)
            });
        let ks_sketch = stats::ks_distance(
            &fresh.iter().map(|&s| sketched.apply(s)).collect::<Vec<_>>(),
            |x| x.clamp(0.0, 1.0),
        );
        assert!(ks_exact < 0.02, "exact KS {ks_exact}");
        assert!(
            ks_sketch < ks_exact + 2.0 * sk.epsilon(),
            "sketch KS {ks_sketch} vs exact {ks_exact} (eps {})",
            sk.epsilon()
        );
    }

    #[test]
    fn sketch_fit_is_gated_by_eq5() {
        use crate::lifecycle::sketch::QuantileSketch;
        let mut sk = QuantileSketch::new(256);
        let mut rng = Rng::new(22);
        for _ in 0..100 {
            sk.insert(rng.f64());
        }
        let refq = stats::prob_grid(11);
        let err = sk
            .summary()
            .fit_quantile_map_gated(&refq, 0.01, 0.2, 1.96)
            .unwrap_err();
        assert!(err.to_string().contains("Eq.5"), "{err}");
        assert!(sk.summary().fit_quantile_map_gated(&refq, 0.5, 0.5, 1.0).is_ok());
    }

    #[test]
    fn grid_fit_rejects_mismatch_and_thin_samples() {
        let refq = stats::prob_grid(11);
        // Grid arity must match the reference.
        assert!(fit_from_grid(vec![0.0, 1.0], 1000, &refq).is_err());
        // A grid "estimated" from fewer samples than points is noise.
        let grid: Vec<f64> = (0..11).map(|i| i as f64 / 10.0).collect();
        assert!(fit_from_grid(grid.clone(), 5, &refq).is_err());
        assert!(fit_from_grid(grid, 1000, &refq).is_ok());
    }

    #[test]
    fn prop_fitted_map_is_monotone() {
        prop::check(50, |g| {
            let n = g.usize(200..2000);
            let scores: Vec<f64> = (0..n).map(|_| g.f64(0.0..1.0).powi(3)).collect();
            let refq = stats::prob_grid(33);
            let m = fit_from_scores(&scores, &refq).map_err(|e| e.to_string())?;
            let mut xs = g.vec_f64(0.0..1.0, 2..50);
            xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let ys: Vec<f64> = xs.iter().map(|&x| m.apply(x)).collect();
            for w in ys.windows(2) {
                prop_assert!(w[1] >= w[0], "monotonicity broken");
            }
            Ok(())
        });
    }

    #[test]
    fn prop_dedup_is_strictly_increasing() {
        prop::check(200, |g| {
            let mut grid = g.vec_f64(0.0..1.0, 2..100);
            grid.sort_by(|a, b| a.partial_cmp(b).unwrap());
            // Inject ties.
            if grid.len() > 4 {
                grid[2] = grid[1];
                let k = grid.len() / 2;
                grid[k] = grid[k - 1];
            }
            dedup_monotone(&mut grid);
            for w in grid.windows(2) {
                prop_assert!(w[1] > w[0], "tie survived dedup");
            }
            Ok(())
        });
    }

    #[test]
    fn monte_carlo_validates_eq5_variance() {
        // Appendix A: the k-th order statistic's alert-rate deviation
        // should stay within +-delta*a for ~95% of trials at the Eq.5
        // sample size. Run a cheap Monte-Carlo check at a=5%.
        let a = 0.05;
        let delta = 0.2;
        let z = 1.96;
        let n = required_samples(a, delta, z).unwrap() as usize;
        let mut rng = Rng::new(7);
        let trials = 400;
        let mut within = 0;
        for _ in 0..trials {
            let mut sample: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
            sample.sort_by(|x, y| x.partial_cmp(y).unwrap());
            let k = ((1.0 - a) * n as f64).round() as usize;
            let threshold = sample[k.min(n - 1)];
            // True alert rate of this threshold under U(0,1):
            let true_alert = 1.0 - threshold;
            if (true_alert - a).abs() <= delta * a {
                within += 1;
            }
        }
        let coverage = within as f64 / trials as f64;
        assert!(coverage > 0.90, "coverage {coverage} too low");
    }
}
