//! Posterior Correction `T^C` (paper Eq. 3, after Dal Pozzolo et al.).
//!
//! Reverses the posterior bias introduced by undersampling the
//! negative (majority) class at rate `beta` during training:
//!
//! `T^C(s) = beta * s / (1 - (1 - beta) * s)`
//!
//! Purely analytical — "negligible latency overhead" on the hot path
//! (a handful of FLOPs; see `benches/transform_bench.rs`).

use anyhow::{ensure, Result};

/// A validated posterior-correction transformation for one expert.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PosteriorCorrection {
    beta: f64,
}

impl PosteriorCorrection {
    /// `beta` is the negative-class keep-rate used at training time;
    /// must lie in (0, 1]. `beta = 1` is the identity (no
    /// undersampling).
    pub fn new(beta: f64) -> Result<Self> {
        ensure!(
            beta > 0.0 && beta <= 1.0 && beta.is_finite(),
            "undersampling ratio beta must be in (0, 1], got {beta}"
        );
        Ok(PosteriorCorrection { beta })
    }

    #[inline]
    pub fn beta(&self) -> f64 {
        self.beta
    }

    /// Apply Eq. 3. Input clamped to [0, 1]; output in [0, 1].
    #[inline]
    pub fn apply(&self, score: f64) -> f64 {
        let s = score.clamp(0.0, 1.0);
        let denom = 1.0 - (1.0 - self.beta) * s;
        // denom >= beta > 0 for s in [0,1], so this is always finite.
        (self.beta * s / denom).clamp(0.0, 1.0)
    }

    /// The inverse map (useful in tests and for replaying the bias):
    /// biased(s) = s / (s + beta (1 - s)).
    #[inline]
    pub fn unapply(&self, corrected: f64) -> f64 {
        let p = corrected.clamp(0.0, 1.0);
        p / (p + self.beta * (1.0 - p))
    }

    /// Apply in place over a batch.
    pub fn apply_batch(&self, scores: &mut [f64]) {
        for s in scores {
            *s = self.apply(*s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop;

    #[test]
    fn rejects_bad_beta() {
        assert!(PosteriorCorrection::new(0.0).is_err());
        assert!(PosteriorCorrection::new(-0.1).is_err());
        assert!(PosteriorCorrection::new(1.1).is_err());
        assert!(PosteriorCorrection::new(f64::NAN).is_err());
        assert!(PosteriorCorrection::new(1.0).is_ok());
    }

    #[test]
    fn fixed_points() {
        for beta in [0.02, 0.18, 0.5, 1.0] {
            let t = PosteriorCorrection::new(beta).unwrap();
            assert_eq!(t.apply(0.0), 0.0);
            assert!((t.apply(1.0) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn identity_at_beta_one() {
        let t = PosteriorCorrection::new(1.0).unwrap();
        for i in 0..=100 {
            let s = i as f64 / 100.0;
            assert!((t.apply(s) - s).abs() < 1e-12);
        }
    }

    #[test]
    fn deflates_for_small_beta() {
        let t = PosteriorCorrection::new(0.02).unwrap();
        for i in 1..100 {
            let s = i as f64 / 100.0;
            assert!(t.apply(s) < s);
        }
    }

    #[test]
    fn prop_monotone_and_bounded() {
        prop::check(256, |g| {
            let beta = g.f64(0.001..1.0);
            let t = PosteriorCorrection::new(beta).map_err(|e| e.to_string())?;
            let mut xs = g.vec_f64(0.0..1.0, 2..200);
            xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let ys: Vec<f64> = xs.iter().map(|&x| t.apply(x)).collect();
            for w in ys.windows(2) {
                prop_assert!(w[1] >= w[0], "not monotone: {} > {}", w[0], w[1]);
            }
            for &y in &ys {
                prop_assert!((0.0..=1.0).contains(&y), "out of range: {y}");
            }
            Ok(())
        });
    }

    #[test]
    fn prop_unapply_inverts() {
        prop::check(256, |g| {
            let beta = g.f64(0.001..1.0);
            let s = g.f64(0.0..1.0);
            let t = PosteriorCorrection::new(beta).unwrap();
            let round = t.unapply(t.apply(s));
            prop_assert!(
                (round - s).abs() < 1e-9,
                "unapply(apply({s})) = {round} (beta={beta})"
            );
            Ok(())
        });
    }

    #[test]
    fn matches_prior_shift_algebra() {
        // If the true posterior is p and negatives are kept w.p. beta,
        // the biased posterior is p / (p + beta (1-p)); Eq. 3 recovers p.
        for beta in [0.02, 0.18] {
            let t = PosteriorCorrection::new(beta).unwrap();
            for i in 1..100 {
                let p = i as f64 / 100.0;
                let biased = p / (p + beta * (1.0 - p));
                assert!((t.apply(biased) - p).abs() < 1e-12, "beta={beta} p={p}");
            }
        }
    }

    #[test]
    fn batch_matches_scalar() {
        let t = PosteriorCorrection::new(0.18).unwrap();
        let mut batch: Vec<f64> = (0..=10).map(|i| i as f64 / 10.0).collect();
        let scalar: Vec<f64> = batch.iter().map(|&s| t.apply(s)).collect();
        t.apply_batch(&mut batch);
        assert_eq!(batch, scalar);
    }

    #[test]
    fn clamps_out_of_range_inputs() {
        let t = PosteriorCorrection::new(0.18).unwrap();
        assert_eq!(t.apply(-0.5), 0.0);
        assert!((t.apply(1.5) - 1.0).abs() < 1e-12);
    }
}
