//! The configurable reference distribution `R` (paper Section 2.3.3).
//!
//! MUSE guarantees the final score follows a fixed reference
//! distribution regardless of the predictor's internals. The paper's
//! production `R` is proprietary; per docs/ARCHITECTURE.md we substitute a Beta
//! mixture with the shape the paper describes: "high density near 0
//! and a longer tail towards 1", giving clients granularity in the
//! useful alert-rate region (0.1%-1%). Alternatively `R` can mirror a
//! legacy system's distribution for migrations.

use crate::coldstart::mixture::BetaMixture;
use anyhow::Result;

/// A named reference distribution with a precomputable quantile grid.
#[derive(Debug, Clone)]
pub struct ReferenceDistribution {
    pub name: String,
    pub mixture: BetaMixture,
}

impl ReferenceDistribution {
    /// The default production-style reference: ~70% of mass in
    /// [0, 0.1) (so a raw predictor putting everything in bin 0 shows
    /// the paper's Fig. 4 "+43% in bin 0" signature), smoothly
    /// decaying mass towards 1 with a fat enough tail that thresholds
    /// at the 99-99.9th percentile are meaningful.
    pub fn fraud_default() -> Self {
        ReferenceDistribution {
            name: "fraud-default".to_string(),
            mixture: BetaMixture::from_params(0.25, 1.0, 25.0, 1.6, 2.2)
                .expect("static parameters are valid"),
        }
    }

    /// A uniform reference (Beta(1,1)); scores become percentiles,
    /// like Sift's secondary percentile score.
    pub fn uniform() -> Self {
        ReferenceDistribution {
            name: "uniform".to_string(),
            mixture: BetaMixture::from_params(0.0, 1.0, 1.0, 1.0, 1.0)
                .expect("static parameters are valid"),
        }
    }

    /// A custom mixture (e.g. fitted to a legacy system's scores for
    /// migration, Section 2.3.3).
    pub fn custom(name: impl Into<String>, mixture: BetaMixture) -> Result<Self> {
        Ok(ReferenceDistribution { name: name.into(), mixture })
    }

    /// Quantile grid `q^R_0..q^R_N` at `n_points` uniform probabilities.
    pub fn quantile_grid(&self, n_points: usize) -> Vec<f64> {
        self.mixture.quantile_grid(n_points)
    }

    /// Target probability mass per uniform score bin — the "target
    /// distribution" column of the paper's Figs. 4 and 6.
    pub fn bin_shares(&self, n_bins: usize) -> Vec<f64> {
        self.mixture.bin_shares(n_bins)
    }

    pub fn cdf(&self, x: f64) -> f64 {
        self.mixture.cdf(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_shape_matches_paper_description() {
        let r = ReferenceDistribution::fraud_default();
        let shares = r.bin_shares(10);
        // High density near zero...
        assert!(
            shares[0] > 0.55 && shares[0] < 0.85,
            "bin0 share = {}",
            shares[0]
        );
        // ...with a usable long tail: every upper bin keeps >= 0.2% mass
        // so alert thresholds in [0.7, 1.0] remain meaningful.
        for (i, &s) in shares.iter().enumerate().skip(5) {
            assert!(s > 0.002, "bin {i} share {s} too small");
        }
        // Monotone decay from bin 0.
        assert!(shares[0] > shares[1] && shares[1] > shares[2]);
        assert!((shares.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn raw_in_bin0_yields_fig4_signature() {
        // A raw concentrated predictor (all mass in bin 0) vs this
        // target gives ~+40% error in bin 0 and -100% elsewhere —
        // matching the paper's Fig. 4 "predictor raw" series.
        let r = ReferenceDistribution::fraud_default();
        let shares = r.bin_shares(10);
        let err0 = 100.0 * (1.0 - shares[0]) / shares[0];
        assert!(err0 > 20.0 && err0 < 80.0, "bin0 rel err = {err0}");
    }

    #[test]
    fn uniform_reference_is_identity_on_percentiles() {
        let r = ReferenceDistribution::uniform();
        let g = r.quantile_grid(101);
        for (i, q) in g.iter().enumerate() {
            assert!((q - i as f64 / 100.0).abs() < 1e-9);
        }
    }

    #[test]
    fn grid_is_strictly_increasing() {
        let g = ReferenceDistribution::fraud_default().quantile_grid(1025);
        for w in g.windows(2) {
            assert!(w[1] > w[0]);
        }
        assert_eq!(g[0], 0.0);
        assert_eq!(*g.last().unwrap(), 1.0);
    }

    #[test]
    fn alert_rate_region_has_granularity() {
        // Thresholding the reference at its 99th..99.9th percentile
        // must produce distinct, high score values (paper: clients
        // need granularity at 0.1%-1% alert rates).
        let r = ReferenceDistribution::fraud_default();
        let q99 = r.mixture.quantile(0.99);
        let q999 = r.mixture.quantile(0.999);
        assert!(q99 > 0.5, "q99 = {q99}");
        assert!(q999 > q99 + 0.01, "q999 = {q999} vs q99 = {q99}");
    }
}
