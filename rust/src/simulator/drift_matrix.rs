//! Adversarial-drift scenario matrix: characterize *when* the
//! paper's quantile-mapping T^Q wins or loses, cell by cell, A/B'd
//! against the full-range calibration strategy
//! (`transforms::full_range`, the arXiv:2607.05481 regime).
//!
//! Every cell drives the **real** engine + lifecycle controller — the
//! only control inputs are `LifecycleHub::tick` calls, exactly like
//! the drift-storm scenario — and scores two things through the
//! existing `calibration/` metrics:
//!
//! * **alert-rate stability**: observed alert rate at the reference
//!   distribution's fixed `(1 - a)` quantile `tau`, per phase
//!   (calibrated steady state / during the regime shift / after the
//!   autopilot reacted);
//! * **fraud recall at tau**: share of labeled-fraud events scoring
//!   `>= tau` (threshold recall, not recall@FPR — the fixed-threshold
//!   view is what a client's decision rule actually experiences, and
//!   it is *not* invariant under T^Q refits, which is the point).
//!
//! The cells:
//!
//! * `CoordinatedWave` — two tenants on one predictor hit by the same
//!   fraud wave simultaneously; both pairs must detect → refit →
//!   shadow → validate → promote independently.
//! * `FastAttack` — the 2607.05481 regime sharpened to its worst
//!   case: 60% of traffic is ONE replayed template event (identical
//!   features, identical raw score) while benign stays stable. The
//!   empirical quantile refit's knots collapse onto the tie mass (a
//!   typed `FitError` after the satellite-2 gate); the full-range
//!   mixture still fits a usable monotone map.
//! * `OnboardingStorm` — N brand-new tenants with zero history; the
//!   cold-start Beta-mixture T^Q (`lifecycle.coldstartMinSamples`)
//!   must be fitted and installed long before the Eq. 5 gate.
//! * `LabelDelay` — a fraud wave whose *labels* arrive `D` batches
//!   late: alert-rate stability is observable immediately, recall
//!   only in the lagged window — the matrix reports both.
//! * `ClassImbalance` — the class prior collapses (1.5% → 0.2%
//!   fraud) with covariates unchanged; a rank-based T^Q must neither
//!   false-alarm nor lose its alert-rate anchor.
//!
//! Seeded end to end: `MUSE_DRIFT_MATRIX_SEED` overrides the default
//! seed (decimal or 0x-hex), and a failing cell's error names the
//! seed + cell so any run can be replayed exactly.

use crate::calibration::alert_rate;
use crate::config::{CalibrationStrategy, Intent, MuseConfig};
use crate::coordinator::{Engine, ScoreRequest};
use crate::lifecycle::PairStatus;
use crate::runtime::{ModelPool, SimArtifacts};
use crate::simulator::workload::{Event, TenantProfile, Workload};
use crate::util::rng::Rng;
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::sync::Arc;

/// Env var overriding the matrix seed (replay recipe, mirroring the
/// model-based suite's `MUSE_MBT_SEED`).
pub const SEED_ENV: &str = "MUSE_DRIFT_MATRIX_SEED";

/// Resolve the matrix seed: `MUSE_DRIFT_MATRIX_SEED` if set (decimal
/// or `0x`-hex), else `default`.
pub fn matrix_seed(default: u64) -> u64 {
    match std::env::var(SEED_ENV) {
        Ok(s) => parse_seed(&s).unwrap_or(default),
        Err(_) => default,
    }
}

fn parse_seed(s: &str) -> Option<u64> {
    let s = s.trim();
    if let Some(hex) = s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        u64::from_str_radix(hex, 16).ok()
    } else {
        s.parse().ok()
    }
}

/// One drift regime (a matrix row; columns are the strategies).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DriftCell {
    CoordinatedWave,
    FastAttack,
    OnboardingStorm,
    LabelDelay,
    ClassImbalance,
}

impl DriftCell {
    pub const ALL: [DriftCell; 5] = [
        DriftCell::CoordinatedWave,
        DriftCell::FastAttack,
        DriftCell::OnboardingStorm,
        DriftCell::LabelDelay,
        DriftCell::ClassImbalance,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            DriftCell::CoordinatedWave => "coordinated-wave",
            DriftCell::FastAttack => "fast-attack",
            DriftCell::OnboardingStorm => "onboarding-storm",
            DriftCell::LabelDelay => "label-delay",
            DriftCell::ClassImbalance => "class-imbalance",
        }
    }
}

/// Matrix parameters (defaults sized for the CI smoke run: the full
/// 5 x 2 grid is ~150k scored events).
#[derive(Debug, Clone)]
pub struct DriftMatrixConfig {
    pub seed: u64,
    /// Events per batch; one controller tick per batch.
    pub batch_size: usize,
    /// Cap on batches waiting for the initial Eq. 5 fit.
    pub calibration_batches: usize,
    /// Batches per alert-rate measurement window.
    pub measure_batches: usize,
    /// Cap on storm batches (wave / label-delay recovery).
    pub storm_batches: usize,
    /// Fixed fast-attack batches (no early exit: both strategies see
    /// the identical stream).
    pub attack_batches: usize,
    /// Batches of collapsed-prior traffic (class-imbalance).
    pub imbalance_batches: usize,
    pub onboarding_tenants: usize,
    /// Post-cold-start measurement rounds (onboarding).
    pub onboarding_rounds: usize,
    /// Label latency in batches (label-delay cell).
    pub label_delay_batches: usize,
    /// The collapsed positive prior (class-imbalance cell).
    pub imbalance_fraud_rate: f64,
    pub cells: Vec<DriftCell>,
    pub strategies: Vec<CalibrationStrategy>,
}

impl Default for DriftMatrixConfig {
    fn default() -> Self {
        DriftMatrixConfig {
            seed: matrix_seed(0x4D41_5452), // "MATR"
            batch_size: 256,
            calibration_batches: 40,
            measure_batches: 8,
            storm_batches: 60,
            attack_batches: 26,
            imbalance_batches: 16,
            onboarding_tenants: 6,
            onboarding_rounds: 2,
            label_delay_batches: 4,
            imbalance_fraud_rate: 0.002,
            cells: DriftCell::ALL.to_vec(),
            strategies: vec![CalibrationStrategy::QuantileMap, CalibrationStrategy::FullRange],
        }
    }
}

/// Alert-rate + threshold-recall over one measurement window, via the
/// existing `calibration::alert_rate` metric.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PhaseMetrics {
    pub alert_rate: f64,
    /// Share of labeled frauds scoring >= tau (0 if no frauds seen).
    pub fraud_recall: f64,
    pub events: u64,
    pub frauds: u64,
}

#[derive(Default)]
struct PhaseAcc {
    scores: Vec<f64>,
    labels: Vec<f64>,
}

impl PhaseAcc {
    fn push(&mut self, score: f64, is_fraud: bool) {
        self.scores.push(score);
        self.labels.push(if is_fraud { 1.0 } else { 0.0 });
    }

    fn metrics(&self, tau: f64) -> PhaseMetrics {
        let fraud_scores: Vec<f64> = self
            .scores
            .iter()
            .zip(&self.labels)
            .filter(|(_, &y)| y > 0.5)
            .map(|(&s, _)| s)
            .collect();
        PhaseMetrics {
            alert_rate: alert_rate(&self.scores, tau),
            fraud_recall: alert_rate(&fraud_scores, tau),
            events: self.scores.len() as u64,
            frauds: fraud_scores.len() as u64,
        }
    }
}

/// One (cell, strategy) outcome.
#[derive(Debug, Clone)]
pub struct CellOutcome {
    pub cell: &'static str,
    pub strategy: &'static str,
    pub target_alert_rate: f64,
    pub before: PhaseMetrics,
    pub during: PhaseMetrics,
    pub after: PhaseMetrics,
    pub fits: u64,
    pub promotions: u64,
    pub validation_failures: u64,
    pub coldstart_fits: u64,
    pub drift_skips: u64,
    /// A refit was refused on the satellite-2 degenerate-grid gate.
    pub refit_refused: bool,
    pub dropped_samples: u64,
    pub events_total: u64,
    pub note: String,
}

impl CellOutcome {
    fn rel_err(&self, m: &PhaseMetrics) -> f64 {
        (m.alert_rate - self.target_alert_rate).abs() / self.target_alert_rate
    }

    pub fn render(&self) -> String {
        format!(
            "{:<17} {:<11} alert {:.3}/{:.3}/{:.3} recall {:.2}/{:.2}/{:.2} \
             fits {} prom {} vfail {} cold {} refused {} | {}",
            self.cell,
            self.strategy,
            self.before.alert_rate,
            self.during.alert_rate,
            self.after.alert_rate,
            self.before.fraud_recall,
            self.during.fraud_recall,
            self.after.fraud_recall,
            self.fits,
            self.promotions,
            self.validation_failures,
            self.coldstart_fits,
            self.refit_refused,
            self.note,
        )
    }
}

/// Full matrix report.
#[derive(Debug, Clone)]
pub struct MatrixReport {
    pub seed: u64,
    pub outcomes: Vec<CellOutcome>,
    pub events_total: u64,
}

impl MatrixReport {
    pub fn render(&self) -> String {
        let mut out = format!(
            "drift matrix (seed 0x{:X}, {} cells, {} events; replay: {}=0x{:X}):\n",
            self.seed,
            self.outcomes.len(),
            self.events_total,
            SEED_ENV,
            self.seed
        );
        for o in &self.outcomes {
            out.push_str("  ");
            out.push_str(&o.render());
            out.push('\n');
        }
        out
    }

    /// Stable fingerprint of every numeric outcome — two runs with the
    /// same seed must produce identical fingerprints (the satellite-4
    /// determinism contract).
    pub fn fingerprint(&self) -> String {
        let mut out = String::new();
        for o in &self.outcomes {
            let p = |m: &PhaseMetrics| {
                format!(
                    "a={:.6},r={:.6},n={},f={};",
                    m.alert_rate, m.fraud_recall, m.events, m.frauds
                )
            };
            out.push_str(&format!(
                "{}/{}:{}{}{}fits={},prom={},vfail={},cold={},refused={},ev={}\n",
                o.cell,
                o.strategy,
                p(&o.before),
                p(&o.during),
                p(&o.after),
                o.fits,
                o.promotions,
                o.validation_failures,
                o.coldstart_fits,
                o.refit_refused,
                o.events_total,
            ));
        }
        out
    }
}

/// Run the matrix: every configured cell x strategy, each on a fresh
/// engine over the synthetic sim-dialect artifacts.
pub fn run_drift_matrix(cfg: &DriftMatrixConfig) -> Result<MatrixReport> {
    ensure!(!cfg.cells.is_empty(), "no cells configured");
    ensure!(!cfg.strategies.is_empty(), "no strategies configured");
    ensure!(cfg.batch_size >= 1, "batch_size must be >= 1");
    let mut outcomes = Vec::new();
    let mut events_total = 0;
    for cell in &cfg.cells {
        for strategy in &cfg.strategies {
            let outcome = run_cell(cfg, *cell, *strategy).with_context(|| {
                format!(
                    "cell '{}' strategy '{}' failed — replay with {}=0x{:X}",
                    cell.name(),
                    strategy.as_str(),
                    SEED_ENV,
                    cfg.seed
                )
            })?;
            events_total += outcome.events_total;
            outcomes.push(outcome);
        }
    }
    Ok(MatrixReport { seed: cfg.seed, outcomes, events_total })
}

// ---------------------------------------------------------------- cells

/// A live engine plus the fixed alert threshold for one cell run.
struct Cell {
    _fix: SimArtifacts,
    engine: Engine,
    tau: f64,
    target: f64,
    batch_size: usize,
    batch_no: u64,
    events: u64,
}

impl Cell {
    /// Fresh engine: each managed tenant gets its own scoring rule on
    /// the shared "duo" predictor (a promote rewrites only that
    /// tenant's rule), everything else falls through to "solo".
    fn new(
        cfg: &DriftMatrixConfig,
        strategy: CalibrationStrategy,
        tenants: &[String],
        alert: f64,
        coldstart_min: u64,
    ) -> Result<Cell> {
        let mut rules = String::new();
        for t in tenants {
            rules.push_str(&format!(
                "  - description: \"{t}\"\n    condition:\n      tenants: [\"{t}\"]\n    targetPredictorName: \"duo\"\n"
            ));
        }
        let tenant_list = tenants
            .iter()
            .map(|t| format!("\"{t}\""))
            .collect::<Vec<_>>()
            .join(", ");
        let yaml = format!(
            r#"
routing:
  scoringRules:
{rules}  - description: "catch-all"
    condition: {{}}
    targetPredictorName: "solo"
predictors:
- name: duo
  experts: [s1, s2]
  quantile: custom
- name: solo
  experts: [s3]
  quantile: identity
server:
  workers: 2
  maxBatchEvents: 1024
  lakeMaxRecords: 200000
lifecycle:
  enabled: true
  tenants: [{tenant_list}]
  autoDiscover: false
  sketchK: 4096
  alertRate: {alert}
  delta: 0.1
  minDriftSamples: 512
  minValidationSamples: 512
  validationTolerance: 0.08
  cooldownTicks: 4
  warmFeedCapacity: 512
  calibrationStrategy: {strategy}
  coldstartMinSamples: {coldstart_min}
  coldstartW: 0.02
"#,
            strategy = strategy.as_str(),
        );
        let fix = SimArtifacts::in_temp().context("sim artifacts")?;
        let pool = Arc::new(ModelPool::new(fix.manifest()?));
        let engine = Engine::build(&MuseConfig::from_yaml(&yaml)?, pool).context("engine")?;
        // Alert threshold: the reference's (1 - a) quantile. After a
        // correct fit the final score follows the reference, so the
        // observed alert rate at tau must equal the target rate.
        let reference = match engine.registry.config("duo") {
            Some(pc) => Engine::reference(&pc.reference),
            None => Engine::reference("fraud-default"),
        };
        let grid = reference.quantile_grid(4097);
        let tau = grid[((1.0 - alert) * 4096.0).round() as usize];
        Ok(Cell {
            _fix: fix,
            engine,
            tau,
            target: alert,
            batch_size: cfg.batch_size,
            batch_no: 0,
            events: 0,
        })
    }

    /// Score one batch for `tenant`, folding (score, label) pairs into
    /// `acc`. Every request must come back — a lost response is a cell
    /// failure, not a statistic.
    fn drive(&mut self, tenant: &str, events: &[Event], acc: &mut PhaseAcc) -> Result<()> {
        let reqs: Vec<ScoreRequest> = events
            .iter()
            .enumerate()
            .map(|(i, e)| ScoreRequest {
                intent: Intent {
                    tenant: tenant.to_string(),
                    ..Intent::default()
                },
                entity: format!("dm{}-{}", self.batch_no, i),
                features: e.features.clone(),
            })
            .collect();
        let resps = self.engine.score_batch(&reqs).context("drift-matrix batch")?;
        ensure!(
            resps.len() == reqs.len(),
            "lost appends: {} responses for {} requests",
            resps.len(),
            reqs.len()
        );
        for (r, e) in resps.iter().zip(events.iter()) {
            acc.push(r.score, e.is_fraud);
        }
        self.events += resps.len() as u64;
        self.batch_no += 1;
        Ok(())
    }

    /// One controller tick (mirrored shadow traffic drained first so
    /// validation sees it — the cadence `spawn_controller` provides in
    /// production).
    fn tick(&self) -> Result<()> {
        self.engine.drain_shadows();
        let hub = self
            .engine
            .lifecycle
            .as_ref()
            .ok_or_else(|| anyhow!("lifecycle disabled"))?;
        hub.tick(&self.engine)?;
        Ok(())
    }

    fn pair(&self, tenant: &str) -> Result<PairStatus> {
        self.engine
            .lifecycle
            .as_ref()
            .ok_or_else(|| anyhow!("lifecycle disabled"))?
            .status()
            .into_iter()
            .find(|p| p.tenant == tenant)
            .ok_or_else(|| anyhow!("autopilot is not tracking tenant '{tenant}'"))
    }

    /// Wait (driving `wl` traffic) until the tenant's initial Eq. 5
    /// fit lands.
    fn calibrate(
        &mut self,
        tenants: &mut [(String, Workload)],
        max_batches: usize,
        acc: &mut PhaseAcc,
    ) -> Result<()> {
        for _ in 0..max_batches {
            for (name, wl) in tenants.iter_mut() {
                let evs = gen_batch(wl, self.batch_size);
                let name = name.clone();
                self.drive(&name, &evs, acc)?;
            }
            self.tick()?;
            let mut all_fit = true;
            for (name, _) in tenants.iter() {
                if self.pair(name)?.fits < 1 {
                    all_fit = false;
                }
            }
            if all_fit {
                return Ok(());
            }
        }
        let states: Vec<String> = tenants
            .iter()
            .map(|(n, _)| match self.pair(n) {
                Ok(p) => format!("{n}: {:?} fits={} err={:?}", p.state, p.fits, p.last_error),
                Err(e) => format!("{n}: {e}"),
            })
            .collect();
        bail!("no initial fit within {max_batches} calibration batches: {states:?}")
    }

    /// Fold the pairs' counters into a `CellOutcome`.
    /// `phases` is the `[before, during, after]` metrics triple.
    fn outcome(
        &self,
        cell: DriftCell,
        strategy: CalibrationStrategy,
        tenants: &[String],
        phases: [PhaseMetrics; 3],
        refit_refused: bool,
        note: String,
    ) -> Result<CellOutcome> {
        let [before, during, after] = phases;
        let (mut fits, mut prom, mut vfail, mut dropped) = (0, 0, 0, 0);
        for t in tenants {
            let p = self.pair(t)?;
            fits += p.fits;
            prom += p.promotions;
            vfail += p.validation_failures;
            dropped += p.dropped_samples;
        }
        ensure!(
            dropped == 0,
            "lost feed appends: {dropped} samples dropped (ring undersized for the batch cadence?)"
        );
        Ok(CellOutcome {
            cell: cell.name(),
            strategy: strategy.as_str(),
            target_alert_rate: self.target,
            before,
            during,
            after,
            fits,
            promotions: prom,
            validation_failures: vfail,
            coldstart_fits: self.engine.counters.get("lifecycle_coldstart_fits"),
            drift_skips: self
                .engine
                .counters
                .get("lifecycle_drift_skipped_thin_window"),
            refit_refused,
            dropped_samples: dropped,
            events_total: self.events,
            note,
        })
    }
}

fn gen_batch(wl: &mut Workload, n: usize) -> Vec<Event> {
    (0..n).map(|_| wl.next_event()).collect()
}

/// Steady-state profile for one tenant (1.5% fraud, mostly P0).
fn baseline_profile(name: &str, seed: u64) -> TenantProfile {
    TenantProfile::new(name, seed, 0.3, 0.1)
}

/// The wave shift: attack rate 1.5% -> 25%, pattern flips to P1 —
/// same covariate transform (same seed), a strong directional shift.
fn wave_profile(name: &str, seed: u64) -> TenantProfile {
    TenantProfile::new(name, seed, 0.3, 0.6).with_fraud_rate(0.25)
}

/// The fast-attack stream: `rate` of all events are one exact replay
/// of a single fraud template (identical features => identical raw
/// score), the rest is the stable benign baseline.
struct AttackStream {
    base: Workload,
    template: Event,
    rate: f64,
    rng: Rng,
}

impl AttackStream {
    fn new(name: &str, seed: u64, rate: f64) -> AttackStream {
        // Deterministic template: first fraud event of a pure-fraud,
        // pure-P1 stream.
        let mut tpl = Workload::new(
            TenantProfile::new(name, seed, 0.3, 1.0).with_fraud_rate(1.0),
            seed ^ 0xA77A,
        );
        let template = loop {
            let e = tpl.next_event();
            if e.is_fraud {
                break e;
            }
        };
        AttackStream {
            base: Workload::new(baseline_profile(name, seed), seed ^ 0x5707),
            template,
            rate,
            rng: Rng::new(seed ^ 0xFA57),
        }
    }

    fn batch(&mut self, n: usize) -> Vec<Event> {
        (0..n)
            .map(|_| {
                if self.rng.bernoulli(self.rate) {
                    self.template.clone()
                } else {
                    self.base.next_event()
                }
            })
            .collect()
    }
}

fn run_cell(
    cfg: &DriftMatrixConfig,
    cell: DriftCell,
    strategy: CalibrationStrategy,
) -> Result<CellOutcome> {
    match cell {
        DriftCell::CoordinatedWave => run_wave(cfg, strategy),
        DriftCell::FastAttack => run_fast_attack(cfg, strategy),
        DriftCell::OnboardingStorm => run_onboarding(cfg, strategy),
        DriftCell::LabelDelay => run_label_delay(cfg, strategy),
        DriftCell::ClassImbalance => run_imbalance(cfg, strategy),
    }
}

fn run_wave(cfg: &DriftMatrixConfig, strategy: CalibrationStrategy) -> Result<CellOutcome> {
    let names = vec!["wave0".to_string(), "wave1".to_string()];
    let mut cell = Cell::new(cfg, strategy, &names, 0.1, 0)?;
    let seed = cfg.seed;
    let mut tenants: Vec<(String, Workload)> = names
        .iter()
        .enumerate()
        .map(|(i, n)| {
            let p = baseline_profile(n, seed.wrapping_add(i as u64 * 101));
            (n.clone(), Workload::new(p, seed ^ (i as u64 + 1)))
        })
        .collect();

    let mut scratch = PhaseAcc::default();
    cell.calibrate(&mut tenants, cfg.calibration_batches, &mut scratch)?;

    let mut acc = PhaseAcc::default();
    for _ in 0..cfg.measure_batches {
        for (name, wl) in tenants.iter_mut() {
            let evs = gen_batch(wl, cell.batch_size);
            let name = name.clone();
            cell.drive(&name, &evs, &mut acc)?;
        }
        cell.tick()?;
    }
    let before = acc.metrics(cell.tau);

    // The coordinated wave: both tenants shift in the same batch.
    let mut storm: Vec<(String, Workload)> = names
        .iter()
        .enumerate()
        .map(|(i, n)| {
            let p = wave_profile(n, seed.wrapping_add(i as u64 * 101));
            (n.clone(), Workload::new(p, seed ^ 0x5707 ^ (i as u64 + 1)))
        })
        .collect();
    let mut during_acc = PhaseAcc::default();
    let mut refused = false;
    for _ in 0..cfg.storm_batches {
        for (name, wl) in storm.iter_mut() {
            let evs = gen_batch(wl, cell.batch_size);
            let name = name.clone();
            cell.drive(&name, &evs, &mut during_acc)?;
        }
        cell.tick()?;
        let mut all_promoted = true;
        for n in &names {
            let p = cell.pair(n)?;
            refused |= refused_refit(&p);
            if p.promotions == 0 {
                all_promoted = false;
            }
        }
        if all_promoted {
            break;
        }
    }
    let during = during_acc.metrics(cell.tau);
    cell.tick()?; // finalize Promoted -> Observing

    let mut after_acc = PhaseAcc::default();
    for _ in 0..cfg.measure_batches {
        for (name, wl) in storm.iter_mut() {
            let evs = gen_batch(wl, cell.batch_size);
            let name = name.clone();
            cell.drive(&name, &evs, &mut after_acc)?;
        }
        cell.tick()?;
    }
    let after = after_acc.metrics(cell.tau);

    let promoted = names
        .iter()
        .filter(|n| cell.pair(n).map(|p| p.promotions >= 1).unwrap_or(false))
        .count();
    let note = format!("{promoted} of {} tenants promoted", names.len());
    let outcome = cell.outcome(
        DriftCell::CoordinatedWave,
        strategy,
        &names,
        [before, during, after],
        refused,
        note,
    )?;
    // The paper's own strategy must ride the wave out fully; the
    // full-range column is characterization (its fixed low w cannot
    // represent a 25% attack mode, so validation may refuse it —
    // that slower chase is the 2607.05481 trade-off, reported, not
    // asserted).
    if strategy == CalibrationStrategy::QuantileMap {
        ensure!(
            promoted == names.len(),
            "coordinated wave: only {promoted} of {} tenants promoted",
            names.len()
        );
        ensure!(
            outcome.rel_err(&outcome.after) <= 0.25,
            "post-wave alert rate off target: {outcome:?}"
        );
    } else {
        ensure!(outcome.fits >= 4, "full-range never refit: {outcome:?}");
        ensure!(!outcome.refit_refused, "full-range hit the degeneracy gate: {outcome:?}");
    }
    cell.engine.drain_shadows();
    Ok(outcome)
}

fn refused_refit(p: &PairStatus) -> bool {
    p.last_error
        .as_deref()
        .is_some_and(|e| e.contains("degenerate quantile grid"))
}

fn run_fast_attack(cfg: &DriftMatrixConfig, strategy: CalibrationStrategy) -> Result<CellOutcome> {
    let names = vec!["acme".to_string()];
    let mut cell = Cell::new(cfg, strategy, &names, 0.1, 0)?;
    let mut tenants = vec![(
        "acme".to_string(),
        Workload::new(baseline_profile("acme", cfg.seed), cfg.seed),
    )];
    let mut scratch = PhaseAcc::default();
    cell.calibrate(&mut tenants, cfg.calibration_batches, &mut scratch)?;

    let mut acc = PhaseAcc::default();
    for _ in 0..cfg.measure_batches {
        let evs = gen_batch(&mut tenants[0].1, cell.batch_size);
        cell.drive("acme", &evs, &mut acc)?;
        cell.tick()?;
    }
    let before = acc.metrics(cell.tau);

    // The attack: 60% exact-replay template, benign unchanged. Fixed
    // batch count — both strategies see the identical stream, and the
    // interesting outcome is *which* seam each one fails or survives
    // at, not how fast it promotes.
    let mut attack = AttackStream::new("acme", cfg.seed, 0.6);
    let mut during_acc = PhaseAcc::default();
    let mut refused = false;
    for _ in 0..cfg.attack_batches {
        let evs = attack.batch(cell.batch_size);
        cell.drive("acme", &evs, &mut during_acc)?;
        cell.tick()?;
        refused |= refused_refit(&cell.pair("acme")?);
    }
    let during = during_acc.metrics(cell.tau);
    let after = during; // the attack never ends inside this cell

    let p = cell.pair("acme")?;
    let note = format!(
        "exact-tie attack; state {:?}, last_error {}",
        p.state,
        p.last_error.as_deref().unwrap_or("none")
    );
    let outcome = cell.outcome(
        DriftCell::FastAttack,
        strategy,
        &names,
        [before, during, after],
        refused,
        note,
    )?;
    match strategy {
        CalibrationStrategy::QuantileMap => {
            // The headline split: the empirical refit MUST be refused
            // on the degenerate-grid gate (pre-PR it silently fitted a
            // mostly-degenerate T^Q), so no refit lands.
            ensure!(
                outcome.refit_refused,
                "quantile-map refit was not refused under an exact-tie attack: {outcome:?}"
            );
            ensure!(outcome.fits == 1, "a degenerate refit landed: {outcome:?}");
            ensure!(outcome.promotions == 0, "{outcome:?}");
        }
        CalibrationStrategy::FullRange => {
            // The smooth fit survives the ties and produces a candidate
            // (whether the point mass can *validate* against the
            // reference is reported, not asserted — no distribution
            // with a 60% atom matches a continuous reference).
            ensure!(
                !outcome.refit_refused,
                "full-range must not hit the tie gate: {outcome:?}"
            );
            ensure!(outcome.fits >= 2, "full-range never refit: {outcome:?}");
        }
    }
    cell.engine.drain_shadows();
    Ok(outcome)
}

fn run_onboarding(cfg: &DriftMatrixConfig, strategy: CalibrationStrategy) -> Result<CellOutcome> {
    let names: Vec<String> = (0..cfg.onboarding_tenants)
        .map(|i| format!("fresh{i}"))
        .collect();
    // a = 1%: Eq. 5 needs ~9.5k samples/tenant — far beyond this cell,
    // which is the point: the cold-start mixture must carry serving
    // until then. coldstartMinSamples = one batch.
    let mut cell = Cell::new(cfg, strategy, &names, 0.01, cfg.batch_size.max(129) as u64)?;
    let mut tenants: Vec<(String, Workload)> = names
        .iter()
        .enumerate()
        .map(|(i, n)| {
            let p = baseline_profile(n, cfg.seed.wrapping_add(i as u64 * 997));
            (n.clone(), Workload::new(p, cfg.seed ^ (i as u64 + 11)))
        })
        .collect();

    cell.tick()?; // discover pairs, wire rings

    // Round 1 scores through the identity default (the pre-PR
    // permanent state); its tick then fits every tenant's mixture.
    let mut before_acc = PhaseAcc::default();
    for (name, wl) in tenants.iter_mut() {
        let evs = gen_batch(wl, cell.batch_size);
        let name = name.clone();
        cell.drive(&name, &evs, &mut before_acc)?;
    }
    cell.tick()?;
    let before = before_acc.metrics(cell.tau);

    let fitted = cell.engine.counters.get("lifecycle_coldstart_fits");
    ensure!(
        fitted == names.len() as u64,
        "cold-start fits for {fitted} of {} fresh tenants",
        names.len()
    );
    for n in &names {
        let p = cell.pair(n)?;
        ensure!(p.coldstart, "pair '{n}' not flagged coldstart: {p:?}");
        ensure!(!p.baseline_frozen, "cold-start froze a baseline for '{n}': {p:?}");
        ensure!(p.fits == 0, "cold-start counted as an Eq. 5 fit for '{n}': {p:?}");
        ensure!(
            cell.engine.predictor("duo")?.has_tenant_quantile(n),
            "no tenant T^Q installed for '{n}'"
        );
    }

    // Post-cold-start rounds: every event now maps through the fitted
    // Beta-mixture T^Q, still well before the Eq. 5 gate.
    let mut after_acc = PhaseAcc::default();
    for _ in 0..cfg.onboarding_rounds {
        for (name, wl) in tenants.iter_mut() {
            let evs = gen_batch(wl, cell.batch_size);
            let name = name.clone();
            cell.drive(&name, &evs, &mut after_acc)?;
        }
        cell.tick()?;
    }
    let after = after_acc.metrics(cell.tau);
    for n in &names {
        ensure!(cell.pair(n)?.fits == 0, "Eq. 5 gate passed prematurely for '{n}'");
    }

    let note = format!(
        "{} fresh tenants; identity -> mixture T^Q before Eq. 5",
        names.len()
    );
    // "during" = the cold-start-served window.
    let outcome = cell.outcome(
        DriftCell::OnboardingStorm,
        strategy,
        &names,
        [before, after, after],
        false,
        note,
    )?;
    ensure!(outcome.coldstart_fits == names.len() as u64, "{outcome:?}");
    cell.engine.drain_shadows();
    Ok(outcome)
}

fn run_label_delay(cfg: &DriftMatrixConfig, strategy: CalibrationStrategy) -> Result<CellOutcome> {
    let names = vec!["acme".to_string()];
    let mut cell = Cell::new(cfg, strategy, &names, 0.1, 0)?;
    let mut tenants = vec![(
        "acme".to_string(),
        Workload::new(baseline_profile("acme", cfg.seed ^ 0x1ABE1), cfg.seed),
    )];
    let mut scratch = PhaseAcc::default();
    cell.calibrate(&mut tenants, cfg.calibration_batches, &mut scratch)?;

    let mut acc = PhaseAcc::default();
    for _ in 0..cfg.measure_batches {
        let evs = gen_batch(&mut tenants[0].1, cell.batch_size);
        cell.drive("acme", &evs, &mut acc)?;
        cell.tick()?;
    }
    let before = acc.metrics(cell.tau);

    // Fraud wave with lagged labels: alert rates are computed over the
    // full storm window, recall only over batches whose labels have
    // "arrived" (all but the trailing `label_delay_batches`).
    let mut storm = Workload::new(
        wave_profile("acme", cfg.seed ^ 0x1ABE1),
        cfg.seed ^ 0x5707,
    );
    let mut batches: Vec<PhaseAcc> = Vec::new();
    let mut refused = false;
    for _ in 0..cfg.storm_batches {
        let evs = gen_batch(&mut storm, cell.batch_size);
        let mut b = PhaseAcc::default();
        cell.drive("acme", &evs, &mut b)?;
        batches.push(b);
        cell.tick()?;
        let p = cell.pair("acme")?;
        refused |= refused_refit(&p);
        if p.promotions > 0 {
            break;
        }
    }
    let mut during_acc = PhaseAcc::default();
    let labeled_upto = batches.len().saturating_sub(cfg.label_delay_batches);
    let mut labeled_acc = PhaseAcc::default();
    for (i, b) in batches.iter().enumerate() {
        for (s, y) in b.scores.iter().zip(&b.labels) {
            during_acc.push(*s, *y > 0.5);
            if i < labeled_upto {
                labeled_acc.push(*s, *y > 0.5);
            }
        }
    }
    let during = during_acc.metrics(cell.tau);
    let labeled = labeled_acc.metrics(cell.tau);
    cell.tick()?;

    let mut after_acc = PhaseAcc::default();
    for _ in 0..cfg.measure_batches {
        let evs = gen_batch(&mut storm, cell.batch_size);
        cell.drive("acme", &evs, &mut after_acc)?;
        cell.tick()?;
    }
    let after = after_acc.metrics(cell.tau);

    let note = format!(
        "labels lag {} batches: labeled-window recall {:.2} vs full {:.2}",
        cfg.label_delay_batches, labeled.fraud_recall, during.fraud_recall
    );
    let outcome = cell.outcome(
        DriftCell::LabelDelay,
        strategy,
        &names,
        [before, during, after],
        refused,
        note,
    )?;
    if strategy == CalibrationStrategy::QuantileMap {
        ensure!(outcome.promotions >= 1, "wave never promoted: {outcome:?}");
        ensure!(
            outcome.rel_err(&outcome.after) <= 0.25,
            "post-recovery alert rate off target: {outcome:?}"
        );
    } else {
        ensure!(outcome.fits >= 2, "full-range never refit: {outcome:?}");
        ensure!(!outcome.refit_refused, "{outcome:?}");
    }
    cell.engine.drain_shadows();
    Ok(outcome)
}

fn run_imbalance(cfg: &DriftMatrixConfig, strategy: CalibrationStrategy) -> Result<CellOutcome> {
    let names = vec!["acme".to_string()];
    let mut cell = Cell::new(cfg, strategy, &names, 0.1, 0)?;
    let profile_seed = cfg.seed ^ 0x1B1A;
    let mut tenants = vec![(
        "acme".to_string(),
        Workload::new(baseline_profile("acme", profile_seed), cfg.seed),
    )];
    let mut scratch = PhaseAcc::default();
    cell.calibrate(&mut tenants, cfg.calibration_batches, &mut scratch)?;

    let mut acc = PhaseAcc::default();
    for _ in 0..cfg.measure_batches {
        let evs = gen_batch(&mut tenants[0].1, cell.batch_size);
        cell.drive("acme", &evs, &mut acc)?;
        cell.tick()?;
    }
    let before = acc.metrics(cell.tau);

    // Collapse the class prior only: same covariate transform (same
    // profile seed), fraud 1.5% -> 0.2%. A rank-based T^Q should see
    // almost no distribution shift — no refit, no promotion, and the
    // alert-rate anchor holds.
    let sparse = baseline_profile("acme", profile_seed)
        .with_fraud_rate(cfg.imbalance_fraud_rate);
    let mut wl = Workload::new(sparse, cfg.seed ^ 0x2B2B);
    let mut during_acc = PhaseAcc::default();
    for _ in 0..cfg.imbalance_batches {
        let evs = gen_batch(&mut wl, cell.batch_size);
        cell.drive("acme", &evs, &mut during_acc)?;
        cell.tick()?;
    }
    let during = during_acc.metrics(cell.tau);

    let note = format!(
        "prior 1.5% -> {:.1}%: no refit expected",
        100.0 * cfg.imbalance_fraud_rate
    );
    let outcome = cell.outcome(
        DriftCell::ClassImbalance,
        strategy,
        &names,
        [before, during, during],
        false,
        note,
    )?;
    ensure!(
        outcome.promotions == 0 && outcome.fits == 1,
        "class-prior shift alone must not trigger recalibration: {outcome:?}"
    );
    if strategy == CalibrationStrategy::QuantileMap {
        ensure!(
            outcome.rel_err(&outcome.during) <= 0.3,
            "alert-rate anchor lost under class imbalance: {outcome:?}"
        );
    }
    cell.engine.drain_shadows();
    Ok(outcome)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reduced(seed: u64, cells: Vec<DriftCell>) -> DriftMatrixConfig {
        DriftMatrixConfig {
            seed,
            cells,
            ..DriftMatrixConfig::default()
        }
    }

    #[test]
    fn seed_parsing_accepts_decimal_and_hex() {
        assert_eq!(parse_seed("42"), Some(42));
        assert_eq!(parse_seed("0x2A"), Some(42));
        assert_eq!(parse_seed(" 0X2a "), Some(42));
        assert_eq!(parse_seed("nope"), None);
        // Unset env falls through to the default (skip the check if a
        // developer exported an override — that's the documented
        // replay behavior, not a bug).
        if std::env::var(SEED_ENV).is_err() {
            assert_eq!(matrix_seed(7), 7);
        }
    }

    #[test]
    fn reduced_matrix_is_deterministic_and_replayable() {
        // Satellite 4: the exact seed reproduces the exact numbers —
        // the replay recipe printed on failure is sufficient. Two
        // cheap cells x both strategies, run twice.
        let cells = vec![DriftCell::OnboardingStorm, DriftCell::ClassImbalance];
        let a = run_drift_matrix(&reduced(0xC0FFEE, cells.clone())).unwrap();
        let b = run_drift_matrix(&reduced(0xC0FFEE, cells)).unwrap();
        println!("{}", a.render());
        assert_eq!(a.fingerprint(), b.fingerprint(), "matrix is not replayable");
        assert_eq!(a.outcomes.len(), 4, "2 cells x 2 strategies");
        // Every cell emitted both strategies' metrics.
        for o in &a.outcomes {
            assert!(o.events_total > 0);
            assert_eq!(o.dropped_samples, 0, "lost appends: {o:?}");
        }
        // The onboarding cells proved the cold-start path.
        for o in a.outcomes.iter().filter(|o| o.cell == "onboarding-storm") {
            assert_eq!(o.coldstart_fits, 6, "{o:?}");
            assert_eq!(o.fits, 0, "{o:?}");
        }
    }

    #[test]
    fn fast_attack_cell_splits_the_strategies() {
        // The matrix's headline A/B: the exact-tie attack forces the
        // empirical quantile refit onto the satellite-2 degeneracy
        // gate, while the full-range mixture keeps fitting.
        let cfg = reduced(0xA17AC4, vec![DriftCell::FastAttack]);
        let report = run_drift_matrix(&cfg).unwrap();
        println!("{}", report.render());
        let qm = report
            .outcomes
            .iter()
            .find(|o| o.strategy == "quantileMap")
            .unwrap();
        let fr = report
            .outcomes
            .iter()
            .find(|o| o.strategy == "fullRange")
            .unwrap();
        assert!(qm.refit_refused && qm.fits == 1 && qm.promotions == 0, "{qm:?}");
        assert!(!fr.refit_refused && fr.fits >= 2, "{fr:?}");
    }
}
