//! Connection-storm scenario: the ingress plane's scaling proof.
//!
//! Where `saturation` ramps threads against `Engine::score` directly,
//! this scenario attacks the whole serving stack **over real
//! sockets**: it opens thousands of concurrent keep-alive HTTP
//! connections against a live [`spawn_server`] instance and drives
//! every one of them from a *single* client thread multiplexed by the
//! same [`Poller`] the server's reactor uses. The seed's
//! thread-per-connection server kept all of `maxConnections` worker
//! threads parked on blocking reads under this load; the event-driven
//! ingress plane holds every connection on one reactor thread and
//! keeps the worker pool free for scoring.
//!
//! The scenario is also an end-to-end conservation check, in the
//! `saturation` tradition: the client drivers tally every response
//! per (tenant, predictor) and, after the storm, those tallies must
//! agree **exactly** with the engine's observation plane — the
//! sharded `DataLake` per-pair counts, the wait-free
//! `hot.requests_live` gauge, and the `ingress_*` counters that the
//! reactor publishes into `GET /metrics`. No request lost, none
//! double-counted, across connect/accept, event-loop dispatch, worker
//! hand-off and keep-alive reuse.
//!
//! `examples/connection_storm.rs` is the CI smoke wrapper (>= 5k
//! connections; `MUSE_STORM_CONNS` overrides).
//!
//! [`spawn_server`]: crate::server::spawn_server
//! [`Poller`]: crate::server::reactor::Poller

use crate::coordinator::Engine;
use crate::server::reactor::{PollEvent, Poller, EV_READ, EV_WRITE};
use crate::simulator::workload::{TenantProfile, Workload};
use anyhow::{bail, ensure, Context, Result};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::os::fd::AsRawFd;
use std::sync::Arc;
use std::time::Instant;

/// Scenario parameters (defaults match the unit test; the CI example
/// scales `connections` to >= 5000).
#[derive(Debug, Clone)]
pub struct ConnectionStormConfig {
    /// Concurrent keep-alive connections to hold open.
    pub connections: usize,
    /// Requests each connection sends (> 1 exercises keep-alive).
    pub requests_per_connection: usize,
    /// Tenant mix; connections round-robin over it.
    pub tenants: Vec<TenantProfile>,
    /// Server worker threads.
    pub server_workers: usize,
    pub seed: u64,
}

impl Default for ConnectionStormConfig {
    fn default() -> Self {
        ConnectionStormConfig {
            connections: 256,
            requests_per_connection: 3,
            tenants: vec![
                TenantProfile::new("bank1", 7, 0.3, 0.1),
                TenantProfile::new("bank2", 11, 0.3, 0.1),
            ],
            server_workers: 4,
            seed: 29,
        }
    }
}

/// Scenario outcome. The conservation checks have already passed by
/// the time a report is returned; the numbers are for the ledger.
#[derive(Debug, Clone)]
pub struct ConnectionStormReport {
    pub connections: usize,
    /// Connections simultaneously open at the peak (all of them: the
    /// storm connects everyone before the first request is sent).
    pub peak_open: usize,
    pub requests_total: u64,
    pub wall_secs: f64,
    pub requests_per_sec: f64,
    /// Client-observed request latency (write start -> body end).
    pub p50_ms: f64,
    pub p99_ms: f64,
}

impl ConnectionStormReport {
    pub fn render(&self) -> String {
        format!(
            "connection storm ({} keep-alive conns, one client thread):\n  \
             {:>8.0} req/s  p50 {:>7.3} ms  p99 {:>7.3} ms  \
             ({} requests in {:.2}s, peak {} open)",
            self.connections,
            self.requests_per_sec,
            self.p50_ms,
            self.p99_ms,
            self.requests_total,
            self.wall_secs,
            self.peak_open
        )
    }
}

/// One multiplexed client connection's state machine.
struct ClientConn {
    stream: TcpStream,
    tenant: String,
    workload: Workload,
    /// Requests still to send (including any in flight).
    remaining: usize,
    out: Vec<u8>,
    out_pos: usize,
    inbuf: Vec<u8>,
    sent_at: Instant,
    /// Current registered interest (avoid redundant epoll_ctl).
    interest: u32,
    done: bool,
}

impl ClientConn {
    fn next_request(&mut self) -> Vec<u8> {
        let e = self.workload.next_event();
        let feats: Vec<String> = e.features.iter().map(|f| format!("{f:.6}")).collect();
        let body = format!(
            r#"{{"tenant": "{}", "features": [{}]}}"#,
            self.tenant,
            feats.join(",")
        );
        format!(
            "POST /score HTTP/1.1\r\nHost: storm\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        )
        .into_bytes()
    }

    /// A complete response sitting at the front of `inbuf`? Returns
    /// (status, body length consumed) without copying.
    fn complete_response(&self) -> Option<(u16, usize, usize)> {
        let head_end = self
            .inbuf
            .windows(4)
            .position(|w| w == b"\r\n\r\n")
            .map(|p| p + 4)?;
        let head = std::str::from_utf8(&self.inbuf[..head_end]).ok()?;
        let status: u16 = head.split_whitespace().nth(1)?.parse().ok()?;
        let mut content_length = 0usize;
        for line in head.lines() {
            if let Some((k, v)) = line.split_once(':') {
                if k.trim().eq_ignore_ascii_case("content-length") {
                    content_length = v.trim().parse().ok()?;
                }
            }
        }
        if self.inbuf.len() < head_end + content_length {
            return None;
        }
        Some((status, head_end, content_length))
    }
}

/// Run the storm against a live engine's HTTP front end. Returns the
/// report only if every conservation check passed (see module docs).
pub fn run_connection_storm(
    engine: Arc<Engine>,
    cfg: &ConnectionStormConfig,
) -> Result<ConnectionStormReport> {
    ensure!(cfg.connections >= 1, "need >= 1 connection");
    ensure!(cfg.requests_per_connection >= 1, "need >= 1 request per connection");
    ensure!(!cfg.tenants.is_empty(), "need >= 1 tenant");

    let base_requests = engine.counters.get("ingress_requests");
    let base_accepted = engine.counters.get("ingress_accepted");
    let base_live = engine.hot.requests_live.get();

    // Warm-up 0: every scored event must come from this storm so the
    // conservation checks can demand exact equality.
    let (addr, _ready, _server) =
        crate::server::spawn_server(Arc::clone(&engine), "127.0.0.1:0", cfg.server_workers, 0)?;

    // Phase 1: open every connection before sending anything — the
    // storm's whole point is holding them open *simultaneously*.
    let mut poller = Poller::new().context("client poller")?;
    let mut conns: Vec<ClientConn> = Vec::with_capacity(cfg.connections);
    for i in 0..cfg.connections {
        let stream = TcpStream::connect(&addr).with_context(|| format!("connect #{i}"))?;
        stream.set_nodelay(true).ok();
        stream.set_nonblocking(true).context("client nonblocking")?;
        let tenant = cfg.tenants[i % cfg.tenants.len()].clone();
        let workload = Workload::new(tenant.clone(), cfg.seed ^ ((i as u64) << 16));
        conns.push(ClientConn {
            stream,
            tenant: tenant.name.clone(),
            workload,
            remaining: cfg.requests_per_connection,
            out: Vec::new(),
            out_pos: 0,
            inbuf: Vec::new(),
            sent_at: Instant::now(),
            interest: EV_READ,
            done: false,
        });
    }
    let peak_open = conns.len();

    // Phase 2: drive them all from this one thread.
    let mut latencies_ns: Vec<u64> = Vec::with_capacity(cfg.connections * cfg.requests_per_connection);
    let mut tallies: Vec<((String, String), u64)> = Vec::new();
    let t0 = Instant::now();
    for (i, c) in conns.iter_mut().enumerate() {
        c.sent_at = Instant::now();
        c.out = c.next_request();
        c.out_pos = 0;
        // Optimistic write; leftover waits for EV_WRITE.
        pump_write(c)?;
        let interest = if c.out_pos < c.out.len() {
            EV_READ | EV_WRITE
        } else {
            EV_READ
        };
        c.interest = interest;
        poller
            .register(c.stream.as_raw_fd(), i, interest)
            .context("register client conn")?;
    }

    let mut open = conns.len();
    let mut events: Vec<PollEvent> = Vec::new();
    let deadline = Instant::now() + std::time::Duration::from_secs(120);
    while open > 0 {
        ensure!(Instant::now() < deadline, "storm stalled: {open} connections unfinished");
        poller.wait(&mut events, 100).context("client wait")?;
        for &ev in &events {
            let c = match conns.get_mut(ev.token) {
                Some(c) if !c.done => c,
                _ => continue,
            };
            if ev.events & EV_WRITE != 0 {
                pump_write(c)?;
            }
            // Read whatever's there (level-triggered: loop to WouldBlock).
            let mut scratch = [0u8; 16 * 1024];
            loop {
                match c.stream.read(&mut scratch) {
                    Ok(0) => {
                        bail!(
                            "server closed connection {} early ({} requests left)",
                            ev.token,
                            c.remaining
                        );
                    }
                    Ok(n) => c.inbuf.extend_from_slice(&scratch[..n]),
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(e) => return Err(e).context("client read"),
                }
            }
            // Process every complete response in the buffer.
            while let Some((status, head_end, body_len)) = c.complete_response() {
                let body = String::from_utf8_lossy(&c.inbuf[head_end..head_end + body_len])
                    .into_owned();
                c.inbuf.drain(..head_end + body_len);
                ensure!(status == 200, "request failed with {status}: {body}");
                let v = crate::util::json::parse(&body)
                    .map_err(|e| anyhow::anyhow!("bad response body: {e}: {body}"))?;
                let predictor = v.req_str("predictor").map_err(|e| anyhow::anyhow!("{e}"))?;
                latencies_ns.push(c.sent_at.elapsed().as_nanos() as u64);
                let key = (c.tenant.clone(), predictor.to_string());
                match tallies.iter_mut().find(|(k, _)| *k == key) {
                    Some((_, n)) => *n += 1,
                    None => tallies.push((key, 1)),
                }
                c.remaining -= 1;
                if c.remaining == 0 {
                    c.done = true;
                    poller.deregister(c.stream.as_raw_fd()).ok();
                    open -= 1;
                    break;
                }
                // Next request on the same (kept-alive) connection.
                c.sent_at = Instant::now();
                c.out = c.next_request();
                c.out_pos = 0;
                pump_write(c)?;
            }
            if !c.done {
                let want = if c.out_pos < c.out.len() {
                    EV_READ | EV_WRITE
                } else {
                    EV_READ
                };
                if want != c.interest {
                    c.interest = want;
                    poller
                        .modify(c.stream.as_raw_fd(), ev.token, want)
                        .context("modify client conn")?;
                }
            }
        }
    }
    let wall_secs = t0.elapsed().as_secs_f64();
    let requests_total = latencies_ns.len() as u64;
    ensure!(
        requests_total == (cfg.connections * cfg.requests_per_connection) as u64,
        "driver tally lost requests"
    );

    // Conservation: driver tallies vs the engine's observation plane.
    engine.drain_shadows();
    let mut oracle_total = 0u64;
    for ((tenant, predictor), expect) in &tallies {
        let got = engine.lake.count_for(tenant, predictor) as u64;
        ensure!(
            got == *expect,
            "lake count_for({tenant},{predictor}) = {got}, driver says {expect}"
        );
        oracle_total += expect;
    }
    ensure!(oracle_total == requests_total, "per-pair tallies don't sum to the total");
    ensure!(
        engine.hot.requests_live.get() - base_live == requests_total,
        "hot.requests_live {} != driven {requests_total}",
        engine.hot.requests_live.get() - base_live
    );
    // Ingress accounting: every connection accepted once, every
    // request dispatched once (keep-alive reuse, no double counts).
    let accepted = engine.counters.get("ingress_accepted") - base_accepted;
    let dispatched = engine.counters.get("ingress_requests") - base_requests;
    ensure!(
        accepted == cfg.connections as u64,
        "ingress_accepted {accepted} != {} connections",
        cfg.connections
    );
    ensure!(
        dispatched == requests_total,
        "ingress_requests {dispatched} != driven {requests_total}"
    );
    // ...and the same numbers are what /metrics publishes.
    let (status, metrics) =
        crate::server::http::http_request(&addr, "GET", "/metrics", "").context("GET /metrics")?;
    ensure!(status == 200, "/metrics returned {status}");
    let m = crate::util::json::parse(&metrics).map_err(|e| anyhow::anyhow!("{e}"))?;
    let published = m
        .req("counters")
        .and_then(|c| c.req("ingress_requests"))
        .ok()
        .and_then(crate::util::json::Json::as_f64)
        .unwrap_or(-1.0);
    ensure!(
        published >= dispatched as f64,
        "/metrics ingress_requests {published} below driver count {dispatched}"
    );

    latencies_ns.sort_unstable();
    let pct = |p: f64| -> f64 {
        if latencies_ns.is_empty() {
            return 0.0;
        }
        let idx = ((p / 100.0) * (latencies_ns.len() - 1) as f64).round() as usize;
        latencies_ns[idx.min(latencies_ns.len() - 1)] as f64 / 1e6
    };
    let report = ConnectionStormReport {
        connections: cfg.connections,
        peak_open,
        requests_total,
        wall_secs,
        requests_per_sec: requests_total as f64 / wall_secs.max(1e-9),
        p50_ms: pct(50.0),
        p99_ms: pct(99.0),
    };
    ensure!(report.p99_ms >= report.p50_ms, "percentiles out of order");
    ensure!(report.p99_ms > 0.0, "p99 must be measurable");
    Ok(report)
}

/// Write as much pending output as the socket accepts.
fn pump_write(c: &mut ClientConn) -> Result<()> {
    while c.out_pos < c.out.len() {
        match c.stream.write(&c.out[c.out_pos..]) {
            Ok(0) => bail!("client write returned 0"),
            Ok(n) => c.out_pos += n,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e).context("client write"),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MuseConfig;
    use crate::runtime::{ModelPool, SimArtifacts};

    const CONFIG: &str = r#"
routing:
  scoringRules:
  - description: "bank1 dedicated"
    condition:
      tenants: ["bank1"]
    targetPredictorName: "duo"
  - description: "catch-all"
    condition: {}
    targetPredictorName: "solo"
predictors:
- name: duo
  experts: [s1, s2]
  quantile: identity
- name: solo
  experts: [s3]
  quantile: identity
server:
  workers: 2
  maxBatchDelayUs: 50
"#;

    #[test]
    fn storm_holds_concurrent_connections_and_conserves_every_event() {
        // Sim-dialect artifacts: runs without `make artifacts`. Small
        // enough for default fd limits; the CI example runs >= 5k.
        let fix = SimArtifacts::in_temp().unwrap();
        let pool = Arc::new(ModelPool::new(fix.manifest().unwrap()));
        let engine =
            Arc::new(Engine::build(&MuseConfig::from_yaml(CONFIG).unwrap(), pool).unwrap());
        let cfg = ConnectionStormConfig {
            connections: 256,
            requests_per_connection: 2,
            ..ConnectionStormConfig::default()
        };
        let report = run_connection_storm(Arc::clone(&engine), &cfg).unwrap();
        assert_eq!(report.peak_open, 256);
        assert_eq!(report.requests_total, 512);
        assert!(report.requests_per_sec > 0.0);
        let rendered = report.render();
        assert!(rendered.contains("256 keep-alive conns"), "{rendered}");
        // Conservation is enforced inside the run; spot-check the
        // engine side once more from the outside.
        assert_eq!(engine.hot.requests_live.get(), 512);
        assert_eq!(engine.lake.lost_appends(), 0);
    }
}
