//! Tenant-tsunami scenario: the 100k-tenant scale-out proof.
//!
//! An onboarding storm interns tens of thousands of never-seen
//! tenants (one scored event each, control-plane ticks running
//! concurrently), then Zipf-distributed steady-state traffic drives a
//! small head of hot tenants — including one dedicated drifting head
//! tenant the lifecycle autopilot calibrates mid-storm — over a long
//! tail of mostly-idle ones. The scenario proves the tenant state
//! plane's three scale claims end to end:
//!
//! 1. **Bounded registry RSS.** Interner reverse map, per-tenant
//!    event counters and lake pair registry all grow in constant-size
//!    slab segments: segments × `SEG_SIZE` stays within one
//!    shard-rounding of the tenant/pair count, no matter the
//!    onboarding order.
//! 2. **Lifecycle feed memory budget.** After the storm, feed rings
//!    follow activity tiers: the Zipf head is Hot, recently-active
//!    tenants Warm, and the idle tail evicted Cold — total ring bytes
//!    collapse far below the all-warm transient (and to exactly zero
//!    once traffic quiesces), instead of 100k × full-ring.
//! 3. **Zero lost appends, exact accounting.** The lock-free lake
//!    drops nothing (`lost_appends == forced_overwrites == 0`) and
//!    the per-tenant `scored_events` counters — streamed shard by
//!    shard, never cloned — reconcile bitwise with the scenario's own
//!    per-tenant ledger.
//!
//! The artifact-free test below runs the recipe at a reduced tenant
//! count; `MUSE_TSUNAMI_TENANTS` scales it up (CI smoke: 5000; the
//! EXPERIMENTS.md ledger entry: 100000).

use crate::config::Intent;
use crate::coordinator::{Engine, ScoreRequest};
use crate::simulator::workload::{TenantProfile, Workload};
use crate::util::rng::Rng;
use crate::util::slab::SEG_SIZE;
use anyhow::{anyhow, ensure, Result};
use std::collections::BTreeMap;
use std::time::Instant;

/// Scenario parameters.
#[derive(Debug, Clone)]
pub struct TsunamiConfig {
    /// Tenants to onboard (the experiment ledger runs 100_000).
    pub tenants: usize,
    /// Events per `score_batch` call.
    pub batch_size: usize,
    /// Zipf steady-state batches after the onboarding storm.
    pub steady_batches: usize,
    /// Dedicated drifted events per steady batch for the head tenant
    /// (sized to exceed `lifecycle.hotFeedSamples` so the head
    /// provably reaches the Hot tier).
    pub head_events_per_batch: usize,
    /// Zipf exponent for the steady-state tenant pick.
    pub zipf_s: f64,
    pub seed: u64,
}

impl Default for TsunamiConfig {
    fn default() -> Self {
        TsunamiConfig {
            tenants: 100_000,
            batch_size: 512,
            steady_batches: 40,
            head_events_per_batch: 512,
            zipf_s: 1.1,
            seed: 42,
        }
    }
}

/// Scenario outcome. `render()` is the experiment-ledger line.
#[derive(Debug, Clone)]
pub struct TsunamiReport {
    pub tenants: usize,
    pub events_total: u64,
    pub ticks: u64,
    /// Feed ring bytes right after the first tick installed every
    /// managed tenant's warm ring — the transient high-water mark the
    /// tier budget exists to collapse.
    pub feed_bytes_all_warm: usize,
    /// Feed ring bytes at the end of the Zipf steady state.
    pub feed_bytes_steady_end: usize,
    /// Feed ring bytes after quiescence (must be 0: every ring
    /// drained into its sketch and evicted).
    pub feed_bytes_final: usize,
    /// (hot, warm, cold) at the end of the steady state.
    pub tiers_steady_end: (usize, usize, usize),
    pub tiers_final: (usize, usize, usize),
    pub name_segments: usize,
    pub counter_segments: usize,
    pub lake_pairs: usize,
    pub lake_pair_segments: usize,
    pub feed_evictions: u64,
    pub feed_repromotions: u64,
    /// Sketch fits the drifting head tenant accumulated mid-storm.
    pub head_fits: u64,
    pub wall_secs: f64,
    pub events_per_sec: f64,
}

impl TsunamiReport {
    pub fn render(&self) -> String {
        format!(
            "tenant tsunami ({} tenants, {} events, {} ticks):\n  \
             feed bytes: all-warm {} -> steady-end {} -> final {}\n  \
             tiers: steady-end {:?} -> final {:?}\n  \
             segments: names {} | counters {} | lake pairs {} ({} pairs)\n  \
             evictions {} | repromotions {} | head fits {}\n  \
             {:.1}s wall, {:.0} events/s",
            self.tenants,
            self.events_total,
            self.ticks,
            self.feed_bytes_all_warm,
            self.feed_bytes_steady_end,
            self.feed_bytes_final,
            self.tiers_steady_end,
            self.tiers_final,
            self.name_segments,
            self.counter_segments,
            self.lake_pair_segments,
            self.lake_pairs,
            self.feed_evictions,
            self.feed_repromotions,
            self.head_fits,
            self.wall_secs,
            self.events_per_sec,
        )
    }
}

/// Deterministic tenant name for index `i` (index 0 is the head).
pub fn tenant_name(i: usize) -> String {
    format!("tsu-{i:06}")
}

/// Cumulative-weight Zipf sampler over `n` ranks.
struct Zipf {
    cumulative: Vec<f64>,
}

impl Zipf {
    fn new(n: usize, s: f64) -> Zipf {
        let mut cumulative = Vec::with_capacity(n);
        let mut acc = 0.0;
        for i in 0..n {
            acc += 1.0 / ((i + 1) as f64).powf(s);
            cumulative.push(acc);
        }
        Zipf { cumulative }
    }

    fn sample(&self, rng: &mut Rng) -> usize {
        let total = *self.cumulative.last().expect("zipf over 0 ranks");
        let u = rng.f64() * total;
        self.cumulative.partition_point(|&c| c < u)
    }
}

/// Run the scenario. The engine must have `lifecycle.enabled: true`
/// with every `tenant_name(0..cfg.tenants)` managed (the test below
/// builds that config programmatically).
pub fn run_tenant_tsunami(engine: &Engine, cfg: &TsunamiConfig) -> Result<TsunamiReport> {
    ensure!(cfg.tenants >= 16, "need >= 16 tenants");
    ensure!(cfg.batch_size >= 1, "batch_size must be >= 1");
    let hub = engine
        .lifecycle
        .as_ref()
        .ok_or_else(|| anyhow!("tenant tsunami needs lifecycle.enabled: true"))?;
    let cold_ticks = hub.config().cold_after_idle_ticks as usize;

    let mut ledger: BTreeMap<String, u64> = BTreeMap::new();
    let mut events_total = 0u64;
    let mut ticks = 0u64;
    let mut baseline = Workload::new(TenantProfile::new("tsu-base", cfg.seed, 0.3, 0.1), cfg.seed);
    let mut drifted = Workload::new(
        TenantProfile::new("tsu-head", cfg.seed, 0.3, 0.6).with_fraud_rate(0.25),
        cfg.seed ^ 0x5707,
    );
    let t0 = Instant::now();

    let mut score = |engine: &Engine,
                     ledger: &mut BTreeMap<String, u64>,
                     batch: &[(String, Vec<f32>)]|
     -> Result<()> {
        let reqs: Vec<ScoreRequest> = batch
            .iter()
            .enumerate()
            .map(|(i, (tenant, features))| ScoreRequest {
                intent: Intent {
                    tenant: tenant.clone(),
                    ..Intent::default()
                },
                entity: format!("e{events_total}-{i}"),
                features: features.clone(),
            })
            .collect();
        let resps = engine.score_batch(&reqs)?;
        ensure!(resps.len() == reqs.len(), "response count mismatch");
        for (tenant, _) in batch {
            *ledger.entry(tenant.clone()).or_insert(0) += 1;
        }
        events_total += batch.len() as u64;
        Ok(())
    };

    // Phase A — onboarding storm: every batch is `batch_size` fresh,
    // never-seen tenants scoring their first (and only) event, with a
    // controller tick after each batch. First-touch interning, counter
    // slab growth and lake pair interning all run concurrently with
    // the control plane here.
    let mut feed_bytes_all_warm = 0usize;
    let mut next_tenant = 0usize;
    while next_tenant < cfg.tenants {
        let end = (next_tenant + cfg.batch_size).min(cfg.tenants);
        let batch: Vec<(String, Vec<f32>)> = (next_tenant..end)
            .map(|i| (tenant_name(i), baseline.next_event().features))
            .collect();
        next_tenant = end;
        score(engine, &mut ledger, &batch)?;
        hub.tick(engine)?;
        ticks += 1;
        if ticks == 1 {
            // The first tick discovered every managed tenant and
            // installed its warm ring — the transient the tier budget
            // collapses.
            feed_bytes_all_warm = hub.feed_memory_bytes();
        }
    }

    // Phase B — Zipf steady state with a drifting head: rank-0-heavy
    // traffic over the full tenant set, plus a dedicated drifted
    // stream keeping the head tenant's ring at Hot pressure while the
    // autopilot calibrates it.
    let zipf = Zipf::new(cfg.tenants, cfg.zipf_s);
    let mut rng = Rng::new(cfg.seed ^ 0x7521);
    let head = tenant_name(0);
    for _ in 0..cfg.steady_batches {
        let mut batch: Vec<(String, Vec<f32>)> = (0..cfg.batch_size)
            .map(|_| (tenant_name(zipf.sample(&mut rng)), baseline.next_event().features))
            .collect();
        for _ in 0..cfg.head_events_per_batch {
            batch.push((head.clone(), drifted.next_event().features));
        }
        score(engine, &mut ledger, &batch)?;
        engine.drain_shadows();
        hub.tick(engine)?;
        ticks += 1;
    }
    let tiers_steady_end = hub.tier_counts();
    let feed_bytes_steady_end = hub.feed_memory_bytes();

    // Phase C — quiescence: no traffic, ticks only, until every ring
    // has drained into its sketch and been evicted.
    for _ in 0..cold_ticks + 2 {
        hub.tick(engine)?;
        ticks += 1;
    }
    let tiers_final = hub.tier_counts();
    let feed_bytes_final = hub.feed_memory_bytes();
    let wall_secs = t0.elapsed().as_secs_f64();

    // -- Claim 3: exact accounting, zero lost appends. ---------------
    ensure!(
        engine.lake.lost_appends() == 0 && engine.lake.forced_overwrites() == 0,
        "lake dropped records: lost {} forced {}",
        engine.lake.lost_appends(),
        engine.lake.forced_overwrites()
    );
    let counters = engine.scored_events_snapshot();
    ensure!(
        counters == ledger,
        "scored_events diverged from the scenario ledger \
         ({} vs {} tenants, totals {} vs {})",
        counters.len(),
        ledger.len(),
        counters.values().sum::<u64>(),
        ledger.values().sum::<u64>()
    );

    // -- Claim 1: registries grow in constant-size segments. ---------
    let interned = engine.tenants.len();
    let shards = engine.tenants.shard_count();
    let name_segments = engine.tenants.name_segments();
    ensure!(
        name_segments * SEG_SIZE <= interned + shards * SEG_SIZE,
        "interner reverse map over-allocated: {name_segments} segments for {interned} tenants"
    );
    let counter_segments = engine.tenant_events.segments_allocated();
    ensure!(
        counter_segments * SEG_SIZE <= interned + shards * SEG_SIZE,
        "counter slab over-allocated: {counter_segments} segments for {interned} tenants"
    );
    let lake_pairs = engine.lake.pair_count();
    let lake_pair_segments = engine.lake.pair_segments();
    ensure!(
        lake_pair_segments <= lake_pairs.div_ceil(SEG_SIZE) + 16,
        "lake pair registry over-allocated: {lake_pair_segments} segments for {lake_pairs} pairs"
    );

    // -- Claim 2: the feed memory budget. ----------------------------
    let managed = tiers_final.0 + tiers_final.1 + tiers_final.2;
    ensure!(
        managed >= cfg.tenants,
        "hub manages {managed} pairs, expected >= {}",
        cfg.tenants
    );
    let (hot, _warm, cold) = tiers_steady_end;
    ensure!(hot >= 1, "Zipf head never reached the Hot tier");
    // The recency window (`coldAfterIdleTicks` ticks of Zipf draws)
    // keeps a few hundred mid-ranks warm at small tenant counts, so
    // "mostly idle ⇒ mostly evicted" is asserted as a one-third floor
    // here; at the 100k ledger scale the cold share is > 95%.
    ensure!(
        cold * 3 >= managed,
        "idle tail not evicted: only {cold}/{managed} cold at steady end"
    );
    ensure!(
        feed_bytes_steady_end < feed_bytes_all_warm,
        "tiering never beat the all-warm transient: {feed_bytes_steady_end} >= {feed_bytes_all_warm}"
    );
    ensure!(
        feed_bytes_final == 0 && tiers_final == (0, 0, managed),
        "quiescence left rings resident: {feed_bytes_final} bytes, tiers {tiers_final:?}"
    );

    let head_fits = hub
        .status()
        .into_iter()
        .find(|p| p.tenant == head)
        .map(|p| p.fits)
        .unwrap_or(0);
    Ok(TsunamiReport {
        tenants: cfg.tenants,
        events_total,
        ticks,
        feed_bytes_all_warm,
        feed_bytes_steady_end,
        feed_bytes_final,
        tiers_steady_end,
        tiers_final,
        name_segments,
        counter_segments,
        lake_pairs,
        lake_pair_segments,
        feed_evictions: engine.counters.get("lifecycle_feed_evictions"),
        feed_repromotions: engine.counters.get("lifecycle_feed_repromotions"),
        head_fits,
        wall_secs,
        events_per_sec: events_total as f64 / wall_secs.max(1e-9),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MuseConfig;
    use crate::runtime::{ModelPool, SimArtifacts};
    use std::sync::Arc;

    /// Engine over the synthetic sim-dialect artifacts with every
    /// tsunami tenant lifecycle-managed; runs everywhere, incl. CI.
    fn tsunami_engine(tenants: usize) -> (SimArtifacts, Arc<Engine>) {
        let fix = SimArtifacts::in_temp().unwrap();
        let yaml = r#"
routing:
  scoringRules:
  - description: "head tenant dedicated"
    condition:
      tenants: ["tsu-000000"]
    targetPredictorName: "duo"
  - description: "catch-all"
    condition: {}
    targetPredictorName: "solo"
predictors:
- name: duo
  experts: [s1, s2]
  quantile: custom
- name: solo
  experts: [s3]
  quantile: identity
server:
  workers: 2
  maxBatchEvents: 2048
  lakeMaxRecords: 65536
lifecycle:
  enabled: true
  autoDiscover: false
  sketchK: 2048
  alertRate: 0.1
  delta: 0.2
  minDriftSamples: 512
  minValidationSamples: 512
  cooldownTicks: 4
"#;
        let mut config = MuseConfig::from_yaml(yaml).unwrap();
        config.lifecycle.tenants = (0..tenants).map(tenant_name).collect();
        let pool = Arc::new(ModelPool::new(fix.manifest().unwrap()));
        let engine = Arc::new(Engine::build(&config, pool).unwrap());
        (fix, engine)
    }

    #[test]
    fn tsunami_bounds_rss_and_loses_nothing() {
        // Scaled-down default so plain `cargo test` stays quick;
        // MUSE_TSUNAMI_TENANTS=5000 is the CI smoke recipe and
        // =100000 the EXPERIMENTS.md ledger run.
        let tenants = std::env::var("MUSE_TSUNAMI_TENANTS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(2000);
        let (_fix, engine) = tsunami_engine(tenants);
        let cfg = TsunamiConfig {
            tenants,
            ..TsunamiConfig::default()
        };
        let report = run_tenant_tsunami(&engine, &cfg).unwrap();
        println!("{}", report.render());

        // Every tenant interned exactly once; the drifting head both
        // reached the Hot tier (asserted inside the run) and fed the
        // autopilot enough mid-storm samples for its initial fit.
        assert_eq!(engine.tenants.len(), tenants);
        assert!(report.head_fits >= 1, "{report:?}");
        // The idle tail was evicted and later quiescence emptied the
        // feed plane entirely.
        assert!(report.feed_evictions as usize >= tenants / 2, "{report:?}");
        assert_eq!(report.feed_bytes_final, 0);
        engine.drain_shadows();
    }
}
