//! Simulation substrates: the synthetic multi-tenant transaction
//! workload, the Kubernetes-style rolling-update cluster model behind
//! Fig. 5, and the real-thread swap-under-load harness proving that
//! routing-config promotions never stall the data plane.

pub mod cluster;
pub mod workload;

pub use cluster::{
    swap_storm, ClusterConfig, ClusterSim, LatencyModel, RolloutTrace, SwapStormConfig,
    SwapStormReport,
};
pub use workload::{Event, TenantProfile, TrafficMix, Workload, FEATURE_DIM};
