//! Simulation substrates: the synthetic multi-tenant transaction
//! workload, the Kubernetes-style rolling-update cluster model behind
//! Fig. 5, the real-thread swap-under-load harness proving that
//! routing-config promotions never stall the data plane, the
//! multi-tenant batch-scoring throughput scenario exercising
//! `Engine::score_batch` end to end, the drift-storm scenario
//! proving the lifecycle autopilot recalibrates per-tenant alert
//! rates with zero manual control-plane calls, and the saturation
//! ramp measuring `Engine::score` scaling across worker threads while
//! cross-checking the lock-free observation plane against a
//! sequential oracle, and the connection storm holding thousands of
//! concurrent keep-alive sockets against the event-driven ingress
//! plane with exact end-to-end event conservation. `cluster_storm`
//! attacks the real cluster plane (`crate::cluster`): Zipf traffic
//! over N serving nodes racing continuous two-phase publishes, with a
//! mid-flip crash and a log-replay join, asserting zero dropped, zero
//! torn and epoch-exact accounting. `tenant_tsunami` is the
//! 100k-tenant scale-out proof: an onboarding storm plus Zipf
//! steady-state with a drifting head tenant, asserting bounded
//! registry/feed RSS, zero lost appends and exact per-tenant
//! accounting. `drift_matrix` is the adversarial-drift scenario
//! matrix: seeded cells (coordinated fraud waves, exact-tie fast
//! attacks, onboarding storms, label delay, class imbalance) A/B'ing
//! the empirical quantile-map T^Q against full-range calibration
//! through the same shadow→validate→promote path.

pub mod cluster;
pub mod cluster_storm;
pub mod connection_storm;
pub mod drift_matrix;
pub mod drift_storm;
pub mod multitenant;
pub mod saturation;
pub mod tenant_tsunami;
pub mod workload;

pub use cluster::{
    swap_storm, ClusterConfig, ClusterSim, LatencyModel, RolloutTrace, SwapStormConfig,
    SwapStormReport,
};
pub use cluster_storm::{run_cluster_storm, ClusterStormConfig, ClusterStormReport};
pub use connection_storm::{
    run_connection_storm, ConnectionStormConfig, ConnectionStormReport,
};
pub use drift_matrix::{
    matrix_seed, run_drift_matrix, CellOutcome, DriftCell, DriftMatrixConfig, MatrixReport,
    PhaseMetrics,
};
pub use drift_storm::{run_drift_storm, DriftStormConfig, DriftStormReport};
pub use multitenant::{run_batch_mix, BatchMixConfig, BatchMixReport};
pub use saturation::{run_saturation, SaturationConfig, SaturationLevel, SaturationReport};
pub use tenant_tsunami::{run_tenant_tsunami, TsunamiConfig, TsunamiReport};
pub use workload::{Event, TenantProfile, TrafficMix, Workload, FEATURE_DIM};
