//! Simulation substrates: the synthetic multi-tenant transaction
//! workload, the Kubernetes-style rolling-update cluster model behind
//! Fig. 5, the real-thread swap-under-load harness proving that
//! routing-config promotions never stall the data plane, and the
//! multi-tenant batch-scoring throughput scenario exercising
//! `Engine::score_batch` end to end.

pub mod cluster;
pub mod multitenant;
pub mod workload;

pub use cluster::{
    swap_storm, ClusterConfig, ClusterSim, LatencyModel, RolloutTrace, SwapStormConfig,
    SwapStormReport,
};
pub use multitenant::{run_batch_mix, BatchMixConfig, BatchMixReport};
pub use workload::{Event, TenantProfile, TrafficMix, Workload, FEATURE_DIM};
