//! Simulation substrates: the synthetic multi-tenant transaction
//! workload and the Kubernetes-style rolling-update cluster model
//! behind Fig. 5.

pub mod cluster;
pub mod workload;

pub use cluster::{ClusterConfig, ClusterSim, LatencyModel, RolloutTrace};
pub use workload::{Event, TenantProfile, TrafficMix, Workload, FEATURE_DIM};
