//! Saturation scenario: the scaling-curve counterpart to
//! `drift_storm`.
//!
//! Drives a **fixed multi-tenant mix** through `Engine::score` from a
//! ramp of concurrent worker threads (1 → 2 → 4 → 8 by default) and
//! reports events/s plus p50/p99 latency at every level — the curve
//! that exposes any serialization left on the observation plane. With
//! the seed's global `DataLake` mutex and locked counter map, the
//! curve flattens as soon as two workers contend; with the sharded
//! lake, wait-free counters and the allocation-free batcher submit it
//! should keep climbing until PJRT (or the core count) saturates.
//! EXPERIMENTS.md "Observation plane" records the measured curves;
//! `examples/saturation.rs` is the CI smoke wrapper.
//!
//! The scenario also cross-checks the observation plane against a
//! sequential oracle while it runs: every level's scored events are
//! counted by the drivers themselves, and after each ramp level the
//! shard-merged `DataLake` per-pair counts and `len()` must equal
//! those driver-side tallies exactly (no event lost, none double
//! counted, no torn shard merge) — the lock-free refactor's
//! correctness bar, enforced on every CI run.

use crate::config::Intent;
use crate::coordinator::{Engine, ScoreRequest};
use crate::simulator::workload::{TenantProfile, Workload};
use anyhow::{ensure, Context, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Scenario parameters (defaults match the CI smoke run).
#[derive(Debug, Clone)]
pub struct SaturationConfig {
    /// Worker-thread counts to ramp through.
    pub thread_steps: Vec<usize>,
    /// Events each worker drives per level.
    pub events_per_thread: usize,
    /// The fixed tenant mix; workers round-robin over it.
    pub tenants: Vec<TenantProfile>,
    pub seed: u64,
}

impl Default for SaturationConfig {
    fn default() -> Self {
        SaturationConfig {
            thread_steps: vec![1, 2, 4, 8],
            events_per_thread: 2_000,
            tenants: vec![
                TenantProfile::new("bank1", 7, 0.3, 0.1),
                TenantProfile::new("bank2", 11, 0.3, 0.1),
            ],
            seed: 17,
        }
    }
}

/// One ramp level's measurements.
#[derive(Debug, Clone)]
pub struct SaturationLevel {
    pub threads: usize,
    pub events: u64,
    pub wall_secs: f64,
    pub events_per_sec: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
}

/// Scenario outcome.
#[derive(Debug, Clone)]
pub struct SaturationReport {
    pub levels: Vec<SaturationLevel>,
    /// Total events scored across all levels.
    pub events_total: u64,
    /// `events/s` at the highest thread count over `events/s` at one
    /// thread — the scaling factor the ramp achieved.
    pub scaling: f64,
}

impl SaturationReport {
    pub fn render(&self) -> String {
        let mut out = String::from("saturation ramp (Engine::score, fixed tenant mix):\n");
        for l in &self.levels {
            out.push_str(&format!(
                "  threads {:>2}: {:>8.0} events/s  p50 {:>7.3} ms  p99 {:>7.3} ms  ({} events in {:.2}s)\n",
                l.threads, l.events_per_sec, l.p50_ms, l.p99_ms, l.events, l.wall_secs
            ));
        }
        out.push_str(&format!(
            "  scaling {}x threads -> {:.2}x throughput, {} events total",
            self.levels.last().map_or(0, |l| l.threads),
            self.scaling,
            self.events_total
        ));
        out
    }
}

/// Run the ramp against a live engine. Requires only routable tenants;
/// after every level the lake's shard-merged accounting is checked
/// against the drivers' own tallies (see module docs).
pub fn run_saturation(engine: &Engine, cfg: &SaturationConfig) -> Result<SaturationReport> {
    ensure!(!cfg.thread_steps.is_empty(), "need >= 1 ramp level");
    ensure!(!cfg.tenants.is_empty(), "need >= 1 tenant");
    ensure!(cfg.events_per_thread >= 1, "events_per_thread must be >= 1");

    // Per-(tenant, predictor) oracle tallies, accumulated across
    // levels by the drivers themselves.
    let mut oracle: Vec<((String, String), u64)> = Vec::new();
    let mut levels = Vec::new();
    let mut events_total = 0u64;

    for (level_idx, &threads) in cfg.thread_steps.iter().enumerate() {
        ensure!(threads >= 1, "thread counts must be >= 1");
        engine.live_latency.reset();
        let scored = AtomicU64::new(0);
        let level_pairs: std::sync::Mutex<Vec<((String, String), u64)>> =
            std::sync::Mutex::new(Vec::new());
        let t0 = Instant::now();
        std::thread::scope(|scope| -> Result<()> {
            let mut handles = Vec::new();
            for w in 0..threads {
                let tenant = cfg.tenants[w % cfg.tenants.len()].clone();
                let scored = &scored;
                let level_pairs = &level_pairs;
                let seed = cfg.seed ^ ((level_idx as u64) << 32) ^ w as u64;
                handles.push(scope.spawn(move || -> Result<()> {
                    let mut wl = Workload::new(tenant.clone(), seed);
                    // Tally locally; merge once at the end (the oracle
                    // bookkeeping must not serialize the drivers).
                    let mut local: Vec<((String, String), u64)> = Vec::new();
                    for i in 0..cfg.events_per_thread {
                        let e = wl.next_event();
                        let resp = engine
                            .score(&ScoreRequest {
                                intent: Intent {
                                    tenant: tenant.name.clone(),
                                    ..Intent::default()
                                },
                                entity: format!("sat{level_idx}-{w}-{i}"),
                                features: e.features,
                            })
                            .context("saturation score")?;
                        let key = (tenant.name.clone(), resp.predictor.to_string());
                        match local.iter_mut().find(|(k, _)| *k == key) {
                            Some((_, n)) => *n += 1,
                            None => local.push((key, 1)),
                        }
                        scored.fetch_add(1, Ordering::Relaxed);
                    }
                    level_pairs.lock().unwrap().extend(local);
                    Ok(())
                }));
            }
            for h in handles {
                h.join().expect("saturation worker panicked")?;
            }
            Ok(())
        })?;
        let wall_secs = t0.elapsed().as_secs_f64();
        let events = scored.load(Ordering::Relaxed);
        events_total += events;
        ensure!(
            events == (threads * cfg.events_per_thread) as u64,
            "driver tally lost events"
        );

        // Merge this level's tallies into the cross-level oracle.
        for (key, n) in level_pairs.into_inner().unwrap() {
            match oracle.iter_mut().find(|(k, _)| *k == key) {
                Some((_, total)) => *total += n,
                None => oracle.push((key, n)),
            }
        }

        // Observation-plane cross-check: shard-merged per-pair counts
        // must equal the sequentially-merged driver tallies, exactly.
        // (Shadow mirrors would land in separate (tenant, shadow
        // predictor) pairs; the compared pairs are live-only.)
        engine.drain_shadows();
        let mut oracle_total = 0u64;
        for ((tenant, predictor), expect) in &oracle {
            let got = engine.lake.count_for(tenant, predictor) as u64;
            ensure!(
                got == *expect,
                "lake count_for({tenant},{predictor}) = {got}, oracle says {expect}"
            );
            oracle_total += expect;
        }
        ensure!(
            engine.lake.len() as u64 >= oracle_total.min(engine.lake.effective_capacity() as u64),
            "lake len {} below the oracle floor {oracle_total}",
            engine.lake.len()
        );

        levels.push(SaturationLevel {
            threads,
            events,
            wall_secs,
            events_per_sec: events as f64 / wall_secs.max(1e-9),
            p50_ms: engine.live_latency.percentile_ns(50.0) as f64 / 1e6,
            p99_ms: engine.live_latency.percentile_ns(99.0) as f64 / 1e6,
        });
    }

    let scaling = match (levels.first(), levels.last()) {
        (Some(a), Some(b)) if a.events_per_sec > 0.0 => b.events_per_sec / a.events_per_sec,
        _ => 0.0,
    };
    Ok(SaturationReport {
        levels,
        events_total,
        scaling,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MuseConfig;
    use crate::runtime::{ModelPool, SimArtifacts};
    use std::sync::Arc;

    const CONFIG: &str = r#"
routing:
  scoringRules:
  - description: "bank1 dedicated"
    condition:
      tenants: ["bank1"]
    targetPredictorName: "duo"
  - description: "catch-all"
    condition: {}
    targetPredictorName: "solo"
predictors:
- name: duo
  experts: [s1, s2]
  quantile: identity
- name: solo
  experts: [s3]
  quantile: identity
server:
  workers: 2
  maxBatchDelayUs: 50
"#;

    #[test]
    fn saturation_ramp_runs_and_cross_checks_the_lake() {
        // Sim-dialect artifacts: runs without `make artifacts`,
        // including in CI. Small ramp — the test asserts the oracle
        // cross-check and report shape, not absolute throughput.
        let fix = SimArtifacts::in_temp().unwrap();
        let pool = Arc::new(ModelPool::new(fix.manifest().unwrap()));
        let engine = Engine::build(&MuseConfig::from_yaml(CONFIG).unwrap(), pool).unwrap();
        let report = run_saturation(
            &engine,
            &SaturationConfig {
                thread_steps: vec![1, 4],
                events_per_thread: 300,
                ..SaturationConfig::default()
            },
        )
        .unwrap();
        assert_eq!(report.levels.len(), 2);
        assert_eq!(report.events_total, 300 + 4 * 300);
        assert!(report.levels.iter().all(|l| l.events_per_sec > 0.0));
        assert!(report.levels.iter().all(|l| l.p99_ms >= l.p50_ms));
        let rendered = report.render();
        assert!(rendered.contains("threads  1"), "{rendered}");
        assert!(rendered.contains("threads  4"), "{rendered}");
        // The engine-side accounting agrees with the run.
        assert_eq!(engine.hot.requests_live.get(), report.events_total);
        assert_eq!(engine.lake.forced_overwrites(), 0);
        assert_eq!(engine.lake.lost_appends(), 0);
    }
}
