//! Cluster-storm scenario: the cluster plane's seamlessness proof
//! under fire.
//!
//! Where the testkit cluster runner (`testkit::harness::run_cluster_trace`)
//! replays generated storms at *barriers* — commands never race events,
//! so every response gets an exact single-epoch attribution — this
//! scenario removes the barriers. N serving nodes take Zipf-skewed
//! multi-tenant traffic from several client threads **while** a control
//! thread drives continuous version rotations (shadow-deploy → promote
//! → decommission) through the two-phase publish, kills one node
//! mid-flip and joins a replacement that must catch up by replaying
//! the committed log.
//!
//! Three things are asserted, exactly:
//!
//! * **zero dropped, zero torn** — every driven event produces a
//!   response whose predictor matches the control thread's recorded
//!   assignment at *some* committed epoch inside the response's
//!   attribution window `[epoch_lo, epoch_hi]`. A response scored by
//!   predictor X when no epoch in its window assigned X to that
//!   tenant would be a torn, mixed-version score — the exact failure
//!   the two-phase publish exists to rule out.
//! * **epoch-exact accounting** — the per-(tenant, predictor)
//!   non-shadow record counts summed over *every node ever created*
//!   (the crashed node's engine keeps its scored history) equal the
//!   driver tallies as exact multiset counts; no node forced an
//!   overwrite or lost an append.
//! * **lifecycle arithmetic** — exactly one crash, `nodes + 1` joins
//!   (the initial set plus the mid-storm replacement), zero aborts,
//!   and `publishes == committed_epoch`.
//!
//! One deliberate client-side concession: a request that holds a
//! stale engine snapshot while its predictor's batcher is being
//! decommissioned gets a clean "batcher has shut down" error
//! (`coordinator::batcher` shutdown docs) — the engine guarantees the
//! failed attempt leaves **no** trace in the lake or counters, so the
//! driver retries it, exactly as a production client would. Retries
//! are counted and reported; the conservation checks stay exact
//! because only successful attempts record anywhere.
//!
//! `examples/cluster_storm.rs` is the CI smoke wrapper
//! (`MUSE_CLUSTER_EVENTS` / `MUSE_CLUSTER_NODES` override).

use crate::cluster::{
    ClusterCommand, ClusterOptions, FaultPoint, MuseCluster, NodeId, PoolFactory,
};
use crate::config::{
    Condition, Intent, LifecycleConfig, MuseConfig, PredictorConfig, QuantileMode, RoutingConfig,
    ScoringRule, ServerConfig,
};
use crate::coordinator::ScoreRequest;
use crate::runtime::{Manifest, ModelPool, SimArtifacts};
use crate::util::rng::Rng;
use anyhow::{anyhow, ensure, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Scenario parameters (defaults match the unit test; the CI example
/// scales `calls` up and uses 4–8 nodes).
#[derive(Debug, Clone)]
pub struct ClusterStormConfig {
    /// Initial serving nodes (the storm crashes one and joins one).
    pub nodes: usize,
    /// Tenants t0..t{n-1}; traffic is Zipf-skewed toward t0.
    pub tenants: usize,
    /// Scoring calls claimed by the client threads. Every
    /// `batch_every`-th call is a whole batch of `batch_size` events,
    /// so the driven event total is slightly higher.
    pub calls: usize,
    /// Version rotations (shadow-deploy → promote → decommission),
    /// spread evenly across the call stream.
    pub promotions: usize,
    /// Client scorer threads.
    pub threads: usize,
    /// Every k-th call is a batch (0 disables batches).
    pub batch_every: usize,
    pub batch_size: usize,
    /// Two-phase publish ack budget; the injected crash costs exactly
    /// one ack timeout before the victim is fenced.
    pub ack_timeout: Duration,
    pub seed: u64,
}

impl Default for ClusterStormConfig {
    fn default() -> Self {
        ClusterStormConfig {
            nodes: 5,
            tenants: 6,
            calls: 2_000,
            promotions: 12,
            threads: 4,
            batch_every: 7,
            batch_size: 4,
            ack_timeout: Duration::from_millis(500),
            seed: 41,
        }
    }
}

/// Scenario outcome. Every invariant in the module docs has already
/// been enforced by the time a report is returned.
#[derive(Debug, Clone)]
pub struct ClusterStormReport {
    pub nodes_initial: usize,
    pub nodes_serving_final: usize,
    pub calls_total: u64,
    /// Driven events (singles + batch events) == lake non-shadow total.
    pub events_total: u64,
    /// Client-side retries of the decommission/shutdown race.
    pub retries: u64,
    pub promotions: u64,
    pub committed_epoch: u64,
    pub crashes: u64,
    pub joins: u64,
    pub wall_secs: f64,
    pub events_per_sec: f64,
    /// Events served per node id, from the driver's own records.
    pub per_node_events: Vec<(NodeId, u64)>,
    /// Two-phase flip latency (stage send → last commit ack).
    pub flip_p50_ms: f64,
    pub flip_p99_ms: f64,
}

impl ClusterStormReport {
    pub fn render(&self) -> String {
        let mut out = format!(
            "cluster storm ({} nodes, {} threads): {:>8.0} events/s  \
             flip p50 {:.3} ms  p99 {:.3} ms\n  \
             {} events / {} calls in {:.2}s, {} retries, \
             {} promotions -> epoch {}, {} crash(es), {} joins, {} serving\n",
            self.nodes_initial,
            self.per_node_events.len().max(1),
            self.events_per_sec,
            self.flip_p50_ms,
            self.flip_p99_ms,
            self.events_total,
            self.calls_total,
            self.wall_secs,
            self.retries,
            self.promotions,
            self.committed_epoch,
            self.crashes,
            self.joins,
            self.nodes_serving_final,
        );
        for (id, n) in &self.per_node_events {
            out.push_str(&format!(
                "  node {id}: {n} events ({:.0}/s)\n",
                *n as f64 / self.wall_secs.max(1e-9)
            ));
        }
        out
    }
}

/// One recorded response: enough to replay the torn check and the
/// conservation tally after the storm.
struct RespRec {
    tenant: usize,
    node: NodeId,
    epoch_lo: u64,
    epoch_hi: u64,
    predictor: String,
}

struct ScorerOut {
    recs: Vec<RespRec>,
    retries: u64,
}

/// Versioned expert rotation: successive versions of a tenant's
/// predictor really are different models, so a torn score would also
/// be numerically wrong, not just mislabeled.
fn candidate_cfg(tenant: usize, version: usize) -> PredictorConfig {
    PredictorConfig {
        name: format!("p{tenant}-v{version}"),
        experts: vec![format!("s{}", 1 + (tenant + version) % 3)],
        weights: vec![1.0],
        quantile_mode: QuantileMode::Identity,
        reference: "fraud-default".to_string(),
        posterior_correction: false,
    }
}

/// One dedicated predictor per tenant plus a catch-all, mirroring the
/// paper's per-tenant rollout unit.
fn storm_config(tenants: usize) -> MuseConfig {
    let mut scoring_rules: Vec<ScoringRule> = (0..tenants)
        .map(|i| ScoringRule {
            description: format!("dedicated t{i}"),
            condition: Condition {
                tenants: vec![format!("t{i}")],
                ..Condition::default()
            },
            target_predictor: format!("p{i}-v0").into(),
        })
        .collect();
    scoring_rules.push(ScoringRule {
        description: "catch-all".to_string(),
        condition: Condition::default(),
        target_predictor: "p0-v0".into(),
    });
    MuseConfig {
        routing: RoutingConfig {
            scoring_rules,
            shadow_rules: Vec::new(),
        },
        predictors: (0..tenants).map(|i| candidate_cfg(i, 0)).collect(),
        server: ServerConfig {
            workers: 2,
            ..ServerConfig::default()
        },
        lifecycle: LifecycleConfig::default(),
    }
}

/// The tenant live at committed epoch `k`, per the control thread's
/// own record (`history` is promote events in epoch order).
fn assignment_at(history: &[(u64, String)], k: u64) -> Option<&str> {
    history
        .iter()
        .rev()
        .find(|(e, _)| *e <= k)
        .map(|(_, name)| name.as_str())
}

/// Zipf(1) pick over tenant ranks: t0 most popular.
fn zipf_pick(cum: &[f64], u: f64) -> usize {
    let total = cum.last().copied().unwrap_or(1.0);
    let target = u * total;
    cum.iter().position(|&c| target < c).unwrap_or(cum.len() - 1)
}

/// Run the storm. Returns the report only if every seamlessness,
/// conservation and lifecycle check passed (see module docs).
pub fn run_cluster_storm(
    fix: &SimArtifacts,
    cfg: &ClusterStormConfig,
) -> Result<ClusterStormReport> {
    ensure!(cfg.nodes >= 2, "storm needs >= 2 nodes (one gets crashed)");
    ensure!(cfg.tenants >= 1, "storm needs >= 1 tenant");
    ensure!(cfg.threads >= 1, "storm needs >= 1 scorer thread");
    ensure!(cfg.promotions >= 1, "storm needs >= 1 promotion");
    ensure!(cfg.batch_every == 0 || cfg.batch_size >= 1, "batch_size >= 1");

    let config = storm_config(cfg.tenants);
    let root = fix.root().clone();
    let factory: PoolFactory =
        Box::new(move || Ok(Arc::new(ModelPool::new(Manifest::load(&root)?))));
    let cluster = MuseCluster::build(
        &config,
        ClusterOptions {
            nodes: cfg.nodes,
            ack_timeout: cfg.ack_timeout,
        },
        factory,
    )?;
    let dim = cluster.serving_nodes()[0]
        .engine
        .predictor("p0-v0")?
        .feature_dim();

    // Zipf(1) cumulative weights over tenant ranks.
    let mut cum = Vec::with_capacity(cfg.tenants);
    let mut acc = 0.0f64;
    for i in 0..cfg.tenants {
        acc += 1.0 / (i + 1) as f64;
        cum.push(acc);
    }

    let next_call = AtomicUsize::new(0);
    let aborted = AtomicBool::new(false);
    let t0 = Instant::now();

    let (history, scorer_outs) = std::thread::scope(|s| {
        // The control thread is the cluster's sole publisher, so its
        // (epoch, predictor) record *is* the assignment history — the
        // committed epoch returned by each promote pins exactly when
        // the flip became the cluster truth.
        let control = s.spawn(|| -> Result<Vec<Vec<(u64, String)>>> {
            let mut history: Vec<Vec<(u64, String)>> = (0..cfg.tenants)
                .map(|i| vec![(0, format!("p{i}-v0"))])
                .collect();
            let mut version = vec![0usize; cfg.tenants];
            for r in 0..cfg.promotions {
                // Spread rotations across the call stream instead of
                // racing them all past the first few events.
                let threshold = ((r + 1) * cfg.calls) / (cfg.promotions + 2);
                while next_call.load(Ordering::Relaxed) < threshold
                    && !aborted.load(Ordering::Relaxed)
                {
                    std::thread::sleep(Duration::from_micros(200));
                }
                if aborted.load(Ordering::Relaxed) {
                    break;
                }
                let ti = r % cfg.tenants;
                let v = version[ti] + 1;
                let name = format!("p{ti}-v{v}");
                if r == cfg.promotions / 2 {
                    // Kill one replica mid-flip: it stage-acks the next
                    // publish, then dies before applying the commit —
                    // fenced at the old epoch while survivors flip.
                    let victim = cluster.serving_nodes()[0].id;
                    cluster.arm_fault(victim, FaultPoint::CrashBeforeCommitApply)?;
                }
                cluster.publish(ClusterCommand::ShadowDeploy {
                    cfg: candidate_cfg(ti, v),
                    tenant: format!("t{ti}"),
                    src: vec![0.0, 1.0],
                    refq: vec![0.0, 1.0],
                })?;
                let epoch = cluster.publish(ClusterCommand::Promote {
                    tenant: format!("t{ti}"),
                    predictor: name.clone(),
                })?;
                history[ti].push((epoch, name));
                version[ti] = v;
                // Deferred-by-one retirement: the version demoted two
                // rotations ago has no traffic and no shadow rule left.
                if v >= 2 {
                    cluster.publish(ClusterCommand::Decommission {
                        predictor: format!("p{ti}-v{}", v - 2),
                    })?;
                }
                if r == cfg.promotions / 2 {
                    // The replacement replays the committed log before
                    // taking traffic.
                    cluster.join()?;
                }
            }
            Ok(history)
        });

        let mut scorers = Vec::with_capacity(cfg.threads);
        for t in 0..cfg.threads {
            let cluster = &cluster;
            let cfg = &cfg;
            let cum = &cum;
            let next_call = &next_call;
            let aborted = &aborted;
            scorers.push(s.spawn(move || -> Result<ScorerOut> {
                let mut rng = Rng::new(cfg.seed ^ ((t as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)));
                let gw = cluster.gateway();
                let mut recs: Vec<RespRec> = Vec::new();
                let mut retries = 0u64;
                loop {
                    let idx = next_call.fetch_add(1, Ordering::Relaxed);
                    if idx >= cfg.calls {
                        break;
                    }
                    let ti = zipf_pick(cum, rng.f64());
                    let tenant = format!("t{ti}");
                    let is_batch = cfg.batch_every != 0 && idx % cfg.batch_every == 0;
                    let n_events = if is_batch { cfg.batch_size } else { 1 };
                    let reqs: Vec<ScoreRequest> = (0..n_events)
                        .map(|j| ScoreRequest {
                            intent: Intent {
                                tenant: tenant.clone(),
                                ..Intent::default()
                            },
                            entity: format!("c{idx}-{j}"),
                            features: (0..dim).map(|_| rng.normal() as f32).collect(),
                        })
                        .collect();
                    let mut attempt = 0usize;
                    loop {
                        attempt += 1;
                        let res: Result<Vec<(NodeId, u64, u64, String)>> = if is_batch {
                            gw.score_batch(&reqs).map(|b| {
                                b.resps
                                    .iter()
                                    .map(|r| {
                                        (b.node, b.epoch_lo, b.epoch_hi, r.predictor.to_string())
                                    })
                                    .collect()
                            })
                        } else {
                            gw.score(&reqs[0]).map(|g| {
                                vec![(g.node, g.epoch_lo, g.epoch_hi, g.resp.predictor.to_string())]
                            })
                        };
                        match res {
                            Ok(rs) => {
                                for (node, epoch_lo, epoch_hi, predictor) in rs {
                                    recs.push(RespRec {
                                        tenant: ti,
                                        node,
                                        epoch_lo,
                                        epoch_hi,
                                        predictor,
                                    });
                                }
                                break;
                            }
                            // The decommission/shutdown race (module
                            // docs): the failed attempt recorded
                            // nothing, so a retry cannot double-count.
                            Err(_) if attempt < 64 => {
                                retries += 1;
                                std::thread::yield_now();
                            }
                            Err(e) => {
                                aborted.store(true, Ordering::Relaxed);
                                return Err(anyhow!(
                                    "call {idx} for {tenant} dropped after {attempt} attempts: {e:#}"
                                ));
                            }
                        }
                    }
                }
                Ok(ScorerOut { recs, retries })
            }));
        }

        let mut outs = Vec::with_capacity(scorers.len());
        for h in scorers {
            outs.push(h.join().map_err(|_| anyhow!("scorer thread panicked"))?);
        }
        let history = control
            .join()
            .map_err(|_| anyhow!("control thread panicked"))?;
        Ok::<_, anyhow::Error>((history, outs))
    })?;
    let wall_secs = t0.elapsed().as_secs_f64();

    // Scorer errors first: a dropped request is the root cause worth
    // reporting even when it also derailed the control thread.
    let mut retries = 0u64;
    let mut recs: Vec<RespRec> = Vec::new();
    for out in scorer_outs {
        let out = out?;
        retries += out.retries;
        recs.extend(out.recs);
    }
    let history = history?;

    // Zero dropped: every claimed call produced its full event count.
    let batches = if cfg.batch_every == 0 {
        0
    } else {
        cfg.calls.div_ceil(cfg.batch_every)
    };
    let expected_events = (cfg.calls - batches) + batches * cfg.batch_size;
    ensure!(
        recs.len() == expected_events,
        "driver recorded {} events, drove {expected_events}",
        recs.len()
    );

    // Zero torn: the response predictor must be the tenant's assigned
    // predictor at some committed epoch inside the attribution window.
    let final_epoch = cluster.committed_epoch();
    for rec in &recs {
        ensure!(rec.epoch_lo <= rec.epoch_hi, "inverted epoch window");
        ensure!(
            rec.epoch_hi <= final_epoch,
            "window [{}, {}] beyond committed epoch {final_epoch}",
            rec.epoch_lo,
            rec.epoch_hi
        );
        let hist = &history[rec.tenant];
        let fits = (rec.epoch_lo..=rec.epoch_hi)
            .any(|k| assignment_at(hist, k) == Some(rec.predictor.as_str()));
        ensure!(
            fits,
            "torn score: t{} got '{}' in window [{}, {}] but assignments are {:?}",
            rec.tenant,
            rec.predictor,
            rec.epoch_lo,
            rec.epoch_hi,
            hist
        );
    }

    // Epoch-exact accounting: driver multiset == cluster-aggregated
    // non-shadow lake, over every node ever created (the crashed
    // node's engine keeps its scored history).
    let mut expect: BTreeMap<(String, String), u64> = BTreeMap::new();
    let mut per_node: BTreeMap<NodeId, u64> = BTreeMap::new();
    for rec in &recs {
        *expect
            .entry((format!("t{}", rec.tenant), rec.predictor.clone()))
            .or_default() += 1;
        *per_node.entry(rec.node).or_default() += 1;
    }
    let all_nodes = cluster.nodes();
    for node in &all_nodes {
        node.engine.drain_shadows();
    }
    let mut got: BTreeMap<(String, String), u64> = BTreeMap::new();
    for node in &all_nodes {
        ensure!(
            node.engine.lake.forced_overwrites() == 0,
            "node {}: lake forced an overwrite (storm exceeds capacity?)",
            node.id
        );
        ensure!(
            node.engine.lake.lost_appends() == 0,
            "node {}: lake lost an append",
            node.id
        );
        for ((tenant, predictor, shadow), n) in node.engine.lake.counts() {
            if !shadow {
                *got.entry((tenant, predictor)).or_default() += n as u64;
            }
        }
    }
    ensure!(
        got == expect,
        "cluster lake multiset diverges from driver tallies:\n  lake:   {got:?}\n  driver: {expect:?}"
    );

    // Lifecycle arithmetic.
    let stats = cluster.stats();
    ensure!(stats.crashes == 1, "expected exactly 1 crash, got {}", stats.crashes);
    ensure!(
        stats.joins == (cfg.nodes + 1) as u64,
        "expected {} joins, got {}",
        cfg.nodes + 1,
        stats.joins
    );
    ensure!(stats.aborted == 0, "unexpected aborted publish(es): {}", stats.aborted);
    ensure!(
        stats.publishes == final_epoch,
        "publishes {} != committed epoch {final_epoch}",
        stats.publishes
    );
    let serving = cluster.serving_nodes().len();
    ensure!(
        serving == cfg.nodes,
        "expected {} serving nodes at the end (crash + join), got {serving}",
        cfg.nodes
    );

    let events_total = recs.len() as u64;
    Ok(ClusterStormReport {
        nodes_initial: cfg.nodes,
        nodes_serving_final: serving,
        calls_total: cfg.calls as u64,
        events_total,
        retries,
        promotions: cfg.promotions as u64,
        committed_epoch: final_epoch,
        crashes: stats.crashes,
        joins: stats.joins,
        wall_secs,
        events_per_sec: events_total as f64 / wall_secs.max(1e-9),
        per_node_events: per_node.into_iter().collect(),
        flip_p50_ms: cluster.flip_percentile_ms(50.0),
        flip_p99_ms: cluster.flip_percentile_ms(99.0),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn storm_is_seamless_and_conserves_every_event() {
        let fix = SimArtifacts::in_temp().unwrap();
        let cfg = ClusterStormConfig {
            nodes: 4,
            tenants: 3,
            calls: 400,
            promotions: 6,
            threads: 3,
            ack_timeout: Duration::from_millis(250),
            ..ClusterStormConfig::default()
        };
        let report = run_cluster_storm(&fix, &cfg).unwrap();
        assert_eq!(report.calls_total, 400);
        assert!(report.events_total >= 400);
        assert_eq!(report.crashes, 1);
        assert_eq!(report.joins, 5);
        assert_eq!(report.nodes_serving_final, 4);
        // 6 rotations: 6 deploys + 6 promotes + decommissions for
        // every version that reached v >= 2.
        assert!(report.committed_epoch >= 12);
        assert!(report.events_per_sec > 0.0);
        let rendered = report.render();
        assert!(rendered.contains("cluster storm (4 nodes"), "{rendered}");
        assert!(rendered.contains("1 crash(es)"), "{rendered}");
    }
}
