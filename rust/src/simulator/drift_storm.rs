//! Drift-storm scenario: prove the lifecycle autopilot end to end.
//!
//! A tenant's traffic runs steady-state long enough for the autopilot
//! to install its initial custom `T^Q`, then the score distribution is
//! shifted mid-run (fraud wave: attack rate jumps and flips to the P1
//! pattern — the "adversarial distributions drift fast" scenario from
//! the related calibration-stability work). The scenario then drives
//! traffic and controller ticks only — **zero manual control-plane
//! calls** — and measures the tenant's observed alert rate at a fixed
//! reference threshold in three windows:
//!
//! 1. **before** the storm (calibrated steady state),
//! 2. **during** it (old `T^Q`, shifted scores → alert rate blows up),
//! 3. **after** the autopilot has detected the drift, refit `T^Q`
//!    from its post-drift sketch, shadow-validated and promoted the
//!    candidate (alert rate restored).
//!
//! The acceptance bar (ROADMAP / ISSUE 3): `after` is within 10%
//! relative error of the target alert rate, with ≥ 1 autonomous
//! promotion. The test below runs against the synthetic sim-dialect
//! artifacts (`runtime::simfix`), so it executes everywhere —
//! including CI, where `make artifacts` never ran.

use crate::config::Intent;
use crate::coordinator::{Engine, ScoreRequest};
use crate::simulator::workload::{TenantProfile, Workload};
use anyhow::{anyhow, bail, ensure, Context, Result};

/// Scenario parameters (defaults match the CI smoke run).
#[derive(Debug, Clone)]
pub struct DriftStormConfig {
    pub tenant: String,
    /// Events per `score_batch` call (one controller tick per batch).
    pub batch_size: usize,
    /// Max batches to wait for the initial calibration fit.
    pub calibration_batches: usize,
    /// Batches per alert-rate measurement window.
    pub measure_batches: usize,
    /// Max storm batches for detect → refit → validate → promote.
    pub recovery_batches: usize,
    pub seed: u64,
}

impl Default for DriftStormConfig {
    fn default() -> Self {
        DriftStormConfig {
            tenant: "acme".to_string(),
            batch_size: 256,
            calibration_batches: 90,
            measure_batches: 50,
            recovery_batches: 110,
            seed: 42,
        }
    }
}

/// Scenario outcome.
#[derive(Debug, Clone)]
pub struct DriftStormReport {
    pub target_alert_rate: f64,
    pub alert_before: f64,
    pub alert_during: f64,
    pub alert_after: f64,
    /// |observed - target| / target per window.
    pub rel_err_before: f64,
    pub rel_err_during: f64,
    pub rel_err_after: f64,
    pub fits: u64,
    pub promotions: u64,
    pub validation_failures: u64,
    /// Storm batches until the promotion landed.
    pub batches_to_recover: usize,
    pub events_total: u64,
    /// Predictor serving the tenant when the scenario ended.
    pub final_predictor: String,
}

impl DriftStormReport {
    pub fn render(&self) -> String {
        format!(
            "drift storm (target alert rate {:.3}):\n  \
             before : alert {:.4} (rel err {:>6.1}%)\n  \
             during : alert {:.4} (rel err {:>6.1}%)\n  \
             after  : alert {:.4} (rel err {:>6.1}%)\n  \
             fits {} | promotions {} | validation failures {} | \
             recovered in {} storm batches | {} events | live: {}",
            self.target_alert_rate,
            self.alert_before,
            100.0 * self.rel_err_before,
            self.alert_during,
            100.0 * self.rel_err_during,
            self.alert_after,
            100.0 * self.rel_err_after,
            self.fits,
            self.promotions,
            self.validation_failures,
            self.batches_to_recover,
            self.events_total,
            self.final_predictor,
        )
    }
}

/// Steady-state tenant profile.
fn baseline_profile(cfg: &DriftStormConfig) -> TenantProfile {
    TenantProfile::new(&cfg.tenant, cfg.seed, 0.3, 0.1)
}

/// The storm: same covariate transform (same seed / shift scale), but
/// the attack rate jumps 1.5% → 25% and shifts to the P1 pattern —
/// a deterministic, strongly-directional score-distribution shift.
fn drifted_profile(cfg: &DriftStormConfig) -> TenantProfile {
    TenantProfile::new(&cfg.tenant, cfg.seed, 0.3, 0.6).with_fraud_rate(0.25)
}

struct Driver<'e> {
    engine: &'e Engine,
    tenant: String,
    batch_size: usize,
    tau: f64,
    events: u64,
    batch_no: u64,
}

impl Driver<'_> {
    /// Drive one batch through `score_batch`, returning the number of
    /// responses at or above the alert threshold.
    fn drive(&mut self, wl: &mut Workload) -> Result<usize> {
        let reqs: Vec<ScoreRequest> = (0..self.batch_size)
            .map(|i| ScoreRequest {
                intent: Intent {
                    tenant: self.tenant.clone(),
                    ..Intent::default()
                },
                entity: format!("ds{}-{}", self.batch_no, i),
                features: wl.next_event().features,
            })
            .collect();
        let resps = self.engine.score_batch(&reqs).context("drift-storm batch")?;
        self.events += resps.len() as u64;
        self.batch_no += 1;
        Ok(resps.iter().filter(|r| r.score >= self.tau).count())
    }
}

/// Run the scenario. `engine` must have `lifecycle.enabled: true` and
/// manage `cfg.tenant`; nothing else is assumed. The only control
/// inputs the scenario ever issues are [`crate::lifecycle::LifecycleHub::tick`]
/// calls — the cadence the background controller thread or
/// `POST /v1/lifecycle/check` would provide in production.
pub fn run_drift_storm(engine: &Engine, cfg: &DriftStormConfig) -> Result<DriftStormReport> {
    let hub = engine
        .lifecycle
        .as_ref()
        .ok_or_else(|| anyhow!("drift storm needs lifecycle.enabled: true"))?;
    ensure!(cfg.batch_size >= 1, "batch_size must be >= 1");
    let target = hub.config().alert_rate;

    // Alert threshold: the reference distribution's (1 - a) quantile.
    // After a correct fit, final scores follow the reference, so the
    // observed alert rate at tau must equal the target rate.
    let live0 = engine
        .router
        .resolve(&Intent {
            tenant: cfg.tenant.clone(),
            ..Intent::default()
        })
        .context("resolve scenario tenant")?
        .live
        .to_string();
    let reference = match engine.registry.config(&live0) {
        Some(pc) => Engine::reference(&pc.reference),
        None => Engine::reference("fraud-default"),
    };
    let grid = reference.quantile_grid(4097);
    let tau = grid[((1.0 - target) * 4096.0).round() as usize];

    let mut driver = Driver {
        engine,
        tenant: cfg.tenant.clone(),
        batch_size: cfg.batch_size,
        tau,
        events: 0,
        batch_no: 0,
    };
    let pair = |hub: &crate::lifecycle::LifecycleHub| -> Result<crate::lifecycle::PairStatus> {
        hub.status()
            .into_iter()
            .find(|p| p.tenant == cfg.tenant)
            .ok_or_else(|| anyhow!("autopilot is not tracking tenant '{}'", cfg.tenant))
    };

    // Phase 0 — calibration: traffic flows until the autopilot's
    // initial custom T^Q lands (Eq. 5-gated sketch fit).
    let mut wl = Workload::new(baseline_profile(cfg), cfg.seed);
    let mut calibrated = false;
    for _ in 0..cfg.calibration_batches {
        driver.drive(&mut wl)?;
        hub.tick(engine)?;
        if pair(hub)?.fits >= 1 {
            calibrated = true;
            break;
        }
    }
    if !calibrated {
        bail!(
            "no initial fit within {} calibration batches: {:?}",
            cfg.calibration_batches,
            pair(hub)?
        );
    }

    // Phase 1 — steady state: measure the calibrated alert rate. The
    // controller keeps ticking (and must not false-alarm).
    let mut alerts = 0usize;
    for _ in 0..cfg.measure_batches {
        alerts += driver.drive(&mut wl)?;
        hub.tick(engine)?;
    }
    let n_measure = (cfg.measure_batches * cfg.batch_size) as f64;
    let alert_before = alerts as f64 / n_measure;
    let promotions_baseline = pair(hub)?.promotions;
    ensure!(
        promotions_baseline == 0 && pair(hub)?.state == crate::lifecycle::LifecycleState::Observing,
        "autopilot acted during steady state: {:?}",
        pair(hub)?
    );

    // Phase 2 — the storm: shift the distribution and keep driving.
    // The autopilot must detect, refit from its sketch, shadow-deploy,
    // validate against mirrored traffic and promote — autonomously.
    let mut storm = Workload::new(drifted_profile(cfg), cfg.seed ^ 0x5707);
    let mut storm_alerts = 0usize;
    let mut storm_events = 0usize;
    let mut batches_to_recover = 0usize;
    let mut recovered = false;
    for b in 0..cfg.recovery_batches {
        storm_alerts += driver.drive(&mut storm)?;
        storm_events += cfg.batch_size;
        // Let shadow mirrors land before the tick validates them.
        engine.drain_shadows();
        hub.tick(engine)?;
        if pair(hub)?.promotions > 0 {
            batches_to_recover = b + 1;
            recovered = true;
            break;
        }
    }
    if !recovered {
        bail!(
            "no autonomous promotion within {} storm batches: {:?}",
            cfg.recovery_batches,
            pair(hub)?
        );
    }
    let alert_during = storm_alerts as f64 / storm_events as f64;
    // One extra tick finalizes Promoted → Observing (baseline rotate).
    hub.tick(engine)?;

    // Phase 3 — recovered: same drifted traffic, new T^Q.
    let mut alerts_after = 0usize;
    for _ in 0..cfg.measure_batches {
        alerts_after += driver.drive(&mut storm)?;
        hub.tick(engine)?;
    }
    let alert_after = alerts_after as f64 / n_measure;

    let status = pair(hub)?;
    let rel = |a: f64| (a - target).abs() / target;
    Ok(DriftStormReport {
        target_alert_rate: target,
        alert_before,
        alert_during,
        alert_after,
        rel_err_before: rel(alert_before),
        rel_err_during: rel(alert_during),
        rel_err_after: rel(alert_after),
        fits: status.fits,
        promotions: status.promotions,
        validation_failures: status.validation_failures,
        batches_to_recover,
        events_total: driver.events,
        final_predictor: status.predictor,
    })
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::config::MuseConfig;
    use crate::lifecycle::LifecycleState;
    use crate::runtime::{ModelPool, SimArtifacts};
    use std::sync::Arc;

    /// Engine over the synthetic sim-dialect artifacts — runs without
    /// `make artifacts`, deterministically, everywhere (incl. CI).
    pub(crate) fn sim_engine(extra_lifecycle: &str) -> (SimArtifacts, Arc<Engine>) {
        let fix = SimArtifacts::in_temp().unwrap();
        let yaml = format!(
            r#"
routing:
  scoringRules:
  - description: "acme dedicated"
    condition:
      tenants: ["acme"]
    targetPredictorName: "duo"
  - description: "catch-all"
    condition: {{}}
    targetPredictorName: "solo"
predictors:
- name: duo
  experts: [s1, s2]
  quantile: custom
- name: solo
  experts: [s3]
  quantile: identity
server:
  workers: 2
  maxBatchEvents: 1024
  lakeMaxRecords: 200000
lifecycle:
  enabled: true
  tenants: ["acme"]
  autoDiscover: false
  sketchK: 4096
  alertRate: 0.1
  minDriftSamples: 512
  minValidationSamples: 512
  cooldownTicks: 4
{extra_lifecycle}"#
        );
        let pool = Arc::new(ModelPool::new(fix.manifest().unwrap()));
        let engine =
            Arc::new(Engine::build(&MuseConfig::from_yaml(&yaml).unwrap(), pool).unwrap());
        (fix, engine)
    }

    #[test]
    fn drift_storm_autorecovers_alert_rates() {
        // The tentpole acceptance test: injected distribution shift,
        // zero manual control-plane calls, per-tenant alert rate back
        // within 10% relative error of target after auto-promotion.
        let (_fix, engine) = sim_engine("  delta: 0.05\n  validationTolerance: 0.08\n");
        let report = run_drift_storm(&engine, &DriftStormConfig::default()).unwrap();
        println!("{}", report.render());

        assert!(report.promotions >= 1, "no autonomous promotion");
        assert!(report.fits >= 2, "expected initial fit + ≥1 refit");
        assert_eq!(report.validation_failures, 0, "{report:?}");
        // Calibrated steady state hits the target.
        assert!(
            report.rel_err_before <= 0.10,
            "pre-storm alert rate off target: {report:?}"
        );
        // The storm visibly breaks the alert rate under the old T^Q...
        assert!(
            report.rel_err_during >= 0.5,
            "storm too weak to prove anything: {report:?}"
        );
        // ...and the autopilot restores it (the acceptance bar).
        assert!(
            report.rel_err_after <= 0.10,
            "post-recovery alert rate off target: {report:?}"
        );
        // The tenant was moved to an autopilot candidate.
        assert!(
            report.final_predictor.contains("--lc"),
            "tenant still on '{}'",
            report.final_predictor
        );
        // The replaced predictor was decommissioned (no rule kept it).
        assert!(engine.registry.get("duo").is_none());
        engine.drain_shadows();
    }

    #[test]
    fn failed_validation_never_promotes() {
        // Satellite acceptance: shadow validation fails → candidate
        // torn down, no promote, state returns to Observing.
        // An impossible tolerance guarantees the failure; a lax delta
        // keeps the refit cheap (fit quality is irrelevant here).
        let (_fix, engine) = sim_engine("  delta: 0.2\n  validationTolerance: 0.000001\n");
        let hub = engine.lifecycle.as_ref().unwrap();
        let cfg = DriftStormConfig::default();
        let mut driver_wl = Workload::new(baseline_profile(&cfg), cfg.seed);
        let drive = |wl: &mut Workload| {
            let reqs: Vec<ScoreRequest> = (0..cfg.batch_size)
                .map(|i| ScoreRequest {
                    intent: Intent {
                        tenant: "acme".into(),
                        ..Intent::default()
                    },
                    entity: format!("v{i}"),
                    features: wl.next_event().features,
                })
                .collect();
            engine.score_batch(&reqs).unwrap();
        };
        let pair = || {
            hub.status()
                .into_iter()
                .find(|p| p.tenant == "acme")
                .unwrap()
        };

        // Calibrate (initial fit installs directly, no shadow).
        for _ in 0..cfg.calibration_batches {
            drive(&mut driver_wl);
            hub.tick(&engine).unwrap();
            if pair().fits >= 1 {
                break;
            }
        }
        assert_eq!(pair().fits, 1, "calibration never fit: {:?}", pair());
        assert!(pair().baseline_frozen);

        // Storm until the candidate is shadow-deployed.
        let mut storm = Workload::new(drifted_profile(&cfg), cfg.seed ^ 0x5707);
        let mut saw_shadow = false;
        for _ in 0..cfg.recovery_batches {
            drive(&mut storm);
            engine.drain_shadows();
            hub.tick(&engine).unwrap();
            let p = pair();
            if p.state == LifecycleState::ShadowDeployed {
                saw_shadow = true;
                assert!(p.shadow.is_some());
            }
            if p.validation_failures > 0 {
                break;
            }
        }
        assert!(saw_shadow, "never reached ShadowDeployed: {:?}", pair());
        let p = pair();
        assert_eq!(p.validation_failures, 1, "{p:?}");
        assert_eq!(p.promotions, 0, "promoted despite failed validation");
        assert_eq!(p.state, LifecycleState::Observing, "{p:?}");
        assert!(p.shadow.is_none(), "failed candidate not cleared: {p:?}");
        // Routing untouched; the candidate is gone from the registry.
        let res = engine
            .router
            .resolve(&Intent {
                tenant: "acme".into(),
                ..Intent::default()
            })
            .unwrap();
        assert_eq!(&*res.live, "duo");
        assert!(res.shadows.is_empty(), "shadow rule survived teardown");
        assert!(
            engine.registry.names().iter().all(|n| !n.contains("--lc")),
            "candidate predictor survived: {:?}",
            engine.registry.names()
        );
        engine.drain_shadows();
    }
}
