//! Multi-tenant batch-scoring throughput scenario.
//!
//! Drives a weighted [`TrafficMix`] of tenants through
//! `Engine::score_batch` in fixed-size batches — the workload shape of
//! an upstream stream-processor flushing windows into the scoring
//! tier — and reports end-to-end events/s plus the observed per-tenant
//! split, cross-checked against the engine's batch-aware per-tenant
//! `tenant_events` counters (the `scored_events` object in `/metrics`)
//! so the metrics surface is exercised by the same run. Used by the
//! artifact-gated test below and by `benches/serving_bench.rs`
//! ("batch scoring" section).

use crate::config::Intent;
use crate::coordinator::{Engine, ScoreRequest};
use crate::simulator::workload::{TenantProfile, TrafficMix, Workload};
use anyhow::{ensure, Result};
use std::collections::BTreeMap;
use std::time::Instant;

/// Scenario parameters.
#[derive(Debug, Clone)]
pub struct BatchMixConfig {
    /// (tenant profile, traffic weight) pairs.
    pub tenants: Vec<(TenantProfile, f64)>,
    /// Events per `score_batch` call.
    pub batch_size: usize,
    /// Number of batches to drive.
    pub batches: usize,
    pub seed: u64,
}

/// Scenario outcome.
#[derive(Debug, Clone)]
pub struct BatchMixReport {
    pub events: u64,
    pub batches: u64,
    /// Events scored per tenant (from the scenario's own accounting).
    pub per_tenant: BTreeMap<String, u64>,
    pub wall_secs: f64,
    pub events_per_sec: f64,
}

/// Run the scenario against a live engine. Every batch mixes tenants
/// according to the weights; the engine groups each batch by intent
/// internally, so this also stresses the route-once-per-group path.
pub fn run_batch_mix(engine: &Engine, cfg: &BatchMixConfig) -> Result<BatchMixReport> {
    ensure!(!cfg.tenants.is_empty(), "need >= 1 tenant");
    ensure!(cfg.batch_size >= 1, "batch_size must be >= 1");
    let workloads: Vec<Workload> = cfg
        .tenants
        .iter()
        .map(|(t, _)| Workload::new(t.clone(), cfg.seed))
        .collect();
    let weights: Vec<f64> = cfg.tenants.iter().map(|(_, w)| *w).collect();
    let mut mix = TrafficMix::new(workloads, weights, cfg.seed);

    let mut per_tenant: BTreeMap<String, u64> = BTreeMap::new();
    let mut events = 0u64;
    let counters_before: BTreeMap<String, u64> = engine.scored_events_snapshot();
    let t0 = Instant::now();
    let mut reqs: Vec<ScoreRequest> = Vec::with_capacity(cfg.batch_size);
    for b in 0..cfg.batches {
        reqs.clear();
        for i in 0..cfg.batch_size {
            let (tenant, event) = mix.next_event();
            *per_tenant.entry(tenant.clone()).or_insert(0) += 1;
            reqs.push(ScoreRequest {
                intent: Intent {
                    tenant,
                    ..Intent::default()
                },
                entity: format!("b{b}-{i}"),
                features: event.features,
            });
        }
        let resps = engine.score_batch(&reqs)?;
        ensure!(resps.len() == reqs.len(), "response count mismatch");
        events += resps.len() as u64;
    }
    let wall_secs = t0.elapsed().as_secs_f64();

    // The `/metrics` contract: the per-tenant batch counters must have
    // moved by exactly what this run scored (batch-aware accounting).
    for (tenant, n) in &per_tenant {
        let before = counters_before.get(tenant).copied().unwrap_or(0);
        let after = engine.scored_events(tenant);
        ensure!(
            after - before == *n,
            "scored_events[{tenant}] moved by {} for {n} scored events",
            after - before
        );
    }

    Ok(BatchMixReport {
        events,
        batches: cfg.batches as u64,
        per_tenant,
        wall_secs,
        events_per_sec: events as f64 / wall_secs.max(1e-9),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MuseConfig;
    use crate::runtime::{Manifest, ModelPool};
    use std::path::PathBuf;
    use std::sync::Arc;

    const CONFIG: &str = r#"
routing:
  scoringRules:
  - description: "bank1 dedicated"
    condition:
      tenants: ["bank1"]
    targetPredictorName: "duo"
  - description: "catch-all"
    condition: {}
    targetPredictorName: "solo"
predictors:
- name: duo
  experts: [m1, m2]
  quantile: identity
- name: solo
  experts: [m1]
  quantile: identity
server:
  workers: 2
  maxBatchEvents: 256
"#;

    #[test]
    fn batch_mix_splits_traffic_and_counts_it() {
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !root.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let pool = Arc::new(ModelPool::new(Manifest::load(root).unwrap()));
        let engine = Engine::build(&MuseConfig::from_yaml(CONFIG).unwrap(), pool).unwrap();
        let cfg = BatchMixConfig {
            tenants: vec![
                (TenantProfile::new("bank1", 1, 0.3, 0.1), 3.0),
                (TenantProfile::new("bank2", 2, 0.3, 0.1), 1.0),
            ],
            batch_size: 32,
            batches: 8,
            seed: 42,
        };
        let report = run_batch_mix(&engine, &cfg).unwrap();
        assert_eq!(report.events, 256);
        assert_eq!(report.batches, 8);
        let total: u64 = report.per_tenant.values().sum();
        assert_eq!(total, 256);
        // 3:1 weighting: bank1 must dominate (loose bound, seeded RNG).
        assert!(report.per_tenant["bank1"] > report.per_tenant["bank2"]);
        assert!(report.events_per_sec > 0.0);
        engine.drain_shadows();
    }
}
