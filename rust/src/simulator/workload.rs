//! Rust-native synthetic transaction stream, mirroring
//! `python/compile/datagen.py` (same feature layout, fraud patterns
//! and tenant-shift model; seeds differ since the RNGs differ).
//!
//! Used for live-traffic generation in the serving benches and the
//! Fig. 5 cluster simulation; the *figure* experiments replay the
//! python-generated binary datasets so the models see exactly their
//! training-time distribution family.

use crate::util::rng::Rng;

pub const FEATURE_DIM: usize = 24;
pub const FRAUD_PRIOR: f64 = 0.015;
const AMOUNT_DIM: usize = FEATURE_DIM - 1;
const CORR: f32 = 0.35;
const P0_SHIFT: f32 = 1.15;
const P1_SHIFT: f32 = 1.25;
const P1_ECHO: f32 = 0.25;

/// Per-tenant covariate shift (x -> scale * x + shift), mirroring
/// `datagen.TenantProfile`.
#[derive(Debug, Clone)]
pub struct TenantProfile {
    pub name: String,
    pub seed: u64,
    pub shift_scale: f64,
    pub scale_jitter: f64,
    pub fraud_rate: f64,
    /// Fraction of fraud that is the "new attack" pattern P1.
    pub pattern1_frac: f64,
    shift: Vec<f32>,
    scale: Vec<f32>,
}

impl TenantProfile {
    pub fn new(name: &str, seed: u64, shift_scale: f64, pattern1_frac: f64) -> TenantProfile {
        let mut rng = Rng::new(seed);
        let mut shift: Vec<f32> = (0..FEATURE_DIM)
            .map(|_| (rng.normal() * shift_scale) as f32)
            .collect();
        let mut scale: Vec<f32> = (0..FEATURE_DIM)
            .map(|_| (1.0 + rng.normal() * 0.12).abs() as f32)
            .collect();
        shift[AMOUNT_DIM] *= 0.25;
        scale[AMOUNT_DIM] = 1.0;
        TenantProfile {
            name: name.to_string(),
            seed,
            shift_scale,
            scale_jitter: 0.12,
            fraud_rate: FRAUD_PRIOR,
            pattern1_frac,
            shift,
            scale,
        }
    }

    pub fn with_fraud_rate(mut self, rate: f64) -> Self {
        self.fraud_rate = rate;
        self
    }
}

/// One generated event.
#[derive(Debug, Clone)]
pub struct Event {
    pub features: Vec<f32>,
    pub is_fraud: bool,
}

/// Stream generator for one tenant.
pub struct Workload {
    tenant: TenantProfile,
    rng: Rng,
}

impl Workload {
    pub fn new(tenant: TenantProfile, seed: u64) -> Workload {
        Workload {
            rng: Rng::new(seed ^ tenant.seed.rotate_left(17)),
            tenant,
        }
    }

    pub fn tenant_name(&self) -> &str {
        &self.tenant.name
    }

    /// Generate the next event.
    pub fn next_event(&mut self) -> Event {
        let rng = &mut self.rng;
        let is_fraud = rng.bernoulli(self.tenant.fraud_rate);
        // Correlated Gaussian background.
        let mut z = [0.0f32; FEATURE_DIM];
        for v in z.iter_mut() {
            *v = rng.normal() as f32;
        }
        let mut x = z;
        for i in 1..FEATURE_DIM {
            x[i] += CORR * z[i - 1];
        }
        x[AMOUNT_DIM] = (rng.lognormal(3.2, 1.1) / 100.0) as f32;
        if is_fraud {
            let jitter = (1.0 + rng.normal() * 0.25) as f32;
            if rng.bernoulli(self.tenant.pattern1_frac) {
                for i in 8..16 {
                    x[i] += P1_SHIFT * jitter;
                }
                for i in 0..4 {
                    x[i] += P1_ECHO * jitter;
                }
            } else {
                for i in 0..8 {
                    x[i] += P0_SHIFT * jitter;
                }
            }
            x[AMOUNT_DIM] *= rng.lognormal(0.35, 0.3) as f32;
        }
        // Tenant affine shift.
        let features = x
            .iter()
            .zip(self.tenant.scale.iter())
            .zip(self.tenant.shift.iter())
            .map(|((v, s), b)| v * s + b)
            .collect();
        Event { features, is_fraud }
    }

    /// Generate a row-major feature matrix (n x FEATURE_DIM) + labels.
    pub fn batch(&mut self, n: usize) -> (Vec<f32>, Vec<f32>) {
        let mut features = Vec::with_capacity(n * FEATURE_DIM);
        let mut labels = Vec::with_capacity(n);
        for _ in 0..n {
            let e = self.next_event();
            features.extend_from_slice(&e.features);
            labels.push(if e.is_fraud { 1.0 } else { 0.0 });
        }
        (features, labels)
    }
}

/// A multi-tenant traffic mix with weighted tenant selection.
pub struct TrafficMix {
    workloads: Vec<Workload>,
    weights: Vec<f64>,
    rng: Rng,
}

impl TrafficMix {
    pub fn new(workloads: Vec<Workload>, weights: Vec<f64>, seed: u64) -> TrafficMix {
        assert_eq!(workloads.len(), weights.len());
        assert!(!workloads.is_empty());
        TrafficMix {
            workloads,
            weights,
            rng: Rng::new(seed),
        }
    }

    /// Uniform mix.
    pub fn uniform(workloads: Vec<Workload>, seed: u64) -> TrafficMix {
        let n = workloads.len();
        TrafficMix::new(workloads, vec![1.0; n], seed)
    }

    /// Sample the next (tenant_name, event).
    pub fn next_event(&mut self) -> (String, Event) {
        let total: f64 = self.weights.iter().sum();
        let mut pick = self.rng.f64() * total;
        let mut idx = 0;
        for (i, w) in self.weights.iter().enumerate() {
            if pick < *w {
                idx = i;
                break;
            }
            pick -= w;
            idx = i;
        }
        let name = self.workloads[idx].tenant_name().to_string();
        (name, self.workloads[idx].next_event())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let t = TenantProfile::new("a", 1, 0.4, 0.1);
        let mut w1 = Workload::new(t.clone(), 7);
        let mut w2 = Workload::new(t, 7);
        for _ in 0..50 {
            assert_eq!(w1.next_event().features, w2.next_event().features);
        }
    }

    #[test]
    fn fraud_rate_matches_profile() {
        let t = TenantProfile::new("a", 2, 0.4, 0.0).with_fraud_rate(0.05);
        let mut w = Workload::new(t, 9);
        let (_, labels) = w.batch(100_000);
        let rate = labels.iter().sum::<f32>() as f64 / 100_000.0;
        assert!((rate - 0.05).abs() < 0.005, "rate {rate}");
    }

    #[test]
    fn fraud_is_separable_on_pattern_dims() {
        let t = TenantProfile::new("a", 3, 0.0, 0.0);
        let mut w = Workload::new(t, 1);
        let (feats, labels) = w.batch(50_000);
        let mut fraud_mean = 0.0;
        let mut legit_mean = 0.0;
        let (mut nf, mut nl) = (0.0, 0.0);
        for (i, &y) in labels.iter().enumerate() {
            let row = &feats[i * FEATURE_DIM..(i + 1) * FEATURE_DIM];
            let m: f32 = row[..8].iter().sum::<f32>() / 8.0;
            if y > 0.5 {
                fraud_mean += m as f64;
                nf += 1.0;
            } else {
                legit_mean += m as f64;
                nl += 1.0;
            }
        }
        assert!(fraud_mean / nf - legit_mean / nl > 0.5);
    }

    #[test]
    fn pattern1_moves_different_dims() {
        let t = TenantProfile::new("a", 4, 0.0, 1.0);
        let mut w = Workload::new(t, 2);
        let (feats, labels) = w.batch(50_000);
        let mut d_hi = 0.0;
        let mut n = 0.0;
        for (i, &y) in labels.iter().enumerate() {
            if y > 0.5 {
                let row = &feats[i * FEATURE_DIM..(i + 1) * FEATURE_DIM];
                d_hi += row[8..16].iter().sum::<f32>() as f64 / 8.0;
                n += 1.0;
            }
        }
        assert!(d_hi / n > 0.8, "P1 shift missing: {}", d_hi / n);
    }

    #[test]
    fn tenants_have_distinct_distributions() {
        let mut wa = Workload::new(TenantProfile::new("a", 10, 0.6, 0.0), 1);
        let mut wb = Workload::new(TenantProfile::new("b", 20, 0.6, 0.0), 1);
        let (fa, _) = wa.batch(10_000);
        let (fb, _) = wb.batch(10_000);
        let mean = |f: &[f32], d: usize| -> f64 {
            (0..10_000).map(|i| f[i * FEATURE_DIM + d] as f64).sum::<f64>() / 10_000.0
        };
        let max_gap = (0..FEATURE_DIM)
            .map(|d| (mean(&fa, d) - mean(&fb, d)).abs())
            .fold(0.0f64, f64::max);
        assert!(max_gap > 0.3, "tenants too similar: {max_gap}");
    }

    #[test]
    fn traffic_mix_samples_all_tenants() {
        let mix_tenants = vec![
            Workload::new(TenantProfile::new("a", 1, 0.3, 0.0), 1),
            Workload::new(TenantProfile::new("b", 2, 0.3, 0.0), 2),
        ];
        let mut mix = TrafficMix::uniform(mix_tenants, 5);
        let mut counts = std::collections::HashMap::new();
        for _ in 0..1000 {
            let (name, e) = mix.next_event();
            assert_eq!(e.features.len(), FEATURE_DIM);
            *counts.entry(name).or_insert(0) += 1;
        }
        assert!(counts["a"] > 300 && counts["b"] > 300);
    }
}
