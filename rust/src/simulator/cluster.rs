//! Cluster-level update scenarios. Two substrates live here:
//!
//! 1. a discrete-time cluster simulator: Kubernetes-style rolling
//!    updates with pod warm-up — the substrate for reproducing Fig. 5
//!    (and its no-warm-up ablation);
//! 2. a real-thread swap-under-load harness ([`swap_storm`]): N worker
//!    threads resolve intents through a live [`Router`] while the
//!    control plane runs continuous promotions, proving that config
//!    swaps never stall, drop, or tear a request (paper Section
//!    2.5.1-2.5.2; the lock-free mechanics are in `util::swap`).
//!
//! The paper's mechanism: Java pods suffer JIT-compilation latencies
//! on first execution, so before a pod is `ready` a warm-up subprocess
//! drives ~50 req/s of synthetic traffic at it; rolling updates keep a
//! minimum replica count while swapping transformation versions.
//!
//! Model:
//! * request latency = base lognormal x cold_factor(pod), where
//!   cold_factor decays exponentially in the number of requests the
//!   pod has served (the "first-touch cost" regime);
//! * rolling update: maxSurge=1, maxUnavailable=0 — spawn one new pod,
//!   warm it (50 req/s for `warmup_secs`), mark ready, terminate one
//!   old pod, repeat;
//! * live traffic: Poisson arrivals split uniformly over ready pods.
//!
//! Everything runs in simulated time — no sleeping.

use crate::config::{Condition, Intent, RoutingConfig, ScoringRule, ShadowRule};
use crate::coordinator::Router;
use crate::metrics::{LatencyHistogram, Series};
use crate::util::rng::Rng;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PodPhase {
    WarmingUp,
    Ready,
    Terminated,
}

#[derive(Debug, Clone)]
pub struct Pod {
    pub version: u32,
    pub phase: PodPhase,
    pub requests_served: u64,
    pub warmup_until: f64,
}

/// Latency model parameters (ns scale kept in ms for readability).
#[derive(Debug, Clone, Copy)]
pub struct LatencyModel {
    /// Median warm latency in ms.
    pub base_ms: f64,
    /// Lognormal sigma of the warm latency.
    pub sigma: f64,
    /// Cold multiplier at zero requests served (JIT penalty).
    pub cold_multiplier: f64,
    /// Requests to decay the cold penalty by 1/e.
    pub cold_decay_requests: f64,
}

impl Default for LatencyModel {
    fn default() -> Self {
        LatencyModel {
            base_ms: 4.0,
            sigma: 0.25,
            cold_multiplier: 10.0, // first requests ~40ms: SLO-violating
            cold_decay_requests: 2_000.0,
        }
    }
}

#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub replicas: usize,
    /// Live traffic rate (events/s) across the deployment.
    pub live_rps: f64,
    /// Warm-up driver rate per pod (the paper's ~50 req/s spikes).
    pub warmup_rps: f64,
    /// Warm-up duration per pod (the paper's 15-minute procedure).
    pub warmup_secs: f64,
    /// Measurement window for the output series.
    pub window_secs: f64,
    pub latency: LatencyModel,
    /// Disable warm-up (ablation): pods go ready cold.
    pub skip_warmup: bool,
    pub seed: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            replicas: 6,
            live_rps: 300.0,
            warmup_rps: 50.0,
            warmup_secs: 900.0,
            window_secs: 60.0,
            latency: LatencyModel::default(),
            skip_warmup: false,
            seed: 42,
        }
    }
}

/// Output of a simulated timeline: per-window series + SLO summary.
pub struct RolloutTrace {
    pub pod_count: Series,
    pub warmup_rps: Series,
    pub p99_5_ms: Series,
    pub p99_99_ms: Series,
    pub overall: LatencyHistogram,
    /// Share of windows whose p99.5 exceeded 30ms.
    pub slo_violation_windows: usize,
    pub windows: usize,
}

pub struct ClusterSim {
    cfg: ClusterConfig,
    pods: Vec<Pod>,
    rng: Rng,
    time: f64,
}

impl ClusterSim {
    pub fn new(cfg: ClusterConfig) -> ClusterSim {
        let pods = (0..cfg.replicas)
            .map(|_| Pod {
                version: 1,
                phase: PodPhase::Ready,
                // Baseline pods are long-running and fully warm.
                requests_served: 1_000_000,
                warmup_until: 0.0,
            })
            .collect();
        ClusterSim {
            rng: Rng::new(cfg.seed),
            cfg,
            pods,
            time: 0.0,
        }
    }

    fn sample_latency_ms(rng: &mut Rng, m: &LatencyModel, served: u64) -> f64 {
        let warm = rng.lognormal(m.base_ms.ln(), m.sigma);
        let cold = 1.0 + (m.cold_multiplier - 1.0) * (-(served as f64) / m.cold_decay_requests).exp();
        warm * cold
    }

    /// Run a rolling update from version 1 to version 2 and return the
    /// full trace: `pre_secs` of steady state, the rollout, then
    /// `post_secs` of steady state.
    pub fn rolling_update(&mut self, pre_secs: f64, post_secs: f64) -> RolloutTrace {
        let w = self.cfg.window_secs;
        let mut pod_count = Series::new("pods", w);
        let mut warmup_rps = Series::new("warmup_rps", w);
        let mut p99_5 = Series::new("p99.5_ms", w);
        let mut p99_99 = Series::new("p99.99_ms", w);
        let overall = LatencyHistogram::new();
        let mut violations = 0usize;

        // Rollout plan: replace pods one at a time (surge +1).
        let mut to_replace = self.cfg.replicas;
        let mut surge_pod: Option<usize> = None;
        let rollout_start = pre_secs;

        let window_hist = LatencyHistogram::new();
        let mut window_end = w;
        let mut window_warmup_reqs = 0u64;

        // Estimate total duration.
        let per_pod = if self.cfg.skip_warmup {
            10.0 // pod start latency only
        } else {
            self.cfg.warmup_secs + 10.0
        };
        let total = pre_secs + per_pod * self.cfg.replicas as f64 + post_secs;

        let dt = 1.0; // 1-second steps
        while self.time < total {
            self.time += dt;

            // --- control plane ---
            if self.time >= rollout_start && to_replace > 0 {
                match surge_pod {
                    None => {
                        // Spawn the surge pod (new version).
                        self.pods.push(Pod {
                            version: 2,
                            phase: if self.cfg.skip_warmup {
                                PodPhase::Ready
                            } else {
                                PodPhase::WarmingUp
                            },
                            requests_served: 0,
                            warmup_until: self.time + self.cfg.warmup_secs,
                        });
                        surge_pod = Some(self.pods.len() - 1);
                    }
                    Some(idx) => {
                        let finished = self.cfg.skip_warmup
                            || self.time >= self.pods[idx].warmup_until;
                        if self.pods[idx].phase == PodPhase::WarmingUp && finished {
                            self.pods[idx].phase = PodPhase::Ready;
                        }
                        if self.pods[idx].phase == PodPhase::Ready {
                            // Terminate one old-version pod.
                            if let Some(old) = self
                                .pods
                                .iter()
                                .position(|p| p.version == 1 && p.phase == PodPhase::Ready)
                            {
                                self.pods[old].phase = PodPhase::Terminated;
                            }
                            to_replace -= 1;
                            surge_pod = None;
                        }
                    }
                }
            }

            // --- warm-up traffic (per warming pod) ---
            for pod in self.pods.iter_mut() {
                if pod.phase == PodPhase::WarmingUp {
                    let reqs = poisson_count(&mut self.rng, self.cfg.warmup_rps * dt);
                    pod.requests_served += reqs;
                    window_warmup_reqs += reqs;
                }
            }

            // --- live traffic over ready pods ---
            let ready: Vec<usize> = self
                .pods
                .iter()
                .enumerate()
                .filter(|(_, p)| p.phase == PodPhase::Ready)
                .map(|(i, _)| i)
                .collect();
            if !ready.is_empty() {
                let arrivals = poisson_count(&mut self.rng, self.cfg.live_rps * dt);
                for _ in 0..arrivals {
                    let pod_idx = ready[self.rng.below(ready.len())];
                    let pod = &mut self.pods[pod_idx];
                    let lat_ms = Self::sample_latency_ms(
                        &mut self.rng,
                        &self.cfg.latency,
                        pod.requests_served,
                    );
                    pod.requests_served += 1;
                    let ns = (lat_ms * 1e6) as u64;
                    window_hist.record(ns);
                    overall.record(ns);
                }
            }

            // --- window rollover ---
            if self.time >= window_end {
                let live_pods = self
                    .pods
                    .iter()
                    .filter(|p| p.phase != PodPhase::Terminated)
                    .count();
                pod_count.push(live_pods as f64);
                warmup_rps.push(window_warmup_reqs as f64 / w);
                let p995 = window_hist.percentile_ns(99.5) as f64 / 1e6;
                let p9999 = window_hist.percentile_ns(99.99) as f64 / 1e6;
                p99_5.push(p995);
                p99_99.push(p9999);
                if p995 > 30.0 {
                    violations += 1;
                }
                window_hist.reset();
                window_warmup_reqs = 0;
                window_end += w;
            }
        }

        let windows = p99_5.values.len();
        RolloutTrace {
            pod_count,
            warmup_rps,
            p99_5_ms: p99_5,
            p99_99_ms: p99_99,
            overall,
            slo_violation_windows: violations,
            windows,
        }
    }
}

/// Configuration for the real-thread swap-under-load scenario.
#[derive(Debug, Clone)]
pub struct SwapStormConfig {
    /// Worker threads resolving intents (the data plane).
    pub workers: usize,
    /// Resolutions each worker performs.
    pub requests_per_worker: usize,
    /// Promotions the control-plane thread publishes while workers
    /// run (it keeps swapping until every worker finishes, so the
    /// whole run is under storm; this is the minimum count).
    pub min_swaps: usize,
    /// Scoring rules in the table (routing work per resolution).
    pub rules: usize,
}

impl Default for SwapStormConfig {
    fn default() -> Self {
        SwapStormConfig {
            workers: 4,
            requests_per_worker: 20_000,
            min_swaps: 1_000,
            rules: 32,
        }
    }
}

/// Outcome of a swap storm. The acceptance bar for seamless updates:
/// `errors == 0` (no dropped requests), `torn == 0` (every resolution
/// saw one coherent config), and a bounded `max_resolve_ns` (no
/// stalls while promotions were publishing).
#[derive(Debug, Clone)]
pub struct SwapStormReport {
    pub resolutions: u64,
    pub errors: u64,
    /// Resolutions that mixed two config versions (must be 0).
    pub torn: u64,
    pub swaps: u64,
    /// Worst single resolve latency observed by any worker.
    pub max_resolve_ns: u64,
    pub wall_secs: f64,
}

impl SwapStormReport {
    pub fn throughput_per_s(&self) -> f64 {
        self.resolutions as f64 / self.wall_secs.max(1e-9)
    }

    /// Zero dropped, zero stalled-beyond-`stall_budget_ns`, zero torn.
    pub fn seamless(&self, stall_budget_ns: u64) -> bool {
        self.errors == 0 && self.torn == 0 && self.max_resolve_ns <= stall_budget_ns
    }
}

/// Routing table for storm version `k`: a hot tenant rule, `rules`
/// cold tenant rules, a catch-all, and a shadow rule — every target
/// tagged with the version so a torn read is detectable.
fn storm_config(k: u64, rules: usize) -> RoutingConfig {
    let mut scoring: Vec<ScoringRule> = vec![ScoringRule {
        description: "hot tenant".into(),
        condition: Condition {
            tenants: vec!["hot".into()],
            ..Condition::default()
        },
        target_predictor: format!("live-v{k}").into(),
    }];
    scoring.extend((0..rules).map(|i| ScoringRule {
        description: format!("tenant {i}"),
        condition: Condition {
            tenants: vec![format!("tenant-{i}")],
            ..Condition::default()
        },
        target_predictor: format!("p{}-v{k}", i % 7).into(),
    }));
    scoring.push(ScoringRule {
        description: "catch-all".into(),
        condition: Condition::default(),
        target_predictor: format!("global-v{k}").into(),
    });
    RoutingConfig {
        scoring_rules: scoring,
        shadow_rules: vec![ShadowRule {
            description: "hot shadow".into(),
            condition: Condition {
                tenants: vec!["hot".into()],
                ..Condition::default()
            },
            target_predictors: vec![format!("shadow-v{k}").into()],
        }],
    }
}

fn storm_version(name: &str) -> &str {
    name.rsplit("-v").next().unwrap_or("")
}

/// Run the swap-under-load scenario: `workers` threads resolve a mix
/// of hot/cold/catch-all intents through one shared [`Router`] while a
/// control-plane thread publishes promotions continuously. Real
/// threads, real clock — this is the operational proof behind the
/// "seamless model updates" claim, run as a tier-1 test and printed
/// by `benches/routing_bench.rs`.
pub fn swap_storm(cfg: &SwapStormConfig) -> SwapStormReport {
    let router = Arc::new(Router::new(storm_config(0, cfg.rules)));
    let live_workers = Arc::new(AtomicU64::new(cfg.workers as u64));
    let swaps = Arc::new(AtomicU64::new(0));
    let errors = Arc::new(AtomicU64::new(0));
    let torn = Arc::new(AtomicU64::new(0));
    let max_ns = Arc::new(AtomicU64::new(0));
    let resolutions = Arc::new(AtomicU64::new(0));
    let t0 = Instant::now();

    std::thread::scope(|s| {
        // Control plane: promote for as long as any worker is running
        // (and at least `min_swaps` times), so the whole measurement
        // window is under storm.
        {
            let router = Arc::clone(&router);
            let live_workers = Arc::clone(&live_workers);
            let swaps = Arc::clone(&swaps);
            let min_swaps = cfg.min_swaps as u64;
            let rules = cfg.rules;
            s.spawn(move || {
                let mut k = 0u64;
                while live_workers.load(Ordering::Relaxed) > 0
                    || swaps.load(Ordering::Relaxed) < min_swaps
                {
                    k += 1;
                    router.swap(storm_config(k, rules));
                    swaps.fetch_add(1, Ordering::Relaxed);
                }
            });
        }
        // Data plane workers.
        for w in 0..cfg.workers {
            let router = Arc::clone(&router);
            let live_workers = Arc::clone(&live_workers);
            let errors = Arc::clone(&errors);
            let torn = Arc::clone(&torn);
            let max_ns = Arc::clone(&max_ns);
            let resolutions = Arc::clone(&resolutions);
            let n = cfg.requests_per_worker;
            let rules = cfg.rules;
            s.spawn(move || {
                let mut rng = Rng::new(0x5707u64 ^ w as u64);
                let mut worst = 0u64;
                let mut done = 0u64;
                for i in 0..n {
                    let intent = match i % 3 {
                        0 => Intent {
                            tenant: "hot".into(),
                            ..Intent::default()
                        },
                        1 => Intent {
                            tenant: format!("tenant-{}", rng.below(rules.max(1))),
                            ..Intent::default()
                        },
                        _ => Intent {
                            tenant: "unmatched".into(),
                            ..Intent::default()
                        },
                    };
                    let t = Instant::now();
                    match router.resolve(&intent) {
                        Ok(res) => {
                            done += 1;
                            // Tear check: hot resolutions carry the
                            // version on both live and shadow targets.
                            if !res.shadows.is_empty()
                                && storm_version(&res.live) != storm_version(&res.shadows[0])
                            {
                                torn.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        Err(_) => {
                            errors.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    worst = worst.max(t.elapsed().as_nanos() as u64);
                }
                resolutions.fetch_add(done, Ordering::Relaxed);
                max_ns.fetch_max(worst, Ordering::Relaxed);
                live_workers.fetch_sub(1, Ordering::Relaxed);
            });
        }
    });

    SwapStormReport {
        resolutions: resolutions.load(Ordering::Relaxed),
        errors: errors.load(Ordering::Relaxed),
        torn: torn.load(Ordering::Relaxed),
        swaps: swaps.load(Ordering::Relaxed),
        max_resolve_ns: max_ns.load(Ordering::Relaxed),
        wall_secs: t0.elapsed().as_secs_f64(),
    }
}

fn poisson_count(rng: &mut Rng, mean: f64) -> u64 {
    // Knuth for small means, normal approximation for large.
    if mean <= 0.0 {
        return 0;
    }
    if mean > 50.0 {
        let v = rng.normal_ms(mean, mean.sqrt()).round();
        return v.max(0.0) as u64;
    }
    let l = (-mean).exp();
    let mut k = 0u64;
    let mut p = 1.0;
    loop {
        p *= rng.f64();
        if p <= l {
            return k;
        }
        k += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg(skip_warmup: bool) -> ClusterConfig {
        ClusterConfig {
            replicas: 4,
            live_rps: 200.0,
            warmup_rps: 50.0,
            warmup_secs: 120.0,
            window_secs: 30.0,
            skip_warmup,
            seed: 7,
            ..ClusterConfig::default()
        }
    }

    #[test]
    fn with_warmup_latency_stays_under_slo() {
        let mut sim = ClusterSim::new(quick_cfg(false));
        let trace = sim.rolling_update(120.0, 120.0);
        assert!(trace.windows > 5);
        assert_eq!(
            trace.slo_violation_windows, 0,
            "warm rollout must hold p99.5 < 30ms; got {} violations (max p99.5 {:.1}ms)",
            trace.slo_violation_windows,
            trace.p99_5_ms.max()
        );
    }

    #[test]
    fn without_warmup_latency_spikes() {
        let mut sim = ClusterSim::new(quick_cfg(true));
        let trace = sim.rolling_update(120.0, 120.0);
        assert!(
            trace.slo_violation_windows > 0,
            "cold pods must violate the SLO (ablation); max p99.5 {:.1}ms",
            trace.p99_5_ms.max()
        );
    }

    #[test]
    fn pod_count_surges_and_returns() {
        let mut sim = ClusterSim::new(quick_cfg(false));
        let trace = sim.rolling_update(120.0, 120.0);
        assert_eq!(trace.pod_count.values[0], 4.0, "baseline replicas");
        assert!(trace.pod_count.max() > 4.0, "surge pod visible");
        assert_eq!(*trace.pod_count.values.last().unwrap(), 4.0, "returns to baseline");
    }

    #[test]
    fn warmup_traffic_visible_only_during_rollout() {
        let mut sim = ClusterSim::new(quick_cfg(false));
        let trace = sim.rolling_update(120.0, 180.0);
        assert_eq!(trace.warmup_rps.values[0], 0.0, "no warmup pre-rollout");
        assert!(trace.warmup_rps.max() > 10.0, "warmup spikes up to ~50 req/s");
        assert_eq!(*trace.warmup_rps.values.last().unwrap(), 0.0, "quiet after");
    }

    #[test]
    fn all_pods_replaced() {
        let cfg = quick_cfg(false);
        let mut sim = ClusterSim::new(cfg);
        let _ = sim.rolling_update(60.0, 60.0);
        let v2_ready = sim
            .pods
            .iter()
            .filter(|p| p.version == 2 && p.phase == PodPhase::Ready)
            .count();
        assert_eq!(v2_ready, 4, "every replica must be on the new version");
        assert!(sim
            .pods
            .iter()
            .all(|p| p.version == 2 || p.phase == PodPhase::Terminated));
    }

    #[test]
    fn poisson_mean_is_right() {
        let mut rng = Rng::new(1);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| poisson_count(&mut rng, 3.5) as f64).sum::<f64>() / n as f64;
        assert!((mean - 3.5).abs() < 0.1, "mean {mean}");
        let big: f64 = (0..2000).map(|_| poisson_count(&mut rng, 300.0) as f64).sum::<f64>() / 2000.0;
        assert!((big - 300.0).abs() < 5.0, "big mean {big}");
    }

    #[test]
    fn swap_storm_is_seamless() {
        // The acceptance bar for the lock-free snapshot path: a
        // continuous promotion storm while 4 workers resolve must
        // drop nothing, stall nothing, tear nothing.
        let report = swap_storm(&SwapStormConfig {
            workers: 4,
            requests_per_worker: 10_000,
            min_swaps: 500,
            rules: 16,
        });
        assert_eq!(report.errors, 0, "dropped requests during swaps");
        assert_eq!(report.torn, 0, "torn config observed");
        assert!(report.swaps >= 500, "storm too quiet: {} swaps", report.swaps);
        assert_eq!(report.resolutions, 40_000);
        // A deliberately generous stall budget (1s) so an
        // oversubscribed CI scheduler cannot flake the test: the
        // property being pinned is "no unbounded reader stall", which
        // a reader blocked behind a crashed/slow writer would hit.
        // Typical max latency here is microseconds (see
        // EXPERIMENTS.md "Contention").
        assert!(
            report.seamless(1_000_000_000),
            "max resolve latency {}ns under storm",
            report.max_resolve_ns
        );
    }

    #[test]
    fn swap_storm_reports_throughput() {
        let report = swap_storm(&SwapStormConfig {
            workers: 2,
            requests_per_worker: 2_000,
            min_swaps: 50,
            rules: 8,
        });
        assert!(report.throughput_per_s() > 0.0);
        assert!(report.wall_secs > 0.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let t1 = ClusterSim::new(quick_cfg(false)).rolling_update(60.0, 60.0);
        let t2 = ClusterSim::new(quick_cfg(false)).rolling_update(60.0, 60.0);
        assert_eq!(t1.pod_count.values, t2.pod_count.values);
        assert_eq!(t1.p99_5_ms.values, t2.p99_5_ms.values);
    }
}
