//! The feature store (paper Section 2.5.1): "once the appropriate
//! predictor is selected, the system may enrich the request by
//! querying a feature store for any additional model-specific features
//! not included in the initial payload", enabling "easy feature
//! evolution" — models with heterogeneous feature sets served
//! simultaneously without client changes.
//!
//! Here: an in-memory KV of entity -> derived features, plus an
//! enrichment step that pads/joins a partial payload up to a model's
//! full feature dimension.

use anyhow::{ensure, Result};
use std::collections::HashMap;
use std::sync::RwLock;

/// In-memory feature store keyed by entity id (e.g. card hash).
#[derive(Default)]
pub struct FeatureStore {
    derived: RwLock<HashMap<String, Vec<f32>>>,
    /// Global fallback for unseen entities (e.g. population means).
    fallback: RwLock<Vec<f32>>,
}

impl FeatureStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Install/overwrite derived features for an entity.
    pub fn put(&self, entity: &str, features: Vec<f32>) {
        self.derived
            .write()
            .unwrap()
            .insert(entity.to_string(), features);
    }

    /// Set the fallback vector used for unseen entities.
    pub fn set_fallback(&self, features: Vec<f32>) {
        *self.fallback.write().unwrap() = features;
    }

    pub fn len(&self) -> usize {
        self.derived.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Enrich a partial payload to exactly `target_dim` features:
    /// payload features come first, the remainder is joined from the
    /// entity's derived features (or the fallback, or zeros).
    ///
    /// Errors if the payload alone is already wider than the target
    /// (schema mismatch the router should have caught).
    pub fn enrich(&self, entity: &str, payload: &[f32], target_dim: usize) -> Result<Vec<f32>> {
        ensure!(
            payload.len() <= target_dim,
            "payload has {} features but model expects {target_dim}",
            payload.len()
        );
        let mut out = Vec::with_capacity(target_dim);
        out.extend_from_slice(payload);
        let need = target_dim - payload.len();
        if need == 0 {
            return Ok(out);
        }
        let derived = self.derived.read().unwrap();
        if let Some(d) = derived.get(entity) {
            out.extend(d.iter().take(need).cloned());
        }
        if out.len() < target_dim {
            let fb = self.fallback.read().unwrap();
            let have = out.len() - payload.len();
            out.extend(fb.iter().skip(have).take(target_dim - out.len()).cloned());
        }
        out.resize(target_dim, 0.0);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_payload_passthrough() {
        let fs = FeatureStore::new();
        let payload = vec![1.0, 2.0, 3.0];
        assert_eq!(fs.enrich("e", &payload, 3).unwrap(), payload);
    }

    #[test]
    fn joins_derived_features() {
        let fs = FeatureStore::new();
        fs.put("card-1", vec![9.0, 8.0, 7.0]);
        let out = fs.enrich("card-1", &[1.0, 2.0], 4).unwrap();
        assert_eq!(out, vec![1.0, 2.0, 9.0, 8.0]);
    }

    #[test]
    fn fallback_for_unseen_entities() {
        let fs = FeatureStore::new();
        fs.set_fallback(vec![0.5, 0.5, 0.5, 0.5]);
        let out = fs.enrich("unknown", &[1.0], 3).unwrap();
        assert_eq!(out, vec![1.0, 0.5, 0.5]);
    }

    #[test]
    fn zero_pads_when_nothing_known() {
        let fs = FeatureStore::new();
        let out = fs.enrich("unknown", &[1.0], 4).unwrap();
        assert_eq!(out, vec![1.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn partial_derived_plus_fallback() {
        let fs = FeatureStore::new();
        fs.put("e", vec![9.0]); // only one derived feature
        fs.set_fallback(vec![0.1, 0.2, 0.3]);
        let out = fs.enrich("e", &[1.0], 4).unwrap();
        // payload(1) + derived(1) + fallback skipping the 1 already
        // provided by derived.
        assert_eq!(out, vec![1.0, 9.0, 0.2, 0.3]);
    }

    #[test]
    fn oversized_payload_is_schema_error() {
        let fs = FeatureStore::new();
        assert!(fs.enrich("e", &[0.0; 5], 3).is_err());
    }

    #[test]
    fn concurrent_access() {
        use std::sync::Arc;
        let fs = Arc::new(FeatureStore::new());
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let fs = Arc::clone(&fs);
                std::thread::spawn(move || {
                    for i in 0..200 {
                        fs.put(&format!("e{t}-{i}"), vec![t as f32]);
                        let _ = fs.enrich(&format!("e{t}-{i}"), &[0.0], 2).unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(fs.len(), 1600);
    }
}
