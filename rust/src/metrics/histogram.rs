//! Log-bucketed latency histogram (HdrHistogram-style, from scratch)
//! for the paper's SLO percentiles (p99 < 30ms, p99.9 < 150ms,
//! p99.99 tracked in Fig. 5).
//!
//! Fixed memory, O(1) record, percentiles accurate to ~1% relative
//! error: buckets are arranged as 64 power-of-two tiers x 32 linear
//! sub-buckets covering 1ns .. ~18s of microsecond-scale latencies.

use std::sync::atomic::{AtomicU64, Ordering};

const SUB_BITS: u32 = 5; // 32 sub-buckets per power-of-two tier
const SUB: usize = 1 << SUB_BITS;
const TIERS: usize = 40; // covers values up to 2^(40+5) ns ~ 9.7 hours
const BUCKETS: usize = TIERS * SUB;

/// Lock-free recording histogram for u64 values (nanoseconds).
pub struct LatencyHistogram {
    counts: Vec<AtomicU64>,
    total: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

#[inline]
fn bucket_index(value: u64) -> usize {
    let v = value.max(1);
    let msb = 63 - v.leading_zeros();
    if msb < SUB_BITS {
        return v as usize; // values < 32 map linearly
    }
    let tier = (msb - SUB_BITS + 1) as usize;
    let sub = ((v >> (msb - SUB_BITS)) & ((SUB as u64) - 1)) as usize;
    (tier * SUB + sub).min(BUCKETS - 1)
}

/// Representative (midpoint) value for a bucket index.
fn bucket_value(index: usize) -> u64 {
    if index < SUB {
        return index as u64;
    }
    let tier = index / SUB;
    let sub = (index % SUB) as u64;
    let base = 1u64 << (tier as u32 + SUB_BITS - 1);
    let width = base / SUB as u64;
    base + sub * width + width / 2
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram {
            counts: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            total: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Record one value (ns). Lock-free; safe from many threads.
    #[inline]
    pub fn record(&self, value_ns: u64) {
        self.counts[bucket_index(value_ns)].fetch_add(1, Ordering::Relaxed);
        self.total.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(value_ns, Ordering::Relaxed);
        self.max.fetch_max(value_ns, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    pub fn mean_ns(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    pub fn max_ns(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Percentile in [0, 100]; returns the representative value of the
    /// bucket containing that rank (exact max for p=100).
    pub fn percentile_ns(&self, p: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        if p >= 100.0 {
            return self.max_ns();
        }
        let target = ((p / 100.0) * n as f64).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            acc += c.load(Ordering::Relaxed);
            if acc >= target {
                return bucket_value(i);
            }
        }
        self.max_ns()
    }

    /// Reset all counters (between benchmark phases).
    pub fn reset(&self) {
        for c in &self.counts {
            c.store(0, Ordering::Relaxed);
        }
        self.total.store(0, Ordering::Relaxed);
        self.sum.store(0, Ordering::Relaxed);
        self.max.store(0, Ordering::Relaxed);
    }

    /// Render the standard SLO summary line used by the harnesses.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.3}ms p50={:.3}ms p99={:.3}ms p99.5={:.3}ms p99.9={:.3}ms p99.99={:.3}ms max={:.3}ms",
            self.count(),
            self.mean_ns() / 1e6,
            self.percentile_ns(50.0) as f64 / 1e6,
            self.percentile_ns(99.0) as f64 / 1e6,
            self.percentile_ns(99.5) as f64 / 1e6,
            self.percentile_ns(99.9) as f64 / 1e6,
            self.percentile_ns(99.99) as f64 / 1e6,
            self.max_ns() as f64 / 1e6,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    #[test]
    fn bucket_roundtrip_relative_error() {
        for v in [1u64, 7, 31, 32, 100, 1_000, 50_000, 1_000_000, 30_000_000, 10_000_000_000] {
            let rep = bucket_value(bucket_index(v));
            let rel = (rep as f64 - v as f64).abs() / v as f64;
            assert!(rel < 0.04, "v={v} rep={rep} rel={rel}");
        }
    }

    #[test]
    fn small_values_exact() {
        for v in 0..32u64 {
            assert_eq!(bucket_value(bucket_index(v.max(1))), v.max(1));
        }
    }

    #[test]
    fn percentiles_of_uniform() {
        let h = LatencyHistogram::new();
        for v in 1..=10_000u64 {
            h.record(v * 1000); // 1..10000 us
        }
        let p50 = h.percentile_ns(50.0) as f64;
        let p99 = h.percentile_ns(99.0) as f64;
        assert!((p50 / 5_000_000.0 - 1.0).abs() < 0.05, "p50 {p50}");
        assert!((p99 / 9_900_000.0 - 1.0).abs() < 0.05, "p99 {p99}");
        assert_eq!(h.percentile_ns(100.0), 10_000_000);
    }

    #[test]
    fn empty_histogram() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile_ns(99.0), 0);
        assert_eq!(h.mean_ns(), 0.0);
    }

    #[test]
    fn mean_and_max() {
        let h = LatencyHistogram::new();
        h.record(100);
        h.record(200);
        h.record(600);
        assert_eq!(h.mean_ns(), 300.0);
        assert_eq!(h.max_ns(), 600);
    }

    #[test]
    fn reset_clears() {
        let h = LatencyHistogram::new();
        h.record(1234);
        h.reset();
        assert_eq!(h.count(), 0);
        assert_eq!(h.max_ns(), 0);
    }

    #[test]
    fn concurrent_recording() {
        let h = Arc::new(LatencyHistogram::new());
        let mut handles = vec![];
        for t in 0..8 {
            let h = Arc::clone(&h);
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(t);
                for _ in 0..10_000 {
                    h.record(1000 + rng.below(1_000_000) as u64);
                }
            }));
        }
        for j in handles {
            j.join().unwrap();
        }
        assert_eq!(h.count(), 80_000);
    }

    #[test]
    fn heavy_tail_percentiles_ordered() {
        let h = LatencyHistogram::new();
        let mut rng = Rng::new(5);
        for _ in 0..100_000 {
            let v = (rng.lognormal(13.0, 1.0)) as u64; // ~0.5ms median
            h.record(v);
        }
        let p50 = h.percentile_ns(50.0);
        let p99 = h.percentile_ns(99.0);
        let p999 = h.percentile_ns(99.9);
        assert!(p50 < p99 && p99 <= p999, "{p50} {p99} {p999}");
    }

    #[test]
    fn summary_formats() {
        let h = LatencyHistogram::new();
        h.record(2_000_000);
        let s = h.summary();
        assert!(s.contains("n=1") && s.contains("p99"), "{s}");
    }
}
