//! Serving metrics: lock-free latency histograms (SLO percentiles)
//! and wait-free named counters / time series for the control plane.
//! Hot paths bump pre-resolved [`CounterHandle`]s (one `fetch_add`,
//! no lock, no map probe); dynamic keys stay name-addressed through
//! the copy-on-write registry.

pub mod counters;
pub mod histogram;

pub use counters::{CounterHandle, Counters, Series, TenantCounters};
pub use histogram::LatencyHistogram;
