//! Serving metrics: lock-free latency histograms (SLO percentiles)
//! and named counters / time series for the control plane.

pub mod counters;
pub mod histogram;

pub use counters::{Counters, Series};
pub use histogram::LatencyHistogram;
