//! Serving counters + windowed time series (the Fig. 5 pod-count /
//! req-rate traces and the `/metrics` endpoint).
//!
//! The registry is wait-free on every established path: the name →
//! counter map is published copy-on-write through a
//! [`SnapCell`](crate::util::swap::SnapCell), so `inc`/`add`/`get` on
//! a key that already exists perform one wait-free snapshot load, one
//! map probe and one `fetch_add` — no mutex. Only the *first* touch of
//! a new key takes the cell's writer lock to republish the map
//! (control-plane rate). Hot keys go one step further:
//! [`Counters::handle`] resolves a name once — at engine build /
//! deploy time — into a [`CounterHandle`], a direct `Arc<AtomicU64>`
//! whose `inc` is a single `fetch_add` with no load and no probe at
//! all. The engine's per-event counters (`requests_live`, batch
//! counters, shadow-path counters) all go through pre-resolved
//! handles; the name-keyed map survives for cold/dynamic keys and for
//! `/metrics` rendering, which sees handle updates because handles
//! alias the map's own atomics.

use crate::util::slab::HandleSlab;
use crate::util::swap::SnapCell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// A pre-resolved counter: one atomic, shared with the registry map.
/// `Clone` is a refcount bump; `inc`/`add` are single `fetch_add`s —
/// the cheapest possible metrics write, suitable for per-event paths.
#[derive(Clone)]
pub struct CounterHandle(Arc<AtomicU64>);

impl CounterHandle {
    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, delta: u64) {
        self.0.fetch_add(delta, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    /// A fresh zeroed counter not (yet) bound to any name — the
    /// slab-backed registries intern these directly by handle index.
    fn zero() -> CounterHandle {
        CounterHandle(Arc::new(AtomicU64::new(0)))
    }
}

/// A set of named monotonically-increasing counters.
pub struct Counters {
    map: SnapCell<BTreeMap<String, Arc<AtomicU64>>>,
}

impl Default for Counters {
    fn default() -> Self {
        Self::new()
    }
}

impl Counters {
    pub fn new() -> Self {
        Counters {
            map: SnapCell::new(Arc::new(BTreeMap::new())),
        }
    }

    /// Resolve `name` into a direct handle, interning it (at zero) if
    /// new. Call once at deploy/build time; bump the handle on the hot
    /// path.
    pub fn handle(&self, name: &str) -> CounterHandle {
        if let Some(c) = self.map.load().get(name) {
            return CounterHandle(Arc::clone(c));
        }
        CounterHandle(self.intern(name))
    }

    pub fn inc(&self, name: &str) {
        self.add(name, 1);
    }

    /// Wait-free once `name` exists: snapshot load + probe +
    /// `fetch_add`. First touch interns the key copy-on-write.
    pub fn add(&self, name: &str, delta: u64) {
        if let Some(c) = self.map.load().get(name) {
            c.fetch_add(delta, Ordering::Relaxed);
            return;
        }
        self.intern(name).fetch_add(delta, Ordering::Relaxed);
    }

    /// Intern a new key (copy-on-write republish under the cell's
    /// writer lock; re-probes first so racing interners converge on
    /// one atomic).
    #[cold]
    fn intern(&self, name: &str) -> Arc<AtomicU64> {
        self.map.rcu(|old| {
            if let Some(c) = old.get(name) {
                return (Arc::clone(old), Arc::clone(c));
            }
            let counter = Arc::new(AtomicU64::new(0));
            let mut next = old.as_ref().clone();
            next.insert(name.to_string(), Arc::clone(&counter));
            (Arc::new(next), counter)
        })
    }

    pub fn get(&self, name: &str) -> u64 {
        self.map
            .load()
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Visit every counter in sorted key order **without cloning the
    /// map**: one wait-free snapshot load, then borrowed reads. This
    /// is the `/metrics` scrape path — at 100k keys the old
    /// `snapshot()` cloned every `String` per scrape; the visitor
    /// streams straight into the response writer.
    pub fn for_each(&self, mut f: impl FnMut(&str, u64)) {
        let snap = self.map.load();
        for (k, v) in snap.iter() {
            f(k, v.load(Ordering::Relaxed));
        }
    }

    /// Snapshot all counters into an owned map (test assertions and
    /// oracle models that want a value they can hold across
    /// mutations). Render paths should prefer [`Counters::for_each`].
    pub fn snapshot(&self) -> BTreeMap<String, u64> {
        let mut out = BTreeMap::new();
        self.for_each(|k, v| {
            out.insert(k.to_string(), v);
        });
        out
    }
}

/// Per-tenant event counters indexed by the dense
/// [`TenantHandle`](crate::coordinator::TenantHandle) index instead
/// of the tenant-name string. The old layout interned
/// `tenant_events` keys into the copy-on-write name map — the first
/// commit of tenant `n` cloned all `n-1` existing keys; at 100k
/// tenants that is the O(n²) onboarding storm. Here the first commit
/// publishes one constant-size slab segment, and established tenants
/// pay exactly what they did before: one pre-resolved `fetch_add`.
///
/// Name binding (for `/metrics` rendering and oracle diffs) lives
/// with the caller, who owns the interner — this type never touches
/// a string.
pub struct TenantCounters {
    slab: HandleSlab<CounterHandle>,
}

impl TenantCounters {
    /// A counter slab striped over `shards` shards.
    pub fn new(shards: usize) -> TenantCounters {
        TenantCounters {
            slab: HandleSlab::with_shards(shards),
        }
    }

    /// Resolve the counter for a tenant-handle index, interning it at
    /// zero on first touch (racing interners converge on one atomic).
    /// Call once per route; bump the returned handle on the hot path.
    pub fn handle(&self, index: usize) -> CounterHandle {
        self.slab.get_or_insert_with(index, CounterHandle::zero)
    }

    /// Current value at `index` (0 when never interned) — wait-free.
    pub fn get(&self, index: usize) -> u64 {
        self.slab.get(index).map(|c| c.get()).unwrap_or(0)
    }

    /// Visit every interned counter, shard by shard — the streaming
    /// `/metrics` iteration (no map clone, no allocation).
    pub fn for_each(&self, mut f: impl FnMut(usize, u64)) {
        self.slab.for_each(|i, c| f(i, c.get()));
    }

    /// Slab segments allocated (tsunami RSS accounting).
    pub fn segments_allocated(&self) -> usize {
        self.slab.segments_allocated()
    }
}

impl Default for TenantCounters {
    fn default() -> Self {
        TenantCounters::new(crate::coordinator::DEFAULT_NAME_SHARDS)
    }
}

/// A fixed-width time series: one f64 sample per window, used by the
/// Fig. 5 harness to plot pod counts / request rates / percentiles
/// over the rolling-update timeline.
#[derive(Debug, Clone, Default)]
pub struct Series {
    pub name: String,
    pub window_secs: f64,
    pub values: Vec<f64>,
}

impl Series {
    pub fn new(name: impl Into<String>, window_secs: f64) -> Self {
        Series {
            name: name.into(),
            window_secs,
            values: Vec::new(),
        }
    }

    pub fn push(&mut self, v: f64) {
        self.values.push(v);
    }

    pub fn max(&self) -> f64 {
        self.values.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn min(&self) -> f64 {
        self.values.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    /// Render as aligned "t=.. v=.." rows for the harness output.
    pub fn render_rows(&self) -> Vec<String> {
        self.values
            .iter()
            .enumerate()
            .map(|(i, v)| format!("t={:>7.1}s {}={:.3}", i as f64 * self.window_secs, self.name, v))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let c = Counters::new();
        c.inc("requests");
        c.add("requests", 4);
        c.inc("errors");
        assert_eq!(c.get("requests"), 5);
        assert_eq!(c.get("errors"), 1);
        assert_eq!(c.get("missing"), 0);
    }

    #[test]
    fn snapshot_is_sorted_and_complete() {
        let c = Counters::new();
        c.inc("b");
        c.inc("a");
        let snap = c.snapshot();
        let keys: Vec<&String> = snap.keys().collect();
        assert_eq!(keys, vec!["a", "b"]);
    }

    #[test]
    fn handles_alias_the_named_map() {
        let c = Counters::new();
        let h = c.handle("hot");
        h.inc();
        h.add(4);
        // Handle writes are visible through every name-keyed surface.
        assert_eq!(c.get("hot"), 5);
        assert_eq!(c.snapshot()["hot"], 5);
        assert_eq!(h.get(), 5);
        // And name-keyed writes are visible through the handle.
        c.add("hot", 10);
        assert_eq!(h.get(), 15);
        // Re-resolving yields the same underlying atomic.
        let h2 = c.handle("hot");
        h2.inc();
        assert_eq!(h.get(), 16);
    }

    #[test]
    fn handle_pre_registers_key_at_zero() {
        let c = Counters::new();
        let _h = c.handle("deployed");
        assert_eq!(c.snapshot().get("deployed"), Some(&0));
    }

    #[test]
    fn concurrent_increments() {
        use std::sync::Arc as StdArc;
        let c = StdArc::new(Counters::new());
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let c = StdArc::clone(&c);
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        c.inc("hits");
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.get("hits"), 8000);
    }

    #[test]
    fn concurrent_interning_never_loses_counts() {
        // 8 threads race first-touch interning across a disjoint +
        // shared key mix; every increment must land exactly once even
        // when the copy-on-write republish races.
        use std::sync::Arc as StdArc;
        let c = StdArc::new(Counters::new());
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let c = StdArc::clone(&c);
                std::thread::spawn(move || {
                    let h = c.handle("shared_handle");
                    for i in 0..500 {
                        c.inc("shared");
                        c.inc(&format!("own_{t}"));
                        h.inc();
                        if i == 0 {
                            c.inc(&format!("late_{t}"));
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.get("shared"), 4000);
        assert_eq!(c.get("shared_handle"), 4000);
        for t in 0..8 {
            assert_eq!(c.get(&format!("own_{t}")), 500);
            assert_eq!(c.get(&format!("late_{t}")), 1);
        }
    }

    #[test]
    fn for_each_agrees_with_snapshot_in_sorted_order() {
        let c = Counters::new();
        c.add("b", 2);
        c.inc("a");
        c.add("z", 9);
        let mut visited = Vec::new();
        c.for_each(|k, v| visited.push((k.to_string(), v)));
        // Sorted (BTreeMap order) and identical to the owned snapshot.
        assert_eq!(
            visited,
            c.snapshot().into_iter().collect::<Vec<_>>(),
            "visitor and snapshot must expose the same surface"
        );
        assert_eq!(visited[0].0, "a");
        assert_eq!(visited[2], ("z".to_string(), 9));
    }

    #[test]
    fn tenant_counters_index_by_handle_and_stream() {
        let t = TenantCounters::new(4);
        assert_eq!(t.get(3), 0);
        let h = t.handle(3);
        h.add(5);
        // Re-resolving lands on the same atomic.
        t.handle(3).inc();
        assert_eq!(t.get(3), 6);
        t.handle(900).add(2);
        let mut seen = Vec::new();
        t.for_each(|i, v| seen.push((i, v)));
        seen.sort_unstable();
        assert_eq!(seen, vec![(3, 6), (900, 2)]);
        assert!(t.segments_allocated() >= 1);
    }

    #[test]
    fn tenant_counters_concurrent_first_touch_loses_nothing() {
        use std::sync::Arc as StdArc;
        let t = StdArc::new(TenantCounters::new(8));
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let t = StdArc::clone(&t);
                std::thread::spawn(move || {
                    for i in 0..200 {
                        t.handle(i).inc();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        for i in 0..200 {
            assert_eq!(t.get(i), 8, "index {i}");
        }
    }

    #[test]
    fn series_stats() {
        let mut s = Series::new("pods", 10.0);
        for v in [6.0, 9.0, 12.0, 6.0] {
            s.push(v);
        }
        assert_eq!(s.max(), 12.0);
        assert_eq!(s.min(), 6.0);
        let rows = s.render_rows();
        assert_eq!(rows.len(), 4);
        assert!(rows[2].contains("t=   20.0s"));
    }
}
