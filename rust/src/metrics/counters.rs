//! Serving counters + windowed time series (the Fig. 5 pod-count /
//! req-rate traces and the `/metrics` endpoint).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// A set of named monotonically-increasing counters.
#[derive(Default)]
pub struct Counters {
    inner: Mutex<BTreeMap<String, AtomicU64>>,
}

impl Counters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&self, name: &str, delta: u64) {
        let mut map = self.inner.lock().unwrap();
        // Hot counters already exist: bump without allocating a key.
        if let Some(c) = map.get(name) {
            c.fetch_add(delta, Ordering::Relaxed);
            return;
        }
        map.entry(name.to_string())
            .or_insert_with(|| AtomicU64::new(0))
            .fetch_add(delta, Ordering::Relaxed);
    }

    pub fn get(&self, name: &str) -> u64 {
        self.inner
            .lock()
            .unwrap()
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Snapshot all counters (for `/metrics` and test assertions).
    pub fn snapshot(&self) -> BTreeMap<String, u64> {
        self.inner
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect()
    }
}

/// A fixed-width time series: one f64 sample per window, used by the
/// Fig. 5 harness to plot pod counts / request rates / percentiles
/// over the rolling-update timeline.
#[derive(Debug, Clone, Default)]
pub struct Series {
    pub name: String,
    pub window_secs: f64,
    pub values: Vec<f64>,
}

impl Series {
    pub fn new(name: impl Into<String>, window_secs: f64) -> Self {
        Series {
            name: name.into(),
            window_secs,
            values: Vec::new(),
        }
    }

    pub fn push(&mut self, v: f64) {
        self.values.push(v);
    }

    pub fn max(&self) -> f64 {
        self.values.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn min(&self) -> f64 {
        self.values.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    /// Render as aligned "t=.. v=.." rows for the harness output.
    pub fn render_rows(&self) -> Vec<String> {
        self.values
            .iter()
            .enumerate()
            .map(|(i, v)| format!("t={:>7.1}s {}={:.3}", i as f64 * self.window_secs, self.name, v))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let c = Counters::new();
        c.inc("requests");
        c.add("requests", 4);
        c.inc("errors");
        assert_eq!(c.get("requests"), 5);
        assert_eq!(c.get("errors"), 1);
        assert_eq!(c.get("missing"), 0);
    }

    #[test]
    fn snapshot_is_sorted_and_complete() {
        let c = Counters::new();
        c.inc("b");
        c.inc("a");
        let snap = c.snapshot();
        let keys: Vec<&String> = snap.keys().collect();
        assert_eq!(keys, vec!["a", "b"]);
    }

    #[test]
    fn concurrent_increments() {
        use std::sync::Arc;
        let c = Arc::new(Counters::new());
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let c = Arc::clone(&c);
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        c.inc("hits");
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.get("hits"), 8000);
    }

    #[test]
    fn series_stats() {
        let mut s = Series::new("pods", 10.0);
        for v in [6.0, 9.0, 12.0, 6.0] {
            s.push(v);
        }
        assert_eq!(s.max(), 12.0);
        assert_eq!(s.min(), 6.0);
        let rows = s.render_rows();
        assert_eq!(rows.len(), 4);
        assert!(rows[2].contains("t=   20.0s"));
    }
}
