//! The PJRT runtime: loads the HLO-text artifacts produced by the
//! Python compile path and executes them on dedicated model-container
//! threads. Python is never on this path.

pub mod container;
pub mod manifest;
pub mod pool;
pub mod simfix;

pub use container::{ModelContainer, ModelHandle};
pub use manifest::{Manifest, ModelSpec};
pub use pool::{ModelPool, PoolStats};
pub use simfix::SimArtifacts;
