//! Model containers: dedicated inference threads owning PJRT state.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`), so all
//! PJRT work for a model lives on one thread — which conveniently
//! mirrors the paper's architecture: lightweight orchestration in the
//! stateless serving layer, compute-intensive inference in dedicated
//! *Model Server* containers (Triton in the paper, a PJRT thread
//! here). A `ModelHandle` is the cheap, cloneable channel end the
//! coordinator uses; one container is shared by every predictor that
//! references the model (Section 2.2.1).

use super::manifest::ModelSpec;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;

/// An inference request: `features` is a row-major `[n, d]` buffer;
/// the container pads/chunks to the best batch variant. Shared
/// (`Arc`) so an ensemble fan-out ships one copy of the batch matrix
/// to all expert containers instead of one copy per expert.
struct InferJob {
    features: Arc<Vec<f32>>,
    n: usize,
    reply: mpsc::SyncSender<Result<Vec<f32>>>,
}

enum Msg {
    Infer(InferJob),
    Shutdown,
}

/// Cheap cloneable handle to a running model container.
#[derive(Clone)]
pub struct ModelHandle {
    pub name: String,
    pub feature_dim: usize,
    pub beta: f64,
    tx: mpsc::Sender<Msg>,
    infer_count: Arc<AtomicU64>,
}

/// A pending asynchronous inference (join with [`InferTicket::wait`]).
pub struct InferTicket {
    rx: mpsc::Receiver<Result<Vec<f32>>>,
    model: String,
    empty: bool,
}

impl InferTicket {
    pub fn wait(self) -> Result<Vec<f32>> {
        if self.empty {
            return Ok(vec![]);
        }
        self.rx
            .recv()
            .map_err(|_| anyhow!("model container '{}' dropped the reply", self.model))?
    }
}

impl ModelHandle {
    /// Score `n` events (row-major features, `n * feature_dim` long).
    /// Returns `n` raw scores in [0, 1]. Blocks until the container
    /// replies.
    pub fn infer(&self, features: &[f32], n: usize) -> Result<Vec<f32>> {
        self.infer_async(features, n)?.wait()
    }

    /// Enqueue an inference and return immediately; ensembles fan out
    /// to all expert containers concurrently and join (they are
    /// independent threads, so per-event service time is max over
    /// experts, not the sum — see EXPERIMENTS.md "Perf log").
    pub fn infer_async(&self, features: &[f32], n: usize) -> Result<InferTicket> {
        if n == 0 {
            let (_reply_tx, reply_rx) = mpsc::sync_channel(1);
            return Ok(InferTicket {
                rx: reply_rx,
                model: self.name.clone(),
                empty: true,
            });
        }
        self.infer_async_shared(Arc::new(features.to_vec()), n)
    }

    /// As [`ModelHandle::infer_async`], but the caller supplies the
    /// batch matrix behind an `Arc` — an ensemble fan-out builds it
    /// once and ships the same allocation to every expert container
    /// (the per-expert `to_vec` copy is gone from the batch path).
    pub fn infer_async_shared(&self, features: Arc<Vec<f32>>, n: usize) -> Result<InferTicket> {
        let (reply_tx, reply_rx) = mpsc::sync_channel(1);
        if n == 0 {
            return Ok(InferTicket {
                rx: reply_rx,
                model: self.name.clone(),
                empty: true,
            });
        }
        if features.len() != n * self.feature_dim {
            bail!(
                "model '{}': feature buffer is {} floats, expected {}x{}",
                self.name,
                features.len(),
                n,
                self.feature_dim
            );
        }
        self.tx
            .send(Msg::Infer(InferJob {
                features,
                n,
                reply: reply_tx,
            }))
            .map_err(|_| anyhow!("model container '{}' has shut down", self.name))?;
        self.infer_count.fetch_add(1, Ordering::Relaxed);
        Ok(InferTicket {
            rx: reply_rx,
            model: self.name.clone(),
            empty: false,
        })
    }

    /// Number of inference calls served (for the dedup accounting).
    pub fn infer_count(&self) -> u64 {
        self.infer_count.load(Ordering::Relaxed)
    }
}

/// A running model container (joinable). Dropping the container shuts
/// the thread down.
pub struct ModelContainer {
    pub handle: ModelHandle,
    thread: Option<thread::JoinHandle<()>>,
    tx: mpsc::Sender<Msg>,
}

impl ModelContainer {
    /// Spawn the container thread: creates its own PJRT CPU client,
    /// loads + compiles every batch variant of `spec`, then serves.
    /// Blocks until compilation finishes (so readiness is explicit,
    /// like a pod readiness gate).
    pub fn spawn(spec: &ModelSpec) -> Result<ModelContainer> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::sync_channel::<Result<()>>(1);
        let spec_clone = spec.clone();
        let thread = thread::Builder::new()
            .name(format!("model-{}", spec.name))
            .spawn(move || container_main(spec_clone, rx, ready_tx))
            .context("spawn model container thread")?;
        // Wait for compile-or-fail.
        ready_rx
            .recv()
            .map_err(|_| anyhow!("container '{}' died during startup", spec.name))??;
        let handle = ModelHandle {
            name: spec.name.clone(),
            feature_dim: spec.feature_dim,
            beta: spec.beta,
            tx: tx.clone(),
            infer_count: Arc::new(AtomicU64::new(0)),
        };
        Ok(ModelContainer {
            handle,
            thread: Some(thread),
            tx,
        })
    }
}

impl Drop for ModelContainer {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// The container thread body: PJRT client + per-batch executables.
fn container_main(
    spec: ModelSpec,
    rx: mpsc::Receiver<Msg>,
    ready: mpsc::SyncSender<Result<()>>,
) {
    let setup = (|| -> Result<(xla::PjRtClient, BTreeMap<usize, xla::PjRtLoadedExecutable>)> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        let mut execs = BTreeMap::new();
        for (&batch, path) in &spec.batches {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not UTF-8")?,
            )
            .map_err(|e| anyhow!("load {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))?;
            execs.insert(batch, exe);
        }
        Ok((client, execs))
    })();

    let (_client, execs) = match setup {
        Ok(ok) => {
            let _ = ready.send(Ok(()));
            ok
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    // Reusable padded input buffers per batch variant (hot path: no
    // allocation beyond the Literal the PJRT API requires).
    let mut pad_bufs: BTreeMap<usize, Vec<f32>> = execs
        .keys()
        .map(|&b| (b, vec![0.0f32; b * spec.feature_dim]))
        .collect();

    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Shutdown => break,
            Msg::Infer(job) => {
                let result = run_inference(&spec, &execs, &mut pad_bufs, &job);
                let _ = job.reply.send(result);
            }
        }
    }
}

fn run_inference(
    spec: &ModelSpec,
    execs: &BTreeMap<usize, xla::PjRtLoadedExecutable>,
    pad_bufs: &mut BTreeMap<usize, Vec<f32>>,
    job: &InferJob,
) -> Result<Vec<f32>> {
    let d = spec.feature_dim;
    let mut out = Vec::with_capacity(job.n);
    let max_batch = *execs.keys().max().expect("no variants");
    let mut off = 0usize;
    while off < job.n {
        let chunk = (job.n - off).min(max_batch);
        // Smallest variant that fits the chunk.
        let batch = *execs
            .keys()
            .find(|&&b| b >= chunk)
            .expect("max_batch covers chunk");
        let exe = &execs[&batch];
        let buf = pad_bufs.get_mut(&batch).expect("buffer per variant");
        buf[..chunk * d].copy_from_slice(&job.features[off * d..(off + chunk) * d]);
        for v in buf[chunk * d..].iter_mut() {
            *v = 0.0;
        }
        let literal = xla::Literal::vec1(buf)
            .reshape(&[batch as i64, d as i64])
            .map_err(|e| anyhow!("reshape input: {e:?}"))?;
        let result = exe
            .execute::<xla::Literal>(&[literal])
            .map_err(|e| anyhow!("execute '{}' b={batch}: {e:?}", spec.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let scores = lit
            .to_tuple1()
            .map_err(|e| anyhow!("untuple: {e:?}"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("to_vec: {e:?}"))?;
        out.extend_from_slice(&scores[..chunk]);
        off += chunk;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;
    use std::path::PathBuf;

    fn manifest() -> Option<Manifest> {
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if root.join("manifest.json").exists() {
            Some(Manifest::load(root).unwrap())
        } else {
            eprintln!("skipping: artifacts not built");
            None
        }
    }

    #[test]
    fn container_scores_events() {
        let Some(m) = manifest() else { return };
        let spec = m.model("m1").unwrap();
        let c = ModelContainer::spawn(spec).unwrap();
        let d = spec.feature_dim;
        let features = vec![0.1f32; 3 * d];
        let scores = c.handle.infer(&features, 3).unwrap();
        assert_eq!(scores.len(), 3);
        for s in &scores {
            assert!((0.0..=1.0).contains(s), "score {s}");
        }
        // Identical rows -> identical scores.
        assert!((scores[0] - scores[1]).abs() < 1e-6);
        assert_eq!(c.handle.infer_count(), 1);
    }

    #[test]
    fn batching_is_consistent_with_singles() {
        let Some(m) = manifest() else { return };
        let spec = m.model("m2").unwrap();
        let c = ModelContainer::spawn(spec).unwrap();
        let d = spec.feature_dim;
        let mut rng = crate::util::rng::Rng::new(3);
        let n = 40; // crosses batch variants 16 and 64 with padding
        let features: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let batched = c.handle.infer(&features, n).unwrap();
        for i in (0..n).step_by(7) {
            let single = c.handle.infer(&features[i * d..(i + 1) * d], 1).unwrap();
            assert!(
                (batched[i] - single[0]).abs() < 1e-5,
                "row {i}: batched {} vs single {}",
                batched[i],
                single[0]
            );
        }
    }

    #[test]
    fn oversized_requests_are_chunked() {
        let Some(m) = manifest() else { return };
        let spec = m.model("m1").unwrap();
        let c = ModelContainer::spawn(spec).unwrap();
        let d = spec.feature_dim;
        let n = 600; // > largest variant (256): forces chunking
        let features = vec![0.05f32; n * d];
        let scores = c.handle.infer(&features, n).unwrap();
        assert_eq!(scores.len(), n);
        let first = scores[0];
        assert!(scores.iter().all(|s| (s - first).abs() < 1e-6));
    }

    #[test]
    fn rejects_wrong_feature_len() {
        let Some(m) = manifest() else { return };
        let spec = m.model("m1").unwrap();
        let c = ModelContainer::spawn(spec).unwrap();
        assert!(c.handle.infer(&[0.0; 5], 1).is_err());
        assert_eq!(c.handle.infer(&[], 0).unwrap().len(), 0);
    }

    #[test]
    fn handle_survives_cross_thread_use() {
        let Some(m) = manifest() else { return };
        let spec = m.model("m1").unwrap();
        let c = ModelContainer::spawn(spec).unwrap();
        let d = spec.feature_dim;
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let h = c.handle.clone();
                std::thread::spawn(move || {
                    let features = vec![0.01f32 * t as f32; d];
                    h.infer(&features, 1).unwrap()[0]
                })
            })
            .collect();
        for h in handles {
            let s = h.join().unwrap();
            assert!((0.0..=1.0).contains(&s));
        }
    }
}
