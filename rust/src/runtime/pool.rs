//! The shared model-container pool — the mechanism behind the paper's
//! infrastructure-deduplication claim (Section 2.2.1).
//!
//! Predictors *reference* models; the pool owns at most one running
//! container per model and hands out refcounted handles. Deploying a
//! predictor provisions only the net-new models; decommissioning one
//! releases references, and containers with zero references are torn
//! down. `PoolStats` exposes the accounting that the `repro dedup`
//! harness compares against a KServe-style 1:1 baseline.

use super::container::{ModelContainer, ModelHandle};
use super::manifest::Manifest;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

struct Entry {
    container: ModelContainer,
    refs: usize,
}

/// Thread-safe pool of model containers keyed by model name.
pub struct ModelPool {
    manifest: Manifest,
    entries: Mutex<BTreeMap<String, Entry>>,
    /// Lifetime counter for the dedup accounting (atomic: `stats()`
    /// readers never contend with the entries lock for it).
    spawned_total: AtomicU64,
}

/// A snapshot of pool occupancy.
#[derive(Debug, Clone, PartialEq)]
pub struct PoolStats {
    pub live_containers: usize,
    pub total_references: usize,
    pub spawned_total: u64,
}

impl ModelPool {
    pub fn new(manifest: Manifest) -> Self {
        ModelPool {
            manifest,
            entries: Mutex::new(BTreeMap::new()),
            spawned_total: AtomicU64::new(0),
        }
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Acquire a handle to `model`, spawning the container on first
    /// reference (compile happens here — the "provisioning cost").
    pub fn acquire(&self, model: &str) -> Result<ModelHandle> {
        let mut entries = self.entries.lock().unwrap();
        if let Some(e) = entries.get_mut(model) {
            e.refs += 1;
            return Ok(e.container.handle.clone());
        }
        let spec = self
            .manifest
            .model(model)
            .with_context(|| format!("acquire unknown model '{model}'"))?;
        let container = ModelContainer::spawn(spec)?;
        let handle = container.handle.clone();
        entries.insert(model.to_string(), Entry { container, refs: 1 });
        self.spawned_total.fetch_add(1, Ordering::Relaxed);
        Ok(handle)
    }

    /// Release one reference; tears the container down at zero refs.
    /// Releasing an unknown model is a no-op (idempotent teardown).
    pub fn release(&self, model: &str) {
        let mut entries = self.entries.lock().unwrap();
        let drop_it = match entries.get_mut(model) {
            Some(e) => {
                e.refs = e.refs.saturating_sub(1);
                e.refs == 0
            }
            None => false,
        };
        if drop_it {
            entries.remove(model); // Drop joins the container thread.
        }
    }

    pub fn stats(&self) -> PoolStats {
        let entries = self.entries.lock().unwrap();
        PoolStats {
            live_containers: entries.len(),
            total_references: entries.values().map(|e| e.refs).sum(),
            spawned_total: self.spawned_total.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn pool() -> Option<ModelPool> {
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !root.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(ModelPool::new(Manifest::load(root).unwrap()))
    }

    #[test]
    fn containers_are_shared_not_duplicated() {
        let Some(pool) = pool() else { return };
        // Predictor p1 = {m1, m2}; p2 = {m1, m2, m3} (the paper's
        // Fig. 1 example): deploying p2 after p1 spawns only m3.
        let _p1 = (pool.acquire("m1").unwrap(), pool.acquire("m2").unwrap());
        let after_p1 = pool.stats();
        assert_eq!(after_p1.live_containers, 2);
        let _p2 = (
            pool.acquire("m1").unwrap(),
            pool.acquire("m2").unwrap(),
            pool.acquire("m3").unwrap(),
        );
        let after_p2 = pool.stats();
        assert_eq!(after_p2.live_containers, 3, "only m3 is net-new");
        assert_eq!(after_p2.spawned_total, 3);
        assert_eq!(after_p2.total_references, 5);
    }

    #[test]
    fn release_tears_down_at_zero_refs() {
        let Some(pool) = pool() else { return };
        let _h1 = pool.acquire("m1").unwrap();
        let _h2 = pool.acquire("m1").unwrap();
        assert_eq!(pool.stats().live_containers, 1);
        pool.release("m1");
        assert_eq!(pool.stats().live_containers, 1, "still one ref");
        pool.release("m1");
        assert_eq!(pool.stats().live_containers, 0);
        // Idempotent.
        pool.release("m1");
        assert_eq!(pool.stats().live_containers, 0);
    }

    #[test]
    fn reacquire_after_teardown_respawns() {
        let Some(pool) = pool() else { return };
        let h = pool.acquire("m4").unwrap();
        drop(h);
        pool.release("m4");
        assert_eq!(pool.stats().live_containers, 0);
        let h2 = pool.acquire("m4").unwrap();
        assert_eq!(pool.stats().live_containers, 1);
        assert_eq!(pool.stats().spawned_total, 2);
        let scores = h2.infer(&vec![0.0f32; h2.feature_dim], 1).unwrap();
        assert_eq!(scores.len(), 1);
    }

    #[test]
    fn unknown_model_is_error() {
        let Some(pool) = pool() else { return };
        assert!(pool.acquire("m99").is_err());
    }

    #[test]
    fn handles_usable_after_extra_acquire_release() {
        let Some(pool) = pool() else { return };
        let h = pool.acquire("m1").unwrap();
        let h2 = pool.acquire("m1").unwrap();
        pool.release("m1");
        // h (and h2) still valid: one reference remains.
        let s = h.infer(&vec![0.1f32; h.feature_dim], 1).unwrap();
        let s2 = h2.infer(&vec![0.1f32; h2.feature_dim], 1).unwrap();
        assert!((s[0] - s2[0]).abs() < 1e-7);
    }
}
