//! Synthetic artifact fixture: a self-contained `muse-sim-hlo v1`
//! artifact set generated at runtime, so the full serving stack —
//! containers, predictors, engine, HTTP, lifecycle autopilot — runs
//! **without** `make artifacts` (no Python, no network, no real HLO).
//!
//! The models are hand-built linear scorers over the simulator's
//! 24-dim transaction features (`simulator::workload`): each computes
//! `sigmoid(w·x + b)` with weight patterns aligned to the workload's
//! fraud signatures (P0 lifts dims 0–8, P1 lifts dims 8–16), so fraud
//! events score meaningfully higher than legit traffic and the score
//! distribution responds to covariate/label drift exactly the way the
//! lifecycle scenarios need. The vendored `xla` shim interprets the
//! programs with the same batch-variant/padding contract as the real
//! AOT path, so everything downstream (chunking, batchers, pipelines)
//! is exercised unmodified.
//!
//! Everything lifecycle-related (tests, the drift-storm scenario and
//! example, the sketch-feed bench) builds on [`SimArtifacts::in_temp`]
//! so it runs identically everywhere — including CI, where
//! `make artifacts` never ran. The fixture's model roster (`s1..s3`)
//! is deliberately distinct from the real one (`m1..m8`): configs name
//! their experts explicitly, so the two sets cannot be silently
//! confused.

use super::manifest::Manifest;
use crate::simulator::{TenantProfile, Workload};
use crate::util::dataset::DATASET_MAGIC;
use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// Feature dimension — matches `simulator::workload::FEATURE_DIM`.
pub const SIM_FEATURE_DIM: usize = 24;
/// Quantile grid resolution for sim-backed engines.
pub const SIM_QUANTILE_POINTS: usize = 129;
/// AOT batch variants the fixture emits per model.
pub const SIM_BATCHES: [usize; 3] = [1, 64, 256];

/// One synthetic expert definition.
struct SimModel {
    name: &'static str,
    beta: f64,
    bias: f32,
    /// (dim range start, end, base weight) bands.
    bands: [(usize, usize, f32); 3],
}

const MODELS: [SimModel; 3] = [
    // Pattern-P0 specialist: heavy on dims 0..8.
    SimModel {
        name: "s1",
        beta: 0.20,
        bias: -2.3,
        bands: [(0, 8, 0.45), (8, 16, 0.22), (16, 24, 0.02)],
    },
    // Pattern-P1 specialist: heavy on dims 8..16.
    SimModel {
        name: "s2",
        beta: 0.12,
        bias: -2.1,
        bands: [(0, 8, 0.28), (8, 16, 0.40), (16, 24, 0.03)],
    },
    // Weak generalist.
    SimModel {
        name: "s3",
        beta: 0.45,
        bias: -1.9,
        bands: [(0, 8, 0.16), (8, 16, 0.16), (16, 24, 0.16)],
    },
];

/// The paper-shaped roster for the repro harnesses: the 8-expert
/// ensemble of Fig. 4 (`m1..m8`) with per-model undersampling ratios
/// (`beta`) spanning the paper's range — `m3` is the beta=2%
/// specialist Table 1 singles out. Band patterns alternate between
/// P0-heavy, P1-heavy and generalist so ensembles over subsets behave
/// like genuinely distinct experts.
const PAPER_MODELS: [SimModel; 8] = [
    SimModel {
        name: "m1",
        beta: 0.18,
        bias: -2.3,
        bands: [(0, 8, 0.45), (8, 16, 0.20), (16, 24, 0.03)],
    },
    SimModel {
        name: "m2",
        beta: 0.18,
        bias: -2.1,
        bands: [(0, 8, 0.26), (8, 16, 0.42), (16, 24, 0.04)],
    },
    SimModel {
        name: "m3",
        beta: 0.02,
        bias: -2.6,
        bands: [(0, 8, 0.10), (8, 16, 0.52), (16, 24, 0.02)],
    },
    SimModel {
        name: "m4",
        beta: 0.25,
        bias: -1.9,
        bands: [(0, 8, 0.18), (8, 16, 0.18), (16, 24, 0.16)],
    },
    SimModel {
        name: "m5",
        beta: 0.32,
        bias: -2.0,
        bands: [(0, 8, 0.38), (8, 16, 0.10), (16, 24, 0.08)],
    },
    SimModel {
        name: "m6",
        beta: 0.12,
        bias: -2.2,
        bands: [(0, 8, 0.14), (8, 16, 0.34), (16, 24, 0.10)],
    },
    SimModel {
        name: "m7",
        beta: 0.08,
        bias: -2.4,
        bands: [(0, 8, 0.30), (8, 16, 0.30), (16, 24, 0.02)],
    },
    SimModel {
        name: "m8",
        beta: 0.50,
        bias: -1.8,
        bands: [(0, 8, 0.12), (8, 16, 0.12), (16, 24, 0.20)],
    },
];

/// One synthetic dataset spec for the paper fixture.
struct SimDataset {
    name: &'static str,
    n: usize,
    /// (tenant name, profile seed, shift_scale, pattern1_frac,
    /// fraud_rate, stream seed) — `client_b_pre`/`client_b_post`
    /// share a profile seed so they model the *same* tenant before
    /// and after the P1 fraud wave the Fig. 6 update answers.
    profile: (&'static str, u64, f64, f64, f64, u64),
}

const PAPER_DATASETS: [SimDataset; 7] = [
    SimDataset {
        name: "train_pool",
        n: 12_000,
        profile: ("provider", 11, 0.05, 0.10, 0.05, 101),
    },
    SimDataset {
        name: "client_a_live",
        n: 8_000,
        profile: ("clientA", 23, 0.55, 0.15, 0.03, 103),
    },
    SimDataset {
        name: "client_b_pre",
        n: 8_000,
        profile: ("clientB", 31, 0.35, 0.05, 0.04, 107),
    },
    SimDataset {
        name: "client_b_post",
        n: 8_000,
        profile: ("clientB", 31, 0.35, 0.75, 0.10, 109),
    },
    SimDataset {
        name: "valid_m1",
        n: 4_000,
        profile: ("valid1", 41, 0.05, 0.10, 0.05, 113),
    },
    SimDataset {
        name: "valid_m2",
        n: 4_000,
        profile: ("valid2", 43, 0.05, 0.25, 0.05, 127),
    },
    SimDataset {
        name: "valid_m3",
        n: 4_000,
        profile: ("valid3", 47, 0.05, 0.60, 0.05, 131),
    },
];

static NONCE: AtomicU64 = AtomicU64::new(0);

/// A generated artifact directory; dropping it removes the directory.
pub struct SimArtifacts {
    root: PathBuf,
}

impl SimArtifacts {
    /// Generate the fixture under a fresh temp directory.
    pub fn in_temp() -> Result<SimArtifacts> {
        let dir = std::env::temp_dir().join(format!(
            "muse-simfix-{}-{}",
            std::process::id(),
            NONCE.fetch_add(1, Ordering::Relaxed)
        ));
        SimArtifacts::generate(dir)
    }

    /// Generate the paper-roster fixture (`m1..m8` + binary datasets)
    /// under a fresh temp directory — enough surface for every
    /// `repro::*` harness to run end to end without `make artifacts`
    /// (see `tests/repro_smoke.rs`; point `MUSE_ARTIFACTS` at
    /// [`SimArtifacts::root`]).
    pub fn in_temp_paper() -> Result<SimArtifacts> {
        let dir = std::env::temp_dir().join(format!(
            "muse-simfix-paper-{}-{}",
            std::process::id(),
            NONCE.fetch_add(1, Ordering::Relaxed)
        ));
        SimArtifacts::generate_paper(dir)
    }

    /// Generate the lifecycle fixture (`s1..s3`, no datasets) under
    /// `dir` (created if missing).
    pub fn generate(dir: impl Into<PathBuf>) -> Result<SimArtifacts> {
        SimArtifacts::generate_with(dir.into(), &MODELS, &[])
    }

    /// Generate the paper-roster fixture (`m1..m8` + the Fig. 4/6 and
    /// Table 1 datasets) under `dir`.
    pub fn generate_paper(dir: impl Into<PathBuf>) -> Result<SimArtifacts> {
        SimArtifacts::generate_with(dir.into(), &PAPER_MODELS, &PAPER_DATASETS)
    }

    fn generate_with(
        root: PathBuf,
        models: &[SimModel],
        datasets: &[SimDataset],
    ) -> Result<SimArtifacts> {
        let models_dir = root.join("models");
        std::fs::create_dir_all(&models_dir)
            .with_context(|| format!("create {}", models_dir.display()))?;

        let mut model_entries: Vec<Json> = Vec::new();
        for m in models {
            let weights = m.weights();
            let mut batches: BTreeMap<String, Json> = BTreeMap::new();
            for &b in &SIM_BATCHES {
                let rel = format!("models/{}_b{b}.sim.txt", m.name);
                let program = render_program(b, &weights, m.bias);
                std::fs::write(root.join(&rel), program)
                    .with_context(|| format!("write {rel}"))?;
                batches.insert(b.to_string(), Json::str(rel));
            }
            model_entries.push(Json::obj(vec![
                ("name", Json::str(m.name)),
                ("arch", Json::str("simlin")),
                ("beta", Json::Num(m.beta)),
                ("feature_dim", Json::Num(SIM_FEATURE_DIM as f64)),
                ("batches", Json::Obj(batches)),
            ]));
        }
        let mut dataset_entries: Vec<Json> = Vec::new();
        if !datasets.is_empty() {
            let data_dir = root.join("data");
            std::fs::create_dir_all(&data_dir)
                .with_context(|| format!("create {}", data_dir.display()))?;
            for ds in datasets {
                let (tenant, pseed, shift, p1, fraud, sseed) = ds.profile;
                let profile =
                    TenantProfile::new(tenant, pseed, shift, p1).with_fraud_rate(fraud);
                let mut wl = Workload::new(profile, sseed);
                let (features, labels) = wl.batch(ds.n);
                let rel = format!("data/{}.bin", ds.name);
                write_dataset(&root.join(&rel), &features, &labels, SIM_FEATURE_DIM)
                    .with_context(|| format!("write {rel}"))?;
                dataset_entries.push(Json::obj(vec![
                    ("name", Json::str(ds.name)),
                    ("path", Json::str(rel)),
                    ("n", Json::Num(ds.n as f64)),
                ]));
            }
        }
        let mut manifest_fields = vec![
            ("version", Json::Num(1.0)),
            ("feature_dim", Json::Num(SIM_FEATURE_DIM as f64)),
            ("fraud_prior", Json::Num(0.015)),
            ("quantile_points", Json::Num(SIM_QUANTILE_POINTS as f64)),
            (
                "batch_variants",
                Json::Arr(SIM_BATCHES.iter().map(|&b| Json::Num(b as f64)).collect()),
            ),
            ("models", Json::Arr(model_entries)),
        ];
        if !dataset_entries.is_empty() {
            manifest_fields.push(("datasets", Json::Arr(dataset_entries)));
        }
        let manifest = Json::obj(manifest_fields);
        std::fs::write(root.join("manifest.json"), manifest.to_string())
            .context("write manifest.json")?;
        Ok(SimArtifacts { root })
    }

    pub fn root(&self) -> &PathBuf {
        &self.root
    }

    pub fn manifest(&self) -> Result<Manifest> {
        Manifest::load(&self.root)
    }
}

impl Drop for SimArtifacts {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.root);
    }
}

impl SimModel {
    /// Band weights with a small deterministic jitter so no two dims
    /// are exactly tied (ties would make expert scores degenerate
    /// under symmetric inputs).
    fn weights(&self) -> Vec<f32> {
        let mut rng = Rng::new(0x51_4D0D ^ self.name.as_bytes()[1] as u64);
        let mut w = vec![0.0f32; SIM_FEATURE_DIM];
        for &(lo, hi, base) in &self.bands {
            for slot in w.iter_mut().take(hi).skip(lo) {
                *slot = base + 0.02 * (rng.f64() - 0.5) as f32;
            }
        }
        // De-emphasize the amount dim (heavy-tailed lognormal): keep
        // the logit variance dominated by the Gaussian pattern dims.
        w[SIM_FEATURE_DIM - 1] = 0.005;
        w
    }
}

/// Write one dataset in the binary interchange `util::dataset::Dataset`
/// reads (`python/compile/datagen.py::write_dataset` layout):
/// `magic | version | n | d | reserved | f32 features | f32 labels`.
fn write_dataset(path: &Path, features: &[f32], labels: &[f32], d: usize) -> Result<()> {
    debug_assert_eq!(features.len(), labels.len() * d);
    let mut buf: Vec<u8> = Vec::with_capacity(24 + 4 * (features.len() + labels.len()));
    buf.extend_from_slice(&DATASET_MAGIC.to_le_bytes());
    buf.extend_from_slice(&1u32.to_le_bytes());
    buf.extend_from_slice(&(labels.len() as u64).to_le_bytes());
    buf.extend_from_slice(&(d as u32).to_le_bytes());
    buf.extend_from_slice(&0u32.to_le_bytes());
    for f in features {
        buf.extend_from_slice(&f.to_le_bytes());
    }
    for l in labels {
        buf.extend_from_slice(&l.to_le_bytes());
    }
    std::fs::write(path, buf).with_context(|| format!("write {}", path.display()))
}

fn render_program(batch: usize, weights: &[f32], bias: f32) -> String {
    let mut out = String::with_capacity(weights.len() * 12 + 128);
    out.push_str("muse-sim-hlo v1\n");
    let _ = writeln!(out, "input {batch} {SIM_FEATURE_DIM}");
    let _ = writeln!(out, "dense {SIM_FEATURE_DIM} 1");
    for w in weights {
        let _ = writeln!(out, "{w:.6}");
    }
    let _ = writeln!(out, "{bias:.6}");
    out.push_str("sigmoid\noutput 1\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ModelPool;
    use crate::simulator::{TenantProfile, Workload, FEATURE_DIM};
    use std::sync::Arc;

    #[test]
    fn generated_manifest_loads_and_containers_score() {
        let fix = SimArtifacts::in_temp().unwrap();
        let m = fix.manifest().unwrap();
        assert_eq!(m.feature_dim, FEATURE_DIM);
        assert_eq!(m.quantile_points, SIM_QUANTILE_POINTS);
        assert_eq!(m.models.len(), 3);
        let pool = Arc::new(ModelPool::new(m));
        let h = pool.acquire("s1").unwrap();
        let scores = h.infer(&vec![0.0f32; 2 * FEATURE_DIM], 2).unwrap();
        assert_eq!(scores.len(), 2);
        for s in &scores {
            assert!((0.0..=1.0).contains(s), "score {s}");
            // sigmoid(-2.3) ≈ 0.091 for the zero vector.
            assert!((s - 0.091).abs() < 0.02, "zero-vector score {s}");
        }
        pool.release("s1");
    }

    #[test]
    fn fraud_scores_higher_than_legit() {
        let fix = SimArtifacts::in_temp().unwrap();
        let pool = ModelPool::new(fix.manifest().unwrap());
        let mut wl = Workload::new(TenantProfile::new("t", 5, 0.3, 0.3), 7);
        let (feats, labels) = wl.batch(4000);
        for model in ["s1", "s2", "s3"] {
            let h = pool.acquire(model).unwrap();
            let scores = h.infer(&feats, 4000).unwrap();
            let (mut fraud, mut legit, mut nf, mut nl) = (0.0f64, 0.0f64, 0u32, 0u32);
            for (s, &y) in scores.iter().zip(&labels) {
                if y > 0.5 {
                    fraud += *s as f64;
                    nf += 1;
                } else {
                    legit += *s as f64;
                    nl += 1;
                }
            }
            let gap = fraud / nf as f64 - legit / nl as f64;
            assert!(gap > 0.15, "{model}: fraud/legit gap {gap} too small");
            pool.release(model);
        }
    }

    #[test]
    fn batch_variants_agree_with_singles() {
        let fix = SimArtifacts::in_temp().unwrap();
        let pool = ModelPool::new(fix.manifest().unwrap());
        let h = pool.acquire("s2").unwrap();
        let mut rng = crate::util::rng::Rng::new(3);
        let n = 90; // crosses the 64 and 256 variants with padding
        let feats: Vec<f32> = (0..n * FEATURE_DIM).map(|_| rng.normal() as f32).collect();
        let batched = h.infer(&feats, n).unwrap();
        for i in (0..n).step_by(13) {
            let single = h
                .infer(&feats[i * FEATURE_DIM..(i + 1) * FEATURE_DIM], 1)
                .unwrap();
            assert!(
                (batched[i] - single[0]).abs() < 1e-6,
                "row {i}: batched {} vs single {}",
                batched[i],
                single[0]
            );
        }
        pool.release("s2");
    }

    #[test]
    fn paper_fixture_has_full_roster_and_readable_datasets() {
        let fix = SimArtifacts::in_temp_paper().unwrap();
        let m = fix.manifest().unwrap();
        assert_eq!(m.models.len(), 8);
        assert!((m.model("m3").unwrap().beta - 0.02).abs() < 1e-12);
        assert_eq!(m.quantile_points, SIM_QUANTILE_POINTS);
        // Every dataset the repro harnesses name loads through the
        // binary reader with the declared row count and a usable
        // positive rate.
        for name in [
            "train_pool",
            "client_a_live",
            "client_b_pre",
            "client_b_post",
            "valid_m1",
            "valid_m2",
            "valid_m3",
        ] {
            let spec = m.dataset(name).unwrap();
            let ds = crate::util::dataset::Dataset::load(&spec.path).unwrap();
            assert_eq!(ds.n, spec.n, "{name}");
            assert_eq!(ds.d, FEATURE_DIM, "{name}");
            let pr = ds.positive_rate();
            assert!(pr > 0.005 && pr < 0.3, "{name}: positive rate {pr}");
        }
        // The paper-roster models score through containers like the
        // lifecycle roster does.
        let pool = ModelPool::new(fix.manifest().unwrap());
        let h = pool.acquire("m3").unwrap();
        let scores = h.infer(&vec![0.0f32; FEATURE_DIM], 1).unwrap();
        assert!((0.0..=1.0).contains(&scores[0]));
        pool.release("m3");
        // The drifted post-period is the same tenant (same covariate
        // profile seed), not a new one: pre and post differ in fraud
        // mix, which is exactly the Fig. 6 scenario.
        let pre = m.dataset("client_b_pre").unwrap();
        let post = m.dataset("client_b_post").unwrap();
        let pre_ds = crate::util::dataset::Dataset::load(&pre.path).unwrap();
        let post_ds = crate::util::dataset::Dataset::load(&post.path).unwrap();
        assert!(post_ds.positive_rate() > 2.0 * pre_ds.positive_rate());
    }

    #[test]
    fn temp_fixture_cleans_up_on_drop() {
        let path = {
            let fix = SimArtifacts::in_temp().unwrap();
            assert!(fix.root().join("manifest.json").exists());
            fix.root().clone()
        };
        assert!(!path.exists(), "fixture dir survived drop");
    }
}
