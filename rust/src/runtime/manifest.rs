//! The artifact manifest: the contract between the Python compile path
//! (`python/compile/aot.py`) and the rust runtime. Parsed from
//! `artifacts/manifest.json` with the crate's own JSON parser.

use crate::util::json::{self, Json};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One trained expert model and its AOT-compiled batch variants.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub arch: String,
    /// Negative-class undersampling ratio used in training — the
    /// `beta_k` of the Posterior Correction (Eq. 3).
    pub beta: f64,
    pub feature_dim: usize,
    /// batch size -> HLO text artifact path (absolute).
    pub batches: BTreeMap<usize, PathBuf>,
    pub train_pool_auc: Option<f64>,
}

/// A lowered fused-transform pipeline artifact (batched offline path).
#[derive(Debug, Clone)]
pub struct TransformSpec {
    pub k: usize,
    pub batch: usize,
    pub n_points: usize,
    pub path: PathBuf,
}

/// A binary evaluation dataset.
#[derive(Debug, Clone)]
pub struct DatasetSpec {
    pub name: String,
    pub path: PathBuf,
    pub n: usize,
}

/// The parsed artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub root: PathBuf,
    pub feature_dim: usize,
    pub fraud_prior: f64,
    pub quantile_points: usize,
    pub batch_variants: Vec<usize>,
    pub models: BTreeMap<String, ModelSpec>,
    pub transforms: Vec<TransformSpec>,
    pub datasets: BTreeMap<String, DatasetSpec>,
}

impl Manifest {
    /// Load `<root>/manifest.json`.
    pub fn load(root: impl AsRef<Path>) -> Result<Manifest> {
        let root = root.as_ref().to_path_buf();
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read manifest {} (run `make artifacts`)", path.display()))?;
        let v = json::parse(&text).context("parse manifest.json")?;
        Manifest::from_json(root, &v)
    }

    fn from_json(root: PathBuf, v: &Json) -> Result<Manifest> {
        if v.req_f64("version")? as u64 != 1 {
            bail!("unsupported manifest version");
        }
        let feature_dim = v.req_f64("feature_dim")? as usize;
        let mut models = BTreeMap::new();
        for m in v.req("models")?.as_arr().context("models must be a list")? {
            let name = m.req_str("name")?.to_string();
            let mut batches = BTreeMap::new();
            for (b, p) in m.req("batches")?.as_obj().context("batches must be a map")? {
                let batch: usize = b.parse().context("batch keys must be integers")?;
                batches.insert(
                    batch,
                    root.join(p.as_str().context("batch path must be a string")?),
                );
            }
            models.insert(
                name.clone(),
                ModelSpec {
                    name,
                    arch: m.req_str("arch")?.to_string(),
                    beta: m.req_f64("beta")?,
                    feature_dim: m.req_f64("feature_dim")? as usize,
                    batches,
                    train_pool_auc: m.get("train_pool_auc").and_then(Json::as_f64),
                },
            );
        }
        let mut transforms = vec![];
        if let Some(Json::Arr(ts)) = v.get("transforms") {
            for t in ts {
                transforms.push(TransformSpec {
                    k: t.req_f64("k")? as usize,
                    batch: t.req_f64("batch")? as usize,
                    n_points: t.req_f64("n_points")? as usize,
                    path: root.join(t.req_str("path")?),
                });
            }
        }
        let mut datasets = BTreeMap::new();
        if let Some(Json::Arr(ds)) = v.get("datasets") {
            for d in ds {
                let name = d.req_str("name")?.to_string();
                datasets.insert(
                    name.clone(),
                    DatasetSpec {
                        name,
                        path: root.join(d.req_str("path")?),
                        n: d.req_f64("n")? as usize,
                    },
                );
            }
        }
        Ok(Manifest {
            root,
            feature_dim,
            fraud_prior: v.req_f64("fraud_prior")?,
            quantile_points: v.req_f64("quantile_points")? as usize,
            batch_variants: v
                .req("batch_variants")?
                .to_f64_vec()
                .context("batch_variants must be numbers")?
                .into_iter()
                .map(|b| b as usize)
                .collect(),
            models,
            transforms,
            datasets,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models
            .get(name)
            .with_context(|| format!("model '{name}' not in manifest"))
    }

    pub fn dataset(&self, name: &str) -> Result<&DatasetSpec> {
        self.datasets
            .get(name)
            .with_context(|| format!("dataset '{name}' not in manifest"))
    }

    /// The default artifact root (`$MUSE_ARTIFACTS` or `./artifacts`).
    pub fn default_root() -> PathBuf {
        std::env::var("MUSE_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Best batch variant for `n` events: the smallest variant >= n,
    /// or the largest available (callers then chunk).
    pub fn pick_batch(&self, spec: &ModelSpec, n: usize) -> usize {
        let mut best: Option<usize> = None;
        for &b in spec.batches.keys() {
            if b >= n && best.map_or(true, |x| b < x) {
                best = Some(b);
            }
        }
        best.unwrap_or_else(|| *spec.batches.keys().max().expect("no batch variants"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_manifest_json() -> Json {
        json::parse(
            r#"{
          "version": 1, "feature_dim": 24, "fraud_prior": 0.015,
          "quantile_points": 1025, "batch_variants": [1, 16, 64],
          "models": [
            {"name": "m1", "arch": "mlp1", "beta": 0.18, "feature_dim": 24,
             "batches": {"1": "models/m1_b1.hlo.txt", "16": "models/m1_b16.hlo.txt",
                         "64": "models/m1_b64.hlo.txt"},
             "train_pool_auc": 0.93}
          ],
          "transforms": [{"k": 3, "batch": 64, "n_points": 1025,
                          "path": "transform/transform_k3_b64.hlo.txt"}],
          "datasets": [{"name": "train_pool", "path": "data/train_pool.bin",
                        "n": 60000, "seed": 1}]
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_toy_manifest() {
        let m = Manifest::from_json(PathBuf::from("/art"), &toy_manifest_json()).unwrap();
        assert_eq!(m.feature_dim, 24);
        let m1 = m.model("m1").unwrap();
        assert_eq!(m1.beta, 0.18);
        assert_eq!(m1.batches.len(), 3);
        assert!(m1.batches[&16].ends_with("models/m1_b16.hlo.txt"));
        assert!(m1.batches[&16].starts_with("/art"));
        assert_eq!(m.transforms[0].k, 3);
        assert_eq!(m.dataset("train_pool").unwrap().n, 60000);
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn pick_batch_prefers_smallest_fit() {
        let m = Manifest::from_json(PathBuf::from("/art"), &toy_manifest_json()).unwrap();
        let spec = m.model("m1").unwrap();
        assert_eq!(m.pick_batch(spec, 1), 1);
        assert_eq!(m.pick_batch(spec, 2), 16);
        assert_eq!(m.pick_batch(spec, 16), 16);
        assert_eq!(m.pick_batch(spec, 17), 64);
        assert_eq!(m.pick_batch(spec, 500), 64); // chunked by caller
    }

    #[test]
    fn rejects_bad_version() {
        let mut v = toy_manifest_json();
        if let Json::Obj(o) = &mut v {
            o.insert("version".into(), Json::Num(9.0));
        }
        assert!(Manifest::from_json(PathBuf::from("/a"), &v).is_err());
    }

    #[test]
    fn loads_real_manifest_if_built() {
        // Integration: when `make artifacts` has run, the real
        // manifest must parse and contain the 8-expert roster.
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !root.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&root).unwrap();
        assert_eq!(m.models.len(), 8);
        assert!(m.model("m3").unwrap().beta < 0.05);
        for spec in m.models.values() {
            for path in spec.batches.values() {
                assert!(path.exists(), "missing artifact {}", path.display());
            }
        }
    }
}
