//! The `muse` CLI — launcher for the serving coordinator and the
//! paper-exhibit harnesses.
//!
//! ```text
//! muse serve  [--config FILE] [--addr HOST:PORT]   start the server
//! muse repro  <exhibit>                            regenerate a paper exhibit
//!             fig4 | fig5 | fig6 | table1 | appendix-a |
//!             headline | dedup | baselines | all
//! muse info                                        artifact/manifest summary
//! ```

use anyhow::{bail, Context, Result};
use muse::config::MuseConfig;
use muse::coordinator::Engine;
use muse::runtime::{Manifest, ModelPool};
use std::sync::Arc;

const DEFAULT_CONFIG: &str = r#"
routing:
  scoringRules:
  - description: "default: shared 3-expert ensemble"
    condition: {}
    targetPredictorName: "global-v1"
predictors:
- name: global-v1
  experts: [m1, m2, m3]
  quantile: default
server:
  listenAddr: "127.0.0.1:7461"
  workers: 8
"#;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    match args.first().map(String::as_str) {
        Some("serve") => serve(&args[1..]),
        Some("repro") => repro(&args[1..]),
        Some("info") => info(),
        Some("help") | None => {
            print!("{}", usage());
            Ok(())
        }
        Some(other) => bail!("unknown command '{other}'\n{}", usage()),
    }
}

fn usage() -> String {
    "muse — Multi-tenant model serving with seamless model updates\n\n\
     USAGE:\n\
       muse serve [--config FILE] [--addr HOST:PORT] [--warmup N]\n\
       muse repro <fig4|fig5|fig6|table1|appendix-a|headline|dedup|baselines|all>\n\
       muse info\n"
        .to_string()
}

fn flag_value<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .map(String::as_str)
}

fn serve(args: &[String]) -> Result<()> {
    let yaml = match flag_value(args, "--config") {
        Some(path) => std::fs::read_to_string(path).with_context(|| format!("read {path}"))?,
        None => DEFAULT_CONFIG.to_string(),
    };
    let config = MuseConfig::from_yaml(&yaml)?;
    let addr = flag_value(args, "--addr")
        .unwrap_or(&config.server.listen_addr)
        .to_string();
    let warmup: usize = flag_value(args, "--warmup")
        .map(|v| v.parse())
        .transpose()
        .context("--warmup must be an integer")?
        .unwrap_or(config.server.warmup_requests);

    let manifest = Manifest::load(Manifest::default_root())
        .context("artifacts missing — run `make artifacts`")?;
    let pool = Arc::new(ModelPool::new(manifest));
    let engine = Arc::new(Engine::build(&config, pool)?);

    // Cold-start defaults for predictors configured with
    // `quantile: default` (Section 2.4).
    install_default_quantiles(&engine, &config)?;

    eprintln!("muse: warming up ({warmup} requests) ...");
    let (bound, _ready, handle) =
        muse::server::spawn_server(Arc::clone(&engine), &addr, config.server.workers, warmup)?;
    // Lifecycle autopilot: background drift-detection + shadow→promote
    // loop, one tick per `lifecycle.checkIntervalMs`.
    let _autopilot = if config.lifecycle.enabled {
        let c = muse::lifecycle::spawn_controller(Arc::clone(&engine))?;
        eprintln!(
            "muse: lifecycle autopilot on ({}ms ticks)",
            config.lifecycle.check_interval_ms
        );
        Some(c)
    } else {
        None
    };
    eprintln!("muse: ready, serving on http://{bound}");
    eprintln!(
        "muse: POST /score  POST /v1/score/batch  GET /healthz  GET /metrics  \
         GET /admin/stats  GET /v1/lifecycle  POST /v1/lifecycle/check"
    );
    handle.join().ok();
    Ok(())
}

fn install_default_quantiles(engine: &Engine, config: &MuseConfig) -> Result<()> {
    use muse::config::QuantileMode;
    use muse::coordinator::ControlPlane;
    let needs_default: Vec<_> = config
        .predictors
        .iter()
        .filter(|p| p.quantile_mode == QuantileMode::Default)
        .collect();
    if needs_default.is_empty() {
        return Ok(());
    }
    let manifest = Manifest::load(Manifest::default_root())?;
    let Ok(spec) = manifest.dataset("train_pool") else {
        eprintln!("muse: no train_pool dataset; default quantiles stay at identity");
        return Ok(());
    };
    let train = muse::util::dataset::Dataset::load(&spec.path)?;
    let cp = ControlPlane::new(engine);
    for p in needs_default {
        let reference = Engine::reference(&p.reference);
        eprintln!("muse: fitting cold-start T^Q for '{}' ...", p.name);
        cp.fit_default_quantile(&p.name, &train, &reference, &Default::default())?;
    }
    Ok(())
}

fn repro(args: &[String]) -> Result<()> {
    let which = args.first().map(String::as_str).unwrap_or("all");
    let run_one = |name: &str| -> Result<()> {
        let out = match name {
            "fig4" => muse::repro::fig4::run()?,
            "fig5" => muse::repro::fig5::run()?,
            "fig6" => muse::repro::fig6::run()?,
            "table1" => muse::repro::table1::run()?,
            "appendix-a" => muse::repro::appendix_a::run()?,
            "headline" => muse::repro::headline::run()?,
            "dedup" => muse::repro::dedup::run()?,
            "baselines" => muse::repro::baselines_cmp::run()?,
            other => bail!("unknown exhibit '{other}'"),
        };
        println!("{out}");
        Ok(())
    };
    if which == "all" {
        for name in [
            "fig4", "fig5", "fig6", "table1", "appendix-a", "headline", "dedup", "baselines",
        ] {
            run_one(name)?;
        }
        Ok(())
    } else {
        run_one(which)
    }
}

fn info() -> Result<()> {
    let manifest = Manifest::load(Manifest::default_root())
        .context("artifacts missing — run `make artifacts`")?;
    println!("artifact root: {}", manifest.root.display());
    println!(
        "feature_dim={} fraud_prior={} quantile_points={}",
        manifest.feature_dim, manifest.fraud_prior, manifest.quantile_points
    );
    println!("models:");
    for m in manifest.models.values() {
        println!(
            "  {:<4} arch={:<5} beta={:<5} batches={:?} auc={:.3}",
            m.name,
            m.arch,
            m.beta,
            m.batches.keys().collect::<Vec<_>>(),
            m.train_pool_auc.unwrap_or(f64::NAN)
        );
    }
    println!("datasets:");
    for d in manifest.datasets.values() {
        println!("  {:<16} n={}", d.name, d.n);
    }
    Ok(())
}
