//! One serving replica: an unmodified [`Engine`] plus the control
//! loop that stages and commits replicated commands, and the epoch
//! word that makes every response attributable to a committed
//! snapshot generation.
//!
//! ## Stage/commit decomposition
//!
//! Each [`ClusterCommand`] splits so that the staged half is
//! invisible to routing and the committed half is a single published
//! snapshot flip — every intermediate state a request can observe is
//! response-equivalent to either the old epoch or the new one:
//!
//! * `ShadowDeploy` — stage: build the quantile map and
//!   `registry.deploy` (deployed-but-unrouted predictors never affect
//!   responses); commit: append the shadow rule and republish. Abort
//!   undoes the staged deploy.
//! * `Promote` / `Decommission` — stage: validate only (the routing
//!   rewrite cannot be made invisible, so it is deferred wholesale);
//!   commit: the single-node `ControlPlane` op, which ends in one
//!   snapshot publication.
//! * Quantile installs — stage: build + validate the map; commit:
//!   install (copy-on-write inside `QuantileTable`) and republish.
//!
//! ## Epoch word
//!
//! `2k` = stable at committed epoch `k`; `2k+1` = flipping from `k`
//! to `k+1`. [`NodeHandle::score`] reads the word around the engine
//! call and reports the closed window of epochs the response could
//! belong to. The window is **never** re-scored on a race: re-running
//! the engine would double-append lake records and double-count
//! events; attribution, not retry, is the contract.

use super::command::ClusterCommand;
use super::transport::{AckKind, ControlMsg, ControlReply, NodeEndpoint, NodeId};
use crate::config::{Condition, Intent, ShadowRule};
use crate::coordinator::{ControlPlane, Engine, ScoreRequest, ScoreResponse};
use crate::transforms::QuantileMap;
use anyhow::{ensure, Result};
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex};

/// Node lifecycle state, as the gateway and operator see it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeState {
    /// Spawned, replaying the committed log; not in the membership.
    Joining,
    /// Live: routed traffic and replicated publishes.
    Serving,
    /// Leaving gracefully: out of the membership, settling shadows.
    Draining,
    /// Gone after a graceful leave.
    Left,
    /// Fenced: timed out, nacked a commit, or died by fault injection.
    Crashed,
}

impl NodeState {
    fn as_u8(self) -> u8 {
        match self {
            NodeState::Joining => 0,
            NodeState::Serving => 1,
            NodeState::Draining => 2,
            NodeState::Left => 3,
            NodeState::Crashed => 4,
        }
    }

    fn from_u8(v: u8) -> NodeState {
        match v {
            0 => NodeState::Joining,
            1 => NodeState::Serving,
            2 => NodeState::Draining,
            3 => NodeState::Left,
            _ => NodeState::Crashed,
        }
    }

    /// Status-endpoint label.
    pub fn name(self) -> &'static str {
        match self {
            NodeState::Joining => "joining",
            NodeState::Serving => "serving",
            NodeState::Draining => "draining",
            NodeState::Left => "left",
            NodeState::Crashed => "crashed",
        }
    }
}

/// Fault-injection points for the two-phase publish, armed per node
/// and consumed by the next publish that reaches the point.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum FaultPoint {
    #[default]
    None,
    /// Die mid-phase-1: the stage request arrives but is never acked.
    CrashBeforeStageAck,
    /// Die mid-flip: staged and acked, but the commit never applies.
    CrashBeforeCommitApply,
    /// Die after the flip applied but before the commit ack.
    CrashAfterCommitApply,
}

/// A response stamped with the committed-epoch window it could have
/// been scored under (see module docs).
pub struct EpochScored {
    pub resp: ScoreResponse,
    pub epoch_lo: u64,
    pub epoch_hi: u64,
}

/// A batch response with its epoch window.
pub struct EpochScoredBatch {
    pub resps: Vec<ScoreResponse>,
    pub epoch_lo: u64,
    pub epoch_hi: u64,
}

/// Shared handle to one serving node. The control loop, the gateway
/// and the operator all hold `Arc<NodeHandle>`; the engine itself is
/// untouched by clustering.
pub struct NodeHandle {
    pub id: NodeId,
    pub engine: Arc<Engine>,
    /// Epoch word: `2k` stable, `2k+1` flipping (module docs).
    epoch: AtomicU64,
    state: AtomicU8,
    fault: Mutex<FaultPoint>,
}

impl NodeHandle {
    pub(crate) fn new(id: NodeId, engine: Arc<Engine>, state: NodeState) -> NodeHandle {
        NodeHandle {
            id,
            engine,
            epoch: AtomicU64::new(0),
            state: AtomicU8::new(state.as_u8()),
            fault: Mutex::new(FaultPoint::None),
        }
    }

    pub fn state(&self) -> NodeState {
        NodeState::from_u8(self.state.load(Ordering::Acquire))
    }

    pub(crate) fn set_state(&self, s: NodeState) {
        self.state.store(s.as_u8(), Ordering::Release);
    }

    /// Committed epoch this node last flipped to.
    pub fn committed_epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire) >> 1
    }

    /// True while a flip is in progress on this node.
    pub fn is_flipping(&self) -> bool {
        self.epoch.load(Ordering::Acquire) & 1 == 1
    }

    /// Arm a fault for the next publish that reaches its point.
    pub fn arm_fault(&self, fault: FaultPoint) {
        *self.fault.lock().unwrap() = fault;
    }

    fn take_fault_if(&self, point: FaultPoint) -> bool {
        let mut g = self.fault.lock().unwrap();
        if *g == point {
            *g = FaultPoint::None;
            true
        } else {
            false
        }
    }

    /// Score one request, stamped with its epoch window.
    pub fn score(&self, req: &ScoreRequest) -> Result<EpochScored> {
        let e1 = self.epoch.load(Ordering::Acquire);
        let resp = self.engine.score(req)?;
        let e2 = self.epoch.load(Ordering::Acquire);
        Ok(EpochScored {
            resp,
            epoch_lo: e1 >> 1,
            epoch_hi: (e2 >> 1) + (e2 & 1),
        })
    }

    /// Score a whole batch, stamped with its epoch window.
    pub fn score_batch(&self, reqs: &[ScoreRequest]) -> Result<EpochScoredBatch> {
        let e1 = self.epoch.load(Ordering::Acquire);
        let resps = self.engine.score_batch(reqs)?;
        let e2 = self.epoch.load(Ordering::Acquire);
        Ok(EpochScoredBatch {
            resps,
            epoch_lo: e1 >> 1,
            epoch_hi: (e2 >> 1) + (e2 & 1),
        })
    }
}

/// Staged (phase-1) state held between stage and commit/abort.
enum Staged {
    ShadowDeploy { name: String, tenant: String },
    Promote { tenant: String, predictor: String },
    Decommission { predictor: String },
    InstallTenantQuantile { predictor: String, tenant: String, map: Arc<QuantileMap> },
    SetDefaultQuantile { predictor: String, map: Arc<QuantileMap> },
}

/// Phase 1: validate and prepare, with no routing-visible effect.
fn stage(engine: &Engine, cmd: &ClusterCommand) -> Result<Staged> {
    match cmd {
        ClusterCommand::ShadowDeploy {
            cfg,
            tenant,
            src,
            refq,
        } => {
            let map = Arc::new(QuantileMap::new(src.clone(), refq.clone())?);
            // Deployed-but-unrouted predictors never affect responses:
            // the next lazy republish carries the entry, but no rule
            // targets it until the commit appends the shadow rule.
            engine.registry.deploy(cfg, map)?;
            Ok(Staged::ShadowDeploy {
                name: cfg.name.clone(),
                tenant: tenant.clone(),
            })
        }
        ClusterCommand::Promote { tenant, predictor } => {
            // Mirror ControlPlane::promote's checks, in its order, so
            // the nack reason matches the single-node error.
            ensure!(
                engine.registry.get(predictor).is_some(),
                "cannot promote undeployed predictor '{predictor}'"
            );
            let routing = engine.router.snapshot();
            let intent = Intent {
                tenant: tenant.clone(),
                ..Intent::default()
            };
            ensure!(
                routing
                    .scoring_rules
                    .iter()
                    .any(|r| r.condition.matches(&intent)),
                "no scoring rule matches tenant '{tenant}'"
            );
            Ok(Staged::Promote {
                tenant: tenant.clone(),
                predictor: predictor.clone(),
            })
        }
        ClusterCommand::Decommission { predictor } => {
            ensure!(
                engine.registry.get(predictor).is_some(),
                "predictor '{predictor}' is not deployed"
            );
            Ok(Staged::Decommission {
                predictor: predictor.clone(),
            })
        }
        ClusterCommand::InstallTenantQuantile {
            predictor,
            tenant,
            src,
            refq,
        } => {
            let map = Arc::new(QuantileMap::new(src.clone(), refq.clone())?);
            engine.predictor(predictor)?;
            Ok(Staged::InstallTenantQuantile {
                predictor: predictor.clone(),
                tenant: tenant.clone(),
                map,
            })
        }
        ClusterCommand::SetDefaultQuantile {
            predictor,
            src,
            refq,
        } => {
            let map = Arc::new(QuantileMap::new(src.clone(), refq.clone())?);
            engine.predictor(predictor)?;
            Ok(Staged::SetDefaultQuantile {
                predictor: predictor.clone(),
                map,
            })
        }
    }
}

/// Phase 2: flip the staged command into the published snapshot.
fn commit(engine: &Engine, staged: Staged) -> Result<()> {
    let cp = ControlPlane::new(engine);
    match staged {
        Staged::ShadowDeploy { name, tenant } => {
            // The registry half happened at stage; this is the second
            // half of ControlPlane::shadow_deploy, verbatim.
            let mut routing = engine.router.snapshot().as_ref().clone();
            routing.shadow_rules.push(ShadowRule {
                description: format!("shadow {name} for {tenant}"),
                condition: Condition {
                    tenants: vec![tenant],
                    ..Condition::default()
                },
                target_predictors: vec![name.as_str().into()],
            });
            engine.router.swap(routing);
            engine.republish();
            Ok(())
        }
        Staged::Promote { tenant, predictor } => cp.promote(&tenant, &predictor),
        Staged::Decommission { predictor } => cp.decommission(&predictor),
        Staged::InstallTenantQuantile {
            predictor,
            tenant,
            map,
        } => {
            engine.predictor(&predictor)?.install_tenant_quantile(&tenant, map);
            Ok(())
        }
        Staged::SetDefaultQuantile { predictor, map } => {
            engine.predictor(&predictor)?.set_default_quantile(map);
            engine.republish();
            Ok(())
        }
    }
}

/// Undo a staged command's side effects (abort path).
fn undo_stage(engine: &Engine, staged: Staged) {
    if let Staged::ShadowDeploy { name, .. } = staged {
        let _ = engine.registry.decommission(&name);
        engine.republish();
    }
}

/// The node's control loop: runs on a dedicated thread, consuming the
/// transport inbox until shutdown or disconnect. Exactly one staged
/// publish can be pending at a time (the operator serializes
/// publishes), and a commit or abort for any other epoch is rejected
/// as stale.
pub(crate) fn node_loop(node: Arc<NodeHandle>, endpoint: NodeEndpoint) {
    let reply = |epoch: u64, kind: AckKind| {
        let _ = endpoint.replies.send(ControlReply {
            node: node.id,
            epoch,
            kind,
        });
    };
    let mut staged: Option<(u64, Staged)> = None;
    while let Ok(msg) = endpoint.inbox.recv() {
        match msg {
            ControlMsg::Stage { epoch, cmd } => {
                if node.take_fault_if(FaultPoint::CrashBeforeStageAck) {
                    node.set_state(NodeState::Crashed);
                    return; // dies silently; the operator times out
                }
                // A leftover staged publish means the operator gave up
                // on us mid-protocol (it will have fenced this node);
                // unwind it so staging stays idempotent regardless.
                if let Some((_, old)) = staged.take() {
                    undo_stage(&node.engine, old);
                }
                match stage(&node.engine, &cmd) {
                    Ok(st) => {
                        staged = Some((epoch, st));
                        reply(epoch, AckKind::Staged);
                    }
                    Err(e) => reply(epoch, AckKind::Nack(e.to_string())),
                }
            }
            ControlMsg::Commit { epoch } => {
                let matches = staged.as_ref().is_some_and(|(e, _)| *e == epoch);
                if !matches {
                    reply(epoch, AckKind::Nack(format!("stale commit for epoch {epoch}")));
                    continue;
                }
                let (_, st) = staged.take().expect("staged checked above");
                if node.take_fault_if(FaultPoint::CrashBeforeCommitApply) {
                    node.set_state(NodeState::Crashed);
                    return; // fenced at the old epoch, staged state abandoned
                }
                node.epoch.store(2 * epoch - 1, Ordering::Release);
                let applied = commit(&node.engine, st);
                node.epoch.store(2 * epoch, Ordering::Release);
                if node.take_fault_if(FaultPoint::CrashAfterCommitApply) {
                    node.set_state(NodeState::Crashed);
                    return; // flipped but never acked: fenced, consistent
                }
                match applied {
                    Ok(()) => reply(epoch, AckKind::Committed),
                    Err(e) => reply(epoch, AckKind::Nack(e.to_string())),
                }
            }
            ControlMsg::Abort { epoch } => match staged.take() {
                Some((e, st)) if e == epoch => {
                    undo_stage(&node.engine, st);
                    reply(epoch, AckKind::Aborted);
                }
                Some(other) => {
                    staged = Some(other);
                    reply(epoch, AckKind::Nack(format!("stale abort for epoch {epoch}")));
                }
                // Nothing staged (we nacked the stage): ack the abort
                // so the operator's bookkeeping stays simple.
                None => reply(epoch, AckKind::Aborted),
            },
            ControlMsg::Shutdown => break,
        }
    }
}
