//! Tenant-consistent request routing over the live membership.
//!
//! The gateway owns no state of its own: it loads the membership
//! snapshot (published copy-on-write by the control plane through a
//! `SnapCell`, exactly like the engine's routing snapshots) and picks
//! a node by rendezvous (highest-random-weight) hashing of the
//! tenant. Rendezvous gives the two properties the cluster needs
//! without a coordination round:
//!
//! * **stability** — while the membership is unchanged, a tenant
//!   always lands on the same node, so its lake records and shadow
//!   mirrors accumulate in one place;
//! * **minimal disruption** — when a node crashes or leaves, only the
//!   tenants it owned remap (each to its next-best node); everyone
//!   else's placement is untouched.
//!
//! Fail-over is the candidate order itself: scoring walks nodes in
//! descending weight and uses the first one that is `Serving`, so a
//! crash between the membership snapshot and the call costs a skip,
//! never a dropped request. Engine errors (unroutable tenant, feature
//! dim mismatch) are *request* errors, identical on every replica,
//! and propagate without retry.

use super::node::{EpochScored, EpochScoredBatch, NodeHandle, NodeState};
use super::transport::NodeId;
use crate::coordinator::{ScoreRequest, ScoreResponse};
use crate::util::swap::SnapCell;
use anyhow::{bail, Result};
use std::sync::Arc;

/// The live membership: only `Serving` nodes, published by the
/// control plane on every join/leave/crash.
pub struct Membership {
    pub nodes: Vec<Arc<NodeHandle>>,
}

/// A gateway-scored response: the engine response plus the node that
/// served it and the committed-epoch window it is attributable to.
pub struct GatewayResponse {
    pub node: NodeId,
    pub epoch_lo: u64,
    pub epoch_hi: u64,
    pub resp: ScoreResponse,
}

/// A gateway-scored batch (routed whole, by its first event's tenant).
pub struct GatewayBatch {
    pub node: NodeId,
    pub epoch_lo: u64,
    pub epoch_hi: u64,
    pub resps: Vec<ScoreResponse>,
}

/// The scoring front door of the cluster.
pub struct ClusterGateway {
    members: Arc<SnapCell<Membership>>,
}

fn fnv1a64(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// splitmix64 finalizer: cheap, well-mixed avalanche.
fn mix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

fn weight(tenant_hash: u64, node: NodeId) -> u64 {
    mix64(tenant_hash ^ mix64(node as u64))
}

impl ClusterGateway {
    pub(crate) fn new(members: Arc<SnapCell<Membership>>) -> ClusterGateway {
        ClusterGateway { members }
    }

    /// Current membership snapshot (wait-free load).
    pub fn members(&self) -> Arc<Membership> {
        self.members.load()
    }

    /// Fail-over candidate order for `tenant`: members sorted by
    /// descending rendezvous weight (node id breaks exact ties).
    fn ranked(&self, tenant: &str) -> Vec<Arc<NodeHandle>> {
        let members = self.members.load();
        let th = fnv1a64(tenant);
        let mut ranked: Vec<(u64, Arc<NodeHandle>)> = members
            .nodes
            .iter()
            .map(|n| (weight(th, n.id), Arc::clone(n)))
            .collect();
        ranked.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.id.cmp(&b.1.id)));
        ranked.into_iter().map(|(_, n)| n).collect()
    }

    /// The node currently owning `tenant`, if any member serves.
    pub fn route(&self, tenant: &str) -> Option<Arc<NodeHandle>> {
        self.ranked(tenant)
            .into_iter()
            .find(|n| n.state() == NodeState::Serving)
    }

    /// Score one request on the tenant's node, failing over past
    /// non-serving members.
    pub fn score(&self, req: &ScoreRequest) -> Result<GatewayResponse> {
        for node in self.ranked(&req.intent.tenant) {
            if node.state() != NodeState::Serving {
                continue;
            }
            let EpochScored {
                resp,
                epoch_lo,
                epoch_hi,
            } = node.score(req)?;
            return Ok(GatewayResponse {
                node: node.id,
                epoch_lo,
                epoch_hi,
                resp,
            });
        }
        bail!(
            "no serving node for tenant '{}' (membership empty or draining)",
            req.intent.tenant
        )
    }

    /// Score a whole batch on one node, routed by the first event's
    /// tenant (a batch is one request; splitting it would break the
    /// engine's whole-batch admission and grouping semantics).
    pub fn score_batch(&self, reqs: &[ScoreRequest]) -> Result<GatewayBatch> {
        let tenant = reqs
            .first()
            .map(|r| r.intent.tenant.as_str())
            .unwrap_or("");
        for node in self.ranked(tenant) {
            if node.state() != NodeState::Serving {
                continue;
            }
            let EpochScoredBatch {
                resps,
                epoch_lo,
                epoch_hi,
            } = node.score_batch(reqs)?;
            return Ok(GatewayBatch {
                node: node.id,
                epoch_lo,
                epoch_hi,
                resps,
            });
        }
        bail!("no serving node for batch (membership empty or draining)")
    }
}
