//! The operator→node control transport.
//!
//! The two-phase publish protocol needs exactly two primitives from
//! its wire layer: send a control message to one node, and receive
//! the next reply from any node. [`Transport`] captures that surface;
//! [`ChannelTransport`] implements it over in-process mpsc channels
//! (one inbox per node, one shared reply lane back to the operator).
//! Because [`super::command::ClusterCommand`] and the message enums
//! are plain data, a socket transport can replace this without
//! touching the protocol in `plane.rs` or `node.rs`.

use super::command::ClusterCommand;
use std::collections::HashMap;
use std::fmt;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Mutex;
use std::time::Duration;

/// Cluster-unique node identifier, assigned at join time and never
/// reused.
pub type NodeId = usize;

/// Operator→node control messages.
#[derive(Debug)]
pub enum ControlMsg {
    /// Phase 1: validate + prepare `cmd` for `epoch`. Side effects
    /// must be invisible to routing until the commit.
    Stage { epoch: u64, cmd: ClusterCommand },
    /// Phase 2: flip the staged `epoch` into the published snapshot.
    Commit { epoch: u64 },
    /// Undo whatever `Stage { epoch }` prepared.
    Abort { epoch: u64 },
    /// Stop the node's control loop (leave/crash/teardown).
    Shutdown,
}

/// What a node reply means.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AckKind {
    Staged,
    Committed,
    Aborted,
    /// Validation or protocol rejection (stale epoch, failed apply).
    Nack(String),
}

/// Node→operator reply, tagged with the epoch it answers for so the
/// operator can discard stray late acks from timed-out publishes.
#[derive(Clone, Debug)]
pub struct ControlReply {
    pub node: NodeId,
    pub epoch: u64,
    pub kind: AckKind,
}

/// Send-side failure: the node is unknown (never attached or already
/// detached) or its control loop is gone.
#[derive(Debug)]
pub enum TransportError {
    Unknown(NodeId),
    Disconnected(NodeId),
}

impl fmt::Display for TransportError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TransportError::Unknown(id) => write!(f, "node {id} is not attached"),
            TransportError::Disconnected(id) => write!(f, "node {id} control loop is gone"),
        }
    }
}

impl std::error::Error for TransportError {}

/// The operator-side control channel surface.
pub trait Transport: Send + Sync {
    /// Deliver `msg` to `node`'s control loop.
    fn send(&self, node: NodeId, msg: ControlMsg) -> Result<(), TransportError>;
    /// Next reply from any node, or `None` after `timeout`.
    fn recv_reply(&self, timeout: Duration) -> Option<ControlReply>;
}

/// A node's end of the transport: its private inbox plus the shared
/// reply lane back to the operator.
pub struct NodeEndpoint {
    pub node: NodeId,
    pub inbox: Receiver<ControlMsg>,
    pub replies: Sender<ControlReply>,
}

/// In-process channel transport: one mpsc inbox per node, one shared
/// reply channel. Detaching a node drops the only sender to its
/// inbox, which unblocks its control loop with a disconnect.
pub struct ChannelTransport {
    peers: Mutex<HashMap<NodeId, Sender<ControlMsg>>>,
    reply_tx: Sender<ControlReply>,
    reply_rx: Mutex<Receiver<ControlReply>>,
}

impl ChannelTransport {
    pub fn new() -> ChannelTransport {
        let (reply_tx, reply_rx) = channel();
        ChannelTransport {
            peers: Mutex::new(HashMap::new()),
            reply_tx,
            reply_rx: Mutex::new(reply_rx),
        }
    }

    /// Create `node`'s inbox and hand back its endpoint. Replaces any
    /// previous attachment for the id (ids are never reused in
    /// practice).
    pub fn attach(&self, node: NodeId) -> NodeEndpoint {
        let (tx, rx) = channel();
        self.peers.lock().unwrap().insert(node, tx);
        NodeEndpoint {
            node,
            inbox: rx,
            replies: self.reply_tx.clone(),
        }
    }

    /// Forget `node`: subsequent sends fail and its control loop sees
    /// a disconnect once in-flight messages drain.
    pub fn detach(&self, node: NodeId) {
        self.peers.lock().unwrap().remove(&node);
    }
}

impl Default for ChannelTransport {
    fn default() -> Self {
        ChannelTransport::new()
    }
}

impl Transport for ChannelTransport {
    fn send(&self, node: NodeId, msg: ControlMsg) -> Result<(), TransportError> {
        let peers = self.peers.lock().unwrap();
        let tx = peers.get(&node).ok_or(TransportError::Unknown(node))?;
        tx.send(msg).map_err(|_| TransportError::Disconnected(node))
    }

    fn recv_reply(&self, timeout: Duration) -> Option<ControlReply> {
        // The transport holds its own reply_tx clone, so the channel
        // can never disconnect: a recv error here is purely a timeout.
        self.reply_rx.lock().unwrap().recv_timeout(timeout).ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_and_detach_semantics() {
        let t = ChannelTransport::new();
        let ep = t.attach(3);
        t.send(3, ControlMsg::Commit { epoch: 7 }).unwrap();
        match ep.inbox.recv().unwrap() {
            ControlMsg::Commit { epoch } => assert_eq!(epoch, 7),
            other => panic!("unexpected message: {other:?}"),
        }
        ep.replies
            .send(ControlReply {
                node: 3,
                epoch: 7,
                kind: AckKind::Committed,
            })
            .unwrap();
        let r = t.recv_reply(Duration::from_millis(100)).unwrap();
        assert_eq!(r.node, 3);
        assert_eq!(r.epoch, 7);
        assert_eq!(r.kind, AckKind::Committed);

        assert!(matches!(
            t.send(9, ControlMsg::Shutdown),
            Err(TransportError::Unknown(9))
        ));
        t.detach(3);
        assert!(matches!(
            t.send(3, ControlMsg::Shutdown),
            Err(TransportError::Unknown(3))
        ));
        // The node side observes the detach as a disconnect.
        assert!(ep.inbox.recv().is_err());
    }

    #[test]
    fn recv_reply_times_out_without_traffic() {
        let t = ChannelTransport::new();
        assert!(t.recv_reply(Duration::from_millis(10)).is_none());
    }
}
