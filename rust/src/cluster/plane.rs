//! The replicated control plane: desired state, the two-phase
//! publish, membership and node lifecycle.
//!
//! [`MuseCluster`] is the operator. It owns the committed command log
//! and the epoch counter off the request path (the Latchkey split:
//! the operator computes and replicates, nodes consume snapshots);
//! the gateway only ever reads the membership `SnapCell` it
//! publishes. All control-plane mutation — publish, join, leave,
//! crash — serializes on one mutex, so the protocol below never runs
//! concurrently with itself.
//!
//! ## Two-phase publish
//!
//! 1. **Stage**: send `Stage { epoch, cmd }` to every serving node;
//!    each validates and prepares with no routing-visible effect,
//!    then acks. Nodes that nack (validation failure — deterministic
//!    engines nack in unison) abort the publish cluster-wide; nodes
//!    that stay silent past the ack timeout are marked crashed and
//!    fenced out of the membership.
//! 2. **Flip**: send `Commit { epoch }` to every staged node; each
//!    flips its published snapshot (walking its epoch word through
//!    `2k -> 2k+1 -> 2k+2`) and acks. Silent or nacking nodes are
//!    fenced; as long as one node flips, the epoch commits and the
//!    command is appended to the replicated log.
//!
//! The committed log is what makes `join` safe: a new node replays it
//! epoch by epoch (stage + commit per entry, while still outside the
//! membership) and only then starts serving — it can never answer a
//! request from a world older than the committed epoch.

use super::command::ClusterCommand;
use super::gateway::{ClusterGateway, Membership};
use super::node::{node_loop, FaultPoint, NodeHandle, NodeState};
use super::transport::{AckKind, ChannelTransport, ControlMsg, NodeId, Transport};
use crate::config::MuseConfig;
use crate::coordinator::Engine;
use crate::metrics::LatencyHistogram;
use crate::runtime::ModelPool;
use crate::util::swap::SnapCell;
use anyhow::{anyhow, bail, ensure, Result};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Builds one node's model pool. Nodes do not share pools — each
/// replica loads its own experts, as separate processes would.
pub type PoolFactory = Box<dyn Fn() -> Result<Arc<ModelPool>> + Send + Sync>;

/// Cluster construction knobs.
#[derive(Clone, Copy, Debug)]
pub struct ClusterOptions {
    /// Initial node count.
    pub nodes: usize,
    /// Per-phase ack collection budget. In-process acks arrive in
    /// microseconds; this bounds how long a dead node can stall a
    /// publish before it is fenced.
    pub ack_timeout: Duration,
}

impl Default for ClusterOptions {
    fn default() -> ClusterOptions {
        ClusterOptions {
            nodes: 4,
            ack_timeout: Duration::from_millis(250),
        }
    }
}

/// Control-plane event counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct PublishStats {
    /// Committed publishes (the committed epoch equals this).
    pub publishes: u64,
    /// Publishes aborted by a validation nack.
    pub aborted: u64,
    /// Nodes fenced (timeout, commit nack, injected death, forced).
    pub crashes: u64,
    /// Nodes that joined (including the initial set).
    pub joins: u64,
    /// Graceful leaves.
    pub leaves: u64,
}

/// One node's row in the status report.
pub struct NodeStatus {
    pub id: NodeId,
    pub state: NodeState,
    /// Committed epoch the node last flipped to.
    pub epoch: u64,
    pub flipping: bool,
    pub lake_records: usize,
    /// Events scored on this node (live + batch).
    pub scored: u64,
}

/// The `/v1/cluster` view.
pub struct ClusterStatus {
    pub committed_epoch: u64,
    pub stats: PublishStats,
    pub flip_p50_ms: f64,
    pub flip_p99_ms: f64,
    pub nodes: Vec<NodeStatus>,
}

struct PlaneInner {
    /// Every node ever created, in join order. Crashed and left nodes
    /// stay here: their engines still hold scored history, and
    /// cluster-wide conservation is accounted over all of them.
    nodes: Vec<Arc<NodeHandle>>,
    threads: Vec<thread::JoinHandle<()>>,
    committed: u64,
    log: Vec<ClusterCommand>,
    next_id: NodeId,
    stats: PublishStats,
}

/// The cluster: replicated control plane + membership + gateway.
pub struct MuseCluster {
    config: MuseConfig,
    pools: PoolFactory,
    opts: ClusterOptions,
    transport: Arc<ChannelTransport>,
    members: Arc<SnapCell<Membership>>,
    gateway: Arc<ClusterGateway>,
    /// Stage-send to last-commit-ack latency per committed publish.
    flip_latency: LatencyHistogram,
    inner: Mutex<PlaneInner>,
}

impl MuseCluster {
    /// Build a cluster of `opts.nodes` replicas of `config`, each
    /// with its own engine and model pool.
    pub fn build(
        config: &MuseConfig,
        opts: ClusterOptions,
        pools: PoolFactory,
    ) -> Result<Arc<MuseCluster>> {
        ensure!(opts.nodes >= 1, "cluster needs at least one node");
        config.validate()?;
        let members = Arc::new(SnapCell::new(Arc::new(Membership { nodes: Vec::new() })));
        let cluster = Arc::new(MuseCluster {
            config: config.clone(),
            pools,
            opts,
            transport: Arc::new(ChannelTransport::new()),
            gateway: Arc::new(ClusterGateway::new(Arc::clone(&members))),
            members,
            flip_latency: LatencyHistogram::new(),
            inner: Mutex::new(PlaneInner {
                nodes: Vec::new(),
                threads: Vec::new(),
                committed: 0,
                log: Vec::new(),
                next_id: 0,
                stats: PublishStats::default(),
            }),
        });
        for _ in 0..opts.nodes {
            cluster.join()?;
        }
        Ok(cluster)
    }

    /// The scoring front door.
    pub fn gateway(&self) -> Arc<ClusterGateway> {
        Arc::clone(&self.gateway)
    }

    pub fn committed_epoch(&self) -> u64 {
        self.inner.lock().unwrap().committed
    }

    pub fn stats(&self) -> PublishStats {
        self.inner.lock().unwrap().stats
    }

    pub fn options(&self) -> ClusterOptions {
        self.opts
    }

    /// Every node ever created (serving, draining, left and crashed) —
    /// the aggregation domain for cluster-wide conservation checks.
    pub fn nodes(&self) -> Vec<Arc<NodeHandle>> {
        self.inner.lock().unwrap().nodes.clone()
    }

    /// Nodes currently in the membership.
    pub fn serving_nodes(&self) -> Vec<Arc<NodeHandle>> {
        self.members.load().nodes.clone()
    }

    pub fn command_log_len(&self) -> usize {
        self.inner.lock().unwrap().log.len()
    }

    /// Flip latency (stage send to last commit ack) percentile;
    /// `p` is in `[0, 100]` like [`crate::metrics::LatencyHistogram`].
    pub fn flip_percentile_ms(&self, p: f64) -> f64 {
        self.flip_latency.percentile_ns(p) as f64 / 1e6
    }

    /// Replicate `cmd` to every serving node via two-phase publish.
    /// Returns the committed epoch; `Err` means the cluster state is
    /// unchanged (validation abort) or no node survived the flip.
    pub fn publish(&self, cmd: ClusterCommand) -> Result<u64> {
        let mut inner = self.inner.lock().unwrap();
        let epoch = inner.committed + 1;
        let targets: Vec<Arc<NodeHandle>> = inner
            .nodes
            .iter()
            .filter(|n| n.state() == NodeState::Serving)
            .cloned()
            .collect();
        ensure!(!targets.is_empty(), "publish with no serving nodes");
        let t0 = Instant::now();

        // Phase 1: stage everywhere.
        let mut awaiting: Vec<NodeId> = Vec::new();
        let mut dead: Vec<NodeId> = Vec::new();
        for n in &targets {
            match self.transport.send(
                n.id,
                ControlMsg::Stage {
                    epoch,
                    cmd: cmd.clone(),
                },
            ) {
                Ok(()) => awaiting.push(n.id),
                Err(_) => dead.push(n.id),
            }
        }
        let mut staged: Vec<NodeId> = Vec::new();
        let mut nacks: Vec<(NodeId, String)> = Vec::new();
        self.collect(epoch, &mut awaiting, |id, kind| match kind {
            AckKind::Staged => staged.push(id),
            AckKind::Nack(reason) => nacks.push((id, reason)),
            _ => {}
        });
        dead.append(&mut awaiting); // silent past the timeout: crashed mid-phase-1

        if let Some((nacker, reason)) = nacks.first().cloned() {
            // Validation failed. Unwind the staged nodes so the epoch
            // does not advance anywhere, then surface the nack.
            let mut aborting: Vec<NodeId> = Vec::new();
            for &id in &staged {
                if self
                    .transport
                    .send(id, ControlMsg::Abort { epoch })
                    .is_ok()
                {
                    aborting.push(id);
                }
            }
            self.collect(epoch, &mut aborting, |_, _| {});
            self.fence(&mut inner, &dead);
            inner.stats.aborted += 1;
            self.republish_members(&inner);
            bail!("publish rejected at stage by node {nacker}: {reason}");
        }

        if staged.is_empty() {
            self.fence(&mut inner, &dead);
            self.republish_members(&inner);
            bail!("all serving nodes lost during stage of epoch {epoch}");
        }

        // Phase 2: flip every staged node.
        let mut committing: Vec<NodeId> = Vec::new();
        for &id in &staged {
            match self.transport.send(id, ControlMsg::Commit { epoch }) {
                Ok(()) => committing.push(id),
                Err(_) => dead.push(id),
            }
        }
        let mut committed_nodes = 0usize;
        self.collect(epoch, &mut committing, |id, kind| match kind {
            AckKind::Committed => committed_nodes += 1,
            // A commit nack (stale epoch, failed apply) means the node
            // diverged from the replicated state machine: fence it.
            _ => dead.push(id),
        });
        dead.append(&mut committing); // silent mid-flip: crashed, fenced

        self.fence(&mut inner, &dead);
        if committed_nodes == 0 {
            self.republish_members(&inner);
            bail!("no node survived the flip of epoch {epoch}");
        }
        inner.committed = epoch;
        inner.log.push(cmd);
        inner.stats.publishes += 1;
        self.flip_latency.record(t0.elapsed().as_nanos() as u64);
        self.republish_members(&inner);
        Ok(epoch)
    }

    /// Spin up a new node and catch it up: it replays the committed
    /// command log while still outside the membership (staged state,
    /// no traffic), then starts serving. Serialized with publishes by
    /// the plane mutex, so the log cannot move under the replay.
    pub fn join(&self) -> Result<NodeId> {
        let mut inner = self.inner.lock().unwrap();
        let id = inner.next_id;
        inner.next_id += 1;
        let endpoint = self.transport.attach(id);
        let pool = (self.pools)()?;
        let engine = Arc::new(Engine::build(&self.config, pool)?);
        let node = Arc::new(NodeHandle::new(id, engine, NodeState::Joining));
        let handle = {
            let n = Arc::clone(&node);
            thread::Builder::new()
                .name(format!("muse-node-{id}"))
                .spawn(move || node_loop(n, endpoint))?
        };
        inner.nodes.push(Arc::clone(&node));
        inner.threads.push(handle);

        let log: Vec<(u64, ClusterCommand)> = inner
            .log
            .iter()
            .cloned()
            .enumerate()
            .map(|(i, c)| ((i + 1) as u64, c))
            .collect();
        for (epoch, cmd) in log {
            // Committed commands were valid when they committed and
            // replay deterministically; any failure here is a real
            // divergence, so the node never joins.
            if let Err(err) = self.replay_step(id, epoch, cmd) {
                node.set_state(NodeState::Crashed);
                self.transport.detach(id);
                inner.stats.crashes += 1;
                bail!("node {id} failed catch-up at epoch {epoch}: {err:#}");
            }
        }
        node.set_state(NodeState::Serving);
        inner.stats.joins += 1;
        self.republish_members(&inner);
        Ok(id)
    }

    /// Graceful leave: out of the membership first, then settle the
    /// node's shadow mirrors, then stop its control loop. The engine
    /// (and its scored history) stays owned by the cluster.
    pub fn leave(&self, id: NodeId) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        let node = self.find(&inner, id)?;
        ensure!(
            node.state() == NodeState::Serving,
            "node {id} is {} — only serving nodes can leave",
            node.state().name()
        );
        node.set_state(NodeState::Draining);
        self.republish_members(&inner);
        node.engine.drain_shadows();
        node.set_state(NodeState::Left);
        let _ = self.transport.send(id, ControlMsg::Shutdown);
        self.transport.detach(id);
        inner.stats.leaves += 1;
        self.republish_members(&inner);
        Ok(())
    }

    /// Forced node death (fault injection): fence immediately, no
    /// drain. In-flight requests on the node still complete — the
    /// engine is consistent; the node is simply no longer routable.
    pub fn crash(&self, id: NodeId) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        let node = self.find(&inner, id)?;
        ensure!(
            matches!(node.state(), NodeState::Serving | NodeState::Draining),
            "node {id} is already {}",
            node.state().name()
        );
        node.set_state(NodeState::Crashed);
        inner.stats.crashes += 1;
        let _ = self.transport.send(id, ControlMsg::Shutdown);
        self.transport.detach(id);
        self.republish_members(&inner);
        Ok(())
    }

    /// Arm a publish-protocol fault on one node (see [`FaultPoint`]).
    pub fn arm_fault(&self, id: NodeId, fault: FaultPoint) -> Result<()> {
        let inner = self.inner.lock().unwrap();
        self.find(&inner, id)?.arm_fault(fault);
        Ok(())
    }

    /// The `/v1/cluster` status snapshot.
    pub fn status(&self) -> ClusterStatus {
        let inner = self.inner.lock().unwrap();
        let nodes = inner
            .nodes
            .iter()
            .map(|n| NodeStatus {
                id: n.id,
                state: n.state(),
                epoch: n.committed_epoch(),
                flipping: n.is_flipping(),
                lake_records: n.engine.lake.len(),
                scored: n.engine.counters.get("requests_live")
                    + n.engine.counters.get("events_batch"),
            })
            .collect();
        ClusterStatus {
            committed_epoch: inner.committed,
            stats: inner.stats,
            flip_p50_ms: self.flip_percentile_ms(50.0),
            flip_p99_ms: self.flip_percentile_ms(99.0),
            nodes,
        }
    }

    fn find(&self, inner: &PlaneInner, id: NodeId) -> Result<Arc<NodeHandle>> {
        inner
            .nodes
            .iter()
            .find(|n| n.id == id)
            .cloned()
            .ok_or_else(|| anyhow!("unknown node {id}"))
    }

    /// Collect replies for `epoch` from the nodes in `awaiting` until
    /// all answered or the ack budget runs out; answered ids are
    /// removed, stragglers remain for the caller to fence.
    fn collect(&self, epoch: u64, awaiting: &mut Vec<NodeId>, mut on_ack: impl FnMut(NodeId, AckKind)) {
        let deadline = Instant::now() + self.opts.ack_timeout;
        while !awaiting.is_empty() {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let Some(reply) = self.transport.recv_reply(deadline - now) else {
                break;
            };
            if reply.epoch != epoch {
                continue; // stray late ack from a fenced publish
            }
            let Some(pos) = awaiting.iter().position(|&id| id == reply.node) else {
                continue;
            };
            awaiting.swap_remove(pos);
            on_ack(reply.node, reply.kind);
        }
    }

    /// One stage+commit round against a single (joining) node.
    fn replay_step(&self, id: NodeId, epoch: u64, cmd: ClusterCommand) -> Result<()> {
        self.transport
            .send(id, ControlMsg::Stage { epoch, cmd })
            .map_err(|e| anyhow!("{e}"))?;
        self.await_ack(id, epoch, AckKind::Staged)?;
        self.transport
            .send(id, ControlMsg::Commit { epoch })
            .map_err(|e| anyhow!("{e}"))?;
        self.await_ack(id, epoch, AckKind::Committed)?;
        Ok(())
    }

    fn await_ack(&self, id: NodeId, epoch: u64, want: AckKind) -> Result<()> {
        let deadline = Instant::now() + self.opts.ack_timeout;
        loop {
            let now = Instant::now();
            if now >= deadline {
                bail!("node {id} ack timeout at epoch {epoch}");
            }
            let Some(reply) = self.transport.recv_reply(deadline - now) else {
                continue;
            };
            if reply.node != id || reply.epoch != epoch {
                continue; // stray late ack from a fenced publish
            }
            ensure!(
                reply.kind == want,
                "node {id} replied {:?} to epoch {epoch} (wanted {want:?})",
                reply.kind
            );
            return Ok(());
        }
    }

    /// Mark `ids` crashed and cut their transport. Idempotent per
    /// node (a self-crashed node is only counted once).
    fn fence(&self, inner: &mut PlaneInner, ids: &[NodeId]) {
        for &id in ids {
            if let Some(node) = inner.nodes.iter().find(|n| n.id == id) {
                if node.state() != NodeState::Crashed {
                    inner.stats.crashes += 1;
                }
                node.set_state(NodeState::Crashed);
            }
            self.transport.detach(id);
        }
    }

    /// Publish the membership (serving nodes only) for the gateway.
    fn republish_members(&self, inner: &PlaneInner) {
        let nodes = inner
            .nodes
            .iter()
            .filter(|n| n.state() == NodeState::Serving)
            .cloned()
            .collect();
        self.members.store(Arc::new(Membership { nodes }));
    }
}

impl Drop for MuseCluster {
    fn drop(&mut self) {
        let mut inner = self.inner.lock().unwrap();
        let ids: Vec<NodeId> = inner.nodes.iter().map(|n| n.id).collect();
        for id in ids {
            let _ = self.transport.send(id, ControlMsg::Shutdown);
            self.transport.detach(id);
        }
        for handle in inner.threads.drain(..) {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::node::FaultPoint;
    use crate::config::{
        Condition, Intent, LifecycleConfig, PredictorConfig, QuantileMode, RoutingConfig,
        ScoringRule, ServerConfig,
    };
    use crate::coordinator::ScoreRequest;
    use crate::runtime::{Manifest, SimArtifacts};

    fn test_config(tenants: &[&str], pred: &str) -> MuseConfig {
        let mut scoring_rules: Vec<ScoringRule> = tenants
            .iter()
            .map(|t| ScoringRule {
                description: format!("dedicated {t}"),
                condition: Condition {
                    tenants: vec![t.to_string()],
                    ..Condition::default()
                },
                target_predictor: pred.into(),
            })
            .collect();
        scoring_rules.push(ScoringRule {
            description: "catch-all".to_string(),
            condition: Condition::default(),
            target_predictor: pred.into(),
        });
        MuseConfig {
            routing: RoutingConfig {
                scoring_rules,
                shadow_rules: Vec::new(),
            },
            predictors: vec![predictor_cfg(pred)],
            server: ServerConfig {
                workers: 2,
                ..ServerConfig::default()
            },
            lifecycle: LifecycleConfig::default(),
        }
    }

    fn predictor_cfg(name: &str) -> PredictorConfig {
        PredictorConfig {
            name: name.to_string(),
            experts: vec!["s1".to_string()],
            weights: vec![1.0],
            quantile_mode: QuantileMode::Identity,
            reference: "fraud-default".to_string(),
            posterior_correction: false,
        }
    }

    fn build_cluster(fix: &SimArtifacts, nodes: usize) -> Arc<MuseCluster> {
        let config = test_config(&["t0", "t1", "t2"], "base");
        let root = fix.root().clone();
        let factory: PoolFactory =
            Box::new(move || Ok(Arc::new(ModelPool::new(Manifest::load(&root)?))));
        MuseCluster::build(
            &config,
            ClusterOptions {
                nodes,
                ack_timeout: Duration::from_millis(150),
            },
            factory,
        )
        .unwrap()
    }

    fn req(tenant: &str, i: usize) -> ScoreRequest {
        ScoreRequest {
            intent: Intent {
                tenant: tenant.to_string(),
                ..Intent::default()
            },
            entity: format!("e{i}"),
            features: vec![0.25, 0.5, 0.75],
        }
    }

    fn shadow_deploy(name: &str, tenant: &str) -> ClusterCommand {
        ClusterCommand::ShadowDeploy {
            cfg: predictor_cfg(name),
            tenant: tenant.to_string(),
            src: vec![0.0, 1.0],
            refq: vec![0.0, 1.0],
        }
    }

    #[test]
    fn two_phase_publish_replicates_to_all_nodes() {
        let fix = SimArtifacts::in_temp().unwrap();
        let cluster = build_cluster(&fix, 3);
        cluster.publish(shadow_deploy("cand", "t0")).unwrap();
        cluster
            .publish(ClusterCommand::Promote {
                tenant: "t0".to_string(),
                predictor: "cand".to_string(),
            })
            .unwrap();
        assert_eq!(cluster.committed_epoch(), 2);
        for node in cluster.nodes() {
            assert_eq!(node.state(), NodeState::Serving);
            assert_eq!(node.committed_epoch(), 2);
            assert!(!node.is_flipping());
            assert!(node.engine.registry.get("cand").is_some());
            let res = node
                .engine
                .router
                .resolve(&Intent {
                    tenant: "t0".to_string(),
                    ..Intent::default()
                })
                .unwrap();
            assert_eq!(&*res.predictor, "cand");
        }
        let gw = cluster.gateway();
        let r = gw.score(&req("t0", 0)).unwrap();
        assert_eq!(&*r.resp.predictor, "cand");
        assert_eq!(r.epoch_lo, 2);
        assert_eq!(r.epoch_hi, 2);
    }

    #[test]
    fn invalid_command_aborts_cluster_wide() {
        let fix = SimArtifacts::in_temp().unwrap();
        let cluster = build_cluster(&fix, 3);
        let err = cluster
            .publish(ClusterCommand::Promote {
                tenant: "t0".to_string(),
                predictor: "ghost".to_string(),
            })
            .unwrap_err();
        assert!(err.to_string().contains("ghost"), "got: {err}");
        assert_eq!(cluster.committed_epoch(), 0);
        assert_eq!(cluster.stats().aborted, 1);
        for node in cluster.nodes() {
            assert_eq!(node.state(), NodeState::Serving);
            assert_eq!(node.committed_epoch(), 0);
        }
        // An aborted staged deploy must be fully unwound too: a
        // duplicate deploy nacks on every node, and the registry keeps
        // exactly one copy from the earlier committed publish.
        cluster.publish(shadow_deploy("cand", "t0")).unwrap();
        let err = cluster.publish(shadow_deploy("cand", "t1")).unwrap_err();
        assert!(err.to_string().contains("cand"), "got: {err}");
        assert_eq!(cluster.committed_epoch(), 1);
        for node in cluster.nodes() {
            assert!(node.engine.registry.get("cand").is_some());
            assert_eq!(node.engine.registry.names().len(), 2); // base + cand
        }
    }

    #[test]
    fn crash_before_stage_ack_proceeds_with_survivors() {
        let fix = SimArtifacts::in_temp().unwrap();
        let cluster = build_cluster(&fix, 3);
        cluster.publish(shadow_deploy("cand", "t0")).unwrap();
        let victim = cluster.nodes()[1].id;
        cluster.arm_fault(victim, FaultPoint::CrashBeforeStageAck).unwrap();
        cluster
            .publish(ClusterCommand::Promote {
                tenant: "t0".to_string(),
                predictor: "cand".to_string(),
            })
            .unwrap();
        assert_eq!(cluster.committed_epoch(), 2);
        assert_eq!(cluster.serving_nodes().len(), 2);
        assert_eq!(cluster.stats().crashes, 1);
        for node in cluster.nodes() {
            if node.id == victim {
                assert_eq!(node.state(), NodeState::Crashed);
                assert_eq!(node.committed_epoch(), 1); // never staged epoch 2
            } else {
                assert_eq!(node.committed_epoch(), 2);
            }
        }
        // Traffic the victim owned fails over: every tenant scores.
        let gw = cluster.gateway();
        for t in ["t0", "t1", "t2"] {
            let r = gw.score(&req(t, 1)).unwrap();
            assert_ne!(r.node, victim);
        }
    }

    #[test]
    fn crash_mid_flip_fences_node_and_survivors_commit() {
        let fix = SimArtifacts::in_temp().unwrap();
        let cluster = build_cluster(&fix, 3);
        cluster.publish(shadow_deploy("cand", "t1")).unwrap();
        let victim = cluster.nodes()[2].id;
        cluster
            .arm_fault(victim, FaultPoint::CrashBeforeCommitApply)
            .unwrap();
        cluster
            .publish(ClusterCommand::Promote {
                tenant: "t1".to_string(),
                predictor: "cand".to_string(),
            })
            .unwrap();
        assert_eq!(cluster.committed_epoch(), 2);
        let victim_node = cluster
            .nodes()
            .into_iter()
            .find(|n| n.id == victim)
            .unwrap();
        // Staged but never applied: fenced at the old epoch, and its
        // routing still targets the old predictor — which is exactly
        // why it must never serve again.
        assert_eq!(victim_node.state(), NodeState::Crashed);
        assert_eq!(victim_node.committed_epoch(), 1);
        let res = victim_node
            .engine
            .router
            .resolve(&Intent {
                tenant: "t1".to_string(),
                ..Intent::default()
            })
            .unwrap();
        assert_eq!(&*res.predictor, "base");
        for node in cluster.serving_nodes() {
            assert_eq!(node.committed_epoch(), 2);
        }
    }

    #[test]
    fn stale_epoch_commit_is_rejected_at_the_node() {
        // Drive one node's control loop directly: a commit for an
        // epoch that was never staged must nack, not apply.
        let fix = SimArtifacts::in_temp().unwrap();
        let config = test_config(&["t0"], "base");
        let pool = Arc::new(ModelPool::new(fix.manifest().unwrap()));
        let engine = Arc::new(Engine::build(&config, pool).unwrap());
        let transport = ChannelTransport::new();
        let endpoint = transport.attach(0);
        let node = Arc::new(NodeHandle::new(0, engine, NodeState::Serving));
        let handle = {
            let n = Arc::clone(&node);
            thread::spawn(move || node_loop(n, endpoint))
        };
        transport.send(0, ControlMsg::Commit { epoch: 5 }).unwrap();
        let reply = transport.recv_reply(Duration::from_secs(1)).unwrap();
        assert_eq!(reply.epoch, 5);
        assert!(
            matches!(reply.kind, AckKind::Nack(ref r) if r.contains("stale")),
            "got: {:?}",
            reply.kind
        );
        assert_eq!(node.committed_epoch(), 0);

        // And an abort for a staged epoch unwinds the staged deploy.
        transport
            .send(
                0,
                ControlMsg::Stage {
                    epoch: 1,
                    cmd: shadow_deploy("cand", "t0"),
                },
            )
            .unwrap();
        let reply = transport.recv_reply(Duration::from_secs(1)).unwrap();
        assert_eq!(reply.kind, AckKind::Staged);
        assert!(node.engine.registry.get("cand").is_some());
        transport.send(0, ControlMsg::Abort { epoch: 1 }).unwrap();
        let reply = transport.recv_reply(Duration::from_secs(1)).unwrap();
        assert_eq!(reply.kind, AckKind::Aborted);
        assert!(node.engine.registry.get("cand").is_none());
        assert_eq!(node.committed_epoch(), 0);

        transport.send(0, ControlMsg::Shutdown).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn join_replays_log_and_takes_traffic() {
        let fix = SimArtifacts::in_temp().unwrap();
        let cluster = build_cluster(&fix, 2);
        cluster.publish(shadow_deploy("cand", "t2")).unwrap();
        cluster
            .publish(ClusterCommand::Promote {
                tenant: "t2".to_string(),
                predictor: "cand".to_string(),
            })
            .unwrap();
        let id = cluster.join().unwrap();
        assert_eq!(cluster.serving_nodes().len(), 3);
        let joined = cluster.nodes().into_iter().find(|n| n.id == id).unwrap();
        assert_eq!(joined.committed_epoch(), 2);
        assert!(joined.engine.registry.get("cand").is_some());
        let res = joined
            .engine
            .router
            .resolve(&Intent {
                tenant: "t2".to_string(),
                ..Intent::default()
            })
            .unwrap();
        assert_eq!(&*res.predictor, "cand");
        // The joined node answers identically to the rest of the fleet.
        let gw = cluster.gateway();
        let r = gw.score(&req("t2", 3)).unwrap();
        assert_eq!(&*r.resp.predictor, "cand");
    }

    #[test]
    fn leave_drains_and_gateway_reroutes() {
        let fix = SimArtifacts::in_temp().unwrap();
        let cluster = build_cluster(&fix, 2);
        let gone = cluster.nodes()[0].id;
        cluster.leave(gone).unwrap();
        assert_eq!(cluster.serving_nodes().len(), 1);
        assert_eq!(cluster.stats().leaves, 1);
        let gw = cluster.gateway();
        for t in ["t0", "t1", "t2"] {
            let r = gw.score(&req(t, 4)).unwrap();
            assert_ne!(r.node, gone);
        }
        // Leaving twice is an error, as is leaving while not serving.
        assert!(cluster.leave(gone).is_err());
    }

    #[test]
    fn rendezvous_routing_is_stable_until_membership_changes() {
        let fix = SimArtifacts::in_temp().unwrap();
        let cluster = build_cluster(&fix, 4);
        let gw = cluster.gateway();
        let owner = gw.score(&req("t1", 0)).unwrap().node;
        for i in 1..8 {
            assert_eq!(gw.score(&req("t1", i)).unwrap().node, owner);
        }
        cluster.crash(owner).unwrap();
        let next = gw.score(&req("t1", 9)).unwrap().node;
        assert_ne!(next, owner);
        for i in 10..14 {
            assert_eq!(gw.score(&req("t1", i)).unwrap().node, next);
        }
    }
}
