//! The cluster plane: N in-process serving nodes behind one gateway,
//! with a replicated control plane that publishes every model update
//! through a **two-phase publish** so no event anywhere in the fleet
//! is ever scored by a mixed-version view.
//!
//! The paper's deployment is a fleet, not a process: a promote must
//! flip atomically across every serving replica while the request
//! path keeps running (PAPER.md §2.5 — rolling updates + warm-up).
//! PRs 1–7 reproduced seamlessness *inside* one `Engine`; this module
//! is the layer above it:
//!
//! * [`node::NodeHandle`] — one serving replica: an unmodified
//!   [`crate::coordinator::Engine`] plus a control thread that stages
//!   and commits replicated commands, and an epoch word that stamps
//!   every response with the snapshot generation(s) it could have
//!   been scored under.
//! * [`transport::Transport`] — the operator→node control channel.
//!   The in-process [`transport::ChannelTransport`] is the only
//!   implementation today; commands are plain data
//!   ([`command::ClusterCommand`]) so a socket transport can slot in
//!   without touching the protocol.
//! * [`gateway::ClusterGateway`] — tenant-consistent request routing
//!   by rendezvous (highest-random-weight) hashing over the live
//!   membership, with fail-over to the next-best node when the owner
//!   is gone. Scoring never blocks on the control plane.
//! * [`plane::MuseCluster`] — the replicated control plane. It owns
//!   desired state off the request path (the Latchkey split: the
//!   operator computes, nodes consume) and drives the two-phase
//!   publish: phase 1 **stages** the command on every serving node
//!   (validation + side effects invisible to routing) and collects
//!   acks; phase 2 **commits**, flipping each node's published
//!   snapshot. Nodes that never ack are timed out, marked crashed and
//!   fenced out of the membership; survivors flip. A committed
//!   command is appended to the replicated log so a joining node can
//!   replay its way to the committed epoch before taking traffic.
//!
//! ## Epoch rules
//!
//! Each node carries one `AtomicU64` epoch word: value `2k` means
//! "stable at committed epoch `k`", `2k+1` means "flipping from `k`
//! to `k+1`". A scoring call reads the word before and after the
//! engine call; the response is then attributable to the closed
//! window `[e1 >> 1, (e2 >> 1) + (e2 & 1)]` of committed epochs. With
//! no concurrent publish the window is a single epoch; racing a flip
//! widens it to exactly the two adjacent epochs. The cluster-wide
//! seamlessness invariant (verified by the testkit cluster runner and
//! the `cluster_storm` scenario) is that every response equals the
//! oracle's answer at *some* epoch inside its window — i.e. no torn,
//! mixed-version scoring, ever.
//!
//! ## Failure matrix
//!
//! | crash point            | node state            | cluster outcome |
//! |------------------------|-----------------------|-----------------|
//! | before stage ack       | nothing staged        | operator times the node out, marks it crashed, proceeds with survivors |
//! | after stage ack, before commit apply | staged, never flips | survivors flip; the node is fenced at the old epoch |
//! | mid-flip (after apply, before commit ack) | flipped | survivors flip; the node is fenced but consistent |
//! | stale-epoch commit     | rejected (`Nack`)     | defensive: an out-of-protocol commit never applies |
//!
//! A validation `Nack` (deterministic engines nack in unison) aborts
//! the publish cluster-wide: staged side effects are undone on every
//! node and the epoch does not advance — outcome parity with the
//! single-node control plane.

pub mod command;
pub mod gateway;
pub mod node;
pub mod plane;
pub mod transport;

pub use command::ClusterCommand;
pub use gateway::{ClusterGateway, GatewayBatch, GatewayResponse, Membership};
pub use node::{EpochScored, FaultPoint, NodeHandle, NodeState};
pub use plane::{ClusterOptions, ClusterStatus, MuseCluster, NodeStatus, PoolFactory, PublishStats};
pub use transport::{
    AckKind, ChannelTransport, ControlMsg, ControlReply, NodeEndpoint, NodeId, Transport,
    TransportError,
};
