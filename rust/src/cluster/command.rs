//! Replicated control commands, as plain data.
//!
//! The cluster control plane replicates every update command to every
//! serving node, so the command itself must be self-contained: no
//! `Arc`s into one node's state, no prebuilt `QuantileMap`s — each
//! node rebuilds derived state from the raw grids during its stage
//! phase. That keeps the enum trivially serializable for a future
//! socket transport and makes the replicated log replayable on a
//! joining node.

use crate::config::PredictorConfig;

/// One cluster-wide control command. Mirrors the single-node
/// `coordinator::deployment::ControlPlane` surface (shadow deploy,
/// promote, decommission, quantile install) — the node's stage/commit
/// split decomposes each into a routing-invisible preparation step
/// and a single snapshot flip.
#[derive(Clone, Debug)]
pub enum ClusterCommand {
    /// Deploy `cfg` and shadow it for `tenant`. `src`/`refq` are the
    /// quantile alignment grids (monotone, equal length >= 2).
    ShadowDeploy {
        cfg: PredictorConfig,
        tenant: String,
        src: Vec<f64>,
        refq: Vec<f64>,
    },
    /// Flip `tenant`'s live traffic to `predictor`.
    Promote { tenant: String, predictor: String },
    /// Remove `predictor` from routing and the registry.
    Decommission { predictor: String },
    /// Install a per-tenant quantile override on `predictor`.
    InstallTenantQuantile {
        predictor: String,
        tenant: String,
        src: Vec<f64>,
        refq: Vec<f64>,
    },
    /// Swap `predictor`'s default quantile map.
    SetDefaultQuantile {
        predictor: String,
        src: Vec<f64>,
        refq: Vec<f64>,
    },
}

impl ClusterCommand {
    /// Short human-readable label for logs and status output.
    pub fn describe(&self) -> String {
        match self {
            ClusterCommand::ShadowDeploy { cfg, tenant, .. } => {
                format!("shadow-deploy {} for {tenant}", cfg.name)
            }
            ClusterCommand::Promote { tenant, predictor } => {
                format!("promote {predictor} for {tenant}")
            }
            ClusterCommand::Decommission { predictor } => format!("decommission {predictor}"),
            ClusterCommand::InstallTenantQuantile {
                predictor, tenant, ..
            } => format!("install quantile {predictor}/{tenant}"),
            ClusterCommand::SetDefaultQuantile { predictor, .. } => {
                format!("set default quantile {predictor}")
            }
        }
    }
}
