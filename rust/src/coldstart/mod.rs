//! The cold-start problem (paper Section 2.4): serving a brand-new
//! client with no historical data by deriving a *default* quantile
//! transformation from a bimodal Beta mixture fitted to the training
//! score distribution (Eqs. 6-8).

pub mod beta;
pub mod fit;
pub mod mixture;

pub use beta::Beta;
pub use fit::{fit_mixture, FitConfig, MixtureFit};
pub use mixture::BetaMixture;
