//! Beta-distribution special functions, from scratch (no statrs
//! offline): log-gamma (Lanczos), regularized incomplete beta
//! (Lentz continued fraction), PDF/CDF/inverse-CDF.
//!
//! These underpin the cold-start prior (paper Section 2.4: a bimodal
//! Beta mixture fitted to the training score distribution) and the
//! configurable reference distribution R.

use anyhow::{ensure, Result};

/// Lanczos approximation of ln Γ(x), g = 7, n = 9 coefficients.
/// Absolute error < 1e-13 for x > 0.
pub fn ln_gamma(x: f64) -> f64 {
    const G: f64 = 7.0;
    const COEF: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1_259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_571_6e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // Reflection formula.
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + G + 0.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// ln B(a, b) = ln Γ(a) + ln Γ(b) - ln Γ(a+b).
pub fn ln_beta(a: f64, b: f64) -> f64 {
    ln_gamma(a) + ln_gamma(b) - ln_gamma(a + b)
}

/// Regularized incomplete beta I_x(a, b) via the continued fraction
/// (Numerical Recipes 6.4, modified Lentz). Relative error ~1e-14.
pub fn inc_beta(a: f64, b: f64, x: f64) -> f64 {
    assert!(a > 0.0 && b > 0.0, "inc_beta requires a,b > 0");
    if x <= 0.0 {
        return 0.0;
    }
    if x >= 1.0 {
        return 1.0;
    }
    let ln_front = a * x.ln() + b * (1.0 - x).ln() - ln_beta(a, b);
    let front = ln_front.exp();
    // Use the symmetry relation to keep the continued fraction stable.
    if x < (a + 1.0) / (a + b + 2.0) {
        front * betacf(a, b, x) / a
    } else {
        1.0 - (-ln_beta(a, b) + b * (1.0 - x).ln() + a * x.ln()).exp() * betacf(b, a, 1.0 - x) / b
    }
}

fn betacf(a: f64, b: f64, x: f64) -> f64 {
    const MAX_ITER: usize = 300;
    const EPS: f64 = 3e-15;
    const FPMIN: f64 = 1e-300;
    let qab = a + b;
    let qap = a + 1.0;
    let qam = a - 1.0;
    let mut c = 1.0;
    let mut d = 1.0 - qab * x / qap;
    if d.abs() < FPMIN {
        d = FPMIN;
    }
    d = 1.0 / d;
    let mut h = d;
    for m in 1..=MAX_ITER {
        let m = m as f64;
        let m2 = 2.0 * m;
        // Even step.
        let aa = m * (b - m) * x / ((qam + m2) * (a + m2));
        d = 1.0 + aa * d;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = 1.0 + aa / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        h *= d * c;
        // Odd step.
        let aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2));
        d = 1.0 + aa * d;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = 1.0 + aa / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < EPS {
            break;
        }
    }
    h
}

/// A Beta(alpha, beta) distribution on [0, 1].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Beta {
    pub alpha: f64,
    pub beta: f64,
}

impl Beta {
    pub fn new(alpha: f64, beta: f64) -> Result<Self> {
        ensure!(
            alpha > 0.0 && beta > 0.0 && alpha.is_finite() && beta.is_finite(),
            "Beta parameters must be positive and finite, got ({alpha}, {beta})"
        );
        Ok(Beta { alpha, beta })
    }

    pub fn mean(&self) -> f64 {
        self.alpha / (self.alpha + self.beta)
    }

    pub fn variance(&self) -> f64 {
        let s = self.alpha + self.beta;
        self.alpha * self.beta / (s * s * (s + 1.0))
    }

    /// r-th raw moment: E[X^r] = prod_{j=0}^{r-1} (a+j)/(a+b+j).
    pub fn raw_moment(&self, r: u32) -> f64 {
        let mut m = 1.0;
        for j in 0..r {
            m *= (self.alpha + j as f64) / (self.alpha + self.beta + j as f64);
        }
        m
    }

    pub fn pdf(&self, x: f64) -> f64 {
        if !(0.0..=1.0).contains(&x) {
            return 0.0;
        }
        if x == 0.0 {
            return if self.alpha < 1.0 {
                f64::INFINITY
            } else if self.alpha == 1.0 {
                self.beta
            } else {
                0.0
            };
        }
        if x == 1.0 {
            return if self.beta < 1.0 {
                f64::INFINITY
            } else if self.beta == 1.0 {
                self.alpha
            } else {
                0.0
            };
        }
        ((self.alpha - 1.0) * x.ln() + (self.beta - 1.0) * (1.0 - x).ln()
            - ln_beta(self.alpha, self.beta))
        .exp()
    }

    pub fn cdf(&self, x: f64) -> f64 {
        inc_beta(self.alpha, self.beta, x.clamp(0.0, 1.0))
    }

    /// Inverse CDF via bisection refined with Newton steps.
    /// Accurate to ~1e-12 in x.
    pub fn quantile(&self, p: f64) -> f64 {
        let p = p.clamp(0.0, 1.0);
        if p == 0.0 {
            return 0.0;
        }
        if p == 1.0 {
            return 1.0;
        }
        let (mut lo, mut hi) = (0.0_f64, 1.0_f64);
        let mut x = self.mean(); // warm start
        for _ in 0..200 {
            let f = self.cdf(x) - p;
            if f.abs() < 1e-14 {
                break;
            }
            if f > 0.0 {
                hi = x;
            } else {
                lo = x;
            }
            // Newton step, fall back to bisection if it escapes [lo, hi].
            let d = self.pdf(x);
            let newton = if d > 1e-300 { x - f / d } else { f64::NAN };
            x = if newton.is_finite() && newton > lo && newton < hi {
                newton
            } else {
                0.5 * (lo + hi)
            };
            if hi - lo < 1e-14 {
                break;
            }
        }
        x
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop;

    #[test]
    fn ln_gamma_known_values() {
        // Γ(1)=1, Γ(2)=1, Γ(5)=24, Γ(0.5)=sqrt(pi)
        assert!(ln_gamma(1.0).abs() < 1e-12);
        assert!(ln_gamma(2.0).abs() < 1e-12);
        assert!((ln_gamma(5.0) - 24.0_f64.ln()).abs() < 1e-12);
        assert!((ln_gamma(0.5) - std::f64::consts::PI.sqrt().ln()).abs() < 1e-12);
    }

    #[test]
    fn ln_gamma_recurrence() {
        // Γ(x+1) = x Γ(x)
        for x in [0.1, 0.7, 1.3, 4.5, 10.2] {
            let lhs = ln_gamma(x + 1.0);
            let rhs = x.ln() + ln_gamma(x);
            assert!((lhs - rhs).abs() < 1e-11, "x={x}");
        }
    }

    #[test]
    fn inc_beta_bounds_and_symmetry() {
        assert_eq!(inc_beta(2.0, 3.0, 0.0), 0.0);
        assert_eq!(inc_beta(2.0, 3.0, 1.0), 1.0);
        // I_x(a,b) = 1 - I_{1-x}(b,a)
        for (a, b, x) in [(2.0, 5.0, 0.3), (0.5, 0.5, 0.7), (8.0, 1.5, 0.9)] {
            let lhs = inc_beta(a, b, x);
            let rhs = 1.0 - inc_beta(b, a, 1.0 - x);
            assert!((lhs - rhs).abs() < 1e-12, "a={a} b={b} x={x}");
        }
    }

    #[test]
    fn inc_beta_uniform_case() {
        // Beta(1,1) is uniform: I_x(1,1) = x.
        for i in 0..=10 {
            let x = i as f64 / 10.0;
            assert!((inc_beta(1.0, 1.0, x) - x).abs() < 1e-12);
        }
    }

    #[test]
    fn inc_beta_known_value() {
        // I_{0.5}(2,2) = 0.5 by symmetry.
        assert!((inc_beta(2.0, 2.0, 0.5) - 0.5).abs() < 1e-12);
        // Beta(2,1): CDF = x^2.
        assert!((inc_beta(2.0, 1.0, 0.6) - 0.36).abs() < 1e-12);
    }

    #[test]
    fn beta_validation() {
        assert!(Beta::new(0.0, 1.0).is_err());
        assert!(Beta::new(1.0, -1.0).is_err());
        assert!(Beta::new(f64::NAN, 1.0).is_err());
        assert!(Beta::new(1.2, 30.0).is_ok());
    }

    #[test]
    fn beta_moments() {
        let b = Beta::new(2.0, 5.0).unwrap();
        assert!((b.mean() - 2.0 / 7.0).abs() < 1e-12);
        assert!((b.raw_moment(1) - b.mean()).abs() < 1e-12);
        // E[X^2] = a(a+1)/((a+b)(a+b+1))
        assert!((b.raw_moment(2) - 2.0 * 3.0 / (7.0 * 8.0)).abs() < 1e-12);
        let var = b.raw_moment(2) - b.mean() * b.mean();
        assert!((var - b.variance()).abs() < 1e-12);
    }

    #[test]
    fn pdf_integrates_to_one() {
        // Trapezoid over a fine grid.
        for (a, bb) in [(2.0, 5.0), (1.2, 30.0), (8.0, 1.5)] {
            let b = Beta::new(a, bb).unwrap();
            let n = 200_000;
            let mut acc = 0.0;
            for i in 0..n {
                let x0 = i as f64 / n as f64;
                let x1 = (i + 1) as f64 / n as f64;
                acc += 0.5 * (b.pdf(x0.max(1e-12)) + b.pdf(x1.min(1.0 - 1e-12))) / n as f64;
            }
            assert!((acc - 1.0).abs() < 1e-3, "a={a} b={bb} integral={acc}");
        }
    }

    #[test]
    fn quantile_inverts_cdf() {
        for (a, bb) in [(2.0, 5.0), (1.2, 30.0), (8.0, 1.5), (0.7, 0.9)] {
            let b = Beta::new(a, bb).unwrap();
            for i in 1..20 {
                let p = i as f64 / 20.0;
                let x = b.quantile(p);
                assert!((b.cdf(x) - p).abs() < 1e-9, "a={a} b={bb} p={p} x={x}");
            }
        }
    }

    #[test]
    fn quantile_endpoints() {
        let b = Beta::new(2.0, 3.0).unwrap();
        assert_eq!(b.quantile(0.0), 0.0);
        assert_eq!(b.quantile(1.0), 1.0);
    }

    #[test]
    fn prop_cdf_monotone() {
        prop::check(100, |g| {
            let a = g.f64(0.2..10.0);
            let bb = g.f64(0.2..10.0);
            let b = Beta::new(a, bb).unwrap();
            let x0 = g.f64(0.0..1.0);
            let x1 = g.f64(0.0..1.0);
            let (lo, hi) = if x0 < x1 { (x0, x1) } else { (x1, x0) };
            prop_assert!(
                b.cdf(hi) >= b.cdf(lo) - 1e-12,
                "CDF not monotone at ({lo}, {hi})"
            );
            Ok(())
        });
    }

    #[test]
    fn prop_quantile_cdf_roundtrip() {
        prop::check(100, |g| {
            let a = g.f64(0.3..8.0);
            let bb = g.f64(0.3..8.0);
            let p = g.f64(0.01..0.99);
            let b = Beta::new(a, bb).unwrap();
            let x = b.quantile(p);
            prop_assert!(
                (b.cdf(x) - p).abs() < 1e-8,
                "roundtrip failed: a={a} b={bb} p={p} -> x={x} -> {}",
                b.cdf(x)
            );
            Ok(())
        });
    }

    #[test]
    fn sampling_matches_cdf() {
        use crate::util::rng::Rng;
        use crate::util::stats;
        let b = Beta::new(2.0, 5.0).unwrap();
        let mut rng = Rng::new(13);
        let xs: Vec<f64> = (0..50_000).map(|_| rng.beta(2.0, 5.0)).collect();
        let ks = stats::ks_distance(&xs, |x| b.cdf(x));
        assert!(ks < 0.01, "KS = {ks}");
    }
}
