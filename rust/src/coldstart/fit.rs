//! Fitting the cold-start Beta mixture (paper Eqs. 7-8).
//!
//! The shape parameters `(a0, b0, a1, b1)` are found by matching the
//! mixture's first four raw moments to the empirical moments of the
//! training scores:
//!
//! `L = sum_{r=1..4} ((mu_r - ybar_r)^2)^(1/r)`        (Eq. 7)
//!
//! The r-th root evens out the moments' magnitudes at the cost of
//! differentiability, so the paper uses a stochastic search — we
//! implement Differential Evolution (Storn & Price [40]) from scratch.
//! The search is repeated `n_trials` times and the fit minimizing the
//! Jensen-Shannon divergence against the empirical histogram is kept
//! (Eq. 8).

use super::mixture::BetaMixture;
use crate::util::rng::Rng;
use crate::util::stats;
use anyhow::{ensure, Result};

/// Differential-evolution hyper-parameters.
#[derive(Debug, Clone, Copy)]
pub struct FitConfig {
    pub n_trials: usize,    // N_trial of Eq. 8
    pub population: usize,  // DE population size
    pub generations: usize, // DE iterations per trial
    pub f: f64,             // DE differential weight
    pub cr: f64,            // DE crossover rate
    pub hist_bins: usize,   // JSD histogram resolution
    pub seed: u64,
}

impl Default for FitConfig {
    fn default() -> Self {
        FitConfig {
            n_trials: 8,
            population: 40,
            generations: 150,
            f: 0.7,
            cr: 0.9,
            hist_bins: 50,
            seed: 0x4D55_5345,
        }
    }
}

/// Typed rejection of an unusable [`FitConfig`].
#[derive(Debug, Clone, PartialEq)]
pub struct FitConfigError {
    pub field: &'static str,
    pub message: String,
}

impl std::fmt::Display for FitConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "invalid FitConfig.{}: {}", self.field, self.message)
    }
}

impl std::error::Error for FitConfigError {}

impl FitConfig {
    /// Reject configurations the DE search cannot run on. The
    /// rand/1/bin strategy draws three distinct partners besides the
    /// current index, so a population below 4 makes the partner-
    /// selection loops spin forever — that floor used to be a silent
    /// `max(8)` fix-up buried in `de_minimize`, far from the loops it
    /// protected and overriding whatever the caller configured.
    pub fn validate(&self) -> Result<(), FitConfigError> {
        let err = |field: &'static str, message: String| Err(FitConfigError { field, message });
        if self.population < 4 {
            return err(
                "population",
                format!(
                    "DE rand/1/bin needs >= 4 members to pick 3 distinct partners, got {}",
                    self.population
                ),
            );
        }
        if self.n_trials == 0 {
            return err("n_trials", "need at least one trial".into());
        }
        if self.generations == 0 {
            return err("generations", "need at least one generation".into());
        }
        if !(self.f.is_finite() && self.f > 0.0) {
            return err("f", format!("differential weight must be > 0, got {}", self.f));
        }
        if !(0.0..=1.0).contains(&self.cr) {
            return err("cr", format!("crossover rate must be in [0,1], got {}", self.cr));
        }
        if self.hist_bins < 2 {
            return err("hist_bins", format!("JSD needs >= 2 bins, got {}", self.hist_bins));
        }
        Ok(())
    }
}

/// Search space: log-uniform over each Beta shape parameter.
const LOG_LO: f64 = -3.0; // e^-3 ~ 0.05
const LOG_HI: f64 = 5.0; // e^5  ~ 148

/// Result of a mixture fit.
#[derive(Debug, Clone)]
pub struct MixtureFit {
    pub mixture: BetaMixture,
    pub moment_loss: f64,
    pub jsd: f64,
    pub trials: usize,
}

/// Eq. 7: the moment-matching loss for parameters `theta` (in log
/// space) against empirical raw moments `emp[0..4]` (r = 1..=4).
fn moment_loss(w: f64, theta: &[f64; 4], emp: &[f64; 4]) -> f64 {
    let mixture = match BetaMixture::from_params(
        w,
        theta[0].exp(),
        theta[1].exp(),
        theta[2].exp(),
        theta[3].exp(),
    ) {
        Ok(m) => m,
        Err(_) => return f64::INFINITY,
    };
    let mut loss = 0.0;
    for r in 1..=4u32 {
        let mu = mixture.raw_moment(r);
        let diff2 = (mu - emp[(r - 1) as usize]).powi(2);
        loss += diff2.powf(1.0 / r as f64);
    }
    loss
}

/// One DE run (Storn & Price): rand/1/bin strategy with clamping.
fn de_minimize(
    w: f64,
    emp: &[f64; 4],
    cfg: &FitConfig,
    rng: &mut Rng,
) -> Result<([f64; 4], f64)> {
    let np = cfg.population;
    // `FitConfig::validate` already rejected np < 4; re-assert at the
    // site that would otherwise spin forever, so a future caller that
    // skips validation fails loudly instead of hanging.
    ensure!(
        np >= 4,
        "DE partner selection needs population >= 4, got {np} (unvalidated FitConfig?)"
    );
    // Initialise population log-uniformly.
    let mut pop: Vec<[f64; 4]> = (0..np)
        .map(|_| {
            let mut x = [0.0; 4];
            for v in &mut x {
                *v = rng.range(LOG_LO, LOG_HI);
            }
            x
        })
        .collect();
    let mut fitness: Vec<f64> = pop.iter().map(|x| moment_loss(w, x, emp)).collect();

    for _gen in 0..cfg.generations {
        for i in 0..np {
            // Pick three distinct partners != i.
            let (mut a, mut b, mut c);
            loop {
                a = rng.below(np);
                if a != i {
                    break;
                }
            }
            loop {
                b = rng.below(np);
                if b != i && b != a {
                    break;
                }
            }
            loop {
                c = rng.below(np);
                if c != i && c != a && c != b {
                    break;
                }
            }
            // Mutation + binomial crossover.
            let j_rand = rng.below(4);
            let mut trial = pop[i];
            for j in 0..4 {
                if j == j_rand || rng.bernoulli(cfg.cr) {
                    trial[j] =
                        (pop[a][j] + cfg.f * (pop[b][j] - pop[c][j])).clamp(LOG_LO, LOG_HI);
                }
            }
            let t_fit = moment_loss(w, &trial, emp);
            if t_fit <= fitness[i] {
                pop[i] = trial;
                fitness[i] = t_fit;
            }
        }
    }
    let best = fitness
        .iter()
        .enumerate()
        .min_by(|x, y| x.1.partial_cmp(y.1).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    Ok((pop[best], fitness[best]))
}

/// Fit the bimodal Beta mixture to observed scores (Eqs. 6-8).
///
/// `scores` are the predictor's outputs on the combined training data
/// of its experts; `w` is the positive-class prior of that data
/// (paper: `w = P(y=1)`).
pub fn fit_mixture(scores: &[f64], w: f64, cfg: &FitConfig) -> Result<MixtureFit> {
    cfg.validate()?;
    ensure!(scores.len() >= 100, "need >= 100 scores to fit, got {}", scores.len());
    // Same domain, same message as `BetaMixture::new` — the two used
    // to disagree (`[0,1)` here vs `[0,1]` there).
    BetaMixture::validate_weight(w)?;
    ensure!(
        scores.iter().all(|s| (0.0..=1.0).contains(s)),
        "scores must lie in [0,1]"
    );

    let emp = [
        stats::raw_moment(scores, 1),
        stats::raw_moment(scores, 2),
        stats::raw_moment(scores, 3),
        stats::raw_moment(scores, 4),
    ];
    let hist = stats::bin_counts(scores, cfg.hist_bins);

    let mut rng = Rng::new(cfg.seed);
    let mut best: Option<MixtureFit> = None;
    for trial in 0..cfg.n_trials {
        let mut trial_rng = rng.fork(trial as u64 + 1);
        let (theta, loss) = de_minimize(w, &emp, cfg, &mut trial_rng)?;
        let mixture = BetaMixture::from_params(
            w,
            theta[0].exp(),
            theta[1].exp(),
            theta[2].exp(),
            theta[3].exp(),
        )?;
        let jsd = mixture.jsd_vs_histogram(&hist);
        // Eq. 8: keep the trial with minimal JSD against f_S^emp.
        if best.as_ref().map_or(true, |b| jsd < b.jsd) {
            best = Some(MixtureFit {
                mixture,
                moment_loss: loss,
                jsd,
                trials: trial + 1,
            });
        }
    }
    Ok(best.expect("at least one trial runs"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coldstart::beta::Beta;

    fn quick_cfg(seed: u64) -> FitConfig {
        FitConfig {
            n_trials: 4,
            population: 30,
            generations: 80,
            seed,
            ..FitConfig::default()
        }
    }

    fn sample_mixture(m: &BetaMixture, n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                if rng.bernoulli(m.w) {
                    rng.beta(m.c1.alpha, m.c1.beta)
                } else {
                    rng.beta(m.c0.alpha, m.c0.beta)
                }
            })
            .collect()
    }

    #[test]
    fn recovers_known_mixture_shape() {
        let truth = BetaMixture::new(
            0.02,
            Beta::new(1.5, 20.0).unwrap(),
            Beta::new(6.0, 2.0).unwrap(),
        )
        .unwrap();
        let scores = sample_mixture(&truth, 60_000, 3);
        let fit = fit_mixture(&scores, 0.02, &quick_cfg(1)).unwrap();
        // We don't require parameter identification (moments only pin
        // 4 dof and the mixture is nearly non-identifiable), but the
        // fitted distribution must be close in JSD and in moments.
        assert!(fit.jsd < 0.02, "JSD = {}", fit.jsd);
        for r in 1..=4 {
            let diff = (fit.mixture.raw_moment(r) - stats::raw_moment(&scores, r)).abs();
            assert!(diff < 0.01, "moment {r} off by {diff}");
        }
    }

    #[test]
    fn fitted_quantiles_track_empirical() {
        let truth = BetaMixture::new(
            0.05,
            Beta::new(1.2, 25.0).unwrap(),
            Beta::new(7.0, 1.8).unwrap(),
        )
        .unwrap();
        let scores = sample_mixture(&truth, 80_000, 9);
        let fit = fit_mixture(&scores, 0.05, &quick_cfg(2)).unwrap();
        let mut sorted = scores.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // Moment matching pins the bulk, not the exact upper quantiles
        // — the paper's own Fig. 4 shows the cold-start default drifts
        // in high-score bins — so the tolerance here is deliberately
        // loose; distributional closeness is asserted via JSD above.
        for p in [0.5, 0.9, 0.99] {
            let emp_q = stats::quantile_sorted(&sorted, p);
            let fit_q = fit.mixture.quantile(p);
            assert!(
                (emp_q - fit_q).abs() < 0.12,
                "p={p}: empirical {emp_q} vs fitted {fit_q}"
            );
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let truth = BetaMixture::new(
            0.02,
            Beta::new(1.5, 20.0).unwrap(),
            Beta::new(6.0, 2.0).unwrap(),
        )
        .unwrap();
        let scores = sample_mixture(&truth, 20_000, 5);
        let a = fit_mixture(&scores, 0.02, &quick_cfg(7)).unwrap();
        let b = fit_mixture(&scores, 0.02, &quick_cfg(7)).unwrap();
        assert_eq!(a.mixture, b.mixture);
    }

    #[test]
    fn rejects_insufficient_or_invalid_input() {
        assert!(fit_mixture(&[0.5; 10], 0.1, &quick_cfg(1)).is_err());
        assert!(fit_mixture(&vec![0.5; 200], 1.5, &quick_cfg(1)).is_err());
        let mut bad = vec![0.5; 200];
        bad[0] = 1.5;
        assert!(fit_mixture(&bad, 0.1, &quick_cfg(1)).is_err());
    }

    #[test]
    fn tiny_population_is_a_typed_error_not_a_silent_bump() {
        // Regression (ISSUE 10 satellite 3): population < 4 used to be
        // silently rewritten to 8 inside de_minimize — the configured
        // value was ignored and the loop-hang hazard it papered over
        // stayed latent. It is now a typed FitConfig rejection.
        let scores: Vec<f64> = (0..200).map(|i| (i as f64 / 200.0).powi(2)).collect();
        let cfg = FitConfig { population: 3, ..quick_cfg(1) };
        let err = cfg.validate().unwrap_err();
        assert_eq!(err.field, "population");
        let err = fit_mixture(&scores, 0.1, &cfg).unwrap_err();
        assert!(err.to_string().contains("population"), "{err}");
        // The floor itself is exact: 4 is valid.
        assert!(FitConfig { population: 4, ..quick_cfg(1) }.validate().is_ok());
        // Other degenerate hyper-parameters are typed too.
        assert!(FitConfig { n_trials: 0, ..quick_cfg(1) }.validate().is_err());
        assert!(FitConfig { generations: 0, ..quick_cfg(1) }.validate().is_err());
        assert!(FitConfig { cr: 1.5, ..quick_cfg(1) }.validate().is_err());
        assert!(FitConfig { f: 0.0, ..quick_cfg(1) }.validate().is_err());
        assert!(FitConfig { hist_bins: 1, ..quick_cfg(1) }.validate().is_err());
    }

    #[test]
    fn w_domain_matches_beta_mixture_exactly() {
        // Regression (ISSUE 10 satellite 3): fit_mixture rejected
        // w = 1.0 ("prior w must be in [0,1)") while
        // BetaMixture::from_params accepted it — same parameter, two
        // domains, two messages. Both now share one check.
        let scores: Vec<f64> = (0..200).map(|i| i as f64 / 200.0).collect();
        assert!(fit_mixture(&scores, 1.0, &quick_cfg(3)).is_ok(), "w=1.0 is a legal prior");
        let fit_err = fit_mixture(&scores, 1.5, &quick_cfg(3)).unwrap_err().to_string();
        let mix_err = BetaMixture::from_params(1.5, 1.0, 1.0, 1.0, 1.0)
            .unwrap_err()
            .to_string();
        assert_eq!(fit_err, mix_err, "the two paths must reject with one message");
        assert!(fit_mixture(&scores, f64::NAN, &quick_cfg(3)).is_err());
    }

    #[test]
    fn moment_loss_penalizes_bad_params() {
        let emp = [0.05, 0.01, 0.003, 0.001];
        let good = moment_loss(0.02, &[0.4_f64.ln(), 3.0_f64.ln(), 1.8, 0.4], &emp);
        let bad = moment_loss(0.02, &[4.0, 4.0, 4.0, 4.0], &emp);
        assert!(good.is_finite() && bad.is_finite());
        assert!(bad > good, "good={good} bad={bad}");
    }

    #[test]
    fn more_trials_never_worse_jsd() {
        let truth = BetaMixture::new(
            0.03,
            Beta::new(1.1, 15.0).unwrap(),
            Beta::new(5.0, 1.5).unwrap(),
        )
        .unwrap();
        let scores = sample_mixture(&truth, 30_000, 11);
        let one = fit_mixture(&scores, 0.03, &FitConfig { n_trials: 1, ..quick_cfg(3) }).unwrap();
        let many = fit_mixture(&scores, 0.03, &FitConfig { n_trials: 6, ..quick_cfg(3) }).unwrap();
        assert!(many.jsd <= one.jsd + 1e-12);
    }
}
