//! The bimodal Beta mixture prior (paper Eq. 6).
//!
//! `f_S(y) = (1-w) Beta(y; a0, b0) + w Beta(y; a1, b1)`
//!
//! with `w = P(y = 1)` the fraud prior: component 0 approximates the
//! legitimate-class score density, component 1 the fraud-class
//! density. Used to define the cold-start default quantile
//! transformation `T^Q_{v0}` when no tenant data exists, and as the
//! shape family for the configurable reference distribution R.

use super::beta::Beta;
use anyhow::{ensure, Result};

/// A two-component Beta mixture on [0, 1].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BetaMixture {
    pub w: f64, // weight of component 1 (the positive/fraud mode)
    pub c0: Beta,
    pub c1: Beta,
}

impl BetaMixture {
    /// The one domain check for the mixture/fraud prior `w`, shared
    /// verbatim by [`BetaMixture::new`] and `coldstart::fit_mixture`
    /// so both paths reject exactly the same domain with exactly the
    /// same message (they used to disagree: the fit path rejected
    /// `w = 1.0` that the constructor accepted).
    pub fn validate_weight(w: f64) -> Result<()> {
        ensure!(
            (0.0..=1.0).contains(&w) && w.is_finite(),
            "mixture weight must be in [0,1], got {w}"
        );
        Ok(())
    }

    pub fn new(w: f64, c0: Beta, c1: Beta) -> Result<Self> {
        BetaMixture::validate_weight(w)?;
        Ok(BetaMixture { w, c0, c1 })
    }

    /// Construct from raw parameters (Eq. 6's tuple).
    pub fn from_params(w: f64, a0: f64, b0: f64, a1: f64, b1: f64) -> Result<Self> {
        BetaMixture::new(w, Beta::new(a0, b0)?, Beta::new(a1, b1)?)
    }

    pub fn pdf(&self, x: f64) -> f64 {
        (1.0 - self.w) * self.c0.pdf(x) + self.w * self.c1.pdf(x)
    }

    pub fn cdf(&self, x: f64) -> f64 {
        (1.0 - self.w) * self.c0.cdf(x) + self.w * self.c1.cdf(x)
    }

    /// r-th raw moment (mixtures are linear in moments) — the
    /// `mu_r(alpha_0, beta_0, alpha_1, beta_1)` of Eq. 7.
    pub fn raw_moment(&self, r: u32) -> f64 {
        (1.0 - self.w) * self.c0.raw_moment(r) + self.w * self.c1.raw_moment(r)
    }

    pub fn mean(&self) -> f64 {
        self.raw_moment(1)
    }

    /// Inverse CDF by monotone bisection + Newton (the mixture CDF is
    /// strictly increasing wherever the pdf is positive).
    pub fn quantile(&self, p: f64) -> f64 {
        let p = p.clamp(0.0, 1.0);
        if p == 0.0 {
            return 0.0;
        }
        if p == 1.0 {
            return 1.0;
        }
        let (mut lo, mut hi) = (0.0_f64, 1.0_f64);
        let mut x = self.mean().clamp(1e-9, 1.0 - 1e-9);
        for _ in 0..200 {
            let f = self.cdf(x) - p;
            if f.abs() < 1e-14 {
                break;
            }
            if f > 0.0 {
                hi = x;
            } else {
                lo = x;
            }
            let d = self.pdf(x);
            let newton = if d > 1e-300 { x - f / d } else { f64::NAN };
            x = if newton.is_finite() && newton > lo && newton < hi {
                newton
            } else {
                0.5 * (lo + hi)
            };
            if hi - lo < 1e-14 {
                break;
            }
        }
        x
    }

    /// Quantile grid at `n_points` uniform probabilities (the
    /// `q^R_i` / default `q^S_i` used by `QuantileMap`). Endpoints are
    /// pinned to the distribution support [0, 1].
    pub fn quantile_grid(&self, n_points: usize) -> Vec<f64> {
        assert!(n_points >= 2);
        let mut grid: Vec<f64> = (0..n_points)
            .map(|i| self.quantile(i as f64 / (n_points - 1) as f64))
            .collect();
        grid[0] = 0.0;
        grid[n_points - 1] = 1.0;
        crate::transforms::quantile_fit::dedup_monotone(&mut grid);
        grid
    }

    /// Probability mass per uniform score bin (for the paper's
    /// relative-error-vs-target figures).
    pub fn bin_shares(&self, n_bins: usize) -> Vec<f64> {
        (0..n_bins)
            .map(|b| {
                let lo = b as f64 / n_bins as f64;
                let hi = (b + 1) as f64 / n_bins as f64;
                self.cdf(hi) - self.cdf(lo)
            })
            .collect()
    }

    /// Jensen-Shannon divergence against a histogram density estimate
    /// (Eq. 8's model-selection criterion). `hist` contains counts per
    /// uniform bin over [0, 1]; base-2 logs so JSD is in [0, 1].
    pub fn jsd_vs_histogram(&self, hist: &[u64]) -> f64 {
        let n_bins = hist.len();
        let total: u64 = hist.iter().sum();
        if total == 0 {
            return 1.0;
        }
        let mut jsd = 0.0;
        for (b, &count) in hist.iter().enumerate() {
            let p = count as f64 / total as f64; // empirical mass
            let lo = b as f64 / n_bins as f64;
            let hi = (b + 1) as f64 / n_bins as f64;
            let q = (self.cdf(hi) - self.cdf(lo)).max(0.0); // model mass
            let m = 0.5 * (p + q);
            if p > 0.0 && m > 0.0 {
                jsd += 0.5 * p * (p / m).log2();
            }
            if q > 0.0 && m > 0.0 {
                jsd += 0.5 * q * (q / m).log2();
            }
        }
        jsd.max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop;

    fn fraudish() -> BetaMixture {
        // High density near 0, long tail to 1 — the paper's suggested
        // reference shape for imbalanced fraud settings.
        BetaMixture::from_params(0.015, 1.2, 30.0, 8.0, 1.5).unwrap()
    }

    #[test]
    fn validates_weight() {
        assert!(BetaMixture::from_params(-0.1, 1.0, 1.0, 1.0, 1.0).is_err());
        assert!(BetaMixture::from_params(1.1, 1.0, 1.0, 1.0, 1.0).is_err());
        assert!(BetaMixture::from_params(0.5, 0.0, 1.0, 1.0, 1.0).is_err());
    }

    #[test]
    fn pdf_cdf_consistency() {
        let m = fraudish();
        // CDF(1) = 1, CDF(0) = 0, CDF is the integral of the PDF.
        assert!((m.cdf(1.0) - 1.0).abs() < 1e-12);
        assert_eq!(m.cdf(0.0), 0.0);
        let n = 100_000;
        let mut acc = 0.0;
        for i in 0..n {
            let x0 = (i as f64 + 0.5) / n as f64;
            acc += m.pdf(x0) / n as f64;
        }
        assert!((acc - 1.0).abs() < 1e-3, "integral = {acc}");
    }

    #[test]
    fn moments_are_mixture_linear() {
        let m = fraudish();
        for r in 1..=4 {
            let direct = m.raw_moment(r);
            let manual = (1.0 - m.w) * m.c0.raw_moment(r) + m.w * m.c1.raw_moment(r);
            assert_eq!(direct, manual);
        }
    }

    #[test]
    fn quantile_inverts_cdf() {
        let m = fraudish();
        for i in 1..100 {
            let p = i as f64 / 100.0;
            let x = m.quantile(p);
            assert!((m.cdf(x) - p).abs() < 1e-8, "p={p} x={x}");
        }
    }

    #[test]
    fn quantile_grid_is_strictly_increasing() {
        let g = fraudish().quantile_grid(1025);
        assert_eq!(g.len(), 1025);
        assert_eq!(g[0], 0.0);
        assert_eq!(g[1024], 1.0);
        for w in g.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    #[test]
    fn fraudish_shape_matches_paper_intent() {
        // "high density near 0 and a longer tail towards 1": ~most mass
        // below 0.1, but non-trivial mass above 0.9 relative to mid.
        let m = fraudish();
        let shares = m.bin_shares(10);
        assert!(shares[0] > 0.6, "bin0 share {}", shares[0]);
        assert!(shares[9] > 0.001, "top bin share {}", shares[9]);
        assert!((shares.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn jsd_zero_for_own_histogram() {
        let m = fraudish();
        // Build the model's own expected histogram at high resolution.
        let n_bins = 50;
        let total = 10_000_000u64;
        let hist: Vec<u64> = m
            .bin_shares(n_bins)
            .iter()
            .map(|s| (s * total as f64).round() as u64)
            .collect();
        let jsd = m.jsd_vs_histogram(&hist);
        assert!(jsd < 1e-6, "JSD = {jsd}");
    }

    #[test]
    fn jsd_discriminates() {
        let m = fraudish();
        let other = BetaMixture::from_params(0.5, 2.0, 2.0, 2.0, 2.0).unwrap();
        let n_bins = 50;
        let hist: Vec<u64> = other
            .bin_shares(n_bins)
            .iter()
            .map(|s| (s * 1e7).round() as u64)
            .collect();
        assert!(m.jsd_vs_histogram(&hist) > 0.05);
    }

    #[test]
    fn jsd_empty_histogram_is_max() {
        assert_eq!(fraudish().jsd_vs_histogram(&[0; 10]), 1.0);
    }

    #[test]
    fn prop_cdf_in_unit_interval_and_monotone() {
        prop::check(100, |g| {
            let m = BetaMixture::from_params(
                g.f64(0.0..1.0),
                g.f64(0.3..10.0),
                g.f64(0.3..10.0),
                g.f64(0.3..10.0),
                g.f64(0.3..10.0),
            )
            .unwrap();
            let a = g.f64(0.0..1.0);
            let b = g.f64(0.0..1.0);
            let (lo, hi) = if a < b { (a, b) } else { (b, a) };
            let (cl, ch) = (m.cdf(lo), m.cdf(hi));
            prop_assert!((0.0..=1.0).contains(&cl), "cdf out of range");
            prop_assert!(ch >= cl - 1e-12, "cdf not monotone");
            Ok(())
        });
    }

    #[test]
    fn prop_quantile_grid_monotone() {
        prop::check(30, |g| {
            let m = BetaMixture::from_params(
                g.f64(0.001..0.3),
                g.f64(0.5..4.0),
                g.f64(5.0..40.0),
                g.f64(2.0..10.0),
                g.f64(0.5..4.0),
            )
            .unwrap();
            let grid = m.quantile_grid(129);
            for w in grid.windows(2) {
                prop_assert!(w[1] > w[0], "grid not strictly increasing");
            }
            Ok(())
        });
    }
}
