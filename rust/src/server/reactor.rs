//! Event-notification primitive for the ingress plane: a minimal
//! epoll wrapper with **zero** crate dependencies.
//!
//! The offline crate universe has no `libc`/`mio`/`tokio` (see
//! docs/ARCHITECTURE.md "Crate-availability constraint"), so on Linux
//! the three epoll syscalls are issued directly via inline `asm!` —
//! the same vendored-shim spirit as `vendor/anyhow` and `vendor/xla`.
//! On non-Linux unix the [`Poller`] degrades to the timer-tick
//! [`FallbackPoller`]: each `wait` sleeps the **full** requested
//! timeout, then reports every registered token with exactly its
//! registered interest mask (level-triggered semantics make the
//! optimistic readiness *correct* — callers read/write until
//! `WouldBlock` — just not efficient); production targets are Linux.
//! The fallback is compiled and tested on every platform so its
//! timing contract cannot rot where CI never runs it.
//!
//! Level-triggered only, one event loop per [`Poller`]. The server's
//! reactor (`server::http`) registers the listener plus every
//! connection; the `connection_storm` simulator reuses the same
//! primitive client-side to multiplex thousands of sockets from a
//! handful of driver threads.

use std::io;
use std::os::fd::RawFd;

/// Readable (EPOLLIN).
pub const EV_READ: u32 = 0x001;
/// Writable (EPOLLOUT).
pub const EV_WRITE: u32 = 0x004;
/// Error condition (EPOLLERR) — always reported, no need to request.
pub const EV_ERR: u32 = 0x008;
/// Hangup (EPOLLHUP) — always reported, no need to request.
pub const EV_HUP: u32 = 0x010;
/// Peer shut down its write half (EPOLLRDHUP, requestable).
pub const EV_RDHUP: u32 = 0x2000;

/// One readiness notification: the registered token + event mask.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PollEvent {
    pub token: usize,
    pub events: u32,
}

#[cfg(target_os = "linux")]
mod sys {
    use std::io;

    // The epoll_event layout the kernel ABI expects: packed (12
    // bytes) on x86_64, natural alignment (16 bytes) elsewhere.
    #[cfg(target_arch = "x86_64")]
    #[repr(C, packed)]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    #[cfg(not(target_arch = "x86_64"))]
    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    pub const EPOLL_CTL_ADD: i64 = 1;
    pub const EPOLL_CTL_DEL: i64 = 2;
    pub const EPOLL_CTL_MOD: i64 = 3;
    const EPOLL_CLOEXEC: i64 = 0x80000;

    #[cfg(target_arch = "x86_64")]
    mod nr {
        pub const EPOLL_CREATE1: i64 = 291;
        pub const EPOLL_CTL: i64 = 233;
        pub const EPOLL_WAIT: i64 = 232;
        pub const CLOSE: i64 = 3;
    }

    #[cfg(target_arch = "aarch64")]
    mod nr {
        pub const EPOLL_CREATE1: i64 = 20;
        pub const EPOLL_CTL: i64 = 21;
        // aarch64 has no epoll_wait; epoll_pwait with a null sigmask
        // is the exact equivalent.
        pub const EPOLL_PWAIT: i64 = 22;
        pub const CLOSE: i64 = 57;
    }

    /// Raw syscall, up to 6 args. Returns the kernel's i64 result
    /// (negative errno on failure).
    #[cfg(target_arch = "x86_64")]
    unsafe fn syscall6(n: i64, a1: i64, a2: i64, a3: i64, a4: i64, a5: i64, a6: i64) -> i64 {
        let ret: i64;
        std::arch::asm!(
            "syscall",
            inlateout("rax") n => ret,
            in("rdi") a1,
            in("rsi") a2,
            in("rdx") a3,
            in("r10") a4,
            in("r8") a5,
            in("r9") a6,
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
        ret
    }

    #[cfg(target_arch = "aarch64")]
    unsafe fn syscall6(n: i64, a1: i64, a2: i64, a3: i64, a4: i64, a5: i64, a6: i64) -> i64 {
        let ret: i64;
        std::arch::asm!(
            "svc 0",
            in("x8") n,
            inlateout("x0") a1 => ret,
            in("x1") a2,
            in("x2") a3,
            in("x3") a4,
            in("x4") a5,
            in("x5") a6,
            options(nostack),
        );
        ret
    }

    fn check(ret: i64) -> io::Result<i64> {
        if ret < 0 {
            Err(io::Error::from_raw_os_error(-ret as i32))
        } else {
            Ok(ret)
        }
    }

    pub fn epoll_create1() -> io::Result<i32> {
        let ret = unsafe { syscall6(nr::EPOLL_CREATE1, EPOLL_CLOEXEC, 0, 0, 0, 0, 0) };
        check(ret).map(|fd| fd as i32)
    }

    pub fn epoll_ctl(epfd: i32, op: i64, fd: i32, ev: Option<&mut EpollEvent>) -> io::Result<()> {
        let ptr = ev.map(|e| e as *mut EpollEvent as i64).unwrap_or(0);
        let ret = unsafe { syscall6(nr::EPOLL_CTL, epfd as i64, op, fd as i64, ptr, 0, 0) };
        check(ret).map(|_| ())
    }

    pub fn epoll_wait(epfd: i32, events: &mut [EpollEvent], timeout_ms: i32) -> io::Result<usize> {
        let ret = unsafe {
            #[cfg(target_arch = "x86_64")]
            {
                syscall6(
                    nr::EPOLL_WAIT,
                    epfd as i64,
                    events.as_mut_ptr() as i64,
                    events.len() as i64,
                    timeout_ms as i64,
                    0,
                    0,
                )
            }
            #[cfg(target_arch = "aarch64")]
            {
                // Null sigmask; sigsetsize is ignored when the mask
                // is null but 8 keeps strict kernels happy.
                syscall6(
                    nr::EPOLL_PWAIT,
                    epfd as i64,
                    events.as_mut_ptr() as i64,
                    events.len() as i64,
                    timeout_ms as i64,
                    0,
                    8,
                )
            }
        };
        check(ret).map(|n| n as usize)
    }

    pub fn close(fd: i32) {
        let _ = unsafe { syscall6(nr::CLOSE, fd as i64, 0, 0, 0, 0, 0) };
    }
}

/// Degraded timer-tick poller for platforms without epoll. Never
/// touches the fds it is given: `wait` sleeps the full requested
/// timeout (a real tick — the caller genuinely idles instead of
/// spinning) and then optimistically reports every registered token
/// with its registered interest mask, nothing more.
///
/// The previous fallback had two busy-spin bugs, both fixed here and
/// pinned by `fallback_poller_makes_progress_without_pegging_a_core`:
/// it clamped every sleep to 10ms regardless of the requested timeout
/// (so a reactor asking for a 100ms tick woke 100x/s, re-walking
/// every connection each time), and it OR-ed a spurious `EV_ERR` into
/// every event (waking error paths that were never requested). It is
/// compiled unconditionally — the non-Linux [`Poller`] delegates to
/// it — so the regression test runs on Linux CI too.
pub struct FallbackPoller {
    /// fd -> (token, interest); BTreeMap for deterministic report
    /// order.
    registered: std::collections::BTreeMap<RawFd, (usize, u32)>,
}

impl FallbackPoller {
    pub fn new() -> FallbackPoller {
        FallbackPoller {
            registered: std::collections::BTreeMap::new(),
        }
    }

    pub fn register(&mut self, fd: RawFd, token: usize, interest: u32) {
        self.registered.insert(fd, (token, interest));
    }

    pub fn modify(&mut self, fd: RawFd, token: usize, interest: u32) {
        self.registered.insert(fd, (token, interest));
    }

    pub fn deregister(&mut self, fd: RawFd) {
        self.registered.remove(&fd);
    }

    /// Tick semantics: sleep the full `timeout_ms` (0 = non-blocking
    /// poll, no sleep at all), then claim every registered fd ready
    /// for exactly its registered interest. Callers looping on
    /// `wait(.., 0)` own their cadence — the poller must not insert a
    /// hidden sleep into a caller that asked not to block.
    pub fn wait(&mut self, out: &mut Vec<PollEvent>, timeout_ms: i32) {
        out.clear();
        if timeout_ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(timeout_ms as u64));
        }
        for (&_fd, &(token, interest)) in &self.registered {
            out.push(PollEvent {
                token,
                events: interest,
            });
        }
    }
}

impl Default for FallbackPoller {
    fn default() -> Self {
        FallbackPoller::new()
    }
}

/// The event-notification handle. See module docs for semantics.
pub struct Poller {
    #[cfg(target_os = "linux")]
    epfd: i32,
    #[cfg(target_os = "linux")]
    buf: Vec<sys::EpollEvent>,
    #[cfg(not(target_os = "linux"))]
    fallback: FallbackPoller,
}

impl Poller {
    pub fn new() -> io::Result<Poller> {
        #[cfg(target_os = "linux")]
        {
            Ok(Poller {
                epfd: sys::epoll_create1()?,
                buf: vec![sys::EpollEvent { events: 0, data: 0 }; 1024],
            })
        }
        #[cfg(not(target_os = "linux"))]
        {
            Ok(Poller {
                fallback: FallbackPoller::new(),
            })
        }
    }

    /// Start watching `fd` for `interest`, tagging events with
    /// `token`. Level-triggered.
    pub fn register(&mut self, fd: RawFd, token: usize, interest: u32) -> io::Result<()> {
        #[cfg(target_os = "linux")]
        {
            let mut ev = sys::EpollEvent { events: interest, data: token as u64 };
            sys::epoll_ctl(self.epfd, sys::EPOLL_CTL_ADD, fd, Some(&mut ev))
        }
        #[cfg(not(target_os = "linux"))]
        {
            self.fallback.register(fd, token, interest);
            Ok(())
        }
    }

    /// Change the interest set (and token) of a registered fd.
    pub fn modify(&mut self, fd: RawFd, token: usize, interest: u32) -> io::Result<()> {
        #[cfg(target_os = "linux")]
        {
            let mut ev = sys::EpollEvent { events: interest, data: token as u64 };
            sys::epoll_ctl(self.epfd, sys::EPOLL_CTL_MOD, fd, Some(&mut ev))
        }
        #[cfg(not(target_os = "linux"))]
        {
            self.fallback.modify(fd, token, interest);
            Ok(())
        }
    }

    /// Stop watching `fd`. (Closing the fd drops it from the epoll
    /// set anyway; explicit removal keeps the fallback map honest.)
    pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
        #[cfg(target_os = "linux")]
        {
            sys::epoll_ctl(self.epfd, sys::EPOLL_CTL_DEL, fd, None)
        }
        #[cfg(not(target_os = "linux"))]
        {
            self.fallback.deregister(fd);
            Ok(())
        }
    }

    /// Wait up to `timeout_ms` (0 = just poll) and push readiness
    /// events into `out` (cleared first). Returns the event count;
    /// `Ok(0)` on timeout or EINTR.
    pub fn wait(&mut self, out: &mut Vec<PollEvent>, timeout_ms: i32) -> io::Result<usize> {
        out.clear();
        #[cfg(target_os = "linux")]
        {
            let n = match sys::epoll_wait(self.epfd, &mut self.buf, timeout_ms) {
                Ok(n) => n,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => 0,
                Err(e) => return Err(e),
            };
            for ev in &self.buf[..n] {
                // Copy out of the (possibly packed) ABI struct before
                // taking references.
                let (events, data) = (ev.events, ev.data);
                out.push(PollEvent { token: data as usize, events });
            }
            Ok(n)
        }
        #[cfg(not(target_os = "linux"))]
        {
            self.fallback.wait(out, timeout_ms);
            Ok(out.len())
        }
    }
}

impl Drop for Poller {
    fn drop(&mut self) {
        #[cfg(target_os = "linux")]
        sys::close(self.epfd);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::net::{TcpListener, TcpStream};
    use std::os::fd::AsRawFd;

    #[test]
    fn listener_readability_is_reported_with_its_token() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        listener.set_nonblocking(true).unwrap();
        let mut poller = Poller::new().unwrap();
        poller.register(listener.as_raw_fd(), 7, EV_READ).unwrap();

        let mut events = Vec::new();
        // Nothing pending yet (fallback mode may still tick "ready";
        // accept() below disambiguates).
        let _ = poller.wait(&mut events, 0).unwrap();

        let _client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        // The pending connection must surface within a bounded wait.
        let mut seen = false;
        for _ in 0..200 {
            poller.wait(&mut events, 50).unwrap();
            if events.iter().any(|e| e.token == 7 && e.events & EV_READ != 0) {
                seen = true;
                break;
            }
        }
        assert!(seen, "listener readiness never reported");
        assert!(listener.accept().is_ok());
    }

    #[test]
    fn connection_data_and_modify_roundtrip() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let mut client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        server_side.set_nonblocking(true).unwrap();

        let mut poller = Poller::new().unwrap();
        poller
            .register(server_side.as_raw_fd(), 42, EV_READ | EV_RDHUP)
            .unwrap();
        client.write_all(b"ping").unwrap();
        client.flush().unwrap();

        let mut events = Vec::new();
        let mut seen = false;
        for _ in 0..200 {
            poller.wait(&mut events, 50).unwrap();
            if events.iter().any(|e| e.token == 42 && e.events & EV_READ != 0) {
                seen = true;
                break;
            }
        }
        assert!(seen, "data readiness never reported");

        // Retag under a new token + add write interest.
        poller
            .modify(server_side.as_raw_fd(), 43, EV_READ | EV_WRITE)
            .unwrap();
        let mut seen_write = false;
        for _ in 0..200 {
            poller.wait(&mut events, 50).unwrap();
            if events.iter().any(|e| e.token == 43 && e.events & EV_WRITE != 0) {
                seen_write = true;
                break;
            }
        }
        assert!(seen_write, "write readiness never reported after modify");
        poller.deregister(server_side.as_raw_fd()).unwrap();
    }

    #[test]
    fn fallback_poller_makes_progress_without_pegging_a_core() {
        use std::time::{Duration, Instant};

        // Raw fd values only — the fallback never touches the fd.
        let mut poller = FallbackPoller::new();
        poller.register(100, 7, EV_READ);
        poller.register(101, 8, EV_READ | EV_WRITE);

        // Progress with honest timing: each 20ms wait must actually
        // idle ~20ms (the old fallback clamped every sleep to 10ms,
        // so a reactor asking for a long tick busy-woke 100x/s), and
        // every wait must report both tokens so callers advance.
        let t0 = Instant::now();
        let mut events = Vec::new();
        for _ in 0..5 {
            poller.wait(&mut events, 20);
            assert_eq!(events.len(), 2);
            let read = events.iter().find(|e| e.token == 7).unwrap();
            assert_eq!(read.events, EV_READ);
            let rw = events.iter().find(|e| e.token == 8).unwrap();
            // Exactly the registered interest — no spurious EV_ERR
            // (the old fallback OR-ed it into every event).
            assert_eq!(rw.events, EV_READ | EV_WRITE);
        }
        assert!(
            t0.elapsed() >= Duration::from_millis(80),
            "5 waits of 20ms finished in {:?} — the fallback is not \
             honoring its timeout (busy-spin regression)",
            t0.elapsed()
        );

        // A zero timeout is a non-blocking poll: no hidden sleep.
        let t1 = Instant::now();
        for _ in 0..100 {
            poller.wait(&mut events, 0);
        }
        assert!(
            t1.elapsed() < Duration::from_millis(500),
            "non-blocking polls slept: {:?}",
            t1.elapsed()
        );

        // Deregistered fds stop being reported.
        poller.deregister(100);
        poller.wait(&mut events, 0);
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].token, 8);
    }
}
