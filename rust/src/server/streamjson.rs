//! Incremental `/v1/score/batch` body parser: resumable at any byte
//! boundary, so the ingress plane can feed events to the scoring
//! sink **as they parse** instead of materializing the request.
//!
//! # Differential contract (enforced by `tests/ingress_fuzz.rs`)
//!
//! For every body `b` and every way of chunking `b`:
//!
//! * if `util::json::parse(b)` succeeds, the streaming parse succeeds
//!   and emits exactly the elements of the **last** top-level
//!   `"events"` array (duplicate keys are last-wins in the buffered
//!   path's `BTreeMap`; [`StreamItem::EventsRestart`] tells the sink
//!   to discard a superseded collection);
//! * if `util::json::parse(b)` fails, the streaming parse fails with
//!   the **same message at the same byte offset**, regardless of how
//!   the body was chunked.
//!
//! The equality is by construction, not by imitation: this module
//! only hand-emulates the *framing* of the top-level object (`{`,
//! keys, `:`, `,`, `}` and the `"events"` array skeleton — a dozen
//! exactly-mirrored error sites), while every complete value and
//! every key is re-parsed by the production parser via
//! `util::json::parse_value_at`, which reports the production error
//! strings and offsets verbatim. Values are byte-scanned to find
//! their extent (string/escape/depth tracking only — no validation),
//! then validated in one call; a scanner/parser extent disagreement
//! (e.g. mismatched brackets) always trips the production parser
//! first, at the byte the buffered path would have reported.
//!
//! Memory: one event's bytes are buffered at a time (plus any
//! non-`events` member being skipped); the whole request is never
//! held. The HTTP layer separately caps the body via Content-Length.

use crate::util::json::{parse_value_at, Json, JsonError};

/// Items pushed to the sink as the body parses.
#[derive(Debug, Clone, PartialEq)]
pub enum StreamItem {
    /// One element of the top-level `"events"` array, in order.
    Event(Json),
    /// A later top-level `"events"` key supersedes everything emitted
    /// so far (buffered parsing is last-wins): reset accumulated
    /// state, including any deferred per-event validation error.
    EventsRestart,
}

/// What the body said about `"events"`, for the sink's shape errors
/// (`missing required field 'events'` / `events must be a list ...`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BatchShape {
    /// A top-level `"events"` key was present.
    pub events_seen: bool,
    /// The last `"events"` value was an array.
    pub events_is_array: bool,
}

/// Where a completed scanned value goes.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Dest {
    /// An `"events"` array element: emit to the sink.
    Event,
    /// A non-`events` member value: syntax-validate and drop.
    Skip,
    /// A non-object body: validate, then require only trailing ws.
    Top,
}

/// Extent scanner for one value (no validation — see module docs).
#[derive(Debug, Clone, Copy, PartialEq)]
enum Scan {
    /// `{`/`[`: depth-balanced, string-aware.
    Container { depth: u32, in_string: bool, esc: bool },
    /// `"`: ends at the first backslash-unescaped quote.
    Str { esc: bool },
    /// Number or literal: ends at ws / `,` / `]` / `}` / EOF.
    Scalar,
}

#[derive(Debug, Clone, PartialEq)]
enum State {
    /// Leading ws; expecting the top-level value.
    Start,
    /// After `{`: `}` or a first key.
    ObjFirst,
    /// After `,` in the object: a key must follow.
    NextKey,
    /// Inside a key string (stash accumulating).
    Key { esc: bool },
    /// After a key: expecting `:`.
    AfterKey,
    /// After `:`: expecting the member value.
    BeforeValue,
    /// After `[` of the events array: `]` or a first element.
    EventsFirst,
    /// After `,` in the events array: an element must follow.
    EventElem,
    /// Scanning one complete value into the stash.
    Value { dest: Dest, scan: Scan },
    /// After an events element: `,` or `]`.
    AfterEvent,
    /// After a member value: `,` or `}`.
    AfterValue,
    /// After the top-level value: only trailing ws.
    Trailing,
    Done,
}

/// The resumable parser. Feed body slices with [`feed`], then call
/// [`finish`] once the Content-Length is consumed. Errors are sticky:
/// after a failure both methods keep returning the same error.
///
/// [`feed`]: BatchBodyParser::feed
/// [`finish`]: BatchBodyParser::finish
pub struct BatchBodyParser {
    state: State,
    /// Absolute offset of the next unconsumed input byte.
    pos: usize,
    /// Bytes of the key or value being scanned.
    stash: Vec<u8>,
    /// Absolute offset of `stash[0]`.
    stash_start: usize,
    /// Decoded current member key (decides `"events"` routing).
    key_is_events: bool,
    events_seen: bool,
    events_is_array: bool,
    failed: Option<JsonError>,
}

impl Default for BatchBodyParser {
    fn default() -> Self {
        Self::new()
    }
}

const fn is_ws(b: u8) -> bool {
    matches!(b, b' ' | b'\t' | b'\n' | b'\r')
}

impl BatchBodyParser {
    pub fn new() -> BatchBodyParser {
        BatchBodyParser {
            state: State::Start,
            pos: 0,
            stash: Vec::new(),
            stash_start: 0,
            key_is_events: false,
            events_seen: false,
            events_is_array: false,
            failed: None,
        }
    }

    /// Bytes consumed so far (diagnostics / abuse counters).
    pub fn consumed(&self) -> usize {
        self.pos
    }

    fn err(&mut self, msg: &str, offset: usize) -> JsonError {
        let e = JsonError { msg: msg.to_string(), offset };
        self.failed = Some(e.clone());
        e
    }

    /// Feed the next body slice, pushing parsed items to `sink`.
    pub fn feed(
        &mut self,
        chunk: &[u8],
        sink: &mut dyn FnMut(StreamItem),
    ) -> Result<(), JsonError> {
        if let Some(e) = &self.failed {
            return Err(e.clone());
        }
        for &b in chunk {
            let at = self.pos;
            self.process_byte(b, at, sink)?;
            self.pos += 1;
        }
        Ok(())
    }

    /// Signal end of body. Returns the `"events"` shape on success.
    pub fn finish(&mut self, sink: &mut dyn FnMut(StreamItem)) -> Result<BatchShape, JsonError> {
        if let Some(e) = &self.failed {
            return Err(e.clone());
        }
        // A completed scalar at EOF transitions to its after-state,
        // whose own EOF handling then applies — hence the loop.
        loop {
            let at = self.pos;
            match &self.state {
                State::Done | State::Trailing => {
                    self.state = State::Done;
                    return Ok(BatchShape {
                        events_seen: self.events_seen,
                        events_is_array: self.events_is_array,
                    });
                }
                State::Start | State::BeforeValue | State::EventsFirst | State::EventElem => {
                    return Err(self.err("unexpected end of input", at));
                }
                State::ObjFirst | State::NextKey => {
                    return Err(self.err("expected '\"'", at));
                }
                State::AfterKey => return Err(self.err("expected ':'", at)),
                State::AfterEvent => {
                    return Err(self.err("expected ',' or ']' in array", at));
                }
                State::AfterValue => {
                    return Err(self.err("expected ',' or '}' in object", at));
                }
                State::Key { .. } => {
                    // Unterminated key: the production parser reports
                    // the exact mid-string error (unterminated string,
                    // truncated \u escape, ...) at the right offset.
                    let e = match parse_value_at(&self.stash, 0) {
                        Err(e) => e,
                        Ok(_) => unreachable!("key stash has no closing quote"),
                    };
                    return Err(self.err(&e.msg, self.stash_start + e.offset));
                }
                State::Value { dest, scan } => {
                    let (dest, scan) = (*dest, *scan);
                    if scan == Scan::Scalar {
                        // EOF delimits a scalar; validate and fall
                        // through to the after-state's EOF handling.
                        self.finish_value(dest, sink)?;
                        continue;
                    }
                    // Truncated container/string: production error.
                    let e = match parse_value_at(&self.stash, 0) {
                        Err(e) => e,
                        Ok(_) => unreachable!("scanner says the value is incomplete"),
                    };
                    return Err(self.err(&e.msg, self.stash_start + e.offset));
                }
            }
        }
    }

    /// Process one input byte at absolute offset `at`. Loops through
    /// non-consuming transitions (a delimiter that completes a scalar
    /// is re-examined by the successor state within the same call).
    fn process_byte(
        &mut self,
        b: u8,
        at: usize,
        sink: &mut dyn FnMut(StreamItem),
    ) -> Result<(), JsonError> {
        loop {
            match &mut self.state {
                State::Done | State::Trailing => {
                    if is_ws(b) {
                        return Ok(());
                    }
                    return Err(self.err("trailing content after JSON value", at));
                }
                State::Start => {
                    if is_ws(b) {
                        return Ok(());
                    }
                    if b == b'{' {
                        self.state = State::ObjFirst;
                        return Ok(());
                    }
                    return self.begin_value(b, at, Dest::Top);
                }
                State::ObjFirst => {
                    if is_ws(b) {
                        return Ok(());
                    }
                    if b == b'}' {
                        self.state = State::Trailing;
                        return Ok(());
                    }
                    if b == b'"' {
                        self.begin_key(b, at);
                        return Ok(());
                    }
                    return Err(self.err("expected '\"'", at));
                }
                State::NextKey => {
                    if is_ws(b) {
                        return Ok(());
                    }
                    if b == b'"' {
                        self.begin_key(b, at);
                        return Ok(());
                    }
                    return Err(self.err("expected '\"'", at));
                }
                State::Key { esc } => {
                    let was_esc = *esc;
                    *esc = !was_esc && b == b'\\';
                    self.stash.push(b);
                    if !was_esc && b == b'"' {
                        return self.finish_key();
                    }
                    return Ok(());
                }
                State::AfterKey => {
                    if is_ws(b) {
                        return Ok(());
                    }
                    if b == b':' {
                        self.state = State::BeforeValue;
                        return Ok(());
                    }
                    return Err(self.err("expected ':'", at));
                }
                State::BeforeValue => {
                    if is_ws(b) {
                        return Ok(());
                    }
                    if self.key_is_events {
                        if self.events_seen {
                            // Last-wins: tell the sink to drop the
                            // superseded collection.
                            sink(StreamItem::EventsRestart);
                        }
                        self.events_seen = true;
                        self.events_is_array = b == b'[';
                        if b == b'[' {
                            self.state = State::EventsFirst;
                            return Ok(());
                        }
                        // Non-array events value: still has to be
                        // syntactically valid JSON.
                        return self.begin_value(b, at, Dest::Skip);
                    }
                    return self.begin_value(b, at, Dest::Skip);
                }
                State::EventsFirst => {
                    if is_ws(b) {
                        return Ok(());
                    }
                    if b == b']' {
                        self.state = State::AfterValue;
                        return Ok(());
                    }
                    return self.begin_value(b, at, Dest::Event);
                }
                State::EventElem => {
                    if is_ws(b) {
                        return Ok(());
                    }
                    return self.begin_value(b, at, Dest::Event);
                }
                State::AfterEvent => {
                    if is_ws(b) {
                        return Ok(());
                    }
                    if b == b',' {
                        self.state = State::EventElem;
                        return Ok(());
                    }
                    if b == b']' {
                        self.state = State::AfterValue;
                        return Ok(());
                    }
                    // The buffered parser bumps before erroring here.
                    return Err(self.err("expected ',' or ']' in array", at + 1));
                }
                State::AfterValue => {
                    if is_ws(b) {
                        return Ok(());
                    }
                    if b == b',' {
                        self.state = State::NextKey;
                        return Ok(());
                    }
                    if b == b'}' {
                        self.state = State::Trailing;
                        return Ok(());
                    }
                    return Err(self.err("expected ',' or '}' in object", at + 1));
                }
                State::Value { dest, scan } => {
                    let dest = *dest;
                    match scan {
                        Scan::Container { depth, in_string, esc } => {
                            if *in_string {
                                let was_esc = *esc;
                                *esc = !was_esc && b == b'\\';
                                if !was_esc && b == b'"' {
                                    *in_string = false;
                                }
                            } else {
                                match b {
                                    b'"' => *in_string = true,
                                    b'{' | b'[' => *depth += 1,
                                    // Depth only hits 0 outside a
                                    // string, where the scan ends —
                                    // `}`/`]` mismatches are caught by
                                    // the validating re-parse below.
                                    b'}' | b']' => *depth -= 1,
                                    _ => {}
                                }
                            }
                            let complete = *depth == 0;
                            self.stash.push(b);
                            if complete {
                                return self.finish_value(dest, sink);
                            }
                            return Ok(());
                        }
                        Scan::Str { esc } => {
                            let was_esc = *esc;
                            *esc = !was_esc && b == b'\\';
                            self.stash.push(b);
                            if !was_esc && b == b'"' {
                                return self.finish_value(dest, sink);
                            }
                            return Ok(());
                        }
                        Scan::Scalar => {
                            if is_ws(b) || matches!(b, b',' | b']' | b'}') {
                                // Delimiter: complete the scalar, then
                                // re-examine `b` in the after-state.
                                self.finish_value(dest, sink)?;
                                continue;
                            }
                            self.stash.push(b);
                            return Ok(());
                        }
                    }
                }
            }
        }
    }

    fn begin_key(&mut self, quote: u8, at: usize) {
        debug_assert_eq!(quote, b'"');
        self.stash.clear();
        self.stash.push(quote);
        self.stash_start = at;
        self.state = State::Key { esc: false };
    }

    /// Dispatch on a value's first byte exactly like `Parser::value`.
    fn begin_value(&mut self, b: u8, at: usize, dest: Dest) -> Result<(), JsonError> {
        let scan = match b {
            b'{' | b'[' => Scan::Container { depth: 1, in_string: false, esc: false },
            b'"' => Scan::Str { esc: false },
            b't' | b'f' | b'n' | b'-' => Scan::Scalar,
            c if c.is_ascii_digit() => Scan::Scalar,
            _ => return Err(self.err("unexpected character", at)),
        };
        self.stash.clear();
        self.stash.push(b);
        self.stash_start = at;
        self.state = State::Value { dest, scan };
        Ok(())
    }

    /// A key's closing quote landed: decode it with the production
    /// parser (same escape/UTF-8 errors at the same offsets).
    fn finish_key(&mut self) -> Result<(), JsonError> {
        match parse_value_at(&self.stash, 0) {
            Ok((Json::Str(k), _)) => {
                self.key_is_events = k == "events";
                self.stash.clear();
                self.state = State::AfterKey;
                Ok(())
            }
            Ok(_) => unreachable!("a quoted stash parses as a string"),
            Err(e) => Err(self.err(&e.msg, self.stash_start + e.offset)),
        }
    }

    /// A scanned value's extent is complete: validate it with the
    /// production parser, route it, and replay any trailing stash
    /// bytes the parser did not consume (scalar tokens like `truex`)
    /// through the successor state — which rejects them exactly where
    /// the buffered parse would have.
    fn finish_value(
        &mut self,
        dest: Dest,
        sink: &mut dyn FnMut(StreamItem),
    ) -> Result<(), JsonError> {
        let (v, consumed) = match parse_value_at(&self.stash, 0) {
            Ok(ok) => ok,
            Err(e) => {
                let off = self.stash_start + e.offset;
                return Err(self.err(&e.msg, off));
            }
        };
        if dest == Dest::Event {
            sink(StreamItem::Event(v));
        }
        self.state = match dest {
            Dest::Event => State::AfterEvent,
            Dest::Skip => State::AfterValue,
            Dest::Top => State::Trailing,
        };
        if consumed < self.stash.len() {
            // The first unconsumed byte is never a delimiter (the
            // scan would have stopped there), so the successor state
            // rejects it immediately — one byte decides the error.
            let lb = self.stash[consumed];
            let l_at = self.stash_start + consumed;
            self.stash.clear();
            return self.process_byte(lb, l_at, sink);
        }
        self.stash.clear();
        Ok(())
    }
}

/// Convenience used by tests and the differential harness: run a
/// whole body through the parser in the given chunk sizes.
pub fn parse_chunked(
    body: &[u8],
    chunks: &[usize],
    sink: &mut dyn FnMut(StreamItem),
) -> Result<BatchShape, JsonError> {
    let mut p = BatchBodyParser::new();
    let mut idx = 0;
    let mut ci = 0;
    while idx < body.len() {
        let n = if chunks.is_empty() {
            body.len() - idx
        } else {
            let n = chunks[ci % chunks.len()].max(1);
            ci += 1;
            n.min(body.len() - idx)
        };
        p.feed(&body[idx..idx + n], sink)?;
        idx += n;
    }
    p.finish(sink)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    /// Reference semantics: the buffered path's view of a body.
    fn reference(body: &str) -> Result<(Vec<Json>, BatchShape), JsonError> {
        let v = parse(body)?;
        let events = v.get("events");
        let shape = BatchShape {
            events_seen: events.is_some(),
            events_is_array: events.map(|e| e.as_arr().is_some()).unwrap_or(false),
        };
        let evs = events
            .and_then(Json::as_arr)
            .map(|a| a.to_vec())
            .unwrap_or_default();
        Ok((evs, shape))
    }

    /// Streaming semantics under a fixed chunking.
    fn streamed(body: &str, chunks: &[usize]) -> Result<(Vec<Json>, BatchShape), JsonError> {
        let mut events = Vec::new();
        let mut sink = |item: StreamItem| match item {
            StreamItem::Event(v) => events.push(v),
            StreamItem::EventsRestart => events.clear(),
        };
        let shape = parse_chunked(body.as_bytes(), chunks, &mut sink)?;
        Ok((events, shape))
    }

    /// The differential assertion used throughout: reference and
    /// streaming agree event-for-event (or error-for-error, same
    /// message and byte offset) for every chunking tried.
    fn assert_differential(body: &str) {
        let want = reference(body);
        for chunks in [
            vec![],        // one shot
            vec![1],       // byte at a time
            vec![2],
            vec![3, 1],
            vec![7, 1, 2],
            vec![body.len().max(1) / 2 + 1],
        ] {
            let got = streamed(body, &chunks);
            match (&want, &got) {
                (Ok(w), Ok(g)) => assert_eq!(w, g, "body={body:?} chunks={chunks:?}"),
                (Err(w), Err(g)) => {
                    assert_eq!((&w.msg, w.offset), (&g.msg, g.offset),
                        "body={body:?} chunks={chunks:?}");
                }
                _ => panic!(
                    "ok/err divergence for body={body:?} chunks={chunks:?}: \
                     want={want:?} got={got:?}"
                ),
            }
        }
    }

    #[test]
    fn streams_events_in_order() {
        let (evs, shape) = streamed(
            r#"{"events": [{"tenant":"a","features":[1]}, {"tenant":"b","features":[2,3]}]}"#,
            &[1],
        )
        .unwrap();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].req_str("tenant").unwrap(), "a");
        assert_eq!(evs[1].req_str("tenant").unwrap(), "b");
        assert!(shape.events_seen && shape.events_is_array);
    }

    #[test]
    fn shapes_match_reference() {
        for body in [
            r#"{}"#,
            r#"{"other": 1}"#,
            r#"{"events": []}"#,
            r#"{"events": "nope"}"#,
            r#"{"events": {"a": 1}}"#,
            r#"{"events": null}"#,
            r#"[1,2,3]"#,
            r#""just a string""#,
            "42",
        ] {
            assert_differential(body);
        }
    }

    #[test]
    fn duplicate_events_keys_are_last_wins() {
        // BTreeMap insert is last-wins in the buffered path; the
        // stream signals a restart so the sink matches.
        assert_differential(r#"{"events": [{"x":1}], "events": [{"y":2}, {"y":3}]}"#);
        assert_differential(r#"{"events": [{"x":1}], "events": "nope"}"#);
        assert_differential(r#"{"events": "nope", "events": [{"y":2}]}"#);
        let (evs, shape) =
            streamed(r#"{"events": [{"x":1}], "events": [{"y":2}]}"#, &[1]).unwrap();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].get("y"), Some(&Json::Num(2.0)));
        assert!(shape.events_is_array);
    }

    #[test]
    fn nested_events_keys_do_not_stream() {
        assert_differential(r#"{"outer": {"events": [1,2,3]}, "events": [{"z":9}]}"#);
        let (evs, _) =
            streamed(r#"{"outer": {"events": [1,2,3]}, "events": [{"z":9}]}"#, &[2]).unwrap();
        assert_eq!(evs.len(), 1);
    }

    #[test]
    fn errors_carry_buffered_offsets() {
        for body in [
            "",
            "   ",
            "{",
            "}",
            r#"{"events""#,
            r#"{"events" 1}"#,
            r#"{"events": [}"#,
            r#"{"events": [1,]}"#,
            r#"{"events": [1 2]}"#,
            r#"{"events": [truex]}"#,
            r#"{"events": [tru]}"#,
            r#"{"events": [01]}"#,
            r#"{"events": [1.]}"#,
            r#"{"events": [1e]}"#,
            r#"{"events": ["\x"]}"#,
            r#"{"events": ["unterminated}"#,
            r#"{"events": [{"a":1}}"#,
            r#"{"events": [{"a":1]]}"#,
            r#"{"events": [1]} extra"#,
            r#"{"events": [1],}"#,
            r#"{"events": [1] "k": 2}"#,
            r#"{"ev\ud800ents": [1]}"#,
            r#"{"events": [1], 5: 2}"#,
            "{\"a\"\n:\n1\n,\n\"events\":[ ]\n}\n\n",
            "nope",
            "1x",
            "[1,2",
        ] {
            assert_differential(body);
        }
    }

    #[test]
    fn whitespace_and_unicode_bodies() {
        assert_differential("  {  \"events\" :\t[ {\"s\":\"héllo — 事\"} , 2.5e-3 ]\r\n} ");
        assert_differential(r#"{"events": [" \u0041\ud83d\ude00 "]}"#);
    }

    #[test]
    fn parser_is_sticky_after_failure() {
        let mut p = BatchBodyParser::new();
        let mut sink = |_: StreamItem| {};
        let e1 = p.feed(b"nope", &mut sink).unwrap_err();
        let e2 = p.feed(b" more", &mut sink).unwrap_err();
        assert_eq!(e1, e2);
        assert_eq!(p.finish(&mut sink).unwrap_err(), e1);
    }
}
