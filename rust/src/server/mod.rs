//! The MUSE serving API: the HTTP front end over the engine.
//!
//! Endpoints:
//! * `POST /score` — `{tenant, geography?, schema?, channel?, entity?,
//!   features: [f32...]}` -> `{score, predictor, shadows}`
//! * `POST /v1/score/batch` — `{events: [<score payload>...]}` ->
//!   `{count, results: [{score, predictor, shadows}...]}` (input
//!   order preserved; one engine snapshot load for the whole batch,
//!   capped by `server.maxBatchEvents`)
//! * `GET /healthz` — readiness (set after warm-up, Section 3.1.2)
//! * `GET /metrics` — counters, per-tenant batch `scored_events`
//!   object, and request/batch latency percentiles (JSON)
//! * `GET /admin/stats` — registry/pool dedup accounting
//! * `GET /v1/lifecycle` — autopilot status: per-pair state machine,
//!   drift scores, fit/promotion counters
//! * `POST /v1/lifecycle/check` — run one controller tick now and
//!   return the resulting status (manual trigger / cron hook)
//!
//! A [`crate::cluster::MuseCluster`] gets the same front end from
//! [`spawn_cluster_server`]: `POST /score` and `POST /v1/score/batch`
//! route through the rendezvous [`crate::cluster::ClusterGateway`]
//! (responses additionally carry `node`, `epochLo`, `epochHi` — the
//! committed-epoch attribution window), and `GET /v1/cluster` reports
//! the replicated control plane: committed epoch, publish/crash/join
//! accounting, two-phase flip latency percentiles and one row per
//! node ever created.
//!
//! Request bodies over `server.maxBodyBytes` (default 1 MiB) are
//! rejected with `413 Payload Too Large` from the Content-Length
//! header alone — the body is never buffered.
//!
//! ## Ingress plane
//!
//! The front end is an event-driven reactor (`server::http`): one
//! epoll thread multiplexes every connection and hands complete
//! requests to a bounded worker pool. `POST /v1/score/batch` bodies
//! are additionally parsed *incrementally* (`server::streamjson`):
//! events reach [`ScoreBatchSink`] as their bytes arrive, so the
//! batch endpoint never buffers a request body — yet its responses
//! stay byte-identical to the buffered handler path (set
//! `server.streamBatch: false` to get that path back).
//!
//! Streaming also enables **tenant-priority admission control**:
//! the first event of a batch names the tenant, and when the deepest
//! dynamic-batcher queue exceeds `server.shedQueueDepth <<
//! priority(tenant)` the request is shed with `429 Too Many
//! Requests` + `Retry-After` before any scoring work is queued.
//! Slow or abusive clients are bounded by `server.maxHeaderBytes`
//! (431), `server.headerReadTimeoutMs` / `server.bodyReadTimeoutMs`
//! (408) and `server.maxConnections` (accept-time shed); every
//! outcome is accounted under `ingress_*` in `GET /metrics`.

pub mod http;
pub mod reactor;
pub mod streamjson;

use crate::coordinator::{Engine, ScoreRequest, TenantHandle, TenantInterner};
use crate::config::{Intent, ServerConfig};
use crate::util::json::{write_escaped, write_num, Json};
use anyhow::Result;
use http::{
    BatchSink, Handler, HttpServer, IngressConfig, IngressCounters, Request, Response,
    StreamRoute,
};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;
use streamjson::BatchShape;

/// Build the API handler for an engine. `ready` gates /healthz and
/// /score until warm-up completes (a pod readiness gate).
pub fn api_handler(engine: Arc<Engine>, ready: Arc<AtomicBool>) -> Arc<Handler> {
    Arc::new(move |req: &Request| route(&engine, &ready, req))
}

fn route(engine: &Engine, ready: &AtomicBool, req: &Request) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            if ready.load(Ordering::SeqCst) {
                Response::text(200, "ok")
            } else {
                Response::text(503, "warming up")
            }
        }
        ("POST", "/score") => {
            if !ready.load(Ordering::SeqCst) {
                return Response::json(503, r#"{"error":"warming up"}"#);
            }
            match handle_score(engine, &req.body) {
                Ok(resp) => resp,
                Err(e) => Response::json(
                    422,
                    Json::obj(vec![("error", Json::str(e.to_string()))]).to_string(),
                ),
            }
        }
        ("POST", "/v1/score/batch") => {
            if !ready.load(Ordering::SeqCst) {
                return Response::json(503, r#"{"error":"warming up"}"#);
            }
            match handle_score_batch(engine, &req.body) {
                Ok(resp) => resp,
                Err(e) => Response::json(
                    422,
                    Json::obj(vec![("error", Json::str(e.to_string()))]).to_string(),
                ),
            }
        }
        ("GET", "/metrics") => Response::json(200, metrics_json(engine)),
        ("GET", "/v1/lifecycle") => Response::json(200, lifecycle_status_json(engine, false)),
        ("POST", "/v1/lifecycle/check") => match &engine.lifecycle {
            None => Response::json(422, r#"{"error":"lifecycle is not enabled"}"#),
            Some(hub) => match hub.tick(engine) {
                Ok(_) => Response::json(200, lifecycle_status_json(engine, true)),
                Err(e) => Response::json(
                    500,
                    Json::obj(vec![("error", Json::str(e.to_string()))]).to_string(),
                ),
            },
        },
        ("GET", "/admin/stats") => {
            let s = engine.registry.stats();
            // One wait-free snapshot load: the same world the data
            // plane is routing on right now.
            let snap = engine.load_snapshot();
            let body = Json::obj(vec![
                ("predictors", Json::Num(s.predictors as f64)),
                ("model_references", Json::Num(s.model_references as f64)),
                ("live_containers", Json::Num(s.pool.live_containers as f64)),
                ("spawned_total", Json::Num(s.pool.spawned_total as f64)),
                ("datalake_records", Json::Num(engine.lake.len() as f64)),
                ("snapshot_predictors", Json::Num(snap.predictor_count() as f64)),
                (
                    "snapshot_scoring_rules",
                    Json::Num(snap.routing.scoring_rules.len() as f64),
                ),
            ])
            .to_string();
            Response::json(200, body)
        }
        ("POST", _) | ("GET", _) => Response::text(404, "not found"),
        _ => Response::text(405, "method not allowed"),
    }
}

/// `GET /metrics` body, streamed. The counter registry and the
/// per-tenant `scored_events` slab are written entry-by-entry into
/// the response buffer — borrowed names, no intermediate tree. The
/// old builder cloned two whole `BTreeMap`s (every counter name +
/// every tenant key) per scrape; at 100k tenants that was ~100k
/// `String` allocations per poll of what is typically a 10s-interval
/// endpoint hammered by every scrape agent in the fleet. Public so
/// `benches/serving_bench.rs` can measure the scrape directly.
pub fn metrics_json(engine: &Engine) -> String {
    let mut body = String::with_capacity(1024);
    body.push_str("{\"counters\":{");
    let mut first = true;
    engine.counters.for_each(|name, v| {
        if !first {
            body.push(',');
        }
        first = false;
        write_escaped(name, &mut body);
        body.push(':');
        write_num(v as f64, &mut body);
    });

    // Per-tenant batch scored events. Slab entries stream in handle
    // order; a tenant retired and re-onboarded owns several handles,
    // and JSON object keys must stay unique, so the (rare) counts
    // riding on handles that are no longer the name's current binding
    // are pre-merged by name and folded into the live entry — totals
    // per key match `Engine::scored_events_snapshot` exactly.
    body.push_str("},\"scored_events\":{");
    let mut stale: std::collections::BTreeMap<std::sync::Arc<str>, u64> =
        std::collections::BTreeMap::new();
    engine.tenant_events.for_each(|index, n| {
        if n == 0 {
            return;
        }
        let h = TenantHandle::from_index(index);
        if let Some(name) = engine.tenants.name(h) {
            if engine.tenants.lookup(&name) != Some(h) {
                *stale.entry(name).or_insert(0) += n;
            }
        }
    });
    let mut first = true;
    engine.tenant_events.for_each(|index, n| {
        if n == 0 {
            return;
        }
        let h = TenantHandle::from_index(index);
        let Some(name) = engine.tenants.name(h) else {
            return;
        };
        if engine.tenants.lookup(&name) != Some(h) {
            return; // merged into the live entry (or the tail below)
        }
        let total = n + stale.remove(&*name).unwrap_or(0);
        if !first {
            body.push(',');
        }
        first = false;
        write_escaped(&name, &mut body);
        body.push(':');
        write_num(total as f64, &mut body);
    });
    for (name, n) in stale {
        // Counts whose tenant is retired with no current binding.
        if !first {
            body.push(',');
        }
        first = false;
        write_escaped(&name, &mut body);
        body.push(':');
        write_num(n as f64, &mut body);
    }

    body.push_str("},\"latency_ms\":");
    body.push_str(
        &Json::obj(vec![
            ("p50", Json::Num(engine.live_latency.percentile_ns(50.0) as f64 / 1e6)),
            ("p99", Json::Num(engine.live_latency.percentile_ns(99.0) as f64 / 1e6)),
            ("p999", Json::Num(engine.live_latency.percentile_ns(99.9) as f64 / 1e6)),
            ("count", Json::Num(engine.live_latency.count() as f64)),
        ])
        .to_string(),
    );
    body.push_str(",\"batch_latency_ms\":");
    body.push_str(
        &Json::obj(vec![
            ("p50", Json::Num(engine.batch_latency.percentile_ns(50.0) as f64 / 1e6)),
            ("p99", Json::Num(engine.batch_latency.percentile_ns(99.0) as f64 / 1e6)),
            ("count", Json::Num(engine.batch_latency.count() as f64)),
        ])
        .to_string(),
    );
    body.push('}');
    body
}

/// `GET /v1/lifecycle` body: autopilot enablement + per-pair status.
fn lifecycle_status_json(engine: &Engine, ticked: bool) -> String {
    let Some(hub) = &engine.lifecycle else {
        return Json::obj(vec![
            ("enabled", Json::Bool(false)),
            ("pairs", Json::Arr(vec![])),
        ])
        .to_string();
    };
    let pairs: Vec<Json> = hub
        .status()
        .iter()
        .map(|p| {
            Json::obj(vec![
                ("tenant", Json::str(p.tenant.clone())),
                ("predictor", Json::str(p.predictor.clone())),
                ("state", Json::str(p.state.as_str())),
                ("tier", Json::str(p.tier.as_str())),
                ("psi", Json::Num(p.psi)),
                ("ks", Json::Num(p.ks)),
                ("fitSamples", Json::Num(p.fit_samples as f64)),
                ("windowSamples", Json::Num(p.window_samples as f64)),
                ("baselineFrozen", Json::Bool(p.baseline_frozen)),
                ("coldstart", Json::Bool(p.coldstart)),
                ("fits", Json::Num(p.fits as f64)),
                ("promotions", Json::Num(p.promotions as f64)),
                ("validationFailures", Json::Num(p.validation_failures as f64)),
                ("droppedSamples", Json::Num(p.dropped_samples as f64)),
                (
                    "shadow",
                    match &p.shadow {
                        Some(s) => Json::str(s.clone()),
                        None => Json::Null,
                    },
                ),
                (
                    "lastError",
                    match &p.last_error {
                        Some(e) => Json::str(e.clone()),
                        None => Json::Null,
                    },
                ),
            ])
        })
        .collect();
    Json::obj(vec![
        ("enabled", Json::Bool(true)),
        ("ticked", Json::Bool(ticked)),
        ("pairs", Json::Arr(pairs)),
    ])
    .to_string()
}

/// Parse one score payload object into a [`ScoreRequest`] (shared by
/// the single and the batch endpoint, so both accept the same shape).
fn parse_score_request(v: &Json) -> Result<ScoreRequest> {
    let features = v
        .req("features")?
        .to_f32_vec()
        .ok_or_else(|| anyhow::anyhow!("features must be an array of numbers"))?;
    let get = |k: &str| v.get(k).and_then(Json::as_str).unwrap_or("").to_string();
    Ok(ScoreRequest {
        intent: Intent {
            tenant: v.req_str("tenant")?.to_string(),
            geography: get("geography"),
            schema: get("schema"),
            channel: get("channel"),
        },
        entity: get("entity"),
        features,
    })
}

fn score_response_json(resp: &crate::coordinator::ScoreResponse) -> Json {
    Json::obj(vec![
        ("score", Json::Num(resp.score)),
        ("predictor", Json::str(resp.predictor.as_ref())),
        ("shadows", Json::Num(resp.shadow_count as f64)),
    ])
}

fn handle_score(engine: &Engine, body: &str) -> Result<Response> {
    let v = crate::util::json::parse(body)?;
    let req = parse_score_request(&v)?;
    let resp = engine.score(&req)?;
    Ok(Response::json(200, score_response_json(&resp).to_string()))
}

/// `POST /v1/score/batch`: the whole batch is scored off one engine
/// snapshot load (`Engine::score_batch`); results preserve input
/// order. Oversized batches (> `server.maxBatchEvents`) are rejected
/// by the engine's admission cap and surface as 422.
fn handle_score_batch(engine: &Engine, body: &str) -> Result<Response> {
    let v = crate::util::json::parse(body)?;
    let events = v
        .req("events")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("events must be a list of score payloads"))?;
    let reqs = events
        .iter()
        .map(parse_score_request)
        .collect::<Result<Vec<_>>>()?;
    batch_response(engine, &reqs)
}

/// Score a parsed batch and render the `{count, results}` body — the
/// single serialization point shared by the buffered handler and the
/// streaming sink, so the two paths are byte-identical by
/// construction.
fn batch_response(engine: &Engine, reqs: &[ScoreRequest]) -> Result<Response> {
    let resps = engine.score_batch(reqs)?;
    let results: Vec<Json> = resps.iter().map(score_response_json).collect();
    Ok(Response::json(
        200,
        Json::obj(vec![
            ("count", Json::Num(results.len() as f64)),
            ("results", Json::Arr(results)),
        ])
        .to_string(),
    ))
}

/// The buffered route's 422 envelope (`route()` wraps handler errors
/// the same way); the streaming sink reuses it so error bodies match
/// byte-for-byte.
fn error_422(msg: impl Into<String>) -> Response {
    Response::json(
        422,
        Json::obj(vec![("error", Json::str(msg.into()))]).to_string(),
    )
}

// -----------------------------------------------------------------------
// Tenant-priority admission control
// -----------------------------------------------------------------------

/// Sheds batch requests by tenant priority when the engine's dynamic
/// batchers back up. The threshold for a tenant is
/// `shedQueueDepth << priority` (priorities are capped at 16 by
/// config validation): each priority level doubles how deep the
/// queue may grow before that tenant is turned away, so
/// high-priority tenants keep landing while bulk traffic sheds
/// first. `shedQueueDepth: 0` (the default) disables shedding.
pub struct AdmissionControl {
    /// Shared tenant interner (the engine's in production): configured
    /// priorities resolve to handles **once, here at construction** —
    /// the shed gate re-probes nothing per batch.
    tenants: Arc<TenantInterner>,
    /// Priority by tenant-handle index; out-of-range handles (tenants
    /// interned after construction) and never-interned names get
    /// `default_priority` — exactly the unlisted-tenant semantics the
    /// old per-batch linear scan had.
    by_handle: Vec<u8>,
    default_priority: u8,
    shed_queue_depth: usize,
    /// Current pressure signal — in production
    /// [`Engine::ingress_pressure`], injectable in tests/storms.
    depth_probe: Box<dyn Fn() -> usize + Send + Sync>,
}

impl AdmissionControl {
    pub fn new(
        priorities: Vec<(String, u8)>,
        default_priority: u8,
        shed_queue_depth: usize,
        depth_probe: Box<dyn Fn() -> usize + Send + Sync>,
    ) -> AdmissionControl {
        Self::with_interner(
            priorities,
            default_priority,
            shed_queue_depth,
            depth_probe,
            Arc::new(TenantInterner::new()),
        )
    }

    /// Build against an existing interner so the admission table and
    /// the engine's scoring paths agree on handle numbering.
    pub fn with_interner(
        priorities: Vec<(String, u8)>,
        default_priority: u8,
        shed_queue_depth: usize,
        depth_probe: Box<dyn Fn() -> usize + Send + Sync>,
        tenants: Arc<TenantInterner>,
    ) -> AdmissionControl {
        let mut by_handle: Vec<u8> = Vec::new();
        for (t, p) in &priorities {
            let idx = tenants.resolve(t).index();
            if by_handle.len() <= idx {
                by_handle.resize(idx + 1, default_priority);
            }
            by_handle[idx] = *p;
        }
        AdmissionControl {
            tenants,
            by_handle,
            default_priority,
            shed_queue_depth,
            depth_probe,
        }
    }

    /// Wire up from the `server:` config block with the engine's
    /// live batcher-depth gauge as the pressure probe, sharing the
    /// engine's tenant interner.
    pub fn from_config(cfg: &ServerConfig, engine: Arc<Engine>) -> AdmissionControl {
        let tenants = Arc::clone(&engine.tenants);
        AdmissionControl::with_interner(
            cfg.tenant_priorities.clone(),
            cfg.default_priority,
            cfg.shed_queue_depth,
            Box::new(move || engine.ingress_pressure()),
            tenants,
        )
    }

    /// A tenant's configured priority: one interner lookup + one array
    /// load. `lookup` (not `resolve`) on purpose — junk tenant names
    /// arriving at the shed gate must not grow the shared table;
    /// interning happens only after admission, at the scoring edge.
    pub fn priority(&self, tenant: &str) -> u8 {
        self.tenants
            .lookup(tenant)
            .and_then(|h| self.by_handle.get(h.index()).copied())
            .unwrap_or(self.default_priority)
    }

    /// Queue depth above which `tenant` is shed
    /// (`shedQueueDepth << priority`, saturating — a huge configured
    /// depth must never wrap into a tiny threshold).
    pub fn threshold(&self, tenant: &str) -> usize {
        let p = self.priority(tenant).min(64) as u32; // config caps at 16
        let shifted = (self.shed_queue_depth as u128) << p;
        shifted.min(usize::MAX as u128) as usize
    }

    /// Admit a batch for `tenant` right now? Wait-free: one snapshot
    /// load plus relaxed gauge reads.
    pub fn admit(&self, tenant: &str) -> bool {
        self.shed_queue_depth == 0 || (self.depth_probe)() <= self.threshold(tenant)
    }

    /// The shed response: `429` with `Retry-After: 1` so well-behaved
    /// clients back off for a batching interval before retrying.
    fn shed_response(&self, tenant: &str) -> Response {
        Response::json(
            429,
            Json::obj(vec![(
                "error",
                Json::str(format!("overloaded: shedding tenant '{tenant}'")),
            )])
            .to_string(),
        )
        .with_retry_after(1)
    }
}

// -----------------------------------------------------------------------
// Streaming batch route
// -----------------------------------------------------------------------

/// Claims `POST /v1/score/batch` for incremental parsing. Returning
/// `None` (warming up, or some other route) falls back to the
/// buffered handler, which produces the identical response.
pub struct ScoreBatchRoute {
    pub engine: Arc<Engine>,
    pub ready: Arc<AtomicBool>,
    pub admission: Arc<AdmissionControl>,
}

impl StreamRoute for ScoreBatchRoute {
    fn begin(&self, method: &str, path: &str) -> Option<Box<dyn BatchSink>> {
        if method != "POST" || path != "/v1/score/batch" {
            return None;
        }
        if !self.ready.load(Ordering::SeqCst) {
            // Buffered path answers `503 warming up` before parsing;
            // declining here routes the request there.
            return None;
        }
        Some(Box::new(ScoreBatchSink {
            engine: Arc::clone(&self.engine),
            admission: Arc::clone(&self.admission),
            reqs: Vec::new(),
            deferred: None,
        }))
    }
}

/// Collects parsed events as the body streams in. Error surfacing is
/// deliberately *deferred*: the buffered path parses the whole body
/// before validating events, so the first invalid event must produce
/// the same 422 whether it arrives early or late in the stream — we
/// record it, keep draining (keeps the connection synced), and
/// answer at body end.
struct ScoreBatchSink {
    engine: Arc<Engine>,
    admission: Arc<AdmissionControl>,
    reqs: Vec<ScoreRequest>,
    deferred: Option<String>,
}

impl BatchSink for ScoreBatchSink {
    fn event(&mut self, value: Json) -> Option<Response> {
        if self.deferred.is_some() {
            return None; // first error wins, like the buffered path
        }
        match parse_score_request(&value) {
            Ok(req) => {
                // Admission is decided on the batch's first event —
                // the tenant is known, nothing is queued yet.
                if self.reqs.is_empty() && !self.admission.admit(&req.intent.tenant) {
                    return Some(self.admission.shed_response(&req.intent.tenant));
                }
                self.reqs.push(req);
            }
            Err(e) => self.deferred = Some(e.to_string()),
        }
        None
    }

    fn restart(&mut self) {
        // A later top-level `"events"` key supersedes this one
        // (duplicate-key last-wins, matching `util::json::parse`).
        self.reqs.clear();
        self.deferred = None;
    }

    fn finish(self: Box<Self>, shape: BatchShape) -> Response {
        if let Some(msg) = self.deferred {
            return error_422(msg);
        }
        if !shape.events_seen {
            // Byte-identical to the buffered path's
            // `v.req("events")` failure on a valid body.
            let missing = Json::obj(vec![]).req("events").unwrap_err();
            return error_422(missing.to_string());
        }
        if !shape.events_is_array {
            return error_422("events must be a list of score payloads");
        }
        match batch_response(&self.engine, &self.reqs) {
            Ok(resp) => resp,
            Err(e) => error_422(e.to_string()),
        }
    }
}

/// Convenience: build + bind + warm up + serve on a background thread.
/// Returns (address, ready flag, server thread handle).
pub fn spawn_server(
    engine: Arc<Engine>,
    addr: &str,
    workers: usize,
    warmup_requests: usize,
) -> Result<(String, Arc<AtomicBool>, std::thread::JoinHandle<()>)> {
    let ready = Arc::new(AtomicBool::new(false));
    let handler = api_handler(Arc::clone(&engine), Arc::clone(&ready));
    // Ingress limits from the engine's `server:` config block —
    // oversized requests bounce with 413 before their bodies are
    // read, slow readers hit 408, oversized heads 431.
    let cfg = &engine.server_cfg;
    let config = IngressConfig {
        max_body: engine.max_body_bytes,
        max_header: cfg.max_header_bytes,
        max_connections: cfg.max_connections,
        header_deadline: Duration::from_millis(cfg.header_read_timeout_ms),
        body_deadline: Duration::from_millis(cfg.body_read_timeout_ms),
    };
    // Ingress counters live in the engine's registry so they show up
    // in `GET /metrics` next to the serving counters.
    let ingress = IngressCounters::resolve(&engine.counters);
    let stream_route: Option<Arc<dyn StreamRoute>> = if cfg.stream_batch {
        let admission = Arc::new(AdmissionControl::from_config(cfg, Arc::clone(&engine)));
        Some(Arc::new(ScoreBatchRoute {
            engine: Arc::clone(&engine),
            ready: Arc::clone(&ready),
            admission,
        }))
    } else {
        None
    };
    let server = HttpServer::bind_with_config(addr, workers, handler, config, ingress, stream_route)?;
    let bound = server.local_addr();
    let handle = std::thread::spawn(move || {
        let _ = server.serve();
    });
    // Warm up before flipping readiness (paper Section 3.1.2).
    crate::coordinator::warm_up(&engine, warmup_requests, 0xC0FFEE)?;
    ready.store(true, Ordering::SeqCst);
    Ok((bound, ready, handle))
}

// -----------------------------------------------------------------------
// Cluster front end
// -----------------------------------------------------------------------

/// Build the API handler for a cluster: scoring flows through the
/// rendezvous gateway (tenant-consistent, fails over past non-serving
/// nodes), `GET /v1/cluster` reports the replicated control plane.
pub fn cluster_api_handler(
    cluster: Arc<crate::cluster::MuseCluster>,
    ready: Arc<AtomicBool>,
) -> Arc<Handler> {
    Arc::new(move |req: &Request| cluster_route(&cluster, &ready, req))
}

fn cluster_route(
    cluster: &crate::cluster::MuseCluster,
    ready: &AtomicBool,
    req: &Request,
) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            if ready.load(Ordering::SeqCst) && !cluster.serving_nodes().is_empty() {
                Response::text(200, "ok")
            } else {
                Response::text(503, "warming up")
            }
        }
        ("GET", "/v1/cluster") => Response::json(200, cluster_status_json(cluster)),
        ("POST", "/score") => {
            if !ready.load(Ordering::SeqCst) {
                return Response::json(503, r#"{"error":"warming up"}"#);
            }
            match handle_cluster_score(cluster, &req.body) {
                Ok(resp) => resp,
                Err(e) => error_422(e.to_string()),
            }
        }
        ("POST", "/v1/score/batch") => {
            if !ready.load(Ordering::SeqCst) {
                return Response::json(503, r#"{"error":"warming up"}"#);
            }
            match handle_cluster_score_batch(cluster, &req.body) {
                Ok(resp) => resp,
                Err(e) => error_422(e.to_string()),
            }
        }
        ("POST", _) | ("GET", _) => Response::text(404, "not found"),
        _ => Response::text(405, "method not allowed"),
    }
}

/// `GET /v1/cluster`: the two-phase control plane's own ledger.
fn cluster_status_json(cluster: &crate::cluster::MuseCluster) -> String {
    let s = cluster.status();
    let nodes: Vec<Json> = s
        .nodes
        .iter()
        .map(|n| {
            Json::obj(vec![
                ("id", Json::Num(n.id as f64)),
                ("state", Json::str(n.state.name())),
                ("epoch", Json::Num(n.epoch as f64)),
                ("flipping", Json::Bool(n.flipping)),
                ("lakeRecords", Json::Num(n.lake_records as f64)),
                ("scored", Json::Num(n.scored as f64)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("committedEpoch", Json::Num(s.committed_epoch as f64)),
        ("publishes", Json::Num(s.stats.publishes as f64)),
        ("aborted", Json::Num(s.stats.aborted as f64)),
        ("crashes", Json::Num(s.stats.crashes as f64)),
        ("joins", Json::Num(s.stats.joins as f64)),
        ("leaves", Json::Num(s.stats.leaves as f64)),
        (
            "flipLatencyMs",
            Json::obj(vec![
                ("p50", Json::Num(s.flip_p50_ms)),
                ("p99", Json::Num(s.flip_p99_ms)),
            ]),
        ),
        ("nodes", Json::Arr(nodes)),
    ])
    .to_string()
}

fn handle_cluster_score(
    cluster: &crate::cluster::MuseCluster,
    body: &str,
) -> Result<Response> {
    let v = crate::util::json::parse(body)?;
    let req = parse_score_request(&v)?;
    let g = cluster.gateway().score(&req)?;
    let mut fields = match score_response_json(&g.resp) {
        Json::Obj(fields) => fields,
        _ => unreachable!("score_response_json returns an object"),
    };
    fields.push(("node".to_string(), Json::Num(g.node as f64)));
    fields.push(("epochLo".to_string(), Json::Num(g.epoch_lo as f64)));
    fields.push(("epochHi".to_string(), Json::Num(g.epoch_hi as f64)));
    Ok(Response::json(200, Json::Obj(fields).to_string()))
}

/// The whole batch is routed to one node by its first event's tenant
/// and scored off one engine snapshot there; the attribution window
/// covers every event in the batch.
fn handle_cluster_score_batch(
    cluster: &crate::cluster::MuseCluster,
    body: &str,
) -> Result<Response> {
    let v = crate::util::json::parse(body)?;
    let events = v
        .req("events")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("events must be a list of score payloads"))?;
    let reqs = events
        .iter()
        .map(parse_score_request)
        .collect::<Result<Vec<_>>>()?;
    let b = cluster.gateway().score_batch(&reqs)?;
    let results: Vec<Json> = b.resps.iter().map(score_response_json).collect();
    Ok(Response::json(
        200,
        Json::obj(vec![
            ("count", Json::Num(results.len() as f64)),
            ("node", Json::Num(b.node as f64)),
            ("epochLo", Json::Num(b.epoch_lo as f64)),
            ("epochHi", Json::Num(b.epoch_hi as f64)),
            ("results", Json::Arr(results)),
        ])
        .to_string(),
    ))
}

/// Convenience: bind the cluster front end, warm every serving node,
/// flip readiness, serve on a background thread. Ingress limits and
/// counters come from the first node's engine — every replica runs
/// the same `server:` block, and parking the `ingress_*` counters in
/// one node's registry keeps them inspectable.
pub fn spawn_cluster_server(
    cluster: Arc<crate::cluster::MuseCluster>,
    addr: &str,
    workers: usize,
    warmup_requests: usize,
) -> Result<(String, Arc<AtomicBool>, std::thread::JoinHandle<()>)> {
    let nodes = cluster.serving_nodes();
    let first = nodes
        .first()
        .ok_or_else(|| anyhow::anyhow!("cluster has no serving nodes"))?;
    let cfg = &first.engine.server_cfg;
    let config = IngressConfig {
        max_body: first.engine.max_body_bytes,
        max_header: cfg.max_header_bytes,
        max_connections: cfg.max_connections,
        header_deadline: Duration::from_millis(cfg.header_read_timeout_ms),
        body_deadline: Duration::from_millis(cfg.body_read_timeout_ms),
    };
    let ingress = IngressCounters::resolve(&first.engine.counters);
    let ready = Arc::new(AtomicBool::new(false));
    let handler = cluster_api_handler(Arc::clone(&cluster), Arc::clone(&ready));
    let server = HttpServer::bind_with_config(addr, workers, handler, config, ingress, None)?;
    let bound = server.local_addr();
    let handle = std::thread::spawn(move || {
        let _ = server.serve();
    });
    // Warm every replica before flipping readiness — the gateway may
    // route a tenant to any of them.
    for node in &nodes {
        crate::coordinator::warm_up(&node.engine, warmup_requests, 0xC0FFEE)?;
    }
    ready.store(true, Ordering::SeqCst);
    Ok((bound, ready, handle))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MuseConfig;
    use crate::runtime::{Manifest, ModelPool};
    use crate::server::http::http_request;
    use std::path::PathBuf;

    const CONFIG: &str = r#"
routing:
  scoringRules:
  - description: "catch-all"
    condition: {}
    targetPredictorName: "p"
predictors:
- name: p
  experts: [m1, m2]
  quantile: identity
"#;

    fn engine() -> Option<Arc<Engine>> {
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !root.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let pool = Arc::new(ModelPool::new(Manifest::load(root).unwrap()));
        Some(Arc::new(
            Engine::build(&MuseConfig::from_yaml(CONFIG).unwrap(), pool).unwrap(),
        ))
    }

    #[test]
    fn end_to_end_http_scoring() {
        let Some(engine) = engine() else { return };
        let d = engine.predictor("p").unwrap().feature_dim();
        let (addr, _ready, _h) = spawn_server(engine, "127.0.0.1:0", 2, 10).unwrap();
        let (status, body) = http_request(&addr, "GET", "/healthz", "").unwrap();
        assert_eq!((status, body.as_str()), (200, "ok"));

        let features: Vec<String> = (0..d).map(|i| format!("{}", 0.01 * i as f32)).collect();
        let payload = format!(
            r#"{{"tenant": "bank1", "features": [{}]}}"#,
            features.join(",")
        );
        let (status, body) = http_request(&addr, "POST", "/score", &payload).unwrap();
        assert_eq!(status, 200, "{body}");
        let v = crate::util::json::parse(&body).unwrap();
        let score = v.req_f64("score").unwrap();
        assert!((0.0..=1.0).contains(&score));
        assert_eq!(v.req_str("predictor").unwrap(), "p");

        let (status, body) = http_request(&addr, "GET", "/metrics", "").unwrap();
        assert_eq!(status, 200);
        assert!(body.contains("latency_ms"), "{body}");

        let (status, body) = http_request(&addr, "GET", "/admin/stats", "").unwrap();
        assert_eq!(status, 200);
        let v = crate::util::json::parse(&body).unwrap();
        assert_eq!(v.req_f64("live_containers").unwrap(), 2.0);
    }

    #[test]
    fn batch_endpoint_agrees_with_sequential_scores() {
        let Some(engine) = engine() else { return };
        let d = engine.predictor("p").unwrap().feature_dim();
        let (addr, _ready, _h) = spawn_server(engine, "127.0.0.1:0", 2, 10).unwrap();
        let mut rng = crate::util::rng::Rng::new(7);
        let payloads: Vec<String> = (0..6)
            .map(|i| {
                let feats: Vec<String> =
                    (0..d).map(|_| format!("{:.6}", rng.normal())).collect();
                format!(
                    r#"{{"tenant": "bank{}", "features": [{}]}}"#,
                    i % 2,
                    feats.join(",")
                )
            })
            .collect();
        // N sequential /score calls...
        let mut sequential = Vec::new();
        for p in &payloads {
            let (status, body) = http_request(&addr, "POST", "/score", p).unwrap();
            assert_eq!(status, 200, "{body}");
            let v = crate::util::json::parse(&body).unwrap();
            sequential.push(v.req_f64("score").unwrap());
        }
        // ...must agree with one batch call, in order.
        let batch_payload = format!(r#"{{"events": [{}]}}"#, payloads.join(","));
        let (status, body) =
            http_request(&addr, "POST", "/v1/score/batch", &batch_payload).unwrap();
        assert_eq!(status, 200, "{body}");
        let v = crate::util::json::parse(&body).unwrap();
        assert_eq!(v.req_f64("count").unwrap(), 6.0);
        let results = v.req("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 6);
        for (r, want) in results.iter().zip(&sequential) {
            let got = r.req_f64("score").unwrap();
            // Cross-batch-variant PJRT tolerance (see engine tests).
            assert!((got - want).abs() < 2e-5, "batch {got} vs sequential {want}");
            assert_eq!(r.req_str("predictor").unwrap(), "p");
        }
    }

    #[test]
    fn batch_endpoint_rejects_malformed_and_oversized() {
        let Some(engine) = engine() else { return };
        let cap = engine.max_batch_events;
        let d = engine.predictor("p").unwrap().feature_dim();
        let (addr, _ready, _h) = spawn_server(engine, "127.0.0.1:0", 2, 5).unwrap();
        for bad in [
            "",
            "{}",
            r#"{"events": "nope"}"#,
            r#"{"events": [{"tenant": "x"}]}"#, // event missing features
        ] {
            let (status, _) = http_request(&addr, "POST", "/v1/score/batch", bad).unwrap();
            assert_eq!(status, 422, "payload: {bad}");
        }
        // One event over the admission cap -> 422 with the cap named.
        let ev = format!(
            r#"{{"tenant": "t", "features": [{}]}}"#,
            vec!["0.0"; d].join(",")
        );
        let evs = vec![ev; cap + 1];
        let (status, body) = http_request(
            &addr,
            "POST",
            "/v1/score/batch",
            &format!(r#"{{"events": [{}]}}"#, evs.join(",")),
        )
        .unwrap();
        assert_eq!(status, 422, "{body}");
        assert!(body.contains("maxBatchEvents"), "{body}");
    }

    #[test]
    fn malformed_score_payloads_are_422() {
        let Some(engine) = engine() else { return };
        let (addr, _ready, _h) = spawn_server(engine, "127.0.0.1:0", 2, 5).unwrap();
        for bad in [
            "",                       // empty
            "{}",                     // missing fields
            r#"{"tenant": "x"}"#,     // no features
            r#"{"tenant": "x", "features": "nope"}"#,
            r#"{"tenant": "x", "features": [1,2]}"#, // wrong dim is 422 via engine? enrich pads -> ok actually
        ]
        .iter()
        .take(4)
        {
            let (status, _) = http_request(&addr, "POST", "/score", bad).unwrap();
            assert_eq!(status, 422, "payload: {bad}");
        }
    }

    #[test]
    fn lifecycle_endpoints_report_and_tick() {
        // Sim-dialect artifacts: runs without `make artifacts`.
        let (_fix, engine) = crate::simulator::drift_storm::tests::sim_engine("");
        let d = crate::simulator::FEATURE_DIM;
        let (addr, _ready, _h) = spawn_server(engine, "127.0.0.1:0", 2, 5).unwrap();

        // Status before any tick: enabled, no pairs yet.
        let (status, body) = http_request(&addr, "GET", "/v1/lifecycle", "").unwrap();
        assert_eq!(status, 200, "{body}");
        let v = crate::util::json::parse(&body).unwrap();
        assert_eq!(v.get("enabled").and_then(crate::util::json::Json::as_bool), Some(true));

        // Score some traffic for the managed tenant, then trigger a
        // manual check: the pair must appear, observing.
        let features = vec!["0.1"; d].join(",");
        let payload = format!(r#"{{"tenant": "acme", "features": [{features}]}}"#);
        for _ in 0..3 {
            let (s, b) = http_request(&addr, "POST", "/score", &payload).unwrap();
            assert_eq!(s, 200, "{b}");
        }
        let (status, body) = http_request(&addr, "POST", "/v1/lifecycle/check", "").unwrap();
        assert_eq!(status, 200, "{body}");
        let v = crate::util::json::parse(&body).unwrap();
        assert_eq!(v.get("ticked").and_then(crate::util::json::Json::as_bool), Some(true));
        let pairs = v.req("pairs").unwrap().as_arr().unwrap();
        assert_eq!(pairs.len(), 1, "{body}");
        assert_eq!(pairs[0].req_str("tenant").unwrap(), "acme");
        assert_eq!(pairs[0].req_str("predictor").unwrap(), "duo");
        assert_eq!(pairs[0].req_str("state").unwrap(), "observing");
        // The tick also shows up in /metrics counters.
        let (_, metrics) = http_request(&addr, "GET", "/metrics", "").unwrap();
        assert!(metrics.contains("lifecycle_ticks"), "{metrics}");
    }

    #[test]
    fn lifecycle_endpoints_when_disabled() {
        let fix = crate::runtime::SimArtifacts::in_temp().unwrap();
        let pool = Arc::new(crate::runtime::ModelPool::new(fix.manifest().unwrap()));
        let yaml = r#"
routing:
  scoringRules:
  - description: "catch-all"
    condition: {}
    targetPredictorName: "p"
predictors:
- name: p
  experts: [s3]
  quantile: identity
"#;
        let engine = Arc::new(
            Engine::build(&MuseConfig::from_yaml(yaml).unwrap(), pool).unwrap(),
        );
        let (addr, _ready, _h) = spawn_server(engine, "127.0.0.1:0", 2, 5).unwrap();
        let (status, body) = http_request(&addr, "GET", "/v1/lifecycle", "").unwrap();
        assert_eq!(status, 200);
        let v = crate::util::json::parse(&body).unwrap();
        assert_eq!(v.get("enabled").and_then(crate::util::json::Json::as_bool), Some(false));
        let (status, _) = http_request(&addr, "POST", "/v1/lifecycle/check", "").unwrap();
        assert_eq!(status, 422);
    }

    #[test]
    fn configured_body_cap_is_enforced_end_to_end() {
        // Sim-dialect artifacts: runs without `make artifacts`.
        let fix = crate::runtime::SimArtifacts::in_temp().unwrap();
        let pool = Arc::new(crate::runtime::ModelPool::new(fix.manifest().unwrap()));
        let yaml = r#"
routing:
  scoringRules:
  - description: "catch-all"
    condition: {}
    targetPredictorName: "p"
predictors:
- name: p
  experts: [s3]
  quantile: identity
server:
  maxBodyBytes: 2048
"#;
        let engine = Arc::new(
            Engine::build(&MuseConfig::from_yaml(yaml).unwrap(), pool).unwrap(),
        );
        assert_eq!(engine.max_body_bytes, 2048);
        let (addr, _ready, _h) = spawn_server(Arc::clone(&engine), "127.0.0.1:0", 2, 5).unwrap();
        // A payload over the configured cap bounces with 413...
        let big = format!(r#"{{"tenant": "t", "pad": "{}"}}"#, "x".repeat(4096));
        let (status, body) = http_request(&addr, "POST", "/score", &big).unwrap();
        assert_eq!(status, 413, "{body}");
        // ...while a normal request on a fresh connection still works.
        let d = crate::simulator::FEATURE_DIM;
        let payload = format!(
            r#"{{"tenant": "t", "features": [{}]}}"#,
            vec!["0.1"; d].join(",")
        );
        let (status, body) = http_request(&addr, "POST", "/score", &payload).unwrap();
        assert_eq!(status, 200, "{body}");
    }

    #[test]
    fn unknown_route_404s() {
        let Some(engine) = engine() else { return };
        let (addr, _ready, _h) = spawn_server(engine, "127.0.0.1:0", 2, 5).unwrap();
        let (status, _) = http_request(&addr, "GET", "/nope", "").unwrap();
        assert_eq!(status, 404);
    }

    /// Sim-dialect config shared by the ingress tests below.
    const SIM_YAML: &str = r#"
routing:
  scoringRules:
  - description: "catch-all"
    condition: {}
    targetPredictorName: "p"
predictors:
- name: p
  experts: [s3]
  quantile: identity
"#;

    /// Raw round-trip with `Connection: close` so response *headers*
    /// are visible (the `http_request` helper strips them).
    fn raw_request(addr: &str, method: &str, path: &str, body: &str) -> String {
        use std::io::{Read, Write};
        let mut s = std::net::TcpStream::connect(addr).unwrap();
        write!(
            s,
            "{method} {path} HTTP/1.1\r\nHost: x\r\nConnection: close\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        )
        .unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    /// The tentpole's differential guarantee: every body — valid,
    /// malformed, adversarial — gets the *same bytes* back whether it
    /// flows through the incremental streaming sink (default) or the
    /// seed's buffered handler (`server.streamBatch: false`). Both
    /// engines score off the same sim artifacts, so even the float
    /// results must match exactly.
    #[test]
    fn streamed_and_buffered_batch_responses_are_bitwise_identical() {
        let fix = crate::runtime::SimArtifacts::in_temp().unwrap();
        let spawn = |extra: &str| {
            let pool = Arc::new(crate::runtime::ModelPool::new(fix.manifest().unwrap()));
            let yaml = format!("{SIM_YAML}{extra}");
            let engine =
                Arc::new(Engine::build(&MuseConfig::from_yaml(&yaml).unwrap(), pool).unwrap());
            spawn_server(engine, "127.0.0.1:0", 2, 5).unwrap().0
        };
        let streamed = spawn("");
        let buffered = spawn("server:\n  streamBatch: false\n");

        let d = crate::simulator::FEATURE_DIM;
        let feats = vec!["0.25"; d].join(",");
        let ev = format!(r#"{{"tenant": "acme", "features": [{feats}]}}"#);
        let bodies: Vec<String> = vec![
            format!(r#"{{"events": [{ev}, {ev}]}}"#), // happy path
            String::new(),                            // empty body
            "{}".to_string(),                         // no events key
            r#"{"other": 1}"#.to_string(),            // no events key
            r#"{"events": 3}"#.to_string(),           // events not a list
            r#"{"events": [{"tenant": "x"}]}"#.to_string(), // event missing features
            format!(r#"{{"events": [{ev}, {{"tenant": 7}}]}}"#), // second event bad
            r#"{"events": ["#.to_string(),            // truncated JSON
            r#"{"events": [{]}"#.to_string(),         // syntax error mid-object
            format!(r#"{{"events": "no", "events": [{ev}]}}"#), // dup key, last wins
            format!(r#"{{"events": [{ev}], "events": "no"}}"#), // dup key, last invalid
            format!(r#"{{"events": [{ev}]}} trailing"#), // trailing garbage
            r#"{"events": []}"#.to_string(),          // empty batch
        ];
        for body in &bodies {
            let a = http_request(&streamed, "POST", "/v1/score/batch", body).unwrap();
            let b = http_request(&buffered, "POST", "/v1/score/batch", body).unwrap();
            assert_eq!(a, b, "streamed vs buffered diverged for body: {body:?}");
        }
        // The streaming plane accounts itself in /metrics.
        let (status, metrics) = http_request(&streamed, "GET", "/metrics", "").unwrap();
        assert_eq!(status, 200);
        assert!(metrics.contains("ingress_accepted"), "{metrics}");
        assert!(metrics.contains("ingress_streamed_events"), "{metrics}");
    }

    /// The cluster front end end-to-end: gateway-routed scoring with
    /// epoch attribution, `/v1/cluster` control-plane reporting, and
    /// a two-phase promote visible through both.
    #[test]
    fn cluster_front_end_scores_and_reports_the_control_plane() {
        use crate::cluster::{ClusterCommand, ClusterOptions, MuseCluster, PoolFactory};
        use crate::config::PredictorConfig;

        let fix = crate::runtime::SimArtifacts::in_temp().unwrap();
        let yaml = r#"
routing:
  scoringRules:
  - description: "catch-all"
    condition: {}
    targetPredictorName: "p-v0"
predictors:
- name: p-v0
  experts: [s1]
  quantile: identity
server:
  workers: 2
"#;
        let root = fix.root().clone();
        let factory: PoolFactory = Box::new(move || {
            Ok(Arc::new(crate::runtime::ModelPool::new(Manifest::load(
                &root,
            )?)))
        });
        let cluster = MuseCluster::build(
            &MuseConfig::from_yaml(yaml).unwrap(),
            ClusterOptions {
                nodes: 2,
                ..ClusterOptions::default()
            },
            factory,
        )
        .unwrap();
        let d = cluster.serving_nodes()[0]
            .engine
            .predictor("p-v0")
            .unwrap()
            .feature_dim();
        let (addr, _ready, _h) =
            spawn_cluster_server(Arc::clone(&cluster), "127.0.0.1:0", 2, 3).unwrap();

        let (status, body) = http_request(&addr, "GET", "/healthz", "").unwrap();
        assert_eq!((status, body.as_str()), (200, "ok"));

        let payload = format!(
            r#"{{"tenant": "acme", "features": [{}]}}"#,
            vec!["0.2"; d].join(",")
        );
        let (status, body) = http_request(&addr, "POST", "/score", &payload).unwrap();
        assert_eq!(status, 200, "{body}");
        let v = crate::util::json::parse(&body).unwrap();
        assert_eq!(v.req_str("predictor").unwrap(), "p-v0");
        assert_eq!(v.req_f64("epochLo").unwrap(), 0.0);
        assert_eq!(v.req_f64("epochHi").unwrap(), 0.0);

        // Promote a new version through the two-phase publish; the
        // gateway must score with it and the ledger must advance.
        cluster
            .publish(ClusterCommand::ShadowDeploy {
                cfg: PredictorConfig {
                    name: "p-v1".to_string(),
                    experts: vec!["s2".to_string()],
                    weights: vec![1.0],
                    quantile_mode: crate::config::QuantileMode::Identity,
                    reference: "fraud-default".to_string(),
                    posterior_correction: false,
                },
                tenant: "acme".to_string(),
                src: vec![0.0, 1.0],
                refq: vec![0.0, 1.0],
            })
            .unwrap();
        cluster
            .publish(ClusterCommand::Promote {
                tenant: "acme".to_string(),
                predictor: "p-v1".to_string(),
            })
            .unwrap();

        let (status, body) = http_request(&addr, "POST", "/score", &payload).unwrap();
        assert_eq!(status, 200, "{body}");
        let v = crate::util::json::parse(&body).unwrap();
        assert_eq!(v.req_str("predictor").unwrap(), "p-v1");
        assert_eq!(v.req_f64("epochLo").unwrap(), 2.0);

        let batch = format!(r#"{{"events": [{payload}, {payload}]}}"#);
        let (status, body) = http_request(&addr, "POST", "/v1/score/batch", &batch).unwrap();
        assert_eq!(status, 200, "{body}");
        let v = crate::util::json::parse(&body).unwrap();
        assert_eq!(v.req_f64("count").unwrap(), 2.0);
        assert_eq!(v.req_f64("epochLo").unwrap(), 2.0);
        let results = v.req("results").unwrap().as_arr().unwrap();
        assert_eq!(results[0].req_str("predictor").unwrap(), "p-v1");

        let (status, body) = http_request(&addr, "GET", "/v1/cluster", "").unwrap();
        assert_eq!(status, 200, "{body}");
        let v = crate::util::json::parse(&body).unwrap();
        assert_eq!(v.req_f64("committedEpoch").unwrap(), 2.0);
        assert_eq!(v.req_f64("publishes").unwrap(), 2.0);
        assert_eq!(v.req_f64("joins").unwrap(), 2.0);
        assert_eq!(v.req_f64("crashes").unwrap(), 0.0);
        let nodes = v.req("nodes").unwrap().as_arr().unwrap();
        assert_eq!(nodes.len(), 2, "{body}");
        for n in nodes {
            assert_eq!(n.req_str("state").unwrap(), "serving");
            assert_eq!(n.req_f64("epoch").unwrap(), 2.0);
        }

        let (status, _) = http_request(&addr, "GET", "/nope", "").unwrap();
        assert_eq!(status, 404);
    }

    #[test]
    fn admission_thresholds_scale_with_priority() {
        let ac = AdmissionControl::new(
            vec![("vip".to_string(), 4), ("bulk".to_string(), 0)],
            1,
            64,
            Box::new(|| 500),
        );
        assert_eq!(ac.priority("vip"), 4);
        assert_eq!(ac.priority("unlisted"), 1); // defaultPriority
        assert_eq!(ac.threshold("vip"), 64 << 4);
        assert_eq!(ac.threshold("bulk"), 64);
        assert!(ac.admit("vip")); // 500 <= 1024
        assert!(!ac.admit("bulk")); // 500 > 64
        assert!(!ac.admit("unlisted")); // 500 > 128
        // shedQueueDepth 0 disables shedding no matter the pressure.
        let off = AdmissionControl::new(vec![], 0, 0, Box::new(|| usize::MAX));
        assert!(off.admit("anyone"));
        // The shift saturates instead of wrapping into a tiny value.
        let sat = AdmissionControl::new(
            vec![("t".to_string(), 16)],
            0,
            usize::MAX / 2,
            Box::new(|| 0),
        );
        assert_eq!(sat.threshold("t"), usize::MAX);
    }

    /// End-to-end tenant-priority shedding through a real server: a
    /// synthetic pressure probe reports a deep queue; the vip tenant
    /// (priority 4) still lands while bulk traffic is turned away
    /// with `429` + `Retry-After` before any scoring work is queued.
    #[test]
    fn tenant_priority_shed_is_enforced_end_to_end() {
        let fix = crate::runtime::SimArtifacts::in_temp().unwrap();
        let pool = Arc::new(crate::runtime::ModelPool::new(fix.manifest().unwrap()));
        let engine = Arc::new(
            Engine::build(&MuseConfig::from_yaml(SIM_YAML).unwrap(), pool).unwrap(),
        );
        let ready = Arc::new(AtomicBool::new(true));
        let handler = api_handler(Arc::clone(&engine), Arc::clone(&ready));
        let admission = Arc::new(AdmissionControl::new(
            vec![("vip".to_string(), 4)],
            0,
            64,
            Box::new(|| 500), // queue "looks" 500 deep
        ));
        let route: Arc<dyn http::StreamRoute> = Arc::new(ScoreBatchRoute {
            engine: Arc::clone(&engine),
            ready,
            admission,
        });
        let server = HttpServer::bind_with_config(
            "127.0.0.1:0",
            2,
            handler,
            http::IngressConfig::default(),
            http::IngressCounters::resolve(&engine.counters),
            Some(route),
        )
        .unwrap();
        let addr = server.local_addr();
        let counters = server.counters();
        std::thread::spawn(move || {
            let _ = server.serve();
        });

        let d = crate::simulator::FEATURE_DIM;
        let feats = vec!["0.1"; d].join(",");
        let body = |tenant: &str| {
            format!(r#"{{"events": [{{"tenant": "{tenant}", "features": [{feats}]}}]}}"#)
        };
        // vip rides out the pressure (64 << 4 = 1024 >= 500)...
        let (status, resp) =
            http_request(&addr, "POST", "/v1/score/batch", &body("vip")).unwrap();
        assert_eq!(status, 200, "{resp}");
        // ...bulk sheds at threshold 64 with a backoff hint.
        let raw = raw_request(&addr, "POST", "/v1/score/batch", &body("bulk"));
        assert!(raw.starts_with("HTTP/1.1 429 "), "{raw}");
        assert!(raw.contains("Retry-After: 1"), "{raw}");
        assert!(raw.contains("shedding tenant 'bulk'"), "{raw}");
        assert_eq!(counters.shed.get(), 1);
    }
}
