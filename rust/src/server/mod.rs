//! The MUSE serving API: the HTTP front end over the engine.
//!
//! Endpoints:
//! * `POST /score` — `{tenant, geography?, schema?, channel?, entity?,
//!   features: [f32...]}` -> `{score, predictor, shadows}`
//! * `GET /healthz` — readiness (set after warm-up, Section 3.1.2)
//! * `GET /metrics` — counters + latency percentiles (JSON)
//! * `GET /admin/stats` — registry/pool dedup accounting

pub mod http;

use crate::coordinator::{Engine, ScoreRequest};
use crate::config::Intent;
use crate::util::json::Json;
use anyhow::Result;
use http::{Handler, HttpServer, Request, Response};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Build the API handler for an engine. `ready` gates /healthz and
/// /score until warm-up completes (a pod readiness gate).
pub fn api_handler(engine: Arc<Engine>, ready: Arc<AtomicBool>) -> Arc<Handler> {
    Arc::new(move |req: &Request| route(&engine, &ready, req))
}

fn route(engine: &Engine, ready: &AtomicBool, req: &Request) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            if ready.load(Ordering::SeqCst) {
                Response::text(200, "ok")
            } else {
                Response::text(503, "warming up")
            }
        }
        ("POST", "/score") => {
            if !ready.load(Ordering::SeqCst) {
                return Response::json(503, r#"{"error":"warming up"}"#);
            }
            match handle_score(engine, &req.body) {
                Ok(resp) => resp,
                Err(e) => Response::json(
                    422,
                    Json::obj(vec![("error", Json::str(e.to_string()))]).to_string(),
                ),
            }
        }
        ("GET", "/metrics") => {
            let snap = engine.counters.snapshot();
            let counters: Vec<(String, Json)> = snap
                .into_iter()
                .map(|(k, v)| (k, Json::Num(v as f64)))
                .collect();
            let body = Json::obj(vec![
                (
                    "counters",
                    Json::Obj(counters.into_iter().collect()),
                ),
                (
                    "latency_ms",
                    Json::obj(vec![
                        ("p50", Json::Num(engine.live_latency.percentile_ns(50.0) as f64 / 1e6)),
                        ("p99", Json::Num(engine.live_latency.percentile_ns(99.0) as f64 / 1e6)),
                        ("p999", Json::Num(engine.live_latency.percentile_ns(99.9) as f64 / 1e6)),
                        ("count", Json::Num(engine.live_latency.count() as f64)),
                    ]),
                ),
            ])
            .to_string();
            Response::json(200, body)
        }
        ("GET", "/admin/stats") => {
            let s = engine.registry.stats();
            // One wait-free snapshot load: the same world the data
            // plane is routing on right now.
            let snap = engine.load_snapshot();
            let body = Json::obj(vec![
                ("predictors", Json::Num(s.predictors as f64)),
                ("model_references", Json::Num(s.model_references as f64)),
                ("live_containers", Json::Num(s.pool.live_containers as f64)),
                ("spawned_total", Json::Num(s.pool.spawned_total as f64)),
                ("datalake_records", Json::Num(engine.lake.len() as f64)),
                ("snapshot_predictors", Json::Num(snap.predictor_count() as f64)),
                (
                    "snapshot_scoring_rules",
                    Json::Num(snap.routing.scoring_rules.len() as f64),
                ),
            ])
            .to_string();
            Response::json(200, body)
        }
        ("POST", _) | ("GET", _) => Response::text(404, "not found"),
        _ => Response::text(405, "method not allowed"),
    }
}

fn handle_score(engine: &Engine, body: &str) -> Result<Response> {
    let v = crate::util::json::parse(body)?;
    let features = v
        .req("features")?
        .to_f32_vec()
        .ok_or_else(|| anyhow::anyhow!("features must be an array of numbers"))?;
    let get = |k: &str| v.get(k).and_then(Json::as_str).unwrap_or("").to_string();
    let req = ScoreRequest {
        intent: Intent {
            tenant: v.req_str("tenant")?.to_string(),
            geography: get("geography"),
            schema: get("schema"),
            channel: get("channel"),
        },
        entity: get("entity"),
        features,
    };
    let resp = engine.score(&req)?;
    Ok(Response::json(
        200,
        Json::obj(vec![
            ("score", Json::Num(resp.score)),
            ("predictor", Json::str(resp.predictor)),
            ("shadows", Json::Num(resp.shadow_count as f64)),
        ])
        .to_string(),
    ))
}

/// Convenience: build + bind + warm up + serve on a background thread.
/// Returns (address, ready flag, server thread handle).
pub fn spawn_server(
    engine: Arc<Engine>,
    addr: &str,
    workers: usize,
    warmup_requests: usize,
) -> Result<(String, Arc<AtomicBool>, std::thread::JoinHandle<()>)> {
    let ready = Arc::new(AtomicBool::new(false));
    let handler = api_handler(Arc::clone(&engine), Arc::clone(&ready));
    let server = HttpServer::bind(addr, workers, handler)?;
    let bound = server.local_addr();
    let handle = std::thread::spawn(move || {
        let _ = server.serve();
    });
    // Warm up before flipping readiness (paper Section 3.1.2).
    crate::coordinator::warm_up(&engine, warmup_requests, 0xC0FFEE)?;
    ready.store(true, Ordering::SeqCst);
    Ok((bound, ready, handle))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MuseConfig;
    use crate::runtime::{Manifest, ModelPool};
    use crate::server::http::http_request;
    use std::path::PathBuf;

    const CONFIG: &str = r#"
routing:
  scoringRules:
  - description: "catch-all"
    condition: {}
    targetPredictorName: "p"
predictors:
- name: p
  experts: [m1, m2]
  quantile: identity
"#;

    fn engine() -> Option<Arc<Engine>> {
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !root.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let pool = Arc::new(ModelPool::new(Manifest::load(root).unwrap()));
        Some(Arc::new(
            Engine::build(&MuseConfig::from_yaml(CONFIG).unwrap(), pool).unwrap(),
        ))
    }

    #[test]
    fn end_to_end_http_scoring() {
        let Some(engine) = engine() else { return };
        let d = engine.predictor("p").unwrap().feature_dim();
        let (addr, _ready, _h) = spawn_server(engine, "127.0.0.1:0", 2, 10).unwrap();
        let (status, body) = http_request(&addr, "GET", "/healthz", "").unwrap();
        assert_eq!((status, body.as_str()), (200, "ok"));

        let features: Vec<String> = (0..d).map(|i| format!("{}", 0.01 * i as f32)).collect();
        let payload = format!(
            r#"{{"tenant": "bank1", "features": [{}]}}"#,
            features.join(",")
        );
        let (status, body) = http_request(&addr, "POST", "/score", &payload).unwrap();
        assert_eq!(status, 200, "{body}");
        let v = crate::util::json::parse(&body).unwrap();
        let score = v.req_f64("score").unwrap();
        assert!((0.0..=1.0).contains(&score));
        assert_eq!(v.req_str("predictor").unwrap(), "p");

        let (status, body) = http_request(&addr, "GET", "/metrics", "").unwrap();
        assert_eq!(status, 200);
        assert!(body.contains("latency_ms"), "{body}");

        let (status, body) = http_request(&addr, "GET", "/admin/stats", "").unwrap();
        assert_eq!(status, 200);
        let v = crate::util::json::parse(&body).unwrap();
        assert_eq!(v.req_f64("live_containers").unwrap(), 2.0);
    }

    #[test]
    fn malformed_score_payloads_are_422() {
        let Some(engine) = engine() else { return };
        let (addr, _ready, _h) = spawn_server(engine, "127.0.0.1:0", 2, 5).unwrap();
        for bad in [
            "",                       // empty
            "{}",                     // missing fields
            r#"{"tenant": "x"}"#,     // no features
            r#"{"tenant": "x", "features": "nope"}"#,
            r#"{"tenant": "x", "features": [1,2]}"#, // wrong dim is 422 via engine? enrich pads -> ok actually
        ]
        .iter()
        .take(4)
        {
            let (status, _) = http_request(&addr, "POST", "/score", bad).unwrap();
            assert_eq!(status, 422, "payload: {bad}");
        }
    }

    #[test]
    fn unknown_route_404s() {
        let Some(engine) = engine() else { return };
        let (addr, _ready, _h) = spawn_server(engine, "127.0.0.1:0", 2, 5).unwrap();
        let (status, _) = http_request(&addr, "GET", "/nope", "").unwrap();
        assert_eq!(status, 404);
    }
}
