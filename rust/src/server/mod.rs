//! The MUSE serving API: the HTTP front end over the engine.
//!
//! Endpoints:
//! * `POST /score` — `{tenant, geography?, schema?, channel?, entity?,
//!   features: [f32...]}` -> `{score, predictor, shadows}`
//! * `POST /v1/score/batch` — `{events: [<score payload>...]}` ->
//!   `{count, results: [{score, predictor, shadows}...]}` (input
//!   order preserved; one engine snapshot load for the whole batch,
//!   capped by `server.maxBatchEvents`)
//! * `GET /healthz` — readiness (set after warm-up, Section 3.1.2)
//! * `GET /metrics` — counters, per-tenant batch `scored_events`
//!   object, and request/batch latency percentiles (JSON)
//! * `GET /admin/stats` — registry/pool dedup accounting
//! * `GET /v1/lifecycle` — autopilot status: per-pair state machine,
//!   drift scores, fit/promotion counters
//! * `POST /v1/lifecycle/check` — run one controller tick now and
//!   return the resulting status (manual trigger / cron hook)
//!
//! Request bodies over `server.maxBodyBytes` (default 1 MiB) are
//! rejected with `413 Payload Too Large` from the Content-Length
//! header alone — the body is never buffered.

pub mod http;

use crate::coordinator::{Engine, ScoreRequest};
use crate::config::Intent;
use crate::util::json::Json;
use anyhow::Result;
use http::{Handler, HttpServer, Request, Response};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Build the API handler for an engine. `ready` gates /healthz and
/// /score until warm-up completes (a pod readiness gate).
pub fn api_handler(engine: Arc<Engine>, ready: Arc<AtomicBool>) -> Arc<Handler> {
    Arc::new(move |req: &Request| route(&engine, &ready, req))
}

fn route(engine: &Engine, ready: &AtomicBool, req: &Request) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            if ready.load(Ordering::SeqCst) {
                Response::text(200, "ok")
            } else {
                Response::text(503, "warming up")
            }
        }
        ("POST", "/score") => {
            if !ready.load(Ordering::SeqCst) {
                return Response::json(503, r#"{"error":"warming up"}"#);
            }
            match handle_score(engine, &req.body) {
                Ok(resp) => resp,
                Err(e) => Response::json(
                    422,
                    Json::obj(vec![("error", Json::str(e.to_string()))]).to_string(),
                ),
            }
        }
        ("POST", "/v1/score/batch") => {
            if !ready.load(Ordering::SeqCst) {
                return Response::json(503, r#"{"error":"warming up"}"#);
            }
            match handle_score_batch(engine, &req.body) {
                Ok(resp) => resp,
                Err(e) => Response::json(
                    422,
                    Json::obj(vec![("error", Json::str(e.to_string()))]).to_string(),
                ),
            }
        }
        ("GET", "/metrics") => {
            let snap = engine.counters.snapshot();
            let counters: Vec<(String, Json)> = snap
                .into_iter()
                .map(|(k, v)| (k, Json::Num(v as f64)))
                .collect();
            // Batch-path scored events per tenant (bare tenant keys).
            let tenants: Vec<(String, Json)> = engine
                .tenant_events
                .snapshot()
                .into_iter()
                .map(|(k, v)| (k, Json::Num(v as f64)))
                .collect();
            let body = Json::obj(vec![
                (
                    "counters",
                    Json::Obj(counters.into_iter().collect()),
                ),
                (
                    "scored_events",
                    Json::Obj(tenants.into_iter().collect()),
                ),
                (
                    "latency_ms",
                    Json::obj(vec![
                        ("p50", Json::Num(engine.live_latency.percentile_ns(50.0) as f64 / 1e6)),
                        ("p99", Json::Num(engine.live_latency.percentile_ns(99.0) as f64 / 1e6)),
                        ("p999", Json::Num(engine.live_latency.percentile_ns(99.9) as f64 / 1e6)),
                        ("count", Json::Num(engine.live_latency.count() as f64)),
                    ]),
                ),
                (
                    "batch_latency_ms",
                    Json::obj(vec![
                        ("p50", Json::Num(engine.batch_latency.percentile_ns(50.0) as f64 / 1e6)),
                        ("p99", Json::Num(engine.batch_latency.percentile_ns(99.0) as f64 / 1e6)),
                        ("count", Json::Num(engine.batch_latency.count() as f64)),
                    ]),
                ),
            ])
            .to_string();
            Response::json(200, body)
        }
        ("GET", "/v1/lifecycle") => Response::json(200, lifecycle_status_json(engine, false)),
        ("POST", "/v1/lifecycle/check") => match &engine.lifecycle {
            None => Response::json(422, r#"{"error":"lifecycle is not enabled"}"#),
            Some(hub) => match hub.tick(engine) {
                Ok(_) => Response::json(200, lifecycle_status_json(engine, true)),
                Err(e) => Response::json(
                    500,
                    Json::obj(vec![("error", Json::str(e.to_string()))]).to_string(),
                ),
            },
        },
        ("GET", "/admin/stats") => {
            let s = engine.registry.stats();
            // One wait-free snapshot load: the same world the data
            // plane is routing on right now.
            let snap = engine.load_snapshot();
            let body = Json::obj(vec![
                ("predictors", Json::Num(s.predictors as f64)),
                ("model_references", Json::Num(s.model_references as f64)),
                ("live_containers", Json::Num(s.pool.live_containers as f64)),
                ("spawned_total", Json::Num(s.pool.spawned_total as f64)),
                ("datalake_records", Json::Num(engine.lake.len() as f64)),
                ("snapshot_predictors", Json::Num(snap.predictor_count() as f64)),
                (
                    "snapshot_scoring_rules",
                    Json::Num(snap.routing.scoring_rules.len() as f64),
                ),
            ])
            .to_string();
            Response::json(200, body)
        }
        ("POST", _) | ("GET", _) => Response::text(404, "not found"),
        _ => Response::text(405, "method not allowed"),
    }
}

/// `GET /v1/lifecycle` body: autopilot enablement + per-pair status.
fn lifecycle_status_json(engine: &Engine, ticked: bool) -> String {
    let Some(hub) = &engine.lifecycle else {
        return Json::obj(vec![
            ("enabled", Json::Bool(false)),
            ("pairs", Json::Arr(vec![])),
        ])
        .to_string();
    };
    let pairs: Vec<Json> = hub
        .status()
        .iter()
        .map(|p| {
            Json::obj(vec![
                ("tenant", Json::str(p.tenant.clone())),
                ("predictor", Json::str(p.predictor.clone())),
                ("state", Json::str(p.state.as_str())),
                ("psi", Json::Num(p.psi)),
                ("ks", Json::Num(p.ks)),
                ("fitSamples", Json::Num(p.fit_samples as f64)),
                ("windowSamples", Json::Num(p.window_samples as f64)),
                ("baselineFrozen", Json::Bool(p.baseline_frozen)),
                ("fits", Json::Num(p.fits as f64)),
                ("promotions", Json::Num(p.promotions as f64)),
                ("validationFailures", Json::Num(p.validation_failures as f64)),
                ("droppedSamples", Json::Num(p.dropped_samples as f64)),
                (
                    "shadow",
                    match &p.shadow {
                        Some(s) => Json::str(s.clone()),
                        None => Json::Null,
                    },
                ),
                (
                    "lastError",
                    match &p.last_error {
                        Some(e) => Json::str(e.clone()),
                        None => Json::Null,
                    },
                ),
            ])
        })
        .collect();
    Json::obj(vec![
        ("enabled", Json::Bool(true)),
        ("ticked", Json::Bool(ticked)),
        ("pairs", Json::Arr(pairs)),
    ])
    .to_string()
}

/// Parse one score payload object into a [`ScoreRequest`] (shared by
/// the single and the batch endpoint, so both accept the same shape).
fn parse_score_request(v: &Json) -> Result<ScoreRequest> {
    let features = v
        .req("features")?
        .to_f32_vec()
        .ok_or_else(|| anyhow::anyhow!("features must be an array of numbers"))?;
    let get = |k: &str| v.get(k).and_then(Json::as_str).unwrap_or("").to_string();
    Ok(ScoreRequest {
        intent: Intent {
            tenant: v.req_str("tenant")?.to_string(),
            geography: get("geography"),
            schema: get("schema"),
            channel: get("channel"),
        },
        entity: get("entity"),
        features,
    })
}

fn score_response_json(resp: &crate::coordinator::ScoreResponse) -> Json {
    Json::obj(vec![
        ("score", Json::Num(resp.score)),
        ("predictor", Json::str(resp.predictor.as_ref())),
        ("shadows", Json::Num(resp.shadow_count as f64)),
    ])
}

fn handle_score(engine: &Engine, body: &str) -> Result<Response> {
    let v = crate::util::json::parse(body)?;
    let req = parse_score_request(&v)?;
    let resp = engine.score(&req)?;
    Ok(Response::json(200, score_response_json(&resp).to_string()))
}

/// `POST /v1/score/batch`: the whole batch is scored off one engine
/// snapshot load (`Engine::score_batch`); results preserve input
/// order. Oversized batches (> `server.maxBatchEvents`) are rejected
/// by the engine's admission cap and surface as 422.
fn handle_score_batch(engine: &Engine, body: &str) -> Result<Response> {
    let v = crate::util::json::parse(body)?;
    let events = v
        .req("events")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("events must be a list of score payloads"))?;
    let reqs = events
        .iter()
        .map(parse_score_request)
        .collect::<Result<Vec<_>>>()?;
    let resps = engine.score_batch(&reqs)?;
    let results: Vec<Json> = resps.iter().map(score_response_json).collect();
    Ok(Response::json(
        200,
        Json::obj(vec![
            ("count", Json::Num(results.len() as f64)),
            ("results", Json::Arr(results)),
        ])
        .to_string(),
    ))
}

/// Convenience: build + bind + warm up + serve on a background thread.
/// Returns (address, ready flag, server thread handle).
pub fn spawn_server(
    engine: Arc<Engine>,
    addr: &str,
    workers: usize,
    warmup_requests: usize,
) -> Result<(String, Arc<AtomicBool>, std::thread::JoinHandle<()>)> {
    let ready = Arc::new(AtomicBool::new(false));
    let handler = api_handler(Arc::clone(&engine), Arc::clone(&ready));
    // Body cap from the engine's config (`server.maxBodyBytes`):
    // oversized requests bounce with 413 before their bodies are read.
    let server = HttpServer::bind_with_limits(addr, workers, handler, engine.max_body_bytes)?;
    let bound = server.local_addr();
    let handle = std::thread::spawn(move || {
        let _ = server.serve();
    });
    // Warm up before flipping readiness (paper Section 3.1.2).
    crate::coordinator::warm_up(&engine, warmup_requests, 0xC0FFEE)?;
    ready.store(true, Ordering::SeqCst);
    Ok((bound, ready, handle))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MuseConfig;
    use crate::runtime::{Manifest, ModelPool};
    use crate::server::http::http_request;
    use std::path::PathBuf;

    const CONFIG: &str = r#"
routing:
  scoringRules:
  - description: "catch-all"
    condition: {}
    targetPredictorName: "p"
predictors:
- name: p
  experts: [m1, m2]
  quantile: identity
"#;

    fn engine() -> Option<Arc<Engine>> {
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !root.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let pool = Arc::new(ModelPool::new(Manifest::load(root).unwrap()));
        Some(Arc::new(
            Engine::build(&MuseConfig::from_yaml(CONFIG).unwrap(), pool).unwrap(),
        ))
    }

    #[test]
    fn end_to_end_http_scoring() {
        let Some(engine) = engine() else { return };
        let d = engine.predictor("p").unwrap().feature_dim();
        let (addr, _ready, _h) = spawn_server(engine, "127.0.0.1:0", 2, 10).unwrap();
        let (status, body) = http_request(&addr, "GET", "/healthz", "").unwrap();
        assert_eq!((status, body.as_str()), (200, "ok"));

        let features: Vec<String> = (0..d).map(|i| format!("{}", 0.01 * i as f32)).collect();
        let payload = format!(
            r#"{{"tenant": "bank1", "features": [{}]}}"#,
            features.join(",")
        );
        let (status, body) = http_request(&addr, "POST", "/score", &payload).unwrap();
        assert_eq!(status, 200, "{body}");
        let v = crate::util::json::parse(&body).unwrap();
        let score = v.req_f64("score").unwrap();
        assert!((0.0..=1.0).contains(&score));
        assert_eq!(v.req_str("predictor").unwrap(), "p");

        let (status, body) = http_request(&addr, "GET", "/metrics", "").unwrap();
        assert_eq!(status, 200);
        assert!(body.contains("latency_ms"), "{body}");

        let (status, body) = http_request(&addr, "GET", "/admin/stats", "").unwrap();
        assert_eq!(status, 200);
        let v = crate::util::json::parse(&body).unwrap();
        assert_eq!(v.req_f64("live_containers").unwrap(), 2.0);
    }

    #[test]
    fn batch_endpoint_agrees_with_sequential_scores() {
        let Some(engine) = engine() else { return };
        let d = engine.predictor("p").unwrap().feature_dim();
        let (addr, _ready, _h) = spawn_server(engine, "127.0.0.1:0", 2, 10).unwrap();
        let mut rng = crate::util::rng::Rng::new(7);
        let payloads: Vec<String> = (0..6)
            .map(|i| {
                let feats: Vec<String> =
                    (0..d).map(|_| format!("{:.6}", rng.normal())).collect();
                format!(
                    r#"{{"tenant": "bank{}", "features": [{}]}}"#,
                    i % 2,
                    feats.join(",")
                )
            })
            .collect();
        // N sequential /score calls...
        let mut sequential = Vec::new();
        for p in &payloads {
            let (status, body) = http_request(&addr, "POST", "/score", p).unwrap();
            assert_eq!(status, 200, "{body}");
            let v = crate::util::json::parse(&body).unwrap();
            sequential.push(v.req_f64("score").unwrap());
        }
        // ...must agree with one batch call, in order.
        let batch_payload = format!(r#"{{"events": [{}]}}"#, payloads.join(","));
        let (status, body) =
            http_request(&addr, "POST", "/v1/score/batch", &batch_payload).unwrap();
        assert_eq!(status, 200, "{body}");
        let v = crate::util::json::parse(&body).unwrap();
        assert_eq!(v.req_f64("count").unwrap(), 6.0);
        let results = v.req("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 6);
        for (r, want) in results.iter().zip(&sequential) {
            let got = r.req_f64("score").unwrap();
            // Cross-batch-variant PJRT tolerance (see engine tests).
            assert!((got - want).abs() < 2e-5, "batch {got} vs sequential {want}");
            assert_eq!(r.req_str("predictor").unwrap(), "p");
        }
    }

    #[test]
    fn batch_endpoint_rejects_malformed_and_oversized() {
        let Some(engine) = engine() else { return };
        let cap = engine.max_batch_events;
        let d = engine.predictor("p").unwrap().feature_dim();
        let (addr, _ready, _h) = spawn_server(engine, "127.0.0.1:0", 2, 5).unwrap();
        for bad in [
            "",
            "{}",
            r#"{"events": "nope"}"#,
            r#"{"events": [{"tenant": "x"}]}"#, // event missing features
        ] {
            let (status, _) = http_request(&addr, "POST", "/v1/score/batch", bad).unwrap();
            assert_eq!(status, 422, "payload: {bad}");
        }
        // One event over the admission cap -> 422 with the cap named.
        let ev = format!(
            r#"{{"tenant": "t", "features": [{}]}}"#,
            vec!["0.0"; d].join(",")
        );
        let evs = vec![ev; cap + 1];
        let (status, body) = http_request(
            &addr,
            "POST",
            "/v1/score/batch",
            &format!(r#"{{"events": [{}]}}"#, evs.join(",")),
        )
        .unwrap();
        assert_eq!(status, 422, "{body}");
        assert!(body.contains("maxBatchEvents"), "{body}");
    }

    #[test]
    fn malformed_score_payloads_are_422() {
        let Some(engine) = engine() else { return };
        let (addr, _ready, _h) = spawn_server(engine, "127.0.0.1:0", 2, 5).unwrap();
        for bad in [
            "",                       // empty
            "{}",                     // missing fields
            r#"{"tenant": "x"}"#,     // no features
            r#"{"tenant": "x", "features": "nope"}"#,
            r#"{"tenant": "x", "features": [1,2]}"#, // wrong dim is 422 via engine? enrich pads -> ok actually
        ]
        .iter()
        .take(4)
        {
            let (status, _) = http_request(&addr, "POST", "/score", bad).unwrap();
            assert_eq!(status, 422, "payload: {bad}");
        }
    }

    #[test]
    fn lifecycle_endpoints_report_and_tick() {
        // Sim-dialect artifacts: runs without `make artifacts`.
        let (_fix, engine) = crate::simulator::drift_storm::tests::sim_engine("");
        let d = crate::simulator::FEATURE_DIM;
        let (addr, _ready, _h) = spawn_server(engine, "127.0.0.1:0", 2, 5).unwrap();

        // Status before any tick: enabled, no pairs yet.
        let (status, body) = http_request(&addr, "GET", "/v1/lifecycle", "").unwrap();
        assert_eq!(status, 200, "{body}");
        let v = crate::util::json::parse(&body).unwrap();
        assert_eq!(v.get("enabled").and_then(crate::util::json::Json::as_bool), Some(true));

        // Score some traffic for the managed tenant, then trigger a
        // manual check: the pair must appear, observing.
        let features = vec!["0.1"; d].join(",");
        let payload = format!(r#"{{"tenant": "acme", "features": [{features}]}}"#);
        for _ in 0..3 {
            let (s, b) = http_request(&addr, "POST", "/score", &payload).unwrap();
            assert_eq!(s, 200, "{b}");
        }
        let (status, body) = http_request(&addr, "POST", "/v1/lifecycle/check", "").unwrap();
        assert_eq!(status, 200, "{body}");
        let v = crate::util::json::parse(&body).unwrap();
        assert_eq!(v.get("ticked").and_then(crate::util::json::Json::as_bool), Some(true));
        let pairs = v.req("pairs").unwrap().as_arr().unwrap();
        assert_eq!(pairs.len(), 1, "{body}");
        assert_eq!(pairs[0].req_str("tenant").unwrap(), "acme");
        assert_eq!(pairs[0].req_str("predictor").unwrap(), "duo");
        assert_eq!(pairs[0].req_str("state").unwrap(), "observing");
        // The tick also shows up in /metrics counters.
        let (_, metrics) = http_request(&addr, "GET", "/metrics", "").unwrap();
        assert!(metrics.contains("lifecycle_ticks"), "{metrics}");
    }

    #[test]
    fn lifecycle_endpoints_when_disabled() {
        let fix = crate::runtime::SimArtifacts::in_temp().unwrap();
        let pool = Arc::new(crate::runtime::ModelPool::new(fix.manifest().unwrap()));
        let yaml = r#"
routing:
  scoringRules:
  - description: "catch-all"
    condition: {}
    targetPredictorName: "p"
predictors:
- name: p
  experts: [s3]
  quantile: identity
"#;
        let engine = Arc::new(
            Engine::build(&MuseConfig::from_yaml(yaml).unwrap(), pool).unwrap(),
        );
        let (addr, _ready, _h) = spawn_server(engine, "127.0.0.1:0", 2, 5).unwrap();
        let (status, body) = http_request(&addr, "GET", "/v1/lifecycle", "").unwrap();
        assert_eq!(status, 200);
        let v = crate::util::json::parse(&body).unwrap();
        assert_eq!(v.get("enabled").and_then(crate::util::json::Json::as_bool), Some(false));
        let (status, _) = http_request(&addr, "POST", "/v1/lifecycle/check", "").unwrap();
        assert_eq!(status, 422);
    }

    #[test]
    fn configured_body_cap_is_enforced_end_to_end() {
        // Sim-dialect artifacts: runs without `make artifacts`.
        let fix = crate::runtime::SimArtifacts::in_temp().unwrap();
        let pool = Arc::new(crate::runtime::ModelPool::new(fix.manifest().unwrap()));
        let yaml = r#"
routing:
  scoringRules:
  - description: "catch-all"
    condition: {}
    targetPredictorName: "p"
predictors:
- name: p
  experts: [s3]
  quantile: identity
server:
  maxBodyBytes: 2048
"#;
        let engine = Arc::new(
            Engine::build(&MuseConfig::from_yaml(yaml).unwrap(), pool).unwrap(),
        );
        assert_eq!(engine.max_body_bytes, 2048);
        let (addr, _ready, _h) = spawn_server(Arc::clone(&engine), "127.0.0.1:0", 2, 5).unwrap();
        // A payload over the configured cap bounces with 413...
        let big = format!(r#"{{"tenant": "t", "pad": "{}"}}"#, "x".repeat(4096));
        let (status, body) = http_request(&addr, "POST", "/score", &big).unwrap();
        assert_eq!(status, 413, "{body}");
        // ...while a normal request on a fresh connection still works.
        let d = crate::simulator::FEATURE_DIM;
        let payload = format!(
            r#"{{"tenant": "t", "features": [{}]}}"#,
            vec!["0.1"; d].join(",")
        );
        let (status, body) = http_request(&addr, "POST", "/score", &payload).unwrap();
        assert_eq!(status, 200, "{body}");
    }

    #[test]
    fn unknown_route_404s() {
        let Some(engine) = engine() else { return };
        let (addr, _ready, _h) = spawn_server(engine, "127.0.0.1:0", 2, 5).unwrap();
        let (status, _) = http_request(&addr, "GET", "/nope", "").unwrap();
        assert_eq!(status, 404);
    }
}
