//! Minimal HTTP/1.1 server (from scratch; no hyper/tokio offline).
//!
//! Enough protocol for the serving front end: request-line + headers +
//! Content-Length bodies, keep-alive, JSON in/out. Connections are
//! dispatched to the worker thread pool; the scoring handler calls
//! straight into the engine (Python nowhere in sight), which serves
//! each request off one wait-free `EngineSnapshot` load — workers
//! never block on routing or batcher state (they share only the
//! snapshot cell's reader counter, a few uncontended-in-practice
//! atomic ops), so adding workers scales until PJRT saturates
//! (EXPERIMENTS.md "Contention").

use crate::util::threadpool::ThreadPool;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// A parsed HTTP request.
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub body: String,
}

/// A response under construction.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: String,
}

impl Response {
    pub fn json(status: u16, body: impl Into<String>) -> Response {
        Response {
            status,
            content_type: "application/json",
            body: body.into(),
        }
    }

    pub fn text(status: u16, body: impl Into<String>) -> Response {
        Response {
            status,
            content_type: "text/plain",
            body: body.into(),
        }
    }

    fn status_line(&self) -> &'static str {
        match self.status {
            200 => "200 OK",
            202 => "202 Accepted",
            400 => "400 Bad Request",
            404 => "404 Not Found",
            405 => "405 Method Not Allowed",
            413 => "413 Payload Too Large",
            422 => "422 Unprocessable Entity",
            503 => "503 Service Unavailable",
            _ => "500 Internal Server Error",
        }
    }
}

/// Default request-body cap when a server is bound without an
/// explicit limit (`server.maxBodyBytes` default: 1 MiB).
pub const DEFAULT_MAX_BODY_BYTES: usize = 1 << 20;

pub type Handler = dyn Fn(&Request) -> Response + Send + Sync + 'static;

/// The HTTP server: bind, accept, dispatch to the pool.
pub struct HttpServer {
    listener: TcpListener,
    pool: Arc<ThreadPool>,
    handler: Arc<Handler>,
    stop: Arc<AtomicBool>,
    /// Request-body cap (`server.maxBodyBytes`): requests declaring a
    /// larger Content-Length are refused with 413 before the body is
    /// read, so one client cannot balloon worker memory.
    max_body: usize,
}

impl HttpServer {
    pub fn bind(
        addr: &str,
        workers: usize,
        handler: Arc<Handler>,
    ) -> Result<HttpServer> {
        Self::bind_with_limits(addr, workers, handler, DEFAULT_MAX_BODY_BYTES)
    }

    /// As [`HttpServer::bind`], with an explicit request-body cap.
    pub fn bind_with_limits(
        addr: &str,
        workers: usize,
        handler: Arc<Handler>,
        max_body: usize,
    ) -> Result<HttpServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        Ok(HttpServer {
            listener,
            pool: Arc::new(ThreadPool::new(workers)),
            handler,
            stop: Arc::new(AtomicBool::new(false)),
            max_body: max_body.max(1),
        })
    }

    pub fn local_addr(&self) -> String {
        self.listener
            .local_addr()
            .map(|a| a.to_string())
            .unwrap_or_default()
    }

    /// A flag the accept loop checks; set true then poke the socket to
    /// stop `serve`.
    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }

    /// Accept loop (blocks the calling thread). Each connection is
    /// handled on the pool with keep-alive.
    pub fn serve(&self) -> Result<()> {
        for stream in self.listener.incoming() {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = stream else { continue };
            let handler = Arc::clone(&self.handler);
            let max_body = self.max_body;
            self.pool.execute(move || {
                let _ = handle_connection(stream, handler, max_body);
            });
        }
        Ok(())
    }
}

fn handle_connection(stream: TcpStream, handler: Arc<Handler>, max_body: usize) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    loop {
        let req = match read_request_limited(&mut reader, max_body) {
            Ok(ReadOutcome::Request(r)) => r,
            Ok(ReadOutcome::Closed) => return Ok(()), // clean close
            Ok(ReadOutcome::TooLarge) => {
                // Rejected from the Content-Length header alone — the
                // body was never read, so the connection is desynced:
                // answer 413 and close.
                let resp = Response::json(413, r#"{"error":"request body too large"}"#);
                let _ = write_response(&mut writer, &resp, false);
                return Ok(());
            }
            Err(_) => {
                let resp = Response::text(400, "bad request");
                let _ = write_response(&mut writer, &resp, false);
                return Ok(());
            }
        };
        // A panicking handler must not silently drop a keep-alive
        // connection (the client would see an unexplained EOF) or kill
        // the pool worker: catch the unwind, answer with a 500 JSON
        // body, and close this connection — handler state after a
        // panic is unknown, so keep-alive ends here.
        let resp = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| handler(&req)));
        match resp {
            Ok(resp) => write_response(&mut writer, &resp, true)?,
            Err(_) => {
                let resp = Response::json(500, r#"{"error":"internal server error"}"#);
                let _ = write_response(&mut writer, &resp, false);
                return Ok(());
            }
        }
    }
}

/// Outcome of reading one request off a keep-alive connection.
enum ReadOutcome {
    Request(Request),
    /// Clean EOF before a request line.
    Closed,
    /// Declared Content-Length exceeds the cap; the body was never
    /// buffered (the 413 is decided from the header alone).
    TooLarge,
}

/// Read one request; Ok(None) on EOF before a request line. Bodies
/// over [`DEFAULT_MAX_BODY_BYTES`] error; servers configure the cap
/// via [`HttpServer::bind_with_limits`].
pub fn read_request<R: BufRead>(reader: &mut R) -> Result<Option<Request>> {
    match read_request_limited(reader, DEFAULT_MAX_BODY_BYTES)? {
        ReadOutcome::Request(r) => Ok(Some(r)),
        ReadOutcome::Closed => Ok(None),
        ReadOutcome::TooLarge => bail!("body too large"),
    }
}

fn read_request_limited<R: BufRead>(reader: &mut R, max_body: usize) -> Result<ReadOutcome> {
    let mut line = String::new();
    if reader.read_line(&mut line)? == 0 {
        return Ok(ReadOutcome::Closed);
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().context("missing method")?.to_string();
    let path = parts.next().context("missing path")?.to_string();
    let version = parts.next().context("missing version")?;
    if !version.starts_with("HTTP/1.") {
        bail!("unsupported version {version}");
    }
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        if reader.read_line(&mut h)? == 0 {
            bail!("eof in headers");
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((name, value)) = h.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value.trim().parse().context("bad content-length")?;
            }
        }
    }
    if content_length > max_body {
        return Ok(ReadOutcome::TooLarge);
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok(ReadOutcome::Request(Request {
        method,
        path,
        body: String::from_utf8(body).context("body not UTF-8")?,
    }))
}

pub fn write_response<W: Write>(w: &mut W, resp: &Response, keep_alive: bool) -> Result<()> {
    let conn = if keep_alive { "keep-alive" } else { "close" };
    write!(
        w,
        "HTTP/1.1 {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n{}",
        resp.status_line(),
        resp.content_type,
        resp.body.len(),
        conn,
        resp.body
    )?;
    w.flush()?;
    Ok(())
}

/// A tiny blocking client for tests and the warm-up driver.
pub fn http_request(addr: &str, method: &str, path: &str, body: &str) -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true).ok();
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    stream.flush()?;
    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .context("bad status line")?
        .parse()?;
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        if reader.read_line(&mut h)? == 0 {
            break;
        }
        let h2 = h.trim_end();
        if h2.is_empty() {
            break;
        }
        if let Some((name, value)) = h2.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value.trim().parse()?;
            }
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok((status, String::from_utf8(body)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn echo_handler() -> Arc<Handler> {
        Arc::new(|req: &Request| match req.path.as_str() {
            "/healthz" => Response::text(200, "ok"),
            "/echo" => Response::json(200, req.body.clone()),
            "/panic" => panic!("handler exploded"),
            _ => Response::text(404, "not found"),
        })
    }

    fn spawn_echo() -> String {
        let server = HttpServer::bind("127.0.0.1:0", 2, echo_handler()).unwrap();
        let addr = server.local_addr();
        thread::spawn(move || server.serve().unwrap());
        addr
    }

    fn spawn_echo_capped(max_body: usize) -> String {
        let server =
            HttpServer::bind_with_limits("127.0.0.1:0", 2, echo_handler(), max_body).unwrap();
        let addr = server.local_addr();
        thread::spawn(move || server.serve().unwrap());
        addr
    }

    #[test]
    fn health_endpoint() {
        let addr = spawn_echo();
        let (status, body) = http_request(&addr, "GET", "/healthz", "").unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, "ok");
    }

    #[test]
    fn echo_roundtrip_with_body() {
        let addr = spawn_echo();
        let payload = r#"{"x": [1, 2, 3], "s": "héllo"}"#;
        let (status, body) = http_request(&addr, "POST", "/echo", payload).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, payload);
    }

    #[test]
    fn not_found() {
        let addr = spawn_echo();
        let (status, _) = http_request(&addr, "GET", "/nope", "").unwrap();
        assert_eq!(status, 404);
    }

    #[test]
    fn many_concurrent_clients() {
        let addr = spawn_echo();
        let handles: Vec<_> = (0..16)
            .map(|i| {
                let addr = addr.clone();
                thread::spawn(move || {
                    let body = format!("{{\"i\": {i}}}");
                    let (s, b) = http_request(&addr, "POST", "/echo", &body).unwrap();
                    assert_eq!(s, 200);
                    assert_eq!(b, body);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn oversized_body_is_rejected_with_413_before_reading_it() {
        let addr = spawn_echo_capped(256);
        // Declare a body far over the cap but never send it: the 413
        // must come from the Content-Length header alone, proving the
        // server did not try to buffer the payload.
        let mut stream = TcpStream::connect(&addr).unwrap();
        write!(
            stream,
            "POST /echo HTTP/1.1\r\nHost: x\r\nContent-Length: 1000000\r\n\r\n"
        )
        .unwrap();
        stream.flush().unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut status = String::new();
        reader.read_line(&mut status).unwrap();
        assert!(status.contains("413"), "{status}");
        let mut rest = String::new();
        let mut tmp = String::new();
        while reader.read_line(&mut tmp).unwrap() > 0 {
            rest.push_str(&tmp);
            tmp.clear();
        }
        assert!(rest.contains("request body too large"), "{rest}");
        assert!(
            rest.to_ascii_lowercase().contains("connection: close"),
            "oversized request must close the (desynced) connection: {rest}"
        );
        // A body exactly at the cap still round-trips.
        let payload = "x".repeat(256);
        let (status, body) = http_request(&addr, "POST", "/echo", &payload).unwrap();
        assert_eq!((status, body.as_str()), (200, payload.as_str()));
        // One byte over: rejected.
        let payload = "x".repeat(257);
        let (status, _) = http_request(&addr, "POST", "/echo", &payload).unwrap();
        assert_eq!(status, 413);
    }

    #[test]
    fn malformed_request_gets_400() {
        let addr = spawn_echo();
        let mut stream = TcpStream::connect(&addr).unwrap();
        stream.write_all(b"NONSENSE\r\n\r\n").unwrap();
        let mut buf = String::new();
        BufReader::new(stream).read_line(&mut buf).unwrap();
        assert!(buf.contains("400"), "{buf}");
    }

    #[test]
    fn panicking_handler_returns_500_and_keeps_server_alive() {
        let addr = spawn_echo();
        // Mid-keep-alive: a healthy request, then the panicking one on
        // the same connection.
        let mut stream = TcpStream::connect(&addr).unwrap();
        write!(
            stream,
            "GET /healthz HTTP/1.1\r\nHost: x\r\nContent-Length: 0\r\n\r\n"
        )
        .unwrap();
        stream.flush().unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut status = String::new();
        reader.read_line(&mut status).unwrap();
        assert!(status.contains("200"), "{status}");
        loop {
            let mut h = String::new();
            reader.read_line(&mut h).unwrap();
            if h.trim_end().is_empty() {
                break;
            }
        }
        let mut body = [0u8; 2];
        reader.read_exact(&mut body).unwrap();
        write!(
            stream,
            "GET /panic HTTP/1.1\r\nHost: x\r\nContent-Length: 0\r\n\r\n"
        )
        .unwrap();
        stream.flush().unwrap();
        let mut status = String::new();
        reader.read_line(&mut status).unwrap();
        assert!(status.contains("500"), "got: {status}");
        let mut rest = String::new();
        let mut tmp = String::new();
        while reader.read_line(&mut tmp).unwrap() > 0 {
            rest.push_str(&tmp);
            tmp.clear();
        }
        assert!(rest.contains("internal server error"), "{rest}");
        assert!(
            rest.to_ascii_lowercase().contains("connection: close"),
            "panicked connection must not stay keep-alive: {rest}"
        );
        // The pool worker survived: fresh connections still served.
        let (status, body) = http_request(&addr, "GET", "/healthz", "").unwrap();
        assert_eq!((status, body.as_str()), (200, "ok"));
    }

    #[test]
    fn keep_alive_serves_multiple_requests() {
        let addr = spawn_echo();
        let mut stream = TcpStream::connect(&addr).unwrap();
        for _ in 0..3 {
            write!(
                stream,
                "GET /healthz HTTP/1.1\r\nHost: x\r\nContent-Length: 0\r\n\r\n"
            )
            .unwrap();
            stream.flush().unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut status = String::new();
            reader.read_line(&mut status).unwrap();
            assert!(status.contains("200"));
            // Drain headers + body ("ok").
            loop {
                let mut h = String::new();
                reader.read_line(&mut h).unwrap();
                if h.trim_end().is_empty() {
                    break;
                }
            }
            let mut body = [0u8; 2];
            reader.read_exact(&mut body).unwrap();
            assert_eq!(&body, b"ok");
        }
    }
}
