//! Event-driven HTTP/1.1 ingress plane (from scratch; no hyper/tokio
//! offline — see docs/ARCHITECTURE.md "Ingress plane").
//!
//! One reactor thread drives a [`reactor::Poller`] (raw epoll) over
//! the listener plus every connection; per-connection state machines
//! parse request heads and bodies incrementally from nonblocking
//! sockets, and only complete requests are dispatched to the bounded
//! worker pool — a slow or malicious client can no longer pin a
//! worker (the seed's thread-per-connection loop parked one worker on
//! every open socket). Protection raised here, before any JSON or
//! engine work:
//!
//! * **413** from the Content-Length header alone (body never read);
//! * **431** when the header section exceeds `maxHeaderBytes`;
//! * **408** when a started request head/body misses its read
//!   deadline (slowloris) — idle keep-alive connections are exempt;
//! * **400** for malformed request lines, non-UTF-8 buffered bodies
//!   and conflicting duplicate Content-Length headers;
//! * accept-time shedding when `maxConnections` is reached;
//! * pipelined bytes beyond a cap pause reading (level-triggered
//!   interest drop) until the in-flight response drains.
//!
//! `POST /v1/score/batch` can additionally stream: when a
//! [`StreamRoute`] is installed, its [`BatchSink`] receives events
//! from the incremental `streamjson` parser as body slices arrive —
//! the request is never materialized — and scoring runs on a pool
//! worker at body end. Everything else (and the streaming fallback)
//! uses the buffered path, byte-compatible with the seed server.
//!
//! Every rejection increments an [`IngressCounters`] counter; when
//! the server is built by `spawn_server` these resolve into the
//! engine's counter registry and surface in `GET /metrics`.

use super::reactor::{PollEvent, Poller, EV_ERR, EV_HUP, EV_RDHUP, EV_READ, EV_WRITE};
use super::streamjson::{BatchBodyParser, BatchShape, StreamItem};
use crate::metrics::counters::{CounterHandle, Counters};
use crate::util::json::Json;
use crate::util::threadpool::ThreadPool;
use anyhow::{bail, Context, Result};
use std::collections::VecDeque;
use std::io::{BufRead, BufReader, ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::fd::AsRawFd;
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// A parsed HTTP request.
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub body: String,
}

/// A response under construction.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: String,
    /// Emitted as a `Retry-After` header (seconds) when set — the
    /// admission controller's shed hint on 429s.
    pub retry_after: Option<u64>,
}

impl Response {
    pub fn json(status: u16, body: impl Into<String>) -> Response {
        Response {
            status,
            content_type: "application/json",
            body: body.into(),
            retry_after: None,
        }
    }

    pub fn text(status: u16, body: impl Into<String>) -> Response {
        Response {
            status,
            content_type: "text/plain",
            body: body.into(),
            retry_after: None,
        }
    }

    /// Attach a `Retry-After: secs` header.
    pub fn with_retry_after(mut self, secs: u64) -> Response {
        self.retry_after = Some(secs);
        self
    }

    fn status_line(&self) -> &'static str {
        match self.status {
            200 => "200 OK",
            202 => "202 Accepted",
            400 => "400 Bad Request",
            404 => "404 Not Found",
            405 => "405 Method Not Allowed",
            408 => "408 Request Timeout",
            413 => "413 Payload Too Large",
            422 => "422 Unprocessable Entity",
            429 => "429 Too Many Requests",
            431 => "431 Request Header Fields Too Large",
            503 => "503 Service Unavailable",
            _ => "500 Internal Server Error",
        }
    }
}

/// Default request-body cap when a server is bound without an
/// explicit limit (`server.maxBodyBytes` default: 1 MiB).
pub const DEFAULT_MAX_BODY_BYTES: usize = 1 << 20;

/// Pipelined-input cap: while a request is in flight, at most this
/// many unparsed bytes are buffered before the connection's read
/// interest is dropped (connection-level backpressure).
const PIPELINE_CAP: usize = 64 * 1024;

pub type Handler = dyn Fn(&Request) -> Response + Send + Sync + 'static;

/// Ingress limits and deadlines (`server:` config block).
#[derive(Debug, Clone)]
pub struct IngressConfig {
    /// `server.maxBodyBytes`: 413 from the Content-Length alone.
    pub max_body: usize,
    /// `server.maxHeaderBytes`: 431 when the head section exceeds it.
    pub max_header: usize,
    /// `server.maxConnections`: accept-time shed above this.
    pub max_connections: usize,
    /// `server.headerReadTimeoutMs`: first request byte -> head end.
    pub header_deadline: Duration,
    /// `server.bodyReadTimeoutMs`: head end -> body end.
    pub body_deadline: Duration,
}

impl Default for IngressConfig {
    fn default() -> Self {
        IngressConfig {
            max_body: DEFAULT_MAX_BODY_BYTES,
            max_header: 16 * 1024,
            max_connections: 8192,
            header_deadline: Duration::from_secs(5),
            body_deadline: Duration::from_secs(15),
        }
    }
}

/// Wait-free ingress accounting (pre-resolved [`CounterHandle`]s, the
/// `HotCounters` pattern). Resolved against the engine's registry by
/// `spawn_server`, so every counter shows up in `GET /metrics`.
pub struct IngressCounters {
    pub accepted: CounterHandle,
    pub closed: CounterHandle,
    pub requests: CounterHandle,
    pub bad_requests: CounterHandle,
    pub too_large: CounterHandle,
    pub header_overflow: CounterHandle,
    pub timeouts: CounterHandle,
    pub shed: CounterHandle,
    pub truncated: CounterHandle,
    pub panics: CounterHandle,
    pub over_capacity: CounterHandle,
    pub streamed_events: CounterHandle,
}

impl IngressCounters {
    pub fn resolve(c: &Counters) -> IngressCounters {
        IngressCounters {
            accepted: c.handle("ingress_accepted"),
            closed: c.handle("ingress_closed"),
            requests: c.handle("ingress_requests"),
            bad_requests: c.handle("ingress_bad_requests"),
            too_large: c.handle("ingress_too_large"),
            header_overflow: c.handle("ingress_header_overflow"),
            timeouts: c.handle("ingress_timeouts"),
            shed: c.handle("ingress_shed"),
            truncated: c.handle("ingress_truncated"),
            panics: c.handle("ingress_panics"),
            over_capacity: c.handle("ingress_over_capacity"),
            streamed_events: c.handle("ingress_streamed_events"),
        }
    }
}

/// Per-request sink for the streaming batch route. Events arrive on
/// the **reactor** thread as they parse; [`BatchSink::finish`] runs
/// on a pool worker (that's where scoring happens).
pub trait BatchSink: Send {
    /// One parsed event. Return `Some(response)` to abort the stream
    /// early (admission shed): the rest of the body is discarded and
    /// the response sent once it drains.
    fn event(&mut self, value: Json) -> Option<Response>;
    /// A later top-level `"events"` key superseded this collection.
    fn restart(&mut self);
    /// Body complete and syntactically valid: produce the response.
    fn finish(self: Box<Self>, shape: BatchShape) -> Response;
}

/// Installed by the API layer to claim requests for streaming; return
/// `None` to fall back to the buffered handler path.
pub trait StreamRoute: Send + Sync {
    fn begin(&self, method: &str, path: &str) -> Option<Box<dyn BatchSink>>;
}

/// The HTTP server: bind, then [`HttpServer::serve`] runs the
/// reactor on the calling thread.
pub struct HttpServer {
    listener: TcpListener,
    pool: Arc<ThreadPool>,
    handler: Arc<Handler>,
    stop: Arc<AtomicBool>,
    config: IngressConfig,
    ingress: Arc<IngressCounters>,
    stream_route: Option<Arc<dyn StreamRoute>>,
}

impl HttpServer {
    pub fn bind(addr: &str, workers: usize, handler: Arc<Handler>) -> Result<HttpServer> {
        Self::bind_with_limits(addr, workers, handler, DEFAULT_MAX_BODY_BYTES)
    }

    /// As [`HttpServer::bind`], with an explicit request-body cap.
    pub fn bind_with_limits(
        addr: &str,
        workers: usize,
        handler: Arc<Handler>,
        max_body: usize,
    ) -> Result<HttpServer> {
        let config = IngressConfig {
            max_body: max_body.max(1),
            ..IngressConfig::default()
        };
        // Standalone servers (tests, tools) get private counters; the
        // handles keep the atomics alive on their own.
        let ingress = IngressCounters::resolve(&Counters::new());
        Self::bind_with_config(addr, workers, handler, config, ingress, None)
    }

    /// Full-control constructor: explicit limits, shared counters and
    /// an optional streaming route.
    pub fn bind_with_config(
        addr: &str,
        workers: usize,
        handler: Arc<Handler>,
        config: IngressConfig,
        ingress: IngressCounters,
        stream_route: Option<Arc<dyn StreamRoute>>,
    ) -> Result<HttpServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        listener.set_nonblocking(true).context("listener nonblocking")?;
        Ok(HttpServer {
            listener,
            pool: Arc::new(ThreadPool::new(workers)),
            handler,
            stop: Arc::new(AtomicBool::new(false)),
            config,
            ingress: Arc::new(ingress),
            stream_route,
        })
    }

    pub fn local_addr(&self) -> String {
        self.listener
            .local_addr()
            .map(|a| a.to_string())
            .unwrap_or_default()
    }

    /// A flag the reactor checks; set true then poke the socket to
    /// stop `serve`.
    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }

    /// Ingress accounting (tests and the storm driver read these).
    pub fn counters(&self) -> Arc<IngressCounters> {
        Arc::clone(&self.ingress)
    }

    /// Run the reactor event loop on the calling thread until the
    /// stop flag is set (and the listener is poked).
    pub fn serve(&self) -> Result<()> {
        Reactor::new(self)?.run()
    }
}

// -----------------------------------------------------------------------
// Reactor internals
// -----------------------------------------------------------------------

/// Token assignments: listener, worker wakeup pipe, then connections
/// at `slot + CONN_TOKEN_BASE`.
const TOKEN_LISTENER: usize = 0;
const TOKEN_WAKE: usize = 1;
const CONN_TOKEN_BASE: usize = 2;

/// A finished worker job, queued back to the reactor.
struct Completion {
    slot: usize,
    gen: u64,
    resp: Response,
    panicked: bool,
}

type CompletionQueue = Arc<Mutex<VecDeque<Completion>>>;

/// The parsed request head, pending its body.
struct Head {
    method: String,
    path: String,
    content_length: usize,
    connection_close: bool,
}

enum ConnState {
    /// Accumulating head bytes in `buf`.
    Headers,
    /// Head parsed; collecting `need` more body bytes into `body`.
    BufferedBody { need: usize },
    /// Streaming route: feeding body slices straight to the parser.
    Streaming {
        parser: BatchBodyParser,
        sink: Option<Box<dyn BatchSink>>,
        remaining: usize,
        /// Early failure (parse error / shed): the rest of the body
        /// is discarded and this answers once it drains.
        failed: Option<Response>,
    },
    /// A worker owns the request; response arrives as a Completion.
    Dispatched,
    /// Serialized response draining to the socket.
    Writing,
}

struct Conn {
    stream: TcpStream,
    gen: u64,
    state: ConnState,
    /// Unparsed input (head bytes, pipelined requests).
    buf: Vec<u8>,
    /// Buffered-path body accumulator.
    body: Vec<u8>,
    head: Option<Head>,
    /// Pending output and write cursor.
    out: Vec<u8>,
    out_pos: usize,
    close_after: bool,
    /// Read deadline for the *started* request (None while idle).
    deadline: Option<Instant>,
    /// Current poller interest mask.
    interest: u32,
    peer_closed: bool,
}

impl Conn {
    fn reset_for_next_request(&mut self) {
        self.state = ConnState::Headers;
        self.head = None;
        self.body.clear();
        self.out.clear();
        self.out_pos = 0;
        self.deadline = None;
    }
}

struct Reactor<'a> {
    server: &'a HttpServer,
    poller: Poller,
    conns: Vec<Option<Conn>>,
    free: Vec<usize>,
    live: usize,
    next_gen: u64,
    completions: CompletionQueue,
    wake_rx: UnixStream,
    wake_tx: Arc<UnixStream>,
}

impl<'a> Reactor<'a> {
    fn new(server: &'a HttpServer) -> Result<Reactor<'a>> {
        let mut poller = Poller::new().context("poller")?;
        let (wake_tx, wake_rx) = UnixStream::pair().context("wake pipe")?;
        wake_tx.set_nonblocking(true).ok();
        wake_rx.set_nonblocking(true).ok();
        poller
            .register(server.listener.as_raw_fd(), TOKEN_LISTENER, EV_READ)
            .context("register listener")?;
        poller
            .register(wake_rx.as_raw_fd(), TOKEN_WAKE, EV_READ)
            .context("register wake pipe")?;
        Ok(Reactor {
            server,
            poller,
            conns: Vec::new(),
            free: Vec::new(),
            live: 0,
            next_gen: 0,
            completions: Arc::new(Mutex::new(VecDeque::new())),
            wake_rx,
            wake_tx: Arc::new(wake_tx),
        })
    }

    fn run(&mut self) -> Result<()> {
        let mut events: Vec<PollEvent> = Vec::new();
        let mut scratch = vec![0u8; 64 * 1024];
        loop {
            if self.server.stop.load(Ordering::SeqCst) {
                return Ok(());
            }
            // Cap the wait so request deadlines are swept promptly.
            let timeout = self
                .next_deadline()
                .map(|d| {
                    d.saturating_duration_since(Instant::now())
                        .as_millis()
                        .min(100) as i32
                })
                .unwrap_or(100);
            self.poller.wait(&mut events, timeout.max(1))?;
            let batch: Vec<PollEvent> = events.clone();
            for ev in batch {
                match ev.token {
                    TOKEN_LISTENER => self.accept_ready(),
                    TOKEN_WAKE => self.drain_wake(),
                    t => {
                        let slot = t - CONN_TOKEN_BASE;
                        if ev.events & (EV_READ | EV_RDHUP | EV_ERR | EV_HUP) != 0 {
                            self.on_readable(slot, &mut scratch);
                        }
                        if ev.events & EV_WRITE != 0 {
                            self.on_writable(slot);
                        }
                    }
                }
            }
            self.drain_completions();
            self.sweep_deadlines();
        }
    }

    fn next_deadline(&self) -> Option<Instant> {
        self.conns
            .iter()
            .flatten()
            .filter_map(|c| c.deadline)
            .min()
    }

    // ----------------------------------------------------------------
    // Accept path
    // ----------------------------------------------------------------

    fn accept_ready(&mut self) {
        loop {
            match self.server.listener.accept() {
                Ok((stream, _)) => {
                    if self.live >= self.server.config.max_connections {
                        self.server.ingress.over_capacity.inc();
                        drop(stream);
                        continue;
                    }
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    stream.set_nodelay(true).ok();
                    self.server.ingress.accepted.inc();
                    self.next_gen += 1;
                    let conn = Conn {
                        stream,
                        gen: self.next_gen,
                        state: ConnState::Headers,
                        buf: Vec::new(),
                        body: Vec::new(),
                        head: None,
                        out: Vec::new(),
                        out_pos: 0,
                        close_after: false,
                        deadline: None,
                        interest: EV_READ | EV_RDHUP,
                        peer_closed: false,
                    };
                    let slot = match self.free.pop() {
                        Some(s) => {
                            self.conns[s] = Some(conn);
                            s
                        }
                        None => {
                            self.conns.push(Some(conn));
                            self.conns.len() - 1
                        }
                    };
                    let fd = self.conns[slot].as_ref().unwrap().stream.as_raw_fd();
                    if self
                        .poller
                        .register(fd, slot + CONN_TOKEN_BASE, EV_READ | EV_RDHUP)
                        .is_err()
                    {
                        self.close_conn(slot);
                        continue;
                    }
                    self.live += 1;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => return,
            }
        }
    }

    fn drain_wake(&mut self) {
        let mut sink = [0u8; 256];
        loop {
            match (&self.wake_rx).read(&mut sink) {
                Ok(0) => return,
                Ok(_) => continue,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => return,
            }
        }
    }

    // ----------------------------------------------------------------
    // Connection lifecycle
    // ----------------------------------------------------------------

    fn close_conn(&mut self, slot: usize) {
        if let Some(conn) = self.conns[slot].take() {
            self.poller.deregister(conn.stream.as_raw_fd()).ok();
            self.server.ingress.closed.inc();
            self.live = self.live.saturating_sub(1);
            self.free.push(slot);
        }
    }

    /// Recompute and apply the poller interest for a slot.
    fn update_interest(&mut self, slot: usize) {
        let Some(conn) = self.conns[slot].as_mut() else { return };
        let reading = !conn.peer_closed
            && !(matches!(conn.state, ConnState::Dispatched | ConnState::Writing)
                && conn.buf.len() >= PIPELINE_CAP);
        let writing = matches!(conn.state, ConnState::Writing);
        let mut want = 0;
        if reading {
            want |= EV_READ | EV_RDHUP;
        }
        if writing {
            want |= EV_WRITE;
        }
        if want != conn.interest {
            conn.interest = want;
            let fd = conn.stream.as_raw_fd();
            self.poller.modify(fd, slot + CONN_TOKEN_BASE, want).ok();
        }
    }

    fn on_readable(&mut self, slot: usize, scratch: &mut [u8]) {
        loop {
            let Some(conn) = self.conns[slot].as_mut() else { return };
            // Backpressure: while a request is in flight, stop
            // pulling pipelined bytes past the cap.
            if matches!(conn.state, ConnState::Dispatched | ConnState::Writing)
                && conn.buf.len() >= PIPELINE_CAP
            {
                break;
            }
            match conn.stream.read(scratch) {
                Ok(0) => {
                    conn.peer_closed = true;
                    break;
                }
                Ok(n) => {
                    let started = conn.buf.is_empty()
                        && matches!(conn.state, ConnState::Headers)
                        && conn.deadline.is_none();
                    if started {
                        // The head deadline starts at the request's
                        // first byte — idle keep-alive is exempt.
                        conn.deadline = Some(Instant::now() + self.server.config.header_deadline);
                    }
                    if !self.ingest(slot, &scratch[..n]) {
                        return; // connection closed
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.abort_conn(slot);
                    return;
                }
            }
        }
        self.after_read(slot);
    }

    /// Post-read bookkeeping: peer EOF handling + interest refresh.
    fn after_read(&mut self, slot: usize) {
        let Some(conn) = self.conns[slot].as_mut() else { return };
        if conn.peer_closed {
            match conn.state {
                ConnState::Headers if conn.buf.is_empty() => {
                    // Clean close between requests.
                    self.close_conn(slot);
                    return;
                }
                ConnState::Headers
                | ConnState::BufferedBody { .. }
                | ConnState::Streaming { .. } => {
                    // Mid-request disconnect: nothing to answer into.
                    self.server.ingress.truncated.inc();
                    self.close_conn(slot);
                    return;
                }
                // Dispatched/Writing: half-close — finish writing the
                // in-flight response, then close.
                _ => {
                    conn.close_after = true;
                }
            }
        }
        self.update_interest(slot);
    }

    /// Hard-close without a response (I/O error paths).
    fn abort_conn(&mut self, slot: usize) {
        let mid_request = self.conns[slot]
            .as_ref()
            .map(|c| !matches!(c.state, ConnState::Headers) || !c.buf.is_empty())
            .unwrap_or(false);
        if mid_request {
            self.server.ingress.truncated.inc();
        }
        self.close_conn(slot);
    }

    /// Feed freshly read bytes through the connection state machine.
    /// Returns false when the connection was closed.
    fn ingest(&mut self, slot: usize, mut bytes: &[u8]) -> bool {
        while !bytes.is_empty() || self.can_advance(slot) {
            let Some(conn) = self.conns[slot].as_mut() else { return false };
            match &mut conn.state {
                ConnState::Headers => {
                    conn.buf.extend_from_slice(bytes);
                    bytes = &[];
                    match self.try_parse_head(slot) {
                        HeadOutcome::NeedMore => return true,
                        HeadOutcome::Closed => return false,
                        HeadOutcome::Parsed => continue,
                    }
                }
                ConnState::BufferedBody { need } => {
                    let take = (*need).min(bytes.len() + conn.buf.len());
                    // Drain buf first (pipelined bytes), then `bytes`.
                    let from_buf = take.min(conn.buf.len());
                    conn.body.extend_from_slice(&conn.buf[..from_buf]);
                    conn.buf.drain(..from_buf);
                    let from_new = take - from_buf;
                    conn.body.extend_from_slice(&bytes[..from_new]);
                    bytes = &bytes[from_new..];
                    *need -= take;
                    if *need > 0 {
                        return true;
                    }
                    // Anything after the body is pipelined input.
                    conn.buf.extend_from_slice(bytes);
                    bytes = &[];
                    if !self.dispatch_buffered(slot) {
                        return false;
                    }
                }
                ConnState::Streaming { remaining, .. } => {
                    // Route up to `remaining` bytes into the parser;
                    // the rest is pipelined input.
                    let mut slice = Vec::new();
                    let from_buf = (*remaining).min(conn.buf.len());
                    slice.extend_from_slice(&conn.buf[..from_buf]);
                    conn.buf.drain(..from_buf);
                    let from_new = (*remaining - from_buf).min(bytes.len());
                    slice.extend_from_slice(&bytes[..from_new]);
                    let leftover = &bytes[from_new..];
                    conn.buf.extend_from_slice(leftover);
                    bytes = &[];
                    *remaining -= slice.len();
                    let done = *remaining == 0;
                    self.stream_feed(slot, &slice);
                    if done && !self.stream_close(slot) {
                        return false;
                    }
                    if !done {
                        return true;
                    }
                }
                ConnState::Dispatched | ConnState::Writing => {
                    // Park pipelined bytes (bounded by PIPELINE_CAP
                    // via the read loop) until the response drains.
                    conn.buf.extend_from_slice(bytes);
                    return true;
                }
            }
        }
        true
    }

    /// Whether `ingest` should loop again with no new bytes (a state
    /// that can make progress from `buf` alone).
    fn can_advance(&self, slot: usize) -> bool {
        match self.conns[slot].as_ref() {
            Some(c) => match c.state {
                ConnState::Headers => !c.buf.is_empty(),
                ConnState::BufferedBody { need } => need == 0 || !c.buf.is_empty(),
                ConnState::Streaming { remaining, .. } => remaining == 0 || !c.buf.is_empty(),
                _ => false,
            },
            None => false,
        }
    }

    // ----------------------------------------------------------------
    // Head parsing
    // ----------------------------------------------------------------

    fn try_parse_head(&mut self, slot: usize) -> HeadOutcome {
        let conn = self.conns[slot].as_mut().unwrap();
        let Some(end) = find_header_end(&conn.buf) else {
            if conn.buf.len() > self.server.config.max_header {
                self.server.ingress.header_overflow.inc();
                self.respond(
                    slot,
                    Response::json(431, r#"{"error":"header section too large"}"#),
                    true,
                );
                return HeadOutcome::Parsed; // now Writing (then close)
            }
            return HeadOutcome::NeedMore;
        };
        if end > self.server.config.max_header {
            self.server.ingress.header_overflow.inc();
            self.respond(
                slot,
                Response::json(431, r#"{"error":"header section too large"}"#),
                true,
            );
            return HeadOutcome::Parsed;
        }
        let head_bytes: Vec<u8> = conn.buf.drain(..end).collect();
        let head = match parse_head(&head_bytes) {
            Ok(h) => h,
            Err(_) => {
                self.server.ingress.bad_requests.inc();
                self.respond(slot, Response::text(400, "bad request"), true);
                return HeadOutcome::Parsed;
            }
        };
        if head.content_length > self.server.config.max_body {
            // Decided from the header alone — the body was never
            // read, so the connection is desynced: answer and close.
            self.server.ingress.too_large.inc();
            self.respond(
                slot,
                Response::json(413, r#"{"error":"request body too large"}"#),
                true,
            );
            return HeadOutcome::Parsed;
        }
        let conn = self.conns[slot].as_mut().unwrap();
        conn.close_after = conn.close_after || head.connection_close;
        conn.deadline = Some(Instant::now() + self.server.config.body_deadline);
        // Streaming route?
        if let Some(route) = &self.server.stream_route {
            if let Some(sink) = route.begin(&head.method, &head.path) {
                let conn = self.conns[slot].as_mut().unwrap();
                conn.state = ConnState::Streaming {
                    parser: BatchBodyParser::new(),
                    sink: Some(sink),
                    remaining: head.content_length,
                    failed: None,
                };
                conn.head = Some(head);
                return HeadOutcome::Parsed;
            }
        }
        let conn = self.conns[slot].as_mut().unwrap();
        conn.body.clear();
        conn.body.reserve(head.content_length.min(self.server.config.max_body));
        conn.state = ConnState::BufferedBody {
            need: head.content_length,
        };
        conn.head = Some(head);
        HeadOutcome::Parsed
    }

    // ----------------------------------------------------------------
    // Buffered dispatch
    // ----------------------------------------------------------------

    fn dispatch_buffered(&mut self, slot: usize) -> bool {
        let conn = self.conns[slot].as_mut().unwrap();
        let head = conn.head.take().expect("head parsed before body");
        let body_bytes = std::mem::take(&mut conn.body);
        let body = match String::from_utf8(body_bytes) {
            Ok(b) => b,
            Err(_) => {
                self.server.ingress.bad_requests.inc();
                self.respond(slot, Response::text(400, "bad request"), true);
                return true;
            }
        };
        let req = Request {
            method: head.method,
            path: head.path,
            body,
        };
        self.server.ingress.requests.inc();
        conn.state = ConnState::Dispatched;
        conn.deadline = None;
        let gen = conn.gen;
        let handler = Arc::clone(&self.server.handler);
        let completions = Arc::clone(&self.completions);
        let wake = Arc::clone(&self.wake_tx);
        self.server.pool.execute(move || {
            // A panicking handler answers 500 and closes — it must
            // not kill the worker or strand the connection.
            let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| handler(&req)));
            let (resp, panicked) = match out {
                Ok(r) => (r, false),
                Err(_) => (
                    Response::json(500, r#"{"error":"internal server error"}"#),
                    true,
                ),
            };
            completions.lock().unwrap().push_back(Completion {
                slot,
                gen,
                resp,
                panicked,
            });
            let _ = (&*wake).write(&[1u8]);
        });
        self.update_interest(slot);
        true
    }

    // ----------------------------------------------------------------
    // Streaming path
    // ----------------------------------------------------------------

    /// Feed a body slice to the connection's incremental parser.
    fn stream_feed(&mut self, slot: usize, slice: &[u8]) {
        let Some(conn) = self.conns[slot].as_mut() else { return };
        let ConnState::Streaming { parser, sink, failed, .. } = &mut conn.state else {
            return;
        };
        if failed.is_some() {
            return; // discarding the rest of the body
        }
        let Some(sink_ref) = sink.as_mut() else { return };
        let mut abort: Option<Response> = None;
        let mut events = 0u64;
        let fed = {
            let mut cb = |item: StreamItem| match item {
                StreamItem::Event(v) => {
                    if abort.is_none() {
                        events += 1;
                        abort = sink_ref.event(v);
                    }
                }
                StreamItem::EventsRestart => sink_ref.restart(),
            };
            parser.feed(slice, &mut cb)
        };
        self.server.ingress.streamed_events.add(events);
        if let Err(e) = fed {
            // Same error surface as the buffered path: 422 with the
            // JsonError's Display (message + byte offset).
            let body = Json::obj(vec![("error", Json::str(e.to_string()))]).to_string();
            let conn = self.conns[slot].as_mut().unwrap();
            if let ConnState::Streaming { failed, .. } = &mut conn.state {
                *failed = Some(Response::json(422, body));
            }
        } else if let Some(resp) = abort {
            if resp.status == 429 {
                self.server.ingress.shed.inc();
            }
            let conn = self.conns[slot].as_mut().unwrap();
            if let ConnState::Streaming { failed, .. } = &mut conn.state {
                *failed = Some(resp);
            }
        }
    }

    /// Content-Length consumed: close out the streamed request.
    /// Returns false when the connection was closed.
    fn stream_close(&mut self, slot: usize) -> bool {
        let Some(conn) = self.conns[slot].as_mut() else { return false };
        let ConnState::Streaming { parser, sink, failed, .. } = &mut conn.state else {
            return true;
        };
        // Early failure (shed or parse error): the body has drained,
        // the connection is synced — answer and keep it alive.
        if let Some(resp) = failed.take() {
            self.respond(slot, resp, false);
            return true;
        }
        let mut sink_box = sink.take().expect("sink present until finish");
        let mut abort: Option<Response> = None;
        let mut events = 0u64;
        let finished = {
            let mut cb = |item: StreamItem| match item {
                StreamItem::Event(v) => {
                    if abort.is_none() {
                        events += 1;
                        abort = sink_box.event(v);
                    }
                }
                StreamItem::EventsRestart => sink_box.restart(),
            };
            parser.finish(&mut cb)
        };
        self.server.ingress.streamed_events.add(events);
        let shape = match finished {
            Ok(shape) => shape,
            Err(e) => {
                let body = Json::obj(vec![("error", Json::str(e.to_string()))]).to_string();
                self.respond(slot, Response::json(422, body), false);
                return true;
            }
        };
        if let Some(resp) = abort {
            if resp.status == 429 {
                self.server.ingress.shed.inc();
            }
            self.respond(slot, resp, false);
            return true;
        }
        // Scoring happens on a worker, like the buffered path.
        let conn = self.conns[slot].as_mut().unwrap();
        self.server.ingress.requests.inc();
        conn.state = ConnState::Dispatched;
        conn.head = None;
        conn.deadline = None;
        let gen = conn.gen;
        let completions = Arc::clone(&self.completions);
        let wake = Arc::clone(&self.wake_tx);
        self.server.pool.execute(move || {
            let out =
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| sink_box.finish(shape)));
            let (resp, panicked) = match out {
                Ok(r) => (r, false),
                Err(_) => (
                    Response::json(500, r#"{"error":"internal server error"}"#),
                    true,
                ),
            };
            completions.lock().unwrap().push_back(Completion {
                slot,
                gen,
                resp,
                panicked,
            });
            let _ = (&*wake).write(&[1u8]);
        });
        self.update_interest(slot);
        true
    }

    // ----------------------------------------------------------------
    // Responses and completions
    // ----------------------------------------------------------------

    fn drain_completions(&mut self) {
        loop {
            let c = self.completions.lock().unwrap().pop_front();
            let Some(c) = c else { return };
            let Some(conn) = self.conns[c.slot].as_mut() else { continue };
            if conn.gen != c.gen {
                continue; // slot was reused; stale completion
            }
            if c.panicked {
                self.server.ingress.panics.inc();
            }
            self.respond(c.slot, c.resp, c.panicked);
        }
    }

    /// Serialize `resp` and start draining it. `force_close` closes
    /// the connection after the write even if the request asked for
    /// keep-alive (panics, protocol desyncs).
    fn respond(&mut self, slot: usize, resp: Response, force_close: bool) {
        let Some(conn) = self.conns[slot].as_mut() else { return };
        conn.close_after = conn.close_after || force_close;
        let keep_alive = !conn.close_after;
        conn.out = response_bytes(&resp, keep_alive);
        conn.out_pos = 0;
        conn.state = ConnState::Writing;
        conn.deadline = None;
        self.try_write(slot);
    }

    fn on_writable(&mut self, slot: usize) {
        self.try_write(slot);
    }

    fn try_write(&mut self, slot: usize) {
        loop {
            let Some(conn) = self.conns[slot].as_mut() else { return };
            if !matches!(conn.state, ConnState::Writing) {
                self.update_interest(slot);
                return;
            }
            if conn.out_pos >= conn.out.len() {
                // Response fully drained.
                if conn.close_after || conn.peer_closed {
                    self.close_conn(slot);
                    return;
                }
                conn.reset_for_next_request();
                self.update_interest(slot);
                // Pipelined request already buffered? Keep going.
                if self
                    .conns[slot]
                    .as_ref()
                    .map(|c| !c.buf.is_empty())
                    .unwrap_or(false)
                {
                    if let Some(c) = self.conns[slot].as_mut() {
                        c.deadline =
                            Some(Instant::now() + self.server.config.header_deadline);
                    }
                    if !self.ingest(slot, &[]) {
                        return;
                    }
                    continue;
                }
                return;
            }
            match conn.stream.write(&conn.out[conn.out_pos..]) {
                Ok(0) => {
                    self.abort_conn(slot);
                    return;
                }
                Ok(n) => {
                    conn.out_pos += n;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    self.update_interest(slot);
                    return;
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.abort_conn(slot);
                    return;
                }
            }
        }
    }

    /// Expire requests that missed their read deadline (slowloris):
    /// 408 + close. Idle keep-alive connections carry no deadline.
    fn sweep_deadlines(&mut self) {
        let now = Instant::now();
        let expired: Vec<usize> = self
            .conns
            .iter()
            .enumerate()
            .filter_map(|(i, c)| {
                c.as_ref()
                    .and_then(|c| c.deadline)
                    .filter(|d| *d <= now)
                    .map(|_| i)
            })
            .collect();
        for slot in expired {
            self.server.ingress.timeouts.inc();
            self.respond(
                slot,
                Response::json(408, r#"{"error":"request read timed out"}"#),
                true,
            );
        }
    }
}

enum HeadOutcome {
    NeedMore,
    Parsed,
    /// Connection closed during handling.
    #[allow(dead_code)]
    Closed,
}

/// Find the end of the header section: the byte index one past the
/// first blank line. Accepts `\r\n\r\n` and bare `\n\n` (the seed's
/// `read_line` + `trim_end` parser accepted both).
fn find_header_end(buf: &[u8]) -> Option<usize> {
    let mut i = 0;
    while i < buf.len() {
        if buf[i] == b'\n' {
            if buf[i + 1..].starts_with(b"\r\n") {
                return Some(i + 3);
            }
            if buf.get(i + 1) == Some(&b'\n') {
                return Some(i + 2);
            }
        }
        i += 1;
    }
    None
}

/// Parse the request line + headers (same tolerances as the seed:
/// whitespace-split request line, case-insensitive header names,
/// `\r` optional). Hardened: duplicate Content-Length headers with
/// conflicting values are rejected.
fn parse_head(head: &[u8]) -> Result<Head> {
    let text = std::str::from_utf8(head).context("head not UTF-8")?;
    let mut lines = text.split('\n').map(|l| l.trim_end_matches('\r'));
    let request_line = lines.next().context("empty head")?;
    let mut parts = request_line.split_whitespace();
    let method = parts.next().context("missing method")?.to_string();
    let path = parts.next().context("missing path")?.to_string();
    let version = parts.next().context("missing version")?;
    if !version.starts_with("HTTP/1.") {
        bail!("unsupported version {version}");
    }
    let mut content_length: Option<usize> = None;
    let mut connection_close = false;
    for line in lines {
        if line.is_empty() {
            continue;
        }
        if let Some((name, value)) = line.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                let v: usize = value.trim().parse().context("bad content-length")?;
                if let Some(prev) = content_length {
                    if prev != v {
                        bail!("conflicting content-length headers");
                    }
                }
                content_length = Some(v);
            } else if name.eq_ignore_ascii_case("connection") {
                if value.trim().eq_ignore_ascii_case("close") {
                    connection_close = true;
                }
            }
        }
    }
    Ok(Head {
        method,
        path,
        content_length: content_length.unwrap_or(0),
        connection_close,
    })
}

/// Serialize a response (the single wire format both the reactor and
/// [`write_response`] emit — responses stay byte-identical across
/// the streamed and buffered paths).
fn response_bytes(resp: &Response, keep_alive: bool) -> Vec<u8> {
    let conn = if keep_alive { "keep-alive" } else { "close" };
    let retry = resp
        .retry_after
        .map(|s| format!("Retry-After: {s}\r\n"))
        .unwrap_or_default();
    format!(
        "HTTP/1.1 {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n{}\r\n{}",
        resp.status_line(),
        resp.content_type,
        resp.body.len(),
        conn,
        retry,
        resp.body
    )
    .into_bytes()
}

// -----------------------------------------------------------------------
// Blocking helpers (tests, warm-up driver, simple clients)
// -----------------------------------------------------------------------

/// Outcome of reading one request off a blocking reader.
enum ReadOutcome {
    Request(Request),
    Closed,
    TooLarge,
}

/// Read one request from a blocking reader; `Ok(None)` on EOF before
/// a request line. Bodies over [`DEFAULT_MAX_BODY_BYTES`] error. (The
/// server itself parses incrementally — this helper serves tests and
/// tools that want the simple blocking form.)
pub fn read_request<R: BufRead>(reader: &mut R) -> Result<Option<Request>> {
    match read_request_limited(reader, DEFAULT_MAX_BODY_BYTES)? {
        ReadOutcome::Request(r) => Ok(Some(r)),
        ReadOutcome::Closed => Ok(None),
        ReadOutcome::TooLarge => bail!("body too large"),
    }
}

fn read_request_limited<R: BufRead>(reader: &mut R, max_body: usize) -> Result<ReadOutcome> {
    let mut line = String::new();
    if reader.read_line(&mut line)? == 0 {
        return Ok(ReadOutcome::Closed);
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().context("missing method")?.to_string();
    let path = parts.next().context("missing path")?.to_string();
    let version = parts.next().context("missing version")?;
    if !version.starts_with("HTTP/1.") {
        bail!("unsupported version {version}");
    }
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        if reader.read_line(&mut h)? == 0 {
            bail!("eof in headers");
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((name, value)) = h.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value.trim().parse().context("bad content-length")?;
            }
        }
    }
    if content_length > max_body {
        return Ok(ReadOutcome::TooLarge);
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok(ReadOutcome::Request(Request {
        method,
        path,
        body: String::from_utf8(body).context("body not UTF-8")?,
    }))
}

pub fn write_response<W: Write>(w: &mut W, resp: &Response, keep_alive: bool) -> Result<()> {
    w.write_all(&response_bytes(resp, keep_alive))?;
    w.flush()?;
    Ok(())
}

/// A tiny blocking client for tests and the warm-up driver.
pub fn http_request(addr: &str, method: &str, path: &str, body: &str) -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true).ok();
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    stream.flush()?;
    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .context("bad status line")?
        .parse()?;
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        if reader.read_line(&mut h)? == 0 {
            break;
        }
        let h2 = h.trim_end();
        if h2.is_empty() {
            break;
        }
        if let Some((name, value)) = h2.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value.trim().parse()?;
            }
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok((status, String::from_utf8(body)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;
    use std::time::Duration;

    fn echo_handler() -> Arc<Handler> {
        Arc::new(|req: &Request| match req.path.as_str() {
            "/healthz" => Response::text(200, "ok"),
            "/echo" => Response::json(200, req.body.clone()),
            "/panic" => panic!("handler exploded"),
            "/shed" => Response::json(429, r#"{"error":"shed"}"#).with_retry_after(7),
            _ => Response::text(404, "not found"),
        })
    }

    fn spawn_echo() -> String {
        let server = HttpServer::bind("127.0.0.1:0", 2, echo_handler()).unwrap();
        let addr = server.local_addr();
        thread::spawn(move || server.serve().unwrap());
        addr
    }

    fn spawn_echo_capped(max_body: usize) -> String {
        let server =
            HttpServer::bind_with_limits("127.0.0.1:0", 2, echo_handler(), max_body).unwrap();
        let addr = server.local_addr();
        thread::spawn(move || server.serve().unwrap());
        addr
    }

    /// Spawn with explicit config + route; returns (addr, counters).
    fn spawn_with(
        config: IngressConfig,
        route: Option<Arc<dyn StreamRoute>>,
    ) -> (String, Arc<IngressCounters>) {
        let server = HttpServer::bind_with_config(
            "127.0.0.1:0",
            2,
            echo_handler(),
            config,
            IngressCounters::resolve(&Counters::new()),
            route,
        )
        .unwrap();
        let addr = server.local_addr();
        let counters = server.counters();
        thread::spawn(move || server.serve().unwrap());
        (addr, counters)
    }

    /// Read one full response off a blocking reader: (status, raw
    /// header lines, exact body).
    fn read_raw_response<R: BufRead>(reader: &mut R) -> (u16, String, String) {
        let mut status_line = String::new();
        reader.read_line(&mut status_line).unwrap();
        let status: u16 = status_line.split_whitespace().nth(1).unwrap().parse().unwrap();
        let mut headers = String::new();
        let mut content_length = 0usize;
        loop {
            let mut h = String::new();
            reader.read_line(&mut h).unwrap();
            if h.trim_end().is_empty() {
                break;
            }
            if let Some((name, value)) = h.trim_end().split_once(':') {
                if name.eq_ignore_ascii_case("content-length") {
                    content_length = value.trim().parse().unwrap();
                }
            }
            headers.push_str(&h);
        }
        let mut body = vec![0u8; content_length];
        reader.read_exact(&mut body).unwrap();
        (status, headers, String::from_utf8(body).unwrap())
    }

    fn wait_for(counter: &CounterHandle, at_least: u64) {
        for _ in 0..200 {
            if counter.get() >= at_least {
                return;
            }
            thread::sleep(Duration::from_millis(10));
        }
        panic!("counter never reached {at_least} (got {})", counter.get());
    }

    // ------------------------------------------------------------------
    // Seed behavior (must survive the reactor rewrite unchanged)
    // ------------------------------------------------------------------

    #[test]
    fn health_endpoint() {
        let addr = spawn_echo();
        let (status, body) = http_request(&addr, "GET", "/healthz", "").unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, "ok");
    }

    #[test]
    fn echo_roundtrip_with_body() {
        let addr = spawn_echo();
        let payload = r#"{"x": [1, 2, 3], "s": "héllo"}"#;
        let (status, body) = http_request(&addr, "POST", "/echo", payload).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, payload);
    }

    #[test]
    fn not_found() {
        let addr = spawn_echo();
        let (status, _) = http_request(&addr, "GET", "/nope", "").unwrap();
        assert_eq!(status, 404);
    }

    #[test]
    fn many_concurrent_clients() {
        let addr = spawn_echo();
        let handles: Vec<_> = (0..16)
            .map(|i| {
                let addr = addr.clone();
                thread::spawn(move || {
                    let body = format!("{{\"i\": {i}}}");
                    let (s, b) = http_request(&addr, "POST", "/echo", &body).unwrap();
                    assert_eq!(s, 200);
                    assert_eq!(b, body);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn oversized_body_is_rejected_with_413_before_reading_it() {
        let addr = spawn_echo_capped(256);
        // Declare a body far over the cap but never send it: the 413
        // must come from the Content-Length header alone, proving the
        // server did not try to buffer the payload.
        let mut stream = TcpStream::connect(&addr).unwrap();
        write!(
            stream,
            "POST /echo HTTP/1.1\r\nHost: x\r\nContent-Length: 1000000\r\n\r\n"
        )
        .unwrap();
        stream.flush().unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut status = String::new();
        reader.read_line(&mut status).unwrap();
        assert!(status.contains("413"), "{status}");
        let mut rest = String::new();
        let mut tmp = String::new();
        while reader.read_line(&mut tmp).unwrap() > 0 {
            rest.push_str(&tmp);
            tmp.clear();
        }
        assert!(rest.contains("request body too large"), "{rest}");
        assert!(
            rest.to_ascii_lowercase().contains("connection: close"),
            "oversized request must close the (desynced) connection: {rest}"
        );
        // A body exactly at the cap still round-trips.
        let payload = "x".repeat(256);
        let (status, body) = http_request(&addr, "POST", "/echo", &payload).unwrap();
        assert_eq!((status, body.as_str()), (200, payload.as_str()));
        // One byte over: rejected.
        let payload = "x".repeat(257);
        let (status, _) = http_request(&addr, "POST", "/echo", &payload).unwrap();
        assert_eq!(status, 413);
    }

    #[test]
    fn malformed_request_gets_400() {
        let addr = spawn_echo();
        let mut stream = TcpStream::connect(&addr).unwrap();
        stream.write_all(b"NONSENSE\r\n\r\n").unwrap();
        let mut buf = String::new();
        BufReader::new(stream).read_line(&mut buf).unwrap();
        assert!(buf.contains("400"), "{buf}");
    }

    #[test]
    fn panicking_handler_returns_500_and_keeps_server_alive() {
        let addr = spawn_echo();
        // Mid-keep-alive: a healthy request, then the panicking one on
        // the same connection.
        let mut stream = TcpStream::connect(&addr).unwrap();
        write!(
            stream,
            "GET /healthz HTTP/1.1\r\nHost: x\r\nContent-Length: 0\r\n\r\n"
        )
        .unwrap();
        stream.flush().unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut status = String::new();
        reader.read_line(&mut status).unwrap();
        assert!(status.contains("200"), "{status}");
        loop {
            let mut h = String::new();
            reader.read_line(&mut h).unwrap();
            if h.trim_end().is_empty() {
                break;
            }
        }
        let mut body = [0u8; 2];
        reader.read_exact(&mut body).unwrap();
        write!(
            stream,
            "GET /panic HTTP/1.1\r\nHost: x\r\nContent-Length: 0\r\n\r\n"
        )
        .unwrap();
        stream.flush().unwrap();
        let mut status = String::new();
        reader.read_line(&mut status).unwrap();
        assert!(status.contains("500"), "got: {status}");
        let mut rest = String::new();
        let mut tmp = String::new();
        while reader.read_line(&mut tmp).unwrap() > 0 {
            rest.push_str(&tmp);
            tmp.clear();
        }
        assert!(rest.contains("internal server error"), "{rest}");
        assert!(
            rest.to_ascii_lowercase().contains("connection: close"),
            "panicked connection must not stay keep-alive: {rest}"
        );
        // The pool worker survived: fresh connections still served.
        let (status, body) = http_request(&addr, "GET", "/healthz", "").unwrap();
        assert_eq!((status, body.as_str()), (200, "ok"));
    }

    #[test]
    fn keep_alive_serves_multiple_requests() {
        let addr = spawn_echo();
        let mut stream = TcpStream::connect(&addr).unwrap();
        for _ in 0..3 {
            write!(
                stream,
                "GET /healthz HTTP/1.1\r\nHost: x\r\nContent-Length: 0\r\n\r\n"
            )
            .unwrap();
            stream.flush().unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut status = String::new();
            reader.read_line(&mut status).unwrap();
            assert!(status.contains("200"));
            // Drain headers + body ("ok").
            loop {
                let mut h = String::new();
                reader.read_line(&mut h).unwrap();
                if h.trim_end().is_empty() {
                    break;
                }
            }
            let mut body = [0u8; 2];
            reader.read_exact(&mut body).unwrap();
            assert_eq!(&body, b"ok");
        }
    }

    // ------------------------------------------------------------------
    // Protocol-abuse corpus (new with the reactor)
    // ------------------------------------------------------------------

    #[test]
    fn pipelined_requests_are_each_answered_in_order() {
        let addr = spawn_echo();
        let mut stream = TcpStream::connect(&addr).unwrap();
        // Three requests in one write: the reactor must answer all
        // three in order on the same connection.
        let one = "GET /healthz HTTP/1.1\r\nHost: x\r\nContent-Length: 0\r\n\r\n";
        let two = "POST /echo HTTP/1.1\r\nHost: x\r\nContent-Length: 5\r\n\r\nhello";
        stream
            .write_all(format!("{one}{two}{one}").as_bytes())
            .unwrap();
        stream.flush().unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let (s1, _, b1) = read_raw_response(&mut reader);
        let (s2, _, b2) = read_raw_response(&mut reader);
        let (s3, _, b3) = read_raw_response(&mut reader);
        assert_eq!((s1, b1.as_str()), (200, "ok"));
        assert_eq!((s2, b2.as_str()), (200, "hello"));
        assert_eq!((s3, b3.as_str()), (200, "ok"));
    }

    #[test]
    fn mid_body_disconnect_is_counted_and_server_survives() {
        let (addr, counters) = spawn_with(IngressConfig::default(), None);
        {
            let mut stream = TcpStream::connect(&addr).unwrap();
            write!(
                stream,
                "POST /echo HTTP/1.1\r\nHost: x\r\nContent-Length: 100\r\n\r\npartial"
            )
            .unwrap();
            stream.flush().unwrap();
            // Drop mid-body.
        }
        wait_for(&counters.truncated, 1);
        assert_eq!(counters.requests.get(), 0, "truncated request must not dispatch");
        let (status, body) = http_request(&addr, "GET", "/healthz", "").unwrap();
        assert_eq!((status, body.as_str()), (200, "ok"));
    }

    #[test]
    fn conflicting_duplicate_content_length_is_rejected() {
        let (addr, counters) = spawn_with(IngressConfig::default(), None);
        let mut stream = TcpStream::connect(&addr).unwrap();
        write!(
            stream,
            "POST /echo HTTP/1.1\r\nContent-Length: 5\r\nContent-Length: 6\r\n\r\nhello!"
        )
        .unwrap();
        stream.flush().unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let (status, headers, _) = read_raw_response(&mut reader);
        assert_eq!(status, 400);
        assert!(headers.to_ascii_lowercase().contains("connection: close"));
        wait_for(&counters.bad_requests, 1);
        // Duplicate but *agreeing* Content-Length headers stay legal.
        let mut stream = TcpStream::connect(&addr).unwrap();
        write!(
            stream,
            "POST /echo HTTP/1.1\r\nContent-Length: 5\r\nContent-Length: 5\r\n\r\nhello"
        )
        .unwrap();
        stream.flush().unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let (status, _, body) = read_raw_response(&mut reader);
        assert_eq!((status, body.as_str()), (200, "hello"));
    }

    #[test]
    fn oversized_header_section_gets_431() {
        let config = IngressConfig {
            max_header: 512,
            ..IngressConfig::default()
        };
        let (addr, counters) = spawn_with(config, None);
        let mut stream = TcpStream::connect(&addr).unwrap();
        let giant = "x".repeat(2048);
        write!(
            stream,
            "GET /healthz HTTP/1.1\r\nX-Giant: {giant}\r\n\r\n"
        )
        .unwrap();
        stream.flush().unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let (status, headers, body) = read_raw_response(&mut reader);
        assert_eq!(status, 431);
        assert!(body.contains("header section too large"), "{body}");
        assert!(headers.to_ascii_lowercase().contains("connection: close"));
        wait_for(&counters.header_overflow, 1);
    }

    #[test]
    fn content_length_mismatch_desyncs_into_400() {
        let addr = spawn_echo();
        // Body longer than declared: the excess parses as the next
        // "request", which is garbage -> 400 + close after the first
        // (valid) response.
        let mut stream = TcpStream::connect(&addr).unwrap();
        write!(
            stream,
            "POST /echo HTTP/1.1\r\nContent-Length: 5\r\n\r\nhelloXYZ\r\n\r\n"
        )
        .unwrap();
        stream.flush().unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let (s1, _, b1) = read_raw_response(&mut reader);
        assert_eq!((s1, b1.as_str()), (200, "hello"));
        let (s2, _, _) = read_raw_response(&mut reader);
        assert_eq!(s2, 400);
    }

    #[test]
    fn slowloris_header_drip_hits_read_deadline_with_408() {
        let config = IngressConfig {
            header_deadline: Duration::from_millis(200),
            ..IngressConfig::default()
        };
        let (addr, counters) = spawn_with(config, None);
        let mut stream = TcpStream::connect(&addr).unwrap();
        // Start a request but never finish the head.
        stream.write_all(b"GET /healthz HTT").unwrap();
        stream.flush().unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let (status, headers, _) = read_raw_response(&mut reader);
        assert_eq!(status, 408);
        assert!(headers.to_ascii_lowercase().contains("connection: close"));
        wait_for(&counters.timeouts, 1);
    }

    #[test]
    fn idle_keep_alive_is_exempt_from_read_deadlines() {
        let config = IngressConfig {
            header_deadline: Duration::from_millis(200),
            body_deadline: Duration::from_millis(200),
            ..IngressConfig::default()
        };
        let (addr, _) = spawn_with(config, None);
        let mut stream = TcpStream::connect(&addr).unwrap();
        // First request proves the connection is live.
        write!(
            stream,
            "GET /healthz HTTP/1.1\r\nHost: x\r\nContent-Length: 0\r\n\r\n"
        )
        .unwrap();
        stream.flush().unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let (s1, _, _) = read_raw_response(&mut reader);
        assert_eq!(s1, 200);
        // Idle well past the deadline: the connection must survive,
        // because the deadline only arms at a request's first byte.
        thread::sleep(Duration::from_millis(600));
        write!(
            stream,
            "GET /healthz HTTP/1.1\r\nHost: x\r\nContent-Length: 0\r\n\r\n"
        )
        .unwrap();
        stream.flush().unwrap();
        let (s2, _, b2) = read_raw_response(&mut reader);
        assert_eq!((s2, b2.as_str()), (200, "ok"));
    }

    #[test]
    fn connection_cap_sheds_excess_accepts() {
        let config = IngressConfig {
            max_connections: 2,
            ..IngressConfig::default()
        };
        let (addr, counters) = spawn_with(config, None);
        // Two established connections, proven live with a request.
        let mut keep = Vec::new();
        for _ in 0..2 {
            let mut stream = TcpStream::connect(&addr).unwrap();
            write!(
                stream,
                "GET /healthz HTTP/1.1\r\nHost: x\r\nContent-Length: 0\r\n\r\n"
            )
            .unwrap();
            stream.flush().unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let (s, _, _) = read_raw_response(&mut reader);
            assert_eq!(s, 200);
            keep.push(stream);
        }
        // The third is dropped at accept time.
        let mut extra = TcpStream::connect(&addr).unwrap();
        extra
            .set_read_timeout(Some(Duration::from_secs(5)))
            .unwrap();
        let mut buf = [0u8; 1];
        let got = extra.read(&mut buf);
        assert!(
            matches!(got, Ok(0)) || got.is_err(),
            "over-capacity connection should be dropped, got {got:?}"
        );
        wait_for(&counters.over_capacity, 1);
        drop(keep);
    }

    #[test]
    fn retry_after_header_is_emitted_on_shed_responses() {
        let addr = spawn_echo();
        let mut stream = TcpStream::connect(&addr).unwrap();
        write!(
            stream,
            "GET /shed HTTP/1.1\r\nHost: x\r\nContent-Length: 0\r\n\r\n"
        )
        .unwrap();
        stream.flush().unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let (status, headers, _) = read_raw_response(&mut reader);
        assert_eq!(status, 429);
        assert!(
            headers.contains("Retry-After: 7"),
            "missing Retry-After: {headers}"
        );
    }

    // ------------------------------------------------------------------
    // Streaming route plumbing
    // ------------------------------------------------------------------

    /// Test sink: counts events; sheds after `abort_after` if set.
    struct CountingSink {
        count: usize,
        restarts: usize,
        abort_after: Option<usize>,
    }

    impl BatchSink for CountingSink {
        fn event(&mut self, _value: Json) -> Option<Response> {
            self.count += 1;
            match self.abort_after {
                Some(n) if self.count >= n => Some(
                    Response::json(429, r#"{"error":"shed"}"#).with_retry_after(1),
                ),
                _ => None,
            }
        }
        fn restart(&mut self) {
            self.count = 0;
            self.restarts += 1;
        }
        fn finish(self: Box<Self>, shape: BatchShape) -> Response {
            Response::json(
                200,
                format!(
                    "{{\"count\":{},\"restarts\":{},\"seen\":{}}}",
                    self.count, self.restarts, shape.events_seen
                ),
            )
        }
    }

    struct CountingRoute {
        abort_after: Option<usize>,
    }

    impl StreamRoute for CountingRoute {
        fn begin(&self, method: &str, path: &str) -> Option<Box<dyn BatchSink>> {
            if method == "POST" && path == "/v1/score/batch" {
                Some(Box::new(CountingSink {
                    count: 0,
                    restarts: 0,
                    abort_after: self.abort_after,
                }))
            } else {
                None
            }
        }
    }

    #[test]
    fn streaming_route_sees_every_event_without_buffering() {
        let route: Arc<dyn StreamRoute> = Arc::new(CountingRoute { abort_after: None });
        let (addr, counters) = spawn_with(IngressConfig::default(), Some(route));
        let body = r#"{"events": [{"a":1},{"a":2},{"a":3}], "tag": "x"}"#;
        let (status, resp) = http_request(&addr, "POST", "/v1/score/batch", body).unwrap();
        assert_eq!(status, 200);
        assert_eq!(resp, r#"{"count":3,"restarts":0,"seen":true}"#);
        assert_eq!(counters.streamed_events.get(), 3);
        // Non-matching paths still take the buffered handler.
        let (status, resp) = http_request(&addr, "POST", "/echo", "plain").unwrap();
        assert_eq!((status, resp.as_str()), (200, "plain"));
    }

    #[test]
    fn streamed_parse_error_is_422_and_keeps_the_connection() {
        let route: Arc<dyn StreamRoute> = Arc::new(CountingRoute { abort_after: None });
        let (addr, _) = spawn_with(IngressConfig::default(), Some(route));
        let bad = r#"{"events": [{"a":1}, wat]}"#;
        let mut stream = TcpStream::connect(&addr).unwrap();
        write!(
            stream,
            "POST /v1/score/batch HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{bad}",
            bad.len()
        )
        .unwrap();
        stream.flush().unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let (status, _, body) = read_raw_response(&mut reader);
        assert_eq!(status, 422);
        assert!(body.contains("json error at byte"), "{body}");
        // The body was fully consumed: the connection stays usable.
        write!(
            stream,
            "GET /healthz HTTP/1.1\r\nHost: x\r\nContent-Length: 0\r\n\r\n"
        )
        .unwrap();
        stream.flush().unwrap();
        let (s2, _, b2) = read_raw_response(&mut reader);
        assert_eq!((s2, b2.as_str()), (200, "ok"));
    }

    #[test]
    fn streamed_shed_aborts_early_drains_and_keeps_the_connection() {
        let route: Arc<dyn StreamRoute> = Arc::new(CountingRoute { abort_after: Some(1) });
        let (addr, counters) = spawn_with(IngressConfig::default(), Some(route));
        let body = r#"{"events": [{"a":1},{"a":2},{"a":3}]}"#;
        let mut stream = TcpStream::connect(&addr).unwrap();
        write!(
            stream,
            "POST /v1/score/batch HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        )
        .unwrap();
        stream.flush().unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let (status, headers, _) = read_raw_response(&mut reader);
        assert_eq!(status, 429);
        assert!(headers.contains("Retry-After: 1"), "{headers}");
        assert_eq!(counters.shed.get(), 1);
        // Keep-alive after the shed: the remaining body was drained.
        write!(
            stream,
            "GET /healthz HTTP/1.1\r\nHost: x\r\nContent-Length: 0\r\n\r\n"
        )
        .unwrap();
        stream.flush().unwrap();
        let (s2, _, b2) = read_raw_response(&mut reader);
        assert_eq!((s2, b2.as_str()), (200, "ok"));
    }

    #[test]
    fn chunk_boundaries_do_not_change_streamed_results() {
        let route: Arc<dyn StreamRoute> = Arc::new(CountingRoute { abort_after: None });
        let (addr, _) = spawn_with(IngressConfig::default(), Some(route));
        let body = r#"{"events": [{"a":1},{"b":[2,3]},{"c":"x"}]}"#;
        // Drip the body one byte at a time across many packets: the
        // incremental parser must produce the same result as one shot.
        let mut stream = TcpStream::connect(&addr).unwrap();
        write!(
            stream,
            "POST /v1/score/batch HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n",
            body.len()
        )
        .unwrap();
        stream.flush().unwrap();
        for chunk in body.as_bytes().chunks(1) {
            stream.write_all(chunk).unwrap();
            stream.flush().unwrap();
        }
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let (status, _, resp) = read_raw_response(&mut reader);
        assert_eq!(status, 200);
        assert_eq!(resp, r#"{"count":3,"restarts":0,"seen":true}"#);
    }
}
