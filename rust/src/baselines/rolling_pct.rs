//! Sift-style rolling-percentile scoring (Section 4): alongside the
//! raw model output, the provider delivers a secondary score — the
//! event's percentile within a rolling window of recent traffic.
//!
//! Trade-offs the paper calls out: the provider must maintain a
//! rolling window of scores per tenant (state! — MUSE's transformation
//! is a fixed table), and the percentile is *relative*: during an
//! attack the window itself fills with high scores, so the percentile
//! of a given raw score sags — the score semantics drift exactly when
//! stability matters.

use std::collections::VecDeque;

/// A rolling-window percentile scorer (per tenant, stateful).
pub struct RollingPercentile {
    window: VecDeque<f64>,
    capacity: usize,
}

impl RollingPercentile {
    pub fn new(capacity: usize) -> RollingPercentile {
        assert!(capacity >= 1);
        RollingPercentile {
            window: VecDeque::with_capacity(capacity),
            capacity,
        }
    }

    pub fn len(&self) -> usize {
        self.window.len()
    }

    pub fn is_empty(&self) -> bool {
        self.window.is_empty()
    }

    /// Score = share of the window strictly below `raw` (0.5 for an
    /// empty window), then push `raw` into the window. O(window) —
    /// part of the complexity cost the paper notes.
    pub fn score_and_update(&mut self, raw: f64) -> f64 {
        let pct = if self.window.is_empty() {
            0.5
        } else {
            self.window.iter().filter(|&&w| w < raw).count() as f64 / self.window.len() as f64
        };
        if self.window.len() == self.capacity {
            self.window.pop_front();
        }
        self.window.push_back(raw);
        pct
    }

    /// Memory footprint in bytes (the provider pays this per tenant).
    pub fn state_bytes(&self) -> usize {
        self.capacity * std::mem::size_of::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transforms::quantile_fit;
    use crate::transforms::ReferenceDistribution;
    use crate::util::rng::Rng;

    #[test]
    fn percentiles_are_uniform_under_stationary_traffic() {
        let mut rp = RollingPercentile::new(5_000);
        let mut rng = Rng::new(1);
        // Fill the window first.
        for _ in 0..5_000 {
            rp.score_and_update(rng.beta(1.2, 12.0));
        }
        let scores: Vec<f64> = (0..20_000)
            .map(|_| rp.score_and_update(rng.beta(1.2, 12.0)))
            .collect();
        let ks = crate::util::stats::ks_distance(&scores, |x| x.clamp(0.0, 1.0));
        assert!(ks < 0.03, "KS = {ks}");
    }

    #[test]
    fn attack_deflates_percentile_of_fixed_raw_score() {
        // The instability the paper contrasts against: the same raw
        // score's percentile sags once the window fills with attack
        // traffic.
        let mut rp = RollingPercentile::new(2_000);
        let mut rng = Rng::new(2);
        for _ in 0..2_000 {
            rp.score_and_update(rng.beta(1.2, 12.0));
        }
        let probe = 0.5;
        let before = rp.window.iter().filter(|&&w| w < probe).count() as f64 / 2_000.0;
        // Attack: 30% of traffic is fraud-shaped (high scores).
        for _ in 0..2_000 {
            let s = if rng.bernoulli(0.30) {
                rng.beta(6.0, 2.0)
            } else {
                rng.beta(1.2, 12.0)
            };
            rp.score_and_update(s);
        }
        let after = rp.window.iter().filter(|&&w| w < probe).count() as f64 / 2_000.0;
        assert!(
            before - after > 0.05,
            "attack should deflate the percentile: {before} -> {after}"
        );
    }

    #[test]
    fn muse_fixed_map_is_stable_under_the_same_attack() {
        // Counterpart: a fixed quantile transformation's output for
        // the same raw score is *identical* regardless of traffic.
        let mut rng = Rng::new(3);
        let pre: Vec<f64> = (0..50_000).map(|_| rng.beta(1.2, 12.0)).collect();
        let refq = ReferenceDistribution::fraud_default().quantile_grid(513);
        let map = quantile_fit::fit_from_scores(&pre, &refq).unwrap();
        let before = map.apply(0.5);
        // ... attack traffic does not touch the map at all:
        let after = map.apply(0.5);
        assert_eq!(before, after);
    }

    #[test]
    fn empty_window_gives_half() {
        let mut rp = RollingPercentile::new(10);
        assert_eq!(rp.score_and_update(0.7), 0.5);
        assert_eq!(rp.len(), 1);
    }

    #[test]
    fn window_is_bounded() {
        let mut rp = RollingPercentile::new(100);
        for i in 0..1_000 {
            rp.score_and_update(i as f64 / 1000.0);
        }
        assert_eq!(rp.len(), 100);
        assert_eq!(rp.state_bytes(), 800);
    }

    #[test]
    fn monotone_in_raw_score_given_fixed_window() {
        let mut rp = RollingPercentile::new(1_000);
        let mut rng = Rng::new(4);
        for _ in 0..1_000 {
            rp.score_and_update(rng.f64());
        }
        let w = rp.window.clone();
        let pct = |raw: f64| w.iter().filter(|&&x| x < raw).count() as f64 / w.len() as f64;
        assert!(pct(0.2) <= pct(0.5) && pct(0.5) <= pct(0.9));
    }
}
