//! KServe-style deployment accounting (paper Section 4): a 1:1
//! mapping between models+transformers and InferenceServices means
//! "serving the same ensemble to multiple clients with unique
//! calibrations requires deploying a separate Inference Service per
//! tenant" — 1:N duplication that can exhaust cluster limits (IPs).
//!
//! This module models that cost analytically (containers, memory, IPs)
//! so the `repro dedup` harness can sweep tenant counts far beyond
//! what we'd want to physically spawn, and contrasts it with MUSE's
//! shared-pool accounting (which *is* physically exercised in
//! `runtime::pool` tests).

use std::collections::BTreeSet;

/// Resource cost of a deployment strategy.
#[derive(Debug, Clone, PartialEq)]
pub struct DeploymentCost {
    pub containers: usize,
    /// One service IP per InferenceService (KServe) or per container
    /// pool entry (MUSE).
    pub service_ips: usize,
    /// Memory estimate in MB (container fixed cost x count).
    pub memory_mb: f64,
}

/// Per-container memory footprint estimate (model weights are tiny
/// here; production containers carry the runtime: ~500MB for a Triton
/// pod is conservative).
pub const CONTAINER_MEMORY_MB: f64 = 500.0;

/// A predictor definition for accounting purposes: its expert models.
pub type PredictorModels = Vec<String>;

/// KServe-style: every predictor (tenant-specific transformation
/// included) becomes its own InferenceService replicating all its
/// models.
pub struct KServeStyleDeployment;

impl KServeStyleDeployment {
    pub fn cost(predictors: &[PredictorModels]) -> DeploymentCost {
        let containers: usize = predictors.iter().map(|p| p.len()).sum();
        DeploymentCost {
            containers,
            service_ips: predictors.len(),
            memory_mb: containers as f64 * CONTAINER_MEMORY_MB,
        }
    }
}

/// MUSE accounting: containers = |union of referenced models|.
pub struct MuseDeployment;

impl MuseDeployment {
    pub fn cost(predictors: &[PredictorModels]) -> DeploymentCost {
        let unique: BTreeSet<&String> = predictors.iter().flatten().collect();
        DeploymentCost {
            containers: unique.len(),
            service_ips: unique.len(),
            memory_mb: unique.len() as f64 * CONTAINER_MEMORY_MB,
        }
    }
}

/// The paper's incremental-update claim (Section 2.2.1): marginal cost
/// of deploying `new` after `existing` = net-new models only.
pub fn marginal_models(existing: &[PredictorModels], new: &PredictorModels) -> usize {
    let have: BTreeSet<&String> = existing.iter().flatten().collect();
    new.iter().filter(|m| !have.contains(m)).count()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(models: &[&str]) -> PredictorModels {
        models.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn fig1_example_costs() {
        // p1 = {m1, m2}, p2 = {m1, m2, m3}.
        let predictors = vec![p(&["m1", "m2"]), p(&["m1", "m2", "m3"])];
        let kserve = KServeStyleDeployment::cost(&predictors);
        let muse = MuseDeployment::cost(&predictors);
        assert_eq!(kserve.containers, 5);
        assert_eq!(muse.containers, 3);
        assert_eq!(marginal_models(&predictors[..1], &predictors[1]), 1);
    }

    #[test]
    fn multi_tenant_gap_grows_linearly() {
        // 100 tenants, each a tenant-specific calibration of the same
        // 8-model ensemble: KServe duplicates everything, MUSE shares.
        let ensemble = p(&["m1", "m2", "m3", "m4", "m5", "m6", "m7", "m8"]);
        let predictors: Vec<PredictorModels> = (0..100).map(|_| ensemble.clone()).collect();
        let kserve = KServeStyleDeployment::cost(&predictors);
        let muse = MuseDeployment::cost(&predictors);
        assert_eq!(kserve.containers, 800);
        assert_eq!(muse.containers, 8);
        assert_eq!(kserve.service_ips, 100);
        assert!(kserve.memory_mb / muse.memory_mb >= 99.0);
    }

    #[test]
    fn disjoint_predictors_have_no_savings() {
        let predictors = vec![p(&["a"]), p(&["b"]), p(&["c"])];
        let kserve = KServeStyleDeployment::cost(&predictors);
        let muse = MuseDeployment::cost(&predictors);
        assert_eq!(kserve.containers, muse.containers);
    }

    #[test]
    fn marginal_cost_of_duplicate_is_zero() {
        let existing = vec![p(&["m1", "m2"])];
        assert_eq!(marginal_models(&existing, &p(&["m1", "m2"])), 0);
        assert_eq!(marginal_models(&[], &p(&["m1"])), 1);
    }

    #[test]
    fn accounting_matches_live_pool() {
        // Cross-check the analytical model against the real pool.
        use crate::runtime::{Manifest, ModelPool};
        use std::path::PathBuf;
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !root.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let pool = ModelPool::new(Manifest::load(root).unwrap());
        let predictors = vec![p(&["m1", "m2"]), p(&["m1", "m2", "m3"])];
        for pred in &predictors {
            for m in pred {
                pool.acquire(m).unwrap();
            }
        }
        let expected = MuseDeployment::cost(&predictors);
        assert_eq!(pool.stats().live_containers, expected.containers);
    }
}
