//! Comparison baselines from the paper's Related Work (Section 4):
//!
//! * `kserve_style` — the 1:1 predictor-to-InferenceService deployment
//!   model whose duplication MUSE's shared containers avoid.
//! * `global_prob` — Stripe-Radar/Kount-style globally-calibrated
//!   probability scores, coupling every tenant to the global threat
//!   landscape.
//! * `rolling_pct` — Sift-style rolling-window percentile scores.

pub mod global_prob;
pub mod kserve_style;
pub mod rolling_pct;

pub use global_prob::GlobalProbabilityScorer;
pub use kserve_style::{DeploymentCost, KServeStyleDeployment};
pub use rolling_pct::RollingPercentile;
