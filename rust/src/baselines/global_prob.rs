//! Stripe-Radar/Kount-style global-probability scoring (Section 4):
//! scores are anchored to a *global* probability of fraud ("a score of
//! 90 implies 90% fraud likelihood"), with the provider periodically
//! recalibrating against the global stream.
//!
//! Failure mode the paper highlights: the tenant's decision volume is
//! coupled to the global threat landscape — an attack on *other*
//! tenants shifts the global calibration and therefore every tenant's
//! alert volume, even if their own traffic is unchanged. MUSE's
//! per-tenant quantile mapping against a fixed reference decouples
//! this.

use crate::transforms::QuantileMap;
use crate::util::stats;
use anyhow::Result;

/// A provider-side global calibrator: maps raw model scores to global
/// fraud probabilities via isotonic-ish binning over the pooled
/// multi-tenant stream, refreshed on `recalibrate`.
pub struct GlobalProbabilityScorer {
    /// Piecewise map raw score -> global P(fraud), refit on the pooled
    /// stream (we reuse QuantileMap machinery with probability knots).
    map: QuantileMap,
}

impl GlobalProbabilityScorer {
    /// Fit from pooled (raw score, label) pairs: equal-mass bins of
    /// the raw score, each mapped to its empirical fraud rate.
    pub fn fit(raw: &[f64], labels: &[f64], bins: usize) -> Result<GlobalProbabilityScorer> {
        assert_eq!(raw.len(), labels.len());
        let mut pairs: Vec<(f64, f64)> =
            raw.iter().cloned().zip(labels.iter().cloned()).collect();
        pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let n = pairs.len();
        let mut knots_x = Vec::with_capacity(bins + 1);
        let mut knots_y = Vec::with_capacity(bins + 1);
        knots_x.push(0.0);
        knots_y.push(0.0);
        let mut running_max = 0.0f64;
        for b in 0..bins {
            let lo = b * n / bins;
            let hi = ((b + 1) * n / bins).max(lo + 1).min(n);
            let chunk = &pairs[lo..hi];
            let x = chunk.last().unwrap().0;
            let prob = chunk.iter().map(|(_, y)| y).sum::<f64>() / chunk.len() as f64;
            // Enforce monotone (isotonic) probabilities.
            running_max = running_max.max(prob);
            knots_x.push(x);
            knots_y.push(running_max);
        }
        knots_x.push(1.0);
        knots_y.push(1.0);
        crate::transforms::quantile_fit::dedup_monotone(&mut knots_x);
        Ok(GlobalProbabilityScorer {
            map: QuantileMap::new(knots_x, knots_y)?,
        })
    }

    /// Score: the globally-calibrated fraud probability.
    pub fn score(&self, raw: f64) -> f64 {
        self.map.apply(raw)
    }

    /// Alert volume (share of events above the probability threshold)
    /// a tenant sees under this calibration.
    pub fn alert_rate(&self, raws: &[f64], prob_threshold: f64) -> f64 {
        if raws.is_empty() {
            return 0.0;
        }
        raws.iter()
            .filter(|&&r| self.score(r) >= prob_threshold)
            .count() as f64
            / raws.len() as f64
    }
}

/// Measure the paper's coupling effect: tenant A's alert-rate change
/// when an attack hits only tenant B and the provider recalibrates
/// globally. Returns (rate_before, rate_after) for tenant A at a fixed
/// probability threshold.
pub fn tenant_coupling_experiment(
    tenant_a_raw: &[f64],
    tenant_b_raw_before: &[f64],
    tenant_b_raw_attack: &[f64],
    labels_a: &[f64],
    labels_b_before: &[f64],
    labels_b_attack: &[f64],
    prob_threshold: f64,
) -> Result<(f64, f64)> {
    let pool =
        |a: &[f64], b: &[f64]| -> Vec<f64> { a.iter().chain(b.iter()).cloned().collect() };
    let before = GlobalProbabilityScorer::fit(
        &pool(tenant_a_raw, tenant_b_raw_before),
        &pool(labels_a, labels_b_before),
        50,
    )?;
    let after = GlobalProbabilityScorer::fit(
        &pool(tenant_a_raw, tenant_b_raw_attack),
        &pool(labels_a, labels_b_attack),
        50,
    )?;
    Ok((
        before.alert_rate(tenant_a_raw, prob_threshold),
        after.alert_rate(tenant_a_raw, prob_threshold),
    ))
}

/// The MUSE counterfactual: tenant A's alert rate under its own fixed
/// quantile transformation is independent of tenant B entirely.
pub fn muse_alert_rate(tenant_a_raw: &[f64], map: &QuantileMap, threshold: f64) -> f64 {
    if tenant_a_raw.is_empty() {
        return 0.0;
    }
    tenant_a_raw
        .iter()
        .filter(|&&r| map.apply(r) >= threshold)
        .count() as f64
        / tenant_a_raw.len() as f64
}

/// Helper: synthesize a raw-score population with the given fraud
/// rate; scores ~ Beta(1.2, 12) for legit, Beta(6, 2) for fraud.
pub fn synth_scores(n: usize, fraud_rate: f64, seed: u64) -> (Vec<f64>, Vec<f64>) {
    let mut rng = crate::util::rng::Rng::new(seed);
    let mut raw = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        let fraud = rng.bernoulli(fraud_rate);
        labels.push(if fraud { 1.0 } else { 0.0 });
        raw.push(if fraud {
            rng.beta(6.0, 2.0)
        } else {
            rng.beta(1.2, 12.0)
        });
    }
    let _ = stats::mean(&raw);
    (raw, labels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transforms::ReferenceDistribution;
    use crate::util::stats::prob_grid;

    #[test]
    fn calibrated_probabilities_are_monotone_and_bounded() {
        let (raw, labels) = synth_scores(50_000, 0.02, 1);
        let g = GlobalProbabilityScorer::fit(&raw, &labels, 40).unwrap();
        let mut prev = -1.0;
        for i in 0..=100 {
            let s = g.score(i as f64 / 100.0);
            assert!((0.0..=1.0).contains(&s));
            assert!(s >= prev - 1e-12);
            prev = s;
        }
    }

    #[test]
    fn calibration_tracks_empirical_rate() {
        let (raw, labels) = synth_scores(200_000, 0.05, 2);
        let g = GlobalProbabilityScorer::fit(&raw, &labels, 50).unwrap();
        // In the upper region the probability must be far above prior.
        assert!(g.score(0.9) > 0.3);
        assert!(g.score(0.05) < 0.05);
    }

    #[test]
    fn attack_on_tenant_b_shifts_tenant_a_alerts() {
        // Tenant A: stable 1.5% fraud. Tenant B: 1.5% -> 15% (attack).
        let (raw_a, lab_a) = synth_scores(60_000, 0.015, 3);
        let (raw_b0, lab_b0) = synth_scores(60_000, 0.015, 4);
        let (raw_b1, lab_b1) = synth_scores(60_000, 0.15, 5);
        let (before, after) = tenant_coupling_experiment(
            &raw_a, &raw_b0, &raw_b1, &lab_a, &lab_b0, &lab_b1, 0.5,
        )
        .unwrap();
        // Global recalibration moves A's alert volume even though A's
        // traffic didn't change (the paper's coupling failure).
        let change = (after - before).abs() / before.max(1e-9);
        assert!(
            change > 0.2,
            "expected >20% coupling shift, got {before} -> {after}"
        );
    }

    #[test]
    fn muse_alert_rate_is_invariant_to_other_tenants() {
        let (raw_a, _) = synth_scores(60_000, 0.015, 6);
        // Tenant A's own fixed map (fit on its own pre-period stream).
        let refq = ReferenceDistribution::fraud_default().quantile_grid(513);
        let map = crate::transforms::quantile_fit::fit_from_scores(&raw_a, &refq).unwrap();
        let r1 = muse_alert_rate(&raw_a, &map, 0.9);
        // ... nothing about tenant B enters this computation at all;
        // re-evaluating after "the attack" yields bitwise-identical
        // rates:
        let r2 = muse_alert_rate(&raw_a, &map, 0.9);
        assert_eq!(r1, r2);
        assert!(r1 > 0.0, "threshold 0.9 should alert on the ref tail");
        let _ = prob_grid(3);
    }
}
