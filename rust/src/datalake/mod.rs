//! The shadow-score Data Lake (paper Fig. 2 / Section 2.5.1).
//!
//! Shadow predictors' responses are mirrored here "without affecting
//! the response returned to the client"; the control plane later reads
//! them back to validate distribution stability and to fit custom
//! quantile transformations. In production this is an object-store
//! sink; here it is an in-memory, thread-safe append-only store with
//! the same query surface.

use std::collections::BTreeMap;
use std::sync::Mutex;

/// One recorded scoring event.
#[derive(Debug, Clone, PartialEq)]
pub struct Record {
    pub tenant: String,
    pub predictor: String,
    /// Final (post-transform) score returned by that predictor.
    pub score: f64,
    /// Pre-quantile (aggregated, calibrated) score — what custom
    /// quantile fits consume.
    pub raw_score: f64,
    /// Whether this was the live response or a shadow mirror.
    pub shadow: bool,
    /// Monotone event index (stands in for event time).
    pub seq: u64,
}

#[derive(Default)]
struct Inner {
    records: Vec<Record>,
    seq: u64,
}

/// Append-only, thread-safe data lake.
#[derive(Default)]
pub struct DataLake {
    inner: Mutex<Inner>,
}

impl DataLake {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn append(&self, tenant: &str, predictor: &str, score: f64, raw_score: f64, shadow: bool) {
        let mut inner = self.inner.lock().unwrap();
        let seq = inner.seq;
        inner.seq += 1;
        inner.records.push(Record {
            tenant: tenant.to_string(),
            predictor: predictor.to_string(),
            score,
            raw_score,
            shadow,
            seq,
        });
    }

    /// Append a whole scored batch (one lock acquisition, contiguous
    /// sequence numbers) — the batch scoring path's sink.
    pub fn append_batch(
        &self,
        tenant: &str,
        predictor: &str,
        scores: &[f64],
        raw_scores: &[f64],
        shadow: bool,
    ) {
        debug_assert_eq!(scores.len(), raw_scores.len());
        let mut inner = self.inner.lock().unwrap();
        inner.records.reserve(scores.len());
        for (&score, &raw_score) in scores.iter().zip(raw_scores) {
            let seq = inner.seq;
            inner.seq += 1;
            inner.records.push(Record {
                tenant: tenant.to_string(),
                predictor: predictor.to_string(),
                score,
                raw_score,
                shadow,
                seq,
            });
        }
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Raw (pre-quantile) scores for a tenant/predictor pair — the
    /// input to a custom `T^Q` fit (Section 2.3.3).
    pub fn raw_scores(&self, tenant: &str, predictor: &str) -> Vec<f64> {
        self.inner
            .lock()
            .unwrap()
            .records
            .iter()
            .filter(|r| r.tenant == tenant && r.predictor == predictor)
            .map(|r| r.raw_score)
            .collect()
    }

    /// Final scores (for distribution-stability validation).
    pub fn final_scores(&self, tenant: &str, predictor: &str) -> Vec<f64> {
        self.inner
            .lock()
            .unwrap()
            .records
            .iter()
            .filter(|r| r.tenant == tenant && r.predictor == predictor)
            .map(|r| r.score)
            .collect()
    }

    /// Count of records per (tenant, predictor, shadow-flag).
    pub fn counts(&self) -> BTreeMap<(String, String, bool), usize> {
        let mut out = BTreeMap::new();
        for r in self.inner.lock().unwrap().records.iter() {
            *out.entry((r.tenant.clone(), r.predictor.clone(), r.shadow))
                .or_insert(0) += 1;
        }
        out
    }

    /// Drop all records for a predictor (after decommissioning).
    pub fn purge_predictor(&self, predictor: &str) -> usize {
        let mut inner = self.inner.lock().unwrap();
        let before = inner.records.len();
        inner.records.retain(|r| r.predictor != predictor);
        before - inner.records.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn append_and_query() {
        let lake = DataLake::new();
        lake.append("bank1", "p1", 0.9, 0.12, false);
        lake.append("bank1", "p2", 0.8, 0.10, true);
        lake.append("bank2", "p1", 0.7, 0.08, false);
        assert_eq!(lake.len(), 3);
        assert_eq!(lake.raw_scores("bank1", "p1"), vec![0.12]);
        assert_eq!(lake.final_scores("bank1", "p2"), vec![0.8]);
        assert!(lake.raw_scores("bank3", "p1").is_empty());
    }

    #[test]
    fn append_batch_matches_sequential_appends() {
        let a = DataLake::new();
        let b = DataLake::new();
        let finals = [0.9, 0.8, 0.7];
        let raws = [0.12, 0.10, 0.08];
        a.append_batch("t", "p", &finals, &raws, true);
        for (f, r) in finals.iter().zip(&raws) {
            b.append("t", "p", *f, *r, true);
        }
        assert_eq!(a.len(), b.len());
        assert_eq!(a.final_scores("t", "p"), b.final_scores("t", "p"));
        assert_eq!(a.raw_scores("t", "p"), b.raw_scores("t", "p"));
        let inner = a.inner.lock().unwrap();
        for w in inner.records.windows(2) {
            assert_eq!(w[1].seq, w[0].seq + 1, "batch seq must stay contiguous");
        }
    }

    #[test]
    fn seq_is_monotone() {
        let lake = DataLake::new();
        for i in 0..10 {
            lake.append("t", "p", i as f64, 0.0, false);
        }
        let inner = lake.inner.lock().unwrap();
        for w in inner.records.windows(2) {
            assert!(w[1].seq > w[0].seq);
        }
    }

    #[test]
    fn counts_split_shadow_and_live() {
        let lake = DataLake::new();
        lake.append("t", "p", 0.1, 0.1, false);
        lake.append("t", "p", 0.2, 0.2, true);
        lake.append("t", "p", 0.3, 0.3, true);
        let counts = lake.counts();
        assert_eq!(counts[&("t".into(), "p".into(), false)], 1);
        assert_eq!(counts[&("t".into(), "p".into(), true)], 2);
    }

    #[test]
    fn purge_removes_only_target() {
        let lake = DataLake::new();
        lake.append("t", "old", 0.1, 0.1, false);
        lake.append("t", "new", 0.2, 0.2, false);
        assert_eq!(lake.purge_predictor("old"), 1);
        assert_eq!(lake.len(), 1);
        assert_eq!(lake.raw_scores("t", "new").len(), 1);
    }

    #[test]
    fn concurrent_appends() {
        use std::sync::Arc;
        let lake = Arc::new(DataLake::new());
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let lake = Arc::clone(&lake);
                std::thread::spawn(move || {
                    for i in 0..500 {
                        lake.append(&format!("t{t}"), "p", i as f64 / 500.0, 0.0, false);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(lake.len(), 4000);
    }
}
