//! The shadow-score Data Lake (paper Fig. 2 / Section 2.5.1).
//!
//! Shadow predictors' responses are mirrored here "without affecting
//! the response returned to the client"; the control plane later reads
//! them back to validate distribution stability and to fit custom
//! quantile transformations. In production this is an object-store
//! sink; here it is an in-memory, thread-safe store with the same
//! query surface.
//!
//! Retention: the lake is a bounded ring
//! ([`DataLake::with_capacity`]) — once `cap` records are held, each
//! append evicts the oldest. Long simulator runs used to grow the
//! lake without bound; now that `T^Q` refits consume lifecycle
//! sketches instead of replaying full history, the lake only needs
//! enough depth for shadow validation and the repro harnesses.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::Mutex;

/// One recorded scoring event.
#[derive(Debug, Clone, PartialEq)]
pub struct Record {
    pub tenant: String,
    pub predictor: String,
    /// Final (post-transform) score returned by that predictor.
    pub score: f64,
    /// Pre-quantile (aggregated, calibrated) score — what custom
    /// quantile fits consume.
    pub raw_score: f64,
    /// Whether this was the live response or a shadow mirror.
    pub shadow: bool,
    /// Monotone event index (stands in for event time).
    pub seq: u64,
}

#[derive(Default)]
struct Inner {
    records: VecDeque<Record>,
    seq: u64,
    /// Retained records per tenant → predictor, maintained
    /// incrementally so `count_for` is O(1) — the lifecycle
    /// controller polls it every tick while a shadow accumulates
    /// mirrors, and an O(records) scan here would hold the same mutex
    /// the scoring hot path's `append` needs.
    counts: HashMap<String, HashMap<String, usize>>,
}

impl Inner {
    #[inline]
    fn push(&mut self, record: Record, cap: usize) {
        if cap > 0 && self.records.len() >= cap {
            if let Some(old) = self.records.pop_front() {
                self.dec(&old.tenant, &old.predictor);
            }
        }
        // Probe with &str (no allocation on the established path);
        // clone only the first time a pair appears.
        match self.counts.get_mut(&record.tenant) {
            Some(m) => match m.get_mut(&record.predictor) {
                Some(c) => *c += 1,
                None => {
                    m.insert(record.predictor.clone(), 1);
                }
            },
            None => {
                let mut m = HashMap::new();
                m.insert(record.predictor.clone(), 1);
                self.counts.insert(record.tenant.clone(), m);
            }
        }
        self.records.push_back(record);
    }

    #[inline]
    fn dec(&mut self, tenant: &str, predictor: &str) {
        if let Some(m) = self.counts.get_mut(tenant) {
            if let Some(c) = m.get_mut(predictor) {
                *c = c.saturating_sub(1);
            }
        }
    }
}

/// Thread-safe data lake: append-mostly ring with a retention cap.
#[derive(Default)]
pub struct DataLake {
    inner: Mutex<Inner>,
    /// Max records retained; 0 = unbounded.
    cap: usize,
}

impl DataLake {
    /// Unbounded lake (tests, short harnesses).
    pub fn new() -> Self {
        Self::default()
    }

    /// Bounded lake: once `cap` records are held, each append evicts
    /// the oldest record (0 = unbounded).
    pub fn with_capacity(cap: usize) -> Self {
        DataLake {
            inner: Mutex::new(Inner::default()),
            cap,
        }
    }

    /// The configured retention cap (0 = unbounded).
    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn append(&self, tenant: &str, predictor: &str, score: f64, raw_score: f64, shadow: bool) {
        let mut inner = self.inner.lock().unwrap();
        let seq = inner.seq;
        inner.seq += 1;
        let record = Record {
            tenant: tenant.to_string(),
            predictor: predictor.to_string(),
            score,
            raw_score,
            shadow,
            seq,
        };
        inner.push(record, self.cap);
    }

    /// Append a whole scored batch (one lock acquisition, contiguous
    /// sequence numbers) — the batch scoring path's sink.
    pub fn append_batch(
        &self,
        tenant: &str,
        predictor: &str,
        scores: &[f64],
        raw_scores: &[f64],
        shadow: bool,
    ) {
        debug_assert_eq!(scores.len(), raw_scores.len());
        let mut inner = self.inner.lock().unwrap();
        for (&score, &raw_score) in scores.iter().zip(raw_scores) {
            let seq = inner.seq;
            inner.seq += 1;
            let record = Record {
                tenant: tenant.to_string(),
                predictor: predictor.to_string(),
                score,
                raw_score,
                shadow,
                seq,
            };
            inner.push(record, self.cap);
        }
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Raw (pre-quantile) scores for a tenant/predictor pair — the
    /// input to a custom `T^Q` fit (Section 2.3.3).
    pub fn raw_scores(&self, tenant: &str, predictor: &str) -> Vec<f64> {
        self.inner
            .lock()
            .unwrap()
            .records
            .iter()
            .filter(|r| r.tenant == tenant && r.predictor == predictor)
            .map(|r| r.raw_score)
            .collect()
    }

    /// Final scores (for distribution-stability validation).
    pub fn final_scores(&self, tenant: &str, predictor: &str) -> Vec<f64> {
        self.inner
            .lock()
            .unwrap()
            .records
            .iter()
            .filter(|r| r.tenant == tenant && r.predictor == predictor)
            .map(|r| r.score)
            .collect()
    }

    /// Number of retained records for a tenant/predictor pair — O(1)
    /// from the incrementally maintained per-pair counts (the
    /// lifecycle controller polls this every tick while a shadow
    /// accumulates mirrors; scanning the ring here would stall
    /// hot-path appends behind the same mutex).
    pub fn count_for(&self, tenant: &str, predictor: &str) -> usize {
        self.inner
            .lock()
            .unwrap()
            .counts
            .get(tenant)
            .and_then(|m| m.get(predictor))
            .copied()
            .unwrap_or(0)
    }

    /// Count of records per (tenant, predictor, shadow-flag).
    pub fn counts(&self) -> BTreeMap<(String, String, bool), usize> {
        let mut out = BTreeMap::new();
        for r in self.inner.lock().unwrap().records.iter() {
            *out.entry((r.tenant.clone(), r.predictor.clone(), r.shadow))
                .or_insert(0) += 1;
        }
        out
    }

    /// Drop all records for a predictor (after decommissioning).
    pub fn purge_predictor(&self, predictor: &str) -> usize {
        let mut inner = self.inner.lock().unwrap();
        let before = inner.records.len();
        inner.records.retain(|r| r.predictor != predictor);
        for m in inner.counts.values_mut() {
            m.remove(predictor);
        }
        before - inner.records.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn append_and_query() {
        let lake = DataLake::new();
        lake.append("bank1", "p1", 0.9, 0.12, false);
        lake.append("bank1", "p2", 0.8, 0.10, true);
        lake.append("bank2", "p1", 0.7, 0.08, false);
        assert_eq!(lake.len(), 3);
        assert_eq!(lake.raw_scores("bank1", "p1"), vec![0.12]);
        assert_eq!(lake.final_scores("bank1", "p2"), vec![0.8]);
        assert!(lake.raw_scores("bank3", "p1").is_empty());
    }

    #[test]
    fn append_batch_matches_sequential_appends() {
        let a = DataLake::new();
        let b = DataLake::new();
        let finals = [0.9, 0.8, 0.7];
        let raws = [0.12, 0.10, 0.08];
        a.append_batch("t", "p", &finals, &raws, true);
        for (f, r) in finals.iter().zip(&raws) {
            b.append("t", "p", *f, *r, true);
        }
        assert_eq!(a.len(), b.len());
        assert_eq!(a.final_scores("t", "p"), b.final_scores("t", "p"));
        assert_eq!(a.raw_scores("t", "p"), b.raw_scores("t", "p"));
        let inner = a.inner.lock().unwrap();
        for (prev, next) in inner.records.iter().zip(inner.records.iter().skip(1)) {
            assert_eq!(next.seq, prev.seq + 1, "batch seq must stay contiguous");
        }
    }

    #[test]
    fn seq_is_monotone() {
        let lake = DataLake::new();
        for i in 0..10 {
            lake.append("t", "p", i as f64, 0.0, false);
        }
        let inner = lake.inner.lock().unwrap();
        for (prev, next) in inner.records.iter().zip(inner.records.iter().skip(1)) {
            assert!(next.seq > prev.seq);
        }
    }

    #[test]
    fn retention_cap_evicts_oldest() {
        let lake = DataLake::with_capacity(100);
        assert_eq!(lake.capacity(), 100);
        for i in 0..350 {
            lake.append("t", "p", i as f64 / 350.0, i as f64, false);
        }
        assert_eq!(lake.len(), 100, "cap must bound the lake");
        // Survivors are the newest 100, in order, seq intact.
        let raws = lake.raw_scores("t", "p");
        assert_eq!(raws[0], 250.0);
        assert_eq!(raws[99], 349.0);
        let inner = lake.inner.lock().unwrap();
        assert_eq!(inner.records.front().unwrap().seq, 250);
        assert_eq!(inner.records.back().unwrap().seq, 349);
    }

    #[test]
    fn retention_cap_applies_to_batches() {
        let lake = DataLake::with_capacity(64);
        let scores: Vec<f64> = (0..50).map(|i| i as f64).collect();
        lake.append_batch("t", "p", &scores, &scores, false);
        lake.append_batch("t", "p", &scores, &scores, true);
        assert_eq!(lake.len(), 64);
        // Oldest live records evicted first; all 50 shadow records
        // (newest) retained plus the last 14 live ones.
        let counts = lake.counts();
        assert_eq!(counts[&("t".into(), "p".into(), true)], 50);
        assert_eq!(counts[&("t".into(), "p".into(), false)], 14);
        assert_eq!(lake.count_for("t", "p"), 64);
    }

    #[test]
    fn zero_capacity_means_unbounded() {
        let lake = DataLake::with_capacity(0);
        for i in 0..5000 {
            lake.append("t", "p", 0.0, i as f64, false);
        }
        assert_eq!(lake.len(), 5000);
    }

    #[test]
    fn count_for_filters_pairs() {
        let lake = DataLake::new();
        lake.append("a", "p", 0.1, 0.1, false);
        lake.append("a", "q", 0.2, 0.2, false);
        lake.append("b", "p", 0.3, 0.3, true);
        assert_eq!(lake.count_for("a", "p"), 1);
        assert_eq!(lake.count_for("a", "q"), 1);
        assert_eq!(lake.count_for("b", "p"), 1);
        assert_eq!(lake.count_for("c", "p"), 0);
    }

    #[test]
    fn counts_split_shadow_and_live() {
        let lake = DataLake::new();
        lake.append("t", "p", 0.1, 0.1, false);
        lake.append("t", "p", 0.2, 0.2, true);
        lake.append("t", "p", 0.3, 0.3, true);
        let counts = lake.counts();
        assert_eq!(counts[&("t".into(), "p".into(), false)], 1);
        assert_eq!(counts[&("t".into(), "p".into(), true)], 2);
    }

    #[test]
    fn purge_removes_only_target() {
        let lake = DataLake::new();
        lake.append("t", "old", 0.1, 0.1, false);
        lake.append("t", "new", 0.2, 0.2, false);
        assert_eq!(lake.purge_predictor("old"), 1);
        assert_eq!(lake.len(), 1);
        assert_eq!(lake.raw_scores("t", "new").len(), 1);
        // The O(1) pair counts track the purge.
        assert_eq!(lake.count_for("t", "old"), 0);
        assert_eq!(lake.count_for("t", "new"), 1);
    }

    #[test]
    fn count_for_stays_consistent_under_eviction() {
        // The incrementally maintained counts must agree with a full
        // scan after interleaved appends from two pairs roll through
        // the retention cap.
        let lake = DataLake::with_capacity(50);
        for i in 0..200 {
            let pred = if i % 3 == 0 { "a" } else { "b" };
            lake.append("t", pred, 0.0, i as f64, false);
        }
        let scan_a = lake.raw_scores("t", "a").len();
        let scan_b = lake.raw_scores("t", "b").len();
        assert_eq!(lake.count_for("t", "a"), scan_a);
        assert_eq!(lake.count_for("t", "b"), scan_b);
        assert_eq!(scan_a + scan_b, 50);
    }

    #[test]
    fn concurrent_appends() {
        use std::sync::Arc;
        let lake = Arc::new(DataLake::new());
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let lake = Arc::clone(&lake);
                std::thread::spawn(move || {
                    for i in 0..500 {
                        lake.append(&format!("t{t}"), "p", i as f64 / 500.0, 0.0, false);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(lake.len(), 4000);
    }
}
