//! The shadow-score Data Lake (paper Fig. 2 / Section 2.5.1).
//!
//! Shadow predictors' responses are mirrored here "without affecting
//! the response returned to the client"; the control plane later reads
//! them back to validate distribution stability and to fit custom
//! quantile transformations. In production this is an object-store
//! sink; here it is an in-memory, thread-safe store with the same
//! query surface.
//!
//! # Lock-free sharded ring (the observation-plane hot path)
//!
//! Every scored event appends here, so the lake's write path is part
//! of the engine's per-event cost structure. The previous
//! implementation serialized all appends (and the lifecycle
//! controller's `count_for` polls) on one `Mutex<Inner>`; this one
//! performs **zero mutex/rwlock acquisitions and zero heap
//! allocations** on the established append path:
//!
//! * **One global sequence claim.** `next_seq.fetch_add(1)` assigns
//!   each record a monotone sequence number — the only cross-thread
//!   coordination on the write path (a single wait-free atomic, vs.
//!   the old lock + critical section). The sequence number
//!   deterministically derives everything else: the stripe
//!   (`seq % shards`), the slot within the stripe's ring, and the
//!   ring lap.
//! * **Striped slot arrays.** Records land in `server.lakeShards`
//!   stripes of fixed-size slot rings, so consecutive claims write to
//!   different stripes (different cache lines/pages) instead of
//!   contending on one deque. Stripe capacities partition the total
//!   retention cap exactly, so `len()` can never exceed
//!   `server.lakeMaxRecords`.
//! * **Per-slot seqlock.** Each slot carries a version word encoding
//!   `(lap, state)`; writers claim with a CAS, publish with a
//!   monotone `fetch_max`, and readers (control-plane rate) retry the
//!   handful of slots they observe mid-write. Versions only move
//!   forward, so reads are never torn.
//! * **Interned pair slots, sharded.** `(tenant, predictor)` pairs
//!   are interned once into slots carrying an `AtomicU64`
//!   retained-record count, registered in two places: a name index
//!   **sharded by tenant hash** (each shard a
//!   [`SnapCell`](crate::util::swap::SnapCell) of `Arc`'d per-tenant
//!   maps, so a first touch republishes one shard shallowly — never a
//!   global table) and an id-keyed
//!   [`HandleSlab`](crate::util::slab::HandleSlab) whose publication
//!   clones one constant-size segment. The hot path probes the
//!   published shard by `&str` (no allocation) and bumps one atomic;
//!   `count_for` — polled every lifecycle tick while a shadow
//!   accumulates mirrors — is one wait-free probe + load, O(1), and
//!   never touches the write path. Eviction resolves the outgoing
//!   record's pair by id through the slab, so append paths carry no
//!   table snapshot at all.
//! * **Lazy segments.** Stripe rings allocate 4096-slot segments on
//!   first touch, so a default-capacity (2^20 records) lake costs
//!   memory proportional to its high-water mark, not its cap.
//!
//! Eviction is per-stripe ring overwrite: once a stripe's ring is
//! full, each claim overwrites (and un-counts) the oldest record *in
//! that stripe*. Because claims round-robin the stripes, the retained
//! set tracks global FIFO to within one round (`shards` records) —
//! `len()` and the per-pair counts stay exact (see the eviction
//! property tests), only the survivor *boundary* is approximate.
//!
//! ## Accepted degradation under pathological stalls
//!
//! A writer that claims a sequence number and then sleeps for an
//! entire ring lap (`lakeMaxRecords` subsequent appends — minutes at
//! full throughput) can race the writer that laps it. The protocol
//! bounds the damage to that one slot: the lapping writer spins
//! briefly, then force-claims (counted in [`DataLake::forced_overwrites`]);
//! the stalled writer detects the lap on wake and drops its record
//! (counted in [`DataLake::lost_appends`]). Both counters staying at
//! zero — which every test asserts — means the fast path ran
//! uncontested. This mirrors the bounded-loss contract of
//! `lifecycle::ScoreFeed`: an observability store degrades by
//! dropping a sample, never by blocking the data plane.

use crate::util::slab::HandleSlab;
use crate::util::swap::SnapCell;
use std::collections::{BTreeMap, HashMap};
use std::ptr;
use std::sync::atomic::{AtomicPtr, AtomicU32, AtomicU64, Ordering};
use std::sync::Arc;

/// One recorded scoring event (the read-side view; storage is packed
/// into atomic slots internally).
#[derive(Debug, Clone, PartialEq)]
pub struct Record {
    pub tenant: String,
    pub predictor: String,
    /// Final (post-transform) score returned by that predictor.
    pub score: f64,
    /// Pre-quantile (aggregated, calibrated) score — what custom
    /// quantile fits consume.
    pub raw_score: f64,
    /// Whether this was the live response or a shadow mirror.
    pub shadow: bool,
    /// Monotone event index (stands in for event time).
    pub seq: u64,
}

/// Capacity a `lakeMaxRecords: 0` ("default") lake resolves to.
/// Matches the order of the `server.lakeMaxRecords` default so
/// harness lakes built with [`DataLake::new`] behave like a
/// default-configured server. (The sharded rings are fixed-geometry,
/// so a truly unbounded lake no longer exists; config validation
/// applies the same resolution.)
pub const DEFAULT_CAPACITY: usize = 1 << 20;

/// Default stripe count ([`DataLake::with_capacity`]); servers set it
/// via `server.lakeShards`.
pub const DEFAULT_SHARDS: usize = 8;

/// Slots per lazily-allocated ring segment (2^12).
const SEG_BITS: usize = 12;
const SEG: usize = 1 << SEG_BITS;

/// Spins before a lapping writer force-claims a slot whose previous
/// writer is still mid-write (see the module docs).
const FORCE_SPINS: u32 = 4096;

// Slot version states for ring lap `L` (versions are monotone, so a
// reader can never observe a state regress):
//   0            empty (never written)
//   4L + 1       claimed, payload being written
//   4L + 2       stable, live
//   4L + 3       stable, tombstoned by `purge_predictor`
#[inline]
fn v_writing(lap: u64) -> u64 {
    4 * lap + 1
}
#[inline]
fn v_live(lap: u64) -> u64 {
    4 * lap + 2
}
#[inline]
fn v_dead(lap: u64) -> u64 {
    4 * lap + 3
}

/// One ring slot: a seqlock version plus the packed record payload.
struct Slot {
    version: AtomicU64,
    /// `pair_id << 1 | shadow`.
    meta: AtomicU64,
    /// `f64::to_bits` of the final score.
    score: AtomicU64,
    /// `f64::to_bits` of the raw (pre-quantile) score.
    raw: AtomicU64,
}

impl Slot {
    fn empty() -> Slot {
        Slot {
            version: AtomicU64::new(0),
            meta: AtomicU64::new(0),
            score: AtomicU64::new(0),
            raw: AtomicU64::new(0),
        }
    }
}

/// One stripe: a fixed-capacity ring of slots, segment-allocated on
/// first touch.
struct Stripe {
    /// Ring capacity of this stripe (the stripe's share of the total
    /// retention cap; stripe shares partition the cap exactly).
    cap: usize,
    /// `segments[i]` points at `seg_len(i)` heap slots, or null while
    /// untouched. Thin pointers; lengths are recomputed from `cap`.
    segments: Box<[AtomicPtr<Slot>]>,
}

impl Stripe {
    fn new(cap: usize) -> Stripe {
        debug_assert!(cap >= 1);
        let n_segs = cap.div_ceil(SEG);
        Stripe {
            cap,
            segments: (0..n_segs).map(|_| AtomicPtr::new(ptr::null_mut())).collect(),
        }
    }

    #[inline]
    fn seg_len(&self, seg: usize) -> usize {
        (self.cap - (seg << SEG_BITS)).min(SEG)
    }

    /// The slot at ring position `pos`, allocating its segment on
    /// first touch (CAS race: the loser frees its allocation and uses
    /// the winner's — no locks).
    #[inline]
    fn slot(&self, pos: usize) -> &Slot {
        debug_assert!(pos < self.cap);
        let seg = pos >> SEG_BITS;
        let off = pos & (SEG - 1);
        let mut p = self.segments[seg].load(Ordering::Acquire);
        if p.is_null() {
            p = self.alloc_segment(seg);
        }
        // SAFETY: `p` points at `seg_len(seg)` slots allocated by
        // `alloc_segment` and never freed before the stripe drops;
        // `off < seg_len(seg)` because `pos < cap`.
        unsafe { &*p.add(off) }
    }

    #[cold]
    fn alloc_segment(&self, seg: usize) -> *mut Slot {
        let n = self.seg_len(seg);
        let boxed: Box<[Slot]> = (0..n).map(|_| Slot::empty()).collect();
        let raw = Box::into_raw(boxed) as *mut Slot;
        match self.segments[seg].compare_exchange(
            ptr::null_mut(),
            raw,
            Ordering::AcqRel,
            Ordering::Acquire,
        ) {
            Ok(_) => raw,
            Err(winner) => {
                // SAFETY: `raw` is the allocation we just made and
                // lost the publication race with; nobody else saw it.
                unsafe { drop(Box::from_raw(ptr::slice_from_raw_parts_mut(raw, n))) };
                winner
            }
        }
    }
}

impl Drop for Stripe {
    fn drop(&mut self) {
        for (i, seg) in self.segments.iter().enumerate() {
            let p = seg.load(Ordering::Acquire);
            if !p.is_null() {
                // SAFETY: published exactly once by `alloc_segment`
                // with length `seg_len(i)`; we have exclusive access
                // in drop.
                unsafe { drop(Box::from_raw(ptr::slice_from_raw_parts_mut(p, self.seg_len(i)))) };
            }
        }
    }
}

/// An interned `(tenant, predictor)` pair: stable id (the slab index)
/// plus the retained-record count the hot path maintains and
/// `count_for` reads — O(1), wait-free on both sides.
struct PairSlot {
    tenant: Arc<str>,
    predictor: Arc<str>,
    id: u32,
    count: AtomicU64,
}

/// One shard of the name-keyed pair index: tenant → (predictor →
/// slot). Inner per-tenant maps are `Arc`'d so republishing a shard
/// clones only its outer entries (shallow, O(tenants-in-shard) `Arc`
/// bumps) plus the one touched tenant's inner map (a handful of
/// predictors) — never every pair in the lake.
type TenantPairs = HashMap<Arc<str>, Arc<HashMap<Arc<str>, Arc<PairSlot>>>>;

/// Shard count for the pair name index and the id slab — the same
/// scale-out factor the tenant interner defaults to
/// (`coordinator::tenants::DEFAULT_NAME_SHARDS`), kept as a local
/// constant so the observation plane does not depend on the
/// coordinator layer.
const PAIR_SHARDS: usize = 16;

/// FNV-1a over the tenant name — one cheap pass to pick the owning
/// shard (the shard map re-hashes internally for its probe; same
/// idiom as the tenant interner).
#[inline]
fn pair_shard_hash(name: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// An opaque, cacheable resolution of one `(tenant, predictor)` pair:
/// [`DataLake::append_ref`] / [`DataLake::append_batch_ref`] take it
/// instead of two `&str` keys, turning the hot path's two hashmap
/// probes into one slab-index + pointer-identity check. The engine's
/// per-predictor tenant routes (`coordinator::snapshot::TenantRoute`)
/// resolve one per (tenant, predictor) lifetime and reuse it forever
/// — the pair registry is grow-only and ids are never reused, so a
/// ref cannot go stale; the identity check is cheap insurance should
/// that invariant ever change.
#[derive(Clone)]
pub struct PairRef {
    slot: Arc<PairSlot>,
}

/// Thread-safe data lake: sharded append-mostly rings with a global
/// retention cap. See the module docs for the concurrency contract.
pub struct DataLake {
    /// Retention cap as configured (0 = default capacity).
    declared_cap: usize,
    /// Effective total capacity (>= 1); stripe caps partition it.
    cap: usize,
    stripes: Box<[Stripe]>,
    /// Global append counter; the claimed value *is* the record's seq.
    next_seq: AtomicU64,
    /// Tombstoned records still occupying a slot (purged but not yet
    /// overwritten by a later lap).
    dead: AtomicU64,
    /// Diagnostic: slots force-claimed over a stalled prior writer.
    forced: AtomicU64,
    /// Diagnostic: appends dropped after losing a full-lap race.
    lost: AtomicU64,
    /// Name-keyed pair index, sharded by tenant hash; each shard
    /// publishes copy-on-write independently (see [`TenantPairs`]).
    pair_shards: Box<[SnapCell<TenantPairs>]>,
    /// Id → slot registry on the slab substrate: publishing a new
    /// pair clones one constant-size segment, and evict/scan paths
    /// resolve ids through it wait-free with no table snapshot.
    pair_slab: HandleSlab<Arc<PairSlot>>,
    /// Next pair id. Monotone: ids are never reused.
    next_pair_id: AtomicU32,
}

impl Default for DataLake {
    fn default() -> Self {
        Self::new()
    }
}

impl DataLake {
    /// Default-capacity lake (tests, short harnesses).
    pub fn new() -> Self {
        Self::with_capacity(0)
    }

    /// Bounded lake with [`DEFAULT_SHARDS`] stripes: once `cap`
    /// records are held, each append evicts the oldest record in its
    /// stripe (0 = default capacity, 2^20).
    pub fn with_capacity(cap: usize) -> Self {
        Self::with_shards(cap, DEFAULT_SHARDS)
    }

    /// Bounded lake with an explicit stripe count
    /// (`server.lakeShards`). The stripe count is clamped to
    /// `[1, cap]` so every stripe owns at least one slot.
    pub fn with_shards(cap: usize, shards: usize) -> Self {
        let declared_cap = cap;
        let cap = if cap == 0 { DEFAULT_CAPACITY } else { cap };
        let shards = shards.clamp(1, cap);
        let base = cap / shards;
        let extra = cap % shards;
        DataLake {
            declared_cap,
            cap,
            stripes: (0..shards)
                .map(|s| Stripe::new(base + usize::from(s < extra)))
                .collect(),
            next_seq: AtomicU64::new(0),
            dead: AtomicU64::new(0),
            forced: AtomicU64::new(0),
            lost: AtomicU64::new(0),
            pair_shards: (0..PAIR_SHARDS)
                .map(|_| SnapCell::new(Arc::new(TenantPairs::new())))
                .collect(),
            pair_slab: HandleSlab::with_shards(PAIR_SHARDS),
            next_pair_id: AtomicU32::new(0),
        }
    }

    /// The configured retention cap (0 = default capacity; see
    /// [`DataLake::effective_capacity`] for the resolved bound).
    pub fn capacity(&self) -> usize {
        self.declared_cap
    }

    /// The resolved retention bound `len()` can never exceed.
    pub fn effective_capacity(&self) -> usize {
        self.cap
    }

    /// Number of ring stripes.
    pub fn shards(&self) -> usize {
        self.stripes.len()
    }

    /// Slots force-claimed over a stalled writer (see module docs);
    /// 0 in every healthy run.
    pub fn forced_overwrites(&self) -> u64 {
        self.forced.load(Ordering::Relaxed)
    }

    /// Appends dropped after losing a full-lap race; 0 in every
    /// healthy run.
    pub fn lost_appends(&self) -> u64 {
        self.lost.load(Ordering::Relaxed)
    }

    // ---------------------------------------------------------------
    // Write path
    // ---------------------------------------------------------------

    /// Append one record. Hot path: one pair-shard load + probe, one
    /// global `fetch_add`, one slot claim/publish, one pair-count
    /// bump — no mutex, no allocation once the pair is interned.
    pub fn append(&self, tenant: &str, predictor: &str, score: f64, raw_score: f64, shadow: bool) {
        let pair = self.pair_slot(tenant, predictor);
        let seq = self.next_seq.fetch_add(1, Ordering::Relaxed);
        self.write_record(&pair, seq, score, raw_score, shadow);
    }

    /// Append a whole scored batch: the pair resolves once and the
    /// sequence block is claimed with a single `fetch_add`, so batch
    /// records keep contiguous sequence numbers — the batch scoring
    /// path's sink.
    pub fn append_batch(
        &self,
        tenant: &str,
        predictor: &str,
        scores: &[f64],
        raw_scores: &[f64],
        shadow: bool,
    ) {
        debug_assert_eq!(scores.len(), raw_scores.len());
        if scores.is_empty() {
            return;
        }
        let pair = self.pair_slot(tenant, predictor);
        let base = self.next_seq.fetch_add(scores.len() as u64, Ordering::Relaxed);
        for (i, (&score, &raw)) in scores.iter().zip(raw_scores).enumerate() {
            self.write_record(&pair, base + i as u64, score, raw, shadow);
        }
    }

    /// Resolve (or intern) a cacheable pair ref for
    /// `(tenant, predictor)` — the control-plane half of the
    /// string-free append path (see [`PairRef`]).
    pub fn pair_ref(&self, tenant: &str, predictor: &str) -> PairRef {
        PairRef {
            slot: self.pair_slot(tenant, predictor),
        }
    }

    /// Append one record through a cached [`PairRef`]: identical
    /// side effects to [`DataLake::append`], zero string hashing.
    pub fn append_ref(&self, pair: &PairRef, score: f64, raw_score: f64, shadow: bool) {
        let seq = self.next_seq.fetch_add(1, Ordering::Relaxed);
        if self.ref_is_current(&pair.slot) {
            self.write_record(&pair.slot, seq, score, raw_score, shadow);
        } else {
            self.append_ref_stale(pair, seq, score, raw_score, shadow);
        }
    }

    /// Append a whole scored batch through a cached [`PairRef`]:
    /// identical side effects to [`DataLake::append_batch`] (one
    /// contiguous sequence block), zero string hashing.
    pub fn append_batch_ref(
        &self,
        pair: &PairRef,
        scores: &[f64],
        raw_scores: &[f64],
        shadow: bool,
    ) {
        debug_assert_eq!(scores.len(), raw_scores.len());
        if scores.is_empty() {
            return;
        }
        let base = self.next_seq.fetch_add(scores.len() as u64, Ordering::Relaxed);
        if self.ref_is_current(&pair.slot) {
            for (i, (&score, &raw)) in scores.iter().zip(raw_scores).enumerate() {
                self.write_record(&pair.slot, base + i as u64, score, raw, shadow);
            }
        } else {
            let slot = self.pair_slot(&pair.slot.tenant, &pair.slot.predictor);
            for (i, (&score, &raw)) in scores.iter().zip(raw_scores).enumerate() {
                self.write_record(&slot, base + i as u64, score, raw, shadow);
            }
        }
    }

    /// Whether a cached ref's slot is the one the registry holds
    /// under its id (always true today — the registry is grow-only).
    #[inline]
    fn ref_is_current(&self, slot: &Arc<PairSlot>) -> bool {
        self.pair_slab
            .get(slot.id as usize)
            .is_some_and(|p| Arc::ptr_eq(&p, slot))
    }

    /// Never taken under the current grow-only registry invariant;
    /// kept so a cached ref degrades to a by-name re-resolve instead
    /// of corrupting pair accounting if that invariant ever changes.
    #[cold]
    fn append_ref_stale(&self, pair: &PairRef, seq: u64, score: f64, raw: f64, shadow: bool) {
        let slot = self.pair_slot(&pair.slot.tenant, &pair.slot.predictor);
        self.write_record(&slot, seq, score, raw, shadow);
    }

    /// The pair shard owning `tenant`'s slots.
    #[inline]
    fn pair_shard(&self, tenant: &str) -> &SnapCell<TenantPairs> {
        &self.pair_shards[(pair_shard_hash(tenant) as usize) % self.pair_shards.len()]
    }

    /// Resolve (or intern) the pair slot for `(tenant, predictor)`.
    /// Established pairs: one wait-free shard load + two `&str` map
    /// probes + one `Arc` refcount bump. First appearance: one
    /// shard-local shallow republish (control-plane rate).
    #[inline]
    fn pair_slot(&self, tenant: &str, predictor: &str) -> Arc<PairSlot> {
        let shard = self.pair_shard(tenant).load();
        if let Some(slot) = shard.get(tenant).and_then(|m| m.get(predictor)) {
            return Arc::clone(slot);
        }
        self.intern(tenant, predictor)
    }

    #[cold]
    fn intern(&self, tenant: &str, predictor: &str) -> Arc<PairSlot> {
        self.pair_shard(tenant).rcu(|old| {
            // Re-probe under the shard's writer lock: another thread
            // may have interned the pair between our load and this rcu.
            if let Some(slot) = old.get(tenant).and_then(|m| m.get(predictor)) {
                return (Arc::clone(old), Arc::clone(slot));
            }
            let id = self.next_pair_id.fetch_add(1, Ordering::Relaxed);
            assert!(id != u32::MAX, "pair id overflow");
            let slot = Arc::new(PairSlot {
                tenant: Arc::from(tenant),
                predictor: Arc::from(predictor),
                id,
                count: AtomicU64::new(0),
            });
            // Publish the id registry first so an evictor can un-count
            // a record the instant its id can appear in a ring slot.
            self.pair_slab.set(id as usize, Arc::clone(&slot));
            let mut next = old.as_ref().clone();
            let mut inner = next
                .get(tenant)
                .map(|m| m.as_ref().clone())
                .unwrap_or_default();
            inner.insert(Arc::clone(&slot.predictor), Arc::clone(&slot));
            next.insert(Arc::clone(&slot.tenant), Arc::new(inner));
            (Arc::new(next), slot)
        })
    }

    /// Write the record claimed as `seq` into its slot, evicting (and
    /// un-counting) whatever the previous lap left there.
    fn write_record(&self, pair: &PairSlot, seq: u64, score: f64, raw: f64, shadow: bool) {
        let n = self.stripes.len() as u64;
        let stripe = &self.stripes[(seq % n) as usize];
        let k = seq / n;
        let cs = stripe.cap as u64;
        let pos = (k % cs) as usize;
        let lap = k / cs;
        let slot = stripe.slot(pos);
        if !self.claim(slot, lap) {
            return; // lost a full-lap race; accounted in `lost`
        }
        // Release fence: the claim's version transition must become
        // visible before the payload stores below on weakly-ordered
        // hardware, or a reader could pass its version-unchanged check
        // on torn data (the crossbeam-seqlock writer pattern; pairs
        // with the reader's Acquire payload loads in `read_slot`).
        std::sync::atomic::fence(Ordering::Release);
        slot.meta
            .store(((pair.id as u64) << 1) | shadow as u64, Ordering::Relaxed);
        slot.score.store(score.to_bits(), Ordering::Relaxed);
        slot.raw.store(raw.to_bits(), Ordering::Relaxed);
        pair.count.fetch_add(1, Ordering::Relaxed);
        // Publish with a monotone max so a force-claimed stalled
        // writer waking late can never regress the version.
        slot.version.fetch_max(v_live(lap), Ordering::AcqRel);
    }

    /// Claim a slot for lap `lap`. Returns false when this append lost
    /// a full-lap race (record dropped, counted). On success, the
    /// evicted predecessor (if any) has been un-counted.
    fn claim(&self, slot: &Slot, lap: u64) -> bool {
        let writing = v_writing(lap);
        let mut spins = 0u32;
        loop {
            let v = slot.version.load(Ordering::Acquire);
            if v >= writing {
                // A same-or-later-lap writer already owns this slot:
                // we stalled for at least one full ring cycle between
                // claiming our seq and writing. Drop the record.
                self.lost.fetch_add(1, Ordering::Relaxed);
                return false;
            }
            if lap == 0 {
                // v < 1 means v == 0 (empty): the only legal
                // predecessor state for lap 0.
                match slot.version.compare_exchange_weak(
                    0,
                    writing,
                    Ordering::AcqRel,
                    Ordering::Acquire,
                ) {
                    Ok(_) => return true,
                    Err(_) => continue,
                }
            }
            let prior_live = v_live(lap - 1);
            let prior_dead = v_dead(lap - 1);
            if v == prior_live || v == prior_dead {
                match slot.version.compare_exchange_weak(
                    v,
                    writing,
                    Ordering::AcqRel,
                    Ordering::Acquire,
                ) {
                    Ok(_) => {
                        if v == prior_live {
                            self.uncount_evicted(slot);
                        } else {
                            // Tombstone physically leaves the ring.
                            self.dead.fetch_sub(1, Ordering::Relaxed);
                        }
                        return true;
                    }
                    // Lost the CAS to a purge tombstoning the slot (or
                    // a spurious failure): re-read and retry.
                    Err(_) => continue,
                }
            }
            // Predecessor still unwritten or mid-write: its writer is
            // stalled a full ring lap behind. Spin briefly, then force.
            spins += 1;
            if spins > FORCE_SPINS {
                slot.version.fetch_max(writing, Ordering::AcqRel);
                self.forced.fetch_add(1, Ordering::Relaxed);
                // The predecessor's accounting state is unknowable
                // here; the diagnostic counter records the (bounded)
                // possible drift.
                return true;
            }
            std::hint::spin_loop();
        }
    }

    /// Decrement the retained count of the record being evicted from
    /// `slot` (called with the slot exclusively claimed, payload
    /// still the predecessor's). The id registry is live (not a
    /// snapshot) and a pair's slab publication happens-before any
    /// record carrying its id, so the probe cannot miss; the guard is
    /// defensive.
    fn uncount_evicted(&self, slot: &Slot) {
        let old_id = (slot.meta.load(Ordering::Acquire) >> 1) as usize;
        if let Some(p) = self.pair_slab.get(old_id) {
            p.count.fetch_sub(1, Ordering::Relaxed);
        }
    }

    // ---------------------------------------------------------------
    // Read path (control-plane / test rate)
    // ---------------------------------------------------------------

    /// Number of retained records. Exact under quiescence: occupancy
    /// derives from the claimed sequence counter and the stripe
    /// geometry, minus tombstones still holding slots.
    pub fn len(&self) -> usize {
        let issued = self.next_seq.load(Ordering::Acquire);
        let n = self.stripes.len() as u64;
        let mut occ = 0u64;
        for (s, stripe) in self.stripes.iter().enumerate() {
            // Seqs < issued congruent to s (mod n).
            let appended = issued / n + u64::from(issued % n > s as u64);
            occ += appended.min(stripe.cap as u64);
        }
        occ.saturating_sub(self.dead.load(Ordering::Acquire)) as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Seqlock read of one slot: `Some((seq, pair_id, shadow, score,
    /// raw))` when it holds a stable live record. Retries while a
    /// writer is publishing (versions are monotone, so each retry
    /// observes a strictly newer state — the loop terminates).
    fn read_slot(
        &self,
        slot: &Slot,
        stripe_idx: usize,
        stripe_cap: usize,
        pos: usize,
    ) -> Option<(u64, usize, bool, f64, f64)> {
        loop {
            let v1 = slot.version.load(Ordering::Acquire);
            if v1 == 0 || v1 % 4 != 2 {
                return None; // empty, mid-write, or tombstoned
            }
            let meta = slot.meta.load(Ordering::Acquire);
            let score = slot.score.load(Ordering::Acquire);
            let raw = slot.raw.load(Ordering::Acquire);
            if slot.version.load(Ordering::Acquire) != v1 {
                continue; // raced a writer; re-read
            }
            let lap = (v1 - 2) / 4;
            let seq = (lap * stripe_cap as u64 + pos as u64) * self.stripes.len() as u64
                + stripe_idx as u64;
            return Some((
                seq,
                (meta >> 1) as usize,
                meta & 1 == 1,
                f64::from_bits(score),
                f64::from_bits(raw),
            ));
        }
    }

    /// Visit every stable live record (unordered; callers sort by seq
    /// where order matters).
    fn scan(&self, mut f: impl FnMut(u64, &PairSlot, bool, f64, f64)) {
        for (si, stripe) in self.stripes.iter().enumerate() {
            for (seg, cell) in stripe.segments.iter().enumerate() {
                let p = cell.load(Ordering::Acquire);
                if p.is_null() {
                    continue; // untouched segment
                }
                for off in 0..stripe.seg_len(seg) {
                    // SAFETY: `p` points at `seg_len(seg)` live slots.
                    let slot = unsafe { &*p.add(off) };
                    let pos = (seg << SEG_BITS) + off;
                    if let Some((seq, id, shadow, score, raw)) =
                        self.read_slot(slot, si, stripe.cap, pos)
                    {
                        if let Some(pair) = self.pair_slab.get(id) {
                            f(seq, &pair, shadow, score, raw);
                        }
                    }
                }
            }
        }
    }

    fn pair_id(&self, tenant: &str, predictor: &str) -> Option<u32> {
        self.pair_shard(tenant)
            .load()
            .get(tenant)
            .and_then(|m| m.get(predictor))
            .map(|p| p.id)
    }

    /// Raw (pre-quantile) scores for a tenant/predictor pair in append
    /// order — the input to a custom `T^Q` fit (Section 2.3.3).
    pub fn raw_scores(&self, tenant: &str, predictor: &str) -> Vec<f64> {
        self.collect_pair(tenant, predictor, |_, raw| raw)
    }

    /// Final scores (for distribution-stability validation), in append
    /// order.
    pub fn final_scores(&self, tenant: &str, predictor: &str) -> Vec<f64> {
        self.collect_pair(tenant, predictor, |score, _| score)
    }

    fn collect_pair(
        &self,
        tenant: &str,
        predictor: &str,
        pick: impl Fn(f64, f64) -> f64,
    ) -> Vec<f64> {
        let Some(id) = self.pair_id(tenant, predictor) else {
            return Vec::new();
        };
        let mut out: Vec<(u64, f64)> = Vec::new();
        self.scan(|seq, pair, _shadow, score, raw| {
            if pair.id == id {
                out.push((seq, pick(score, raw)));
            }
        });
        out.sort_unstable_by_key(|&(seq, _)| seq);
        out.into_iter().map(|(_, v)| v).collect()
    }

    /// All retained records for a pair, in append order (tests and
    /// oracle checks).
    pub fn records_for(&self, tenant: &str, predictor: &str) -> Vec<Record> {
        let Some(id) = self.pair_id(tenant, predictor) else {
            return Vec::new();
        };
        let mut out: Vec<Record> = Vec::new();
        self.scan(|seq, pair, shadow, score, raw| {
            if pair.id == id {
                out.push(Record {
                    tenant: pair.tenant.to_string(),
                    predictor: pair.predictor.to_string(),
                    score,
                    raw_score: raw,
                    shadow,
                    seq,
                });
            }
        });
        out.sort_unstable_by_key(|r| r.seq);
        out
    }

    /// Number of retained records for a tenant/predictor pair — O(1),
    /// wait-free, from the incrementally maintained pair counts (the
    /// lifecycle controller polls this every tick while a shadow
    /// accumulates mirrors; it never touches the rings).
    pub fn count_for(&self, tenant: &str, predictor: &str) -> usize {
        self.pair_shard(tenant)
            .load()
            .get(tenant)
            .and_then(|m| m.get(predictor))
            .map(|p| p.count.load(Ordering::Relaxed) as usize)
            .unwrap_or(0)
    }

    /// Number of `(tenant, predictor)` pairs ever interned (grow-only).
    pub fn pair_count(&self) -> usize {
        self.next_pair_id.load(Ordering::Relaxed) as usize
    }

    /// Id-registry segments actually allocated — pair-registry memory
    /// grows in constant-size steps (tsunami RSS accounting).
    pub fn pair_segments(&self) -> usize {
        self.pair_slab.segments_allocated()
    }

    /// Count of records per (tenant, predictor, shadow-flag).
    pub fn counts(&self) -> BTreeMap<(String, String, bool), usize> {
        let mut out = BTreeMap::new();
        self.scan(|_seq, pair, shadow, _score, _raw| {
            *out.entry((pair.tenant.to_string(), pair.predictor.to_string(), shadow))
                .or_insert(0) += 1;
        });
        out
    }

    /// Drop all records for a predictor (after decommissioning):
    /// matching slots are tombstoned (CAS live → dead) and un-counted;
    /// the tombstones are reclaimed as later laps overwrite them.
    pub fn purge_predictor(&self, predictor: &str) -> usize {
        let mut removed = 0usize;
        for stripe in self.stripes.iter() {
            for (seg, cell) in stripe.segments.iter().enumerate() {
                let p = cell.load(Ordering::Acquire);
                if p.is_null() {
                    continue;
                }
                for off in 0..stripe.seg_len(seg) {
                    // SAFETY: `p` points at `seg_len(seg)` live slots.
                    let slot = unsafe { &*p.add(off) };
                    loop {
                        let v = slot.version.load(Ordering::Acquire);
                        if v == 0 || v % 4 != 2 {
                            break; // nothing stable+live to purge
                        }
                        let meta = slot.meta.load(Ordering::Acquire);
                        if slot.version.load(Ordering::Acquire) != v {
                            continue; // torn read; re-examine
                        }
                        let id = (meta >> 1) as usize;
                        let Some(pair) = self.pair_slab.get(id) else { break };
                        if &*pair.predictor != predictor {
                            break;
                        }
                        // live(L) -> dead(L) is +1 on the version.
                        if slot
                            .version
                            .compare_exchange(v, v + 1, Ordering::AcqRel, Ordering::Acquire)
                            .is_ok()
                        {
                            pair.count.fetch_sub(1, Ordering::Relaxed);
                            self.dead.fetch_add(1, Ordering::Relaxed);
                            removed += 1;
                            break;
                        }
                        // Raced a writer claiming the slot; re-examine.
                    }
                }
            }
        }
        removed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop;

    #[test]
    fn append_and_query() {
        let lake = DataLake::new();
        lake.append("bank1", "p1", 0.9, 0.12, false);
        lake.append("bank1", "p2", 0.8, 0.10, true);
        lake.append("bank2", "p1", 0.7, 0.08, false);
        assert_eq!(lake.len(), 3);
        assert_eq!(lake.raw_scores("bank1", "p1"), vec![0.12]);
        assert_eq!(lake.final_scores("bank1", "p2"), vec![0.8]);
        assert!(lake.raw_scores("bank3", "p1").is_empty());
    }

    #[test]
    fn append_batch_matches_sequential_appends() {
        let a = DataLake::new();
        let b = DataLake::new();
        let finals = [0.9, 0.8, 0.7];
        let raws = [0.12, 0.10, 0.08];
        a.append_batch("t", "p", &finals, &raws, true);
        for (f, r) in finals.iter().zip(&raws) {
            b.append("t", "p", *f, *r, true);
        }
        assert_eq!(a.len(), b.len());
        assert_eq!(a.final_scores("t", "p"), b.final_scores("t", "p"));
        assert_eq!(a.raw_scores("t", "p"), b.raw_scores("t", "p"));
        let records = a.records_for("t", "p");
        for w in records.windows(2) {
            assert_eq!(w[1].seq, w[0].seq + 1, "batch seq must stay contiguous");
        }
        assert!(records.iter().all(|r| r.shadow));
    }

    #[test]
    fn cached_pair_refs_match_string_keyed_appends() {
        let a = DataLake::new();
        let b = DataLake::new();
        // Refs resolved before AND after other pairs intern must stay
        // valid (ids are slab-stable across copy-on-write republish).
        let early = a.pair_ref("t", "p");
        a.append("other", "q", 0.5, 0.5, false);
        b.append("other", "q", 0.5, 0.5, false);
        let finals = [0.9, 0.8, 0.7];
        let raws = [0.12, 0.10, 0.08];
        a.append_ref(&early, 0.1, 0.2, false);
        b.append("t", "p", 0.1, 0.2, false);
        a.append_batch_ref(&early, &finals, &raws, true);
        b.append_batch("t", "p", &finals, &raws, true);
        assert_eq!(a.len(), b.len());
        assert_eq!(a.raw_scores("t", "p"), b.raw_scores("t", "p"));
        assert_eq!(a.final_scores("t", "p"), b.final_scores("t", "p"));
        assert_eq!(a.counts(), b.counts());
        assert_eq!(a.count_for("t", "p"), 4);
        // A ref re-resolved later aliases the same interned slot.
        let again = a.pair_ref("t", "p");
        assert!(Arc::ptr_eq(&early.slot, &again.slot));
    }

    #[test]
    fn pair_registry_is_slab_backed_and_grow_only() {
        // An onboarding storm of distinct tenants grows the pair
        // registry in constant-size segment steps (one per slab shard
        // here: 600 dense ids over PAIR_SHARDS shards stay inside each
        // shard's first segment) — never a whole-table republish.
        let lake = DataLake::new();
        assert_eq!(lake.pair_count(), 0);
        assert_eq!(lake.pair_segments(), 0);
        for i in 0..600 {
            lake.append(&format!("tenant-{i}"), "p", 0.5, 0.5, false);
        }
        assert_eq!(lake.pair_count(), 600);
        assert_eq!(lake.pair_segments(), PAIR_SHARDS);
        for i in (0..600).step_by(97) {
            assert_eq!(lake.count_for(&format!("tenant-{i}"), "p"), 1);
        }
        // A second predictor for an existing tenant interns a fresh
        // id without disturbing the first pair's slot.
        let before = lake.pair_ref("tenant-0", "p");
        lake.append("tenant-0", "q", 0.1, 0.1, true);
        assert_eq!(lake.pair_count(), 601);
        let after = lake.pair_ref("tenant-0", "p");
        assert!(Arc::ptr_eq(&before.slot, &after.slot));
        assert_eq!(lake.count_for("tenant-0", "q"), 1);
    }

    #[test]
    fn seq_is_monotone() {
        let lake = DataLake::new();
        for i in 0..10 {
            lake.append("t", "p", i as f64, 0.0, false);
        }
        let records = lake.records_for("t", "p");
        assert_eq!(records.len(), 10);
        for (prev, next) in records.iter().zip(records.iter().skip(1)) {
            assert!(next.seq > prev.seq);
        }
        // Append order is preserved by the seq sort.
        for (i, r) in records.iter().enumerate() {
            assert_eq!(r.score, i as f64);
        }
    }

    #[test]
    fn retention_cap_evicts_oldest_per_stripe() {
        let lake = DataLake::with_capacity(100);
        assert_eq!(lake.capacity(), 100);
        assert_eq!(lake.effective_capacity(), 100);
        for i in 0..350 {
            lake.append("t", "p", i as f64 / 350.0, i as f64, false);
        }
        assert_eq!(lake.len(), 100, "cap must bound the lake");
        assert_eq!(lake.count_for("t", "p"), 100);
        // Survivors are the newest 100 to within one stripe round:
        // eviction is per-stripe FIFO and appends round-robin the
        // stripes, so the survivor boundary can skew by at most
        // `shards` sequence numbers.
        let records = lake.records_for("t", "p");
        assert_eq!(records.len(), 100);
        let shards = lake.shards() as u64;
        let oldest = records.first().unwrap().seq;
        assert!(
            oldest >= 250 - shards && oldest <= 250 + shards,
            "oldest survivor {oldest} too far from the FIFO boundary 250"
        );
        // The newest record always survives, and raws come back in
        // append order.
        assert_eq!(records.last().unwrap().seq, 349);
        let raws = lake.raw_scores("t", "p");
        for w in raws.windows(2) {
            assert!(w[1] > w[0], "append order lost: {} then {}", w[0], w[1]);
        }
        assert_eq!(lake.forced_overwrites(), 0);
        assert_eq!(lake.lost_appends(), 0);
    }

    #[test]
    fn retention_cap_applies_to_batches() {
        let lake = DataLake::with_capacity(64);
        let scores: Vec<f64> = (0..50).map(|i| i as f64).collect();
        lake.append_batch("t", "p", &scores, &scores, false);
        lake.append_batch("t", "p", &scores, &scores, true);
        assert_eq!(lake.len(), 64);
        // Oldest records evicted first per stripe; every one of the 50
        // newest (shadow) records fits under each stripe's share, so
        // all survive alongside the last 14 live ones.
        let counts = lake.counts();
        assert_eq!(counts[&("t".into(), "p".into(), true)], 50);
        assert_eq!(counts[&("t".into(), "p".into(), false)], 14);
        assert_eq!(lake.count_for("t", "p"), 64);
    }

    #[test]
    fn zero_capacity_resolves_to_default() {
        let lake = DataLake::with_capacity(0);
        assert_eq!(lake.capacity(), 0);
        assert_eq!(lake.effective_capacity(), 1 << 20);
        for i in 0..5000 {
            lake.append("t", "p", 0.0, i as f64, false);
        }
        assert_eq!(lake.len(), 5000);
    }

    #[test]
    fn tiny_caps_clamp_shards() {
        // cap < shards: stripe count clamps so every stripe owns >= 1
        // slot, and the cap still binds exactly.
        for cap in [1usize, 2, 3, 5, 7] {
            let lake = DataLake::with_shards(cap, 8);
            assert_eq!(lake.shards(), cap);
            for i in 0..40 {
                lake.append("t", "p", i as f64, i as f64, false);
            }
            assert_eq!(lake.len(), cap, "cap {cap}");
            assert_eq!(lake.count_for("t", "p"), cap);
        }
    }

    #[test]
    fn count_for_filters_pairs() {
        let lake = DataLake::new();
        lake.append("a", "p", 0.1, 0.1, false);
        lake.append("a", "q", 0.2, 0.2, false);
        lake.append("b", "p", 0.3, 0.3, true);
        assert_eq!(lake.count_for("a", "p"), 1);
        assert_eq!(lake.count_for("a", "q"), 1);
        assert_eq!(lake.count_for("b", "p"), 1);
        assert_eq!(lake.count_for("c", "p"), 0);
    }

    #[test]
    fn counts_split_shadow_and_live() {
        let lake = DataLake::new();
        lake.append("t", "p", 0.1, 0.1, false);
        lake.append("t", "p", 0.2, 0.2, true);
        lake.append("t", "p", 0.3, 0.3, true);
        let counts = lake.counts();
        assert_eq!(counts[&("t".into(), "p".into(), false)], 1);
        assert_eq!(counts[&("t".into(), "p".into(), true)], 2);
    }

    #[test]
    fn purge_removes_only_target() {
        let lake = DataLake::new();
        lake.append("t", "old", 0.1, 0.1, false);
        lake.append("t", "new", 0.2, 0.2, false);
        assert_eq!(lake.purge_predictor("old"), 1);
        assert_eq!(lake.len(), 1);
        assert_eq!(lake.raw_scores("t", "new").len(), 1);
        assert!(lake.raw_scores("t", "old").is_empty());
        // The O(1) pair counts track the purge.
        assert_eq!(lake.count_for("t", "old"), 0);
        assert_eq!(lake.count_for("t", "new"), 1);
    }

    #[test]
    fn purged_slots_are_reclaimed_by_later_laps() {
        let lake = DataLake::with_shards(16, 4);
        for i in 0..16 {
            lake.append("t", "a", i as f64, 0.0, false);
        }
        assert_eq!(lake.purge_predictor("a"), 16);
        assert_eq!(lake.len(), 0);
        // New appends overwrite the tombstones and the bound holds.
        for i in 0..40 {
            lake.append("t", "b", i as f64, 0.0, false);
        }
        assert_eq!(lake.len(), 16);
        assert_eq!(lake.count_for("t", "b"), 16);
        assert_eq!(lake.count_for("t", "a"), 0);
    }

    #[test]
    fn count_for_stays_consistent_under_eviction() {
        // The incrementally maintained counts must agree with a full
        // scan after interleaved appends from two pairs roll through
        // the retention cap.
        let lake = DataLake::with_capacity(50);
        for i in 0..200 {
            let pred = if i % 3 == 0 { "a" } else { "b" };
            lake.append("t", pred, 0.0, i as f64, false);
        }
        let scan_a = lake.raw_scores("t", "a").len();
        let scan_b = lake.raw_scores("t", "b").len();
        assert_eq!(lake.count_for("t", "a"), scan_a);
        assert_eq!(lake.count_for("t", "b"), scan_b);
        assert_eq!(scan_a + scan_b, 50);
        assert_eq!(lake.len(), 50);
    }

    #[test]
    fn sharded_reads_match_single_stripe_oracle() {
        // shards=1 degenerates to exactly the old global-FIFO ring;
        // the sharded lake must agree with it on everything except
        // the (documented) survivor boundary — and when no eviction
        // happens, on everything.
        let oracle = DataLake::with_shards(1000, 1);
        let sharded = DataLake::with_shards(1000, 8);
        let mut rng = crate::util::rng::Rng::new(42);
        for i in 0..800 {
            let tenant = if rng.bernoulli(0.5) { "t1" } else { "t2" };
            let shadow = rng.bernoulli(0.3);
            let s = rng.f64();
            oracle.append(tenant, "p", s, i as f64, shadow);
            sharded.append(tenant, "p", s, i as f64, shadow);
        }
        assert_eq!(oracle.len(), sharded.len());
        for t in ["t1", "t2"] {
            assert_eq!(oracle.raw_scores(t, "p"), sharded.raw_scores(t, "p"));
            assert_eq!(oracle.final_scores(t, "p"), sharded.final_scores(t, "p"));
            assert_eq!(oracle.count_for(t, "p"), sharded.count_for(t, "p"));
        }
        assert_eq!(oracle.counts(), sharded.counts());
    }

    #[test]
    fn prop_eviction_never_exceeds_cap_and_counts_stay_exact() {
        // Satellite acceptance: across random cap/shard/append mixes,
        // len() never exceeds the cap, per-pair counts always equal a
        // full scan, and the occupancy formula matches reality.
        prop::check(24, |g| {
            let cap = g.usize(4..400);
            let shards = g.usize(1..12);
            let appends = g.usize(1..1200);
            let lake = DataLake::with_shards(cap, shards);
            let pairs = [("a", "p"), ("a", "q"), ("b", "p")];
            let mut appended_per_pair = [0usize; 3];
            for i in 0..appends {
                let which = g.usize(0..3);
                let (t, p) = pairs[which];
                appended_per_pair[which] += 1;
                if g.bool(0.1) {
                    let scores = [i as f64, i as f64 + 0.5];
                    lake.append_batch(t, p, &scores, &scores, g.bool(0.5));
                    appended_per_pair[which] += 1;
                } else {
                    lake.append(t, p, i as f64, i as f64, g.bool(0.5));
                }
            }
            let len = lake.len();
            prop_assert!(
                len <= cap,
                "len {len} exceeds cap {cap} (shards {shards})"
            );
            let mut total_scanned = 0usize;
            for (i, &(t, p)) in pairs.iter().enumerate() {
                let scanned = lake.records_for(t, p).len();
                total_scanned += scanned;
                prop_assert!(
                    lake.count_for(t, p) == scanned,
                    "count_for({t},{p}) = {} but scan found {scanned}",
                    lake.count_for(t, p)
                );
                prop_assert!(
                    scanned <= appended_per_pair[i],
                    "pair ({t},{p}) retains more than appended"
                );
            }
            prop_assert!(
                total_scanned == len,
                "len {len} disagrees with scan total {total_scanned}"
            );
            prop_assert!(lake.forced_overwrites() == 0, "forced overwrite in a quiet run");
            prop_assert!(lake.lost_appends() == 0, "lost append in a quiet run");
            Ok(())
        });
    }

    #[test]
    fn concurrent_appends() {
        let lake = Arc::new(DataLake::new());
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let lake = Arc::clone(&lake);
                std::thread::spawn(move || {
                    for i in 0..500 {
                        lake.append(&format!("t{t}"), "p", i as f64 / 500.0, 0.0, false);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(lake.len(), 4000);
        for t in 0..8 {
            assert_eq!(lake.count_for(&format!("t{t}"), "p"), 500);
        }
        assert_eq!(lake.forced_overwrites(), 0);
        assert_eq!(lake.lost_appends(), 0);
    }

    #[test]
    fn concurrent_appends_under_eviction_keep_exact_counts() {
        // 8 writers push far past the cap from two pairs each; after
        // quiescence the merged reads must satisfy the same exactness
        // the mutex implementation gave: len == cap, every pair count
        // equals its scan, and no slot was torn or force-claimed.
        let lake = Arc::new(DataLake::with_shards(512, 8));
        let per_thread = 2000usize;
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let lake = Arc::clone(&lake);
                std::thread::spawn(move || {
                    for i in 0..per_thread {
                        let pred = if i % 2 == 0 { "even" } else { "odd" };
                        lake.append(&format!("t{}", t % 2), pred, i as f64, i as f64, i % 5 == 0);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(lake.len(), 512);
        let mut total = 0usize;
        for t in ["t0", "t1"] {
            for p in ["even", "odd"] {
                let scanned = lake.records_for(t, p).len();
                assert_eq!(lake.count_for(t, p), scanned, "pair ({t},{p})");
                total += scanned;
            }
        }
        assert_eq!(total, 512);
        assert_eq!(lake.forced_overwrites(), 0);
        assert_eq!(lake.lost_appends(), 0);
    }

    #[test]
    fn concurrent_purge_during_appends_is_safe() {
        // A decommission purge racing live appends must leave counts
        // consistent with a scan (purge and eviction each un-count a
        // record at most once).
        let lake = Arc::new(DataLake::with_shards(256, 4));
        for i in 0..256 {
            lake.append("t", "victim", i as f64, 0.0, false);
        }
        let appender = {
            let lake = Arc::clone(&lake);
            std::thread::spawn(move || {
                for i in 0..4000 {
                    lake.append("t", "live", i as f64, 0.0, false);
                }
            })
        };
        let purger = {
            let lake = Arc::clone(&lake);
            std::thread::spawn(move || {
                let mut removed = 0;
                for _ in 0..8 {
                    removed += lake.purge_predictor("victim");
                }
                removed
            })
        };
        appender.join().unwrap();
        let _removed = purger.join().unwrap();
        // Quiesced: victims are gone (purged or evicted), live counts
        // agree with the scan, and the cap holds.
        assert_eq!(lake.count_for("t", "victim"), lake.records_for("t", "victim").len());
        assert_eq!(lake.count_for("t", "live"), lake.records_for("t", "live").len());
        assert!(lake.len() <= 256);
        assert_eq!(lake.forced_overwrites(), 0);
        assert_eq!(lake.lost_appends(), 0);
    }
}
