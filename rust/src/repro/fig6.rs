//! Figure 6 + Section 3.2: Live Model Update — expanding the shared
//! ensemble {m1, m2} with the specialist m3 (new fraud pattern).
//!
//! Series:
//! * `p1`   = {m1,m2} + T^Q_v1 (fit to the client's pre-period) — the
//!   incumbent, evaluated pre-deployment: aligned.
//! * `p1.5` = {m1,m2,m3} + the OLD T^Q_v1 — the hypothetical "swap the
//!   model, keep the transformation": first bin over-represented,
//!   upper bins under-alerting (errors < 0).
//! * `p2`   = {m1,m2,m3} + T^Q_v2 (refit on recent data): aligned.
//!
//! Plus the recall claims: Recall@1%FPR(p2) > Recall(p1) (~+1pp in
//! the paper) and Recall(p1.5) == Recall(p2) exactly (monotonicity).

use super::common::{self, bin_error_table, render_bin_errors, BinErrorRow};
use crate::calibration::recall::recall_at_fpr;
use crate::transforms::{quantile_fit, ReferenceDistribution};
use anyhow::Result;

const CONFIG: &str = r#"
routing:
  scoringRules:
  - description: "client B"
    condition: {}
    targetPredictorName: "p1"
predictors:
- name: p1
  experts: [m1, m2]
  quantile: custom
- name: p2
  experts: [m1, m2, m3]
  quantile: custom
"#;

pub struct Fig6Output {
    pub p1_rows: Vec<BinErrorRow>,
    pub p15_rows: Vec<BinErrorRow>,
    pub p2_rows: Vec<BinErrorRow>,
    pub recall_p1: f64,
    pub recall_p15: f64,
    pub recall_p2: f64,
    pub report: String,
}

pub fn compute() -> Result<Fig6Output> {
    let engine = common::build_engine(CONFIG)?;
    let manifest = common::load_manifest()?;
    let reference = ReferenceDistribution::fraud_default();
    let n_points = engine.quantile_points;
    let refq = reference.quantile_grid(n_points);

    // Pre-deployment period (3 months prior in the paper) and
    // post-deployment period with the new fraud pattern P1 surging.
    let pre = common::load_dataset(&manifest, "client_b_pre")?;
    let post = common::load_dataset(&manifest, "client_b_post")?;

    // --- p1: old ensemble + T^Q_v1 fit on (the first half of) pre ---
    let raw_p1_pre = common::score_dataset_raw(&engine, "p1", &pre)?;
    let split = pre.n / 2;
    let map_v1 = quantile_fit::fit_from_scores(&raw_p1_pre[..split], &refq)?;
    let p1_scores: Vec<f64> = raw_p1_pre[split..].iter().map(|&s| map_v1.apply(s)).collect();
    let p1_rows = bin_error_table(&p1_scores, &reference);

    // --- p1.5: NEW ensemble + OLD transformation, on post period ---
    let raw_p2_post = common::score_dataset_raw(&engine, "p2", &post)?;
    let p15_scores: Vec<f64> = raw_p2_post.iter().map(|&s| map_v1.apply(s)).collect();
    let p15_rows = bin_error_table(&p15_scores, &reference);

    // --- p2: new ensemble + T^Q_v2 refit on recent (post) data ------
    let split2 = post.n / 2;
    let map_v2 = quantile_fit::fit_from_scores(&raw_p2_post[..split2], &refq)?;
    let p2_scores: Vec<f64> = raw_p2_post[split2..].iter().map(|&s| map_v2.apply(s)).collect();
    let p2_rows = bin_error_table(&p2_scores, &reference);

    // --- recall @ 1% FPR on the post period -------------------------
    let raw_p1_post = common::score_dataset_raw(&engine, "p1", &post)?;
    let labels = &post.labels;
    let labels_f64: Vec<f64> = labels.iter().map(|&y| y as f64).collect();
    let recall_p1 = recall_at_fpr(&raw_p1_post, &labels_f64, 0.01);
    let recall_p15 = recall_at_fpr(&p15_scores, &labels_f64, 0.01);
    let p2_scores_full: Vec<f64> = raw_p2_post.iter().map(|&s| map_v2.apply(s)).collect();
    let recall_p2 = recall_at_fpr(&p2_scores_full, &labels_f64, 0.01);

    let mut report = String::from("  shape checks vs paper:\n");
    let mut pass = true;
    let mut check = |name: &str, ok: bool| {
        report.push_str(&format!("    [{}] {name}\n", if ok { "ok" } else { "FAIL" }));
        pass &= ok;
    };
    let populated = |rows: &[BinErrorRow]| {
        rows.iter()
            .filter(|r| r.observed > 300)
            .map(|r| r.err_pct.abs())
            .fold(0.0, f64::max)
    };
    check("p1 aligned pre-deployment (populated bins within noise)", populated(&p1_rows) < 35.0);
    // The paper's reading of p1.5: "severe misalignment ... severe
    // under-alerting for any threshold higher than 0.1%". Our ensemble
    // shift is milder in the bulk (the paper saw +35% in bin 0; here
    // the bulk stays near target), but the alert region — where client
    // thresholds actually live — starves severely, which is the
    // operational failure the figure is about.
    check(
        "p1.5: clearly misaligned (worst populated bin >= 2x p2's)",
        populated(&p15_rows) > 2.0 * populated(&p2_rows).max(5.0),
    );
    check(
        "p1.5: severe under-alerting in the alert region (top bin < -30%)",
        p15_rows[9].err_pct < -30.0 && p15_rows[8].err_pct < 0.0,
    );
    check("p2 restores alignment", populated(&p2_rows) < 35.0);
    check(
        "recall(p2) > recall(p1) (paper: +1.1pp at 1% FPR)",
        recall_p2 > recall_p1,
    );
    check(
        "recall(p1.5) == recall(p2) (quantile map is monotone)",
        (recall_p15 - recall_p2).abs() < 1e-9,
    );
    report.push_str(&format!(
        "\n  Recall@1%FPR: p1={:.4}  p1.5={:.4}  p2={:.4}  (p2 - p1 = {:+.2}pp)\n",
        recall_p1,
        recall_p15,
        recall_p2,
        100.0 * (recall_p2 - recall_p1)
    ));
    if !pass {
        report.push_str("  WARNING: shape deviates from the paper\n");
    }

    Ok(Fig6Output {
        p1_rows,
        p15_rows,
        p2_rows,
        recall_p1,
        recall_p15,
        recall_p2,
        report,
    })
}

pub fn run() -> Result<String> {
    let mut out = String::new();
    out.push_str("== Figure 6 / Section 3.2: live model update {m1,m2} -> {m1,m2,m3} ==\n\n");
    let o = compute()?;
    out.push_str(&render_bin_errors(
        "predictor p1 ({m1,m2} + T^Q_v1, pre-deployment)",
        &o.p1_rows,
    ));
    out.push('\n');
    out.push_str(&render_bin_errors(
        "predictor p1.5 ({m1,m2,m3} + OLD T^Q_v1, post-deployment)",
        &o.p15_rows,
    ));
    out.push('\n');
    out.push_str(&render_bin_errors(
        "predictor p2 ({m1,m2,m3} + refit T^Q_v2, post-deployment)",
        &o.p2_rows,
    ));
    out.push('\n');
    out.push_str(&o.report);
    Ok(out)
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig6_reproduces_paper_shape() {
        if !crate::runtime::Manifest::default_root().join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let out = super::run().unwrap();
        assert!(!out.contains("[FAIL]"), "shape check failed:\n{out}");
    }
}
