//! Table 1: ECE_SWEEP^EM and Brier before/after Posterior Correction,
//! for each expert {m1 (beta~18%), m2 (beta~18%), m3 (beta~2%)} on its
//! own in-distribution validation data and on out-of-distribution live
//! client data, plus the aggregated ensemble p2.
//!
//! Paper shape: PC cuts ECE by >80% for every expert (most for the
//! beta=2% specialist), Brier by 30-99%; the calibrated ensemble
//! improves both by ~90% on live data.

use super::common::{self, Table};
use crate::calibration::{brier::brier, ece::ece_sweep_em};
use crate::transforms::{Aggregation, PosteriorCorrection};
use crate::util::dataset::Dataset;
use anyhow::Result;

const EXPERTS: [&str; 3] = ["m1", "m2", "m3"];

const CONFIG: &str = r#"
routing:
  scoringRules:
  - description: "x"
    condition: {}
    targetPredictorName: "s_m1"
predictors:
- name: s_m1
  experts: [m1]
  quantile: identity
  posteriorCorrection: false
- name: s_m2
  experts: [m2]
  quantile: identity
  posteriorCorrection: false
- name: s_m3
  experts: [m3]
  quantile: identity
  posteriorCorrection: false
"#;

struct Row {
    dataset: String,
    predictor: String,
    beta: f64,
    ece_without: f64,
    ece_with: f64,
    brier_without: f64,
    brier_with: f64,
}

fn pct_change(with: f64, without: f64) -> f64 {
    if without == 0.0 {
        0.0
    } else {
        100.0 * (with - without) / without
    }
}

fn eval_expert(
    engine: &crate::coordinator::Engine,
    name: &str,
    beta: f64,
    ds: &Dataset,
    dataset_label: &str,
) -> Result<Row> {
    let raw = common::score_dataset_raw(engine, &format!("s_{name}"), ds)?;
    let pc = PosteriorCorrection::new(beta)?;
    let corrected: Vec<f64> = raw.iter().map(|&s| pc.apply(s)).collect();
    let labels: Vec<f64> = ds.labels.iter().map(|&y| y as f64).collect();
    Ok(Row {
        dataset: dataset_label.to_string(),
        predictor: format!("Expert {name}"),
        beta,
        ece_without: ece_sweep_em(&raw, &labels),
        ece_with: ece_sweep_em(&corrected, &labels),
        brier_without: brier(&raw, &labels),
        brier_with: brier(&corrected, &labels),
    })
}

pub fn run() -> Result<String> {
    let mut out = String::new();
    out.push_str("== Table 1: calibration errors before/after Posterior Correction ==\n\n");

    let engine = common::build_engine(CONFIG)?;
    let manifest = common::load_manifest()?;
    let mut rows: Vec<Row> = vec![];

    // In-distribution: each expert on its own validation set.
    for name in EXPERTS {
        let beta = manifest.model(name)?.beta;
        let ds = common::load_dataset(&manifest, &format!("valid_{name}"))?;
        rows.push(eval_expert(&engine, name, beta, &ds, &format!("Validation {name}"))?);
    }

    // Out-of-distribution: live client data (client B post-period).
    let live = common::load_dataset(&manifest, "client_b_post")?;
    let labels: Vec<f64> = live.labels.iter().map(|&y| y as f64).collect();
    let mut per_expert_raw: Vec<Vec<f64>> = vec![];
    for name in EXPERTS {
        let beta = manifest.model(name)?.beta;
        rows.push(eval_expert(&engine, name, beta, &live, "Live Client Data")?);
        per_expert_raw.push(common::score_dataset_raw(&engine, &format!("s_{name}"), &live)?);
    }

    // Ensemble p2 = mean aggregation of the three experts, with and
    // without per-expert correction.
    let agg = Aggregation::Mean;
    let pcs: Vec<PosteriorCorrection> = EXPERTS
        .iter()
        .map(|n| PosteriorCorrection::new(manifest.model(n).unwrap().beta).unwrap())
        .collect();
    let n = live.n;
    let mut ens_without = Vec::with_capacity(n);
    let mut ens_with = Vec::with_capacity(n);
    for i in 0..n {
        let raw: Vec<f64> = per_expert_raw.iter().map(|s| s[i]).collect();
        let cor: Vec<f64> = raw.iter().zip(&pcs).map(|(&s, pc)| pc.apply(s)).collect();
        ens_without.push(agg.apply_unchecked(&raw));
        ens_with.push(agg.apply_unchecked(&cor));
    }
    rows.push(Row {
        dataset: "Live Client Data".into(),
        predictor: "p2 Ensemble {m1,m2,m3}".into(),
        beta: f64::NAN,
        ece_without: ece_sweep_em(&ens_without, &labels),
        ece_with: ece_sweep_em(&ens_with, &labels),
        brier_without: brier(&ens_without, &labels),
        brier_with: brier(&ens_with, &labels),
    });

    let mut table = Table::new(&[
        "Dataset", "Predictor", "PC beta", "Error", "Without PC", "With PC", "Change",
    ]);
    for r in &rows {
        let beta = if r.beta.is_nan() {
            "-".to_string()
        } else {
            format!("~{:.0}%", r.beta * 100.0)
        };
        table.row(vec![
            r.dataset.clone(),
            r.predictor.clone(),
            beta.clone(),
            "ECE".into(),
            format!("{:.3e}", r.ece_without),
            format!("{:.3e}", r.ece_with),
            format!("{:+.1}%", pct_change(r.ece_with, r.ece_without)),
        ]);
        table.row(vec![
            r.dataset.clone(),
            r.predictor.clone(),
            beta,
            "Brier".into(),
            format!("{:.3e}", r.brier_without),
            format!("{:.3e}", r.brier_with),
            format!("{:+.1}%", pct_change(r.brier_with, r.brier_without)),
        ]);
    }
    out.push_str(&table.render());

    // Shape checks.
    let mut report = String::from("\n  shape checks vs paper:\n");
    let mut pass = true;
    let mut check = |name: &str, ok: bool| {
        report.push_str(&format!("    [{}] {name}\n", if ok { "ok" } else { "FAIL" }));
        pass &= ok;
    };
    for r in &rows {
        if r.predictor.starts_with("Expert") {
            check(
                &format!("{} / {}: PC reduces ECE by >=50%", r.dataset, r.predictor),
                r.ece_with < 0.5 * r.ece_without,
            );
            check(
                &format!("{} / {}: PC reduces Brier", r.dataset, r.predictor),
                r.brier_with < r.brier_without,
            );
        }
    }
    let ens = rows.last().unwrap();
    check(
        "ensemble: PC reduces ECE by >=70% on live data (paper: -90.8%)",
        ens.ece_with < 0.3 * ens.ece_without,
    );
    check(
        "ensemble: PC reduces Brier on live data (paper: -90.6%)",
        ens.brier_with < ens.brier_without,
    );
    let m3_val = &rows[2];
    check(
        "beta=2% specialist sees the largest ECE reduction class (>=90%)",
        m3_val.ece_with < 0.1 * m3_val.ece_without,
    );
    out.push_str(&report);
    if !pass {
        out.push_str("  WARNING: shape deviates from the paper\n");
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    #[test]
    fn table1_reproduces_paper_shape() {
        if !crate::runtime::Manifest::default_root().join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let out = super::run().unwrap();
        assert!(!out.contains("[FAIL]"), "shape check failed:\n{out}");
    }
}
