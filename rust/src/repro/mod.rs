//! Paper-exhibit harnesses: one module per table/figure, each printing
//! the same rows/series the paper reports (see docs/ARCHITECTURE.md experiment
//! index).

pub mod common;
pub mod appendix_a;
pub mod baselines_cmp;
pub mod dedup;
pub mod fig4;
pub mod fig5;
pub mod headline;
pub mod fig6;
pub mod table1;
