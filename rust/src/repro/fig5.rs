//! Figure 5: system performance during the rolling update from
//! T^Q_{v0} to T^Q_{v1} — pod count rises and returns to baseline,
//! per-pod warm-up drives ~50 req/s spikes, and the serving
//! percentiles (p99.5, p99.99) stay strictly below 30 ms throughout.
//!
//! Plus the ablation the warm-up machinery exists for: the same
//! rollout with warm-up disabled violates the SLO at every pod start.

use crate::simulator::{ClusterConfig, ClusterSim};
use anyhow::Result;

pub fn run() -> Result<String> {
    run_with(ClusterConfig {
        replicas: 6,
        live_rps: 300.0,
        warmup_rps: 50.0,
        warmup_secs: 300.0, // paper: 15 min; compressed timeline here
        window_secs: 60.0,
        seed: 20260710,
        ..ClusterConfig::default()
    })
}

pub fn run_with(cfg: ClusterConfig) -> Result<String> {
    let mut out = String::new();
    out.push_str("== Figure 5: rolling update T^Q_v0 -> T^Q_v1 with pod warm-up ==\n");
    out.push_str(&format!(
        "   replicas={} live={}eps warmup={}req/s x {}s per pod, windows of {}s\n\n",
        cfg.replicas, cfg.live_rps, cfg.warmup_rps, cfg.warmup_secs, cfg.window_secs
    ));

    let mut sim = ClusterSim::new(cfg.clone());
    let trace = sim.rolling_update(300.0, 300.0);

    out.push_str("  t[s]      pods  warmup[req/s]  p99.5[ms]  p99.99[ms]\n");
    out.push_str("  ------------------------------------------------------\n");
    for i in 0..trace.windows {
        out.push_str(&format!(
            "  {:>7.0}  {:>5}  {:>13.1}  {:>9.2}  {:>10.2}\n",
            i as f64 * cfg.window_secs,
            trace.pod_count.values[i],
            trace.warmup_rps.values[i],
            trace.p99_5_ms.values[i],
            trace.p99_99_ms.values[i],
        ));
    }
    out.push_str(&format!(
        "\n  overall: {}\n  SLO (30ms) violation windows: {}/{}\n",
        trace.overall.summary(),
        trace.slo_violation_windows,
        trace.windows
    ));

    // Ablation: no warm-up.
    let mut cold_cfg = cfg;
    cold_cfg.skip_warmup = true;
    let mut cold_sim = ClusterSim::new(cold_cfg);
    let cold = cold_sim.rolling_update(300.0, 300.0);
    out.push_str(&format!(
        "\n  ablation (warm-up disabled): p99.5 max {:.1}ms, SLO violations {}/{}\n",
        cold.p99_5_ms.max(),
        cold.slo_violation_windows,
        cold.windows
    ));

    let mut report = String::from("\n  shape checks vs paper:\n");
    let mut pass = true;
    let mut check = |name: &str, ok: bool| {
        report.push_str(&format!("    [{}] {name}\n", if ok { "ok" } else { "FAIL" }));
        pass &= ok;
    };
    check(
        "pod count rises above baseline and returns",
        trace.pod_count.max() > trace.pod_count.values[0]
            && *trace.pod_count.values.last().unwrap() == trace.pod_count.values[0],
    );
    check(
        "warm-up spikes visible (~50 req/s per warming pod)",
        trace.warmup_rps.max() > 20.0,
    );
    check(
        "latencies strictly below 30ms throughout the update",
        trace.slo_violation_windows == 0,
    );
    check(
        "ablation: cold pods violate the SLO",
        cold.slo_violation_windows > 0,
    );
    out.push_str(&report);
    if !pass {
        out.push_str("  WARNING: shape deviates from the paper\n");
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig5_reproduces_paper_shape() {
        let out = super::run().unwrap();
        assert!(!out.contains("[FAIL]"), "shape check failed:\n{out}");
    }
}
