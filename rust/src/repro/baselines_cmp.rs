//! Section 4 comparisons: score-semantics stability of MUSE's fixed
//! quantile mapping vs (a) globally-calibrated probability scores
//! (Stripe Radar / Kount style) and (b) rolling-window percentile
//! scores (Sift style), under a fraud-attack spike.

use super::common::Table;
use crate::baselines::global_prob::{
    muse_alert_rate, synth_scores, tenant_coupling_experiment, GlobalProbabilityScorer,
};
use crate::baselines::rolling_pct::RollingPercentile;
use crate::transforms::{quantile_fit, ReferenceDistribution};
use crate::util::rng::Rng;
use anyhow::Result;

pub fn run() -> Result<String> {
    let mut out = String::new();
    out.push_str("== Section 4: score-stability comparison under an attack spike ==\n\n");

    // Tenant A is quiet; tenant B suffers an attack (1.5% -> 15%).
    let (raw_a, lab_a) = synth_scores(80_000, 0.015, 11);
    let (raw_b0, lab_b0) = synth_scores(80_000, 0.015, 12);
    let (raw_b1, lab_b1) = synth_scores(80_000, 0.15, 13);

    // --- global probability provider (recalibrates on the pool) -----
    let (gp_before, gp_after) = tenant_coupling_experiment(
        &raw_a, &raw_b0, &raw_b1, &lab_a, &lab_b0, &lab_b1, 0.5,
    )?;

    // --- MUSE: tenant A's own fixed map --------------------------------
    let reference = ReferenceDistribution::fraud_default();
    let refq = reference.quantile_grid(1025);
    let muse_map = quantile_fit::fit_from_scores(&raw_a, &refq)?;
    let muse_before = muse_alert_rate(&raw_a, &muse_map, 0.9);
    let muse_after = muse_alert_rate(&raw_a, &muse_map, 0.9); // B's attack can't touch it

    // --- Sift-style rolling percentile on the ATTACKED tenant ----------
    // Semantics drift: the same raw score's percentile sags as the
    // window fills with attack traffic.
    let mut rp = RollingPercentile::new(10_000);
    let mut rng = Rng::new(14);
    for _ in 0..10_000 {
        rp.score_and_update(rng.beta(1.2, 12.0));
    }
    let probe = 0.5;
    let pct_before = rp.score_and_update(probe);
    for _ in 0..10_000 {
        let s = if rng.bernoulli(0.3) {
            rng.beta(6.0, 2.0)
        } else {
            rng.beta(1.2, 12.0)
        };
        rp.score_and_update(s);
    }
    let pct_after = rp.score_and_update(probe);
    let muse_probe_before = muse_map.apply(probe);
    let muse_probe_after = muse_map.apply(probe);

    let mut table = Table::new(&["scheme", "metric", "before attack", "during attack", "drift"]);
    table.row(vec![
        "global probability (Radar/Kount)".into(),
        "quiet tenant A alert rate @p>=0.5".into(),
        format!("{:.4}%", gp_before * 100.0),
        format!("{:.4}%", gp_after * 100.0),
        format!("{:+.1}%", 100.0 * (gp_after - gp_before) / gp_before.max(1e-12)),
    ]);
    table.row(vec![
        "MUSE fixed T^Q".into(),
        "quiet tenant A alert rate @score>=0.9".into(),
        format!("{:.4}%", muse_before * 100.0),
        format!("{:.4}%", muse_after * 100.0),
        "0.0% (by construction)".into(),
    ]);
    table.row(vec![
        "rolling percentile (Sift)".into(),
        "score of fixed raw event 0.5".into(),
        format!("{:.4}", pct_before),
        format!("{:.4}", pct_after),
        format!("{:+.1}%", 100.0 * (pct_after - pct_before) / pct_before.max(1e-12)),
    ]);
    table.row(vec![
        "MUSE fixed T^Q".into(),
        "score of fixed raw event 0.5".into(),
        format!("{:.4}", muse_probe_before),
        format!("{:.4}", muse_probe_after),
        "0.0% (stateless table)".into(),
    ]);
    out.push_str(&table.render());
    out.push_str(&format!(
        "\n  rolling-percentile state cost: {} bytes per tenant (MUSE: none beyond the fixed table)\n",
        RollingPercentile::new(10_000).state_bytes()
    ));

    let mut pass = true;
    let mut checks = String::from("\n  checks:\n");
    let mut check = |name: &str, ok: bool| {
        checks.push_str(&format!("    [{}] {name}\n", if ok { "ok" } else { "FAIL" }));
        pass &= ok;
    };
    check(
        "global calibration couples quiet tenant to the attack (>20% drift)",
        (gp_after - gp_before).abs() / gp_before.max(1e-12) > 0.2,
    );
    check("MUSE alert rate bitwise stable", muse_before == muse_after);
    check(
        "rolling percentile semantics drift under attack",
        (pct_before - pct_after).abs() > 0.02,
    );
    check(
        "MUSE mapped score bitwise stable",
        muse_probe_before == muse_probe_after,
    );
    // Sanity: a global prob scorer is still a valid calibrator.
    let g = GlobalProbabilityScorer::fit(&raw_a, &lab_a, 40)?;
    check("global prob scorer monotone sanity", g.score(0.9) >= g.score(0.1));
    out.push_str(&checks);
    if !pass {
        out.push_str("  WARNING: baseline comparison deviates\n");
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    #[test]
    fn baseline_comparison_holds() {
        let out = super::run().unwrap();
        assert!(!out.contains("[FAIL]"), "{out}");
    }
}
