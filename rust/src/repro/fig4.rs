//! Figure 4: Quantile Transformation update for a cold-start
//! deployment — relative error against the target distribution for
//! *predictor raw* (no T^Q), *predictor v0* (cold-start default
//! transformation, Section 2.4) and *predictor v1* (custom,
//! client-specific transformation fitted on live data).
//!
//! Paper shape: raw confines all scores to [0, 0.1) (+43% error there,
//! -100% elsewhere); v0 drifts progressively in high-score bins
//! (hundreds to ~1700%); v1 restores alignment (single-digit errors in
//! populated bins).

use super::common::{self, bin_error_table, render_bin_errors};
use crate::coldstart::FitConfig;
use crate::coordinator::ControlPlane;
use crate::transforms::{quantile_fit, ReferenceDistribution};
use anyhow::Result;

const CONFIG: &str = r#"
routing:
  scoringRules:
  - description: "cold-start client A on the shared 8-expert ensemble"
    condition: {}
    targetPredictorName: "ensemble8"
predictors:
- name: ensemble8
  experts: [m1, m2, m3, m4, m5, m6, m7, m8]
  quantile: default
"#;

pub fn run() -> Result<String> {
    let mut out = String::new();
    out.push_str("== Figure 4: default -> client-specific quantile transformation ==\n");
    out.push_str("   (8-expert ensemble; cold-start client with covariate shift)\n\n");

    let engine = common::build_engine(CONFIG)?;
    let manifest = common::load_manifest()?;
    let reference = ReferenceDistribution::fraud_default();
    let n_points = engine.quantile_points;

    // The provider's combined training pool (what the default
    // transformation is derived from, Section 2.4) and the client's
    // live traffic (covariate-shifted).
    let train = common::load_dataset(&manifest, "train_pool")?;
    let live = common::load_dataset(&manifest, "client_a_live")?;

    // --- predictor raw: ensemble output without quantile transform ---
    let raw_live = common::score_dataset_raw(&engine, "ensemble8", &live)?;
    let raw_rows = bin_error_table(&raw_live, &reference);

    // --- predictor v0: cold-start default T^Q_{v0} ----------------
    let cp = ControlPlane::new(&engine);
    let fit_cfg = FitConfig::default();
    let v0_map = cp.fit_default_quantile("ensemble8", &train, &reference, &fit_cfg)?;
    // Onboarding period: the client's first window scored through v0.
    let (first_half, second_half) = live.split_at(live.n / 2);
    let raw_first: Vec<f64> = raw_live[..first_half.n].to_vec();
    let raw_second: Vec<f64> = raw_live[first_half.n..].to_vec();
    let v0_scores: Vec<f64> = raw_first.iter().map(|&s| v0_map.apply(s)).collect();
    let v0_rows = bin_error_table(&v0_scores, &reference);

    // --- predictor v1: custom transformation fitted on the collected
    //     (unlabeled) onboarding traffic, evaluated on the next window.
    let refq = reference.quantile_grid(n_points);
    let v1_map = quantile_fit::fit_from_scores(&raw_first, &refq)?;
    let v1_scores: Vec<f64> = raw_second.iter().map(|&s| v1_map.apply(s)).collect();
    let v1_rows = bin_error_table(&v1_scores, &reference);

    out.push_str(&render_bin_errors(
        "predictor raw (no quantile transformation)",
        &raw_rows,
    ));
    out.push('\n');
    out.push_str(&render_bin_errors(
        "predictor v0 (cold-start default transformation T^Q_v0)",
        &v0_rows,
    ));
    out.push('\n');
    out.push_str(&render_bin_errors(
        "predictor v1 (custom client-specific transformation T^Q_v1)",
        &v1_rows,
    ));
    out.push('\n');

    // Shape assertions mirroring the paper's reading of the figure.
    let checks = shape_checks(&raw_rows, &v0_rows, &v1_rows);
    out.push_str(&checks.1);
    out.push_str(&format!(
        "\n  split: onboarding={} events (fit), evaluation={} events\n",
        first_half.n, second_half.n
    ));
    Ok(out)
}

/// (pass, report) of the paper-shape assertions.
pub fn shape_checks(
    raw: &[super::common::BinErrorRow],
    v0: &[super::common::BinErrorRow],
    v1: &[super::common::BinErrorRow],
) -> (bool, String) {
    let mut report = String::from("  shape checks vs paper:\n");
    let mut pass = true;
    let mut check = |name: &str, ok: bool| {
        report.push_str(&format!("    [{}] {name}\n", if ok { "ok" } else { "FAIL" }));
        pass &= ok;
    };
    check(
        "raw: positive error in bin0 (paper: +43%)",
        raw[0].err_pct > 10.0,
    );
    check(
        "raw: near-total starvation of upper bins (paper: -100%)",
        raw[1..].iter().all(|r| r.err_pct <= -80.0),
    );
    let v0_max_hi = v0[5..].iter().map(|r| r.err_pct.abs()).fold(0.0, f64::max);
    let v1_max_hi = v1[5..].iter().map(|r| r.err_pct.abs()).fold(0.0, f64::max);
    check(
        "v0: drifts in high-score bins (paper: 207%..1691%)",
        v0_max_hi > 50.0,
    );
    check(
        "v1: restores alignment (errors shrink vs v0 in high bins)",
        v1_max_hi < v0_max_hi / 2.0,
    );
    check(
        "v1: populated bins within tens of percent (paper: -1.5%..11%)",
        v1.iter().filter(|r| r.observed > 500).all(|r| r.err_pct.abs() < 35.0),
    );
    (pass, report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_reproduces_paper_shape() {
        if !crate::runtime::Manifest::default_root().join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let out = run().unwrap();
        assert!(!out.contains("[FAIL]"), "shape check failed:\n{out}");
    }
}
