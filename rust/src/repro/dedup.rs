//! Section 2.2.1: infrastructure deduplication — MUSE's shared model
//! containers vs the KServe-style 1:1 predictor-per-InferenceService
//! baseline, swept over tenant counts; small configurations are also
//! physically exercised through the real PJRT pool.

use super::common::{self, Table};
use crate::baselines::kserve_style::{
    marginal_models, DeploymentCost, KServeStyleDeployment, MuseDeployment,
};
use crate::runtime::ModelPool;
use anyhow::Result;
use std::sync::Arc;

pub fn run() -> Result<String> {
    let mut out = String::new();
    out.push_str("== Section 2.2.1: infrastructure deduplication vs KServe-style ==\n\n");

    // Tenant sweep: every tenant gets its own calibrated predictor
    // over the same shared 8-expert ensemble (the paper's multi-tenant
    // cost-saving scenario).
    let ensemble: Vec<String> = (1..=8).map(|i| format!("m{i}")).collect();
    let mut table = Table::new(&[
        "tenants", "KServe containers", "MUSE containers", "KServe mem(GB)", "MUSE mem(GB)", "ratio",
    ]);
    for tenants in [1usize, 4, 16, 64, 128, 256, 512] {
        let predictors: Vec<Vec<String>> = (0..tenants).map(|_| ensemble.clone()).collect();
        let k: DeploymentCost = KServeStyleDeployment::cost(&predictors);
        let m: DeploymentCost = MuseDeployment::cost(&predictors);
        table.row(vec![
            tenants.to_string(),
            k.containers.to_string(),
            m.containers.to_string(),
            format!("{:.1}", k.memory_mb / 1024.0),
            format!("{:.1}", m.memory_mb / 1024.0),
            format!("{:.0}x", k.containers as f64 / m.containers as f64),
        ]);
    }
    out.push_str(&table.render());

    // Incremental ensemble update (the Fig. 1 example): deploying
    // p2 = p1 + {m3} costs exactly one net-new container.
    let p1: Vec<String> = vec!["m1".into(), "m2".into()];
    let p2: Vec<String> = vec!["m1".into(), "m2".into(), "m3".into()];
    out.push_str(&format!(
        "\n  incremental update (Fig. 1): deploy p2 after p1 -> {} net-new container(s)\n",
        marginal_models(&[p1.clone()], &p2)
    ));

    // Physical cross-check through the real PJRT pool.
    let mut physical = String::new();
    let manifest = common::load_manifest();
    let mut pass = true;
    if let Ok(manifest) = manifest {
        let pool = Arc::new(ModelPool::new(manifest));
        for m in &p1 {
            pool.acquire(m)?;
        }
        let after_p1 = pool.stats().live_containers;
        for m in &p2 {
            pool.acquire(m)?;
        }
        let after_p2 = pool.stats().live_containers;
        physical.push_str(&format!(
            "  physical pool: p1 -> {after_p1} containers; +p2 -> {after_p2} containers\n"
        ));
        pass &= after_p1 == 2 && after_p2 == 3;
    } else {
        physical.push_str("  (artifacts not built; physical cross-check skipped)\n");
    }
    out.push_str(&physical);

    let mut check_out = String::from("\n  checks:\n");
    let mut check = |name: &str, ok: bool| {
        check_out.push_str(&format!("    [{}] {name}\n", if ok { "ok" } else { "FAIL" }));
        pass &= ok;
    };
    let many: Vec<Vec<String>> = (0..512).map(|_| ensemble.clone()).collect();
    check(
        "512 tenants: KServe needs 4096 containers, MUSE needs 8",
        KServeStyleDeployment::cost(&many).containers == 4096
            && MuseDeployment::cost(&many).containers == 8,
    );
    check(
        "marginal cost of {m1,m2}->{m1,m2,m3} is exactly 1",
        marginal_models(&[p1], &p2) == 1,
    );
    out.push_str(&check_out);
    if !pass {
        out.push_str("  WARNING: dedup accounting deviates\n");
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    #[test]
    fn dedup_claims_hold() {
        let out = super::run().unwrap();
        assert!(!out.contains("[FAIL]"), "{out}");
    }
}
