//! Shared plumbing for the paper-exhibit harnesses: engine
//! construction, dataset replay, relative-error tables.

use crate::calibration::wilson;
use crate::config::MuseConfig;
use crate::coordinator::Engine;
use crate::runtime::{Manifest, ModelPool};
use crate::transforms::ReferenceDistribution;
use crate::util::dataset::Dataset;
use crate::util::stats;
use anyhow::{Context, Result};
use std::sync::Arc;

/// Load the artifact manifest from the default root.
pub fn load_manifest() -> Result<Manifest> {
    Manifest::load(Manifest::default_root()).context(
        "artifacts not found — run `make artifacts` first (MUSE_ARTIFACTS overrides the root)",
    )
}

/// Build an engine from inline YAML against the default artifact root.
pub fn build_engine(yaml: &str) -> Result<Engine> {
    let manifest = load_manifest()?;
    let pool = Arc::new(ModelPool::new(manifest));
    Engine::build(&MuseConfig::from_yaml(yaml)?, pool)
}

/// Load a named dataset from the manifest.
pub fn load_dataset(manifest: &Manifest, name: &str) -> Result<Dataset> {
    Dataset::load(&manifest.dataset(name)?.path)
}

/// One row of a Fig. 4/6-style relative-error table.
#[derive(Debug, Clone)]
pub struct BinErrorRow {
    pub bin: usize,
    pub observed: u64,
    pub err_pct: f64,
    pub err_lo_pct: f64,
    pub err_hi_pct: f64,
}

/// Bin scores into 10 uniform bins and compute the relative error vs
/// the reference's target shares, with Wilson 95% error bars.
pub fn bin_error_table(scores: &[f64], reference: &ReferenceDistribution) -> Vec<BinErrorRow> {
    let n_bins = 10;
    let counts = stats::bin_counts(scores, n_bins);
    let target = reference.bin_shares(n_bins);
    let total: u64 = counts.iter().sum();
    counts
        .iter()
        .enumerate()
        .map(|(b, &c)| {
            let (lo, err, hi) = wilson::relative_error_with_interval(c, total, target[b], 1.96);
            BinErrorRow {
                bin: b,
                observed: c,
                err_pct: err,
                err_lo_pct: lo,
                err_hi_pct: hi,
            }
        })
        .collect()
}

/// Render rows like the paper's figures: `[0.3,0.4): +12.3% (+-)`.
pub fn render_bin_errors(label: &str, rows: &[BinErrorRow]) -> String {
    let mut out = String::new();
    out.push_str(&format!("  {label}\n"));
    for r in rows {
        let lo = r.bin as f64 / 10.0;
        let hi = lo + 0.1;
        let bracket = if r.bin == 9 { ']' } else { '[' };
        out.push_str(&format!(
            "    [{lo:.1},{hi:.1}{bracket}  n={:>8}  err={:>+9.1}%  95% CI [{:>+9.1}%, {:>+9.1}%]\n",
            r.observed, r.err_pct, r.err_lo_pct, r.err_hi_pct
        ));
    }
    out
}

/// Score a dataset through a predictor's raw pipeline in chunks
/// (keeps peak memory bounded on the 100k+ datasets).
pub fn score_dataset_raw(engine: &Engine, predictor: &str, ds: &Dataset) -> Result<Vec<f64>> {
    let p = engine.predictor(predictor)?;
    let chunk = 4096;
    let mut out = Vec::with_capacity(ds.n);
    let mut start = 0;
    while start < ds.n {
        let len = chunk.min(ds.n - start);
        let raw = p.score_raw(ds.rows(start, len), len)?;
        out.extend(raw);
        start += len;
    }
    Ok(out)
}

/// Simple fixed-width table printer for the harness outputs.
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate().take(ncol) {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("  ");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!("{:<width$}  ", c, width = widths[i]));
            }
            line.trim_end().to_string() + "\n"
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push_str("  ");
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * ncol));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bin_errors_match_known_distribution() {
        let r = ReferenceDistribution::fraud_default();
        // A sample drawn exactly from the reference: errors ~ 0.
        let n = 200_000;
        let scores: Vec<f64> = (0..n)
            .map(|i| r.mixture.quantile((i as f64 + 0.5) / n as f64))
            .collect();
        let rows = bin_error_table(&scores, &r);
        for row in &rows {
            assert!(
                row.err_pct.abs() < 5.0,
                "bin {} err {}%",
                row.bin,
                row.err_pct
            );
            assert!(row.err_lo_pct <= row.err_pct && row.err_pct <= row.err_hi_pct);
        }
    }

    #[test]
    fn concentrated_scores_show_fig4_raw_signature() {
        let r = ReferenceDistribution::fraud_default();
        let scores = vec![0.01; 10_000];
        let rows = bin_error_table(&scores, &r);
        assert!(rows[0].err_pct > 20.0, "bin0 {}", rows[0].err_pct);
        for row in &rows[1..] {
            assert_eq!(row.err_pct, -100.0, "bin {}", row.bin);
        }
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["a", "metric"]);
        t.row(vec!["x".into(), "1.0".into()]);
        t.row(vec!["longer".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("a       metric"), "{s}");
        assert_eq!(s.lines().count(), 4);
    }
}
