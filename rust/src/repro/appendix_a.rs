//! Appendix A / Eq. 5: Monte-Carlo validation of the sample-size bound
//! `n ~= z^2 (1-a) / (delta^2 a)` for quantile-transformation fitting.
//!
//! For each (alert rate a, relative error delta): draw n scores, pick
//! the k-th order statistic as threshold (k/n ~= 1-a), measure the
//! threshold's true alert rate, and check it lies within delta*a. At
//! z = 1.96 the empirical coverage should be ~95%; at n/4 samples the
//! coverage must degrade (the bound is tight, not slack).

use super::common::Table;
use crate::transforms::quantile_fit::required_samples;
use crate::util::rng::Rng;
use anyhow::Result;

/// Monte-Carlo coverage of the alert-rate error bound at sample size
/// `n`. Uses the exact order-statistics law from the paper's own
/// derivation (Eq. 9): the k-th order statistic of n U(0,1) draws is
/// Beta(k, n-k+1), so the threshold is sampled directly instead of
/// sorting n floats per trial (identical distribution, O(1) per
/// trial). `coverage_empirical` cross-checks this equivalence on a
/// small n.
pub fn coverage(a: f64, delta: f64, n: usize, trials: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let k = (((1.0 - a) * n as f64).round() as usize).clamp(1, n);
    let mut within = 0usize;
    for _ in 0..trials {
        let threshold = rng.beta(k as f64, (n - k + 1) as f64);
        // Under U(0,1) the true alert rate of `threshold` is 1-t.
        let true_alert = 1.0 - threshold;
        if (true_alert - a).abs() <= delta * a {
            within += 1;
        }
    }
    within as f64 / trials as f64
}

/// Literal mechanism (sort + pick the k-th lowest score), used to
/// validate the Beta shortcut on a tractable n.
pub fn coverage_empirical(a: f64, delta: f64, n: usize, trials: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let mut within = 0usize;
    for _ in 0..trials {
        let mut sample: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
        sample.sort_by(|x, y| x.partial_cmp(y).unwrap());
        let k = (((1.0 - a) * n as f64).round() as usize).min(n - 1);
        let true_alert = 1.0 - sample[k];
        if (true_alert - a).abs() <= delta * a {
            within += 1;
        }
    }
    within as f64 / trials as f64
}

pub fn run() -> Result<String> {
    let mut out = String::new();
    out.push_str("== Appendix A / Eq. 5: sample-size bound for quantile fitting ==\n");
    out.push_str("   n = z^2 (1-a) / (delta^2 a), z = 1.96 (95% confidence)\n\n");

    let z = 1.96;
    let trials = 2000;
    let mut table = Table::new(&[
        "alert rate a", "delta", "n (Eq.5)", "coverage@n", "coverage@n/4",
    ]);
    let mut pass = true;
    let mut results = vec![];
    for (i, &a) in [0.001, 0.005, 0.01, 0.05].iter().enumerate() {
        for (j, &delta) in [0.1, 0.2].iter().enumerate() {
            let n = required_samples(a, delta, z)? as usize;
            let cov = coverage(a, delta, n, trials, 1000 + 17 * (i * 2 + j) as u64);
            let cov_quarter = coverage(a, delta, n / 4, trials, 2000 + 17 * (i * 2 + j) as u64);
            results.push((a, delta, n, cov, cov_quarter));
            table.row(vec![
                format!("{:.3}%", a * 100.0),
                format!("{delta}"),
                format!("{n}"),
                format!("{:.1}%", cov * 100.0),
                format!("{:.1}%", cov_quarter * 100.0),
            ]);
        }
    }
    out.push_str(&table.render());

    let mut report = String::from("\n  checks:\n");
    let mut check = |name: &str, ok: bool| {
        report.push_str(&format!("    [{}] {name}\n", if ok { "ok" } else { "FAIL" }));
        pass &= ok;
    };
    check(
        "coverage at n within [92%, 98%] for every cell",
        results.iter().all(|r| r.3 > 0.92 && r.3 < 0.98),
    );
    check(
        "bound is tight: n/4 coverage drops below 90% everywhere",
        results.iter().all(|r| r.4 < 0.90),
    );
    check(
        "n*a ~= z^2/delta^2 (paper's normality-condition remark)",
        results.iter().all(|r| {
            let na = r.2 as f64 * r.0;
            let target = z * z / (r.1 * r.1) * (1.0 - r.0);
            (na - target).abs() / target < 0.05
        }),
    );
    // Cross-check the Beta order-statistic shortcut against the
    // literal sort-and-pick mechanism on a tractable cell.
    let (a_c, d_c) = (0.05, 0.2);
    let n_c = required_samples(a_c, d_c, z)? as usize;
    let fast = coverage(a_c, d_c, n_c, trials, 31);
    let slow = coverage_empirical(a_c, d_c, n_c, 400, 32);
    check(
        "Beta(k, n-k+1) shortcut matches the literal mechanism",
        (fast - slow).abs() < 0.05,
    );
    out.push_str(&report);
    if !pass {
        out.push_str("  WARNING: Eq.5 validation deviates\n");
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    #[test]
    fn eq5_bound_validates() {
        let out = super::run().unwrap();
        assert!(!out.contains("[FAIL]"), "{out}");
    }
}
