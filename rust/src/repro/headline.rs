//! The headline serving claims (Sections 1-3): thousands of events per
//! second under 30ms p99 / 150ms p99.9 SLOs, with "negligible
//! overhead from the transformation pipeline".
//!
//! Drives real multi-tenant traffic through the full engine (router ->
//! enrichment -> PJRT inference on shared containers -> T^C -> A ->
//! tenant T^Q -> data lake) from concurrent client threads, then
//! measures the transformation pipeline in isolation.

use super::common;
use crate::config::Intent;
use crate::coordinator::{warm_up, Engine, ScoreRequest};
use crate::metrics::LatencyHistogram;
use crate::simulator::{TenantProfile, Workload};
use crate::transforms::{PosteriorCorrection, QuantileMap, ReferenceDistribution};
use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

const CONFIG: &str = r#"
routing:
  scoringRules:
  - description: "bank1 rides the 3-expert ensemble"
    condition:
      tenants: ["bank1"]
    targetPredictorName: "trio"
  - description: "bank2 rides a single model"
    condition:
      tenants: ["bank2"]
    targetPredictorName: "solo"
  - description: "everyone else on the shared trio"
    condition: {}
    targetPredictorName: "trio"
predictors:
- name: trio
  experts: [m1, m2, m3]
  quantile: identity
- name: solo
  experts: [m4]
  quantile: identity
"#;

pub struct HeadlineResult {
    pub throughput_eps: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub p999_ms: f64,
    pub transform_ns_per_event: f64,
}

pub fn measure(engine: &Engine, clients: usize, events_per_client: usize) -> Result<HeadlineResult> {
    let latency = Arc::new(LatencyHistogram::new());
    let done = Arc::new(AtomicU64::new(0));
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for c in 0..clients {
            let latency = Arc::clone(&latency);
            let done = Arc::clone(&done);
            let engine_ref = &*engine;
            scope.spawn(move || {
                let tenants = ["bank1", "bank2", "bank3"];
                let tenant = tenants[c % tenants.len()];
                let mut wl = Workload::new(
                    TenantProfile::new(tenant, 100 + c as u64, 0.4, 0.1),
                    999 + c as u64,
                );
                for i in 0..events_per_client {
                    let e = wl.next_event();
                    let req = ScoreRequest {
                        intent: Intent {
                            tenant: tenant.into(),
                            ..Intent::default()
                        },
                        entity: format!("c{c}-{i}"),
                        features: e.features,
                    };
                    let s = Instant::now();
                    if engine_ref.score(&req).is_ok() {
                        latency.record(s.elapsed().as_nanos() as u64);
                        done.fetch_add(1, Ordering::Relaxed);
                    }
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let n = done.load(Ordering::Relaxed);

    // Transformation pipeline in isolation (the "negligible overhead"
    // claim): T^C x3 + weighted mean + T^Q lookup per event.
    let pc = PosteriorCorrection::new(0.18)?;
    let reference = ReferenceDistribution::fraud_default();
    let refq = reference.quantile_grid(1025);
    let src: Vec<f64> = (0..1025).map(|i| (i as f64 / 1024.0).powi(2)).collect();
    let mut src = src;
    crate::transforms::quantile_fit::dedup_monotone(&mut src);
    let q = QuantileMap::new(src, refq)?;
    let iters = 2_000_000u64;
    let tt0 = Instant::now();
    let mut acc = 0.0f64;
    for i in 0..iters {
        let s = (i % 1000) as f64 / 1000.0;
        let c = (pc.apply(s) + pc.apply(s * 0.7) + pc.apply(s * 0.3)) / 3.0;
        acc += q.apply(c);
    }
    let transform_ns = tt0.elapsed().as_nanos() as f64 / iters as f64;
    std::hint::black_box(acc);

    Ok(HeadlineResult {
        throughput_eps: n as f64 / wall,
        p50_ms: latency.percentile_ns(50.0) as f64 / 1e6,
        p99_ms: latency.percentile_ns(99.0) as f64 / 1e6,
        p999_ms: latency.percentile_ns(99.9) as f64 / 1e6,
        transform_ns_per_event: transform_ns,
    })
}

pub fn run() -> Result<String> {
    // Enough client concurrency to exercise the dynamic batcher
    // (concurrent events coalesce into shared PJRT calls — "Perf log"
    // in EXPERIMENTS.md: batching took this host from 2.5k eps with a
    // 56ms p99 tail to ~10k eps with p99 < 10ms).
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4);
    run_scaled((4 * cores).clamp(8, 16), 3000)
}

pub fn run_scaled(clients: usize, events_per_client: usize) -> Result<String> {
    let mut out = String::new();
    out.push_str("== Headline: throughput & latency SLOs (Sections 1/3) ==\n\n");
    let engine = common::build_engine(CONFIG)?;
    let report = warm_up(&engine, 500, 7)?;
    out.push_str(&format!(
        "  warm-up: {} requests (cold p50 {:.2}ms -> warm p50 {:.2}ms)\n",
        report.requests,
        report.cold_p50_ns as f64 / 1e6,
        report.warm_p50_ns as f64 / 1e6
    ));
    let r = measure(&engine, clients, events_per_client)?;
    out.push_str(&format!(
        "  {} client threads x {} events, multi-tenant mix\n\n",
        clients, events_per_client
    ));
    out.push_str(&format!("  throughput: {:>10.0} events/s (paper cluster avg: 4500 eps)\n", r.throughput_eps));
    out.push_str(&format!("  latency:    p50 {:.3}ms  p99 {:.3}ms  p99.9 {:.3}ms\n", r.p50_ms, r.p99_ms, r.p999_ms));
    out.push_str(&format!(
        "  transformation pipeline alone: {:.0} ns/event ({:.4}% of a 30ms budget)\n",
        r.transform_ns_per_event,
        100.0 * r.transform_ns_per_event / 30e6
    ));

    let mut pass = true;
    let mut report_s = String::from("\n  SLO checks:\n");
    let mut check = |name: &str, ok: bool| {
        report_s.push_str(&format!("    [{}] {name}\n", if ok { "ok" } else { "FAIL" }));
        pass &= ok;
    };
    check("p99 < 30ms", r.p99_ms < 30.0);
    check("p99.9 < 150ms", r.p999_ms < 150.0);
    check(">= 1000 events/s single node (paper: >1000 eps)", r.throughput_eps >= 1000.0);
    check(
        "transformation overhead negligible (< 0.1% of latency budget)",
        r.transform_ns_per_event < 30_000.0,
    );
    out.push_str(&report_s);
    if !pass {
        out.push_str("  WARNING: SLO not met on this host\n");
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    #[test]
    fn headline_slos_hold() {
        if !crate::runtime::Manifest::default_root().join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        // Reduced volume for CI speed; the full run is `muse repro headline`.
        let out = super::run_scaled(4, 500).unwrap();
        // The SLO numbers are only meaningful with optimizations on;
        // `cargo test` builds debug, where we only require the harness
        // to complete. `cargo bench` / `muse repro headline` (release)
        // enforce the SLOs.
        if cfg!(debug_assertions) {
            assert!(out.contains("throughput"), "{out}");
        } else {
            assert!(!out.contains("[FAIL]"), "{out}");
        }
    }
}
