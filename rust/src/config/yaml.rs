//! A from-scratch YAML-subset parser for MUSE's declarative routing
//! configuration (paper Fig. 2). No `serde_yaml` exists in the offline
//! crate universe, and the config language only needs a disciplined
//! subset:
//!
//! * block mappings + block sequences with 2-space-ish indentation,
//! * inline (flow) sequences `["a", "b"]` and the empty map `{}`,
//! * scalars: double/single-quoted strings, bare strings, integers,
//!   floats, booleans, null,
//! * `#` comments and blank lines.
//!
//! The parse result is the crate's own `Json` value tree, so the
//! config schema layer shares accessors with the JSON manifest.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// Parse a YAML-subset document into a `Json` tree.
pub fn parse(input: &str) -> Result<Json> {
    let lines = logical_lines(input);
    if lines.is_empty() {
        return Ok(Json::Obj(BTreeMap::new()));
    }
    let mut pos = 0;
    let v = parse_block(&lines, &mut pos, lines[0].indent)?;
    if pos != lines.len() {
        bail!(
            "yaml: trailing content at line {} ('{}')",
            lines[pos].number,
            lines[pos].text
        );
    }
    Ok(v)
}

#[derive(Debug, Clone)]
struct Line {
    indent: usize,
    text: String, // content after indentation, comments stripped
    number: usize,
}

fn logical_lines(input: &str) -> Vec<Line> {
    let mut out = Vec::new();
    for (i, raw) in input.lines().enumerate() {
        let without_comment = strip_comment(raw);
        let trimmed_end = without_comment.trim_end();
        if trimmed_end.trim().is_empty() {
            continue;
        }
        let indent = trimmed_end.len() - trimmed_end.trim_start().len();
        if trimmed_end.trim_start().starts_with('\t') {
            // Keep the error story simple: tabs are not allowed.
            continue;
        }
        out.push(Line {
            indent,
            text: trimmed_end.trim_start().to_string(),
            number: i + 1,
        });
    }
    out
}

/// Strip a `#` comment unless it is inside a quoted string.
fn strip_comment(line: &str) -> String {
    let mut in_double = false;
    let mut in_single = false;
    let mut prev_escape = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' if !in_single && !prev_escape => in_double = !in_double,
            '\'' if !in_double => in_single = !in_single,
            '#' if !in_double && !in_single => {
                // YAML requires '#' to start a comment at line start or
                // after whitespace.
                if i == 0 || line[..i].ends_with(' ') {
                    return line[..i].to_string();
                }
            }
            _ => {}
        }
        prev_escape = c == '\\' && in_double && !prev_escape;
    }
    line.to_string()
}

/// Parse a block (mapping or sequence) at the given indentation.
fn parse_block(lines: &[Line], pos: &mut usize, indent: usize) -> Result<Json> {
    if *pos >= lines.len() {
        return Ok(Json::Null);
    }
    if lines[*pos].text.starts_with("- ") || lines[*pos].text == "-" {
        parse_sequence(lines, pos, indent)
    } else {
        parse_mapping(lines, pos, indent)
    }
}

fn parse_sequence(lines: &[Line], pos: &mut usize, indent: usize) -> Result<Json> {
    let mut items = Vec::new();
    while *pos < lines.len() {
        let line = &lines[*pos];
        if line.indent < indent {
            break;
        }
        if line.indent > indent {
            bail!("yaml line {}: unexpected indent in sequence", line.number);
        }
        if !(line.text.starts_with("- ") || line.text == "-") {
            break;
        }
        let rest = line.text[1..].trim_start().to_string();
        *pos += 1;
        if rest.is_empty() {
            // Item body is the following deeper block.
            if *pos < lines.len() && lines[*pos].indent > indent {
                let child_indent = lines[*pos].indent;
                items.push(parse_block(lines, pos, child_indent)?);
            } else {
                items.push(Json::Null);
            }
        } else if let Some((key, val)) = split_key(&rest) {
            // "- key: value" starts an inline mapping whose remaining
            // keys sit deeper than the dash.
            let mut map = BTreeMap::new();
            insert_entry(&mut map, key, val, lines, pos, indent + 2)?;
            while *pos < lines.len() && lines[*pos].indent > indent {
                let child = &lines[*pos];
                let (k, v) = split_key(&child.text)
                    .ok_or_else(|| anyhow!("yaml line {}: expected 'key:'", child.number))?;
                let child_indent = child.indent;
                *pos += 1;
                insert_entry(&mut map, k, v, lines, pos, child_indent)?;
            }
            items.push(Json::Obj(map));
        } else {
            items.push(parse_scalar(&rest)?);
        }
    }
    Ok(Json::Arr(items))
}

fn parse_mapping(lines: &[Line], pos: &mut usize, indent: usize) -> Result<Json> {
    let mut map = BTreeMap::new();
    while *pos < lines.len() {
        let line = &lines[*pos];
        if line.indent < indent {
            break;
        }
        if line.indent > indent {
            bail!("yaml line {}: unexpected indent in mapping", line.number);
        }
        if line.text.starts_with("- ") || line.text == "-" {
            break;
        }
        let (key, val) = split_key(&line.text)
            .ok_or_else(|| anyhow!("yaml line {}: expected 'key:' got '{}'", line.number, line.text))?;
        *pos += 1;
        insert_entry(&mut map, key, val, lines, pos, indent)?;
    }
    Ok(Json::Obj(map))
}

fn insert_entry(
    map: &mut BTreeMap<String, Json>,
    key: String,
    inline_val: Option<String>,
    lines: &[Line],
    pos: &mut usize,
    indent: usize,
) -> Result<()> {
    let value = match inline_val {
        Some(v) => parse_scalar(&v)?,
        None => {
            // Nested block (deeper indent) or empty value.
            if *pos < lines.len() && lines[*pos].indent > indent {
                let child_indent = lines[*pos].indent;
                parse_block(lines, pos, child_indent)?
            } else if *pos < lines.len()
                && lines[*pos].indent == indent
                && (lines[*pos].text.starts_with("- ") || lines[*pos].text == "-")
            {
                // Sequences are commonly written at the same indent as
                // their key.
                parse_sequence(lines, pos, indent)?
            } else {
                Json::Null
            }
        }
    };
    map.insert(key, value);
    Ok(())
}

/// Split "key: value" / "key:" into (key, Some(value)/None).
/// Returns None when the text is not a mapping entry.
fn split_key(text: &str) -> Option<(String, Option<String>)> {
    // Find the first ':' outside quotes followed by space/end.
    let mut in_double = false;
    let mut in_single = false;
    for (i, c) in text.char_indices() {
        match c {
            '"' if !in_single => in_double = !in_double,
            '\'' if !in_double => in_single = !in_single,
            ':' if !in_double && !in_single => {
                let after = &text[i + 1..];
                if after.is_empty() {
                    return Some((unquote_key(&text[..i]), None));
                }
                if after.starts_with(' ') {
                    let v = after.trim();
                    return Some((
                        unquote_key(&text[..i]),
                        if v.is_empty() { None } else { Some(v.to_string()) },
                    ));
                }
            }
            _ => {}
        }
    }
    None
}

fn unquote_key(k: &str) -> String {
    let k = k.trim();
    if (k.starts_with('"') && k.ends_with('"') && k.len() >= 2)
        || (k.starts_with('\'') && k.ends_with('\'') && k.len() >= 2)
    {
        k[1..k.len() - 1].to_string()
    } else {
        k.to_string()
    }
}

/// Parse a scalar or flow collection.
fn parse_scalar(text: &str) -> Result<Json> {
    let t = text.trim();
    if t == "{}" {
        return Ok(Json::Obj(BTreeMap::new()));
    }
    if t == "[]" {
        return Ok(Json::Arr(vec![]));
    }
    if t.starts_with('[') && t.ends_with(']') {
        return parse_flow_seq(t);
    }
    if t.starts_with('"') && t.ends_with('"') && t.len() >= 2 {
        return Ok(Json::Str(unescape_double(&t[1..t.len() - 1])));
    }
    if t.starts_with('\'') && t.ends_with('\'') && t.len() >= 2 {
        return Ok(Json::Str(t[1..t.len() - 1].replace("''", "'")));
    }
    match t {
        "null" | "~" | "Null" | "NULL" => return Ok(Json::Null),
        "true" | "True" | "TRUE" => return Ok(Json::Bool(true)),
        "false" | "False" | "FALSE" => return Ok(Json::Bool(false)),
        _ => {}
    }
    if let Ok(n) = t.parse::<f64>() {
        if !t.is_empty() && t != "." && !t.starts_with('+') {
            return Ok(Json::Num(n));
        }
    }
    Ok(Json::Str(t.to_string()))
}

fn parse_flow_seq(t: &str) -> Result<Json> {
    let inner = &t[1..t.len() - 1];
    let mut items = Vec::new();
    let mut depth = 0;
    let mut in_double = false;
    let mut in_single = false;
    let mut start = 0;
    for (i, c) in inner.char_indices() {
        match c {
            '"' if !in_single => in_double = !in_double,
            '\'' if !in_double => in_single = !in_single,
            '[' if !in_double && !in_single => depth += 1,
            ']' if !in_double && !in_single => depth -= 1,
            ',' if depth == 0 && !in_double && !in_single => {
                let piece = inner[start..i].trim();
                if !piece.is_empty() {
                    items.push(parse_scalar(piece)?);
                }
                start = i + 1;
            }
            _ => {}
        }
    }
    let piece = inner[start..].trim();
    if !piece.is_empty() {
        items.push(parse_scalar(piece)?);
    }
    Ok(Json::Arr(items))
}

fn unescape_double(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c == '\\' {
            match chars.next() {
                Some('n') => out.push('\n'),
                Some('t') => out.push('\t'),
                Some('"') => out.push('"'),
                Some('\\') => out.push('\\'),
                Some(other) => {
                    out.push('\\');
                    out.push(other);
                }
                None => out.push('\\'),
            }
        } else {
            out.push(c);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_paper_fig2_config() {
        let src = r#"
routing:
  scoringRules:
  - description: "Custom DAG for bank1"
    condition:
      tenants: ["bank1"]
    targetPredictorName: "bank1-predictor-v1"
  - description: "Custom DAG for tenants in US or LATAM, using schema v1"
    condition:
      geographies: ["NAMER", "LATAM"]
      schemas: ["fraud_v1"]
    targetPredictorName: "america-predictor-v1"
  - description: "Default DAG for cold start clients"
    condition: {}   # Catch-all
    targetPredictorName: "global-predictor-v3"
  shadowRules:
  - description: "Evaluate predictor v2 in shadow mode for bank1"
    condition:
      tenants: ["bank1"]
    targetPredictorNames: ["bank1-predictor-v2"]
"#;
        let v = parse(src).unwrap();
        let routing = v.get("routing").unwrap();
        let rules = routing.get("scoringRules").unwrap().as_arr().unwrap();
        assert_eq!(rules.len(), 3);
        assert_eq!(
            rules[0].get("targetPredictorName").unwrap().as_str(),
            Some("bank1-predictor-v1")
        );
        assert_eq!(
            rules[0]
                .get("condition")
                .unwrap()
                .get("tenants")
                .unwrap()
                .as_arr()
                .unwrap()[0]
                .as_str(),
            Some("bank1")
        );
        // Catch-all condition is an empty map.
        assert_eq!(rules[2].get("condition").unwrap().as_obj().unwrap().len(), 0);
        let shadows = routing.get("shadowRules").unwrap().as_arr().unwrap();
        assert_eq!(shadows.len(), 1);
        assert_eq!(
            shadows[0].get("targetPredictorNames").unwrap().as_arr().unwrap().len(),
            1
        );
    }

    #[test]
    fn scalars() {
        let v = parse("a: 1\nb: 2.5\nc: true\nd: null\ne: bare string\nf: \"q\"\n").unwrap();
        assert_eq!(v.req_f64("a").unwrap(), 1.0);
        assert_eq!(v.req_f64("b").unwrap(), 2.5);
        assert_eq!(v.get("c").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("d"), Some(&Json::Null));
        assert_eq!(v.req_str("e").unwrap(), "bare string");
        assert_eq!(v.req_str("f").unwrap(), "q");
    }

    #[test]
    fn flow_sequences() {
        let v = parse("xs: [1, 2, 3]\nys: [\"a\", 'b', c]\nempty: []\n").unwrap();
        assert_eq!(v.get("xs").unwrap().to_f64_vec().unwrap(), vec![1.0, 2.0, 3.0]);
        let ys = v.get("ys").unwrap().as_arr().unwrap();
        assert_eq!(ys[2].as_str(), Some("c"));
        assert_eq!(v.get("empty").unwrap().as_arr().unwrap().len(), 0);
    }

    #[test]
    fn nested_blocks() {
        let src = "outer:\n  middle:\n    inner: 7\n  other: x\n";
        let v = parse(src).unwrap();
        assert_eq!(
            v.get("outer").unwrap().get("middle").unwrap().req_f64("inner").unwrap(),
            7.0
        );
        assert_eq!(v.get("outer").unwrap().req_str("other").unwrap(), "x");
    }

    #[test]
    fn block_sequence_of_scalars() {
        let src = "items:\n- one\n- two\n- 3\n";
        let v = parse(src).unwrap();
        let items = v.get("items").unwrap().as_arr().unwrap();
        assert_eq!(items.len(), 3);
        assert_eq!(items[0].as_str(), Some("one"));
        assert_eq!(items[2].as_f64(), Some(3.0));
    }

    #[test]
    fn comments_and_blank_lines() {
        let src = "# full comment\na: 1  # trailing\n\nb: \"#notcomment\"\n";
        let v = parse(src).unwrap();
        assert_eq!(v.req_f64("a").unwrap(), 1.0);
        assert_eq!(v.req_str("b").unwrap(), "#notcomment");
    }

    #[test]
    fn empty_document() {
        let v = parse("   \n# only comments\n").unwrap();
        assert_eq!(v.as_obj().unwrap().len(), 0);
    }

    #[test]
    fn sequence_items_with_nested_maps() {
        let src = "rules:\n- name: a\n  weight: 1.5\n- name: b\n  weight: 2\n";
        let v = parse(src).unwrap();
        let rules = v.get("rules").unwrap().as_arr().unwrap();
        assert_eq!(rules.len(), 2);
        assert_eq!(rules[1].req_str("name").unwrap(), "b");
        assert_eq!(rules[1].req_f64("weight").unwrap(), 2.0);
    }

    #[test]
    fn rejects_bad_indent() {
        assert!(parse("a:\n  b: 1\n   c: 2\n").is_err());
    }

    #[test]
    fn single_quote_escape() {
        let v = parse("s: 'it''s'\n").unwrap();
        assert_eq!(v.req_str("s").unwrap(), "it's");
    }

    #[test]
    fn deeper_sequence_under_key() {
        let src = "k:\n  - 1\n  - 2\n";
        let v = parse(src).unwrap();
        assert_eq!(v.get("k").unwrap().to_f64_vec().unwrap(), vec![1.0, 2.0]);
    }
}
