//! Typed configuration schema: the declarative routing config of
//! paper Fig. 2 plus predictor and server definitions, parsed from the
//! YAML subset (`yaml.rs`) or JSON and validated up front so the hot
//! path never sees malformed config.

use crate::util::json::Json;
use anyhow::{bail, ensure, Context, Result};
use std::sync::Arc;

/// Request metadata evaluated by routing conditions. This is the
/// client's *intent* — never a model name (Section 2.5.1).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Intent {
    pub tenant: String,
    pub geography: String,
    pub schema: String,
    pub channel: String,
}

/// A routing condition; empty fields are wildcards. A condition with
/// all fields empty is the catch-all (`condition: {}`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Condition {
    pub tenants: Vec<String>,
    pub geographies: Vec<String>,
    pub schemas: Vec<String>,
    pub channels: Vec<String>,
}

impl Condition {
    pub fn matches(&self, intent: &Intent) -> bool {
        let hit = |allow: &[String], v: &str| allow.is_empty() || allow.iter().any(|a| a == v);
        hit(&self.tenants, &intent.tenant)
            && hit(&self.geographies, &intent.geography)
            && hit(&self.schemas, &intent.schema)
            && hit(&self.channels, &intent.channel)
    }

    pub fn is_catch_all(&self) -> bool {
        self.tenants.is_empty()
            && self.geographies.is_empty()
            && self.schemas.is_empty()
            && self.channels.is_empty()
    }

    fn from_json(v: &Json) -> Result<Condition> {
        let get_list = |key: &str| -> Result<Vec<String>> {
            match v.get(key) {
                None => Ok(vec![]),
                Some(Json::Arr(items)) => items
                    .iter()
                    .map(|i| {
                        i.as_str()
                            .map(str::to_string)
                            .with_context(|| format!("condition.{key} entries must be strings"))
                    })
                    .collect(),
                Some(_) => bail!("condition.{key} must be a list"),
            }
        };
        Ok(Condition {
            tenants: get_list("tenants")?,
            geographies: get_list("geographies")?,
            schemas: get_list("schemas")?,
            channels: get_list("channels")?,
        })
    }
}

/// Scoring rule: evaluated sequentially; the first match selects the
/// *live* predictor. Targets are `Arc<str>` so resolving a request
/// shares the name instead of allocating a fresh `String` per event.
#[derive(Debug, Clone, PartialEq)]
pub struct ScoringRule {
    pub description: String,
    pub condition: Condition,
    pub target_predictor: Arc<str>,
}

/// Shadow rule: evaluated in parallel; every match mirrors the request
/// to additional predictors whose responses go to the data lake.
#[derive(Debug, Clone, PartialEq)]
pub struct ShadowRule {
    pub description: String,
    pub condition: Condition,
    pub target_predictors: Vec<Arc<str>>,
}

#[derive(Debug, Clone, Default, PartialEq)]
pub struct RoutingConfig {
    pub scoring_rules: Vec<ScoringRule>,
    pub shadow_rules: Vec<ShadowRule>,
}

/// How a predictor's quantile transformation is initialised.
#[derive(Debug, Clone, PartialEq)]
pub enum QuantileMode {
    /// Cold-start default: Beta-mixture prior fitted on the training
    /// score distribution (Section 2.4).
    Default,
    /// Identity map (testing / raw passthrough — the Fig. 4
    /// "predictor raw" baseline).
    Identity,
    /// Custom, fitted per tenant from live scores (installed via the
    /// control plane; configs may also pre-declare it).
    Custom,
}

/// Declarative predictor definition (the `p = <M, A, T^Q>` tuple of
/// Section 2.2.2, by reference to the artifact manifest's models).
#[derive(Debug, Clone, PartialEq)]
pub struct PredictorConfig {
    pub name: String,
    /// Expert model names, resolved against the artifact manifest.
    pub experts: Vec<String>,
    /// Aggregation weights (defaults to uniform).
    pub weights: Vec<f64>,
    pub quantile_mode: QuantileMode,
    /// Reference distribution name ("fraud-default" | "uniform").
    pub reference: String,
    /// Apply posterior correction before aggregation (Eq. 3); single
    /// models skip it per the paper unless forced.
    pub posterior_correction: bool,
}

#[derive(Debug, Clone, PartialEq)]
pub struct ServerConfig {
    pub listen_addr: String,
    pub workers: usize,
    /// Dynamic batcher: max events per batch (must be one of the AOT
    /// batch variants) and max queueing delay in microseconds.
    pub max_batch: usize,
    pub max_batch_delay_us: u64,
    /// Admission cap for `POST /v1/score/batch`: max events per batch
    /// request (oversized payloads are rejected with 422, protecting
    /// the engine from unbounded single-request work).
    pub max_batch_events: usize,
    pub warmup_requests: usize,
    /// Data-lake retention cap: oldest records are evicted (per
    /// stripe) once the lake holds this many (0 = the lake's default
    /// capacity, 2^20). Quantile refits no longer replay full history
    /// (they consume lifecycle sketches), so the lake only needs
    /// enough depth for shadow validation and the repro harnesses.
    pub lake_max_records: usize,
    /// Ring stripes in the sharded data lake (`datalake` module docs):
    /// consecutive appends land on different stripes, so concurrent
    /// workers never write the same cache lines. Clamped internally to
    /// the retention cap.
    pub lake_shards: usize,
    /// Max HTTP request-body bytes; oversized requests are rejected
    /// with `413 Payload Too Large` before the body is read, so one
    /// client cannot balloon worker memory.
    pub max_body_bytes: usize,
    /// Stream `POST /v1/score/batch` bodies through the incremental
    /// parser (events reach the scoring sink as they parse, the body
    /// is never materialized). Off = the buffered path; responses are
    /// bitwise identical either way.
    pub stream_batch: bool,
    /// Tenant -> shed priority for ingress admission control. A
    /// tenant with priority `p` is shed only once the batcher queue
    /// exceeds `shedQueueDepth << p`, so higher-priority tenants
    /// survive deeper overload. Tenants not listed use
    /// `defaultPriority`.
    pub tenant_priorities: Vec<(String, u8)>,
    /// Shed priority for tenants absent from `tenantPriorities`.
    pub default_priority: u8,
    /// Batcher queue depth at which priority-0 tenants start being
    /// shed with `429 Too Many Requests` + `Retry-After`.
    /// 0 disables admission control entirely.
    pub shed_queue_depth: usize,
    /// Slowloris guards: deadline from a request's first byte to the
    /// end of its header section, and from there to the end of its
    /// body. Idle keep-alive connections carry no deadline.
    pub header_read_timeout_ms: u64,
    pub body_read_timeout_ms: u64,
    /// Max request header-section bytes (431 beyond this).
    pub max_header_bytes: usize,
    /// Max concurrently open connections; accepts beyond this are
    /// dropped immediately (counted in `ingress_over_capacity`).
    pub max_connections: usize,
    /// Shards in the tenant state plane (interner name maps and the
    /// handle-indexed slab registries: quantile slots, tenant event
    /// counters, routes, lifecycle feeds). More shards = less
    /// contention between concurrent onboarding threads; reads are
    /// wait-free at any count. Shard-count 1 reproduces the old
    /// single-cell copy-on-write layout.
    pub tenant_shards: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            listen_addr: "127.0.0.1:7461".to_string(),
            workers: 4,
            max_batch: 64,
            max_batch_delay_us: 500,
            max_batch_events: 1024,
            warmup_requests: 200,
            lake_max_records: 1_000_000,
            lake_shards: 8,
            max_body_bytes: 1 << 20,
            stream_batch: true,
            tenant_priorities: Vec::new(),
            default_priority: 0,
            shed_queue_depth: 0,
            header_read_timeout_ms: 5_000,
            body_read_timeout_ms: 15_000,
            max_header_bytes: 16 * 1024,
            max_connections: 8192,
            tenant_shards: 16,
        }
    }
}

/// Which T^Q re-fitting strategy the lifecycle autopilot uses when a
/// pair's fit gate (Eq. 5) or drift pipeline asks for a new map
/// (`lifecycle.calibrationStrategy`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CalibrationStrategy {
    /// The paper's empirical quantile mapping: live sketch quantile
    /// grid → reference quantile grid (Eq. 4). Exact on the observed
    /// sample, but tie-heavy adversarial score masses collapse its
    /// knots and fast attacker drift drags the whole map.
    #[default]
    QuantileMap,
    /// Full-range calibration (arXiv:2607.05481 regime): fit a smooth
    /// low-dof Beta-mixture to the live distribution and map through
    /// its analytic quantiles instead of raw empirical knots
    /// (`transforms::full_range`). Robust to ties and slower to chase
    /// an attacker's score mass.
    FullRange,
}

impl CalibrationStrategy {
    pub fn as_str(&self) -> &'static str {
        match self {
            CalibrationStrategy::QuantileMap => "quantileMap",
            CalibrationStrategy::FullRange => "fullRange",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "quantileMap" => Ok(CalibrationStrategy::QuantileMap),
            "fullRange" => Ok(CalibrationStrategy::FullRange),
            other => bail!(
                "lifecycle.calibrationStrategy must be 'quantileMap' or 'fullRange', got '{other}'"
            ),
        }
    }
}

/// Lifecycle-autopilot configuration (`lifecycle:` block): the
/// streaming-sketch feed, drift thresholds, Eq. 5 fit gate and the
/// shadow→promote control loop (`lifecycle` module). Disabled by
/// default — enabling it costs the data plane one wait-free feed-table
/// load plus one atomic ring append per scored event.
#[derive(Debug, Clone, PartialEq)]
pub struct LifecycleConfig {
    pub enabled: bool,
    /// Tenants the autopilot manages explicitly.
    pub tenants: Vec<String>,
    /// Also manage every tenant named in a scoring rule's condition.
    pub auto_discover: bool,
    /// Sketch compaction capacity `k` (memory/accuracy knob: rank
    /// error bound (2·(levels−1) + 2)/k, memory ≤ k·levels items per
    /// pair — see `lifecycle::sketch`).
    pub sketch_k: usize,
    /// Per-worker feed: number of ring stripes and cells per stripe.
    pub feed_stripes: usize,
    pub feed_capacity: usize,
    /// Drift thresholds (PSI > 0.25 = significant shift, by the
    /// standard interpretation bands; KS = max CDF gap).
    pub psi_threshold: f64,
    pub ks_threshold: f64,
    pub drift_bins: usize,
    /// Minimum detection-window samples before a drift evaluation.
    pub min_drift_samples: u64,
    /// Eq. 5 fit gate: target alert rate, relative error, z-score.
    pub alert_rate: f64,
    pub delta: f64,
    pub z: f64,
    /// Shadow validation: minimum mirrored samples and max per-bin
    /// share deviation vs the reference (`validate_shadow`).
    pub min_validation_samples: usize,
    pub validation_tolerance: f64,
    /// Ticks a candidate may sit in ShadowDeployed waiting for enough
    /// mirrored traffic before it is torn down (starvation guard: the
    /// shared lake ring may never retain `minValidationSamples` for a
    /// low-traffic tenant).
    pub shadow_timeout_ticks: u32,
    /// Ticks to hold off after a failed validation before re-arming.
    pub cooldown_ticks: u32,
    /// Background controller cadence (`lifecycle::spawn_controller`).
    pub check_interval_ms: u64,
    /// Decommission the replaced predictor after a promotion when no
    /// routing rule references it anymore.
    pub decommission_old: bool,
    /// Memory-budget tiers (bounded RSS at ~100k mostly-idle tenants;
    /// `lifecycle::controller` module docs). A pair whose one-tick
    /// ring pressure (samples drained + samples overwritten) reaches
    /// this gets (or keeps) the full-size **hot** feed ring; below it
    /// the pair runs a small **warm** ring.
    pub hot_feed_samples: u64,
    /// Consecutive zero-sample ticks after which a pair's feed ring is
    /// evicted entirely (**cold**: the ring is drained into the pair's
    /// sketch first, so eviction never loses a buffered sample).
    /// Cold pairs are re-promoted to warm when their data-lake pair
    /// count moves again; samples that arrived while cold are
    /// accounted in `lifecycle_cold_missed_samples`.
    pub cold_after_idle_ticks: u32,
    /// Warm-tier ring capacity (single stripe; rounded up to a power
    /// of two, minimum 64 — `ScoreFeed::new`).
    pub warm_feed_capacity: usize,
    /// Which T^Q fitting strategy the autopilot installs (initial fit
    /// and drift re-fit alike).
    pub calibration_strategy: CalibrationStrategy,
    /// Cold-start gate: once a fresh pair (no frozen baseline yet) has
    /// accumulated this many samples — but still fewer than the Eq. 5
    /// requirement — the controller fits a Beta-mixture prior
    /// (`coldstart::fit_mixture`, Eqs. 6-8) from those early samples
    /// and installs it as the tenant's provisional T^Q, so no-history
    /// tenants stop scoring through the identity map while the gate
    /// fills. 0 disables cold-start fitting.
    pub coldstart_min_samples: u64,
    /// Positive-class prior `w` for the cold-start mixture (paper:
    /// `w = P(y=1)`; labels aren't available at the feed, so this is
    /// configured, not estimated).
    pub coldstart_w: f64,
}

impl Default for LifecycleConfig {
    fn default() -> Self {
        LifecycleConfig {
            enabled: false,
            tenants: vec![],
            auto_discover: true,
            sketch_k: 1024,
            feed_stripes: 8,
            feed_capacity: 8192,
            psi_threshold: 0.25,
            ks_threshold: 0.15,
            drift_bins: 10,
            min_drift_samples: 512,
            alert_rate: 0.01,
            delta: 0.2,
            z: 1.96,
            min_validation_samples: 512,
            validation_tolerance: 0.1,
            shadow_timeout_ticks: 240,
            cooldown_ticks: 8,
            check_interval_ms: 1000,
            decommission_old: true,
            hot_feed_samples: 256,
            cold_after_idle_ticks: 8,
            warm_feed_capacity: 128,
            calibration_strategy: CalibrationStrategy::QuantileMap,
            coldstart_min_samples: 0,
            coldstart_w: 0.02,
        }
    }
}

/// Top-level MUSE configuration.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MuseConfig {
    pub routing: RoutingConfig,
    pub predictors: Vec<PredictorConfig>,
    pub server: ServerConfig,
    pub lifecycle: LifecycleConfig,
}

impl MuseConfig {
    /// Parse + validate from YAML text.
    pub fn from_yaml(text: &str) -> Result<MuseConfig> {
        let v = super::yaml::parse(text)?;
        MuseConfig::from_json(&v)
    }

    pub fn from_json(v: &Json) -> Result<MuseConfig> {
        let routing = match v.get("routing") {
            Some(r) => parse_routing(r)?,
            None => RoutingConfig::default(),
        };
        let mut predictors = vec![];
        if let Some(Json::Arr(items)) = v.get("predictors") {
            for p in items {
                predictors.push(parse_predictor(p)?);
            }
        }
        let server = match v.get("server") {
            Some(s) => parse_server(s)?,
            None => ServerConfig::default(),
        };
        let lifecycle = match v.get("lifecycle") {
            Some(l) => parse_lifecycle(l)?,
            None => LifecycleConfig::default(),
        };
        let cfg = MuseConfig {
            routing,
            predictors,
            server,
            lifecycle,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Cross-field validation: every routed predictor must exist, the
    /// catch-all (if any) must be last, weights arity must match.
    pub fn validate(&self) -> Result<()> {
        let names: Vec<&str> = self.predictors.iter().map(|p| p.name.as_str()).collect();
        {
            let mut sorted = names.clone();
            sorted.sort_unstable();
            for w in sorted.windows(2) {
                ensure!(w[0] != w[1], "duplicate predictor name '{}'", w[0]);
            }
        }
        for p in &self.predictors {
            ensure!(!p.experts.is_empty(), "predictor '{}' has no experts", p.name);
            ensure!(
                p.weights.len() == p.experts.len(),
                "predictor '{}': {} weights for {} experts",
                p.name,
                p.weights.len(),
                p.experts.len()
            );
            ensure!(
                p.weights.iter().all(|w| *w >= 0.0 && w.is_finite())
                    && p.weights.iter().sum::<f64>() > 0.0,
                "predictor '{}': invalid weights",
                p.name
            );
        }
        for (i, rule) in self.routing.scoring_rules.iter().enumerate() {
            ensure!(
                names.contains(&&*rule.target_predictor),
                "scoring rule {} targets unknown predictor '{}'",
                i,
                rule.target_predictor
            );
            if rule.condition.is_catch_all() {
                ensure!(
                    i == self.routing.scoring_rules.len() - 1,
                    "catch-all scoring rule must be last (rule {i} shadows later rules)"
                );
            }
        }
        for (i, rule) in self.routing.shadow_rules.iter().enumerate() {
            for t in &rule.target_predictors {
                ensure!(
                    names.contains(&&**t),
                    "shadow rule {i} targets unknown predictor '{t}'"
                );
            }
        }
        ensure!(self.server.workers >= 1, "server.workers must be >= 1");
        ensure!(self.server.max_batch >= 1, "server.max_batch must be >= 1");
        ensure!(
            self.server.max_batch_events >= 1,
            "server.max_batch_events must be >= 1"
        );
        ensure!(self.server.lake_shards >= 1, "server.lakeShards must be >= 1");
        ensure!(
            self.server.max_body_bytes >= 1024,
            "server.maxBodyBytes must be >= 1024 (scoring payloads alone are hundreds of bytes)"
        );
        ensure!(
            self.server.max_header_bytes >= 256,
            "server.maxHeaderBytes must be >= 256 (a bare request line plus Host is ~64 bytes)"
        );
        ensure!(
            self.server.max_connections >= 1,
            "server.maxConnections must be >= 1"
        );
        ensure!(
            self.server.header_read_timeout_ms >= 10 && self.server.body_read_timeout_ms >= 10,
            "server read timeouts must be >= 10 ms (lower values shed healthy clients)"
        );
        ensure!(
            self.server.default_priority <= 16,
            "server.defaultPriority must be <= 16 (shed threshold is shedQueueDepth << priority)"
        );
        for (tenant, p) in &self.server.tenant_priorities {
            ensure!(
                *p <= 16,
                "server.tenantPriorities['{tenant}'] must be <= 16 (shed threshold is shedQueueDepth << priority)"
            );
        }
        let lc = &self.lifecycle;
        ensure!(
            lc.alert_rate > 0.0 && lc.alert_rate < 1.0,
            "lifecycle.alertRate must be in (0,1)"
        );
        ensure!(lc.delta > 0.0, "lifecycle.delta must be positive");
        ensure!(lc.z > 0.0, "lifecycle.z must be positive");
        ensure!(lc.sketch_k >= 8, "lifecycle.sketchK must be >= 8");
        ensure!(lc.drift_bins >= 2, "lifecycle.driftBins must be >= 2");
        ensure!(
            lc.psi_threshold > 0.0 && lc.ks_threshold > 0.0,
            "lifecycle drift thresholds must be positive"
        );
        ensure!(
            lc.validation_tolerance > 0.0,
            "lifecycle.validationTolerance must be positive"
        );
        ensure!(
            lc.feed_stripes >= 1 && lc.feed_capacity >= 64,
            "lifecycle feed needs >= 1 stripe of >= 64 cells"
        );
        ensure!(
            lc.cold_after_idle_ticks >= 1,
            "lifecycle.coldAfterIdleTicks must be >= 1 (0 would evict every idle tick)"
        );
        ensure!(
            lc.warm_feed_capacity >= 1,
            "lifecycle.warmFeedCapacity must be >= 1"
        );
        ensure!(
            lc.min_drift_samples >= 1,
            "lifecycle.minDriftSamples must be >= 1 (drift on an empty window is not evaluable)"
        );
        ensure!(
            (0.0..=1.0).contains(&lc.coldstart_w),
            "lifecycle.coldstartW must be in [0,1] (it is the positive-class prior)"
        );
        ensure!(
            lc.coldstart_min_samples == 0 || lc.coldstart_min_samples >= 100,
            "lifecycle.coldstartMinSamples must be 0 (disabled) or >= 100 \
             (coldstart::fit_mixture needs >= 100 scores)"
        );
        ensure!(
            self.server.tenant_shards >= 1 && self.server.tenant_shards <= 4096,
            "server.tenantShards must be in 1..=4096"
        );
        ensure!(
            lc.shadow_timeout_ticks >= 1,
            "lifecycle.shadowTimeoutTicks must be >= 1"
        );
        // Starvation guard: the lake rings are shared by every
        // (tenant, predictor, live/shadow) stream, so a candidate's
        // retained mirrors plateau at its share of the rings. A cap
        // close to minValidationSamples could keep validation gated
        // forever. 0 resolves to the lake's default capacity.
        if lc.enabled {
            let effective = if self.server.lake_max_records == 0 {
                crate::datalake::DEFAULT_CAPACITY
            } else {
                self.server.lake_max_records
            };
            ensure!(
                effective >= 8 * lc.min_validation_samples,
                "server.lakeMaxRecords ({}) must be >= 8x lifecycle.minValidationSamples ({}) \
                 or 0 (default capacity), or shadow validation can starve",
                self.server.lake_max_records,
                lc.min_validation_samples
            );
        }
        Ok(())
    }
}

fn parse_routing(v: &Json) -> Result<RoutingConfig> {
    let mut scoring_rules = vec![];
    if let Some(Json::Arr(rules)) = v.get("scoringRules") {
        for r in rules {
            scoring_rules.push(ScoringRule {
                description: r.get("description").and_then(Json::as_str).unwrap_or("").to_string(),
                condition: Condition::from_json(r.get("condition").unwrap_or(&Json::Null))?,
                target_predictor: r
                    .req_str("targetPredictorName")
                    .context("scoring rule missing targetPredictorName")?
                    .into(),
            });
        }
    }
    let mut shadow_rules = vec![];
    if let Some(Json::Arr(rules)) = v.get("shadowRules") {
        for r in rules {
            let targets = match r.get("targetPredictorNames") {
                Some(Json::Arr(ts)) => ts
                    .iter()
                    .map(|t| {
                        t.as_str()
                            .map(Arc::<str>::from)
                            .context("targetPredictorNames must be strings")
                    })
                    .collect::<Result<Vec<_>>>()?,
                _ => bail!("shadow rule missing targetPredictorNames"),
            };
            shadow_rules.push(ShadowRule {
                description: r.get("description").and_then(Json::as_str).unwrap_or("").to_string(),
                condition: Condition::from_json(r.get("condition").unwrap_or(&Json::Null))?,
                target_predictors: targets,
            });
        }
    }
    Ok(RoutingConfig {
        scoring_rules,
        shadow_rules,
    })
}

fn parse_predictor(v: &Json) -> Result<PredictorConfig> {
    let name = v.req_str("name")?.to_string();
    let experts: Vec<String> = match v.get("experts") {
        Some(Json::Arr(es)) => es
            .iter()
            .map(|e| {
                e.as_str()
                    .map(str::to_string)
                    .with_context(|| format!("predictor '{name}': experts must be strings"))
            })
            .collect::<Result<Vec<_>>>()?,
        _ => bail!("predictor '{name}' missing experts list"),
    };
    let weights = match v.get("weights") {
        Some(w) => w
            .to_f64_vec()
            .with_context(|| format!("predictor '{name}': weights must be numbers"))?,
        None => vec![1.0; experts.len()],
    };
    let quantile_mode = match v.get("quantile").and_then(Json::as_str).unwrap_or("default") {
        "default" => QuantileMode::Default,
        "identity" | "raw" => QuantileMode::Identity,
        "custom" => QuantileMode::Custom,
        other => bail!("predictor '{name}': unknown quantile mode '{other}'"),
    };
    let reference = v
        .get("reference")
        .and_then(Json::as_str)
        .unwrap_or("fraud-default")
        .to_string();
    let posterior_correction = v
        .get("posteriorCorrection")
        .and_then(Json::as_bool)
        .unwrap_or(experts.len() > 1); // paper: ensembles only, by default
    Ok(PredictorConfig {
        name,
        experts,
        weights,
        quantile_mode,
        reference,
        posterior_correction,
    })
}

fn parse_lifecycle(v: &Json) -> Result<LifecycleConfig> {
    let d = LifecycleConfig::default();
    let tenants = match v.get("tenants") {
        None => vec![],
        Some(Json::Arr(ts)) => ts
            .iter()
            .map(|t| {
                t.as_str()
                    .map(str::to_string)
                    .context("lifecycle.tenants entries must be strings")
            })
            .collect::<Result<Vec<_>>>()?,
        Some(_) => bail!("lifecycle.tenants must be a list"),
    };
    let get_f64 = |k: &str, dv: f64| v.get(k).and_then(Json::as_f64).unwrap_or(dv);
    let get_usize = |k: &str, dv: usize| v.get(k).and_then(Json::as_usize).unwrap_or(dv);
    let get_bool = |k: &str, dv: bool| v.get(k).and_then(Json::as_bool).unwrap_or(dv);
    Ok(LifecycleConfig {
        enabled: get_bool("enabled", d.enabled),
        tenants,
        auto_discover: get_bool("autoDiscover", d.auto_discover),
        sketch_k: get_usize("sketchK", d.sketch_k),
        feed_stripes: get_usize("feedStripes", d.feed_stripes),
        feed_capacity: get_usize("feedCapacity", d.feed_capacity),
        psi_threshold: get_f64("psiThreshold", d.psi_threshold),
        ks_threshold: get_f64("ksThreshold", d.ks_threshold),
        drift_bins: get_usize("driftBins", d.drift_bins),
        min_drift_samples: v
            .get("minDriftSamples")
            .and_then(Json::as_u64)
            .unwrap_or(d.min_drift_samples),
        alert_rate: get_f64("alertRate", d.alert_rate),
        delta: get_f64("delta", d.delta),
        z: get_f64("z", d.z),
        min_validation_samples: get_usize("minValidationSamples", d.min_validation_samples),
        validation_tolerance: get_f64("validationTolerance", d.validation_tolerance),
        shadow_timeout_ticks: v
            .get("shadowTimeoutTicks")
            .and_then(Json::as_u64)
            .unwrap_or(d.shadow_timeout_ticks as u64) as u32,
        cooldown_ticks: v
            .get("cooldownTicks")
            .and_then(Json::as_u64)
            .unwrap_or(d.cooldown_ticks as u64) as u32,
        check_interval_ms: v
            .get("checkIntervalMs")
            .and_then(Json::as_u64)
            .unwrap_or(d.check_interval_ms),
        decommission_old: get_bool("decommissionOld", d.decommission_old),
        hot_feed_samples: v
            .get("hotFeedSamples")
            .and_then(Json::as_u64)
            .unwrap_or(d.hot_feed_samples),
        cold_after_idle_ticks: v
            .get("coldAfterIdleTicks")
            .and_then(Json::as_u64)
            .unwrap_or(d.cold_after_idle_ticks as u64) as u32,
        warm_feed_capacity: get_usize("warmFeedCapacity", d.warm_feed_capacity),
        calibration_strategy: match v.get("calibrationStrategy").and_then(Json::as_str) {
            Some(s) => CalibrationStrategy::parse(s)?,
            None => d.calibration_strategy,
        },
        coldstart_min_samples: v
            .get("coldstartMinSamples")
            .and_then(Json::as_u64)
            .unwrap_or(d.coldstart_min_samples),
        coldstart_w: get_f64("coldstartW", d.coldstart_w),
    })
}

fn parse_server(v: &Json) -> Result<ServerConfig> {
    let d = ServerConfig::default();
    Ok(ServerConfig {
        listen_addr: v
            .get("listenAddr")
            .and_then(Json::as_str)
            .unwrap_or(&d.listen_addr)
            .to_string(),
        workers: v.get("workers").and_then(Json::as_usize).unwrap_or(d.workers),
        max_batch: v.get("maxBatch").and_then(Json::as_usize).unwrap_or(d.max_batch),
        max_batch_delay_us: v
            .get("maxBatchDelayUs")
            .and_then(Json::as_u64)
            .unwrap_or(d.max_batch_delay_us),
        max_batch_events: v
            .get("maxBatchEvents")
            .and_then(Json::as_usize)
            .unwrap_or(d.max_batch_events),
        warmup_requests: v
            .get("warmupRequests")
            .and_then(Json::as_usize)
            .unwrap_or(d.warmup_requests),
        lake_max_records: v
            .get("lakeMaxRecords")
            .and_then(Json::as_usize)
            .unwrap_or(d.lake_max_records),
        lake_shards: v
            .get("lakeShards")
            .and_then(Json::as_usize)
            .unwrap_or(d.lake_shards),
        max_body_bytes: v
            .get("maxBodyBytes")
            .and_then(Json::as_usize)
            .unwrap_or(d.max_body_bytes),
        stream_batch: v
            .get("streamBatch")
            .and_then(Json::as_bool)
            .unwrap_or(d.stream_batch),
        tenant_priorities: match v.get("tenantPriorities") {
            None => d.tenant_priorities,
            Some(Json::Obj(m)) => m
                .iter()
                .map(|(tenant, p)| {
                    p.as_usize()
                        .filter(|p| *p <= u8::MAX as usize)
                        .map(|p| (tenant.clone(), p as u8))
                        .with_context(|| {
                            format!(
                                "server.tenantPriorities['{tenant}'] must be a small non-negative integer"
                            )
                        })
                })
                .collect::<Result<Vec<_>>>()?,
            Some(_) => bail!("server.tenantPriorities must be a map of tenant -> priority"),
        },
        default_priority: v
            .get("defaultPriority")
            .and_then(Json::as_usize)
            .unwrap_or(d.default_priority as usize) as u8,
        shed_queue_depth: v
            .get("shedQueueDepth")
            .and_then(Json::as_usize)
            .unwrap_or(d.shed_queue_depth),
        header_read_timeout_ms: v
            .get("headerReadTimeoutMs")
            .and_then(Json::as_usize)
            .unwrap_or(d.header_read_timeout_ms as usize) as u64,
        body_read_timeout_ms: v
            .get("bodyReadTimeoutMs")
            .and_then(Json::as_usize)
            .unwrap_or(d.body_read_timeout_ms as usize) as u64,
        max_header_bytes: v
            .get("maxHeaderBytes")
            .and_then(Json::as_usize)
            .unwrap_or(d.max_header_bytes),
        max_connections: v
            .get("maxConnections")
            .and_then(Json::as_usize)
            .unwrap_or(d.max_connections),
        tenant_shards: v
            .get("tenantShards")
            .and_then(Json::as_usize)
            .unwrap_or(d.tenant_shards),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const FULL: &str = r#"
routing:
  scoringRules:
  - description: "Custom DAG for bank1"
    condition:
      tenants: ["bank1"]
    targetPredictorName: "bank1-v1"
  - description: "Default DAG"
    condition: {}
    targetPredictorName: "global-v3"
  shadowRules:
  - description: "Shadow v2 for bank1"
    condition:
      tenants: ["bank1"]
    targetPredictorNames: ["bank1-v2"]
predictors:
- name: bank1-v1
  experts: [m1, m2]
  weights: [1.0, 1.0]
  quantile: custom
- name: bank1-v2
  experts: [m1, m2, m3]
  quantile: default
- name: global-v3
  experts: [m1]
  quantile: default
server:
  workers: 8
  maxBatch: 64
  maxBatchEvents: 512
"#;

    #[test]
    fn parses_full_config() {
        let cfg = MuseConfig::from_yaml(FULL).unwrap();
        assert_eq!(cfg.routing.scoring_rules.len(), 2);
        assert_eq!(cfg.routing.shadow_rules.len(), 1);
        assert_eq!(cfg.predictors.len(), 3);
        assert_eq!(cfg.server.workers, 8);
        assert_eq!(cfg.server.max_batch_events, 512);
        // Uniform default weights.
        assert_eq!(cfg.predictors[1].weights, vec![1.0, 1.0, 1.0]);
        // Ensembles get posterior correction by default, singles don't.
        assert!(cfg.predictors[0].posterior_correction);
        assert!(!cfg.predictors[2].posterior_correction);
    }

    #[test]
    fn condition_matching() {
        let c = Condition {
            tenants: vec!["bank1".into()],
            geographies: vec![],
            schemas: vec!["fraud_v1".into()],
            channels: vec![],
        };
        let mut intent = Intent {
            tenant: "bank1".into(),
            schema: "fraud_v1".into(),
            ..Intent::default()
        };
        assert!(c.matches(&intent));
        intent.schema = "fraud_v2".into();
        assert!(!c.matches(&intent));
        intent.schema = "fraud_v1".into();
        intent.tenant = "bank2".into();
        assert!(!c.matches(&intent));
        assert!(Condition::default().matches(&intent)); // catch-all
    }

    #[test]
    fn rejects_unknown_predictor_target() {
        let bad = FULL.replace("targetPredictorName: \"global-v3\"", "targetPredictorName: \"nope\"");
        assert!(MuseConfig::from_yaml(&bad).is_err());
    }

    #[test]
    fn rejects_catch_all_before_end() {
        let src = r#"
routing:
  scoringRules:
  - description: "catch all first"
    condition: {}
    targetPredictorName: "a"
  - description: "never reached"
    condition:
      tenants: ["x"]
    targetPredictorName: "a"
predictors:
- name: a
  experts: [m1]
"#;
        let err = MuseConfig::from_yaml(src).unwrap_err().to_string();
        assert!(err.contains("catch-all"), "{err}");
    }

    #[test]
    fn rejects_duplicate_predictors() {
        let src = "predictors:\n- name: a\n  experts: [m1]\n- name: a\n  experts: [m2]\n";
        assert!(MuseConfig::from_yaml(src).is_err());
    }

    #[test]
    fn rejects_weight_arity_mismatch() {
        let src = "predictors:\n- name: a\n  experts: [m1, m2]\n  weights: [1.0]\n";
        assert!(MuseConfig::from_yaml(src).is_err());
    }

    #[test]
    fn rejects_unknown_quantile_mode() {
        let src = "predictors:\n- name: a\n  experts: [m1]\n  quantile: sideways\n";
        assert!(MuseConfig::from_yaml(src).is_err());
    }

    #[test]
    fn empty_config_is_valid() {
        let cfg = MuseConfig::from_yaml("").unwrap();
        assert!(cfg.routing.scoring_rules.is_empty());
        assert_eq!(cfg.server.workers, ServerConfig::default().workers);
        assert_eq!(cfg.server.max_batch_events, 1024);
    }

    #[test]
    fn rejects_zero_max_batch_events() {
        assert!(MuseConfig::from_yaml("server:\n  maxBatchEvents: 0\n").is_err());
    }

    #[test]
    fn shadow_rule_requires_targets() {
        let src = "routing:\n  shadowRules:\n  - description: x\n    condition: {}\n";
        assert!(MuseConfig::from_yaml(src).is_err());
    }

    #[test]
    fn lifecycle_defaults_are_off_but_valid() {
        let cfg = MuseConfig::from_yaml("").unwrap();
        assert!(!cfg.lifecycle.enabled);
        assert!(cfg.lifecycle.auto_discover);
        assert_eq!(cfg.lifecycle.sketch_k, 1024);
        assert_eq!(cfg.lifecycle, LifecycleConfig::default());
    }

    #[test]
    fn lifecycle_block_parses() {
        let src = r#"
lifecycle:
  enabled: true
  tenants: ["bank1", "bank2"]
  autoDiscover: false
  sketchK: 2048
  psiThreshold: 0.3
  ksThreshold: 0.2
  alertRate: 0.05
  minDriftSamples: 1024
  validationTolerance: 0.08
  checkIntervalMs: 250
  decommissionOld: false
"#;
        let cfg = MuseConfig::from_yaml(src).unwrap();
        let lc = &cfg.lifecycle;
        assert!(lc.enabled);
        assert_eq!(lc.tenants, vec!["bank1", "bank2"]);
        assert!(!lc.auto_discover);
        assert_eq!(lc.sketch_k, 2048);
        assert_eq!(lc.psi_threshold, 0.3);
        assert_eq!(lc.ks_threshold, 0.2);
        assert_eq!(lc.alert_rate, 0.05);
        assert_eq!(lc.min_drift_samples, 1024);
        assert_eq!(lc.validation_tolerance, 0.08);
        assert_eq!(lc.check_interval_ms, 250);
        assert!(!lc.decommission_old);
        // Unspecified knobs keep their defaults.
        assert_eq!(lc.delta, 0.2);
        assert_eq!(lc.cooldown_ticks, 8);
    }

    #[test]
    fn lifecycle_rejects_degenerate_knobs() {
        for bad in [
            "lifecycle:\n  alertRate: 0.0\n",
            "lifecycle:\n  alertRate: 1.5\n",
            "lifecycle:\n  sketchK: 2\n",
            "lifecycle:\n  driftBins: 1\n",
            "lifecycle:\n  validationTolerance: 0.0\n",
            "lifecycle:\n  feedCapacity: 2\n",
            "lifecycle:\n  shadowTimeoutTicks: 0\n",
        ] {
            assert!(MuseConfig::from_yaml(bad).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn server_observation_plane_knobs_parse_and_validate() {
        let cfg =
            MuseConfig::from_yaml("server:\n  lakeShards: 16\n  maxBodyBytes: 4096\n").unwrap();
        assert_eq!(cfg.server.lake_shards, 16);
        assert_eq!(cfg.server.max_body_bytes, 4096);
        let d = MuseConfig::from_yaml("").unwrap();
        assert_eq!(d.server.lake_shards, 8);
        assert_eq!(d.server.max_body_bytes, 1 << 20);
        assert!(MuseConfig::from_yaml("server:\n  lakeShards: 0\n").is_err());
        assert!(MuseConfig::from_yaml("server:\n  maxBodyBytes: 100\n").is_err());
    }

    #[test]
    fn server_ingress_knobs_parse_and_validate() {
        let cfg = MuseConfig::from_yaml(
            "server:\n  streamBatch: false\n  shedQueueDepth: 128\n  defaultPriority: 1\n  tenantPriorities:\n    vip: 4\n    bulk: 0\n  headerReadTimeoutMs: 250\n  bodyReadTimeoutMs: 900\n  maxHeaderBytes: 4096\n  maxConnections: 512\n",
        )
        .unwrap();
        assert!(!cfg.server.stream_batch);
        assert_eq!(cfg.server.shed_queue_depth, 128);
        assert_eq!(cfg.server.default_priority, 1);
        assert_eq!(cfg.server.header_read_timeout_ms, 250);
        assert_eq!(cfg.server.body_read_timeout_ms, 900);
        assert_eq!(cfg.server.max_header_bytes, 4096);
        assert_eq!(cfg.server.max_connections, 512);
        // BTreeMap source: entries arrive sorted by tenant.
        assert_eq!(
            cfg.server.tenant_priorities,
            vec![("bulk".to_string(), 0), ("vip".to_string(), 4)]
        );

        let d = MuseConfig::from_yaml("").unwrap();
        assert!(d.server.stream_batch, "streaming ingress is the default");
        assert_eq!(d.server.shed_queue_depth, 0, "admission control defaults off");
        assert_eq!(d.server.max_header_bytes, 16 * 1024);
        assert_eq!(d.server.max_connections, 8192);
        assert_eq!(d.server.header_read_timeout_ms, 5_000);
        assert_eq!(d.server.body_read_timeout_ms, 15_000);
        assert!(d.server.tenant_priorities.is_empty());
    }

    #[test]
    fn server_ingress_knobs_reject_nonsense() {
        assert!(MuseConfig::from_yaml("server:\n  maxHeaderBytes: 10\n").is_err());
        assert!(MuseConfig::from_yaml("server:\n  maxConnections: 0\n").is_err());
        assert!(MuseConfig::from_yaml("server:\n  headerReadTimeoutMs: 1\n").is_err());
        assert!(MuseConfig::from_yaml("server:\n  defaultPriority: 40\n").is_err());
        assert!(
            MuseConfig::from_yaml("server:\n  tenantPriorities:\n    vip: 40\n").is_err(),
            "priority over 16 would overflow the shift"
        );
        assert!(
            MuseConfig::from_yaml("server:\n  tenantPriorities: 3\n").is_err(),
            "tenantPriorities must be a map"
        );
    }

    #[test]
    fn lifecycle_rejects_starvable_lake_cap() {
        // A lake ring barely larger than the validation window can
        // keep a shadow's retained mirrors below the gate forever.
        let bad = "server:\n  lakeMaxRecords: 1000\nlifecycle:\n  enabled: true\n";
        let err = MuseConfig::from_yaml(bad).unwrap_err().to_string();
        assert!(err.contains("lakeMaxRecords"), "{err}");
        // Unbounded (0) is fine, as is a comfortably larger cap, as is
        // the same cap with the autopilot disabled.
        assert!(MuseConfig::from_yaml("server:\n  lakeMaxRecords: 0\nlifecycle:\n  enabled: true\n").is_ok());
        assert!(MuseConfig::from_yaml("server:\n  lakeMaxRecords: 5000\nlifecycle:\n  enabled: true\n").is_ok());
        assert!(MuseConfig::from_yaml("server:\n  lakeMaxRecords: 1000\n").is_ok());
    }
}
