//! Declarative configuration: the YAML-subset parser and the typed,
//! validated schema for routing (paper Fig. 2), predictors and the
//! server.

pub mod schema;
pub mod yaml;

pub use schema::{
    CalibrationStrategy, Condition, Intent, LifecycleConfig, MuseConfig, PredictorConfig,
    QuantileMode, RoutingConfig, ScoringRule, ServerConfig, ShadowRule,
};
